"""Online quality-estimation subsystem (core/quality + the vectorized
metrics.score_batch scorer): batched-vs-scalar metric parity and the
padding/length-mask edge cases, deterministic batch-keyed probe
sampling, per-parser quality EWMAs with the no-signal rule, the
α-retuning policy, and the controller's quality loop — α climbing
within operator bounds on a degrading corpus, and trace replay
reproducing the recorded α trajectory + a byte-identical record set
across a disk-store process restart (the ISSUE-4 acceptance bar)."""
import numpy as np
import pytest

from repro.core import metrics as M
from repro.core.backends import DiskResultStore, ResultCache
from repro.core.campaign import (CampaignController, CampaignExecutor,
                                 ControllerConfig, ExecutorConfig,
                                 RoundTelemetry)
from repro.core.engine import AdaParseEngine, EngineConfig
from repro.core.quality import (QualityMonitor, QualityProbe,
                                QualityProbeConfig, propose_alpha,
                                record_hypothesis, target_alpha)
from repro.data.synthetic import CorpusConfig, generate_corpus
from repro.launch.serve import build_ft_router


# -- metrics.score_batch ------------------------------------------------------


def _random_pairs(rng, n=8, max_tokens=60):
    refs, hyps = [], []
    for _ in range(n):
        length = rng.randint(1, max_tokens)
        ref = rng.randint(10, 40, size=length).astype(np.int32)
        hyp = ref.copy()
        flip = rng.rand(length) < 0.3
        hyp[flip] = rng.randint(10, 40, size=int(flip.sum()))
        refs.append(ref)
        hyps.append(hyp[:rng.randint(1, length + 1)])
    return refs, hyps


def test_score_batch_matches_scalar_metrics(rng):
    """The batched jitted scorers agree with the host scalar metrics
    doc-for-doc (BLEU) and corpus-mean (ROUGE-L / CAR)."""
    refs, hyps = _random_pairs(rng)
    s = M.score_batch(refs, hyps, max_len=64)
    host = np.array([M.bleu(r, h) for r, h in zip(refs, hyps)])
    np.testing.assert_allclose(s["bleu"], host, atol=1e-6)
    assert np.mean(s["rouge"]) == pytest.approx(
        M.rouge_l(refs, hyps, max_len=64))
    assert np.mean(s["car"]) == pytest.approx(M.car(refs, hyps, max_len=64))


def test_score_batch_empty_hypothesis_scores_zero():
    """An empty hypothesis (a parser that failed the doc) scores 0 on
    every metric instead of NaN-ing the batch; an empty reference never
    divides by zero either."""
    ref = np.arange(10, 30).astype(np.int32)
    empty = np.zeros(0, np.int32)
    s = M.score_batch([ref, empty], [empty, ref], max_len=32)
    assert s["bleu"][0] == 0.0 and s["rouge"][0] == 0.0
    assert s["car"][0] == 0.0
    assert np.all(np.isfinite([s[m][1] for m in M.SCORE_METRICS]))
    assert s["hyp_len"][0] == 0 and s["ref_len"][1] == 0


def test_score_batch_truncates_overlong_hypothesis(rng):
    """Hypotheses (and references) longer than the pad length are
    truncated to max_len and scored like the host metrics on the
    truncated streams — never overflow, never unmasked padding."""
    ref = rng.randint(10, 40, size=90).astype(np.int32)
    hyp = rng.randint(10, 40, size=120).astype(np.int32)
    s = M.score_batch([ref], [hyp], max_len=32)
    assert s["ref_len"][0] == 32 and s["hyp_len"][0] == 32
    assert s["bleu"][0] == pytest.approx(M.bleu(ref[:32], hyp[:32]),
                                         abs=1e-6)
    assert s["rouge"][0] == pytest.approx(M.rouge_l([ref], [hyp],
                                                    max_len=32))


def test_score_batch_validates_inputs():
    with pytest.raises(ValueError, match="one hypothesis per reference"):
        M.score_batch([np.zeros(3, np.int32)], [])
    with pytest.raises(ValueError, match="unknown score metrics"):
        M.score_batch([], [], metrics=("wer",))
    empty = M.score_batch([], [])
    assert all(len(empty[m]) == 0 for m in M.SCORE_METRICS)


# -- QualityProbe -------------------------------------------------------------


def test_probe_sampling_is_deterministic_and_batch_keyed():
    """should_probe is a pure function of (probe seed, batch key): two
    probe instances agree on every key, rate 0/1 are exact, and the
    sampled fraction tracks the configured rate."""
    cfg = QualityProbeConfig(probe_rate=0.25, seed=3)
    a, b = QualityProbe(cfg), QualityProbe(cfg)
    keys = range(2000)
    picks = [a.should_probe(k) for k in keys]
    assert picks == [b.should_probe(k) for k in keys]
    assert 0.18 < np.mean(picks) < 0.32
    assert not any(QualityProbe(QualityProbeConfig(probe_rate=0.0))
                   .should_probe(k) for k in range(50))
    assert all(QualityProbe(QualityProbeConfig(probe_rate=1.0))
               .should_probe(k) for k in range(50))
    # a different probe seed samples a different subset
    other = QualityProbe(QualityProbeConfig(probe_rate=0.25, seed=4))
    assert [other.should_probe(k) for k in keys] != picks


def test_probe_scores_records_per_parser(corpus, ft_router):
    """score_records groups a completed batch by emitting parser and
    returns (mean quality, doc count) per group."""
    ccfg, docs = corpus
    eng = AdaParseEngine(EngineConfig(alpha=0.2, batch_size=16),
                         ft_router, ccfg)
    batch = docs[75:91]
    recs = eng.process_batch(batch, batch_key=0)
    probe = QualityProbe(QualityProbeConfig(probe_rate=1.0, max_len=128))
    out = probe.score_records(batch, recs)
    assert set(out) == {r.parser for r in recs}
    assert sum(n for _, n in out.values()) == len(batch)
    for q, n in out.values():
        assert 0.0 <= q <= 1.0 and n > 0


def test_probe_config_validation():
    with pytest.raises(ValueError, match="probe_rate"):
        QualityProbeConfig(probe_rate=1.5)
    with pytest.raises(ValueError, match="metric"):
        QualityProbeConfig(metric="wer")
    with pytest.raises(ValueError, match="max_len"):
        QualityProbeConfig(max_len=0)


# -- QualityMonitor + retune policy -------------------------------------------


def test_monitor_ewma_blend_and_no_signal():
    mon = QualityMonitor(ewma=0.5)
    assert mon.estimate("pymupdf") is None
    assert mon.observe(None) == 0                  # unprobed/cached batch
    assert mon.observe({"pymupdf": (0.8, 16)}) == 16
    assert mon.estimate("pymupdf") == pytest.approx(0.8)
    mon.update("pymupdf", 0.4, 16)
    assert mon.estimate("pymupdf") == pytest.approx(0.6)
    mon.update("pymupdf", 0.0, 0)                  # zero docs: ignored
    assert mon.estimate("pymupdf") == pytest.approx(0.6)
    assert mon.n_docs["pymupdf"] == 32
    assert mon.snapshot() == {"pymupdf": pytest.approx(0.6)}
    with pytest.raises(ValueError, match="ewma"):
        QualityMonitor(ewma=0.0)


def test_target_alpha_is_cheapest_meeting_target():
    bounds = (0.05, 0.6)
    # exact interpolation point, clamped to the operator bounds
    assert target_alpha(0.3, 0.8, 0.55, bounds) == pytest.approx(0.5)
    assert target_alpha(0.3, 0.8, 0.9, bounds) == 0.6      # unreachable
    assert target_alpha(0.3, 0.8, 0.31, bounds) == 0.05    # barely short
    assert target_alpha(0.7, 0.8, 0.5, bounds) == 0.05     # already met
    assert target_alpha(0.3, 0.2, 0.5, bounds) == 0.05     # exp no better


def test_propose_alpha_policy():
    bounds, step = (0.05, 0.6), 0.1
    mon = QualityMonitor()

    def prop(alpha):
        return propose_alpha(alpha, mon, "cheap", "exp", bounds=bounds,
                             step=step, quality_target=0.5)

    assert prop(0.2) == (0.2, "no-signal")         # nothing observed
    mon.update("cheap", 0.2, 16)                   # below target, exp unseen
    assert prop(0.2) == (pytest.approx(0.3), "raise")   # bounded explore
    mon2 = QualityMonitor()
    mon2.update("cheap", 0.9, 16)                  # above target, exp unseen
    assert propose_alpha(0.2, mon2, "cheap", "exp", bounds=bounds,
                         step=step, quality_target=0.5) == (0.2, "hold")
    mon.update("exp", 0.8, 4)                      # est: cheap 0.2, exp 0.8
    # target_alpha = (0.5-0.2)/0.6 = 0.5; one step at a time
    assert prop(0.2) == (pytest.approx(0.3), "raise")
    assert prop(0.45) == (pytest.approx(0.5), "raise")
    assert prop(0.5) == (0.5, "hold")
    # quality recovered: steer back down toward lo, never below
    mon3 = QualityMonitor()
    mon3.update("cheap", 0.9, 16)
    mon3.update("exp", 0.95, 4)
    assert propose_alpha(0.3, mon3, "cheap", "exp", bounds=bounds,
                         step=step, quality_target=0.5) \
        == (pytest.approx(0.2), "lower")
    assert propose_alpha(0.05, mon3, "cheap", "exp", bounds=bounds,
                         step=step, quality_target=0.5) == (0.05, "hold")


# -- engine probe wiring ------------------------------------------------------


def test_engine_attaches_probe_quality_to_telemetry(corpus, ft_router):
    """Probed batches carry per-parser scores on BatchTelemetry; cache
    replays carry quality=None (excluded from the signal exactly like
    their timing is excluded from throughput)."""
    ccfg, docs = corpus
    probe = QualityProbe(QualityProbeConfig(probe_rate=1.0, max_len=128))
    eng = AdaParseEngine(EngineConfig(alpha=0.2, batch_size=16), ft_router,
                         ccfg, cache=ResultCache(), probe=probe)
    eng.process_batch(docs[75:91], batch_key=0)
    t = eng.telemetry[-1]
    assert t.quality is not None and not t.cached
    assert sum(n for _, n in t.quality.values()) == 16
    eng.process_batch(docs[75:91], batch_key=0)    # warm replay
    t2 = eng.telemetry[-1]
    assert t2.cached and t2.quality is None


def test_engine_set_alpha_invalidates_route_and_cache_tag(corpus,
                                                          ft_router):
    """set_alpha swaps the routing budget: the cache tag changes (records
    parsed at a different α must not replay), and a re-parse of the same
    batch routes more documents under the larger budget."""
    ccfg, docs = corpus
    cache = ResultCache()
    eng = AdaParseEngine(EngineConfig(alpha=0.05, batch_size=16),
                         ft_router, ccfg, cache=cache)
    tag0 = eng._cache_tag
    eng.process_batch(docs[75:91], batch_key=0)
    eng.set_alpha(0.05)                            # no-op
    assert eng._cache_tag is tag0
    eng.set_alpha(0.5)
    assert eng.cfg.alpha == 0.5 and eng._cache_tag != tag0
    misses0 = cache.misses
    recs = eng.process_batch(docs[75:91], batch_key=0)
    assert cache.misses == misses0 + 1             # tag change: no replay
    assert sum(r.parser == eng.cfg.expensive for r in recs) > 0


def test_probe_cost_charged_to_scoring_node(corpus, ft_router):
    """The probe cost model (ROADMAP "probe cost model"): scoring a
    probed batch costs cost_s_per_doc node-seconds on the node that
    completed it, recorded as BatchTelemetry.probe_s and included in
    total_s; unprobed and cache-replayed batches charge nothing."""
    ccfg, docs = corpus
    probe = QualityProbe(QualityProbeConfig(probe_rate=1.0, max_len=64,
                                            cost_s_per_doc=0.5))
    eng = AdaParseEngine(EngineConfig(alpha=0.2, batch_size=16),
                         ft_router, ccfg, cache=ResultCache(),
                         probe=probe)
    ns0 = eng.stats.node_seconds
    eng.process_batch(docs[75:91], batch_key=0)
    t = eng.telemetry[-1]
    assert t.probe_s == pytest.approx(0.5 * 16)
    assert t.total_s == pytest.approx(t.prepare_s + t.route_s
                                      + t.complete_s + t.probe_s)
    assert eng.stats.node_seconds - ns0 >= 0.5 * 16
    eng.process_batch(docs[75:91], batch_key=0)    # warm replay
    assert eng.telemetry[-1].probe_s == 0.0
    off = QualityProbe(QualityProbeConfig(probe_rate=0.0,
                                          cost_s_per_doc=0.5))
    eng2 = AdaParseEngine(EngineConfig(alpha=0.2, batch_size=16),
                          ft_router, ccfg, probe=off)
    eng2.process_batch(docs[91:107], batch_key=1)
    assert eng2.telemetry[-1].probe_s == 0.0
    with pytest.raises(ValueError, match="cost_s_per_doc"):
        QualityProbeConfig(cost_s_per_doc=-1.0)


def test_probe_cost_slows_observed_throughput(corpus, ft_router):
    """The controller's throughput EWMA sees probe overhead: the same
    campaign with a charged probe measures lower per-node docs/s and a
    longer wall than the free-probe run — while the record sets stay
    identical (probe cost is clock/telemetry only)."""
    ccfg, docs = corpus
    test = docs[75:]
    ecfg = EngineConfig(alpha=0.1, batch_size=8)
    xcfg = ExecutorConfig(n_nodes=2, straggler_rate=0.0)

    def run(cost):
        ctl = ControllerConfig(
            rounds=2, probe=QualityProbeConfig(probe_rate=1.0,
                                               cost_s_per_doc=cost))
        return CampaignController(ecfg, xcfg, ctl, ft_router,
                                  ccfg).run(test)

    free = run(0.0)
    charged = run(0.05)
    assert charged.wall_s > free.wall_s
    assert all(c < f for c, f in
               zip(charged.telemetry[0].throughput,
                   free.telemetry[0].throughput))
    assert set(free.records) == set(charged.records)
    for i in free.records:
        assert free.records[i].parser == charged.records[i].parser
        assert free.records[i].cost_s == charged.records[i].cost_s


# -- controller quality loop --------------------------------------------------


@pytest.fixture(scope="module")
def degrading():
    """Degrading corpus: an easy segment followed by an equally long
    hard/scanned segment where the cheap extraction parser collapses
    (the Fig. 3 crossing) — plus an FT router fit on held-out docs."""
    ccfg = CorpusConfig(n_docs=420, seed=0)
    docs = generate_corpus(ccfg)
    router = build_ft_router(docs[:96], ccfg, np.random.RandomState(1))
    pool = sorted(docs[96:], key=lambda d: d.difficulty)
    return ccfg, router, pool[:96] + pool[-96:]


def _mean_bleu(test, records):
    refs = [d.full_text() for d in test]
    hyps = [record_hypothesis(records[d.doc_id]) for d in test]
    return float(np.mean(M.score_batch(refs, hyps, max_len=192,
                                       metrics=("bleu",))["bleu"]))


_RETUNE_CTL = dict(rounds=6, alpha_bounds=(0.05, 0.9), alpha_step=0.3,
                   quality_target=0.5, quality_ewma=1.0,
                   probe=QualityProbeConfig(probe_rate=1.0, max_len=128))


def test_controller_retunes_alpha_within_bounds_and_beats_fixed(degrading):
    """The quality loop end-to-end: on the degrading corpus α climbs
    inside the operator bounds once the cheap parser collapses, every
    (round, α, quality) decision is recorded, and the retuned campaign
    beats the fixed-α campaign's output quality."""
    ccfg, router, test = degrading
    ecfg = EngineConfig(alpha=0.05, batch_size=16)
    xcfg = ExecutorConfig(n_nodes=2, straggler_rate=0.0)
    fixed = CampaignExecutor(ecfg, xcfg, router, ccfg).run(test)
    res = CampaignController(ecfg, xcfg, ControllerConfig(**_RETUNE_CTL),
                             router, ccfg).run(test)
    lo, hi = _RETUNE_CTL["alpha_bounds"]
    traj = res.alpha_trajectory
    assert len(res.telemetry) == res.rounds == 6
    assert all(lo <= a <= hi for a in traj)
    assert traj[0] == 0.05 and traj[-1] > 0.05     # climbed on the tail
    assert all(abs(b - a) <= _RETUNE_CTL["alpha_step"] + 1e-12
               for a, b in zip(traj, traj[1:]))    # round-granular steps
    assert any(t.decision == "raise" for t in res.telemetry)
    assert all(t.n_probe_docs > 0 for t in res.telemetry)
    assert res.node_alphas == [traj[-1]] * 2
    assert _mean_bleu(test, res.records) > _mean_bleu(test, fixed.records)


def test_controller_retune_trace_replay_restart_parity(degrading,
                                                       tmp_path):
    """The ISSUE-4 acceptance bar: replaying a recorded quality-retuned
    run's telemetry trace over the same disk store, from a fresh store
    instance and controller ("process restart"), reproduces the exact
    α trajectory and a byte-identical record set — every batch a cache
    hit, weights pinned too."""
    ccfg, router, test = degrading
    ecfg = EngineConfig(alpha=0.05, batch_size=16)
    xcfg = ExecutorConfig(n_nodes=2, straggler_rate=0.0)
    store = DiskResultStore(tmp_path / "cache")
    cold = CampaignController(ecfg, xcfg, ControllerConfig(**_RETUNE_CTL),
                              router, ccfg).run(test, cache=store)
    assert cold.alpha_trajectory[-1] > 0.05        # the run really retuned
    assert cold.cache_misses > 0

    store2 = DiskResultStore(tmp_path / "cache")
    ctl2 = ControllerConfig(telemetry_trace=cold.telemetry, **_RETUNE_CTL)
    warm = CampaignController(ecfg, xcfg, ctl2, router, ccfg).run(
        test, cache=store2)
    assert warm.alpha_trajectory == cold.alpha_trajectory
    assert warm.weight_history == cold.weight_history
    assert warm.cache_misses == 0
    assert warm.cache_hits == cold.cache_misses
    assert all(t.decision == "replay" for t in warm.telemetry)
    assert set(warm.records) == set(cold.records)
    for i in cold.records:
        a, b = cold.records[i], warm.records[i]
        assert a.parser == b.parser and a.cost_s == b.cost_s
        for pa, pb in zip(a.pages, b.pages):
            np.testing.assert_array_equal(pa, pb)


def test_controller_all_replay_rounds_report_no_signal(degrading,
                                                       tmp_path):
    """The stale-EWMA guard: cache replays produce no probe samples, so
    an un-replayed warm round must report no-signal and hold α rather
    than retune — divergence from the cold run stays round-granular
    (rounds whose records were cached at a different α re-parse and
    re-derive the signal)."""
    ccfg, router, test = degrading
    ecfg = EngineConfig(alpha=0.05, batch_size=16)
    xcfg = ExecutorConfig(n_nodes=2, straggler_rate=0.0)
    store = DiskResultStore(tmp_path / "cache")
    ctl = ControllerConfig(**_RETUNE_CTL)
    CampaignController(ecfg, xcfg, ctl, router, ccfg).run(test,
                                                          cache=store)
    warm = CampaignController(ecfg, xcfg, ctl, router, ccfg).run(
        test, cache=store)
    cached_rounds = [t for t in warm.telemetry if t.n_probe_docs == 0]
    assert cached_rounds, "warm run should replay at least the α=lo rounds"
    assert all(t.decision == "no-signal" for t in cached_rounds)
    # α never moved off a no-signal round: each such round's α equals
    # the following round's α unless that round produced fresh signal
    for a, b in zip(warm.telemetry, warm.telemetry[1:]):
        if a.n_probe_docs == 0:
            assert b.alpha == a.alpha


def test_controller_validates_quality_config(corpus, ft_router):
    ccfg, docs = corpus
    ecfg = EngineConfig(alpha=0.1, batch_size=8)
    xcfg = ExecutorConfig(n_nodes=2)
    with pytest.raises(ValueError, match="alpha_bounds"):
        CampaignController(ecfg, xcfg,
                           ControllerConfig(alpha_bounds=(0.5, 0.2)),
                           ft_router, ccfg)
    with pytest.raises(ValueError, match="outside alpha_bounds"):
        CampaignController(ecfg, xcfg,
                           ControllerConfig(alpha_bounds=(0.2, 0.5)),
                           ft_router, ccfg)
    with pytest.raises(ValueError, match="alpha_step"):
        CampaignController(ecfg, xcfg,
                           ControllerConfig(alpha_bounds=(0.05, 0.5),
                                            alpha_step=0.0),
                           ft_router, ccfg)


def test_bare_throughput_trace_leaves_alpha_retuning_live(degrading):
    """A PR-3 bare per-node docs/s trace pins the *weights* only: with
    alpha_bounds set, the retuner still derives α live from the probe
    signal instead of freezing it at the start value."""
    ccfg, router, test = degrading
    ecfg = EngineConfig(alpha=0.05, batch_size=16)
    xcfg = ExecutorConfig(n_nodes=2, straggler_rate=0.0)
    live = CampaignController(ecfg, xcfg, ControllerConfig(**_RETUNE_CTL),
                              router, ccfg).run(test)
    bare = [list(t.throughput) for t in live.telemetry]
    ctl = ControllerConfig(telemetry_trace=bare, **_RETUNE_CTL)
    res = CampaignController(ecfg, xcfg, ctl, router, ccfg).run(test)
    assert res.alpha_trajectory == live.alpha_trajectory
    assert res.alpha_trajectory[-1] > 0.05        # retuning stayed live
    assert any(t.decision == "raise" for t in res.telemetry)


def test_round_telemetry_trace_accepts_dicts(corpus, ft_router):
    """Trace entries may be RoundTelemetry, equivalent dicts, or the
    PR-3 bare throughput lists; dict/typed entries pin α as well."""
    ccfg, docs = corpus
    test = docs[75:]
    ecfg = EngineConfig(alpha=0.1, batch_size=8)
    xcfg = ExecutorConfig(n_nodes=2, straggler_rate=0.0)
    rec = CampaignController(ecfg, xcfg, ControllerConfig(rounds=3),
                             ft_router, ccfg).run(test)
    as_dicts = [{"throughput": t.throughput, "alpha": t.alpha}
                for t in rec.telemetry]
    replay = CampaignController(
        ecfg, xcfg, ControllerConfig(rounds=3, telemetry_trace=as_dicts),
        ft_router, ccfg).run(test)
    assert replay.weight_history == rec.weight_history
    assert replay.alpha_trajectory == rec.alpha_trajectory
    assert isinstance(rec.telemetry[0], RoundTelemetry)
