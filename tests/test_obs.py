"""The observability plane (core/obs): recorder noop contract, exact
log2-histogram folding, Prometheus text rendering, trace-writer
round-trips — and the span-conservation property on a real 4-worker
crash/flap process campaign: every emitted batch has exactly one
winning ``complete`` span, every dropped duplicate a ``dedup`` span,
every re-issue a ``reissue`` span, and the trace-file replay counts
match the ``ExecutorResult`` counters exactly."""
import json
from collections import Counter

import numpy as np
import pytest

from repro.core import obs
from repro.core.campaign import (CampaignExecutor, ExecutorConfig,
                                 FaultInjection)
from repro.core.engine import EngineConfig


# ---------------------------------------------------------------------------
# Recorder
# ---------------------------------------------------------------------------


def test_recorder_is_noop_by_default():
    rec = obs.recorder()
    assert not rec.enabled
    # recording through the noop is free of state: nothing to drain
    rec.span("prepare", 0, 0.0, 1.0)
    assert rec.drain() == []
    assert rec.dropped == 0


def test_ring_recorder_records_and_drains_in_order():
    rec = obs.RingRecorder(cap=64, node=3)
    for k in range(5):
        rec.span("prepare", k, float(k), 0.5)
    got = rec.drain()
    assert [s.trace for s in got] == [str(k) for k in range(5)]
    assert all(s.node == 3 for s in got)
    assert rec.drain() == []             # drained empty
    assert rec.dropped == 0


def test_ring_recorder_overflow_is_drop_counted_never_blocking():
    rec = obs.RingRecorder(cap=4, node=0)
    for k in range(10):
        rec.span("route", k, float(k), 0.1)
    got = rec.drain()
    assert len(got) == 4                 # bounded ring kept the newest
    assert rec.dropped == 6
    assert [s.trace for s in got] == ["6", "7", "8", "9"]


def test_configure_swaps_recorder_and_restores_noop():
    rec = obs.configure(enabled=True, cap=16, node=1)
    try:
        assert rec.enabled and obs.recorder() is rec
    finally:
        rec2 = obs.configure(enabled=False)
    assert not rec2.enabled and obs.recorder() is rec2


# ---------------------------------------------------------------------------
# Metrics: histograms fold exactly across processes
# ---------------------------------------------------------------------------


def test_histogram_buckets_are_log2_and_merge_exactly():
    a, b = obs.Registry(), obs.Registry()
    vals_a = [1e-6, 3e-4, 0.01, 0.8, 2.5]
    vals_b = [2e-5, 0.01, 0.01, 7.0]
    for v in vals_a:
        a.observe("lat", v)
    for v in vals_b:
        b.observe("lat", v)
    both = obs.Registry()
    for v in vals_a + vals_b:
        both.observe("lat", v)
    folded = obs.fold([a.snapshot(), b.snapshot()])
    # elementwise-exact: the fold of two processes' buckets equals one
    # process having observed every value
    assert folded["hists"]["lat"] == both.snapshot()["hists"]["lat"]
    assert folded["hists"]["lat"]["total"] == len(vals_a) + len(vals_b)


def test_histogram_quantiles_bracket_observations():
    r = obs.Registry()
    for v in [0.001] * 90 + [1.0] * 10:
        r.observe("lat", v)
    h = r.hists["lat"]
    assert h.quantile(0.5) == pytest.approx(0.001, rel=1.0)
    assert h.quantile(0.99) == pytest.approx(1.0, rel=1.0)


def test_fold_counters_add_and_diff_subtracts_baseline():
    a, b = obs.Registry(), obs.Registry()
    a.count("pool.batches_done", 3)
    b.count("pool.batches_done", 4)
    b.gauge("worker.queue_depth.n1", 2)
    folded = obs.fold([a.snapshot(), b.snapshot()])
    assert folded["counters"]["pool.batches_done"] == 7
    assert folded["gauges"]["worker.queue_depth.n1"] == 2
    base = a.snapshot()
    a.count("pool.batches_done", 5)
    a.observe("lat", 0.1)
    d = obs.diff(a.snapshot(), base)
    assert d["counters"] == {"pool.batches_done": 5}
    assert d["hists"]["lat"]["total"] == 1


def test_prometheus_text_renders_all_metric_kinds():
    r = obs.Registry()
    r.count("pool.reissued", 2)
    r.gauge("pool.window", 3)
    r.observe("engine.route_s", 0.01)
    text = obs.prometheus_text(obs.fold([r.snapshot()]))
    assert "# TYPE adaparse_pool_reissued counter" in text
    assert "adaparse_pool_reissued_total 2" in text
    assert "adaparse_pool_window 3" in text
    assert 'adaparse_engine_route_s_bucket{le="+Inf"} 1' in text
    assert "adaparse_engine_route_s_count 1" in text


# ---------------------------------------------------------------------------
# Trace writer
# ---------------------------------------------------------------------------


def _some_spans():
    return [
        obs.Span("prepare", "7", 0, 4242, 100.0, 0.5),
        obs.Span("complete", "7", 1, 4243, 100.6, 1.2, attempt=1,
                 cached=True),
        obs.Span("dedup", "7", 2, 4244, 101.9, 0.0, abandoned=True,
                 detail="lost completion race"),
    ]


def test_trace_writer_roundtrip_and_chrome_json(tmp_path):
    spans = _some_spans()
    chrome = obs.TraceWriter(tmp_path).write(spans, dropped=2)
    got, meta = obs.load_spans(tmp_path)
    assert got == spans                  # lossless jsonl round-trip
    assert meta == {"n_spans": 3, "dropped": 2}
    doc = json.loads(chrome.read_text())
    events = doc["traceEvents"]
    lanes = {e["args"]["name"] for e in events
             if e.get("name") == "thread_name"}
    assert {"worker 0", "worker 1", "worker 2"} <= lanes
    durs = [e for e in events if e.get("ph") == "X"]
    instants = [e for e in events if e.get("ph") == "i"]
    assert len(durs) == 2 and len(instants) == 1
    assert all(e["ts"] >= 100.0 * 1e6 for e in durs)


def test_obs_report_summarizes_stages_workers_and_causes(tmp_path):
    from repro.launch import obs_report

    spans = _some_spans() + [
        obs.Span("reissue", "8", 0, 4242, 102.0, 0.0,
                 detail="crash worker 2, prepare stage"),
        obs.Span("reissue", "9", 1, 4242, 102.5, 0.0,
                 detail="wedged worker 0, complete stage"),
    ]
    obs.TraceWriter(tmp_path).write(spans)
    rep = obs_report.main(["--trace-dir", str(tmp_path)])
    assert rep["n_spans"] == 5
    assert rep["stages"]["prepare"]["n"] == 1
    assert rep["stages"]["prepare"]["p50_s"] == pytest.approx(0.5)
    assert rep["reissue_causes"] == {"crash": 1, "wedged": 1}
    assert rep["complete"] == 1 and rep["complete_cached"] == 1
    assert rep["dedup"] == 1
    assert 0 in rep["workers"] and rep["workers"][0]["busy_s"] > 0
    text = obs_report.render(rep)
    assert "crash 1" in text and "wedged 1" in text


# ---------------------------------------------------------------------------
# Span conservation on a real crash/flap worker fleet
# ---------------------------------------------------------------------------


def test_obs_off_campaign_has_no_spans(corpus, ft_router):
    ccfg, docs = corpus
    test = docs[75:107]
    ecfg = EngineConfig(alpha=0.1, batch_size=16)
    res = CampaignExecutor(ecfg, ExecutorConfig(n_nodes=2,
                                                straggler_rate=0.0),
                           ft_router, ccfg).run(test)
    assert res.spans == []
    assert not obs.recorder().enabled    # the run left the noop in place


def test_span_conservation_4worker_crash_flap(corpus, ft_router):
    """The ISSUE-9 conservation laws, on the adversarial fleet shape
    (one worker hard-crashes, another mutes then flaps back, payloads
    over shm): replaying the trace file reproduces the executor's
    counters *exactly* — the trace is an audit log of the dedup gate,
    not a sample."""
    ccfg, docs = corpus
    test = docs[75:]
    ecfg = EngineConfig(alpha=0.1, batch_size=8)
    xcfg = ExecutorConfig(
        n_nodes=4, runtime="process", prefetch_depth=2,
        transport="shm", obs=True,
        heartbeat_timeout_s=2.0, heartbeat_interval_s=0.1,
        straggler_grace_s=2.5,
        fault_injection=FaultInjection(crash_after=((2, 1),),
                                       mute_after=((1, 0),),
                                       unmute_after=((1, 2),),
                                       mute_slowdown_s=0.9))
    res = CampaignExecutor(ecfg, xcfg, ft_router, ccfg).run(test)
    assert len(res.records) == len(test)
    assert res.reissued >= 1             # the faults actually fired

    by_name = Counter(s.name for s in res.spans)
    n_batches = -(-len(test) // 8)
    # exactly one winning complete span per emitted batch...
    assert by_name["complete"] == n_batches
    # ...each for a distinct batch key (no double emission)
    complete_keys = [s.trace for s in res.spans if s.name == "complete"]
    assert len(set(complete_keys)) == n_batches
    # every dropped duplicate left a dedup span, every re-issue a
    # reissue span, and cached wins carry the flag
    assert by_name["dedup"] == res.duplicates_dropped
    assert by_name["reissue"] == res.reissued
    assert sum(s.cached for s in res.spans
               if s.name == "complete") == res.cache_hits
    assert set(by_name) <= set(obs.SPAN_STAGES)

    # the folded fleet metrics agree with the executor counters
    c = res.obs_metrics["counters"]
    assert c.get("pool.batches_done", 0) == n_batches
    assert c.get("pool.dedup_dropped", 0) == res.duplicates_dropped
    assert c.get("pool.reissued", 0) == res.reissued
    assert c.get("pool.reissued_reparse", 0) == res.reissued_reparse

    # trace-file replay: writing + re-loading loses nothing
    import tempfile

    with tempfile.TemporaryDirectory() as td:
        obs.TraceWriter(td).write(res.spans)
        replay, meta = obs.load_spans(td)
        assert meta["n_spans"] == len(res.spans)
        assert Counter(s.name for s in replay) == by_name
        assert Counter(
            s.trace for s in replay if s.name == "complete"
        ) == Counter(complete_keys)
        json.load(open(f"{td}/trace.json"))   # Chrome trace parses


def test_local_runtime_emits_conserved_spans(corpus, ft_router):
    """The simulated LocalWorkerPool honors the same laws (cheap to
    run, so it guards the contract in the fast lane): one complete per
    batch and a reissue span per simulated straggler re-issue."""
    ccfg, docs = corpus
    test = docs[75:]
    ecfg = EngineConfig(alpha=0.1, batch_size=8)
    xcfg = ExecutorConfig(n_nodes=3, straggler_rate=0.4,
                          straggler_slowdown=6.0, deadline_factor=1.5,
                          obs=True, seed=5)
    res = CampaignExecutor(ecfg, xcfg, ft_router, ccfg).run(test)
    by_name = Counter(s.name for s in res.spans)
    assert by_name["complete"] == -(-len(test) // 8)
    assert by_name["reissue"] == res.reissued
    assert not obs.recorder().enabled    # restored after collection
