"""Parser-backend runtime: registry contents/dispatch, a custom backend
end-to-end through AdaParseEngine.process_batch, result-cache replay
determinism, engine prefetch overlap, and the pool-aware greedy
scheduler."""
import numpy as np
import pytest

from repro.core import backends as B
from repro.core import parsers as P
from repro.core import scheduler
from repro.core.engine import AdaParseEngine, EngineConfig


def _assert_same_records(a: dict, b: dict):
    assert set(a) == set(b)
    for i in a:
        assert a[i].parser == b[i].parser
        assert len(a[i].pages) == len(b[i].pages)
        for pa, pb in zip(a[i].pages, b[i].pages):
            np.testing.assert_array_equal(pa, pb)


# -- registry -----------------------------------------------------------------


def test_default_registry_wraps_parser_specs():
    assert set(B.available_backends()) == set(P.PARSER_SPECS)
    assert B.get_backend("pymupdf").info.device == "cpu"
    assert B.get_backend("nougat").info.device == "gpu"
    assert (B.get_backend("nougat").info.warm_start_s
            == P.PARSER_SPECS["nougat"].warmup_s)
    assert isinstance(B.get_backend("pymupdf"), B.ParserBackend)


def test_get_backend_unknown_name():
    with pytest.raises(KeyError, match="unknown parser backend"):
        B.get_backend("no-such-parser")


def test_register_rejects_duplicates():
    with pytest.raises(ValueError, match="already registered"):
        B.register_backend(B.ChannelBackend(P.PARSER_SPECS["pymupdf"]))


def test_parsers_dispatch_through_registry(corpus):
    """run_parser_batch / parse_cost_batch hit the registry, so a
    replaced backend is picked up by the legacy name-based API too."""
    ccfg, docs = corpus
    spec = P.PARSER_SPECS["pymupdf"]
    outs = P.run_parser_batch("pymupdf", docs[:5], ccfg,
                              np.random.RandomState(0))
    assert len(outs) == 5
    np.testing.assert_allclose(
        P.parse_cost_batch("pymupdf", docs[:5]),
        np.array([d.n_pages for d in docs[:5]])
        / P.MEAN_PAGES / spec.pdf_per_sec_node)
    assert P.parse_cost_s("pymupdf", docs[0]) == pytest.approx(
        docs[0].n_pages / P.MEAN_PAGES / spec.pdf_per_sec_node)


# -- custom backend end-to-end ------------------------------------------------


class EchoBackend:
    """Toy custom backend: returns the ground-truth pages verbatim at a
    fixed cost (a stand-in for plugging a real parser binary in)."""

    def __init__(self, name="echo", device="cpu"):
        self.info = B.BackendInfo(name=name, device=device,
                                  pdf_per_sec_node=50.0, warm_start_s=1.0)
        self.calls = 0

    def parse_batch(self, docs, cfg, rng, *, image_degraded=False,
                    text_degraded=False):
        self.calls += 1
        return [[np.asarray(pg, np.int32) for pg in d.pages] for d in docs]

    def cost_batch(self, docs):
        return np.full(len(docs), 1.0 / self.info.pdf_per_sec_node)


@pytest.fixture
def echo_backend():
    be = B.register_backend(EchoBackend())
    yield be
    B.unregister_backend("echo")


def test_custom_backend_through_engine(corpus, ft_router, echo_backend):
    """A registered custom backend works as the expensive parser through
    the full process_batch pipeline: selected docs carry its name and
    its (perfect) output pages."""
    ccfg, docs = corpus
    ecfg = EngineConfig(alpha=0.25, batch_size=16, expensive="echo")
    eng = AdaParseEngine(ecfg, ft_router, ccfg)
    recs = eng.process_batch(docs[75:91], batch_key=0)
    assert echo_backend.calls == 1
    echoed = [r for r in recs if r.parser == "echo"]
    assert echoed and len(echoed) <= int(0.25 * 16)
    by_id = {d.doc_id: d for d in docs[75:91]}
    for r in echoed:
        for pg, ref in zip(r.pages, by_id[r.doc_id].pages):
            np.testing.assert_array_equal(pg, ref)
    # warm-start cost charged once per node
    assert eng.stats.node_seconds >= echo_backend.info.warm_start_s


# -- result cache -------------------------------------------------------------


def test_engine_cache_replay_matches_cold_run(corpus, ft_router):
    """Cache-hit replay is bit-identical to the cold run, and the second
    pass does no parsing (hit counters + untouched node_seconds)."""
    ccfg, docs = corpus
    test = docs[75:]
    ecfg = EngineConfig(alpha=0.1, batch_size=16)
    cache = B.ResultCache()
    cold_eng = AdaParseEngine(ecfg, ft_router, ccfg, cache=cache)
    cold = cold_eng.run(test)
    assert cache.hits == 0 and cache.misses == len(cache) > 0
    warm_eng = AdaParseEngine(ecfg, ft_router, ccfg, cache=cache)
    warm = warm_eng.run(test)
    _assert_same_records(cold, warm)
    assert cache.hits == cache.misses == len(cache)
    assert warm_eng.stats.cache_hits == len(cache)
    assert warm_eng.stats.node_seconds == 0.0
    assert warm_eng.stats.n_docs == len(test)


def test_cache_key_separates_configs(corpus, ft_router):
    """Different alpha -> different fingerprint -> no cross-config
    replay."""
    ccfg, docs = corpus
    cache = B.ResultCache()
    a = AdaParseEngine(EngineConfig(alpha=0.1, batch_size=16), ft_router,
                       ccfg, cache=cache)
    b = AdaParseEngine(EngineConfig(alpha=0.2, batch_size=16), ft_router,
                       ccfg, cache=cache)
    a.process_batch(docs[75:91], batch_key=0)
    b.process_batch(docs[75:91], batch_key=0)
    assert cache.hits == 0 and cache.misses == 2 and len(cache) == 2


def test_engine_prefetch_overlap_matches_sequential(corpus, ft_router):
    """prefetch_depth > 0 routes prepare through the Prefetcher worker
    thread; records must equal the sequential path exactly."""
    ccfg, docs = corpus
    test = docs[75:]
    seq = AdaParseEngine(EngineConfig(alpha=0.1, batch_size=16),
                         ft_router, ccfg).run(test)
    ovl_eng = AdaParseEngine(
        EngineConfig(alpha=0.1, batch_size=16, prefetch_depth=3),
        ft_router, ccfg)
    ovl = ovl_eng.run(test)
    _assert_same_records(seq, ovl)
    assert ovl_eng.stats.n_docs == len(test)


# -- pool-aware greedy scheduler ---------------------------------------------


def test_greedy_pool_budget_caps_gpu_upgrades():
    """With a tiny GPU-pool budget, the greedy knapsack buys CPU upgrades
    but cannot move work onto the GPU parser beyond the pool cap."""
    rng = np.random.RandomState(0)
    n = 60
    # parsers: cheap cpu, mid cpu, expensive gpu (best accuracy)
    costs = np.array([0.01, 0.05, 1.0])
    devices = ["cpu", "cpu", "gpu"]
    acc = np.stack([rng.rand(n) * 0.3, rng.rand(n) * 0.5,
                    0.8 + rng.rand(n) * 0.2], axis=1)
    unpooled = scheduler.assign_parsers_greedy(acc, costs, budget=20.0)
    assert (unpooled == 2).sum() > 3
    gpu_budget = 3.0
    pooled = scheduler.assign_parsers_greedy(
        acc, costs, budget=20.0, devices=devices,
        device_budgets={"gpu": gpu_budget, "cpu": np.inf})
    assert costs[pooled][pooled == 2].sum() <= gpu_budget + 1e-9
    assert (pooled == 2).sum() < (unpooled == 2).sum()
    # total budget still respected and never worse than all-cheapest
    assert costs[pooled].sum() <= 20.0 + 1e-9
    assert (acc[np.arange(n), pooled].sum()
            >= acc[np.arange(n), 0].sum() - 1e-9)


def test_greedy_pooled_matches_unpooled_when_budgets_loose():
    rng = np.random.RandomState(3)
    acc = rng.rand(40, 3)
    costs = np.sort(rng.rand(3) + 0.1)
    budget = 40 * costs[0] * 3
    base = scheduler.assign_parsers_greedy(acc, costs, budget)
    pooled = scheduler.assign_parsers_greedy(
        acc, costs, budget, devices=["cpu", "cpu", "gpu"],
        device_budgets={"cpu": np.inf, "gpu": np.inf})
    np.testing.assert_array_equal(base, pooled)
