"""Parser-backend runtime: registry contents/dispatch, a custom backend
end-to-end through AdaParseEngine.process_batch, result-cache replay
determinism, engine prefetch overlap, and the pool-aware greedy
scheduler."""
import numpy as np
import pytest

from repro.core import backends as B
from repro.core import parsers as P
from repro.core import scheduler
from repro.core.engine import AdaParseEngine, EngineConfig


def _assert_same_records(a: dict, b: dict):
    assert set(a) == set(b)
    for i in a:
        assert a[i].parser == b[i].parser
        assert len(a[i].pages) == len(b[i].pages)
        for pa, pb in zip(a[i].pages, b[i].pages):
            np.testing.assert_array_equal(pa, pb)


# -- registry -----------------------------------------------------------------


def test_default_registry_wraps_parser_specs():
    assert set(B.available_backends()) == set(P.PARSER_SPECS)
    assert B.get_backend("pymupdf").info.device == "cpu"
    assert B.get_backend("nougat").info.device == "gpu"
    assert (B.get_backend("nougat").info.warm_start_s
            == P.PARSER_SPECS["nougat"].warmup_s)
    assert isinstance(B.get_backend("pymupdf"), B.ParserBackend)


def test_get_backend_unknown_name():
    with pytest.raises(KeyError, match="unknown parser backend"):
        B.get_backend("no-such-parser")


def test_register_rejects_duplicates():
    with pytest.raises(ValueError, match="already registered"):
        B.register_backend(B.ChannelBackend(P.PARSER_SPECS["pymupdf"]))


def test_parsers_dispatch_through_registry(corpus):
    """run_parser_batch / parse_cost_batch hit the registry, so a
    replaced backend is picked up by the legacy name-based API too."""
    ccfg, docs = corpus
    spec = P.PARSER_SPECS["pymupdf"]
    outs = P.run_parser_batch("pymupdf", docs[:5], ccfg,
                              np.random.RandomState(0))
    assert len(outs) == 5
    np.testing.assert_allclose(
        P.parse_cost_batch("pymupdf", docs[:5]),
        np.array([d.n_pages for d in docs[:5]])
        / P.MEAN_PAGES / spec.pdf_per_sec_node)
    assert P.parse_cost_s("pymupdf", docs[0]) == pytest.approx(
        docs[0].n_pages / P.MEAN_PAGES / spec.pdf_per_sec_node)


# -- custom backend end-to-end ------------------------------------------------


class EchoBackend:
    """Toy custom backend: returns the ground-truth pages verbatim at a
    fixed cost (a stand-in for plugging a real parser binary in)."""

    def __init__(self, name="echo", device="cpu"):
        self.info = B.BackendInfo(name=name, device=device,
                                  pdf_per_sec_node=50.0, warm_start_s=1.0)
        self.calls = 0

    def parse_batch(self, docs, cfg, rng, *, image_degraded=False,
                    text_degraded=False):
        self.calls += 1
        return [[np.asarray(pg, np.int32) for pg in d.pages] for d in docs]

    def cost_batch(self, docs):
        return np.full(len(docs), 1.0 / self.info.pdf_per_sec_node)


@pytest.fixture
def echo_backend():
    be = B.register_backend(EchoBackend())
    yield be
    B.unregister_backend("echo")


def test_custom_backend_through_engine(corpus, ft_router, echo_backend):
    """A registered custom backend works as the expensive parser through
    the full process_batch pipeline: selected docs carry its name and
    its (perfect) output pages."""
    ccfg, docs = corpus
    ecfg = EngineConfig(alpha=0.25, batch_size=16, expensive="echo")
    eng = AdaParseEngine(ecfg, ft_router, ccfg)
    recs = eng.process_batch(docs[75:91], batch_key=0)
    assert echo_backend.calls == 1
    echoed = [r for r in recs if r.parser == "echo"]
    assert echoed and len(echoed) <= int(0.25 * 16)
    by_id = {d.doc_id: d for d in docs[75:91]}
    for r in echoed:
        for pg, ref in zip(r.pages, by_id[r.doc_id].pages):
            np.testing.assert_array_equal(pg, ref)
    # warm-start cost charged once per node
    assert eng.stats.node_seconds >= echo_backend.info.warm_start_s


# -- result cache -------------------------------------------------------------


def test_engine_cache_replay_matches_cold_run(corpus, ft_router):
    """Cache-hit replay is bit-identical to the cold run, and the second
    pass does no parsing (hit counters + untouched node_seconds)."""
    ccfg, docs = corpus
    test = docs[75:]
    ecfg = EngineConfig(alpha=0.1, batch_size=16)
    cache = B.ResultCache()
    cold_eng = AdaParseEngine(ecfg, ft_router, ccfg, cache=cache)
    cold = cold_eng.run(test)
    assert cache.hits == 0 and cache.misses == len(cache) > 0
    warm_eng = AdaParseEngine(ecfg, ft_router, ccfg, cache=cache)
    warm = warm_eng.run(test)
    _assert_same_records(cold, warm)
    assert cache.hits == cache.misses == len(cache)
    assert warm_eng.stats.cache_hits == len(cache)
    assert warm_eng.stats.node_seconds == 0.0
    assert warm_eng.stats.n_docs == len(test)


def test_cache_key_separates_configs(corpus, ft_router):
    """Different alpha -> different fingerprint -> no cross-config
    replay."""
    ccfg, docs = corpus
    cache = B.ResultCache()
    a = AdaParseEngine(EngineConfig(alpha=0.1, batch_size=16), ft_router,
                       ccfg, cache=cache)
    b = AdaParseEngine(EngineConfig(alpha=0.2, batch_size=16), ft_router,
                       ccfg, cache=cache)
    a.process_batch(docs[75:91], batch_key=0)
    b.process_batch(docs[75:91], batch_key=0)
    assert cache.hits == 0 and cache.misses == 2 and len(cache) == 2


def _rec(i, n=64):
    from repro.core.engine import ParseRecord
    return ParseRecord(i, "pymupdf",
                       [np.arange(n, dtype=np.int32) + i], float(i))


def test_result_stores_satisfy_protocol(tmp_path):
    assert isinstance(B.ResultCache(), B.ResultStore)
    assert isinstance(B.DiskResultStore(tmp_path / "c"), B.ResultStore)


@pytest.mark.parametrize("make_store", [
    lambda tmp: B.ResultCache(),
    lambda tmp: B.DiskResultStore(tmp / "c"),
], ids=["memory", "disk"])
def test_result_store_threaded_counters(tmp_path, make_store):
    """Hit/miss counters stay exact under concurrent lookups (the
    executor's prefetch workers race the consumer's stores): every
    lookup of a stored key is a hit, every other a miss."""
    import threading

    store = make_store(tmp_path)
    stored = [("k", i) for i in range(0, 40, 2)]     # even keys stored
    missing = [("k", i) for i in range(1, 40, 2)]
    for k in stored:
        store.store(k, [_rec(k[1])])
    errs = []

    def worker(keys, expect_hit):
        try:
            for k in keys:
                recs = store.lookup(k)
                assert (recs is not None) == expect_hit
                if expect_hit:
                    np.testing.assert_array_equal(
                        recs[0].pages[0], _rec(k[1]).pages[0])
        except Exception as e:          # surfaces in the main thread
            errs.append(e)

    threads = [threading.Thread(target=worker, args=(stored, True))
               for _ in range(4)]
    threads += [threading.Thread(target=worker, args=(missing, False))
                for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    assert store.hits == 4 * len(stored)
    assert store.misses == 4 * len(missing)
    assert len(store) == len(stored)


def test_disk_store_persists_across_instances(tmp_path):
    """A fresh DiskResultStore over the same directory replays the
    records a prior instance stored (the process-restart path), with
    exact page contents and costs."""
    d = tmp_path / "cache"
    a = B.DiskResultStore(d)
    recs = [_rec(0), _rec(1)]
    a.store(("tag", 0, (0, 1)), recs)
    b = B.DiskResultStore(d)
    assert len(b) == 1
    got = b.lookup(("tag", 0, (0, 1)))
    assert b.hits == 1 and b.misses == 0
    for r, g in zip(recs, got):
        assert g.doc_id == r.doc_id and g.parser == r.parser
        assert g.cost_s == r.cost_s
        np.testing.assert_array_equal(g.pages[0], r.pages[0])
    assert b.lookup(("tag", 9, (9,))) is None and b.misses == 1


def test_disk_store_lru_eviction_is_deterministic(tmp_path):
    """Byte-budget eviction follows the logical LRU clock (lookups
    refresh recency), so the same operation sequence leaves the same
    survivors — in-process and after a restart."""

    def sequence(d):
        one = len(B.pickle.dumps([_rec(0)], protocol=4))
        st = B.DiskResultStore(d, max_bytes=int(3.5 * one))
        for i in range(3):                     # a, b, c fit (3 <= 3.5)
            st.store(("k", i), [_rec(i)])
        assert st.lookup(("k", 0)) is not None  # refresh a
        st.store(("k", 3), [_rec(3)])           # over budget -> evict b
        return st

    st = sequence(tmp_path / "one")
    assert len(st) == 3
    assert st.lookup(("k", 1)) is None          # LRU victim
    assert all(st.lookup(("k", i)) is not None for i in (0, 2, 3))
    assert st.total_bytes <= st.max_bytes
    # identical op sequence in a fresh directory -> identical survivors
    st2 = sequence(tmp_path / "two")
    assert {i for i in range(4) if st2.lookup(("k", i)) is not None} \
        == {0, 2, 3}
    # restart sees the same entries and the same LRU order going forward
    st3 = B.DiskResultStore(tmp_path / "one", max_bytes=st.max_bytes)
    assert len(st3) == 3 and st3.lookup(("k", 1)) is None


def test_disk_store_hits_batch_index_writes(tmp_path):
    """Warm-replay hits must not rewrite index.json per lookup: each
    hit appends one WAL line; the snapshot is only rewritten by
    compaction (flush(), eviction, or the COMPACT_EVERY threshold)."""
    st = B.DiskResultStore(tmp_path / "c")
    st.store(("k", 0), [_rec(0)])
    st.flush()                              # compact the store op away
    idx = tmp_path / "c" / B.DiskResultStore.INDEX_NAME
    wal = tmp_path / "c" / B.DiskResultStore.WAL_NAME
    before = idx.read_bytes()
    assert wal.read_bytes() == b""          # compaction truncated the WAL
    for i in range(100):
        assert st.lookup(("k", 0)) is not None
    assert idx.read_bytes() == before       # bumps live in the WAL
    assert len(wal.read_text().splitlines()) == 100
    st.flush()
    assert idx.read_bytes() != before       # now folded into the snapshot
    assert wal.read_bytes() == b""


def test_disk_store_wal_recovers_unflushed_ops(tmp_path):
    """Ops that never made it into a compacted snapshot (a crash before
    flush()) are replayed from the WAL on open: a fresh instance sees
    the stored entries and the hit-refreshed LRU order."""
    d = tmp_path / "c"
    st = B.DiskResultStore(d)
    for i in range(3):
        st.store(("k", i), [_rec(i)])
    assert st.lookup(("k", 0)) is not None   # refresh entry 0
    # no flush(): index.json never written, everything lives in the WAL
    assert not (d / B.DiskResultStore.INDEX_NAME).exists()
    assert (d / B.DiskResultStore.WAL_NAME).stat().st_size > 0

    one = len(B.pickle.dumps([_rec(0)], protocol=4))
    st2 = B.DiskResultStore(d, max_bytes=int(3.5 * one))
    assert len(st2) == 3
    # replayed LRU order: entry 1 (oldest un-refreshed) evicts first
    st2.store(("k", 3), [_rec(3)])
    assert st2.lookup(("k", 1)) is None
    assert all(st2.lookup(("k", i)) is not None for i in (0, 2, 3))


def test_disk_store_compaction_preserves_other_instances_wal_tail(
        tmp_path):
    """Two store handles over one dir (the worker runtime's shared
    disk store): compaction in one must fold the *other's* WAL appends
    into the snapshot instead of truncating them away — the
    multi-process recovery bug the flock'd fold-from-disk fixes."""
    d = tmp_path / "c"
    a = B.DiskResultStore(d)
    b = B.DiskResultStore(d)
    a.store(("k", 0), [_rec(0)])
    b.store(("k", 1), [_rec(1)])        # another process's WAL append
    a.flush()                           # compacts; must keep b's entry
    assert (d / B.DiskResultStore.WAL_NAME).read_bytes() == b""
    fresh = B.DiskResultStore(d)
    assert len(fresh) == 2
    assert fresh.lookup(("k", 0)) is not None
    assert fresh.lookup(("k", 1)) is not None
    # compaction also adopts the merged view in-memory: a now sees b's
    # entry without reopening
    assert a.lookup(("k", 1)) is not None


def test_disk_store_concurrent_instances_interleave_safely(tmp_path):
    """Concurrent stores + periodic compactions from three independent
    handles on one dir (each append is one O_APPEND line under a
    shared flock; compaction holds the exclusive flock): every entry
    from every handle survives and replays."""
    import threading

    d = tmp_path / "c"
    stores = [B.DiskResultStore(d) for _ in range(3)]
    errs = []

    def work(st, base):
        try:
            for i in range(30):
                st.store(("k", base + i), [_rec(i)])
                if i % 10 == 9:
                    st.flush()          # interleaved compactions
        except Exception as e:          # surfaces in the main thread
            errs.append(e)

    threads = [threading.Thread(target=work, args=(st, 100 * j))
               for j, st in enumerate(stores)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    fresh = B.DiskResultStore(d)
    assert len(fresh) == 90
    for j in range(3):
        for i in range(30):
            assert fresh.lookup(("k", 100 * j + i)) is not None


def test_disk_store_wal_torn_tail_is_ignored(tmp_path):
    """A crash mid-append leaves a torn final WAL line; recovery keeps
    every complete op before it and drops the tail."""
    d = tmp_path / "c"
    st = B.DiskResultStore(d)
    st.store(("k", 0), [_rec(0)])
    st.store(("k", 1), [_rec(1)])
    with open(d / B.DiskResultStore.WAL_NAME, "a") as f:
        f.write('{"op": "del", "d": "tr')     # torn append
    st2 = B.DiskResultStore(d)
    assert len(st2) == 2
    assert st2.lookup(("k", 0)) is not None
    assert st2.lookup(("k", 1)) is not None


def test_campaign_flushes_lru_bumps_on_exit(corpus, ft_router, tmp_path):
    """A hit-only warm campaign compacts its LRU recency bumps into the
    snapshot at the end of the run (CampaignExecutor calls flush()), so
    restart-then-evict follows true LRU order even when the bumps never
    crossed the COMPACT_EVERY threshold."""
    from repro.core.campaign import CampaignExecutor, ExecutorConfig

    ccfg, docs = corpus
    test = docs[75:]
    ecfg = EngineConfig(alpha=0.1, batch_size=16)
    xcfg = ExecutorConfig(n_nodes=2, straggler_rate=0.0)
    store = B.DiskResultStore(tmp_path / "c")
    CampaignExecutor(ecfg, xcfg, ft_router, ccfg).run(test, cache=store)
    idx = tmp_path / "c" / B.DiskResultStore.INDEX_NAME
    wal = tmp_path / "c" / B.DiskResultStore.WAL_NAME
    before = idx.read_bytes()
    assert wal.read_bytes() == b""          # cold run flushed on exit
    warm_store = B.DiskResultStore(tmp_path / "c")
    res = CampaignExecutor(ecfg, xcfg, ft_router, ccfg).run(
        test, cache=warm_store)
    assert res.cache_misses == 0 and 0 < res.cache_hits \
        < B.DiskResultStore.COMPACT_EVERY
    assert idx.read_bytes() != before       # recency bumps persisted
    assert wal.read_bytes() == b""


def test_router_fingerprint_distinguishes_enc_cfg(corpus):
    """Routers differing only in encoder *config* (same param leaves)
    must not share a cache fingerprint — enc_cfg shapes the forward."""
    import dataclasses as dc

    from repro.configs.base import EncoderConfig
    from repro.core.engine import _router_fingerprint
    from repro.core.router import AdaParseRouter, LinearStage

    cls1 = LinearStage(np.zeros(4), 0.0)
    cfg = EncoderConfig(name="t", n_layers=1, d_model=16, n_heads=2,
                        d_ff=32, vocab_size=64, max_len=12,
                        param_dtype="float32", compute_dtype="float32")
    params = {"w": np.zeros(4, np.float32)}
    a = AdaParseRouter("llm", cls1, None, enc_cfg=cfg, enc_params=params)
    b = AdaParseRouter("llm", cls1, None,
                       enc_cfg=dc.replace(cfg, n_heads=4),
                       enc_params=params)
    same = AdaParseRouter("llm", cls1, None, enc_cfg=cfg,
                          enc_params=params)
    assert _router_fingerprint(a) != _router_fingerprint(b)
    assert _router_fingerprint(a) == _router_fingerprint(same)


def test_disk_store_single_oversized_batch_is_kept(tmp_path):
    """A record batch larger than the whole byte budget evicts everything
    else but is itself retained (the store never wedges)."""
    st = B.DiskResultStore(tmp_path / "c", max_bytes=10)
    st.store(("k", 0), [_rec(0)])
    st.store(("k", 1), [_rec(1)])
    assert len(st) == 1
    assert st.lookup(("k", 1)) is not None


def test_disk_store_eviction_budget_is_fleet_wide(tmp_path):
    """N handles over one dir (the worker fleet sharing --cache-dir)
    enforce ONE ``max_bytes``. Regression: the byte total and LRU
    victim choice used to read only the local in-memory ``_entries``,
    so each handle stayed under budget in its own view while the disk
    total overshot ~N× — eviction must fold the on-disk snapshot + WAL
    before judging the budget."""
    d = tmp_path / "c"
    one = len(B.pickle.dumps([_rec(0)], protocol=4))
    budget = int(4.5 * one)
    # both handles open on the empty dir: neither sees the other's
    # entries except through the on-disk fold
    a = B.DiskResultStore(d, max_bytes=budget)
    b = B.DiskResultStore(d, max_bytes=budget)
    for i in range(3):                  # 6 entries written, only 4 fit
        a.store(("a", i), [_rec(i)])
        b.store(("b", i), [_rec(i)])
    fresh = B.DiskResultStore(d, max_bytes=budget)
    assert fresh.total_bytes <= budget
    assert len(fresh) <= 4
    # the budget survivors replay; no handle wedged the store
    alive = [k for k in [("a", i) for i in range(3)]
             + [("b", i) for i in range(3)] if fresh.lookup(k) is not None]
    assert len(alive) == len(fresh)


def test_engine_disk_store_replay_across_engine_instances(corpus,
                                                          ft_router,
                                                          tmp_path):
    """Cold engine run through a DiskResultStore, then a fresh engine +
    fresh store over the same dir: all hits, identical records, no parse
    time charged (the single-node restart-replay path)."""
    ccfg, docs = corpus
    test = docs[75:]
    ecfg = EngineConfig(alpha=0.1, batch_size=16)
    cold_store = B.DiskResultStore(tmp_path / "c")
    cold = AdaParseEngine(ecfg, ft_router, ccfg, cache=cold_store).run(test)
    assert cold_store.hits == 0 and cold_store.misses == len(cold_store) > 0
    warm_store = B.DiskResultStore(tmp_path / "c")
    warm_eng = AdaParseEngine(ecfg, ft_router, ccfg, cache=warm_store)
    warm = warm_eng.run(test)
    _assert_same_records(cold, warm)
    assert warm_store.misses == 0
    assert warm_store.hits == len(warm_store) == warm_eng.stats.cache_hits
    assert warm_eng.stats.node_seconds == 0.0


def test_engine_prefetch_overlap_matches_sequential(corpus, ft_router):
    """prefetch_depth > 0 routes prepare through the Prefetcher worker
    thread; records must equal the sequential path exactly."""
    ccfg, docs = corpus
    test = docs[75:]
    seq = AdaParseEngine(EngineConfig(alpha=0.1, batch_size=16),
                         ft_router, ccfg).run(test)
    ovl_eng = AdaParseEngine(
        EngineConfig(alpha=0.1, batch_size=16, prefetch_depth=3),
        ft_router, ccfg)
    ovl = ovl_eng.run(test)
    _assert_same_records(seq, ovl)
    assert ovl_eng.stats.n_docs == len(test)


# -- per-stage telemetry ------------------------------------------------------


def test_engine_emits_per_stage_batch_telemetry(corpus, ft_router):
    """Every completed batch leaves a BatchTelemetry record on the
    ingest engine with the per-stage costs the controller autotunes
    from; cache replays are flagged and cost nothing."""
    ccfg, docs = corpus
    ecfg = EngineConfig(alpha=0.25, batch_size=16)
    cache = B.ResultCache()
    eng = AdaParseEngine(ecfg, ft_router, ccfg, cache=cache)
    eng.process_batch(docs[75:91], batch_key=0)
    eng.process_batch(docs[91:107], batch_key=1)
    assert len(eng.telemetry) == 2
    t0 = eng.telemetry[0]
    assert t0.batch_key == 0 and t0.n_docs == 16 and not t0.cached
    assert t0.prepare_s > 0 and t0.route_s > 0
    assert t0.complete_s > 0 and t0.n_expensive > 0
    assert t0.total_s == pytest.approx(t0.prepare_s + t0.route_s
                                       + t0.complete_s)
    np.testing.assert_allclose(
        sum(t.total_s for t in eng.telemetry), eng.stats.node_seconds)
    eng.process_batch(docs[75:91], batch_key=0)     # replay
    t2 = eng.telemetry[2]
    assert t2.cached and t2.total_s == 0.0 and t2.batch_key == 0


# -- pool-aware greedy scheduler ---------------------------------------------


def test_greedy_pool_budget_caps_gpu_upgrades():
    """With a tiny GPU-pool budget, the greedy knapsack buys CPU upgrades
    but cannot move work onto the GPU parser beyond the pool cap."""
    rng = np.random.RandomState(0)
    n = 60
    # parsers: cheap cpu, mid cpu, expensive gpu (best accuracy)
    costs = np.array([0.01, 0.05, 1.0])
    devices = ["cpu", "cpu", "gpu"]
    acc = np.stack([rng.rand(n) * 0.3, rng.rand(n) * 0.5,
                    0.8 + rng.rand(n) * 0.2], axis=1)
    unpooled = scheduler.assign_parsers_greedy(acc, costs, budget=20.0)
    assert (unpooled == 2).sum() > 3
    gpu_budget = 3.0
    pooled = scheduler.assign_parsers_greedy(
        acc, costs, budget=20.0, devices=devices,
        device_budgets={"gpu": gpu_budget, "cpu": np.inf})
    assert costs[pooled][pooled == 2].sum() <= gpu_budget + 1e-9
    assert (pooled == 2).sum() < (unpooled == 2).sum()
    # total budget still respected and never worse than all-cheapest
    assert costs[pooled].sum() <= 20.0 + 1e-9
    assert (acc[np.arange(n), pooled].sum()
            >= acc[np.arange(n), 0].sum() - 1e-9)


def test_reissue_candidates_policy():
    """Same-pool peers first; crossing pools only for CPU-capable work;
    GPU work stuck in a lone-node pool has no eligible peer."""
    pools = ["cpu", "cpu", "gpu"]
    assert scheduler.reissue_candidates(0, pools, "cpu", 3) == [1]
    assert scheduler.reissue_candidates(2, pools, "gpu", 3) == []
    assert scheduler.reissue_candidates(2, pools, "cpu", 3) == [0, 1]
    assert scheduler.reissue_candidates(1, None, "gpu", 3) == [0, 2]
    pools2 = ["gpu", "gpu", "cpu"]
    assert scheduler.reissue_candidates(0, pools2, "gpu", 3) == [1]


def test_least_loaded_breaks_ties_by_node_index():
    clocks = np.array([5.0, 1.0, 1.0, 3.0])
    assert scheduler.least_loaded([0, 1, 2, 3], clocks) == 1
    assert scheduler.least_loaded([2, 1], clocks) == 1
    assert scheduler.least_loaded([3, 0], clocks) == 3


def test_greedy_pooled_matches_unpooled_when_budgets_loose():
    rng = np.random.RandomState(3)
    acc = rng.rand(40, 3)
    costs = np.sort(rng.rand(3) + 0.1)
    budget = 40 * costs[0] * 3
    base = scheduler.assign_parsers_greedy(acc, costs, budget)
    pooled = scheduler.assign_parsers_greedy(
        acc, costs, budget, devices=["cpu", "cpu", "gpu"],
        device_budgets={"cpu": np.inf, "gpu": np.inf})
    np.testing.assert_array_equal(base, pooled)
