"""Routing parity: the host numpy mirror (scheduler.plan_batch), the jnp
reference, and the Pallas kernel (interpret mode) must choose identical
document sets on the same scores — plus the budget_topk invariants ported
from the hypothesis suite (seeded, always run in tier-1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import scheduler
from repro.kernels.budget_route.kernel import budget_route_kernel
from repro.kernels.budget_route.ops import budget_route
from repro.kernels.budget_route.ref import budget_route_ref


def _device_set(idx) -> set:
    idx = np.asarray(idx)
    return set(idx[idx >= 0].tolist())


# -- budget_topk invariants (ported from tests/test_properties.py) -----------


@pytest.mark.parametrize("k,alpha,seed", [
    (8, 0.0, 0), (8, 1.0, 1), (17, 0.05, 2), (64, 0.1, 3), (100, 0.5, 4),
    (200, 0.031, 5), (33, 0.99, 6), (150, 0.2, 7),
])
def test_budget_topk_respects_budget(k, alpha, seed):
    """Never route more than floor(alpha*k) items; all routed items have
    positive predicted improvement."""
    rng = np.random.RandomState(seed)
    scores = jnp.asarray(rng.randn(k).astype(np.float32))
    mask, idx = scheduler.budget_topk(scores, alpha)
    n_sel = int(mask.sum())
    assert n_sel <= int(alpha * k)
    if n_sel:
        assert float(scores[mask].min()) > 0


@pytest.mark.parametrize("k,alpha,seed", [
    (8, 0.25, 10), (50, 0.04, 11), (64, 0.5, 12), (128, 0.05, 13),
    (99, 0.33, 14), (200, 0.9, 15),
])
def test_budget_topk_takes_the_best(k, alpha, seed):
    """Every selected score >= every unselected score."""
    rng = np.random.RandomState(seed)
    scores = jnp.asarray(rng.randn(k).astype(np.float32))
    mask, _ = scheduler.budget_topk(scores, alpha)
    m = np.asarray(mask)
    if m.any() and (~m).any():
        assert float(scores[m].min()) >= float(scores[~m].max()) - 1e-6


# -- host / ref / kernel three-way agreement ---------------------------------


@pytest.mark.parametrize("k,alpha,seed", [
    (64, 0.05, 0), (64, 0.25, 1), (100, 0.1, 2), (256, 0.05, 3),
    (40, 0.5, 4), (96, 0.031, 5), (128, 1.0, 6),
])
def test_plan_batch_matches_device_selection(k, alpha, seed):
    """Host plan_batch and the fused device op (ref AND Pallas kernel in
    interpret mode) choose identical document sets on the same scores."""
    rng = np.random.RandomState(seed)
    scores = rng.randn(k).astype(np.float32)
    tokens = rng.randn(k, 8).astype(np.float32)
    host = set(scheduler.plan_batch(scores, alpha).expensive_idx.tolist())

    _, idx_ref, cnt_ref = budget_route(jnp.asarray(scores),
                                       jnp.asarray(tokens), alpha)
    _, idx_kern, cnt_kern = budget_route(jnp.asarray(scores),
                                         jnp.asarray(tokens), alpha,
                                         force_kernel=True)
    assert _device_set(idx_ref) == host
    assert _device_set(idx_kern) == host
    assert int(cnt_ref) == int(cnt_kern) == len(host)


def test_parity_alpha_k_zero():
    """alpha*k < 1 routes nothing on both paths (floor semantics — the
    budget is a hard cap)."""
    scores = np.random.RandomState(0).randn(12).astype(np.float32)
    plan = scheduler.plan_batch(scores, 0.05)
    assert plan.expensive_idx.size == 0
    routed, idx, count = budget_route(jnp.asarray(scores),
                                      jnp.zeros((12, 4)), 0.05)
    assert routed.shape == (0, 4) and idx.shape == (0,) and int(count) == 0


def test_parity_all_negative_improvements():
    """No doc with non-positive predicted improvement is ever routed."""
    scores = -np.abs(np.random.RandomState(1).randn(48)).astype(np.float32)
    plan = scheduler.plan_batch(scores, 0.25)
    assert plan.expensive_idx.size == 0
    for fk in (False, True):
        _, idx, count = budget_route(jnp.asarray(scores), jnp.zeros((48, 4)),
                                     0.25, force_kernel=fk)
        assert int(count) == 0 and _device_set(idx) == set()


def test_parity_inf_cls1_overrides():
    """+inf CLS-I overrides (host) / CLS1_OVERRIDE (device) win the budget
    and both paths keep the same ties-in-row-order subset when overrides
    exceed capacity."""
    from repro.core.router import CLS1_OVERRIDE
    k, alpha = 40, 0.1                    # capacity 4, 6 overridden docs
    rng = np.random.RandomState(2)
    scores = rng.randn(k).astype(np.float32) * 0.1
    invalid = np.array([3, 7, 11, 19, 23, 31])
    host_scores = scores.copy()
    host_scores[invalid] = np.inf
    host_scores = np.nan_to_num(host_scores,
                                posinf=CLS1_OVERRIDE).astype(np.float32)
    plan = scheduler.plan_batch(host_scores, alpha)
    assert set(plan.expensive_idx.tolist()) == {3, 7, 11, 19}
    for fk in (False, True):
        _, idx, _ = budget_route(jnp.asarray(host_scores),
                                 jnp.zeros((k, 4)), alpha, force_kernel=fk)
        assert _device_set(idx) == set(plan.expensive_idx.tolist())


def test_parity_capacity_clamp_at_k():
    """alpha = 1: capacity clamps at k; only positive scores routed, and
    host/ref/kernel agree."""
    rng = np.random.RandomState(3)
    scores = rng.randn(32).astype(np.float32)
    plan = scheduler.plan_batch(scores, 1.0)
    want = set(np.nonzero(scores >= scheduler.POSITIVE_TAU)[0].tolist())
    assert set(plan.expensive_idx.tolist()) == want
    for fk in (False, True):
        _, idx, count = budget_route(jnp.asarray(scores),
                                     jnp.zeros((32, 4)), 1.0,
                                     force_kernel=fk)
        assert _device_set(idx) == want and int(count) == len(want)


def test_route_step_device_vs_host_mirror():
    """The full fused route_step (encoder fwd + budget_route) selects
    exactly the set the host mirror picks from the very same improvement
    scores it computed."""
    from repro.common import unwrap
    from repro.configs.base import EncoderConfig
    from repro.core.router import make_route_step
    from repro.models import encoder as enc_lib

    cfg = EncoderConfig(name="t", n_layers=1, d_model=16, n_heads=2,
                        d_ff=32, vocab_size=64, max_len=12,
                        param_dtype="float32", compute_dtype="float32")
    params = unwrap(enc_lib.init_encoder(cfg, 0))
    rng = np.random.RandomState(0)
    b = 40
    toks = rng.randint(2, 64, (b, 12)).astype(np.int32)
    mask = np.ones((b, 12), np.float32)
    valid = rng.randn(b).astype(np.float32)
    step = jax.jit(make_route_step(cfg, alpha=0.1))
    out = step(params, jnp.asarray(toks), jnp.asarray(mask),
               jnp.asarray(valid))
    imp = np.asarray(out["improvement"]).astype(np.float32)
    host = set(scheduler.plan_batch(imp, 0.1).expensive_idx.tolist())
    assert _device_set(out["selected_idx"]) == host
    assert set(np.nonzero(np.asarray(out["selected_mask"]))[0].tolist()) \
        == host
    # invalid docs carry the CLS-I override score
    from repro.core.router import CLS1_OVERRIDE
    assert (imp[valid < 0] == CLS1_OVERRIDE).all()


def test_ties_never_displace_strictly_better():
    """A strictly higher-scoring doc is always routed, even when tied
    lower scores fill the batch ahead of it in row order (host, ref, and
    kernel all guarantee rows > tau are kept; only ties at tau compete
    for the remaining slots)."""
    scores = np.array([0.3, 0.3, 0.7], np.float32)   # capacity 2
    plan = scheduler.plan_batch(scores, 2 / 3)
    assert 2 in plan.expensive_idx.tolist()
    assert set(plan.expensive_idx.tolist()) == {0, 2}
    for fk in (False, True):
        _, idx, count = budget_route(jnp.asarray(scores),
                                     jnp.zeros((3, 4)), 2 / 3,
                                     force_kernel=fk)
        assert _device_set(idx) == {0, 2} and int(count) == 2
    # many ties before the best doc, tie budget spread across blocks
    scores = np.full(80, 0.5, np.float32)
    scores[70] = 2.0
    plan = scheduler.plan_batch(scores, 0.1)          # capacity 8
    assert plan.expensive_idx.tolist() == [0, 1, 2, 3, 4, 5, 6, 70]
    for fk in (False, True):
        _, idx, _ = budget_route(jnp.asarray(scores), jnp.zeros((80, 4)),
                                 0.1, force_kernel=fk)
        assert _device_set(idx) == set(plan.expensive_idx.tolist())
    # small blocks: the tie budget must carry across kernel grid steps
    _, idx, _ = budget_route_kernel(jnp.asarray(scores),
                                    jnp.zeros((80, 4)), 0.5, capacity=8,
                                    block_n=16, interpret=True)
    assert _device_set(idx) == set(plan.expensive_idx.tolist())


@pytest.mark.parametrize("k,alpha,want", [
    # rational α whose product is an exact integer: IEEE gives
    # 28.999999999999996 and int() under-floors to 28 — the float-dust
    # capacity bug this sweep regresses
    (100, 0.29, 29), (50, 0.58, 29), (200, 0.145, 29),
    # exact and near-exact products that must stay unchanged
    (10, 0.7, 7), (3, 2 / 3, 2), (300, 0.07, 21),
    # genuinely fractional products must still truncate, never snap up
    (100, 0.2899999, 28),
])
def test_capacity_floor_rational_alpha_parity(k, alpha, want):
    """⌊α·k⌋ is exact for rational α across every selection path —
    the shared epsilon-guarded floor — and host mirror, jnp ref, and
    Pallas kernel (interpret) agree on the selected set."""
    from repro.kernels.budget_route.ops import capacity_floor

    assert capacity_floor(alpha, k) == want
    rng = np.random.RandomState(k)
    # all-positive scores so capacity alone determines the count
    scores = (np.abs(rng.randn(k)) + 1.0).astype(np.float32)
    plan = scheduler.plan_batch(scores, alpha)
    assert plan.expensive_idx.size == want
    mask, _ = scheduler.budget_topk(jnp.asarray(scores), alpha)
    assert int(np.asarray(mask).sum()) == want
    tokens = rng.randn(k, 4).astype(np.float32)
    for fk in (False, True):
        _, idx, count = budget_route(jnp.asarray(scores),
                                     jnp.asarray(tokens), alpha,
                                     force_kernel=fk)
        assert int(count) == want
        assert _device_set(idx) == set(plan.expensive_idx.tolist())


@pytest.mark.parametrize("n,cap", [(64, 7), (100, 100), (128, 1)])
def test_kernel_vs_ref_tie_handling(n, cap):
    """Duplicate scores at the threshold: kernel and ref both keep the
    earliest rows (stable compaction)."""
    rng = np.random.RandomState(4)
    scores = rng.randint(0, 5, n).astype(np.float32)   # heavy ties
    tokens = rng.randn(n, 4).astype(np.float32)
    tau = float(np.sort(scores)[-cap])
    o1, i1, c1 = budget_route_kernel(scores, tokens, tau, capacity=cap,
                                     interpret=True)
    o2, i2, c2 = budget_route_ref(jnp.asarray(scores), jnp.asarray(tokens),
                                  tau, capacity=cap)
    assert int(c1) == int(c2)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2))
