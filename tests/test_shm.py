"""The zero-copy shared-memory transport (core/shm): codec exactness,
generation-tagged slot safety, inline-pickle degradation, transport
parity across real worker processes, and the no-orphaned-segments
regression after a hard worker crash."""
import glob
import os

import numpy as np
import pytest

from repro.core import shm as S
from repro.core.campaign import (CampaignExecutor, ExecutorConfig,
                                 FaultInjection)
from repro.core.engine import AdaParseEngine, EngineConfig, ParseRecord
from repro.data.synthetic import Document


def _roundtrip(obj):
    header, arrays, descs, nbytes = S.pack_payload(obj)
    buf = bytearray(nbytes)
    for a, (_dt, _shape, off) in zip(arrays, descs):
        buf[off:off + a.nbytes] = memoryview(a.reshape(-1)).cast("B")
    return S.unpack_payload(header, descs, bytes(buf))


def _assert_docs_equal(a: Document, b: Document):
    assert (a.doc_id, a.difficulty, a.latex_density) == \
        (b.doc_id, b.difficulty, b.latex_density)
    assert (a.producer, a.publisher, a.category, a.year, a.scanned) == \
        (b.producer, b.publisher, b.category, b.year, b.scanned)
    assert len(a.pages) == len(b.pages)
    for pa, pb in zip(a.pages, b.pages):
        assert pa.dtype == pb.dtype
        np.testing.assert_array_equal(pa, pb)


def _doc(doc_id=0, pages=None, producer="pdflatex", publisher="acm"):
    return Document(doc_id=doc_id,
                    pages=(pages if pages is not None
                           else [np.arange(5, dtype=np.int32)]),
                    difficulty=0.3, latex_density=0.1, producer=producer,
                    publisher=publisher, category="cs.DC", year=2024,
                    scanned=False)


# ---------------------------------------------------------------------------
# Codec: decode(encode(x)) is byte-identical
# ---------------------------------------------------------------------------


def test_codec_roundtrips_empty_document():
    """A document with no pages, and one whose only page is a length-0
    array (a failed parse), both survive exactly."""
    for pages in ([], [np.zeros(0, np.int32)],
                  [np.zeros(0, np.int32), np.arange(3, dtype=np.int32)]):
        doc = _doc(pages=pages)
        _assert_docs_equal(doc, _roundtrip(doc))


def test_codec_roundtrips_non_ascii_text():
    doc = _doc(producer="pdfTeX-1.40 — фреймворк", publisher="Éditions 数学")
    out = _roundtrip(doc)
    _assert_docs_equal(doc, out)
    assert out.producer == "pdfTeX-1.40 — фреймворк"


def test_codec_roundtrips_max_length_pages():
    """Pages at the corpus page_tokens ceiling, several dtypes, plus a
    ParseRecord wrapping them — every byte survives."""
    rng = np.random.RandomState(0)
    pages = [rng.randint(0, 2**31 - 1, 6144).astype(np.int32),
             rng.randint(0, 255, 6144).astype(np.uint8),
             rng.randn(6144)]
    rec = ParseRecord(doc_id=7, parser="pymupdf", pages=pages,
                      cost_s=0.125)
    out = _roundtrip(rec)
    assert (out.doc_id, out.parser, out.cost_s) == (7, "pymupdf", 0.125)
    for pa, pb in zip(pages, out.pages):
        assert pa.dtype == pb.dtype
        np.testing.assert_array_equal(pa, pb)


def test_codec_roundtrips_rng_and_containers():
    """RandomState streams (PreparedBatch.rng) resume identically, and
    nested container/scalar structure is type-exact."""
    rs = np.random.RandomState(42)
    rs.rand(17)                        # partially consumed stream
    obj = {"rng": rs, "t": (1, "α", None, np.float32(0.5)),
           "l": [np.arange(4), b"raw"], "flag": True}
    out = _roundtrip(obj)
    assert out["t"] == (1, "α", None, np.float32(0.5))
    assert isinstance(out["t"], tuple) and isinstance(out["l"], list)
    assert type(out["t"][3]) is np.float32
    assert out["l"][1] == b"raw" and out["flag"] is True
    np.testing.assert_array_equal(out["rng"].rand(9),
                                  np.random.RandomState(42).rand(17 + 9)[17:])


def test_codec_rejects_unknown_types_actionably():
    with pytest.raises(TypeError, match="cannot pack"):
        S.pack_payload({"bad": object()})


# ---------------------------------------------------------------------------
# Arena + coordinator transport: generations, fallbacks, cleanup
# ---------------------------------------------------------------------------


def _shm_entries(prefix: str) -> list[str]:
    return sorted(glob.glob(f"/dev/shm/{prefix}*"))


def test_stale_generation_raises_shm_stale():
    """Reading a freed (reclaimed) task slot is a clean ShmStale, not
    silent wrong bytes — the straggler-re-issue safety property."""
    t = S.CoordinatorShmTransport("adp-shmtest-stale", 1, n_task_slots=2,
                                  n_resp_slots=2)
    try:
        ref = t.encode_task([np.arange(10)])
        assert ref is not None
        np.testing.assert_array_equal(t._task.read(ref)[0], np.arange(10))
        t.free_task(ref)
        with pytest.raises(S.ShmStale):
            t._task.read(ref)
    finally:
        t.close()
    assert _shm_entries("adp-shmtest-stale") == []


def test_oversize_and_exhausted_slots_fall_back_inline():
    """A payload over the slot capacity and a full arena both return
    None (ship inline) instead of failing; freed slots are reused."""
    t = S.CoordinatorShmTransport("adp-shmtest-fb", 1, n_task_slots=2,
                                  n_resp_slots=2)
    try:
        small = [np.zeros(8, np.uint8)]
        r1, r2 = t.encode_task(small), t.encode_task(small)
        assert r1 is not None and r2 is not None
        assert t.encode_task(small) is None          # slots exhausted
        big = [np.zeros(2 * t._task.slot_bytes, np.uint8)]
        assert t.encode_task(big) is None            # over slot capacity
        assert t.fallbacks == 2
        t.free_task(r1)
        assert t.encode_task(small) is not None      # slot came back
    finally:
        t.close()
    assert _shm_entries("adp-shmtest-fb") == []


def test_worker_response_slots_cycle_free_full():
    """Worker encode flips a free slot FULL; coordinator take_result
    decodes byte-identically and frees it; exhaustion falls back."""
    t = S.CoordinatorShmTransport("adp-shmtest-resp", 1, n_task_slots=2,
                                  n_resp_slots=2)
    try:
        assert t.encode_task([np.arange(3)]) is not None  # sizes arenas
        w = S.WorkerShmTransport("adp-shmtest-resp", 0, 1, n_resp_slots=2)
        payload = {"recs": [np.arange(100, dtype=np.int64)], "n": 5}
        refs = [w.encode_result(payload) for _ in range(2)]
        assert all(r is not None for r in refs)
        assert w.encode_result(payload) is None      # both slots FULL
        out = t.take_result(refs[0])
        np.testing.assert_array_equal(out["recs"][0],
                                      np.arange(100, dtype=np.int64))
        assert out["n"] == 5
        assert w.encode_result(payload) is not None  # slot freed
        w.close()
    finally:
        t.close()
    assert _shm_entries("adp-shmtest-resp") == []


# ---------------------------------------------------------------------------
# Real worker fleets: transport parity + crash-orphan regression
# ---------------------------------------------------------------------------


def _assert_same_records(a: dict, b: dict):
    assert set(a) == set(b)
    for i in a:
        assert a[i].parser == b[i].parser
        assert a[i].cost_s == b[i].cost_s
        assert len(a[i].pages) == len(b[i].pages)
        for pa, pb in zip(a[i].pages, b[i].pages):
            np.testing.assert_array_equal(pa, pb)


@pytest.fixture()
def pool_spy(monkeypatch):
    """Capture every ProcessWorkerPool the campaign layer builds, so
    tests can inspect its shm transport after the run."""
    from repro.core import workers as W

    pools = []
    orig = W.ProcessWorkerPool.__init__

    def spy(self, *a, **kw):
        orig(self, *a, **kw)
        pools.append(self)

    monkeypatch.setattr(W.ProcessWorkerPool, "__init__", spy)
    return pools


def test_shm_and_pickle_campaigns_match_record_for_record(
        corpus, ft_router, pool_spy):
    """Satellite 4: the same 2-worker campaign over shm and pickle
    transports produces record-for-record identical output, equal to
    the single-node reference — and the shm run actually used the
    arenas (zero inline fallbacks, no leftover segments)."""
    ccfg, docs = corpus
    test = docs[75:]
    ecfg = EngineConfig(alpha=0.1, batch_size=16)
    single = AdaParseEngine(ecfg, ft_router, ccfg).run(test)
    runs = {}
    for transport in ("shm", "pickle"):
        xcfg = ExecutorConfig(n_nodes=2, runtime="process",
                              transport=transport)
        runs[transport] = CampaignExecutor(ecfg, xcfg, ft_router,
                                           ccfg).run(test)
    _assert_same_records(single, runs["shm"].records)
    _assert_same_records(runs["pickle"].records, runs["shm"].records)
    shm_pool = pool_spy[0]
    assert shm_pool._shm is not None
    assert shm_pool._shm.fallbacks == 0
    assert shm_pool._shm._task is None           # close() ran
    assert pool_spy[1]._shm is None              # pickle run: no arenas
    assert _shm_entries(shm_pool._shm.base) == []


def test_invalid_transport_is_actionable(corpus, ft_router):
    ccfg, docs = corpus
    ecfg = EngineConfig(alpha=0.1, batch_size=16)
    with pytest.raises(ValueError, match="transport"):
        CampaignExecutor(
            ecfg, ExecutorConfig(n_nodes=2, runtime="process",
                                 transport="grpc"),
            ft_router, ccfg).run(docs[75:99])


def test_crashed_worker_leaves_no_shm_orphans(corpus, ft_router,
                                              pool_spy):
    """Satellite 3 regression: a worker hard-killed via os._exit with a
    batch in flight must not strand /dev/shm segments — the coordinator
    unlinks the dead worker's response arena at crash recovery and
    everything else at close(), while the record set still matches the
    single-node run."""
    ccfg, docs = corpus
    test = docs[75:]
    ecfg = EngineConfig(alpha=0.1, batch_size=16)
    single = AdaParseEngine(ecfg, ft_router, ccfg).run(test)
    xcfg = ExecutorConfig(
        n_nodes=2, runtime="process", transport="shm",
        heartbeat_timeout_s=5.0, heartbeat_interval_s=0.1,
        fault_injection=FaultInjection(crash_after=((1, 1),)))
    res = CampaignExecutor(ecfg, xcfg, ft_router, ccfg).run(test)
    _assert_same_records(single, res.records)
    assert res.reissued >= 1
    base = pool_spy[0]._shm.base
    assert base.startswith(f"adaparse-{os.getpid():x}-")
    assert _shm_entries(base) == []
    # and no orphan from ANY pool this process ever created
    assert _shm_entries(f"adaparse-{os.getpid():x}-") == []
