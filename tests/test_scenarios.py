"""The scenario lab (core/scenarios): a named, deterministic stress-
scenario matrix over both worker runtimes. Tier-1 runs the fast subset
(the local simulated fleet) end-to-end — each scenario asserts the
byte-identical-records invariant against its single-node reference
inside ``run_scenario`` — plus the registry/runner contracts. The
process-runtime scenarios run in the bench sweep (BENCH_scenarios.json)
and the CI fast lane."""
import dataclasses

import numpy as np
import pytest

from repro.core.scenarios import (FAST_SCENARIOS, SCENARIOS,
                                  ScenarioMismatch, ScenarioSpec,
                                  _assert_records_match, get_scenario,
                                  run_scenario)

REQUIRED = {"crash_storm", "wedged_straggler_flap", "bursty_arrivals",
            "bimodal_retune", "cold_warm_shared_store", "slowdown_skew",
            "shm_crash_reissue", "elastic_join_leave"}


def test_registry_ships_the_scenario_matrix():
    """At least the six ISSUE-6 scenarios plus the shm-transport crash
    scenario and the elastic fabric scenario, each fully declarative
    and self-describing; the fast subset is a strict subset that avoids
    process spawns."""
    assert REQUIRED <= set(SCENARIOS)
    assert len(SCENARIOS) >= 8
    for name, spec in SCENARIOS.items():
        assert spec.name == name
        assert isinstance(spec, ScenarioSpec) and spec.description
        assert spec.runtime in ("local", "process", "fabric")
        assert spec.transport in ("shm", "pickle")
    assert SCENARIOS["shm_crash_reissue"].transport == "shm"
    assert SCENARIOS["shm_crash_reissue"].fault is not None
    elastic = SCENARIOS["elastic_join_leave"]
    assert elastic.runtime == "fabric"
    assert elastic.fault is not None       # the mid-campaign crash
    assert elastic.fabric is not None      # the join + reject schedule
    assert elastic.fabric.join_after and elastic.fabric.reject >= 1
    assert set(FAST_SCENARIOS) <= set(SCENARIOS)
    assert all(SCENARIOS[n].runtime == "local" for n in FAST_SCENARIOS)


def test_get_scenario_unknown_name_is_actionable():
    with pytest.raises(KeyError, match="crash_storm"):
        get_scenario("no_such_scenario")


@pytest.mark.parametrize("name", FAST_SCENARIOS)
def test_fast_scenarios_end_to_end(name):
    """Each fast scenario runs its fleet, survives its adversarial
    schedule, and reproduces the single-node reference byte-for-byte
    (run_scenario raises ScenarioMismatch otherwise)."""
    res = run_scenario(SCENARIOS[name])
    assert res.records_match
    assert res.n_docs > 0 and res.goodput_docs_per_s > 0
    m = res.metrics()
    for key in ("records_match", "goodput_docs_per_s", "reissued",
                "duplicates_dropped", "cache_hits", "cache_misses"):
        assert key in m


def test_slowdown_skew_exercises_reissue():
    """The pathological-skew scenario actually trips the local
    runtime's deadline re-issue path (otherwise it guards nothing)."""
    res = run_scenario(SCENARIOS["slowdown_skew"])
    assert res.reissued >= 1


def test_bimodal_retune_moves_alpha():
    """The bimodal corpus + full-rate probe produce a live α
    trajectory (the retuner reacts), and parity still holds against
    the n_nodes=1 controller reference."""
    res = run_scenario(SCENARIOS["bimodal_retune"])
    assert res.rounds == SCENARIOS["bimodal_retune"].rounds
    assert len(res.alpha_trajectory) == res.rounds
    assert len(set(res.alpha_trajectory)) > 1


def test_record_mismatch_raises_scenario_mismatch():
    """The determinism assert fires on any divergence: a missing doc,
    a different parser, or different page payloads."""
    from repro.core.engine import ParseRecord

    def rec(i, parser="pymupdf", fill=0):
        return ParseRecord(i, parser,
                           [np.full(8, fill, np.int32)], 1.0)

    ref = {0: rec(0), 1: rec(1)}
    _assert_records_match("t", ref, {0: rec(0), 1: rec(1)})
    with pytest.raises(ScenarioMismatch, match="doc ids"):
        _assert_records_match("t", ref, {0: rec(0)})
    with pytest.raises(ScenarioMismatch, match="diverged"):
        _assert_records_match("t", ref,
                              {0: rec(0), 1: rec(1, parser="nougat")})
    with pytest.raises(ScenarioMismatch, match="diverged"):
        _assert_records_match("t", ref, {0: rec(0), 1: rec(1, fill=7)})


def test_spec_overrides_stay_declarative():
    """Specs are frozen dataclasses: a tweaked copy runs without
    touching the registry (the serve.py --scenario contract)."""
    spec = dataclasses.replace(SCENARIOS["bursty_arrivals"], rounds=1,
                               arrival_skew=((4.0, 1.0, 1.0, 0.5),))
    res = run_scenario(spec)
    assert res.records_match and res.rounds == 1
    with pytest.raises(dataclasses.FrozenInstanceError):
        SCENARIOS["bursty_arrivals"].rounds = 5
