"""Per-architecture smoke tests: REDUCED config of each assigned arch runs
one forward/train step on CPU; asserts output shapes + no NaNs. The FULL
configs are exercised only via the dry-run (ShapeDtypeStruct, no alloc)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs
from repro.launch.specs import build_cell

SMOKE_CELLS = [
    ("olmoe-1b-7b", "train_4k"),
    ("olmoe-1b-7b", "decode_32k"),
    ("grok-1-314b", "train_4k"),
    ("grok-1-314b", "prefill_32k"),
    ("h2o-danube-3-4b", "train_4k"),
    ("h2o-danube-3-4b", "long_500k"),
    ("phi3-medium-14b", "train_4k"),
    ("phi3-medium-14b", "decode_32k"),
    ("qwen3-1.7b", "train_4k"),
    ("qwen3-1.7b", "prefill_32k"),
    ("equiformer-v2", "full_graph_sm"),
    ("equiformer-v2", "minibatch_lg"),
    ("equiformer-v2", "ogb_products"),
    ("equiformer-v2", "molecule"),
    ("autoint", "train_batch"),
    ("autoint", "serve_p99"),
    ("dien", "train_batch"),
    ("dien", "retrieval_cand"),
    ("dlrm-mlperf", "train_batch"),
    ("dlrm-mlperf", "serve_bulk"),
    ("deepfm", "train_batch"),
    ("deepfm", "retrieval_cand"),
    ("adaparse-router", "sft_4k"),
    ("adaparse-router", "dpo_2k"),
    ("adaparse-router", "route_64k"),
    ("nougat-base", "train_pages"),
    ("nougat-base", "parse_encode"),
    ("nougat-base", "parse_decode"),
]


@pytest.mark.parametrize("arch_id,shape", SMOKE_CELLS,
                         ids=[f"{a}-{s}" for a, s in SMOKE_CELLS])
def test_arch_smoke(arch_id, shape):
    cell = build_cell(arch_id, shape, rules=None, abstract=False,
                      reduced=True)
    out = jax.jit(cell.fn)(*cell.args)
    leaves = jax.tree_util.tree_leaves(out)
    assert leaves, "no outputs"
    for x in leaves:
        if jnp.issubdtype(x.dtype, jnp.floating):
            assert bool(jnp.isfinite(x).all()), f"non-finite in {x.shape}"


def test_all_archs_registered():
    archs = list_archs()
    assert len(archs) == 12       # 10 assigned + router + nougat
    for a in archs:
        cfg = get_config(a)
        assert cfg.reduced is not None
        assert cfg.shapes


def test_documented_skips():
    """long_500k must be skipped exactly for the pure full-attention LMs."""
    full_attn = {"olmoe-1b-7b", "grok-1-314b", "phi3-medium-14b",
                 "qwen3-1.7b"}
    for a in full_attn:
        assert "long_500k" in get_config(a).skips
    assert "long_500k" not in get_config("h2o-danube-3-4b").skips


def test_40_cell_matrix():
    """10 assigned archs x 4 shapes = 40 cells; skips documented."""
    assigned = [a for a in list_archs()
                if a not in ("adaparse-router", "nougat-base")]
    total = sum(len(get_config(a).shapes) for a in assigned)
    assert total == 40
    runnable = sum(len(get_config(a).runnable_shapes()) for a in assigned)
    skipped = sum(len(get_config(a).skips) for a in assigned)
    assert runnable + skipped == 40
