"""Model substrate unit tests: attention equivalences, decode consistency,
MoE routing, equivariance, SO(3) exactness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common import unwrap
from repro.configs.base import GNNConfig, LMConfig, MoEConfig
from repro.models import attention as A
from repro.models import transformer as T
from repro.models.attention import KVCache
from repro.models.gnn import equiformer as EQ
from repro.models.gnn import sampler, so3


@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 7)])
def test_xla_flash_matches_naive(causal, window):
    q = jax.random.normal(jax.random.key(1), (2, 33, 4, 8))
    k = jax.random.normal(jax.random.key(2), (2, 49, 2, 8))
    v = jax.random.normal(jax.random.key(3), (2, 49, 2, 8))
    o1 = A.attention_naive(q, k, v, causal=causal, window=window)
    o2 = A.attention_xla_flash(q, k, v, causal=causal, window=window,
                               q_chunk=8, kv_chunk=16)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-6)


def _tiny_lm(**kw):
    base = dict(name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                d_ff=128, vocab_size=256, param_dtype="float32",
                compute_dtype="float32", q_chunk=8, kv_chunk=8)
    base.update(kw)
    return LMConfig(**base)


def test_prefill_decode_match_full_forward():
    cfg = _tiny_lm(qk_norm=True)
    p = unwrap(T.init_lm(cfg, 0))
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, 256)
    full, _ = T.lm_logits(p, cfg, toks)
    lg_pre, cache = T.prefill(p, cfg, toks[:, :15])
    np.testing.assert_allclose(np.asarray(lg_pre), np.asarray(full[:, 14]),
                               atol=1e-4)
    cache = KVCache(jnp.pad(cache.k, ((0, 0), (0, 0), (0, 1), (0, 0), (0, 0))),
                    jnp.pad(cache.v, ((0, 0), (0, 0), (0, 1), (0, 0), (0, 0))))
    lg_dec, _ = T.decode_step(p, cfg, toks[:, 15:16], cache, jnp.asarray(15))
    np.testing.assert_allclose(np.asarray(lg_dec), np.asarray(full[:, 15]),
                               atol=1e-4)


def test_sliding_window_decode_matches_full():
    cfg = _tiny_lm(sliding_window=6)
    p = unwrap(T.init_lm(cfg, 0))
    toks = jax.random.randint(jax.random.key(2), (1, 24), 0, 256)
    full, _ = T.lm_logits(p, cfg, toks)
    _, cache = T.prefill(p, cfg, toks[:, :23])
    cache = KVCache(jnp.pad(cache.k, ((0, 0), (0, 0), (0, 1), (0, 0), (0, 0))),
                    jnp.pad(cache.v, ((0, 0), (0, 0), (0, 1), (0, 0), (0, 0))))
    lg, _ = T.decode_step(p, cfg, toks[:, 23:24], cache, jnp.asarray(23))
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, 23]),
                               atol=1e-4)


def test_scan_vs_unrolled_layers_identical():
    cfg = _tiny_lm()
    p = unwrap(T.init_lm(cfg, 0))
    toks = jax.random.randint(jax.random.key(3), (2, 12), 0, 256)
    l1, _ = T.lm_logits(p, cfg, toks)
    import dataclasses
    cfg2 = dataclasses.replace(cfg, scan_layers=False, unroll_pairs=True)
    l2, _ = T.lm_logits(p, cfg2, toks)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)


def test_moe_topk_capacity_and_aux():
    cfg = MoEConfig(n_experts=8, top_k=2, d_ff_expert=16,
                    capacity_factor=1.0)
    lm = _tiny_lm(n_kv_heads=4, moe=cfg)
    p = unwrap(T.init_lm(lm, 0))
    toks = jax.random.randint(jax.random.key(4), (2, 16), 0, 256)
    logits, aux = T.lm_logits(p, lm, toks)
    assert np.isfinite(np.asarray(logits)).all()
    assert float(aux) > 0                      # load-balance loss active


def test_moe_budget_router_runs():
    cfg = MoEConfig(n_experts=8, top_k=2, d_ff_expert=16, router="budget",
                    budget_alpha=0.2)
    lm = _tiny_lm(n_kv_heads=4, moe=cfg)
    p = unwrap(T.init_lm(lm, 0))
    toks = jax.random.randint(jax.random.key(5), (2, 16), 0, 256)
    logits, _ = T.lm_logits(p, lm, toks)
    assert np.isfinite(np.asarray(logits)).all()


# -- SO(3) / Equiformer -------------------------------------------------------


def test_wigner_represents_rotations():
    rng = np.random.RandomState(1)
    q1, _ = np.linalg.qr(rng.randn(3, 3))
    if np.linalg.det(q1) < 0:
        q1[:, 0] *= -1
    r = jnp.asarray(q1, jnp.float32)
    u = jax.random.normal(jax.random.key(2), (20, 3))
    u = u / jnp.linalg.norm(u, axis=-1, keepdims=True)
    d = so3.wigner_from_rotation(r, 4)
    yu = so3.real_sph_harm(u, 4)
    yru = so3.real_sph_harm(u @ r.T, 4)
    for l in range(5):
        lhs = yru[:, l * l:(l + 1) ** 2]
        rhs = jnp.einsum("nm,km->kn", d[l], yu[:, l * l:(l + 1) ** 2])
        np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                                   atol=5e-6)
        # orthogonality
        eye = np.asarray(d[l] @ d[l].T)
        np.testing.assert_allclose(eye, np.eye(2 * l + 1), atol=5e-6)


@pytest.mark.slow
def test_equiformer_rotation_invariance():
    cfg = GNNConfig(name="t", n_layers=2, d_hidden=16, l_max=3, m_max=2,
                    n_heads=4, n_radial=8, d_in=7, n_out=3)
    p = unwrap(EQ.init_equiformer(cfg, 0))
    n, e = 20, 60
    pos = jax.random.normal(jax.random.key(0), (n, 3))
    batch = {
        "pos": pos,
        "src": jax.random.randint(jax.random.key(1), (e,), 0, n),
        "dst": jax.random.randint(jax.random.key(2), (e,), 0, n),
        "node_feat": jax.random.normal(jax.random.key(3), (n, 7)),
    }
    rng = np.random.RandomState(5)
    q, _ = np.linalg.qr(rng.randn(3, 3))
    if np.linalg.det(q) < 0:
        q[:, 0] *= -1
    o1 = EQ.equiformer_forward(p, cfg, batch)
    o2 = EQ.equiformer_forward(
        p, cfg, dict(batch, pos=pos @ jnp.asarray(q, jnp.float32).T))
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=5e-5)
    # translation invariance
    o3 = EQ.equiformer_forward(
        p, cfg, dict(batch, pos=pos + jnp.asarray([1.0, -2.0, 3.0])))
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o3), atol=5e-5)


def test_neighbor_sampler_static_shapes():
    g = sampler.random_powerlaw_graph(2000, 8, seed=0)
    rng = np.random.RandomState(0)
    for b, fo in [(16, [5, 3]), (8, [15, 10])]:
        sub = sampler.static_sample(g, np.arange(b), fo, rng)
        assert len(sub["nodes"]) == sampler.static_node_count(b, fo)
        assert len(sub["src"]) == sampler.static_edge_count(b, fo)
        assert sub["dst"].max() < len(sub["nodes"])
        # message flow: children (later indices) feed parents
        assert (sub["src"] > sub["dst"]).all()
