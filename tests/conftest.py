import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)


@pytest.fixture(scope="session")
def corpus():
    """The shared synthetic corpus (150 docs, seed 0): session-scoped so
    AdaParse system tests pay corpus generation once."""
    from repro.data.synthetic import CorpusConfig, generate_corpus
    ccfg = CorpusConfig(n_docs=150, seed=0)
    return ccfg, generate_corpus(ccfg)


@pytest.fixture(scope="session")
def ft_router(corpus):
    """FT-variant router (CLS I+II) trained on the first half of the
    shared corpus — one training pass for every engine/executor test."""
    from repro.launch.serve import build_ft_router
    ccfg, docs = corpus
    return build_ft_router(docs[:75], ccfg, np.random.RandomState(1))
