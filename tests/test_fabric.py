"""The cross-machine campaign fabric (core/fabric + core/specs +
launch/fabric_worker): length-prefixed frame transport, spec-
fingerprint admission with actionable rejections, elastic membership
(mid-campaign joins, crash-leaves re-issued to live peers, the
join/leave span conservation law), and the determinism bar — a fabric
campaign with workers joining and crashing mid-run reproduces the
single-node record set byte-identically."""
import queue as queue_lib
import struct
import time
from collections import Counter

import numpy as np
import pytest

from repro.core import obs
from repro.core import specs
from repro.core.campaign import (CampaignController, CampaignExecutor,
                                 ControllerConfig, ExecutorConfig,
                                 FaultInjection)
from repro.core.engine import AdaParseEngine, EngineConfig
from repro.core.fabric import (MISMATCHED_FINGERPRINT, FabricElastic,
                               FabricWorkerPool, FrameDecoder, Hello,
                               Shutdown, encode_frame, parse_addr)
from repro.core.workers import ProcessWorkerPool


def _assert_same_records(a: dict, b: dict):
    assert set(a) == set(b)
    for i in a:
        assert a[i].parser == b[i].parser
        assert a[i].cost_s == b[i].cost_s
        assert len(a[i].pages) == len(b[i].pages)
        for pa, pb in zip(a[i].pages, b[i].pages):
            np.testing.assert_array_equal(pa, pb)


@pytest.fixture(scope="module")
def single_run(corpus, ft_router):
    """The reference record set every fabric campaign must reproduce
    byte-for-byte (batch_size=8 so small fleets see enough batches for
    the elastic schedules to fire)."""
    ccfg, docs = corpus
    test = docs[75:]
    ecfg = EngineConfig(alpha=0.1, batch_size=8)
    return test, ecfg, AdaParseEngine(ecfg, ft_router, ccfg).run(test)


# ---------------------------------------------------------------------------
# Wire format
# ---------------------------------------------------------------------------


def test_frame_roundtrip_one_byte_at_a_time():
    """The incremental decoder reassembles frames from arbitrary
    chunking — the TCP stream guarantees order, nothing else."""
    msgs = [Hello(fingerprint=None, host="h", pid=7), Shutdown(),
            {"arr": np.arange(5, dtype=np.int32), "s": "x" * 100}]
    stream = b"".join(encode_frame(m) for m in msgs)
    dec = FrameDecoder()
    got = []
    for i in range(len(stream)):
        got.extend(dec.feed(stream[i:i + 1]))
    assert len(got) == 3
    assert isinstance(got[0], Hello) and got[0].pid == 7
    assert isinstance(got[1], Shutdown)
    np.testing.assert_array_equal(got[2]["arr"], np.arange(5))
    # and in one gulp
    dec2 = FrameDecoder()
    assert len(list(dec2.feed(stream))) == 3


def test_frame_decoder_rejects_absurd_lengths():
    """A corrupt or hostile length prefix must not allocate an
    unbounded buffer."""
    from repro.core.fabric import MAX_FRAME_BYTES
    bad = struct.pack("!Q", MAX_FRAME_BYTES + 1) + b"x"
    with pytest.raises(ValueError, match="exceeds"):
        list(FrameDecoder().feed(bad))


def test_parse_addr():
    assert parse_addr("127.0.0.1:7777") == ("127.0.0.1", 7777)
    assert parse_addr("0.0.0.0:0") == ("0.0.0.0", 0)
    with pytest.raises(ValueError, match="HOST:PORT"):
        parse_addr("7777")
    with pytest.raises(ValueError, match="HOST:PORT"):
        parse_addr(":7777")


# ---------------------------------------------------------------------------
# Spec fingerprints (core/specs) + admission
# ---------------------------------------------------------------------------


def test_describe_mismatch_names_the_differing_field():
    fp = {"router": "a" * 16, "engine_config": "b" * 16,
          "backends": "c" * 16}
    assert specs.describe_mismatch(fp, dict(fp)) is None
    msg = specs.describe_mismatch(fp, dict(fp, router="0" * 16))
    assert "'router'" in msg and "a" * 16 in msg and "0" * 16 in msg
    msg = specs.describe_mismatch(fp, dict(fp, engine_config="0" * 16))
    assert "'engine_config'" in msg and "EngineConfig" in msg
    msg = specs.describe_mismatch(fp, dict(fp, extra="zzz"))
    assert "unknown fields" in msg and "extra" in msg


def test_engine_config_fingerprint_tracks_record_shaping_fields():
    a = specs.engine_config_fingerprint(EngineConfig(alpha=0.1))
    assert a == specs.engine_config_fingerprint(EngineConfig(alpha=0.1))
    assert a != specs.engine_config_fingerprint(EngineConfig(alpha=0.2))
    x = specs.backend_specs_fingerprint((("m", "f"),))
    assert x == specs.backend_specs_fingerprint((("m", "f"),))
    assert x != specs.backend_specs_fingerprint((("m", "g"),))
    assert x != specs.backend_specs_fingerprint(())


def test_admission_decision_is_actionable():
    """The pure admission check: trust-on-join admits, a matching
    fingerprint admits, a mismatch names the field, a full fleet says
    how to grow it."""
    from collections import deque
    fp = {"router": "a" * 16, "engine_config": "b" * 16,
          "backends": "c" * 16}
    pool = FabricWorkerPool.__new__(FabricWorkerPool)
    pool.n_nodes = 2
    pool._expected_fp = fp
    pool._unassigned = deque([0])
    assert pool._admission_error(Hello(fingerprint=None)) is None
    assert pool._admission_error(Hello(fingerprint=dict(fp))) is None
    reason = pool._admission_error(
        Hello(fingerprint=MISMATCHED_FINGERPRINT))
    assert "'router'" in reason
    pool._unassigned.clear()
    assert "fleet full" in pool._admission_error(Hello())
    # a mismatch is reported even when the fleet is full (the worker
    # should fix its build, not wait for a slot)
    assert "'router'" in pool._admission_error(
        Hello(fingerprint=MISMATCHED_FINGERPRINT))


def test_fabric_pool_heartbeat_clocks_not_comparable():
    """Cross-machine CLOCK_MONOTONIC stamps are never differenced; the
    spawn runtime (same host) keeps the queue-delay diagnostic."""
    assert ProcessWorkerPool._mono_comparable is True
    assert FabricWorkerPool._mono_comparable is False


# ---------------------------------------------------------------------------
# Elastic membership over a live loopback fleet
# ---------------------------------------------------------------------------


def _pump_until(pool, cond, timeout_s: float, what: str):
    """Drive the pool's message loop by hand until ``cond()`` holds
    (the drain loop isn't running — tests single-step membership)."""
    deadline = time.time() + timeout_s
    while not cond():
        if time.time() > deadline:
            raise AssertionError(f"timed out waiting for {what}")
        try:
            msg = pool.result_q.get(timeout=0.2)
        except queue_lib.Empty:
            continue
        pool._handle(msg)


def test_membership_span_conservation_law(corpus, ft_router):
    """#join - #leave == the live fleet delta: every admission emits a
    join span, every dropped connection a leave span, every refused
    dialer an admission_rejected span — and the counts reconcile with
    the pool's live view at any instant."""
    ccfg, _ = corpus
    ecfg = EngineConfig(alpha=0.1, batch_size=8)
    xcfg = ExecutorConfig(n_nodes=2, runtime="fabric", obs=True,
                          heartbeat_interval_s=0.2)
    rec = obs.configure(enabled=True, node=-1)
    base = obs.metrics().snapshot()       # counters are per-process
    pool = None
    reject_proc = None
    try:
        pool = FabricWorkerPool(ecfg, xcfg, ft_router, ccfg, 2, [0, 1],
                                [0, 1], None)
        assert pool._joins == 2 and pool._leaves == 0
        assert sorted(pool.live_ingest_nodes()) == [0, 1]

        # a dialer built from a different spec is refused with the
        # differing field named, and exits non-zero
        from repro.launch.fabric_worker import spawn_loopback
        reject_proc = spawn_loopback(pool.addr,
                                     fingerprint=MISMATCHED_FINGERPRINT)
        _pump_until(pool, lambda: pool._rejected == 1, 120.0,
                    "the mismatched dialer's rejection")
        reject_proc.join(timeout=60.0)
        assert reject_proc.exitcode == 4
        assert pool._joins == 2            # a rejection is not a join

        # hard-kill one worker: its connection drops, the pool records
        # the leave, and the live view shrinks by exactly one
        pool._local_procs[0].terminate()
        _pump_until(pool, lambda: pool._leaves == 1, 60.0,
                    "the killed worker's leave")
        live = pool.live_ingest_nodes()
        assert pool._joins - pool._leaves == len(live) == 1

        spans = rec.drain(100000)
        names = Counter(s.name for s in spans)
        assert names["join"] == 2
        assert names["leave"] == 1
        assert names["admission_rejected"] == 1
        assert names["join"] - names["leave"] == len(live)
        rejected = [s for s in spans if s.name == "admission_rejected"]
        assert "'router'" in rejected[0].detail

        # the byte counters moved in both directions
        pool._flush_net_counters()
        counters = obs.diff(obs.metrics().snapshot(), base)["counters"]
        assert counters.get("fabric.joins", 0) == 2
        assert counters.get("fabric.leaves", 0) == 1
        assert counters.get("fabric.rejected", 0) == 1
        assert counters.get("fabric.bytes_tx", 0) > 0
        assert counters.get("fabric.bytes_rx", 0) > 0
    finally:
        if pool is not None:
            pool.close()
        if reject_proc is not None and reject_proc.is_alive():
            reject_proc.terminate()
        obs.configure(enabled=False)


# ---------------------------------------------------------------------------
# Campaign determinism over the fabric
# ---------------------------------------------------------------------------


def test_fabric_pool_matches_single_node(corpus, ft_router, single_run):
    """2 loopback fabric workers produce the byte-identical record set
    of the single-node in-process run."""
    ccfg, _ = corpus
    test, ecfg, single = single_run
    xcfg = ExecutorConfig(n_nodes=2, runtime="fabric",
                          heartbeat_interval_s=0.2)
    res = CampaignExecutor(ecfg, xcfg, ft_router, ccfg).run(test)
    _assert_same_records(single, res.records)
    assert sum(s.n_docs for s in res.node_stats) == len(test)
    assert all(s.n_docs > 0 for s in res.node_stats)


def test_elastic_join_crash_campaign_matches_single_node(
        corpus, ft_router, single_run):
    """The tentpole acceptance bar: an adaptive fabric campaign where a
    worker joins mid-run and another hard-crashes (its in-flight and
    queued batches re-route through the inherited re-issue path, and
    the controller re-shards over the live fleet at round boundaries)
    reproduces the single-node record set byte-identically, with the
    membership spans and fleet-folded fabric counters to show for it."""
    ccfg, _ = corpus
    test, ecfg, single = single_run
    xcfg = ExecutorConfig(
        n_nodes=3, runtime="fabric", obs=True,
        heartbeat_timeout_s=5.0, heartbeat_interval_s=0.1,
        fault_injection=FaultInjection(crash_after=((1, 2),)),
        fabric=FabricElastic(join_after=((2, 3),)))
    res = CampaignController(ecfg, xcfg, ControllerConfig(rounds=2),
                             ft_router, ccfg).run(test)
    _assert_same_records(single, res.records)
    assert res.reissued >= 1               # the crash re-routed work
    assert res.rounds == 2

    by = Counter(s.name for s in (res.spans or []))
    assert by["leave"] == 1                # exactly the crashed worker
    assert by["join"] >= 2                 # the initial fleet admitted
    assert by["join"] - by["leave"] >= 1   # someone survived to finish
    assert set(by) <= set(obs.SPAN_STAGES)

    counters = (res.obs_metrics or {}).get("counters", {})
    assert counters.get("fabric.joins", 0) == by["join"]
    assert counters.get("fabric.leaves", 0) == 1
    assert counters.get("fabric.bytes_tx", 0) > 0
    assert counters.get("fabric.bytes_rx", 0) > 0


def test_fabric_runtime_rejects_bad_config(corpus, ft_router):
    """Actionable errors before any socket binds: the shared xcfg
    validation applies, and an elastic schedule naming unknown nodes is
    refused."""
    ccfg, docs = corpus
    ecfg = EngineConfig(alpha=0.1, batch_size=16)
    with pytest.raises(ValueError, match="simulation-only"):
        CampaignExecutor(
            ecfg, ExecutorConfig(n_nodes=2, runtime="fabric",
                                 node_speed_factors=[1.0, 4.0]),
            ft_router, ccfg).run(docs[75:])
    with pytest.raises(ValueError, match="join_after"):
        CampaignExecutor(
            ecfg, ExecutorConfig(n_nodes=2, runtime="fabric",
                                 fabric=FabricElastic(
                                     join_after=((7, 1),))),
            ft_router, ccfg).run(docs[75:])
