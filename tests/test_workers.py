"""The multi-process worker runtime (core/workers): real spawned
worker processes behind campaign dispatch. Record parity with the
single-node in-process engine (homogeneous, pooled + prefetched +
disk-cached + adaptive — the ISSUE-5 acceptance combination),
worker-crash recovery via heartbeat liveness with pool-aware re-issue,
the first-completion-wins dedup gate (a re-issued straggler's late
results never duplicate an emitted record), cross-process warm replay
through the shared multi-process-safe DiskResultStore, and the
config validation that keeps simulation-only knobs out of the real
runtime."""
import numpy as np
import pytest

from repro.core.backends import DiskResultStore, ResultCache
from repro.core.campaign import (CampaignController, CampaignExecutor,
                                 ControllerConfig, ExecutorConfig,
                                 FaultInjection)
from repro.core.engine import AdaParseEngine, EngineConfig
from repro.core.workers import (LocalWorkerPool, ProcessWorkerPool,
                                WorkerPool)


def _assert_same_records(a: dict, b: dict):
    assert set(a) == set(b)
    for i in a:
        assert a[i].parser == b[i].parser
        assert a[i].cost_s == b[i].cost_s
        assert len(a[i].pages) == len(b[i].pages)
        for pa, pb in zip(a[i].pages, b[i].pages):
            np.testing.assert_array_equal(pa, pb)


@pytest.fixture(scope="module")
def single_run(corpus, ft_router):
    """The reference record set every process campaign must reproduce
    byte-for-byte."""
    ccfg, docs = corpus
    test = docs[75:]
    ecfg = EngineConfig(alpha=0.1, batch_size=16)
    return test, ecfg, AdaParseEngine(ecfg, ft_router, ccfg).run(test)


def test_process_pool_matches_single_node(corpus, ft_router, single_run):
    """2 real worker processes produce the byte-identical record set of
    the single-node in-process run, and both workers did real work."""
    ccfg, _ = corpus
    test, ecfg, single = single_run
    xcfg = ExecutorConfig(n_nodes=2, runtime="process")
    res = CampaignExecutor(ecfg, xcfg, ft_router, ccfg).run(test)
    _assert_same_records(single, res.records)
    assert res.wall_s > 0 and res.docs_per_s > 0
    assert all(s.n_docs > 0 for s in res.node_stats)
    assert sum(s.n_docs for s in res.node_stats) == len(test)


def test_process_pool_pools_prefetch_disk_adaptive_parity(
        corpus, ft_router, single_run, tmp_path):
    """The ISSUE-5 acceptance bar: a 4-worker fleet with heterogeneous
    pools + prefetch windows + a shared on-disk result store + adaptive
    rounds reproduces the single-node record set byte-for-byte; a
    subsequent single-process warm run over the same store dir replays
    everything the worker processes wrote (multi-process-safe WAL)."""
    ccfg, _ = corpus
    test, ecfg, single = single_run
    store = DiskResultStore(tmp_path / "cache")
    xcfg = ExecutorConfig(n_nodes=4,
                          node_pools=["cpu", "cpu", "cpu", "gpu"],
                          prefetch_depth=2, runtime="process")
    res = CampaignController(ecfg, xcfg, ControllerConfig(rounds=2),
                             ft_router, ccfg).run(test, cache=store)
    _assert_same_records(single, res.records)
    assert res.rounds == 2
    assert res.cache_hits == 0 and res.cache_misses > 0
    # GPU-pool worker completed re-parses but ingested nothing
    assert res.node_stats[3].n_docs == 0
    assert res.node_stats[3].n_expensive > 0
    assert sum(s.n_docs for s in res.node_stats[:3]) == len(test)

    # cross-process warm replay: a fresh store over the same dir sees
    # every batch the four workers stored
    store2 = DiskResultStore(tmp_path / "cache")
    warm = CampaignExecutor(
        ecfg, ExecutorConfig(n_nodes=2, straggler_rate=0.0),
        ft_router, ccfg).run(test, cache=store2)
    _assert_same_records(single, warm.records)
    assert warm.cache_misses == 0
    assert warm.cache_hits == res.cache_misses


def test_process_pool_survives_worker_crash(corpus, ft_router,
                                            single_run):
    """Kill a worker mid-campaign (hard os._exit with a batch in
    flight): liveness detection re-issues its work to the surviving
    peer and the record set still matches the single-node run."""
    ccfg, _ = corpus
    test, ecfg, single = single_run
    xcfg = ExecutorConfig(
        n_nodes=2, runtime="process", heartbeat_timeout_s=5.0,
        heartbeat_interval_s=0.1,
        fault_injection=FaultInjection(crash_after=((1, 1),)))
    res = CampaignExecutor(ecfg, xcfg, ft_router, ccfg).run(test)
    assert res.reissued >= 1
    _assert_same_records(single, res.records)
    assert sum(s.n_docs for s in res.node_stats) == len(test)


@pytest.mark.parametrize("slowdown", [0.9, 1.4])
def test_heartbeat_reissue_never_duplicates_records(corpus, ft_router,
                                                    single_run, slowdown):
    """Property (ISSUE-5): heartbeat-deadline re-issue never duplicates
    an emitted record, whatever the straggler timing. Worker 1 stops
    heartbeating but keeps working (slowed) — its batches re-issue to
    the peer, both attempts eventually produce results, and exactly one
    emission per batch survives: per-doc records match the single-node
    run and the per-node doc counts sum to the corpus exactly."""
    ccfg, _ = corpus
    test, ecfg, single = single_run
    xcfg = ExecutorConfig(
        n_nodes=2, runtime="process", heartbeat_timeout_s=0.5,
        heartbeat_interval_s=0.1, straggler_grace_s=2.5,
        fault_injection=FaultInjection(mute_after=((1, 0),),
                                       mute_slowdown_s=slowdown))
    res = CampaignExecutor(ecfg, xcfg, ft_router, ccfg).run(test)
    _assert_same_records(single, res.records)
    assert res.reissued >= 1
    # no double emission: every doc counted exactly once across nodes
    assert sum(s.n_docs for s in res.node_stats) == len(test)
    # the straggler's late result for a re-issued batch was dropped,
    # not emitted (observable once its sleep ends within the grace)
    assert res.duplicates_dropped >= 1


class _FakeQ:
    """Capture-only stand-in for a worker task queue."""

    def __init__(self):
        self.sent = []

    def put(self, msg):
        self.sent.append(msg)


def _bare_pool(n_nodes=2, window=3):
    """A ProcessWorkerPool with coordinator state only — no processes,
    no queues — for unit-testing the dispatch/liveness bookkeeping."""
    import numpy as np

    from repro.core.workers import ProcessWorkerPool as P
    pool = P.__new__(P)
    pool.n_nodes = n_nodes
    pool.pools = None
    pool.cheap_dev = pool.exp_dev = "cpu"
    pool.reparse_nodes = list(range(n_nodes))
    pool.alpha = 0.1
    pool._alpha_of = {}
    pool._window = window
    pool.clocks = np.zeros(n_nodes)
    pool._tasks = {}
    pool._open = set()
    pool._late = set()
    pool._load = [0] * n_nodes
    pool._dead = set()
    pool._quiet = set()
    pool._stalled = set()
    pool._next_task_id = 0
    pool._reissued_tasks = [0] * n_nodes
    pool.reissued = 0
    pool.reissued_reparse = 0
    pool._shm = None                     # inline payloads
    pool.task_qs = [_FakeQ() for _ in range(n_nodes)]
    return pool


def test_recovered_straggler_window_counts_owed_late_results():
    """Regression (recovery window overcommit): a quieted worker's
    re-issued batches are still executing on it when it heartbeats
    back; the refill must count those owed late results against the
    ``1 + prefetch_depth`` window instead of refilling it in full —
    pre-fix the recovered straggler held window + owed batches."""
    from collections import deque

    pool = _bare_pool(n_nodes=2, window=3)
    pending = {1: deque({"batch_key": k, "docs": ()} for k in range(8))}
    pool._top_up(pending)
    assert pool._load[1] == 3            # window full, 5 batches queued

    # worker 1 misses its heartbeat deadline: quiet + re-issue
    pool._quiet.add(1)
    pool._reissue_from(1)
    assert pool._load[1] == 0 and pool._owed(1) == 3
    assert pool._load[0] == 3            # peers took the batches over
    assert pool.reissued == 3

    # worker 1 heartbeats back while its 3 batches still execute
    pool._quiet.discard(1)
    sent_before = len(pool.task_qs[1].sent)
    pool._top_up(pending)
    assert len(pool.task_qs[1].sent) == sent_before
    assert pool._load[1] + pool._owed(1) <= pool._window

    # one late result lands -> exactly one slot frees
    pool._late.discard(next(iter(pool._late)))
    pool._top_up(pending)
    assert len(pool.task_qs[1].sent) == sent_before + 1
    assert pool._load[1] + pool._owed(1) <= pool._window


class _FakeProc:
    """Always-alive stand-in for a spawned worker process."""

    def is_alive(self):
        return True


def test_backlogged_but_alive_worker_not_reissued_early():
    """Regression (backlog vs wedge): a worker whose last heartbeat
    reported a deep task queue is digesting a backlog — its beacons can
    sit behind bulky results in the shared queue well past the base
    deadline. The coordinator must extend that worker's effective
    deadline (one extra base timeout per reported queued task, bounded)
    instead of re-issuing its in-flight work; a worker that reported an
    *empty* queue and then went silent keeps the base deadline and is
    policed as wedged."""
    import time
    from collections import deque

    pool = _bare_pool(n_nodes=2, window=3)
    pool.xcfg = ExecutorConfig(n_nodes=2, runtime="process",
                               heartbeat_timeout_s=1.0)
    pool.procs = [_FakeProc(), _FakeProc()]
    pool._hb_task = [None, None]
    pool._hb_delay = [0.0, 0.0]
    pending = {0: deque([{"batch_key": 0, "docs": ()}]),
               1: deque([{"batch_key": 1, "docs": ()}])}
    pool._top_up(pending)
    assert pool._load == [1, 1]

    # both workers silent for 2x the base deadline; only worker 0's
    # last beacon reported queued work
    pool._beat = [time.time() - 2.0, time.time() - 2.0]
    pool._hb_depth = [3, 0]
    assert pool._deadline_for(0) == pytest.approx(4.0)   # 1 + min(3,4)
    assert pool._deadline_for(1) == pytest.approx(1.0)
    pool._police()
    assert 0 not in pool._quiet          # backlogged but alive: spared
    assert 1 in pool._quiet              # silent with an empty queue
    assert pool.reissued == 1
    assert pool._load[0] == 2            # worker 1's task moved over

    # the depth grant is bounded: a huge reported backlog cannot defer
    # policing forever
    pool._hb_depth[0] = 500
    assert pool._deadline_for(0) == pytest.approx(5.0)
    pool._beat[0] = time.time() - 6.0
    pool._police()
    assert 0 in pool._quiet              # past even the extended bound


def test_heartbeat_send_stamp_is_same_host_only():
    """Regression (cross-machine clock skew): ``Heartbeat.sent_mono``
    is a CLOCK_MONOTONIC stamp whose epoch is per-machine — boot time —
    so differencing it against the coordinator's clock is meaningless
    off-host. Liveness deadlines always run on coordinator *receive*
    time (``_beat``); the send stamp only feeds the same-host
    queue-delay diagnostic, and a pool whose workers may live on other
    machines (``_mono_comparable = False``, the fabric contract) must
    leave that diagnostic untouched however skewed the stamp."""
    import time

    from repro.core.workers import Heartbeat

    pool = _bare_pool(n_nodes=1, window=1)
    pool.procs = [_FakeProc()]
    pool._beat = [0.0]
    pool._hb_depth = [-1]
    pool._hb_task = [None]
    pool._hb_delay = [0.0]
    pool.obs_spans = []
    pool._obs_snaps = {}

    # a worker on a machine booted much later: its monotonic clock is
    # thousands of seconds behind/ahead of the coordinator's
    pool._mono_comparable = False
    for skew in (9999.0, -9999.0):
        pool._handle(Heartbeat(0, time.time(), None,
                               sent_mono=time.monotonic() + skew,
                               queue_depth=2))
        assert pool._hb_delay[0] == 0.0  # diagnostic never computed
        # liveness state still updates from coordinator receive time
        assert pool._beat[0] == pytest.approx(time.time(), abs=2.0)
        assert pool._hb_depth[0] == 2

    # the same-host spawn runtime keeps the diagnostic: a stamp from
    # the shared clock yields the real (non-negative) queue delay
    pool._mono_comparable = True
    pool._handle(Heartbeat(0, time.time(), None,
                           sent_mono=time.monotonic() - 0.5,
                           queue_depth=0))
    assert 0.4 < pool._hb_delay[0] < 5.0
    # ...and even on one host, a stamp from the future (clock step
    # between reads) clamps at zero rather than going negative
    pool._handle(Heartbeat(0, time.time(), None,
                           sent_mono=time.monotonic() + 50.0,
                           queue_depth=0))
    assert pool._hb_delay[0] == 0.0


def test_straggler_flap_recovers_without_overcommit(corpus, ft_router,
                                                    single_run):
    """End-to-end flap (mute → re-issue → heartbeats resume): the
    recovered worker is re-admitted at reduced window while it still
    owes late results, and the record set matches the single-node run
    with every doc counted exactly once."""
    ccfg, _ = corpus
    test, ecfg, single = single_run
    xcfg = ExecutorConfig(
        n_nodes=2, runtime="process", prefetch_depth=2,
        heartbeat_timeout_s=0.5, heartbeat_interval_s=0.1,
        straggler_grace_s=2.5,
        fault_injection=FaultInjection(mute_after=((1, 0),),
                                       unmute_after=((1, 2),),
                                       mute_slowdown_s=0.9))
    res = CampaignExecutor(ecfg, xcfg, ft_router, ccfg).run(test)
    _assert_same_records(single, res.records)
    assert res.reissued >= 1
    assert sum(s.n_docs for s in res.node_stats) == len(test)


def test_process_runtime_rejects_simulation_only_config(corpus,
                                                        ft_router):
    """Actionable errors before any process spawns: simulated speed
    factors and in-memory result stores are local-runtime concepts."""
    ccfg, docs = corpus
    ecfg = EngineConfig(alpha=0.1, batch_size=16)
    with pytest.raises(ValueError, match="simulation-only"):
        CampaignExecutor(
            ecfg, ExecutorConfig(n_nodes=2, runtime="process",
                                 node_speed_factors=[1.0, 4.0]),
            ft_router, ccfg).run(docs[75:])
    with pytest.raises(ValueError, match="cannot be shared across"):
        CampaignExecutor(
            ecfg, ExecutorConfig(n_nodes=2, runtime="process"),
            ft_router, ccfg).run(docs[75:], cache=ResultCache())
    with pytest.raises(ValueError, match="heartbeat_timeout_s"):
        CampaignExecutor(
            ecfg, ExecutorConfig(n_nodes=2, runtime="process",
                                 heartbeat_timeout_s=0.0),
            ft_router, ccfg).run(docs[75:])
    with pytest.raises(ValueError, match="unknown worker runtime"):
        CampaignExecutor(
            ecfg, ExecutorConfig(n_nodes=2, runtime="threads"),
            ft_router, ccfg).run(docs[75:])


def test_reissue_candidates_exclude_precedes_pool_short_circuit():
    """Dead workers are removed from the fleet *before* the same-pool
    short-circuit: with every same-pool peer dead, CPU work still
    falls through to cross-pool nodes, while GPU work (which cannot
    cross) correctly finds no peer."""
    from repro.core import scheduler

    pools = ["cpu", "cpu", "gpu"]
    # both CPU workers dead: cpu work may run on the GPU node's host
    assert scheduler.reissue_candidates(0, pools, "cpu", 3,
                                        exclude={1}) == [2]
    # without exclusion the dead same-pool peer masks the fallback
    assert scheduler.reissue_candidates(0, pools, "cpu", 3) == [1]
    # gpu work never leaves its pool, dead peers or not
    assert scheduler.reissue_candidates(2, ["cpu", "gpu", "gpu"],
                                        "gpu", 3, exclude={1}) == []
    assert scheduler.reissue_candidates(0, None, "cpu", 3,
                                        exclude={2}) == [1]


def test_local_pool_satisfies_worker_pool_protocol(corpus, ft_router):
    """Both runtimes sit behind one structural protocol; the executor
    and controller never branch on the concrete pool type."""
    ccfg, docs = corpus
    ecfg = EngineConfig(alpha=0.1, batch_size=16)
    ex = CampaignExecutor(ecfg, ExecutorConfig(n_nodes=2), ft_router,
                          ccfg)
    pool = ex._make_pool(2, [0, 1], [0, 1], None, {}, None)
    assert isinstance(pool, LocalWorkerPool)
    assert isinstance(pool, WorkerPool)
    for method in ("drain", "node_telemetry", "set_alpha", "node_stats",
                   "snapshot_cache", "finalize", "close"):
        assert callable(getattr(ProcessWorkerPool, method))
