"""Hypothesis property tests on the system's invariants.

Skipped wholesale when ``hypothesis`` is not installed; the budget_topk
invariants are additionally ported to always-run seeded parametrize tests
in ``test_routing.py`` so tier-1 keeps covering them either way.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import metrics as M
from repro.core import scheduler
from repro.data.pipeline import pack_documents, stateless_rng

SMALL = dict(max_examples=25, deadline=None)


# -- scheduler invariants -----------------------------------------------------


@given(st.integers(8, 200), st.floats(0.0, 1.0), st.integers(0, 10 ** 6))
@settings(**SMALL)
def test_budget_topk_respects_budget(k, alpha, seed):
    """Never route more than floor(alpha*k) items; all routed items have
    positive predicted improvement."""
    rng = np.random.RandomState(seed)
    scores = jnp.asarray(rng.randn(k).astype(np.float32))
    mask, idx = scheduler.budget_topk(scores, alpha)
    n_sel = int(mask.sum())
    assert n_sel <= int(alpha * k)
    if n_sel:
        assert float(scores[mask].min()) > 0


@given(st.integers(8, 200), st.floats(0.01, 1.0), st.integers(0, 10 ** 6))
@settings(**SMALL)
def test_budget_topk_takes_the_best(k, alpha, seed):
    """Every selected score >= every unselected score."""
    rng = np.random.RandomState(seed)
    scores = jnp.asarray(rng.randn(k).astype(np.float32))
    mask, _ = scheduler.budget_topk(scores, alpha)
    m = np.asarray(mask)
    if m.any() and (~m).any():
        assert float(scores[m].min()) >= float(scores[~m].max()) - 1e-6


@given(st.floats(0.0, 1.0), st.floats(0.001, 0.1), st.floats(0.2, 2.0))
@settings(**SMALL)
def test_alpha_budget_formula(alpha, t_cheap, t_exp):
    """alpha_for_budget inverts the cost model within the feasible range."""
    n = 1000
    budget = n * ((1 - alpha) * t_cheap + alpha * t_exp)
    a = scheduler.alpha_for_budget(budget, n, t_cheap, t_exp)
    assert abs(a - alpha) < 1e-6


@given(st.integers(2, 6), st.integers(10, 80), st.integers(0, 10 ** 6),
       st.floats(1.1, 10.0))
@settings(**SMALL)
def test_greedy_knapsack_respects_budget(m, n, seed, budget_scale):
    rng = np.random.RandomState(seed)
    acc = rng.rand(n, m)
    costs = np.sort(rng.rand(m) + 0.1)
    budget = n * costs[0] * budget_scale
    assign = scheduler.assign_parsers_greedy(acc, costs, budget)
    assert costs[assign].sum() <= budget + 1e-9
    # never worse than all-cheapest
    assert acc[np.arange(n), assign].sum() >= acc[:, 0].sum() - 1e-9


# -- metric invariants --------------------------------------------------------


@given(st.integers(5, 100), st.integers(0, 10 ** 6))
@settings(**SMALL)
def test_bleu_bounds_and_identity(n, seed):
    rng = np.random.RandomState(seed)
    ref = rng.randint(0, 50, n)
    hyp = rng.randint(0, 50, rng.randint(1, n + 10))
    b = M.bleu(ref, hyp)
    assert 0.0 <= b <= 1.0 + 1e-9
    assert M.bleu(ref, ref) > 0.999


@given(st.integers(5, 60), st.integers(0, 10 ** 6))
@settings(**SMALL)
def test_car_is_one_minus_normalized_edits(n, seed):
    rng = np.random.RandomState(seed)
    ref = rng.randint(10, 500, n)
    k = rng.randint(0, n // 2 + 1)
    hyp = ref.copy()
    pos = rng.choice(n, k, replace=False)
    hyp[pos] = hyp[pos] + 10000          # guaranteed mismatches
    car = M.car([ref], [hyp])
    assert abs(car - (1 - k / n)) < 1e-6


@given(st.integers(5, 60), st.integers(0, 10 ** 6))
@settings(**SMALL)
def test_rouge_symmetry_bounds(n, seed):
    rng = np.random.RandomState(seed)
    a = rng.randint(0, 30, n)
    b = rng.randint(0, 30, n)
    r = M.rouge_l([a], [b])
    assert 0.0 <= r <= 1.0 + 1e-9
    assert M.rouge_l([a], [a]) > 0.999


# -- pipeline invariants ------------------------------------------------------


@given(st.integers(0, 2 ** 20), st.integers(0, 1000), st.integers(0, 63))
@settings(**SMALL)
def test_stateless_rng_deterministic(seed, step, shard):
    a = stateless_rng(seed, step, shard).randint(0, 1 << 30, 8)
    b = stateless_rng(seed, step, shard).randint(0, 1 << 30, 8)
    np.testing.assert_array_equal(a, b)


@given(st.lists(st.integers(1, 40), min_size=1, max_size=20),
       st.integers(0, 10 ** 6))
@settings(**SMALL)
def test_packing_preserves_tokens(lengths, seed):
    rng = np.random.RandomState(seed)
    docs = [rng.randint(2, 100, ln) for ln in lengths]
    seq_len = 64
    packed = pack_documents(docs, seq_len, pad_id=0, eos_id=1)
    # every document's (truncated) tokens appear exactly once
    n_tokens = sum(min(len(d), seq_len - 1) for d in docs)
    n_eos = len(docs)
    flat = packed.ravel()
    assert (flat != 0).sum() == n_tokens + n_eos
    assert (flat == 1).sum() == n_eos


# -- DPO loss properties ------------------------------------------------------


def test_dpo_loss_at_init_is_log2():
    """With policy == reference the DPO logits are 0 -> loss = log 2."""
    from repro.common import unwrap
    from repro.configs.base import EncoderConfig
    from repro.core.dpo import dpo_loss
    from repro.models.encoder import init_encoder

    cfg = EncoderConfig(name="t", n_layers=1, d_model=16, n_heads=2,
                        d_ff=32, vocab_size=64, max_len=16,
                        param_dtype="float32", compute_dtype="float32")
    p = unwrap(init_encoder(cfg, 0))
    batch = {
        "tok_pos": jnp.ones((4, 8), jnp.int32),
        "mask_pos": jnp.ones((4, 8)),
        "tok_neg": jnp.ones((4, 8), jnp.int32) * 2,
        "mask_neg": jnp.ones((4, 8)),
    }
    loss = dpo_loss(p, p, cfg, batch)
    np.testing.assert_allclose(float(loss), np.log(2.0), rtol=1e-5)


# -- sharding rules -----------------------------------------------------------


@given(st.integers(1, 4), st.integers(1, 4))
@settings(max_examples=10, deadline=None)
def test_spec_never_reuses_mesh_axis(a, b):
    import jax as _jax
    from repro.distributed.meshrules import AxisRules
    from repro.launch.mesh import make_mesh
    if a * b > len(_jax.devices()):
        return
    mesh = make_mesh((a, b), ("data", "model"))
    rules = AxisRules(mesh)
    spec = rules.spec_for(("batch", "seq", "heads", "d_ff"),
                          (a * 8, 128, b * 4, b * 2))
    used = [x for e in spec if e is not None
            for x in ((e,) if isinstance(e, str) else e)]
    assert len(used) == len(set(used))
