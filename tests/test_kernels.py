"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.budget_route.kernel import budget_route_kernel
from repro.kernels.budget_route.ref import budget_route_ref
from repro.kernels.embedding_bag.kernel import embedding_bag_kernel
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.segment_mm.kernel import segment_matmul_kernel
from repro.kernels.segment_mm.ref import segment_matmul_ref


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("b,sq,skv,h,hk,d", [
    (2, 64, 64, 4, 2, 16),
    (1, 48, 80, 4, 4, 32),
    (2, 96, 96, 8, 1, 8),       # MQA
    (1, 100, 100, 2, 2, 64),    # padding path
])
@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 24)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, sq, skv, h, hk, d, causal, window, dtype):
    q = jax.random.normal(jax.random.key(1), (b, sq, h, d), dtype)
    k = jax.random.normal(jax.random.key(2), (b, skv, hk, d), dtype)
    v = jax.random.normal(jax.random.key(3), (b, skv, hk, d), dtype)
    got = flash_attention_kernel(q, k, v, causal=causal, window=window,
                                 block_q=32, block_k=32, interpret=True)
    want = flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("n,d,cap,block", [
    (128, 16, 8, 32), (100, 8, 13, 32), (256, 32, 64, 64), (64, 4, 64, 16),
])
def test_budget_route_sweep(n, d, cap, block):
    scores = jax.random.normal(jax.random.key(1), (n,))
    tokens = jax.random.normal(jax.random.key(2), (n, d))
    tau = jax.lax.top_k(scores, min(cap, n))[0][-1]
    o1, i1, c1 = budget_route_kernel(scores, tokens, tau, capacity=cap,
                                     block_n=block, interpret=True)
    o2, i2, c2 = budget_route_ref(scores, tokens, tau, capacity=cap)
    assert int(c1) == int(c2)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2))


@pytest.mark.slow
def test_budget_route_interpret_at_route_64k_shape():
    """The fused selection op at the `route_64k` production serve shape
    (65536 docs x 512 tokens, alpha = 0.05), kernel in interpret mode vs
    the jnp ref AND the host mirror — keeps the kernel path honest at
    the real shape until real-TPU runs land (ROADMAP open item). Scores
    are heavily quantized so the tie budget carries across many grid
    blocks."""
    from repro.configs import get_config
    from repro.core import scheduler

    shape = next(s for s in get_config("adaparse-router").shapes
                 if s.name == "route_64k")
    n, d = shape.dims["global_batch"], shape.dims["seq_len"]
    alpha = 0.05
    cap = int(alpha * n)
    rng = np.random.RandomState(0)
    scores = (rng.randint(0, 50, n) / 10.0).astype(np.float32)
    tokens = rng.randn(n, d).astype(np.float32)
    tau = float(np.sort(scores)[-cap])
    o1, i1, c1 = budget_route_kernel(jnp.asarray(scores),
                                     jnp.asarray(tokens), tau,
                                     capacity=cap, block_n=1024,
                                     interpret=True)
    o2, i2, c2 = budget_route_ref(jnp.asarray(scores), jnp.asarray(tokens),
                                  tau, capacity=cap)
    assert int(c1) == int(c2) == cap
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2))
    # host mirror picks the same document set at the same shape
    host = scheduler.plan_batch(scores, alpha)
    idx = np.asarray(i1)
    np.testing.assert_array_equal(np.sort(idx[idx >= 0]),
                                  host.expensive_idx)


def test_budget_route_selects_topk():
    """Selected rows are exactly the alpha-fraction highest scores."""
    n, cap = 200, 20
    scores = jax.random.normal(jax.random.key(5), (n,))
    tokens = jnp.arange(n, dtype=jnp.float32)[:, None]
    tau = jax.lax.top_k(scores, cap)[0][-1]
    _, idx, count = budget_route_kernel(scores, tokens, tau, capacity=cap,
                                        interpret=True)
    top = set(np.asarray(jax.lax.top_k(scores, cap)[1]).tolist())
    assert int(count) == cap
    assert set(np.asarray(idx).tolist()) == top


@pytest.mark.parametrize("e,n,din,dout", [
    (100, 20, 16, 8), (256, 64, 8, 8), (73, 10, 32, 16),
])
def test_segment_mm_sweep(e, n, din, dout):
    x = jax.random.normal(jax.random.key(0), (n, din))
    src = jax.random.randint(jax.random.key(1), (e,), 0, n)
    dst = jax.random.randint(jax.random.key(2), (e,), 0, n)
    w = jax.random.normal(jax.random.key(3), (din, dout))
    order = jnp.argsort(dst, stable=True)
    xg = jnp.take(x, src[order], axis=0)
    got = segment_matmul_kernel(xg, w, dst[order], n_nodes=n, block_e=64,
                                interpret=True)
    want = segment_matmul_ref(xg, w, dst[order], n_nodes=n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("r,d,b,bag,comb", [
    (500, 16, 32, 8, "sum"), (1000, 8, 50, 5, "mean"), (64, 4, 7, 3, "sum"),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_embedding_bag_sweep(r, d, b, bag, comb, dtype):
    table = jax.random.normal(jax.random.key(0), (r, d), dtype)
    ids = jax.random.randint(jax.random.key(1), (b, bag), 0, r)
    w = jax.random.uniform(jax.random.key(2), (b, bag))
    got = embedding_bag_kernel(table, ids, w, combiner=comb, interpret=True)
    want = embedding_bag_ref(table, ids, w, combiner=comb)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])
