"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.budget_route import autotune as rt_autotune
from repro.kernels.budget_route.kernel import budget_route_kernel
from repro.kernels.budget_route.ops import budget_route, capacity_floor
from repro.kernels.budget_route.ref import budget_route_ref
from repro.kernels.ngram_score.kernel import ngram_bleu_kernel
from repro.kernels.ngram_score.ref import ngram_bleu_ref
from repro.kernels.embedding_bag.kernel import embedding_bag_kernel
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.segment_mm.kernel import segment_matmul_kernel
from repro.kernels.segment_mm.ref import segment_matmul_ref


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("b,sq,skv,h,hk,d", [
    (2, 64, 64, 4, 2, 16),
    (1, 48, 80, 4, 4, 32),
    (2, 96, 96, 8, 1, 8),       # MQA
    (1, 100, 100, 2, 2, 64),    # padding path
])
@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 24)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, sq, skv, h, hk, d, causal, window, dtype):
    q = jax.random.normal(jax.random.key(1), (b, sq, h, d), dtype)
    k = jax.random.normal(jax.random.key(2), (b, skv, hk, d), dtype)
    v = jax.random.normal(jax.random.key(3), (b, skv, hk, d), dtype)
    got = flash_attention_kernel(q, k, v, causal=causal, window=window,
                                 block_q=32, block_k=32, interpret=True)
    want = flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("n,d,cap,block", [
    (128, 16, 8, 32), (100, 8, 13, 32), (256, 32, 64, 64), (64, 4, 64, 16),
])
def test_budget_route_sweep(n, d, cap, block):
    scores = jax.random.normal(jax.random.key(1), (n,))
    tokens = jax.random.normal(jax.random.key(2), (n, d))
    tau = jax.lax.top_k(scores, min(cap, n))[0][-1]
    o1, i1, c1 = budget_route_kernel(scores, tokens, tau, capacity=cap,
                                     block_n=block, interpret=True)
    o2, i2, c2 = budget_route_ref(scores, tokens, tau, capacity=cap)
    assert int(c1) == int(c2)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2))


@pytest.mark.slow
def test_budget_route_interpret_at_route_64k_shape():
    """The fused selection op at the `route_64k` production serve shape
    (65536 docs x 512 tokens, alpha = 0.05), kernel in interpret mode vs
    the jnp ref AND the host mirror — keeps the kernel path honest at
    the real shape until real-TPU runs land (ROADMAP open item). Scores
    are heavily quantized so the tie budget carries across many grid
    blocks."""
    from repro.configs import get_config
    from repro.core import scheduler

    shape = next(s for s in get_config("adaparse-router").shapes
                 if s.name == "route_64k")
    n, d = shape.dims["global_batch"], shape.dims["seq_len"]
    alpha = 0.05
    cap = int(alpha * n)
    rng = np.random.RandomState(0)
    scores = (rng.randint(0, 50, n) / 10.0).astype(np.float32)
    tokens = rng.randn(n, d).astype(np.float32)
    tau = float(np.sort(scores)[-cap])
    o1, i1, c1 = budget_route_kernel(jnp.asarray(scores),
                                     jnp.asarray(tokens), tau,
                                     capacity=cap, block_n=1024,
                                     interpret=True)
    o2, i2, c2 = budget_route_ref(jnp.asarray(scores), jnp.asarray(tokens),
                                  tau, capacity=cap)
    assert int(c1) == int(c2) == cap
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2))
    # host mirror picks the same document set at the same shape
    host = scheduler.plan_batch(scores, alpha)
    idx = np.asarray(i1)
    np.testing.assert_array_equal(np.sort(idx[idx >= 0]),
                                  host.expensive_idx)


def test_budget_route_selects_topk():
    """Selected rows are exactly the alpha-fraction highest scores."""
    n, cap = 200, 20
    scores = jax.random.normal(jax.random.key(5), (n,))
    tokens = jnp.arange(n, dtype=jnp.float32)[:, None]
    tau = jax.lax.top_k(scores, cap)[0][-1]
    _, idx, count = budget_route_kernel(scores, tokens, tau, capacity=cap,
                                        interpret=True)
    top = set(np.asarray(jax.lax.top_k(scores, cap)[1]).tolist())
    assert int(count) == cap
    assert set(np.asarray(idx).tolist()) == top


# ---------------------------------------------------------------------------
# ngram_score: fused BLEU kernel vs the numpy oracle vs the host scorer
# ---------------------------------------------------------------------------


def _ngram_batch(b, max_len, lens_r, lens_h, vocab=12, seed=0):
    """Padded (B, max_len) batches whose pad region is GARBAGE (not
    zeros) — parity then proves the length masks, not lucky padding."""
    rng = np.random.RandomState(seed)
    ref = rng.randint(1, vocab, (b, max_len)).astype(np.int32)
    hyp = rng.randint(1, vocab, (b, max_len)).astype(np.int32)
    lr = np.asarray(lens_r, np.int32)
    lh = np.asarray(lens_h, np.int32)
    return ref, hyp, lr, lh


def _kernel_vs_ref(ref, hyp, lr, lh, max_n=4):
    got = ngram_bleu_kernel(jnp.asarray(ref), jnp.asarray(hyp),
                            jnp.asarray(lr), jnp.asarray(lh),
                            max_len=ref.shape[1], max_n=max_n,
                            interpret=True)
    want = ngram_bleu_ref(ref, hyp, lr, lh, max_n=max_n)
    np.testing.assert_allclose(np.asarray(got, np.float64), want,
                               atol=1e-6, rtol=1e-5)


@pytest.mark.parametrize("b,max_len,vocab", [
    (4, 32, 6),          # tiny vocab -> heavy n-gram repetition (clipping)
    (6, 48, 30),
    (3, 64, 4),          # near-degenerate alphabet
])
def test_ngram_bleu_kernel_vs_ref_sweep(b, max_len, vocab):
    rng = np.random.RandomState(b * 7 + max_len)
    lr = rng.randint(1, max_len + 1, b)
    lh = rng.randint(1, max_len + 1, b)
    ref, hyp, lr, lh = _ngram_batch(b, max_len, lr, lh, vocab=vocab,
                                    seed=max_len)
    _kernel_vs_ref(ref, hyp, lr, lh)


def test_ngram_bleu_kernel_edge_cases():
    """Empty hypotheses, empty references, full-length rows, and rows
    shorter than the n-gram order all agree with the oracle; the empty
    hypothesis scores exactly 0."""
    max_len = 24
    lens_r = [0, 10, max_len, 2, 1, max_len]
    lens_h = [5, 0, max_len, 3, 1, 1]
    ref, hyp, lr, lh = _ngram_batch(6, max_len, lens_r, lens_h, vocab=5)
    _kernel_vs_ref(ref, hyp, lr, lh)
    got = np.asarray(ngram_bleu_kernel(
        jnp.asarray(ref), jnp.asarray(hyp), jnp.asarray(lr),
        jnp.asarray(lh), max_len=max_len, interpret=True))
    assert got[1] == 0.0                 # empty hypothesis


def test_ngram_bleu_padding_is_ignored():
    """Two batches identical inside the lengths but with different
    garbage padding must score identically."""
    lens_r, lens_h = [7, 12], [9, 4]
    ref, hyp, lr, lh = _ngram_batch(2, 16, lens_r, lens_h, seed=1)
    ref2, hyp2 = ref.copy(), hyp.copy()
    rng = np.random.RandomState(99)
    for i in range(2):
        ref2[i, lr[i]:] = rng.randint(1000, 2000, 16 - lr[i])
        hyp2[i, lh[i]:] = rng.randint(1000, 2000, 16 - lh[i])
    a = np.asarray(ngram_bleu_kernel(jnp.asarray(ref), jnp.asarray(hyp),
                                     jnp.asarray(lr), jnp.asarray(lh),
                                     max_len=16, interpret=True))
    b = np.asarray(ngram_bleu_kernel(jnp.asarray(ref2), jnp.asarray(hyp2),
                                     jnp.asarray(lr), jnp.asarray(lh),
                                     max_len=16, interpret=True))
    np.testing.assert_array_equal(a, b)


def test_ngram_bleu_matches_host_scorer():
    """Kernel and oracle both reproduce the scalar host rule
    (metrics.bleu) on unpadded streams — the end-to-end quality-probe
    contract."""
    from repro.core import metrics as M

    rng = np.random.RandomState(3)
    max_len = 40
    refs = [rng.randint(1, 9, rng.randint(1, max_len + 1)).astype(np.int32)
            for _ in range(5)]
    hyps = [rng.randint(1, 9, rng.randint(0, max_len + 1)).astype(np.int32)
            for _ in range(5)]
    ref = np.zeros((5, max_len), np.int32)
    hyp = np.zeros((5, max_len), np.int32)
    for i, (r, h) in enumerate(zip(refs, hyps)):
        ref[i, :len(r)] = r
        hyp[i, :len(h)] = h
    lr = np.asarray([len(r) for r in refs], np.int32)
    lh = np.asarray([len(h) for h in hyps], np.int32)
    want = np.asarray([M.bleu(r, h) for r, h in zip(refs, hyps)])
    np.testing.assert_allclose(ngram_bleu_ref(ref, hyp, lr, lh), want,
                               atol=1e-12)
    got = ngram_bleu_kernel(jnp.asarray(ref), jnp.asarray(hyp),
                            jnp.asarray(lr), jnp.asarray(lh),
                            max_len=max_len, interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float64), want,
                               atol=1e-6, rtol=1e-5)


# ---------------------------------------------------------------------------
# budget_route block-size autotune
# ---------------------------------------------------------------------------


def test_autotune_caches_winner_and_budget_route_consults_it():
    rt_autotune.clear_cache()
    try:
        n, d, cap = 256, 8, 16
        rec = rt_autotune.autotune_budget_route(
            n, d, cap, candidates=(32, 64, 128), repeats=1)
        assert rec.value in (32, 64, 128)
        assert len(rec.timings_s) == 3
        assert rt_autotune.tuned_block_n(n, d, cap) == rec.value
        # untuned shape falls back to the default
        assert (rt_autotune.tuned_block_n(n + 1, d, cap)
                == rt_autotune.DEFAULT_BLOCK_N)
        # budget_route with block_n=None (the tuned path) still selects
        # the exact same documents as the jnp reference
        rng = np.random.RandomState(0)
        scores = jnp.asarray(rng.rand(n).astype(np.float32))
        tokens = jnp.asarray(rng.randn(n, d).astype(np.float32))
        alpha = cap / n
        o1, i1, c1 = budget_route(scores, tokens, alpha, force_kernel=True)
        kth = jax.lax.top_k(scores, cap)[0][-1]
        o2, i2, c2 = budget_route_ref(scores, tokens, kth, capacity=cap)
        assert int(c1) == int(c2)
        np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2))
    finally:
        rt_autotune.clear_cache()


def test_autotune_device_sweep_refuses_off_tpu():
    if jax.default_backend() == "tpu":
        pytest.skip("device sweep is legal on a real TPU")
    with pytest.raises(RuntimeError, match="TPU backend"):
        rt_autotune.autotune_budget_route(64, 4, 4, device=True)


def test_autotune_key_separates_interpret_from_device():
    """Regression for the PR-7 cache key omitting the device flag: an
    interpret-mode winner must never answer a device-mode lookup (on a
    TPU host that would poison compiled dispatch with interpret
    timings), and each mode resolves independently."""
    rt_autotune.clear_cache()
    try:
        n, d, cap = 128, 8, 16
        rec = rt_autotune.autotune_budget_route(
            n, d, cap, candidates=(32, 64), repeats=1)
        assert rec.device is False
        # the interpret winner serves interpret-mode lookups only
        assert rt_autotune.tuned_block_n(n, d, cap, device=False) \
            == rec.value
        assert rt_autotune.tuned_block_n(n, d, cap, device=True) \
            == rt_autotune.DEFAULT_BLOCK_N
        # the store key separates the modes too
        from repro.kernels import autotune_common
        k_int = autotune_common.store_key("budget_route", (n, d, cap),
                                          "cpu", False)
        k_dev = autotune_common.store_key("budget_route", (n, d, cap),
                                          "cpu", True)
        assert k_int != k_dev
    finally:
        rt_autotune.clear_cache()


@pytest.mark.slow
def test_autotune_full_grid_at_route_64k():
    """The full candidate grid at the production route_64k shape in
    interpret mode — every BlockSpec configuration must produce a
    winner and a complete timing table."""
    rt_autotune.clear_cache()
    try:
        n, d = rt_autotune.ROUTE_64K
        cap = max(capacity_floor(0.05, n), 1)
        rec = rt_autotune.autotune_budget_route(
            n, d, cap, candidates=rt_autotune.DEFAULT_CANDIDATES,
            repeats=1)
        grid = sorted({min(c, n) for c in rt_autotune.DEFAULT_CANDIDATES})
        assert [b for b, _ in rec.timings_s] == grid
        assert rec.value in grid
        assert all(t > 0 for _, t in rec.timings_s)
        assert rt_autotune.tuned_block_n(n, d, cap) == rec.value
    finally:
        rt_autotune.clear_cache()


# ---------------------------------------------------------------------------
# ngram_score block_b (docs-per-program) blocking
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("block_b", [1, 2, 4, 8, 32])
def test_ngram_bleu_block_b_parity(block_b):
    """Every docs-per-program blocking (including one larger than the
    batch, and batch sizes that don't divide the block) scores exactly
    like the unblocked kernel and the oracle."""
    b, max_len = 13, 24
    rng = np.random.RandomState(7)
    lr = rng.randint(0, max_len + 1, b)
    lh = rng.randint(0, max_len + 1, b)
    ref, hyp, lr, lh = _ngram_batch(b, max_len, lr, lh, vocab=6, seed=2)
    got = ngram_bleu_kernel(jnp.asarray(ref), jnp.asarray(hyp),
                            jnp.asarray(lr), jnp.asarray(lh),
                            max_len=max_len, interpret=True,
                            block_b=block_b)
    want = ngram_bleu_ref(ref, hyp, lr, lh)
    np.testing.assert_allclose(np.asarray(got, np.float64), want,
                               atol=1e-6, rtol=1e-5)


def test_ngram_autotune_sweep_and_dispatch():
    """The ngram block_b sweep runs on the shared harness; the public
    op consults the winner and still matches the oracle."""
    from repro.kernels.ngram_score import autotune as ng_autotune
    from repro.kernels.ngram_score.ops import ngram_bleu

    ng_autotune.clear_cache()
    try:
        b, max_len = 9, 16
        rec = ng_autotune.autotune_ngram_bleu(
            b, max_len, candidates=(1, 2, 4), repeats=1)
        assert rec.value in (1, 2, 4)
        assert rec.param == "block_b"
        assert ng_autotune.tuned_block_b(b, max_len) == rec.value
        assert (ng_autotune.tuned_block_b(b + 1, max_len)
                == ng_autotune.DEFAULT_BLOCK_B)
        ref, hyp, lr, lh = _ngram_batch(b, max_len, [5] * b, [7] * b,
                                        vocab=5, seed=3)
        got = ngram_bleu(ref, hyp, lr, lh, force_kernel=True)
        np.testing.assert_allclose(got, ngram_bleu_ref(ref, hyp, lr, lh),
                                   atol=1e-6, rtol=1e-5)
    finally:
        ng_autotune.clear_cache()


# ---------------------------------------------------------------------------
# fast_features: fused prepare-stage kernel vs oracle vs legacy pipeline
# ---------------------------------------------------------------------------


def _page_batch(n, seed, vocab=10000, max_pg_tok=200):
    """Parser-output batches covering the CLS-I edge cases: docs with no
    pages, docs whose pages are all empty, max-length single-page docs,
    and high token ids (the non-ASCII analogue: latex/ident/garbage
    ranges near the top of the vocab)."""
    r = np.random.RandomState(seed)
    out = []
    for i in range(n):
        kind = r.randint(0, 7)
        if kind == 0:
            out.append([])                           # no output at all
        elif kind == 1:
            out.append([np.zeros(0, np.int32)
                        for _ in range(r.randint(1, 4))])   # empty pages
        elif kind == 2:
            out.append([r.randint(vocab - 300, vocab,
                                  max_pg_tok).astype(np.int32)])
        else:
            out.append([r.randint(0, vocab,
                                  r.randint(0, max_pg_tok)).astype(np.int32)
                        for _ in range(r.randint(1, 6))])
    return out


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("max_len", [0, 32])
def test_fast_features_ref_matches_legacy_bitwise(seed, max_len):
    """The packed-stream host oracle reproduces the legacy per-function
    pipeline bit-for-bit (it is the CPU dispatch path, so records must
    not move)."""
    from repro.core import features as F
    from repro.data.synthetic import CorpusConfig
    from repro.kernels.fast_features.ops import (pack_routing_batch,
                                                 routing_features)

    cfg = CorpusConfig()
    pls = _page_batch(50, seed, vocab=cfg.vocab_size)
    packed = pack_routing_batch(pls, max_len=max_len)
    fast, toks, mask = routing_features(
        packed, ws=2, scramble=3, mangled=4, latex_lo=cfg.latex_lo,
        ident_lo=cfg.ident_lo, vocab_size=cfg.vocab_size)
    np.testing.assert_array_equal(fast, F.batch_fast_features(pls, cfg))
    if max_len:
        lt, lm = F.batch_first_page_tokens(pls, max_len)
        np.testing.assert_array_equal(toks, lt)
        np.testing.assert_array_equal(mask, lm)
    else:
        assert toks is None and mask is None


@pytest.mark.parametrize("seed,max_len,block_l", [
    (0, 32, 128), (1, 32, 256), (2, 0, 128), (3, 64, 512),
])
def test_fast_features_kernel_vs_ref(seed, max_len, block_l):
    """Pallas kernel (interpret) vs the host oracle to 1e-6 across the
    edge-case corpus: empty docs, empty pages, max-length streams, high
    token ids, every block_l candidate."""
    from repro.data.synthetic import CorpusConfig
    from repro.kernels.fast_features.kernel import fast_features_kernel
    from repro.kernels.fast_features.ops import pack_routing_batch
    from repro.kernels.fast_features.ref import routing_features_ref

    cfg = CorpusConfig()
    pls = _page_batch(40, seed, vocab=cfg.vocab_size)
    packed = pack_routing_batch(pls, max_len=max_len)
    kw = dict(ws=2, scramble=3, mangled=4, latex_lo=cfg.latex_lo,
              ident_lo=cfg.ident_lo)
    want, wt, wm = routing_features_ref(
        packed.flat, packed.rows, packed.starts, packed.n_tok,
        packed.first_len, packed.n_pages, packed.n_empty,
        vocab_size=cfg.vocab_size, max_len=max_len, **kw)
    got, gt, gm = fast_features_kernel(
        jnp.asarray(packed.tok_matrix), jnp.asarray(packed.n_tok),
        jnp.asarray(packed.first_len), jnp.asarray(packed.n_pages),
        jnp.asarray(packed.n_empty), max_len=max_len,
        block_l=min(block_l, packed.width), interpret=True, **kw)
    np.testing.assert_allclose(np.asarray(got, np.float64), want,
                               atol=1e-6, rtol=1e-5)
    if max_len:
        np.testing.assert_array_equal(np.asarray(gt), wt)
        np.testing.assert_array_equal(np.asarray(gm), wm)


def test_fast_features_engine_force_mode_matches_host():
    """prepare_batch in feature_kernel='force' (interpret kernel)
    produces routing inputs matching the host path: tokens/mask exact,
    features to 1e-6."""
    from repro.core import features as F
    from repro.data.synthetic import CorpusConfig

    cfg = CorpusConfig()
    pls = _page_batch(30, 5, vocab=cfg.vocab_size)
    hf, ht, hm = F.prepare_routing_inputs(pls, cfg, max_len=24,
                                          mode="host")
    kf, kt, km = F.prepare_routing_inputs(pls, cfg, max_len=24,
                                          mode="force")
    np.testing.assert_allclose(np.asarray(kf, np.float64), hf, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(kt), ht)
    np.testing.assert_array_equal(np.asarray(km), hm)
    with pytest.raises(ValueError, match="feature_kernel"):
        F.prepare_routing_inputs(pls, cfg, mode="gpu")


@pytest.mark.parametrize("e,n,din,dout", [
    (100, 20, 16, 8), (256, 64, 8, 8), (73, 10, 32, 16),
])
def test_segment_mm_sweep(e, n, din, dout):
    x = jax.random.normal(jax.random.key(0), (n, din))
    src = jax.random.randint(jax.random.key(1), (e,), 0, n)
    dst = jax.random.randint(jax.random.key(2), (e,), 0, n)
    w = jax.random.normal(jax.random.key(3), (din, dout))
    order = jnp.argsort(dst, stable=True)
    xg = jnp.take(x, src[order], axis=0)
    got = segment_matmul_kernel(xg, w, dst[order], n_nodes=n, block_e=64,
                                interpret=True)
    want = segment_matmul_ref(xg, w, dst[order], n_nodes=n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("r,d,b,bag,comb", [
    (500, 16, 32, 8, "sum"), (1000, 8, 50, 5, "mean"), (64, 4, 7, 3, "sum"),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_embedding_bag_sweep(r, d, b, bag, comb, dtype):
    table = jax.random.normal(jax.random.key(0), (r, d), dtype)
    ids = jax.random.randint(jax.random.key(1), (b, bag), 0, r)
    w = jax.random.uniform(jax.random.key(2), (b, bag))
    got = embedding_bag_kernel(table, ids, w, combiner=comb, interpret=True)
    want = embedding_bag_ref(table, ids, w, combiner=comb)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])
