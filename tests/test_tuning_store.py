"""The fleet-shared persistent kernel tuning store
(kernels/tuning_store + kernels/autotune_common): the WAL/flock
protocol under concurrent handles (mirroring the DiskResultStore
suite in test_backends), torn-tail recovery, stale-snapshot folds,
the sweep-once-then-read contract of ``ensure_tuned``, cross-process
sweep/read sharing over one --tuning-dir, and the warm-restart
acceptance bar — a 2-worker process fleet restarted over a warm
tuning dir performs **zero** autotune re-sweeps while its records
stay byte-identical to the single-node engine."""
import json
import os
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.core.campaign import CampaignExecutor, ExecutorConfig
from repro.core.engine import AdaParseEngine, EngineConfig
from repro.kernels import autotune_common as AC
from repro.kernels import tuning_store as TS


@pytest.fixture(autouse=True)
def _isolated_tuning_state():
    """Each test starts and ends with no global store and a cold
    in-memory winner cache (the module-level state is process-wide)."""
    AC.clear_cache()
    TS.reset()
    yield
    AC.clear_cache()
    TS.reset()


def _rec(value=128, kernel="fast_features", shape=(256, 0)):
    return AC._record_to_dict(AC.TuneRecord(
        kernel=kernel, shape=tuple(shape), backend="cpu", device=False,
        param="block_l", value=value,
        timings_s=((128, 0.002), (256, 0.001))))


def test_store_roundtrip_persists_across_handles(tmp_path):
    """put/get roundtrip, hit/miss counters, WAL-only recovery before
    any compaction, and snapshot recovery after flush()."""
    d = tmp_path / "t"
    st = TS.TuningStore(d)
    st.put("k1", _rec(value=256))
    assert st.get("k1")["value"] == 256
    assert st.get("nope") is None
    assert st.hits == 1 and st.misses == 1 and st.hit_rate == 0.5
    # a second handle recovers from the WAL alone (no snapshot yet)
    fresh = TS.TuningStore(d)
    assert fresh.get("k1")["value"] == 256
    st.flush()                          # compacts into the snapshot
    assert (d / TS.TuningStore.WAL_NAME).read_bytes() == b""
    again = TS.TuningStore(d)
    assert again.get("k1")["value"] == 256
    assert len(again) == 1


def test_compaction_folds_other_handles_wal_tail(tmp_path):
    """Two handles over one dir (the worker fleet's shape): a stale
    reader refolds a concurrent writer's appends on get(), and
    compaction in one handle folds the *other's* WAL tail into the
    snapshot instead of truncating it away."""
    d = tmp_path / "t"
    a, b = TS.TuningStore(d), TS.TuningStore(d)
    a.put("ka", _rec(value=128))
    # b's view predates a's publish: get() detects the stale disk
    # signature and refolds — "one process sweeps, another reads"
    assert b.get("ka")["value"] == 128
    b.put("kb", _rec(value=512))
    a.flush()                           # must keep b's entry
    assert (d / TS.TuningStore.WAL_NAME).read_bytes() == b""
    assert a.get("kb")["value"] == 512  # compaction adopted the merge
    fresh = TS.TuningStore(d)
    assert fresh.keys() == ("ka", "kb")


def test_torn_wal_tail_is_skipped(tmp_path):
    """A crash mid-append leaves a torn final WAL line; recovery keeps
    every complete record before it and drops the tail — and the next
    compaction discards it for good."""
    d = tmp_path / "t"
    st = TS.TuningStore(d)
    st.put("k0", _rec(value=128))
    st.put("k1", _rec(value=256))
    with open(d / TS.TuningStore.WAL_NAME, "a") as f:
        f.write('{"k": "k2", "v": {"trunca')      # torn append
    fresh = TS.TuningStore(d)
    assert len(fresh) == 2
    assert fresh.get("k1")["value"] == 256
    assert fresh.get("k2") is None
    fresh.flush()
    assert TS.TuningStore(d).keys() == ("k0", "k1")


def test_concurrent_handles_interleave_safely(tmp_path):
    """Concurrent puts + periodic compactions from three independent
    handles: every handle's records survive (each append is one
    O_APPEND line under the shared flock; compaction folds from disk
    under the exclusive flock)."""
    d = tmp_path / "t"
    stores = [TS.TuningStore(d) for _ in range(3)]
    errs = []

    def work(st, base):
        try:
            for i in range(30):
                st.put(f"k{base + i}", _rec(value=128 + i))
                if i % 10 == 9:
                    st.flush()          # interleaved compactions
        except Exception as e:          # surfaces in the main thread
            errs.append(e)

    threads = [threading.Thread(target=work, args=(st, 100 * j))
               for j, st in enumerate(stores)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    fresh = TS.TuningStore(d)
    assert len(fresh) == 90
    for j in range(3):
        for i in range(30):
            assert fresh.get(f"k{100 * j + i}") is not None


def test_ensure_tuned_sweeps_once_then_reads_store(tmp_path):
    """The dispatch-time contract: first call sweeps and publishes;
    after a simulated restart (in-memory cache wiped, store kept) the
    winner is a pure read — zero sweeps, zero kernel runs. Without a
    configured store the hot path never pays a surprise sweep."""
    TS.configure(str(tmp_path / "t"))
    calls = []

    def make_run(cand):
        def run():
            calls.append(cand)
            if cand != 256:             # make 256 the reliable winner
                time.sleep(0.005)
        return run

    v = AC.ensure_tuned("toy", (64,), "block", (128, 256), make_run, 999)
    assert v == 256 and AC.sweeps_run() == 1 and calls
    AC.clear_cache()                    # "restart": drop memory layer
    calls.clear()
    v2 = AC.ensure_tuned("toy", (64,), "block", (128, 256), make_run, 999)
    assert v2 == 256 and AC.sweeps_run() == 0 and calls == []
    TS.reset()                          # no store: default, no sweep
    AC.clear_cache()
    v3 = AC.ensure_tuned("toy", (64,), "block", (128, 256), make_run, 999)
    assert v3 == 999 and AC.sweeps_run() == 0 and calls == []


_CHILD = """
import json, sys
from repro.kernels import autotune_common as AC
from repro.kernels import tuning_store as TS
from repro.kernels.fast_features import autotune as FFA
TS.configure(sys.argv[1])
v = FFA.ensure_tuned(256, 0, device=False)
TS.get_store().flush()
print(json.dumps({"value": int(v), "sweeps": AC.sweeps_run(),
                  "keys": list(TS.get_store().keys())}))
"""


def _run_child(tdir):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    out = subprocess.run([sys.executable, "-c", _CHILD, str(tdir)],
                         capture_output=True, text=True, env=env,
                         timeout=240)
    assert out.returncode == 0, out.stderr
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_two_processes_share_one_tuning_dir(tmp_path):
    """Real OS processes over one --tuning-dir: the first sweeps the
    fast_features grid and publishes; the second resolves the same
    shape as a pure store read — zero sweeps, same winner."""
    d = tmp_path / "t"
    first = _run_child(d)
    assert first["sweeps"] == 1
    assert any(k.startswith("v1|fast_features|256x0|") for k in first["keys"])
    second = _run_child(d)
    assert second["sweeps"] == 0
    assert second["value"] == first["value"]
    assert second["keys"] == first["keys"]


def _assert_same_records(a: dict, b: dict):
    assert set(a) == set(b)
    for i in a:
        assert a[i].parser == b[i].parser
        assert a[i].cost_s == b[i].cost_s
        assert len(a[i].pages) == len(b[i].pages)
        for pa, pb in zip(a[i].pages, b[i].pages):
            np.testing.assert_array_equal(pa, pb)


def test_worker_fleet_warm_restart_zero_resweeps(corpus, ft_router,
                                                 tmp_path):
    """The acceptance bar: a 2-worker process fleet over a shared
    --tuning-dir sweeps the fast_features block sizes once (cold),
    produces the single-node record set byte-for-byte, and a full
    fleet restart over the warm dir performs zero re-sweeps — the
    store files do not change by a single byte — with records still
    byte-identical."""
    ccfg, docs = corpus
    test = docs[110:]
    ecfg = EngineConfig(alpha=0.1, batch_size=16, feature_kernel="force")
    single = AdaParseEngine(ecfg, ft_router, ccfg).run(test)
    tdir = tmp_path / "tuning"
    xcfg = ExecutorConfig(n_nodes=2, runtime="process",
                          tuning_dir=str(tdir))
    cold = CampaignExecutor(ecfg, xcfg, ft_router, ccfg).run(test)
    _assert_same_records(single, cold.records)
    # the workers really swept: winners landed in the shared store
    keys = TS.TuningStore(str(tdir)).keys()
    assert any(k.startswith("v1|fast_features|") for k in keys)
    snap = tdir / TS.TuningStore.SNAP_NAME
    wal = tdir / TS.TuningStore.WAL_NAME
    state = (snap.read_bytes() if snap.exists() else b"",
             wal.read_bytes())
    warm = CampaignExecutor(ecfg, xcfg, ft_router, ccfg).run(test)
    _assert_same_records(single, warm.records)
    state_after = (snap.read_bytes() if snap.exists() else b"",
                   wal.read_bytes())
    assert state_after == state
