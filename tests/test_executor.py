"""CampaignExecutor + CampaignController: multi-node record parity with
the single-node engine (homogeneous, pooled, prefetched, cached,
adaptive, and all combined), pool-aware straggler re-issue of real
batches, α-budget partitioning, speed-weighted sharding, the adaptive
round loop (EWMA-autotuned weights, telemetry trace replay, restart
determinism through the disk store), and the batched channel/feature
paths the executor's engines run on."""
import numpy as np
import pytest

from repro.core import backends as B
from repro.core import features as F
from repro.core import parsers as P
from repro.core.backends import DiskResultStore, ResultCache
from repro.core.campaign import (CampaignController, CampaignExecutor,
                                 ControllerConfig, ExecutorConfig,
                                 autotune_convergence_rounds,
                                 document_shard_source,
                                 weighted_shard_batches)
from repro.core.engine import AdaParseEngine, EngineConfig
from repro.data.synthetic import batch_metadata_features


def _assert_same_records(a: dict, b: dict, costs: bool = False):
    assert set(a) == set(b)
    for i in a:
        assert a[i].parser == b[i].parser
        assert len(a[i].pages) == len(b[i].pages)
        for pa, pb in zip(a[i].pages, b[i].pages):
            np.testing.assert_array_equal(pa, pb)
        if costs:
            assert a[i].cost_s == b[i].cost_s


# -- record parity ------------------------------------------------------------


@pytest.mark.parametrize("n_nodes", [2, 3])
def test_executor_matches_single_node(corpus, ft_router, n_nodes):
    """N-node campaign == single-node engine.run: same ParseRecords (doc
    set, chosen parsers, exact page contents)."""
    ccfg, docs = corpus
    test = docs[75:]
    ecfg = EngineConfig(alpha=0.1, batch_size=16)
    single = AdaParseEngine(ecfg, ft_router, ccfg).run(test)
    res = CampaignExecutor(ecfg, ExecutorConfig(n_nodes=n_nodes),
                           ft_router, ccfg).run(test)
    _assert_same_records(single, res.records)
    assert res.wall_s > 0 and res.docs_per_s > 0
    assert 0 < res.node_busy_frac <= 1 + 1e-9


def test_executor_straggler_reissue_keeps_records(corpus, ft_router):
    """Hung batches are re-issued to the fastest idle node; batch-keyed
    rng streams make the re-run reproduce the same records."""
    ccfg, docs = corpus
    test = docs[75:]
    ecfg = EngineConfig(alpha=0.1, batch_size=8)
    single = AdaParseEngine(ecfg, ft_router, ccfg).run(test)
    res = CampaignExecutor(
        ecfg, ExecutorConfig(n_nodes=3, straggler_rate=0.9,
                             straggler_slowdown=1000.0),
        ft_router, ccfg).run(test)
    assert res.reissued > 0
    _assert_same_records(single, res.records)


def test_executor_alpha_partition(corpus, ft_router):
    """Homogeneous shards recover the campaign alpha exactly; the routed
    fraction respects the per-node budgets (Σ node budgets = campaign)."""
    ccfg, docs = corpus
    ecfg = EngineConfig(alpha=0.1, batch_size=16)
    res = CampaignExecutor(ecfg, ExecutorConfig(n_nodes=2), ft_router,
                           ccfg).run(docs[75:])
    assert res.node_alphas == [0.1, 0.1]
    n = sum(s.n_docs for s in res.node_stats)
    n_exp = sum(s.n_expensive for s in res.node_stats)
    assert n == len(docs[75:])
    assert n_exp <= int(0.1 * n) + 1e-9


def test_executor_weighted_budget_partition(corpus, ft_router):
    """Heterogeneous node_budget_weights: the faster node gets a larger
    share of the expensive-parse budget (alpha_0 > alpha > alpha_1), and
    per-node budgets still sum to the campaign budget."""
    ccfg, docs = corpus
    a = 0.1
    ecfg = EngineConfig(alpha=a, batch_size=16)
    res = CampaignExecutor(
        ecfg, ExecutorConfig(n_nodes=2, node_budget_weights=[3.0, 1.0]),
        ft_router, ccfg).run(docs[75:])
    a0, a1 = res.node_alphas
    assert a0 > a > a1 >= 0.0
    t_c = 1.0 / P.PARSER_SPECS[ecfg.cheap].pdf_per_sec_node
    t_e = 1.0 / P.PARSER_SPECS[ecfg.expensive].pdf_per_sec_node
    sizes = [s.n_docs for s in res.node_stats]
    spent = sum(k * ((1 - ai) * t_c + ai * t_e)
                for k, ai in zip(sizes, res.node_alphas))
    total = sum(sizes) * ((1 - a) * t_c + a * t_e)
    np.testing.assert_allclose(spent, total, rtol=1e-9)


def test_executor_heterogeneous_pools_match_single_node(corpus, ft_router):
    """CPU/GPU pools: ingest shards over the CPU pool, expensive
    re-parses forward to the GPU pool — records still identical to the
    single-node run (rng streams carried from prepare into complete)."""
    ccfg, docs = corpus
    test = docs[75:]
    ecfg = EngineConfig(alpha=0.1, batch_size=16)
    single = AdaParseEngine(ecfg, ft_router, ccfg).run(test)
    res = CampaignExecutor(
        ecfg, ExecutorConfig(n_nodes=4,
                             node_pools=["cpu", "cpu", "cpu", "gpu"],
                             straggler_rate=0.0),
        ft_router, ccfg).run(test)
    _assert_same_records(single, res.records)
    # gpu node did re-parse work but no ingest; cpu nodes the reverse
    gpu = res.node_stats[3]
    assert gpu.n_expensive > 0 and gpu.n_docs == 0
    assert sum(s.n_docs for s in res.node_stats[:3]) == len(test)
    assert sum(s.n_expensive for s in res.node_stats[:3]) == 0


def test_executor_pools_prefetch_cache_match_single_node(corpus, ft_router):
    """The ISSUE-2 determinism invariant: pools + prefetch depth >= 2 +
    a warm result cache reproduce the single-node uncached record set
    exactly, and the warm pass is all hits / no parsing."""
    ccfg, docs = corpus
    test = docs[75:]
    ecfg = EngineConfig(alpha=0.1, batch_size=16)
    single = AdaParseEngine(ecfg, ft_router, ccfg).run(test)
    cache = ResultCache()
    ex = CampaignExecutor(
        ecfg, ExecutorConfig(n_nodes=4,
                             node_pools=["cpu", "cpu", "cpu", "gpu"],
                             prefetch_depth=2, straggler_rate=0.0),
        ft_router, ccfg)
    cold = ex.run(test, cache=cache)
    _assert_same_records(single, cold.records)
    assert cold.cache_hits == 0 and cold.cache_misses > 0
    warm = ex.run(test, cache=cache)
    _assert_same_records(single, warm.records)
    assert warm.cache_misses == 0
    assert warm.cache_hits == cold.cache_misses == len(cache)


def test_executor_pools_straggler_reissue_keeps_records(corpus, ft_router):
    """Straggler re-issue inside the ingest pool preserves records."""
    ccfg, docs = corpus
    test = docs[75:]
    ecfg = EngineConfig(alpha=0.1, batch_size=8)
    single = AdaParseEngine(ecfg, ft_router, ccfg).run(test)
    res = CampaignExecutor(
        ecfg, ExecutorConfig(n_nodes=3, node_pools=["cpu", "cpu", "gpu"],
                             straggler_rate=0.9,
                             straggler_slowdown=1000.0),
        ft_router, ccfg).run(test)
    assert res.reissued > 0
    _assert_same_records(single, res.records)


def test_executor_straggler_reissue_does_not_replay_cache(corpus, ft_router):
    """A re-issued straggler batch must be re-parsed for real, not
    replayed from the entry its abandoned first attempt just stored —
    a cold run stays hit-free and re-issued work costs real time."""
    ccfg, docs = corpus
    test = docs[75:]
    ecfg = EngineConfig(alpha=0.1, batch_size=8)
    single = AdaParseEngine(ecfg, ft_router, ccfg).run(test)
    cache = ResultCache()
    res = CampaignExecutor(
        ecfg, ExecutorConfig(n_nodes=3, straggler_rate=0.9,
                             straggler_slowdown=1000.0),
        ft_router, ccfg).run(test, cache=cache)
    assert res.reissued > 0
    assert res.cache_hits == 0
    _assert_same_records(single, res.records)


def test_cache_distinguishes_corpus_configs(corpus, ft_router):
    """Same seed/n_docs but different corpus shape must not replay
    across configs (full-config fingerprint)."""
    import dataclasses as dc

    from repro.data.synthetic import generate_corpus

    ccfg, docs = corpus
    cache = ResultCache()
    ecfg = EngineConfig(alpha=0.1, batch_size=16)
    AdaParseEngine(ecfg, ft_router, ccfg, cache=cache).process_batch(
        docs[75:91], batch_key=0)
    ccfg2 = dc.replace(ccfg, page_tokens=ccfg.page_tokens * 2)
    docs2 = generate_corpus(ccfg2)
    eng2 = AdaParseEngine(ecfg, ft_router, ccfg2, cache=cache)
    recs = eng2.process_batch(docs2[75:91], batch_key=0)
    assert cache.hits == 0 and cache.misses == 2
    by_id = {d.doc_id: d for d in docs2[75:91]}
    for r in recs:                      # records come from the new corpus
        assert len(r.pages) == by_id[r.doc_id].n_pages


def test_executor_prefetch_overlap_matches_single_node(corpus, ft_router):
    """Homogeneous nodes with prefetch overlap: same records."""
    ccfg, docs = corpus
    test = docs[75:]
    ecfg = EngineConfig(alpha=0.1, batch_size=16)
    single = AdaParseEngine(ecfg, ft_router, ccfg).run(test)
    res = CampaignExecutor(
        ecfg, ExecutorConfig(n_nodes=2, prefetch_depth=2),
        ft_router, ccfg).run(test)
    _assert_same_records(single, res.records)


# -- pool-aware straggler re-issue --------------------------------------------


def test_reparse_straggler_reissues_inside_gpu_pool(corpus, ft_router):
    """A forwarded expensive re-parse stuck on a GPU-pool node re-issues
    to the least-loaded peer of that pool; records stay identical to the
    single-node run and the re-issued work lands on the peers."""
    ccfg, docs = corpus
    test = docs[75:]
    ecfg = EngineConfig(alpha=0.2, batch_size=16)
    single = AdaParseEngine(ecfg, ft_router, ccfg).run(test)
    res = CampaignExecutor(
        ecfg, ExecutorConfig(n_nodes=3, node_pools=["cpu", "gpu", "gpu"],
                             straggler_rate=0.9,
                             straggler_slowdown=1000.0),
        ft_router, ccfg).run(test)
    assert res.reissued_reparse > 0
    assert res.reissued >= res.reissued_reparse
    _assert_same_records(single, res.records)
    # the re-issued re-parses were taken over by GPU-pool peers
    assert sum(res.node_stats[i].reissued_tasks for i in (1, 2)) \
        == res.reissued_reparse


def test_gpu_backend_never_crosses_pools(corpus, ft_router):
    """With a single-node GPU pool there is no eligible peer for a stuck
    Nougat re-parse (GPU work cannot run on CPU nodes): the straggler
    runs to completion instead of re-issuing, and records still match."""
    ccfg, docs = corpus
    test = docs[75:]
    ecfg = EngineConfig(alpha=0.2, batch_size=16)
    single = AdaParseEngine(ecfg, ft_router, ccfg).run(test)
    res = CampaignExecutor(
        ecfg, ExecutorConfig(n_nodes=3, node_pools=["cpu", "cpu", "gpu"],
                             straggler_rate=0.9,
                             straggler_slowdown=1000.0),
        ft_router, ccfg).run(test)
    assert res.reissued_reparse == 0
    assert res.node_stats[2].reissued_tasks == 0
    _assert_same_records(single, res.records)


class _GpuEcho:
    """GPU-device cheap backend (ground-truth pages at fixed cost) used
    to construct a lone-node re-parse pool whose backend is CPU-capable."""

    def __init__(self):
        self.info = B.BackendInfo(name="gpuecho", device="gpu",
                                  pdf_per_sec_node=50.0)

    def parse_batch(self, docs, cfg, rng, *, image_degraded=False,
                    text_degraded=False):
        return [[np.asarray(pg, np.int32) for pg in d.pages] for d in docs]

    def cost_batch(self, docs):
        return np.full(len(docs), 1.0 / self.info.pdf_per_sec_node)


def test_cpu_backend_reissue_crosses_pools(corpus, ft_router):
    """A CPU-device expensive backend stuck on a lone-node pool may
    re-issue across pools (CPU work runs anywhere): GPU ingest node 0
    absorbs the re-parses the stuck CPU node 1 abandoned."""
    ccfg, docs = corpus
    test = docs[75:]
    B.register_backend(_GpuEcho())
    try:
        ecfg = EngineConfig(alpha=0.2, batch_size=16, cheap="gpuecho",
                            expensive="tesseract")
        single = AdaParseEngine(ecfg, ft_router, ccfg).run(test)
        res = CampaignExecutor(
            ecfg, ExecutorConfig(n_nodes=2, node_pools=["gpu", "cpu"],
                                 straggler_rate=0.9,
                                 straggler_slowdown=1000.0),
            ft_router, ccfg).run(test)
        assert res.reissued_reparse > 0
        assert res.node_stats[0].reissued_tasks == res.reissued_reparse
        _assert_same_records(single, res.records)
    finally:
        B.unregister_backend("gpuecho")


def test_partially_warm_replay_does_not_collapse_deadline(corpus,
                                                          ft_router):
    """Cache replays cost zero time; they must stay out of the
    mean-batch deadline baseline, or a partially warm run would see a
    ~zero deadline and spuriously re-issue every real batch. With a
    mild slowdown no real batch exceeds 2.5x the (real) mean, so a
    half-warm campaign re-issues nothing and keeps record parity."""
    ccfg, docs = corpus
    test = docs[75:]
    ecfg = EngineConfig(alpha=0.1, batch_size=8)
    single = AdaParseEngine(ecfg, ft_router, ccfg).run(test)
    cache = ResultCache()
    xcfg = ExecutorConfig(n_nodes=2, straggler_rate=0.0)
    # warm the first half of the batch sequence only
    CampaignExecutor(ecfg, xcfg, ft_router, ccfg).run(test[:40],
                                                      cache=cache)
    stragglers = ExecutorConfig(n_nodes=2, straggler_rate=1.0,
                                straggler_slowdown=1.1)
    res = CampaignExecutor(ecfg, stragglers, ft_router, ccfg).run(
        test, cache=cache)
    assert res.cache_hits > 0 and res.cache_misses > 0
    assert res.reissued == 0
    _assert_same_records(single, res.records)


# -- adaptive controller ------------------------------------------------------


def test_controller_sheds_load_from_slow_node(corpus, ft_router):
    """On a skewed-speed fleet (node 3 four times slower) the controller
    converges node_budget_weights toward measured throughput: the slow
    node's weight drops well below uniform, the fast nodes absorb its
    share, and the record set still equals the single-node run."""
    ccfg, docs = corpus
    test = docs[75:]
    ecfg = EngineConfig(alpha=0.1, batch_size=4)
    single = AdaParseEngine(ecfg, ft_router, ccfg).run(test)
    xcfg = ExecutorConfig(n_nodes=4, straggler_rate=0.0,
                          node_speed_factors=[1.0, 1.0, 1.0, 4.0])
    res = CampaignController(ecfg, xcfg, ControllerConfig(rounds=4),
                             ft_router, ccfg).run(test)
    assert res.rounds == 4
    assert len(res.weight_history) == 5          # per round + final
    assert len(res.telemetry) == 4
    w0, w_final = res.weight_history[0], res.weight_history[-1]
    assert w0 == [0.25] * 4                      # uniform start
    assert w_final[3] < 0.15                     # slow node shed load
    assert all(w_final[i] > w_final[3] for i in range(3))
    _assert_same_records(single, res.records)


def test_controller_beats_static_uniform_on_skewed_speeds(corpus,
                                                          ft_router):
    """The adaptive campaign finishes faster than the uniform-weight
    static executor on the same skewed-speed fleet (the ISSUE-3
    acceptance bar) while producing the identical record set."""
    ccfg, docs = corpus
    test = docs[75:]
    ecfg = EngineConfig(alpha=0.1, batch_size=4)
    xcfg = ExecutorConfig(n_nodes=4, straggler_rate=0.0,
                          node_speed_factors=[1.0, 1.0, 1.0, 4.0])
    static = CampaignExecutor(ecfg, xcfg, ft_router, ccfg).run(test)
    res = CampaignController(ecfg, xcfg, ControllerConfig(rounds=4),
                             ft_router, ccfg).run(test)
    assert res.wall_s < static.wall_s
    _assert_same_records(static.records, res.records)
    conv = autotune_convergence_rounds(res.weight_history)
    assert 0 <= conv <= res.rounds


def test_controller_trace_replay_is_deterministic(corpus, ft_router):
    """A replayed telemetry trace pins the weight trajectory exactly,
    independent of measured clocks (warm cache, different speeds)."""
    ccfg, docs = corpus
    test = docs[75:]
    ecfg = EngineConfig(alpha=0.1, batch_size=8)
    xcfg = ExecutorConfig(n_nodes=2, straggler_rate=0.0)
    rec = CampaignController(ecfg, xcfg, ControllerConfig(rounds=3),
                             ft_router, ccfg).run(test)
    ctl = ControllerConfig(rounds=3, telemetry_trace=rec.telemetry)
    slow = ExecutorConfig(n_nodes=2, straggler_rate=0.0,
                          node_speed_factors=[1.0, 9.0])
    replay = CampaignController(ecfg, slow, ctl, ft_router, ccfg).run(test)
    assert replay.weight_history == rec.weight_history
    _assert_same_records(rec.records, replay.records, costs=True)


def test_controller_adaptive_pooled_disk_cached_restart_parity(
        corpus, ft_router, tmp_path):
    """The ISSUE-3 determinism contract: pools + prefetch + adaptive
    rounds (telemetry replayed from a fixed trace) + disk-backed result
    store reproduce the single-node uncached record set byte-for-byte —
    including across a process restart (fresh store + controller over
    the same cache dir), where the warm pass is all hits."""
    ccfg, docs = corpus
    test = docs[75:]
    ecfg = EngineConfig(alpha=0.1, batch_size=16)
    single = AdaParseEngine(ecfg, ft_router, ccfg).run(test)
    xcfg = ExecutorConfig(n_nodes=4,
                          node_pools=["cpu", "cpu", "cpu", "gpu"],
                          prefetch_depth=2, straggler_rate=0.0)
    trace = [[210.0, 180.0, 150.0]] * 3          # fixed 3-ingest-node trace
    ctl = ControllerConfig(rounds=3, telemetry_trace=trace)

    store = DiskResultStore(tmp_path / "cache")
    cold = CampaignController(ecfg, xcfg, ctl, ft_router, ccfg).run(
        test, cache=store)
    _assert_same_records(single, cold.records, costs=True)
    assert cold.cache_hits == 0 and cold.cache_misses > 0

    # "process restart": a fresh store instance over the same directory
    # and a fresh controller (engines re-fingerprint the same router)
    store2 = DiskResultStore(tmp_path / "cache")
    warm = CampaignController(ecfg, xcfg, ctl, ft_router, ccfg).run(
        test, cache=store2)
    _assert_same_records(single, warm.records, costs=True)
    assert warm.cache_misses == 0
    assert warm.cache_hits == cold.cache_misses == len(store2)
    assert warm.weight_history == cold.weight_history


def test_controller_validates_config(corpus, ft_router):
    ccfg, docs = corpus
    ecfg = EngineConfig(alpha=0.1, batch_size=8)
    with pytest.raises(ValueError, match="at least 1 round"):
        CampaignController(ecfg, ExecutorConfig(n_nodes=2),
                           ControllerConfig(rounds=0), ft_router, ccfg)
    with pytest.raises(ValueError, match="ewma"):
        CampaignController(ecfg, ExecutorConfig(n_nodes=2),
                           ControllerConfig(ewma=0.0), ft_router, ccfg)
    bad_trace = ControllerConfig(rounds=2, telemetry_trace=[[1.0, 2.0,
                                                             3.0]])
    ctrl = CampaignController(ecfg, ExecutorConfig(n_nodes=2), bad_trace,
                              ft_router, ccfg)
    with pytest.raises(ValueError, match="ingest-node observations"):
        ctrl.run(docs[75:107])


def test_speed_factors_survive_node_clamp(corpus, ft_router):
    """Speed factors are sized to the configured fleet; a corpus with
    fewer batches than nodes clamps the fleet and slices the factors
    instead of rejecting a config that is valid at full scale."""
    ccfg, docs = corpus
    ecfg = EngineConfig(alpha=0.1, batch_size=32)   # 2 batches, 4 nodes
    single = AdaParseEngine(ecfg, ft_router, ccfg).run(docs[75:139])
    res = CampaignExecutor(
        ecfg, ExecutorConfig(n_nodes=4, straggler_rate=0.0,
                             node_speed_factors=[1.0, 1.0, 1.0, 4.0]),
        ft_router, ccfg).run(docs[75:139])
    _assert_same_records(single, res.records)


def test_controller_all_warm_replay_keeps_weights_uniform(corpus,
                                                          ft_router):
    """Cache replays advance no clock and must not count as observed
    throughput: an all-warm adaptive run keeps the uniform weights
    (estimates unchanged) instead of inflating cached nodes."""
    ccfg, docs = corpus
    test = docs[75:]
    ecfg = EngineConfig(alpha=0.1, batch_size=8)
    cache = ResultCache()
    xcfg = ExecutorConfig(n_nodes=3, straggler_rate=0.0)
    ctl = ControllerConfig(rounds=3)
    CampaignController(ecfg, xcfg, ctl, ft_router, ccfg).run(
        test, cache=cache)
    warm = CampaignController(ecfg, xcfg, ctl, ft_router, ccfg).run(
        test, cache=cache)
    assert warm.cache_misses == 0 and warm.cache_hits > 0
    assert all(t.throughput == [0.0] * 3 for t in warm.telemetry)
    assert all(w == warm.weight_history[0] for w in warm.weight_history)


def test_executor_rejects_bad_speed_factors(corpus, ft_router):
    ccfg, docs = corpus
    ecfg = EngineConfig(alpha=0.1, batch_size=16)
    with pytest.raises(ValueError, match="speed factors"):
        CampaignExecutor(
            ecfg, ExecutorConfig(n_nodes=2,
                                 node_speed_factors=[1.0]),
            ft_router, ccfg).run(docs[75:])
    with pytest.raises(ValueError, match="positive"):
        CampaignExecutor(
            ecfg, ExecutorConfig(n_nodes=2,
                                 node_speed_factors=[1.0, 0.0]),
            ft_router, ccfg).run(docs[75:])


# -- speed-weighted sharding --------------------------------------------------


def test_weighted_shard_batches_uniform_is_round_robin():
    shards = weighted_shard_batches(7, [1.0, 1.0, 1.0])
    assert shards == [[0, 3, 6], [1, 4], [2, 5]]


def test_weighted_shard_batches_sizes_follow_weights():
    shards = weighted_shard_batches(100, [3.0, 1.0])
    sizes = [len(s) for s in shards]
    assert sizes == [75, 25]
    assert sorted(g for s in shards for g in s) == list(range(100))


def test_weighted_shard_batches_all_zero_weights_fall_back_uniform():
    """All-zero weights carry no signal: fall back to uniform
    round-robin instead of raising from deep inside the executor."""
    assert weighted_shard_batches(7, [0.0, 0.0, 0.0]) == \
        [[0, 3, 6], [1, 4], [2, 5]]


def test_weighted_shard_batches_more_shards_than_batches_uniform():
    """More nodes than batches: skewed quotas would pile every batch on
    the heaviest shard while the others idle — fall back to uniform so
    each batch lands on its own shard."""
    assert weighted_shard_batches(2, [100.0, 1.0, 1.0]) == [[0], [1], []]
    # negative weights are still an error, not a fallback
    with pytest.raises(ValueError, match="non-negative"):
        weighted_shard_batches(4, [1.0, -1.0])


def test_weighted_budget_skews_shard_sizes(corpus, ft_router):
    """node_budget_weights now also skew shard sizes: the faster node
    parses more documents, and the corpus is still covered exactly."""
    ccfg, docs = corpus
    test = docs[75:]
    ecfg = EngineConfig(alpha=0.1, batch_size=8)
    res = CampaignExecutor(
        ecfg, ExecutorConfig(n_nodes=2, node_budget_weights=[3.0, 1.0],
                             straggler_rate=0.0),
        ft_router, ccfg).run(test)
    sizes = [s.n_docs for s in res.node_stats]
    assert sizes[0] > sizes[1] > 0
    assert set(res.records) == {d.doc_id for d in test}


def test_executor_single_node_degenerate(corpus, ft_router):
    ccfg, docs = corpus
    ecfg = EngineConfig(alpha=0.1, batch_size=32)
    single = AdaParseEngine(ecfg, ft_router, ccfg).run(docs[100:])
    res = CampaignExecutor(ecfg, ExecutorConfig(n_nodes=1), ft_router,
                           ccfg).run(docs[100:])
    _assert_same_records(single, res.records)


def test_document_shard_source_covers_corpus(corpus):
    """Round-robin shards partition the global batch sequence exactly."""
    _, docs = corpus
    seen = {}
    for shard in range(3):
        for b in document_shard_source(docs, 16, shard, 3):
            assert b["batch_key"] % 3 == shard
            assert b["batch_key"] not in seen
            seen[b["batch_key"]] = [d.doc_id for d in b["docs"]]
    got = [i for k in sorted(seen) for i in seen[k]]
    assert got == [d.doc_id for d in docs]


# -- batched channel / feature paths -----------------------------------------


def test_run_parser_batch_structure(corpus):
    """Batched channel output preserves per-doc page structure and the
    token id space."""
    ccfg, docs = corpus
    rng = np.random.RandomState(0)
    outs = P.run_parser_batch("pymupdf", docs[:40], ccfg, rng)
    assert len(outs) == 40
    hi = ccfg.ident_lo + ccfg.n_ident
    for d, pages in zip(docs[:40], outs):
        assert len(pages) == d.n_pages
        for pg in pages:
            assert pg.dtype == np.int32
            if len(pg):
                assert 0 <= pg.min() and pg.max() < hi


def test_batch_fast_features_matches_single(corpus):
    ccfg, docs = corpus
    rng = np.random.RandomState(3)
    outs = P.run_parser_batch("pypdf", docs[:25], ccfg, rng)
    batched = F.batch_fast_features(outs, ccfg)
    single = np.stack([F.fast_features(o, ccfg) for o in outs])
    np.testing.assert_allclose(batched, single, rtol=1e-6, atol=1e-7)


def test_batch_first_page_tokens_matches_single(corpus):
    ccfg, docs = corpus
    rng = np.random.RandomState(4)
    outs = P.run_parser_batch("pymupdf", docs[:25], ccfg, rng)
    toks_b, mask_b = F.batch_first_page_tokens(outs, 32)
    for i, o in enumerate(outs):
        t, m = F.first_page_tokens(o, 32)
        np.testing.assert_array_equal(toks_b[i], t)
        np.testing.assert_array_equal(mask_b[i], m)


def test_batch_metadata_features_matches_single(corpus):
    _, docs = corpus
    batched = batch_metadata_features(docs[:30])
    single = np.stack([d.metadata_features() for d in docs[:30]])
    np.testing.assert_allclose(batched, single)


def test_parse_cost_batch_matches_single(corpus):
    _, docs = corpus
    for name in ("pymupdf", "nougat"):
        batched = P.parse_cost_batch(name, docs[:20])
        single = np.array([P.parse_cost_s(name, d) for d in docs[:20]])
        np.testing.assert_allclose(batched, single)


def test_stateless_batch_keys_reproduce(corpus, ft_router):
    """Same batch + same key -> identical records, independent of engine
    instance or call order (the property the executor relies on)."""
    ccfg, docs = corpus
    ecfg = EngineConfig(alpha=0.2, batch_size=16)
    e1 = AdaParseEngine(ecfg, ft_router, ccfg)
    e2 = AdaParseEngine(ecfg, ft_router, ccfg)
    batch = docs[75:91]
    r_warm = e2.process_batch(docs[91:107], node_id=0, batch_key=5)  # noqa
    a = e1.process_batch(batch, node_id=0, batch_key=3)
    b = e2.process_batch(batch, node_id=1, batch_key=3)
    _assert_same_records({r.doc_id: r for r in a}, {r.doc_id: r for r in b})
