"""data/pipeline.Prefetcher: ordering, worker-error propagation (no
silent truncation, no hang), exhaustion, and idempotent close."""
import time

import pytest

from repro.data.pipeline import BatchSource, Prefetcher


def test_prefetcher_preserves_order_and_transform():
    pf = Prefetcher(iter(range(10)), depth=3, transform=lambda x: x * 2)
    assert list(pf) == [x * 2 for x in range(10)]


def test_prefetcher_transform_error_propagates():
    """An exception raised in the worker thread must surface on
    ``__next__`` — not silently end the iteration."""

    def boom(x):
        if x == 3:
            raise ValueError("bad item 3")
        return x

    pf = Prefetcher(iter(range(6)), depth=2, transform=boom)
    got = []
    with pytest.raises(ValueError, match="bad item 3"):
        for item in pf:
            got.append(item)
    assert got == [0, 1, 2]
    # after the error the iterator is finished, not wedged
    with pytest.raises(StopIteration):
        next(pf)


def test_prefetcher_source_error_propagates():
    def gen():
        yield 1
        raise RuntimeError("source died")

    pf = Prefetcher(gen(), depth=2)
    assert next(pf) == 1
    with pytest.raises(RuntimeError, match="source died"):
        next(pf)


def test_prefetcher_exhaustion_does_not_hang():
    """Repeated ``__next__`` after exhaustion keeps raising StopIteration
    (the seed implementation blocked forever on the second call)."""
    pf = Prefetcher(iter([1]), depth=2)
    assert next(pf) == 1
    for _ in range(3):
        with pytest.raises(StopIteration):
            next(pf)


def test_prefetcher_close_is_idempotent():
    pf = Prefetcher(iter(range(100)), depth=2,
                    transform=lambda x: (time.sleep(0.001), x)[1])
    assert next(pf) == 0
    pf.close()
    pf.close()                         # second close must be a no-op
    assert not pf.thread.is_alive()
    with pytest.raises(StopIteration):  # closed iterator is finished
        next(pf)


def test_prefetcher_close_unblocks_full_queue():
    """close() must release a worker blocked on a full queue."""
    pf = Prefetcher(iter(range(1000)), depth=1)
    time.sleep(0.02)                   # let the worker fill the queue
    pf.close()
    assert not pf.thread.is_alive()


def test_batch_source_stateless_resume():
    src = BatchSource(lambda step, rng: {"step": step, "v": rng.rand()},
                      seed=7, start_step=3)
    a = next(src)
    resumed = BatchSource(lambda step, rng: {"step": step, "v": rng.rand()},
                          seed=7, start_step=3)
    b = next(resumed)
    assert a["step"] == b["step"] == 3
    assert a["v"] == b["v"]


def test_prefetcher_over_batch_source():
    src = BatchSource(lambda step, rng: {"step": step}, seed=0)
    pf = Prefetcher(src, depth=2)
    assert [next(pf)["step"] for _ in range(4)] == [0, 1, 2, 3]
    pf.close()
