"""AdaParse system behaviour: corpus/channels, hierarchical routing,
engine end-to-end quality, DPO post-training, campaign scaling."""
import numpy as np
import pytest

from repro.core import metrics as M
from repro.core import parsers as P
from repro.core import scheduler
from repro.core.campaign import CampaignConfig, simulate_parser_campaign
from repro.core.engine import AdaParseEngine, EngineConfig

# ``corpus`` (session-scoped synthetic corpus) and ``ft_router`` (trained
# CLS I+II stages) come from conftest.py.


def test_corpus_properties(corpus):
    ccfg, docs = corpus
    assert len(docs) == 150
    d = np.array([x.difficulty for x in docs])
    assert 0 <= d.min() and d.max() <= 1
    assert all(1 <= x.n_pages <= 8 for x in docs)


def test_parser_quality_ordering(corpus):
    """Fig. 3 structure: pymupdf beats nougat on easy docs; nougat beats
    pymupdf on the hardest quartile."""
    ccfg, docs = corpus
    rng = np.random.RandomState(0)
    d = np.array([x.difficulty for x in docs])
    easy = [x for x in docs if x.difficulty < np.quantile(d, 0.25)]
    hard = [x for x in docs if x.difficulty > np.quantile(d, 0.75)]

    def mean_bleu(name, subset):
        out = []
        for doc in subset:
            pages = P.run_parser(name, doc, ccfg, rng)
            hyp = (np.concatenate(pages) if sum(map(len, pages))
                   else np.zeros(0, np.int32))
            out.append(M.bleu(doc.full_text(), hyp))
        return float(np.mean(out))

    assert mean_bleu("pymupdf", easy) > mean_bleu("nougat", easy)
    assert mean_bleu("nougat", hard) > mean_bleu("pymupdf", hard)


def test_engine_beats_constituents(corpus, ft_router):
    """Table 1 headline: AdaParse BLEU >= max(pymupdf, nougat) - eps at
    alpha=5%, with frac_expensive <= alpha. Router training (conftest
    ``ft_router``) and single-parser baselines both use the batched
    channel path."""
    ccfg, docs = corpus
    test = docs[75:]
    eng = AdaParseEngine(EngineConfig(alpha=0.05, batch_size=32), ft_router,
                         ccfg)
    res = eng.evaluate(test, eng.run(test))
    assert res["frac_expensive"] <= 0.05 + 1e-9

    rng2 = np.random.RandomState(9)
    base = {}
    for n in ("pymupdf", "nougat"):
        outs = P.run_parser_batch(n, test, ccfg, rng2)
        hyps = [np.concatenate(o) if sum(map(len, o))
                else np.zeros(0, np.int32) for o in outs]
        base[n] = M.evaluate_parser([d.full_text() for d in test], hyps)
    assert res["bleu"] > base["nougat"]["bleu"]
    assert res["bleu"] > base["pymupdf"]["bleu"] - 0.01


def test_throughput_claim():
    """The 17x headline: analytic adaparse goodput vs nougat-only."""
    t_cheap = 1.0 / P.PARSER_SPECS["pymupdf"].pdf_per_sec_node
    t_exp = 1.0 / P.PARSER_SPECS["nougat"].pdf_per_sec_node
    g_ada = scheduler.expected_goodput(0.05, t_cheap, t_exp,
                                       router_cost=0.002)
    g_nou = scheduler.expected_goodput(1.0, t_cheap, t_exp)
    assert 14.0 < g_ada / g_nou < 20.0      # paper: 17x


def test_campaign_scaling_shapes():
    """Fig. 5: near-linear for nougat; pymupdf plateaus (FS contention);
    marker capped at 10 nodes."""
    cfg = CampaignConfig(n_docs=50_000)
    import dataclasses
    r1 = simulate_parser_campaign(
        "nougat", dataclasses.replace(cfg, n_nodes=8))
    r2 = simulate_parser_campaign(
        "nougat", dataclasses.replace(cfg, n_nodes=64))
    assert 4 < r2.docs_per_s / r1.docs_per_s <= 9      # ~linear

    m1 = simulate_parser_campaign(
        "marker", dataclasses.replace(cfg, n_nodes=10))
    m2 = simulate_parser_campaign(
        "marker", dataclasses.replace(cfg, n_nodes=100))
    assert m2.docs_per_s / m1.docs_per_s < 1.5         # scale ceiling

    p_small = simulate_parser_campaign(
        "pymupdf", dataclasses.replace(cfg, n_nodes=4, n_docs=200_000))
    p_big = simulate_parser_campaign(
        "pymupdf", dataclasses.replace(cfg, n_nodes=256, n_docs=200_000))
    assert p_big.docs_per_s / p_small.docs_per_s < 64  # sub-linear


def test_straggler_reissue():
    import dataclasses
    cfg = CampaignConfig(n_docs=100_000, straggler_rate=0.2,
                         straggler_slowdown=10.0)
    r = simulate_parser_campaign("pymupdf", cfg)
    assert r.reissued > 0


@pytest.mark.slow
def test_dpo_improves_preference_accuracy():
    """Stage-2 DPO raises pairwise preference accuracy over the SFT-only
    model (Table 4's WR direction)."""
    import jax.numpy as jnp
    from repro.common import unwrap
    from repro.configs.base import EncoderConfig
    from repro.core import dpo as dpo_lib
    from repro.models import encoder as enc_lib

    cfg = EncoderConfig(name="t", n_layers=2, d_model=32, n_heads=4,
                        d_ff=64, vocab_size=128, max_len=16,
                        param_dtype="float32", compute_dtype="float32")
    rng = np.random.RandomState(0)
    n, s = 48, 16
    # preferred texts drawn from low token ids, rejected from high
    tok_pos = rng.randint(2, 60, (n, s)).astype(np.int32)
    tok_neg = rng.randint(64, 126, (n, s)).astype(np.int32)
    pref = {"tok_pos": tok_pos, "mask_pos": np.ones((n, s), np.float32),
            "tok_neg": tok_neg, "mask_neg": np.ones((n, s), np.float32)}
    p = unwrap(enc_lib.init_encoder(cfg, 0))
    batch = {k: jnp.asarray(v) for k, v in pref.items()}
    acc0 = float(dpo_lib.pref_accuracy(p, cfg, batch))
    res = dpo_lib.fit_dpo(p, cfg, pref, steps=40, lr=1e-3, bs=16)
    acc1 = float(dpo_lib.pref_accuracy(res.params_raw, cfg, batch))
    assert acc1 > max(acc0, 0.8)
    assert res.losses[-1] < res.losses[0]
