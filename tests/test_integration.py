"""Integration tests: train loop + checkpoint/restart + elastic restore,
optimizers, pipeline parallelism, compressed collectives, serve driver."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.optim import adafactor, adamw, apply_updates


def test_adamw_and_adafactor_reduce_quadratic():
    def loss(p):
        return jnp.sum(jnp.square(p["w"] - 3.0)) + jnp.sum(
            jnp.square(p["b"] + 1.0))

    # adafactor's sign-like updates need a decaying lr to settle
    for opt in (adamw(0.1), adafactor(lambda s: 0.5 / (1.0 + 0.05 * s))):
        params = {"w": jnp.zeros((4, 4)), "b": jnp.zeros((4,))}
        state = opt.init(params)
        for step in range(200):
            g = jax.grad(loss)(params)
            upd, state = opt.update(g, state, params, jnp.asarray(step))
            params = apply_updates(params, upd)
        assert float(loss(params)) < 1e-2


def test_adafactor_state_is_factored():
    opt = adafactor(1e-2, min_dim_factored=8)
    params = {"big": jnp.zeros((16, 32)), "small": jnp.zeros((4,))}
    st = opt.init(params)
    assert set(st["v"]["big"].keys()) == {"vr", "vc"}
    assert st["v"]["big"]["vr"].shape == (16,)
    assert st["v"]["big"]["vc"].shape == (32,)
    assert set(st["v"]["small"].keys()) == {"v"}


@pytest.mark.slow
def test_train_restart_is_exact():
    """Crash/restart from checkpoint reproduces the uninterrupted run
    bit-for-bit (fault tolerance + stateless data pipeline)."""
    from repro.launch.train import main as train_main
    with tempfile.TemporaryDirectory() as d:
        ck = os.path.join(d, "ck")
        full = train_main(["--arch", "qwen3-1.7b", "--shape", "train_4k",
                           "--reduced", "--steps", "6", "--log-every", "100"])
        # interrupted run: 3 steps, checkpoint, then resume to 6
        train_main(["--arch", "qwen3-1.7b", "--shape", "train_4k",
                    "--reduced", "--steps", "3", "--ckpt-dir", ck,
                    "--ckpt-every", "3", "--log-every", "100"])
        resumed = train_main(["--arch", "qwen3-1.7b", "--shape", "train_4k",
                              "--reduced", "--steps", "6", "--ckpt-dir", ck,
                              "--ckpt-every", "100", "--log-every", "100"])
        np.testing.assert_allclose(full[3:], resumed, rtol=1e-5)


def test_checkpoint_elastic_restore():
    """Restore onto a different mesh shape (elastic rescale)."""
    devs = jax.devices()
    if len(devs) < 2:
        pytest.skip("needs >1 device (XLA_FLAGS host platform count)")
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import make_mesh
    mesh1 = make_mesh((2,), ("data",))
    tree = {"w": jnp.arange(32.0).reshape(8, 4)}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, tree)
        sh = {"w": NamedSharding(mesh1, P("data"))}
        step, restored, _ = ckpt.restore(d, shardings=sh)
        assert restored["w"].sharding == sh["w"]
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(tree["w"]))


def test_serve_driver_end_to_end():
    from repro.launch.serve import main as serve_main
    res = serve_main(["--docs", "120", "--alpha", "0.05",
                      "--variant", "ft", "--batch-size", "32"])
    assert res["bleu"] > 0.3
    assert res["frac_expensive"] <= 0.05 + 1e-9
    assert res["coverage"] > 0.8


def test_serve_validates_cli_arguments(capsys):
    """Malformed --pools / --prefetch-depth specs exit with an
    actionable argparse error instead of a traceback from deep inside
    ExecutorConfig."""
    import pytest

    from repro.launch.serve import main as serve_main, parse_pools

    assert parse_pools("cpu:2,gpu") == ["cpu", "cpu", "gpu"]
    for spec, frag in [("tpu:2", "unknown pool device"),
                       ("cpu:x", "not an integer"),
                       ("cpu:0", "must be >= 1"),
                       ("cpu:2,,gpu", "empty entry")]:
        with pytest.raises(ValueError, match=frag):
            parse_pools(spec)

    def err_of(argv):
        with pytest.raises(SystemExit) as e:
            serve_main(argv)
        assert e.value.code == 2
        return capsys.readouterr().err

    assert "unknown pool device" in err_of(["--pools", "tpu:4"])
    assert "--prefetch-depth must be >= 0" in err_of(
        ["--prefetch-depth", "-1"])
    assert "--adaptive-rounds must be >= 0" in err_of(
        ["--adaptive-rounds", "-2"])
    assert "--cache-max-bytes only applies" in err_of(
        ["--cache-max-bytes", "1000"])
    assert "--cache-max-bytes must be >= 1" in err_of(
        ["--cache-dir", "/tmp/x", "--cache-max-bytes", "0"])
    assert "--nodes must be >= 1" in err_of(["--nodes", "0"])
    # quality-retune flags: same actionable-error style
    from repro.launch.serve import parse_alpha_bounds

    assert parse_alpha_bounds("0.05:0.4") == (0.05, 0.4)
    for spec, frag in [("0.4", "no ':'"), ("a:b", "not a pair"),
                       ("0.5:0.1", "out of order"),
                       ("-0.1:0.5", "out of order")]:
        with pytest.raises(ValueError, match=frag):
            parse_alpha_bounds(spec)
    assert "--quality-probe-rate must be in [0, 1]" in err_of(
        ["--quality-probe-rate", "1.5"])
    assert "--quality-probe-rate needs --adaptive-rounds" in err_of(
        ["--quality-probe-rate", "0.5"])
    assert "--alpha-step must be > 0" in err_of(["--alpha-step", "0"])
    assert "needs --adaptive-rounds" in err_of(
        ["--alpha-bounds", "0.05:0.4"])
    assert "needs --quality-probe-rate" in err_of(
        ["--alpha-bounds", "0.05:0.4", "--adaptive-rounds", "2"])
    assert "outside --alpha-bounds" in err_of(
        ["--alpha-bounds", "0.1:0.4", "--adaptive-rounds", "2",
         "--quality-probe-rate", "0.5", "--alpha", "0.05"])


def test_serve_driver_quality_retune_flags(capsys):
    """serve --quality-probe-rate/--alpha-bounds: the adaptive run wires
    the probe + retuner into the controller and reports the α
    trajectory; metrics stay sane."""
    from repro.launch.serve import main as serve_main

    res = serve_main(["--docs", "108", "--alpha", "0.05",
                      "--batch-size", "8", "--nodes", "2",
                      "--adaptive-rounds", "3",
                      "--quality-probe-rate", "1.0",
                      "--alpha-bounds", "0.05:0.5",
                      "--alpha-step", "0.2"])
    out = capsys.readouterr().out
    assert "quality probe docs=" in out and "alpha 0.05" in out
    assert res["bleu"] > 0.2
    assert res["frac_expensive"] <= 0.5 + 1e-9


def test_serve_driver_adaptive_disk_cached_restart(tmp_path):
    """serve --adaptive-rounds + --cache-dir: the second invocation (a
    real process restart would hit the same path) replays every batch
    from the disk store and reports identical metrics."""
    from repro.launch.serve import main as serve_main

    argv = ["--docs", "90", "--alpha", "0.1", "--batch-size", "16",
            "--pools", "cpu:2,gpu:1", "--adaptive-rounds", "2",
            "--cache-dir", str(tmp_path / "store")]
    cold = serve_main(argv)
    warm = serve_main(argv)
    assert warm["bleu"] == cold["bleu"]
    assert warm["coverage"] == cold["coverage"]


def test_compressed_allreduce_error_feedback_converges():
    """int8-compressed gradient means with error feedback track the true
    mean over steps (bias -> 0)."""
    from repro.optim.compression import compressed_gradients, \
        init_compression_state
    rng = np.random.RandomState(0)
    g_true = {"w": jnp.asarray(rng.randn(64) * 0.01, jnp.float32)}
    state = init_compression_state(g_true)
    acc = jnp.zeros(64)
    acc_true = jnp.zeros(64)
    for _ in range(50):
        comp, state, _ = compressed_gradients(g_true, state, scheme="int8")
        acc = acc + comp["w"]
        acc_true = acc_true + g_true["w"]
    err = float(jnp.abs(acc - acc_true).max() / jnp.abs(acc_true).max())
    assert err < 0.01


def test_router_cell_route_step_budget():
    """The fused route step selects exactly floor(alpha*B) docs (floor
    semantics: alpha*B < 1 routes nothing — the budget is a hard cap)."""
    from repro.launch.specs import build_cell
    cell = build_cell("adaparse-router", "route_64k", abstract=False,
                      reduced=True)
    out = jax.jit(cell.fn)(*cell.args)
    b = out["improvement"].shape[0]
    assert out["selected_idx"].shape[0] == int(0.05 * b)
    assert out["selected_mask"].sum() <= int(0.05 * b)
    assert out["pred_acc"].shape == (b, 6)
