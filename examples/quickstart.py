"""Quickstart: the AdaParse idea in 60 lines.

Generates a synthetic scientific corpus, runs the cheap parser on every
document, routes the predicted-hardest 5% to the expensive parser via the
budget scheduler, and shows the quality/throughput trade (paper Table 1 /
17x headline).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import features as F
from repro.core import metrics as M
from repro.core import parsers as P
from repro.core import scheduler
from repro.data.synthetic import CorpusConfig, generate_corpus

ccfg = CorpusConfig(n_docs=120, seed=0)
docs = generate_corpus(ccfg)
rng = np.random.RandomState(1)

# 1. cheap extraction for everyone (PyMuPDF channel, one batched
#    application over the whole corpus)
extracted = P.run_parser_batch("pymupdf", docs, ccfg, rng)

# 2. CLS-I fast features -> a crude improvement score: garbage fraction
feats = F.batch_fast_features(extracted, ccfg)
improvement = feats[:, 2] + feats[:, 3] + feats[:, 6]   # scramble+mangle+empty

# 3. alpha-budget selection (App. C): top 5% by predicted improvement
plan = scheduler.plan_batch(improvement, alpha=0.05)
print(f"routing {len(plan.expensive_idx)}/{len(docs)} documents to nougat")

# 4. re-parse the selected documents with the expensive parser (batched)
final = list(extracted)
sel = [docs[i] for i in plan.expensive_idx]
for i, pages in zip(plan.expensive_idx, P.run_parser_batch("nougat", sel,
                                                           ccfg, rng)):
    final[i] = pages

# 5. evaluate
refs = [d.full_text() for d in docs]


def flat(pages):
    return np.concatenate(pages) if sum(map(len, pages)) else np.zeros(0, np.int32)


for name, outs in [("pymupdf-only", extracted), ("adaparse", final)]:
    res = M.evaluate_parser(refs, [flat(o) for o in outs])
    print(f"{name:14s} BLEU={res['bleu']*100:.1f} ROUGE={res['rouge']*100:.1f} "
          f"AT={res['at']*100:.1f}")

t_cheap = 1 / P.PARSER_SPECS["pymupdf"].pdf_per_sec_node
t_exp = 1 / P.PARSER_SPECS["nougat"].pdf_per_sec_node
print(f"throughput: adaparse {scheduler.expected_goodput(0.05, t_cheap, t_exp):.1f} "
      f"vs nougat-only {scheduler.expected_goodput(1.0, t_cheap, t_exp):.1f} "
      f"PDF/s/node "
      f"({scheduler.expected_goodput(0.05, t_cheap, t_exp) / scheduler.expected_goodput(1.0, t_cheap, t_exp):.0f}x)")
