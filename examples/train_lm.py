"""Train an assigned-architecture LM with the fault-tolerant driver.

Reduced config by default (CPU-friendly); any of the 12 archs works:

    PYTHONPATH=src python examples/train_lm.py --arch qwen3-1.7b --steps 30
    PYTHONPATH=src python examples/train_lm.py --arch olmoe-1b-7b --steps 20

Demonstrates checkpoint/restart: the run checkpoints every 10 steps; kill
and re-run with the same --ckpt-dir to resume exactly.
"""
import argparse

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--ckpt-dir", default="ckpts/example_lm")
    args = ap.parse_args()
    train_main(["--arch", args.arch, "--shape", "train_4k", "--reduced",
                "--steps", str(args.steps), "--ckpt-dir", args.ckpt_dir,
                "--ckpt-every", "10", "--log-every", "5"])


if __name__ == "__main__":
    main()
