"""End-to-end driver: train the AdaParse router (SciBERT-class encoder)
through the full Appendix-A recipe — SFT accuracy regression, DPO on
preference pairs from the oracle, low-LR re-fit — then deploy it in the
engine and compare against the FT variant.

    PYTHONPATH=src python examples/train_router_dpo.py [--docs 300] [--full]

``--full`` uses the production 110M-parameter SciBERT config (slow on CPU;
the default uses the reduced config, same code path).
"""
import argparse

import numpy as np

from repro.core.engine import AdaParseEngine, EngineConfig
from repro.data.synthetic import CorpusConfig, generate_corpus
from repro.launch.serve import build_ft_router, build_llm_router


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=300)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=150)
    args = ap.parse_args()

    ccfg = CorpusConfig(n_docs=args.docs, seed=0)
    docs = generate_corpus(ccfg)
    train, test = docs[:args.docs // 2], docs[args.docs // 2:]
    rng = np.random.RandomState(1)

    print("== training FT router (CLS I+II linear stages) ==")
    ft = build_ft_router(train, ccfg, rng)

    print("== training LLM router (SFT -> DPO -> re-fit) ==")
    llm = build_llm_router(train, ccfg, rng, sft_steps=args.steps,
                           dpo_steps=args.steps // 2)

    for name, router in [("AdaParse(FT)", ft), ("AdaParse(LLM)", llm)]:
        eng = AdaParseEngine(EngineConfig(alpha=0.05, batch_size=64),
                             router, ccfg)
        res = eng.evaluate(test, eng.run(test))
        print(f"{name:14s} BLEU={res['bleu']*100:.1f} "
              f"AT={res['at']*100:.1f} "
              f"thr={res['throughput_docs_per_node_s']:.1f}/s "
              f"exp={res['frac_expensive']*100:.1f}%")


if __name__ == "__main__":
    main()
