"""Multi-node parsing campaign (Fig 5): simulate 1 -> 128 node scaling for
every parser + the adaptive engine, reproducing the scaling shapes
(linear ViT scaling, extraction FS plateau, Marker's ceiling).

    PYTHONPATH=src python examples/parsing_campaign.py
"""
from repro.core.campaign import CampaignConfig, scaling_curve

cfg = CampaignConfig(n_docs=200_000)
nodes = [1, 4, 16, 64, 128]
print(f"{'parser':14s}" + "".join(f"{n:>10d}" for n in nodes) + "  PDF/s")
for parser in ["pymupdf", "pypdf", "tesseract", "nougat", "marker",
               "adaparse_ft", "adaparse_llm"]:
    kw = {"router_cost_s": 0.002} if parser == "adaparse_llm" else {}
    curve = dict(scaling_curve(parser, nodes, cfg, **kw))
    print(f"{parser:14s}" + "".join(f"{curve[n]:10.1f}" for n in nodes))
print("\npaper anchors: pymupdf ~315 PDF/s @128 (plateau), nougat ~8 @128,")
print("marker ~0.1 avg (10-node ceiling), adaparse 17x nougat @1 node")
