"""Multi-node parsing campaign (Fig 5): (1) analytic scaling simulation
1 -> 128 nodes for every parser + the adaptive engine, reproducing the
scaling shapes (linear ViT scaling, extraction FS plateau, Marker's
ceiling); (2) the REAL multi-node CampaignExecutor on a small corpus,
checking that a heterogeneous fleet — a 3-node CPU ingest pool feeding
a 1-node GPU re-parse pool, with prefetch overlap and a warm result
cache — reproduces the single-node record set exactly; (3) the
round-based adaptive CampaignController on a skewed-speed fleet,
autotuning node_budget_weights from observed throughput (slow nodes
shed shards) while still emitting the identical record set; (4) the
online quality loop (core/quality) on a degrading corpus — an easy
segment followed by a hard scanned segment where the cheap extraction
parser collapses — showing the probe-driven controller climbing α
inside the operator bounds and beating the fixed-α campaign's output
quality; (5) the REAL multi-process worker runtime (core/workers) —
spawned worker processes behind the same executor, heartbeat liveness,
and the byte-identical record set (which is why this script needs the
``__main__`` guard: spawn re-imports the main module); (6) the
cross-machine TCP fabric runtime (core/fabric) over loopback, with the
two-terminal recipe for running the same campaign across real
machines via ``--coordinator`` / ``--connect``.

    PYTHONPATH=src python examples/parsing_campaign.py
"""
import numpy as np

from repro.core import metrics as M
from repro.core.backends import ResultCache, get_backend
from repro.core.campaign import (CampaignConfig, CampaignController,
                                 CampaignExecutor, ControllerConfig,
                                 ExecutorConfig, autotune_convergence_rounds,
                                 scaling_curve)
from repro.core.engine import AdaParseEngine, EngineConfig
from repro.core.quality import QualityProbeConfig, record_hypothesis
from repro.data.synthetic import CorpusConfig, generate_corpus
from repro.launch.serve import build_ft_router

def main():
    cfg = CampaignConfig(n_docs=200_000)
    nodes = [1, 4, 16, 64, 128]
    print(f"{'parser':14s}" + "".join(f"{n:>10d}" for n in nodes) + "  PDF/s")
    for parser in ["pymupdf", "pypdf", "tesseract", "nougat", "marker",
                   "adaparse_ft", "adaparse_llm"]:
        kw = {"router_cost_s": 0.002} if parser == "adaparse_llm" else {}
        curve = dict(scaling_curve(parser, nodes, cfg, **kw))
        print(f"{parser:14s}" + "".join(f"{curve[n]:10.1f}" for n in nodes))
    print("\npaper anchors: pymupdf ~315 PDF/s @128 (plateau), nougat ~8 @128,")
    print("marker ~0.1 avg (10-node ceiling), adaparse 17x nougat @1 node")

    # -- real executor: heterogeneous pools + prefetch + result cache -----------
    # pymupdf ingest runs on the CPU pool, Nougat re-parses forward to the
    # GPU node (backend metadata decides which pool serves which stage)
    ccfg = CorpusConfig(n_docs=360, seed=0)
    docs = generate_corpus(ccfg)
    router = build_ft_router(docs[:120], ccfg, np.random.RandomState(1))
    ecfg = EngineConfig(alpha=0.05, batch_size=32)
    single = AdaParseEngine(ecfg, router, ccfg).run(docs[120:])
    pools = ["cpu", "cpu", "cpu", "gpu"]
    print(f"\npools: {pools}  "
          f"(cheap={ecfg.cheap}/{get_backend(ecfg.cheap).info.device}, "
          f"expensive={ecfg.expensive}/{get_backend(ecfg.expensive).info.device})")
    executor = CampaignExecutor(
        ecfg, ExecutorConfig(n_nodes=4, node_pools=pools, prefetch_depth=2),
        router, ccfg)
    cache = ResultCache()
    for label in ("cold", "warm"):
        res = executor.run(docs[120:], cache=cache)
        same = (set(res.records) == set(single) and
                all(res.records[i].parser == single[i].parser for i in single))
        print(f"executor[{label}]: wall={res.wall_s:.1f}s "
              f"docs/s={res.docs_per_s:.1f} busy={res.node_busy_frac:.2f} "
              f"reissued={res.reissued} "
              f"cache={res.cache_hits}h/{res.cache_misses}m "
              f"identical-to-single-node={same}")

    # -- adaptive controller: online-autotuned budget weights --------------------
    # 4 homogeneous-pool nodes, one simulated 4x slower; the controller
    # dispatches in rounds and feeds measured per-node throughput (EWMA)
    # back into the shard weights — no operator tuning, identical records
    ecfg_a = EngineConfig(alpha=0.05, batch_size=8)
    single_a = AdaParseEngine(ecfg_a, router, ccfg).run(docs[120:])
    xcfg_a = ExecutorConfig(n_nodes=4, straggler_rate=0.0,
                            node_speed_factors=[1.0, 1.0, 1.0, 4.0])
    static = CampaignExecutor(ecfg_a, xcfg_a, router, ccfg).run(docs[120:])
    adaptive = CampaignController(ecfg_a, xcfg_a,
                                  ControllerConfig(rounds=5, ewma=0.4),
                                  router, ccfg).run(docs[120:])
    same = (set(adaptive.records) == set(single_a) and
            all(adaptive.records[i].parser == single_a[i].parser
                for i in single_a))
    w0, w1 = adaptive.weight_history[0], adaptive.weight_history[-1]
    print(f"\nadaptive controller (node 3 is 4x slower):")
    print(f"  weights {['%.2f' % w for w in w0]} -> "
          f"{['%.2f' % w for w in w1]} "
          f"(converged in {autotune_convergence_rounds(adaptive.weight_history)}"
          f"/{adaptive.rounds} rounds)")
    print(f"  wall: static={static.wall_s:.2f}s adaptive={adaptive.wall_s:.2f}s "
          f"({static.wall_s / adaptive.wall_s:.2f}x) "
          f"identical-to-single-node={same}")

    # -- online quality loop: α retuning on a degrading corpus -------------------
    # the campaign parses an easy segment, then an equally long hard/scanned
    # segment where pymupdf's extraction collapses (Fig. 3 crossing). The
    # QualityProbe scores every batch (deterministic batch-keyed sampling),
    # per-parser EWMAs accumulate in the QualityMonitor, and at round
    # boundaries the controller climbs α inside the operator bounds toward
    # the quality target — the fixed-α campaign keeps parsing the hard tail
    # cheaply and pays for it in output quality
    ccfg_q = CorpusConfig(n_docs=700, seed=0)
    docs_q = generate_corpus(ccfg_q)
    router_q = build_ft_router(docs_q[:96], ccfg_q, np.random.RandomState(1))
    by_difficulty = sorted(docs_q[96:], key=lambda d: d.difficulty)
    degrading = by_difficulty[:160] + by_difficulty[-160:]


    def corpus_bleu_of(records):
        refs = [d.full_text() for d in degrading]
        hyps = [record_hypothesis(records[d.doc_id]) for d in degrading]
        return float(np.mean(M.score_batch(refs, hyps, max_len=256,
                                           metrics=("bleu",))["bleu"]))


    ecfg_q = EngineConfig(alpha=0.05, batch_size=16)
    xcfg_q = ExecutorConfig(n_nodes=2, straggler_rate=0.0)
    fixed = CampaignExecutor(ecfg_q, xcfg_q, router_q, ccfg_q).run(degrading)
    ctl_q = ControllerConfig(
        rounds=8, alpha_bounds=(0.05, 0.9), alpha_step=0.3,
        quality_target=0.5, quality_ewma=1.0,
        probe=QualityProbeConfig(probe_rate=1.0, max_len=192))
    retuned = CampaignController(ecfg_q, xcfg_q, ctl_q, router_q,
                                 ccfg_q).run(degrading)
    print("\nquality retuning (easy segment, then hard scanned segment):")
    print("  round  alpha  decision   quality EWMAs")
    for r, t in enumerate(retuned.telemetry):
        q = " ".join(f"{p}={v:.2f}" for p, v in sorted(t.quality.items()))
        print(f"  {r:5d}  {t.alpha:5.2f}  {t.decision:9s}  {q}")
    bleu_fixed = corpus_bleu_of(fixed.records)
    bleu_retuned = corpus_bleu_of(retuned.records)
    print(f"  corpus BLEU: fixed-alpha={bleu_fixed:.3f} "
          f"retuned={bleu_retuned:.3f} ({bleu_retuned / bleu_fixed:.2f}x, "
          f"alpha {retuned.alpha_trajectory[0]:.2f} -> "
          f"{retuned.alpha_trajectory[-1]:.2f} within bounds "
          f"{ctl_q.alpha_bounds})")

    # -- real worker processes: the same campaign on the spawn runtime ------
    # two OS processes, each with its own engine rebuilt from the
    # serialized spec; stragglers detected by heartbeat deadline, and
    # the record set still byte-identical to the single-node run
    xcfg_w = ExecutorConfig(n_nodes=2, runtime="process",
                            prefetch_depth=2)
    mp_res = CampaignExecutor(ecfg, xcfg_w, router, ccfg).run(docs[120:])
    same = (set(mp_res.records) == set(single) and
            all(mp_res.records[i].parser == single[i].parser
                and mp_res.records[i].cost_s == single[i].cost_s
                for i in single))
    print(f"\nworker runtime (2 real processes): "
          f"wall={mp_res.wall_s:.2f}s docs/s={mp_res.docs_per_s:.0f} "
          f"busy={mp_res.node_busy_frac:.2f} "
          f"identical-to-single-node={same}")

    # -- elastic TCP fabric: the same campaign across machines ---------------
    # the fabric runtime (core/fabric) carries the identical messages over
    # length-prefixed TCP streams, with elastic membership: workers dial
    # the coordinator, present a WorkerSpec fingerprint, and join or leave
    # mid-campaign without touching the record set. Loopback here (the
    # fleet self-spawns); across real machines it is two terminals:
    #
    #   terminal 1 (coordinator — owns the campaign, waits for dialers):
    #     PYTHONPATH=src python -m repro.launch.serve \
    #         --fabric-workers 2 --coordinator 0.0.0.0:7777 \
    #         --docs 240 --batch-size 16
    #   terminal 2..N (each extra machine — a standalone worker; a
    #   mismatched fingerprint is rejected with the differing field):
    #     PYTHONPATH=src python -m repro.launch.serve --connect HOST:7777
    xcfg_f = ExecutorConfig(n_nodes=2, runtime="fabric", prefetch_depth=2,
                            heartbeat_timeout_s=30.0)
    fb_res = CampaignExecutor(ecfg, xcfg_f, router, ccfg).run(docs[120:])
    same = (set(fb_res.records) == set(single) and
            all(fb_res.records[i].parser == single[i].parser
                and fb_res.records[i].cost_s == single[i].cost_s
                for i in single))
    print(f"\nfabric runtime (2 TCP workers over loopback): "
          f"wall={fb_res.wall_s:.2f}s docs/s={fb_res.docs_per_s:.0f} "
          f"identical-to-single-node={same}")


if __name__ == "__main__":
    main()
