"""Multi-node parsing-campaign simulator (Fig. 5 + §7.3).

Models an L-node cluster: per-node work queues over document batches,
per-parser node throughput, warm-start costs, shared-filesystem bandwidth
contention (the PyMuPDF/pypdf plateau), Marker's scale ceiling, straggler
injection + re-issue, and the per-node α budget (the partition argument of
§4.1: node budgets sum to the campaign budget, so scheduling stays
embarrassingly parallel)."""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import parsers as P


@dataclasses.dataclass
class CampaignConfig:
    n_nodes: int = 128
    n_docs: int = 100_000
    fs_bandwidth_Bps: float = 650e9     # Eagle Lustre aggregate
    fs_share: float = 0.001             # campaign's share of aggregate BW
    straggler_rate: float = 0.005       # per-batch probability
    straggler_slowdown: float = 4.0
    deadline_factor: float = 2.5        # re-issue if > factor * mean batch
    batch_size: int = 256
    seed: int = 0


@dataclasses.dataclass
class CampaignResult:
    wall_s: float
    docs_per_s: float
    node_busy_frac: float
    reissued: int


def simulate_parser_campaign(parser: str, cfg: CampaignConfig,
                             alpha: float | None = None,
                             router_cost_s: float = 0.0,
                             cheap: str = P.CHEAP_PARSER,
                             expensive: str = P.EXPENSIVE_PARSER
                             ) -> CampaignResult:
    """Simulate a campaign. ``parser`` is a fleet name or "adaparse_ft" /
    "adaparse_llm" (α-budget two-parser mix)."""
    rng = np.random.RandomState(cfg.seed)
    adaptive = parser.startswith("adaparse")
    if adaptive:
        a = 0.05 if alpha is None else alpha
        t_doc = ((1 - a) / P.PARSER_SPECS[cheap].pdf_per_sec_node
                 + a / P.PARSER_SPECS[expensive].pdf_per_sec_node
                 + router_cost_s)
        warm = P.PARSER_SPECS[expensive].warmup_s
        io_doc = P.PARSER_SPECS[cheap].io_bytes_per_doc
        cap_nodes = 10 ** 9
    else:
        spec = P.PARSER_SPECS[parser]
        t_doc = 1.0 / spec.pdf_per_sec_node
        warm = spec.warmup_s
        io_doc = spec.io_bytes_per_doc
        cap_nodes = spec.scale_cap_nodes

    eff_nodes = min(cfg.n_nodes, cap_nodes)
    n_batches = max(cfg.n_docs // cfg.batch_size, 1)
    batch_t = t_doc * cfg.batch_size
    # shared-FS ceiling: bytes/s this campaign may draw
    fs_Bps = cfg.fs_bandwidth_Bps * cfg.fs_share
    io_batch_t = io_doc * cfg.batch_size / fs_Bps * cfg.n_nodes
    # node clocks
    clocks = np.full(eff_nodes, warm, np.float64)
    reissued = 0
    mean_batch = batch_t + io_batch_t
    for _ in range(n_batches):
        i = int(np.argmin(clocks))
        dur = batch_t + io_batch_t
        if rng.rand() < cfg.straggler_rate:
            dur_straggle = dur * cfg.straggler_slowdown
            if dur_straggle > cfg.deadline_factor * mean_batch:
                # re-issue on the next-fastest node after the deadline
                reissued += 1
                clocks[i] += cfg.deadline_factor * mean_batch
                j = int(np.argmin(clocks))
                clocks[j] += dur
                continue
            dur = dur_straggle
        clocks[i] += dur
    wall = float(np.max(clocks))
    busy = float(np.sum(clocks - warm) / (eff_nodes * wall))
    return CampaignResult(wall, cfg.n_docs / wall, busy, reissued)


def scaling_curve(parser: str, node_counts, cfg: CampaignConfig,
                  **kw) -> list[tuple[int, float]]:
    out = []
    for n in node_counts:
        c = dataclasses.replace(cfg, n_nodes=n,
                                n_docs=max(cfg.n_docs, n * 2048))
        out.append((n, simulate_parser_campaign(parser, c, **kw).docs_per_s))
    return out
