"""Multi-node parsing campaigns (Fig. 5 + §7.3): real executor + simulator.

``CampaignExecutor`` runs a *real* ``AdaParseEngine`` per node over
shards of the global batch sequence: per-node work queues, per-node
warm-start, straggler re-issue of actual batches to the fastest idle
node, and per-node α budgets that partition the campaign budget (the
§4.1 argument: node budgets sum to the campaign budget, so scheduling
stays embarrassingly parallel and node-local).

The executor is built on the parser-backend runtime (core/backends):

- **Heterogeneous pools** (``ExecutorConfig.node_pools``): nodes are
  partitioned by device; batches shard over the pool matching the cheap
  backend's device (the ingest pool runs prepare + route), and the
  expensive re-parse of each routed batch is forwarded to the
  least-loaded node of the pool matching the expensive backend's device
  (cheap CPU heuristics next to GPU models — the paper's
  resource-scaling axis).
- **Prefetch overlap** (``ExecutorConfig.prefetch_depth``): each ingest
  node streams its queue through ``data/pipeline.Prefetcher`` so the
  host channel application of the next batch overlaps the
  routing/re-parse of the current one.
- **Result cache** (``backends.ResultCache`` passed to ``run``): batches
  already parsed in a prior campaign are replayed instead of re-parsed;
  hit/miss counters land in ``ExecutorResult``.
- **Speed-weighted sharding**: ``node_budget_weights`` skews both the
  expensive-parse budget *and* the shard sizes toward faster nodes
  (uniform round-robin by default).

Batch rng streams are keyed by the batch's *global* index
(engine.process_batch batch_key) and carried from prepare into
complete, so an N-node campaign — pooled, prefetched, cached,
re-issued, or all of the above — produces exactly the record set of a
single-node run over the same corpus.

``simulate_parser_campaign`` remains the analytic fast path: per-backend
node throughput, warm-start costs, shared-filesystem bandwidth contention
(the PyMuPDF/pypdf plateau), Marker's scale ceiling, and straggler
injection + re-issue, all in closed-form cost arithmetic (used by the
scaling benchmarks, where running 128 real engines would be pointless).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import backends as B
from repro.core import scheduler
from repro.core.engine import AdaParseEngine, EngineConfig, ParseRecord
from repro.data.pipeline import BatchSource, Prefetcher


@dataclasses.dataclass
class CampaignConfig:
    n_nodes: int = 128
    n_docs: int = 100_000
    fs_bandwidth_Bps: float = 650e9     # Eagle Lustre aggregate
    fs_share: float = 0.001             # campaign's share of aggregate BW
    straggler_rate: float = 0.005       # per-batch probability
    straggler_slowdown: float = 4.0
    deadline_factor: float = 2.5        # re-issue if > factor * mean batch
    batch_size: int = 256
    seed: int = 0


@dataclasses.dataclass
class CampaignResult:
    wall_s: float
    docs_per_s: float
    node_busy_frac: float
    reissued: int


def simulate_parser_campaign(parser: str, cfg: CampaignConfig,
                             alpha: float | None = None,
                             router_cost_s: float = 0.0,
                             cheap: str | None = None,
                             expensive: str | None = None
                             ) -> CampaignResult:
    """Simulate a campaign. ``parser`` is a backend name or "adaparse_ft" /
    "adaparse_llm" (α-budget two-parser mix)."""
    from repro.core import parsers as P

    rng = np.random.RandomState(cfg.seed)
    adaptive = parser.startswith("adaparse")
    if adaptive:
        cheap_info = B.get_backend(cheap or P.CHEAP_PARSER).info
        exp_info = B.get_backend(expensive or P.EXPENSIVE_PARSER).info
        a = 0.05 if alpha is None else alpha
        t_doc = ((1 - a) / cheap_info.pdf_per_sec_node
                 + a / exp_info.pdf_per_sec_node
                 + router_cost_s)
        warm = exp_info.warm_start_s
        io_doc = cheap_info.io_bytes_per_doc
        cap_nodes = 10 ** 9
    else:
        info = B.get_backend(parser).info
        t_doc = 1.0 / info.pdf_per_sec_node
        warm = info.warm_start_s
        io_doc = info.io_bytes_per_doc
        cap_nodes = info.scale_cap_nodes

    eff_nodes = min(cfg.n_nodes, cap_nodes)
    n_batches = max(cfg.n_docs // cfg.batch_size, 1)
    batch_t = t_doc * cfg.batch_size
    # shared-FS ceiling: bytes/s this campaign may draw
    fs_Bps = cfg.fs_bandwidth_Bps * cfg.fs_share
    io_batch_t = io_doc * cfg.batch_size / fs_Bps * cfg.n_nodes
    # node clocks
    clocks = np.full(eff_nodes, warm, np.float64)
    reissued = 0
    mean_batch = batch_t + io_batch_t
    for _ in range(n_batches):
        i = int(np.argmin(clocks))
        dur = batch_t + io_batch_t
        if rng.rand() < cfg.straggler_rate:
            dur_straggle = dur * cfg.straggler_slowdown
            if dur_straggle > cfg.deadline_factor * mean_batch:
                # re-issue on the next-fastest node after the deadline
                reissued += 1
                clocks[i] += cfg.deadline_factor * mean_batch
                j = int(np.argmin(clocks))
                clocks[j] += dur
                continue
            dur = dur_straggle
        clocks[i] += dur
    wall = float(np.max(clocks))
    busy = float(np.sum(clocks - warm) / (eff_nodes * wall))
    return CampaignResult(wall, cfg.n_docs / wall, busy, reissued)


# ---------------------------------------------------------------------------
# Real multi-node executor
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ExecutorConfig:
    n_nodes: int = 2
    straggler_rate: float = 0.01        # per-batch hang probability
    straggler_slowdown: float = 4.0
    deadline_factor: float = 2.5        # re-issue if > factor * mean batch
    seed: int = 0
    # relative per-node budget weights (len n_nodes); None = uniform.
    # Uniform weights recover the campaign alpha on every node (exact
    # single-node record parity); heterogeneous weights give faster
    # nodes a larger share of the expensive-parse budget AND a
    # proportionally larger shard of the corpus (speed-weighted
    # sharding).
    node_budget_weights: list[float] | None = None
    # device per node ("cpu" | "gpu", len n_nodes); None = homogeneous
    # (every node runs the full prepare->route->complete pipeline).
    # With pools, ingest work shards over the nodes matching the cheap
    # backend's device and expensive re-parses are forwarded to the
    # least-loaded node matching the expensive backend's device.
    node_pools: list[str] | None = None
    # >0: each ingest node overlaps the host prepare of upcoming batches
    # with routing/re-parse of the current one (data/pipeline.Prefetcher)
    prefetch_depth: int = 0


@dataclasses.dataclass
class ExecutorResult:
    records: dict[int, ParseRecord]
    wall_s: float
    docs_per_s: float
    node_busy_frac: float
    reissued: int
    node_alphas: list[float]
    node_stats: list                    # per-node EngineStats
    cache_hits: int = 0
    cache_misses: int = 0


def document_shard_source(docs, batch_size: int, shard: int,
                          n_shards: int, seed: int = 0) -> BatchSource:
    """Per-node work queue over the corpus: shard ``shard`` yields the
    global batches ``shard, shard + n_shards, ...`` (round-robin), each
    tagged with its global batch index so any node reproduces the same
    stateless rng stream for it."""

    def fn(step, rng):
        g = step * n_shards + shard
        lo = g * batch_size
        if lo >= len(docs):
            raise StopIteration
        return {"batch_key": g, "docs": docs[lo:lo + batch_size]}

    return BatchSource(fn, seed=seed, shard=shard)


def weighted_shard_batches(n_batches: int,
                           weights: list[float]) -> list[list[int]]:
    """Assign global batch indices to shards so shard sizes follow the
    weights (deficit round-robin: batch g goes to the shard furthest
    below its quota w_i·(g+1)). Uniform weights recover plain
    round-robin, and the assignment is deterministic — batch keys stay
    global, so records are placement-independent."""
    w = np.asarray(weights, np.float64)
    if np.any(w < 0) or w.sum() <= 0:
        raise ValueError("shard weights must be non-negative with a "
                         "positive sum")
    w = w / w.sum()
    assigned = np.zeros(len(w), np.float64)
    shards: list[list[int]] = [[] for _ in w]
    for g in range(n_batches):
        i = int(np.argmax(w * (g + 1) - assigned))
        shards[i].append(g)
        assigned[i] += 1.0
    return shards


class CampaignExecutor:
    """Run a real engine per node over shards of the batch sequence.

    The campaign α-budget T̄ = K·((1−α)·T_cheap + α·T_exp) is partitioned
    across ingest nodes proportionally to their shard sizes; each node
    solves its own α_i = alpha_for_budget(T̄_i) (node budgets sum to the
    campaign budget). For homogeneous shards α_i = α exactly (snapped
    against float round-trip), which is what makes the N-node record set
    identical to the single-node run."""

    def __init__(self, ecfg: EngineConfig, xcfg: ExecutorConfig, router,
                 corpus_cfg, image_degraded=False, text_degraded=False):
        self.ecfg = ecfg
        self.xcfg = xcfg
        self.router = router
        self.ccfg = corpus_cfg
        self.image_degraded = image_degraded
        self.text_degraded = text_degraded

    def _node_alphas(self, shard_sizes: list[int],
                     weights: list[float] | None) -> list[float]:
        """Partition the campaign budget T̄ = K·((1−α)T_c + α·T_e) into
        per-node budgets T̄_i and solve each node's α_i. Budget shares
        follow ``weights`` (scaled by shard size); with uniform weights
        every α_i is exactly the campaign α."""
        a = self.ecfg.alpha
        n = len(shard_sizes)
        if weights is None:
            # uniform partition ≡ campaign alpha on every node; skip the
            # round-trip so record parity with a single-node run is exact
            return [a] * n
        t_c = 1.0 / B.get_backend(self.ecfg.cheap).info.pdf_per_sec_node
        t_e = 1.0 / B.get_backend(self.ecfg.expensive).info.pdf_per_sec_node
        total_budget = sum(shard_sizes) * ((1 - a) * t_c + a * t_e)
        shares = np.asarray(weights, np.float64) * np.asarray(
            shard_sizes, np.float64)
        shares = shares / max(shares.sum(), 1e-12)
        return [
            scheduler.alpha_for_budget(float(total_budget * s), k_i, t_c,
                                       t_e) if k_i else a
            for s, k_i in zip(shares, shard_sizes)]

    def run(self, docs, cache: B.ResultCache | None = None
            ) -> ExecutorResult:
        bs = self.ecfg.batch_size
        n_batches = max(-(-len(docs) // bs), 1)
        pools = self.xcfg.node_pools
        if pools is None:
            n_nodes = max(min(self.xcfg.n_nodes, n_batches), 1)
            ingest_nodes = list(range(n_nodes))
            reparse_nodes = ingest_nodes
        else:
            n_nodes = self.xcfg.n_nodes
            if len(pools) != n_nodes:
                raise ValueError(f"need {n_nodes} node pool entries, got "
                                 f"{len(pools)}")
            cheap_dev = B.get_backend(self.ecfg.cheap).info.device
            exp_dev = B.get_backend(self.ecfg.expensive).info.device
            all_nodes = list(range(n_nodes))
            ingest_nodes = [i for i in all_nodes
                            if pools[i] == cheap_dev] or all_nodes
            reparse_nodes = [i for i in all_nodes
                             if pools[i] == exp_dev] or all_nodes

        w = self.xcfg.node_budget_weights
        if w is not None and len(w) != n_nodes:
            raise ValueError(f"need {n_nodes} node weights, got {len(w)}")
        ingest_w = [w[i] for i in ingest_nodes] if w is not None else None
        if ingest_w is None:
            queues = {
                node: list(document_shard_source(docs, bs, j,
                                                 len(ingest_nodes),
                                                 seed=self.ecfg.seed))
                for j, node in enumerate(ingest_nodes)}
        else:
            shards = weighted_shard_batches(n_batches, ingest_w)
            queues = {
                node: [{"batch_key": g, "docs": docs[g * bs:(g + 1) * bs]}
                       for g in shard]
                for node, shard in zip(ingest_nodes, shards)}
        alphas = self._node_alphas(
            [sum(len(b["docs"]) for b in queues[i]) for i in ingest_nodes],
            ingest_w)
        alpha_of = {node: a for node, a in zip(ingest_nodes, alphas)}
        engines = [
            AdaParseEngine(
                dataclasses.replace(self.ecfg,
                                    alpha=alpha_of.get(i, self.ecfg.alpha)),
                self.router, self.ccfg,
                image_degraded=self.image_degraded,
                text_degraded=self.text_degraded, cache=cache)
            for i in range(n_nodes)]

        rng = np.random.RandomState(self.xcfg.seed)
        clocks = np.zeros(n_nodes, np.float64)
        records: dict[int, ParseRecord] = {}
        reissued = 0
        mean_batch = 0.0
        n_done = 0
        heads = {node: 0 for node in ingest_nodes}
        hits0 = cache.hits if cache is not None else 0
        miss0 = cache.misses if cache is not None else 0

        def _make_prep(eng):
            return lambda batch: eng.prepare_or_lookup(
                batch["docs"], batch_key=batch["batch_key"])

        streams = {}
        if self.xcfg.prefetch_depth > 0:
            streams = {
                node: Prefetcher(iter(queues[node]),
                                 depth=self.xcfg.prefetch_depth,
                                 transform=_make_prep(engines[node]))
                for node in ingest_nodes}

        def execute(node, batch, prep_item=None, use_cache=True):
            """Full pipeline for one batch: prepare+route on ``node``,
            complete on the reparse pool. Returns (records, ingest_dur,
            reparse_dur, reparse_node). ``use_cache=False`` (straggler
            re-issue) forces a real re-parse: the abandoned attempt has
            already stored this key, and replaying it would model the
            re-issued work as free."""
            eng = engines[node]
            if prep_item is None:
                key, prep, cached = eng.prepare_or_lookup(
                    batch["docs"], batch_key=batch["batch_key"],
                    use_cache=use_cache)
            else:
                key, prep, cached = prep_item
            if cached is not None:
                eng._account_cache_hit(cached)
                return cached, 0.0, 0.0, node
            plan = eng.route_batch(prep)
            # forward the re-parse to the matching pool only when there is
            # re-parse work; otherwise finish locally
            g = (node if (pools is None or plan.expensive_idx.size == 0)
                 else min(reparse_nodes, key=lambda i: clocks[i]))
            geng = engines[g]
            ingest_dur = (prep.ingest_cost_s
                          + eng.cfg.router_cost_s * len(prep.docs))
            before = eng.stats.node_seconds + (
                geng.stats.node_seconds if geng is not eng else 0.0)
            recs = geng.complete_batch(prep, plan, node_id=g,
                                       ingest_engine=eng)
            after = eng.stats.node_seconds + (
                geng.stats.node_seconds if geng is not eng else 0.0)
            reparse_dur = (after - before) - ingest_dur
            if key is not None:
                eng.cache.store(key, recs)
            return recs, ingest_dur, reparse_dur, g

        def advance(node, ing, rep, g):
            clocks[node] += ing
            if g == node:
                clocks[node] += rep
            else:
                # the reparse node picks the batch up when both it and
                # the ingest hand-off are ready
                clocks[g] = max(clocks[g], clocks[node]) + rep

        try:
            while True:
                # work-conserving dispatch: fastest node with work goes next
                ready = [i for i in ingest_nodes
                         if heads[i] < len(queues[i])]
                if not ready:
                    break
                node = min(ready, key=lambda i: clocks[i])
                batch = queues[node][heads[node]]
                heads[node] += 1
                prep_item = (next(streams[node]) if node in streams
                             else None)
                recs, ing, rep, g = execute(node, batch, prep_item)
                dur = ing + rep
                if rng.rand() < self.xcfg.straggler_rate and n_done:
                    hung = dur * self.xcfg.straggler_slowdown
                    deadline = self.xcfg.deadline_factor * mean_batch
                    if hung > deadline and len(ingest_nodes) > 1:
                        # give up on the hung task at the deadline and
                        # re-issue the ACTUAL batch to the fastest idle
                        # ingest node; same batch_key -> identical records.
                        # Both attempts performed real work, so both stay
                        # charged in the per-node EngineStats.
                        reissued += 1
                        clocks[node] += deadline
                        other = min((i for i in ingest_nodes if i != node),
                                    key=lambda i: clocks[i])
                        recs, ing, rep, g = execute(other, batch,
                                                    use_cache=False)
                        advance(other, ing, rep, g)
                        engines[other].stats.reissued_tasks += 1
                        dur = ing + rep
                    else:
                        advance(node, ing * self.xcfg.straggler_slowdown,
                                rep * self.xcfg.straggler_slowdown, g)
                else:
                    advance(node, ing, rep, g)
                for r in recs:
                    records[r.doc_id] = r
                n_done += 1
                mean_batch += (dur - mean_batch) / n_done
        finally:
            for pf in streams.values():
                pf.close()
        wall = float(clocks.max()) if len(docs) else 0.0
        busy = (float(clocks.sum()) / (n_nodes * wall)) if wall else 0.0
        node_alphas = [alpha_of.get(i, self.ecfg.alpha)
                       for i in range(n_nodes)]
        return ExecutorResult(
            records, wall, len(docs) / wall if wall else 0.0, busy,
            reissued, node_alphas, [e.stats for e in engines],
            cache_hits=(cache.hits - hits0) if cache is not None else 0,
            cache_misses=(cache.misses - miss0) if cache is not None else 0)


def scaling_curve(parser: str, node_counts, cfg: CampaignConfig,
                  **kw) -> list[tuple[int, float]]:
    out = []
    for n in node_counts:
        c = dataclasses.replace(cfg, n_nodes=n,
                                n_docs=max(cfg.n_docs, n * 2048))
        out.append((n, simulate_parser_campaign(parser, c, **kw).docs_per_s))
    return out
