"""Multi-node parsing campaigns (Fig. 5 + §7.3): real executor + simulator.

``CampaignExecutor`` runs a *real* ``AdaParseEngine`` per node over
``data/pipeline.BatchSource`` shards: per-node work queues, per-node
warm-start, straggler re-issue of actual batches to the fastest idle
node, and per-node α budgets that partition the campaign budget (the
§4.1 argument: node budgets sum to the campaign budget, so scheduling
stays embarrassingly parallel and node-local). Batch rng streams are
keyed by the batch's *global* index (engine.process_batch batch_key), so
an N-node campaign — including re-issued batches — produces exactly the
record set of a single-node run over the same corpus.

``simulate_parser_campaign`` remains the analytic fast path: per-parser
node throughput, warm-start costs, shared-filesystem bandwidth contention
(the PyMuPDF/pypdf plateau), Marker's scale ceiling, and straggler
injection + re-issue, all in closed-form cost arithmetic (used by the
scaling benchmarks, where running 128 real engines would be pointless).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import parsers as P
from repro.core import scheduler
from repro.core.engine import AdaParseEngine, EngineConfig, ParseRecord
from repro.data.pipeline import BatchSource


@dataclasses.dataclass
class CampaignConfig:
    n_nodes: int = 128
    n_docs: int = 100_000
    fs_bandwidth_Bps: float = 650e9     # Eagle Lustre aggregate
    fs_share: float = 0.001             # campaign's share of aggregate BW
    straggler_rate: float = 0.005       # per-batch probability
    straggler_slowdown: float = 4.0
    deadline_factor: float = 2.5        # re-issue if > factor * mean batch
    batch_size: int = 256
    seed: int = 0


@dataclasses.dataclass
class CampaignResult:
    wall_s: float
    docs_per_s: float
    node_busy_frac: float
    reissued: int


def simulate_parser_campaign(parser: str, cfg: CampaignConfig,
                             alpha: float | None = None,
                             router_cost_s: float = 0.0,
                             cheap: str = P.CHEAP_PARSER,
                             expensive: str = P.EXPENSIVE_PARSER
                             ) -> CampaignResult:
    """Simulate a campaign. ``parser`` is a fleet name or "adaparse_ft" /
    "adaparse_llm" (α-budget two-parser mix)."""
    rng = np.random.RandomState(cfg.seed)
    adaptive = parser.startswith("adaparse")
    if adaptive:
        a = 0.05 if alpha is None else alpha
        t_doc = ((1 - a) / P.PARSER_SPECS[cheap].pdf_per_sec_node
                 + a / P.PARSER_SPECS[expensive].pdf_per_sec_node
                 + router_cost_s)
        warm = P.PARSER_SPECS[expensive].warmup_s
        io_doc = P.PARSER_SPECS[cheap].io_bytes_per_doc
        cap_nodes = 10 ** 9
    else:
        spec = P.PARSER_SPECS[parser]
        t_doc = 1.0 / spec.pdf_per_sec_node
        warm = spec.warmup_s
        io_doc = spec.io_bytes_per_doc
        cap_nodes = spec.scale_cap_nodes

    eff_nodes = min(cfg.n_nodes, cap_nodes)
    n_batches = max(cfg.n_docs // cfg.batch_size, 1)
    batch_t = t_doc * cfg.batch_size
    # shared-FS ceiling: bytes/s this campaign may draw
    fs_Bps = cfg.fs_bandwidth_Bps * cfg.fs_share
    io_batch_t = io_doc * cfg.batch_size / fs_Bps * cfg.n_nodes
    # node clocks
    clocks = np.full(eff_nodes, warm, np.float64)
    reissued = 0
    mean_batch = batch_t + io_batch_t
    for _ in range(n_batches):
        i = int(np.argmin(clocks))
        dur = batch_t + io_batch_t
        if rng.rand() < cfg.straggler_rate:
            dur_straggle = dur * cfg.straggler_slowdown
            if dur_straggle > cfg.deadline_factor * mean_batch:
                # re-issue on the next-fastest node after the deadline
                reissued += 1
                clocks[i] += cfg.deadline_factor * mean_batch
                j = int(np.argmin(clocks))
                clocks[j] += dur
                continue
            dur = dur_straggle
        clocks[i] += dur
    wall = float(np.max(clocks))
    busy = float(np.sum(clocks - warm) / (eff_nodes * wall))
    return CampaignResult(wall, cfg.n_docs / wall, busy, reissued)


# ---------------------------------------------------------------------------
# Real multi-node executor
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ExecutorConfig:
    n_nodes: int = 2
    straggler_rate: float = 0.01        # per-batch hang probability
    straggler_slowdown: float = 4.0
    deadline_factor: float = 2.5        # re-issue if > factor * mean batch
    seed: int = 0
    # relative per-node budget weights (len n_nodes); None = uniform.
    # Uniform weights recover the campaign alpha on every node (exact
    # single-node record parity); heterogeneous weights give faster
    # nodes a larger share of the expensive-parse budget.
    node_budget_weights: list[float] | None = None


@dataclasses.dataclass
class ExecutorResult:
    records: dict[int, ParseRecord]
    wall_s: float
    docs_per_s: float
    node_busy_frac: float
    reissued: int
    node_alphas: list[float]
    node_stats: list                    # per-node EngineStats


def document_shard_source(docs, batch_size: int, shard: int,
                          n_shards: int, seed: int = 0) -> BatchSource:
    """Per-node work queue over the corpus: shard ``shard`` yields the
    global batches ``shard, shard + n_shards, ...`` (round-robin), each
    tagged with its global batch index so any node reproduces the same
    stateless rng stream for it."""

    def fn(step, rng):
        g = step * n_shards + shard
        lo = g * batch_size
        if lo >= len(docs):
            raise StopIteration
        return {"batch_key": g, "docs": docs[lo:lo + batch_size]}

    return BatchSource(fn, seed=seed, shard=shard)


class CampaignExecutor:
    """Run a real engine per node over BatchSource shards.

    The campaign α-budget T̄ = K·((1−α)·T_cheap + α·T_exp) is partitioned
    across nodes proportionally to their shard sizes; each node solves
    its own α_i = alpha_for_budget(T̄_i) (node budgets sum to the campaign
    budget). For homogeneous shards α_i = α exactly (snapped against
    float round-trip), which is what makes the N-node record set identical
    to the single-node run."""

    def __init__(self, ecfg: EngineConfig, xcfg: ExecutorConfig, router,
                 corpus_cfg, image_degraded=False, text_degraded=False):
        self.ecfg = ecfg
        self.xcfg = xcfg
        self.router = router
        self.ccfg = corpus_cfg
        self.image_degraded = image_degraded
        self.text_degraded = text_degraded

    def _node_alphas(self, shard_sizes: list[int]) -> list[float]:
        """Partition the campaign budget T̄ = K·((1−α)T_c + α·T_e) into
        per-node budgets T̄_i and solve each node's α_i. Budget shares
        follow ``node_budget_weights`` (scaled by shard size); with
        uniform weights every α_i is exactly the campaign α."""
        a = self.ecfg.alpha
        n = len(shard_sizes)
        w = self.xcfg.node_budget_weights
        if w is None:
            # uniform partition ≡ campaign alpha on every node; skip the
            # round-trip so record parity with a single-node run is exact
            return [a] * n
        if len(w) != n:
            raise ValueError(f"need {n} node weights, got {len(w)}")
        t_c = 1.0 / P.PARSER_SPECS[self.ecfg.cheap].pdf_per_sec_node
        t_e = 1.0 / P.PARSER_SPECS[self.ecfg.expensive].pdf_per_sec_node
        total_budget = sum(shard_sizes) * ((1 - a) * t_c + a * t_e)
        shares = np.asarray(w, np.float64) * np.asarray(shard_sizes,
                                                        np.float64)
        shares = shares / max(shares.sum(), 1e-12)
        return [
            scheduler.alpha_for_budget(float(total_budget * s), k_i, t_c,
                                       t_e) if k_i else a
            for s, k_i in zip(shares, shard_sizes)]

    def run(self, docs) -> ExecutorResult:
        bs = self.ecfg.batch_size
        n_batches = max(-(-len(docs) // bs), 1)
        n_nodes = max(min(self.xcfg.n_nodes, n_batches), 1)
        queues = []
        for node in range(n_nodes):
            src = document_shard_source(docs, bs, node, n_nodes,
                                        seed=self.ecfg.seed)
            queues.append(list(src))
        alphas = self._node_alphas(
            [sum(len(b["docs"]) for b in q) for q in queues])
        engines = [
            AdaParseEngine(dataclasses.replace(self.ecfg, alpha=alphas[i]),
                           self.router, self.ccfg,
                           image_degraded=self.image_degraded,
                           text_degraded=self.text_degraded)
            for i in range(n_nodes)]

        rng = np.random.RandomState(self.xcfg.seed)
        clocks = np.zeros(n_nodes, np.float64)
        records: dict[int, ParseRecord] = {}
        reissued = 0
        mean_batch = 0.0
        n_done = 0
        heads = [0] * n_nodes          # per-queue cursor

        def measured(node, batch):
            before = engines[node].stats.node_seconds
            recs = engines[node].process_batch(batch["docs"], node_id=node,
                                               batch_key=batch["batch_key"])
            return recs, engines[node].stats.node_seconds - before

        while True:
            # work-conserving dispatch: fastest node with work goes next
            ready = [i for i in range(n_nodes) if heads[i] < len(queues[i])]
            if not ready:
                break
            node = min(ready, key=lambda i: clocks[i])
            batch = queues[node][heads[node]]
            heads[node] += 1
            recs, dur = measured(node, batch)
            if rng.rand() < self.xcfg.straggler_rate and n_done:
                hung = dur * self.xcfg.straggler_slowdown
                deadline = self.xcfg.deadline_factor * mean_batch
                if hung > deadline and n_nodes > 1:
                    # give up on the hung task at the deadline and
                    # re-issue the ACTUAL batch to the fastest idle node;
                    # same batch_key -> identical records
                    reissued += 1
                    clocks[node] += deadline
                    other = min((i for i in range(n_nodes) if i != node),
                                key=lambda i: clocks[i])
                    recs, dur = measured(other, batch)
                    clocks[other] += dur
                    engines[other].stats.reissued_tasks += 1
                else:
                    clocks[node] += hung
            else:
                clocks[node] += dur
            for r in recs:
                records[r.doc_id] = r
            n_done += 1
            mean_batch += (dur - mean_batch) / n_done
        wall = float(clocks.max()) if len(docs) else 0.0
        busy = (float(clocks.sum()) / (n_nodes * wall)) if wall else 0.0
        return ExecutorResult(records, wall,
                              len(docs) / wall if wall else 0.0, busy,
                              reissued, alphas,
                              [e.stats for e in engines])


def scaling_curve(parser: str, node_counts, cfg: CampaignConfig,
                  **kw) -> list[tuple[int, float]]:
    out = []
    for n in node_counts:
        c = dataclasses.replace(cfg, n_nodes=n,
                                n_docs=max(cfg.n_docs, n * 2048))
        out.append((n, simulate_parser_campaign(parser, c, **kw).docs_per_s))
    return out
