"""Multi-node parsing campaigns (Fig. 5 + §7.3): real executor,
adaptive controller, and the analytic simulator.

``CampaignExecutor`` runs a *real* ``AdaParseEngine`` per node over
shards of the global batch sequence: per-node work queues, per-node
warm-start, straggler re-issue of actual batches, and per-node α
budgets that partition the campaign budget (the §4.1 argument: node
budgets sum to the campaign budget, so scheduling stays embarrassingly
parallel and node-local).

The executor is built on the parser-backend runtime (core/backends):

- **Heterogeneous pools** (``ExecutorConfig.node_pools``): nodes are
  partitioned by device; batches shard over the pool matching the cheap
  backend's device (the ingest pool runs prepare + route), and the
  expensive re-parse of each routed batch is forwarded to the
  least-loaded node of the pool matching the expensive backend's device
  (cheap CPU heuristics next to GPU models — the paper's
  resource-scaling axis).
- **Pool-aware straggler re-issue** (``scheduler.reissue_candidates``):
  a hung ingest batch re-issues to a peer of the ingest pool; a
  forwarded expensive re-parse stuck on a GPU-pool node re-issues to
  the least-loaded peer *in that pool*, crossing pools only when the
  backend's device allows (CPU work runs anywhere, GPU work cannot
  leave the GPU pool).
- **Prefetch overlap** (``ExecutorConfig.prefetch_depth``): each ingest
  node streams its queue through ``data/pipeline.Prefetcher`` so the
  host channel application of the next batch overlaps the
  routing/re-parse of the current one.
- **Result store** (any ``backends.ResultStore`` passed to ``run``):
  batches already parsed in a prior campaign are replayed instead of
  re-parsed; hit/miss counters land in ``ExecutorResult``. With a
  ``DiskResultStore`` the replay works across process restarts.
- **Speed-weighted sharding**: ``node_budget_weights`` skews both the
  expensive-parse budget *and* the shard sizes toward faster nodes
  (uniform round-robin by default).

``CampaignController`` is the *adaptive* layer on top (the paper's
headline claim — resource scaling that responds to observed throughput,
not operator-set constants): it dispatches the batch sequence in
rounds, reads the per-stage timing telemetry the engines emit
(``engine.BatchTelemetry`` / per-node clocks), maintains an EWMA
throughput estimate per ingest node, and re-derives the shard weights —
and with them the per-node α-budget split, which follows shard sizes —
before every round. Slow nodes shed shards, fast nodes absorb them,
without operator tuning. Because per-node budgets stay proportional to
shard sizes, every node routes at the campaign α, so the adaptive
record set is *identical* to the single-node run no matter how the
weights evolve; replaying a recorded telemetry trace
(``ControllerConfig.telemetry_trace``) additionally pins the weight
trajectory itself.

With ``ControllerConfig.alpha_bounds`` set the controller also closes
the *quality* loop (core/quality): a deterministic batch-keyed
``QualityProbe`` scores sampled batches with the batched jitted
scorers in core/metrics, per-parser EWMAs accumulate in a
``QualityMonitor``, and at round boundaries the campaign α itself
moves — inside the operator bounds, at most ``alpha_step`` per round —
toward the cheapest α that meets ``quality_target``. Every
(round, α, quality) decision is recorded in
``ControllerResult.telemetry`` and replayable, so a recorded retuned
campaign reproduces its α trajectory and record set bit-identically
across restarts; without a trace, divergence is round-granular.

Both the executor and the controller dispatch through one
``workers.WorkerPool``: ``ExecutorConfig.runtime="local"`` (default)
runs the simulated in-process fleet (``workers.LocalWorkerPool``, the
former ``_CampaignRun``), and ``runtime="process"`` backs the same
dispatch with **real OS worker processes**
(``workers.ProcessWorkerPool``: spawn context, one engine per worker
rebuilt from a serialized spec, PrepareTask/CompleteTask/BatchDone/
Heartbeat over multiprocessing queues, heartbeat-deadline straggler
detection and worker-crash recovery with pool-aware re-issue).
``runtime="fabric"`` (``fabric.FabricWorkerPool``) carries the same
message protocol over length-prefixed TCP frames instead: a
coordinator listens on ``ExecutorConfig.coordinator``, workers —
loopback or other machines — dial in and are fingerprint-checked at
admission, membership is elastic (join/leave mid-campaign, the
controller re-shards over the live fleet at round boundaries), and the
inherited dedup + re-issue machinery keeps the record set byte-equal
to the single-node run through any churn.

Batch rng streams are keyed by the batch's *global* index
(engine.process_batch batch_key) and carried from prepare into
complete, so an N-node campaign — pooled, prefetched, cached,
re-issued, crash-recovered, adaptive, or all of the above, in either
runtime — produces exactly the record set of a single-node run over
the same corpus.

``simulate_parser_campaign`` remains the analytic fast path: per-backend
node throughput, warm-start costs, shared-filesystem bandwidth contention
(the PyMuPDF/pypdf plateau), Marker's scale ceiling, and straggler
injection + re-issue, all in closed-form cost arithmetic (used by the
scaling benchmarks, where running 128 real engines would be pointless).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import backends as B
from repro.core import obs
from repro.core import scheduler
from repro.core.engine import AdaParseEngine, EngineConfig, ParseRecord
from repro.core.quality import (QualityMonitor, QualityProbe,
                                QualityProbeConfig, propose_alpha)
from repro.core.workers import (FaultInjection, LocalWorkerPool,  # noqa: F401
                                make_worker_pool)
from repro.data.pipeline import BatchSource, batches_for_indices


@dataclasses.dataclass
class CampaignConfig:
    n_nodes: int = 128
    n_docs: int = 100_000
    fs_bandwidth_Bps: float = 650e9     # Eagle Lustre aggregate
    fs_share: float = 0.001             # campaign's share of aggregate BW
    straggler_rate: float = 0.005       # per-batch probability
    straggler_slowdown: float = 4.0
    deadline_factor: float = 2.5        # re-issue if > factor * mean batch
    batch_size: int = 256
    seed: int = 0


@dataclasses.dataclass
class CampaignResult:
    wall_s: float
    docs_per_s: float
    node_busy_frac: float
    reissued: int


def simulate_parser_campaign(parser: str, cfg: CampaignConfig,
                             alpha: float | None = None,
                             router_cost_s: float = 0.0,
                             cheap: str | None = None,
                             expensive: str | None = None
                             ) -> CampaignResult:
    """Simulate a campaign. ``parser`` is a backend name or "adaparse_ft" /
    "adaparse_llm" (α-budget two-parser mix)."""
    from repro.core import parsers as P

    rng = np.random.RandomState(cfg.seed)
    adaptive = parser.startswith("adaparse")
    if adaptive:
        cheap_info = B.get_backend(cheap or P.CHEAP_PARSER).info
        exp_info = B.get_backend(expensive or P.EXPENSIVE_PARSER).info
        a = 0.05 if alpha is None else alpha
        t_doc = ((1 - a) / cheap_info.pdf_per_sec_node
                 + a / exp_info.pdf_per_sec_node
                 + router_cost_s)
        warm = exp_info.warm_start_s
        io_doc = cheap_info.io_bytes_per_doc
        cap_nodes = 10 ** 9
    else:
        info = B.get_backend(parser).info
        t_doc = 1.0 / info.pdf_per_sec_node
        warm = info.warm_start_s
        io_doc = info.io_bytes_per_doc
        cap_nodes = info.scale_cap_nodes

    eff_nodes = min(cfg.n_nodes, cap_nodes)
    n_batches = max(cfg.n_docs // cfg.batch_size, 1)
    batch_t = t_doc * cfg.batch_size
    # shared-FS ceiling: bytes/s this campaign may draw
    fs_Bps = cfg.fs_bandwidth_Bps * cfg.fs_share
    io_batch_t = io_doc * cfg.batch_size / fs_Bps * cfg.n_nodes
    # node clocks
    clocks = np.full(eff_nodes, warm, np.float64)
    reissued = 0
    mean_batch = batch_t + io_batch_t
    for _ in range(n_batches):
        i = int(np.argmin(clocks))
        dur = batch_t + io_batch_t
        if rng.rand() < cfg.straggler_rate:
            dur_straggle = dur * cfg.straggler_slowdown
            if dur_straggle > cfg.deadline_factor * mean_batch:
                # re-issue on the next-fastest node after the deadline
                reissued += 1
                clocks[i] += cfg.deadline_factor * mean_batch
                j = int(np.argmin(clocks))
                clocks[j] += dur
                continue
            dur = dur_straggle
        clocks[i] += dur
    wall = float(np.max(clocks))
    busy = float(np.sum(clocks - warm) / (eff_nodes * wall))
    return CampaignResult(wall, cfg.n_docs / wall, busy, reissued)


# ---------------------------------------------------------------------------
# Real multi-node executor
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ExecutorConfig:
    n_nodes: int = 2
    straggler_rate: float = 0.01        # per-batch hang probability
    straggler_slowdown: float = 4.0
    deadline_factor: float = 2.5        # re-issue if > factor * mean batch
    seed: int = 0
    # relative per-node budget weights (len n_nodes); None = uniform.
    # Uniform weights recover the campaign alpha on every node (exact
    # single-node record parity); heterogeneous weights give faster
    # nodes a larger share of the expensive-parse budget AND a
    # proportionally larger shard of the corpus (speed-weighted
    # sharding).
    node_budget_weights: list[float] | None = None
    # device per node ("cpu" | "gpu", len n_nodes); None = homogeneous
    # (every node runs the full prepare->route->complete pipeline).
    # With pools, ingest work shards over the nodes matching the cheap
    # backend's device and expensive re-parses are forwarded to the
    # least-loaded node matching the expensive backend's device.
    node_pools: list[str] | None = None
    # >0: each ingest node overlaps the host prepare of upcoming batches
    # with routing/re-parse of the current one (data/pipeline.Prefetcher)
    prefetch_depth: int = 0
    # simulation-only per-node slowdown multipliers (len n_nodes, > 0;
    # 4.0 = node runs 4x slower). Scales the simulated clocks — and
    # therefore the telemetry the adaptive controller observes — but
    # never the records (batch rng streams are placement-independent).
    node_speed_factors: list[float] | None = None
    # --- worker runtime (core/workers) ---
    # "local": the in-process simulated fleet (LocalWorkerPool —
    # injected stragglers, simulated clocks/speed factors).
    # "process": real OS worker processes (ProcessWorkerPool — spawn
    # context, one engine per worker, heartbeat-deadline straggler
    # detection, worker-crash recovery). straggler_rate /
    # straggler_slowdown / deadline_factor / node_speed_factors are
    # simulation-only and ignored (or rejected) by the process runtime.
    # "fabric": the cross-machine socket runtime (core/fabric —
    # FabricWorkerPool): a coordinator listens on `coordinator` and
    # workers dial in over TCP with elastic membership (join / leave /
    # admission-rejected mid-campaign), same dedup + re-issue brain as
    # the process runtime, payloads inline (no shm across machines).
    runtime: str = "local"
    # fabric runtime: the coordinator's listen address as HOST:PORT
    # (port 0 = auto-bind an ephemeral port; the pool exposes the bound
    # address as `pool.addr` for workers to dial)
    coordinator: str = "127.0.0.1:0"
    # fabric runtime: True (default) has the pool launch its own
    # loopback worker processes (launch/fabric_worker.spawn_loopback);
    # False leaves every slot open for external workers dialing in
    # (serve.py --connect from other terminals or machines)
    fabric_spawn: bool = True
    # fabric runtime: deterministic elastic-membership schedule for
    # tests and the scenario lab (core/fabric.FabricElastic: deferred
    # mid-campaign joins + intentionally-rejected dialers); production
    # campaigns leave this None
    fabric: object | None = None
    # a worker that sends no heartbeat for this long is treated as
    # wedged: its in-flight batches re-issue to the least-loaded
    # eligible pool peer (it rejoins on its next heartbeat; late
    # duplicate results are dropped)
    heartbeat_timeout_s: float = 30.0
    heartbeat_interval_s: float = 0.5
    # bounded drain-exit linger for a recovered straggler's late
    # duplicate result (dedup accounting only — records are final at
    # first completion, and the linger is excluded from wall_s)
    straggler_grace_s: float = 2.0
    # spawn + imports + engine build budget per worker fleet
    worker_start_timeout_s: float = 180.0
    # deterministic fault hooks for the process runtime (tests/chaos
    # demos): workers.FaultInjection
    fault_injection: FaultInjection | None = None
    # ((module, attr), ...) backend factories re-registered inside each
    # worker process, so custom backends flow into the process runtime
    # the same way they flow through the in-process registry
    worker_backend_specs: tuple = ()
    # batch payload transport for the process runtime: "shm" moves the
    # numpy-heavy bulk (docs, forwarded preps, records) through
    # zero-copy generation-tagged shared-memory arenas (core/shm),
    # falling back to pickled payloads with a warning when /dev/shm is
    # unavailable; "pickle" forces the queue-serialized path. Ignored
    # by the local runtime (no process boundary to cross).
    transport: str = "shm"
    # fleet-shared persistent autotune store directory
    # (kernels/tuning_store): every worker process opens a handle on
    # the same dir, so kernel block-size sweeps run once per
    # (kernel, shape, backend, device) across the fleet's lifetime —
    # a warm restart re-sweeps nothing. None disables persistence
    # (workers fall back to per-process defaults, no sweeps).
    tuning_dir: str | None = None
    # --- observability plane (core/obs) ---
    # span tracing: False keeps the provably-free noop recorder in
    # every process; True installs bounded ring recorders (coordinator
    # + each worker), with worker spans piggybacked on the existing
    # BatchDone/Heartbeat messages — no new queues, drop-counted on
    # overflow, never blocking the hot path
    obs: bool = False
    obs_span_cap: int = 8192
    # >0 (process runtime): a periodic one-line stderr status pulse
    # from the coordinator drain loop (docs/s, α, cache hit rate,
    # in-flight, re-issues) — serve.py --status-interval
    status_interval_s: float = 0.0


@dataclasses.dataclass
class ExecutorResult:
    records: dict[int, ParseRecord]
    wall_s: float
    docs_per_s: float
    node_busy_frac: float
    reissued: int
    node_alphas: list[float]
    node_stats: list                    # per-node EngineStats
    cache_hits: int = 0
    cache_misses: int = 0
    reissued_reparse: int = 0           # of `reissued`: forwarded re-parses
    # process runtime only: late results from re-issued stragglers that
    # lost the first-completion race (dropped, never double-emitted)
    duplicates_dropped: int = 0
    # observability plane (core/obs): the run's collected spans (empty
    # unless ExecutorConfig.obs) and the fleet-folded metrics snapshot
    # (coordinator registry diffed against the run baseline + the last
    # per-worker snapshots) — feed obs.TraceWriter / obs.prometheus_text
    spans: list = dataclasses.field(default_factory=list)
    obs_metrics: dict | None = None


def _obs_begin(xcfg) -> dict:
    """Per-run observability setup: install a fresh ring recorder in
    this (coordinator) process when tracing is on — discarding spans
    from any earlier run — and take the registry baseline so the run's
    folded metrics report this run only (counters are cumulative per
    process, and tests run many campaigns in one interpreter)."""
    if getattr(xcfg, "obs", False):
        obs.configure(True, cap=getattr(xcfg, "obs_span_cap", 8192),
                      node=-1)
    return obs.metrics().snapshot()


def _obs_collect(pool, baseline: dict) -> tuple[list, dict]:
    """Assemble the run's observability artifacts: worker spans/snaps
    absorbed by the pool, plus this process's recorder drain and
    baseline-diffed registry, folded fleet-wide."""
    spans, snaps = pool.obs_drain()
    spans = spans + obs.recorder().drain(None)
    spans.sort(key=lambda s: s.start)
    local = obs.diff(obs.metrics().snapshot(), baseline)
    if obs.recorder().enabled:
        # tracing never outlives its run: restore the noop recorder so
        # later (untraced) campaigns in this process pay nothing
        obs.configure(False)
    return spans, obs.fold(snaps + [local])


def document_shard_source(docs, batch_size: int, shard: int,
                          n_shards: int, seed: int = 0) -> BatchSource:
    """Per-node work queue over the corpus: shard ``shard`` yields the
    global batches ``shard, shard + n_shards, ...`` (round-robin), each
    tagged with its global batch index so any node reproduces the same
    stateless rng stream for it."""

    def fn(step, rng):
        g = step * n_shards + shard
        lo = g * batch_size
        if lo >= len(docs):
            raise StopIteration
        return {"batch_key": g, "docs": docs[lo:lo + batch_size]}

    return BatchSource(fn, seed=seed, shard=shard)


def weighted_shard_batches(n_batches: int,
                           weights: list[float]) -> list[list[int]]:
    """Assign global batch indices to shards so shard sizes follow the
    weights (deficit round-robin: batch g goes to the shard furthest
    below its quota w_i·(g+1)). Uniform weights recover plain
    round-robin, and the assignment is deterministic — batch keys stay
    global, so records are placement-independent.

    Degenerate inputs fall back to uniform: all-zero weights carry no
    signal, and with more shards than batches the quota arithmetic
    would pile the few batches onto the heaviest shard while other
    nodes idle — round-robin (one batch per shard) is optimal there.
    Negative weights are an error."""
    w = np.asarray(weights, np.float64)
    if np.any(w < 0):
        raise ValueError("shard weights must be non-negative")
    if w.sum() <= 0 or n_batches < len(w):
        w = np.ones(len(w), np.float64)
    w = w / w.sum()
    assigned = np.zeros(len(w), np.float64)
    shards: list[list[int]] = [[] for _ in w]
    for g in range(n_batches):
        i = int(np.argmax(w * (g + 1) - assigned))
        shards[i].append(g)
        assigned[i] += 1.0
    return shards


#: The simulated in-process dispatch loop moved to core/workers as
#: ``LocalWorkerPool`` (one of the two ``WorkerPool`` runtimes); the
#: old name stays importable.
_CampaignRun = LocalWorkerPool


class CampaignExecutor:
    """Run a real engine per node over shards of the batch sequence.

    The campaign α-budget T̄ = K·((1−α)·T_cheap + α·T_exp) is partitioned
    across ingest nodes proportionally to their shard sizes; each node
    solves its own α_i = alpha_for_budget(T̄_i) (node budgets sum to the
    campaign budget). For homogeneous shards α_i = α exactly (snapped
    against float round-trip), which is what makes the N-node record set
    identical to the single-node run."""

    def __init__(self, ecfg: EngineConfig, xcfg: ExecutorConfig, router,
                 corpus_cfg, image_degraded=False, text_degraded=False,
                 probe: QualityProbe | None = None):
        self.ecfg = ecfg
        self.xcfg = xcfg
        self.router = router
        self.ccfg = corpus_cfg
        self.image_degraded = image_degraded
        self.text_degraded = text_degraded
        self.probe = probe

    def _topology(self, n_batches: int):
        """(n_nodes, ingest_nodes, reparse_nodes, pools) for this run."""
        pools = self.xcfg.node_pools
        if pools is None:
            n_nodes = max(min(self.xcfg.n_nodes, n_batches), 1)
            ingest_nodes = list(range(n_nodes))
            reparse_nodes = ingest_nodes
            return n_nodes, ingest_nodes, reparse_nodes, None
        n_nodes = self.xcfg.n_nodes
        if len(pools) != n_nodes:
            raise ValueError(f"need {n_nodes} node pool entries, got "
                             f"{len(pools)}")
        cheap_dev = B.get_backend(self.ecfg.cheap).info.device
        exp_dev = B.get_backend(self.ecfg.expensive).info.device
        all_nodes = list(range(n_nodes))
        ingest_nodes = [i for i in all_nodes
                        if pools[i] == cheap_dev] or all_nodes
        reparse_nodes = [i for i in all_nodes
                         if pools[i] == exp_dev] or all_nodes
        return n_nodes, ingest_nodes, reparse_nodes, pools

    def _build_engines(self, n_nodes: int, alpha_of: dict[int, float],
                       cache, probe=None) -> list[AdaParseEngine]:
        return [
            AdaParseEngine(
                dataclasses.replace(self.ecfg,
                                    alpha=alpha_of.get(i, self.ecfg.alpha)),
                self.router, self.ccfg,
                image_degraded=self.image_degraded,
                text_degraded=self.text_degraded, cache=cache,
                probe=probe if probe is not None else self.probe)
            for i in range(n_nodes)]

    def _make_pool(self, n_nodes: int, ingest_nodes: list[int],
                   reparse_nodes: list[int], pools: list[str] | None,
                   alpha_of: dict[int, float], cache, probe=None):
        """Build the worker pool for this run (``ExecutorConfig
        .runtime``): the local simulated fleet over caller-built
        engines, or real workers — spawned processes or fabric dialers
        — that each build their own engine from a serialized spec
        (core/workers, core/fabric)."""
        probe = probe if probe is not None else self.probe
        if getattr(self.xcfg, "runtime", "local") in ("process",
                                                      "fabric"):
            return make_worker_pool(
                self.ecfg, self.xcfg, self.router, self.ccfg, n_nodes,
                ingest_nodes, reparse_nodes, pools, alpha_of=alpha_of,
                cache=cache, probe=probe,
                image_degraded=self.image_degraded,
                text_degraded=self.text_degraded)
        engines = self._build_engines(n_nodes, alpha_of, cache, probe)
        return make_worker_pool(
            self.ecfg, self.xcfg, self.router, self.ccfg, n_nodes,
            ingest_nodes, reparse_nodes, pools, engines=engines)

    def _node_alphas(self, shard_sizes: list[int],
                     weights: list[float] | None) -> list[float]:
        """Partition the campaign budget T̄ = K·((1−α)T_c + α·T_e) into
        per-node budgets T̄_i and solve each node's α_i. Budget shares
        follow ``weights`` (scaled by shard size); with uniform weights
        every α_i is exactly the campaign α."""
        a = self.ecfg.alpha
        n = len(shard_sizes)
        if weights is None:
            # uniform partition ≡ campaign alpha on every node; skip the
            # round-trip so record parity with a single-node run is exact
            return [a] * n
        t_c = 1.0 / B.get_backend(self.ecfg.cheap).info.pdf_per_sec_node
        t_e = 1.0 / B.get_backend(self.ecfg.expensive).info.pdf_per_sec_node
        total_budget = sum(shard_sizes) * ((1 - a) * t_c + a * t_e)
        shares = np.asarray(weights, np.float64) * np.asarray(
            shard_sizes, np.float64)
        shares = shares / max(shares.sum(), 1e-12)
        return [
            scheduler.alpha_for_budget(float(total_budget * s), k_i, t_c,
                                       t_e) if k_i else a
            for s, k_i in zip(shares, shard_sizes)]

    def run(self, docs, cache: B.ResultStore | None = None
            ) -> ExecutorResult:
        bs = self.ecfg.batch_size
        n_batches = max(-(-len(docs) // bs), 1)
        n_nodes, ingest_nodes, reparse_nodes, pools = \
            self._topology(n_batches)

        w = self.xcfg.node_budget_weights
        if w is not None and len(w) != n_nodes:
            raise ValueError(f"need {n_nodes} node weights, got {len(w)}")
        ingest_w = [w[i] for i in ingest_nodes] if w is not None else None
        if ingest_w is None:
            queues = {
                node: list(document_shard_source(docs, bs, j,
                                                 len(ingest_nodes),
                                                 seed=self.ecfg.seed))
                for j, node in enumerate(ingest_nodes)}
        else:
            shards = weighted_shard_batches(n_batches, ingest_w)
            queues = {
                node: batches_for_indices(docs, bs, shard)
                for node, shard in zip(ingest_nodes, shards)}
        alphas = self._node_alphas(
            [sum(len(b["docs"]) for b in queues[i]) for i in ingest_nodes],
            ingest_w)
        alpha_of = {node: a for node, a in zip(ingest_nodes, alphas)}
        obs_base = _obs_begin(self.xcfg)
        pool = self._make_pool(n_nodes, ingest_nodes, reparse_nodes,
                               pools, alpha_of, cache)
        try:
            hits0, miss0 = pool.snapshot_cache(cache)
            pool.drain(queues)
            node_alphas = [alpha_of.get(i, self.ecfg.alpha)
                           for i in range(n_nodes)]
            spans, folded = _obs_collect(pool, obs_base)
            return ExecutorResult(
                node_alphas=node_alphas, spans=spans,
                obs_metrics=folded,
                **pool.finalize(len(docs), cache, hits0, miss0))
        finally:
            pool.close()


# ---------------------------------------------------------------------------
# Round-based adaptive controller
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ControllerConfig:
    """Knobs of the adaptive round loop."""

    rounds: int = 4                  # dispatch the batch sequence in rounds
    ewma: float = 0.5                # weight of the newest observation
    min_weight: float = 0.02         # per-node floor of normalized weights
    # replayed telemetry: per-round observations used INSTEAD of the
    # measured clocks / probe signal. A recorded trace
    # (ControllerResult.telemetry, RoundTelemetry entries) replayed
    # here pins the whole weight trajectory AND the α trajectory,
    # making adaptive runs reproducible across cache states and process
    # restarts; the PR-3 format (bare per-ingest-node docs/s lists)
    # still works and pins the weights only.
    telemetry_trace: list | None = None
    # --- online α retuning (core/quality; None = fixed campaign α) ---
    # operator bounds (lo, hi) the retuned campaign α must stay inside;
    # None disables retuning (quality is still monitored when a probe
    # is configured)
    alpha_bounds: tuple[float, float] | None = None
    alpha_step: float = 0.05         # max per-round α movement
    quality_target: float = 0.45     # blended quality the campaign aims at
    quality_ewma: float = 0.5        # QualityMonitor EWMA weight
    # probe sampling config; defaulted when retuning is enabled without
    # one (alpha_bounds set, probe None)
    probe: QualityProbeConfig | None = None


@dataclasses.dataclass
class RoundTelemetry:
    """One adaptive round's recorded observations + decisions — the
    unit of ``ControllerResult.telemetry`` and of trace replay
    (``ControllerConfig.telemetry_trace``)."""

    alpha: float                     # campaign α used for this round
    throughput: list[float]          # measured per-ingest-node docs/s
    # per-parser quality EWMAs after absorbing this round's probe
    # samples (empty before the first probed batch)
    quality: dict[str, float] = dataclasses.field(default_factory=dict)
    n_probe_docs: int = 0            # fresh probe docs observed this round
    # α decision taken at this round's boundary: "raise" | "lower" |
    # "hold" | "no-signal" (no fresh probe docs — never retune on a
    # stale EWMA) | "replay" (α pinned by a replayed trace) | "fixed"
    # (retuning disabled)
    decision: str = "fixed"


@dataclasses.dataclass
class ControllerResult(ExecutorResult):
    rounds: int = 0
    # weights used for round r (normalized over ingest nodes), plus one
    # final post-update entry — the weights a further round would use
    weight_history: list[list[float]] = dataclasses.field(
        default_factory=list)
    # per-round RoundTelemetry (measured throughput, α, quality EWMAs,
    # retune decisions) — replayable as ControllerConfig.telemetry_trace
    telemetry: list[RoundTelemetry] = dataclasses.field(
        default_factory=list)

    @property
    def alpha_trajectory(self) -> list[float]:
        return [t.alpha for t in self.telemetry]


def _round_trace(trace, r) -> tuple[list[float] | None, float | None]:
    """(throughput_obs, alpha) replayed for round ``r``: accepts
    RoundTelemetry entries (a recorded ControllerResult.telemetry),
    equivalent dicts, or the PR-3 bare per-node docs/s lists (which pin
    the weights but leave α live)."""
    if trace is None or r >= len(trace):
        return None, None
    entry = trace[r]
    if isinstance(entry, RoundTelemetry):
        return list(entry.throughput), entry.alpha
    if isinstance(entry, dict):
        tp = entry.get("throughput")
        return (list(tp) if tp is not None else None), entry.get("alpha")
    return list(entry), None


class CampaignController:
    """Round-based adaptive campaign: online-autotuned budget weights.

    Each round takes the next contiguous chunk of the global batch
    sequence and shards it over the ingest pool with
    ``weighted_shard_batches`` under the *current* weights. After the
    round, per-node throughput observed from the simulated clocks (or
    taken from a replayed telemetry trace) updates an EWMA estimate,
    which — normalized with a small floor — becomes the next round's
    weights: slow nodes shed shards, fast nodes absorb them.

    The α-budget split follows the shard sizes: per-node expensive-parse
    budgets T̄_i = k_i·((1−α)T_c + α·T_e) sum to the campaign budget in
    every round and put every node at exactly the campaign α. That is
    the determinism contract — however the weights evolve, each batch is
    routed with the same α and parsed under its global batch key, so the
    adaptive record set equals the single-node run byte-for-byte.

    **Online α retuning** (``ControllerConfig.alpha_bounds``,
    core/quality): with bounds set, a deterministic batch-keyed
    ``QualityProbe`` scores sampled batches per parser, a
    ``QualityMonitor`` keeps per-parser quality EWMAs, and at every
    round boundary the controller moves the *campaign* α at most
    ``alpha_step`` toward the cheapest α inside the bounds that meets
    ``quality_target`` — every engine follows (``AdaParseEngine
    .set_alpha``), so all nodes still route at one campaign α. Rounds
    with no fresh probe docs (warm-cache replays, α too small to route)
    hold α ("no-signal") rather than retune on a stale EWMA. Every
    (round, α, quality) decision lands in ``ControllerResult
    .telemetry``; replaying it via ``telemetry_trace`` pins the exact α
    trajectory, so a recorded retuned campaign reproduces its record
    set bit-identically across restarts (cache keys embed α) — the
    relaxed-determinism story: bit-identical under replay,
    round-granular divergence otherwise."""

    def __init__(self, ecfg: EngineConfig, xcfg: ExecutorConfig,
                 ctl: ControllerConfig, router, corpus_cfg,
                 image_degraded=False, text_degraded=False):
        if ctl.rounds < 1:
            raise ValueError(f"need at least 1 round, got {ctl.rounds}")
        if not 0.0 < ctl.ewma <= 1.0:
            raise ValueError(f"ewma must be in (0, 1], got {ctl.ewma}")
        if ctl.alpha_bounds is not None:
            lo, hi = ctl.alpha_bounds
            if not 0.0 <= lo <= hi <= 1.0:
                raise ValueError(f"alpha_bounds must satisfy 0 <= lo <= "
                                 f"hi <= 1, got ({lo}, {hi})")
            if not lo <= ecfg.alpha <= hi:
                raise ValueError(f"campaign alpha {ecfg.alpha} lies "
                                 f"outside alpha_bounds ({lo}, {hi}); "
                                 f"start the campaign inside the "
                                 f"operator bounds")
            if ctl.alpha_step <= 0.0:
                raise ValueError(f"alpha_step must be > 0, got "
                                 f"{ctl.alpha_step}")
        self.ecfg = ecfg
        self.xcfg = xcfg
        self.ctl = ctl
        # a probe is configured explicitly, or defaulted as soon as
        # retuning is on (no signal -> nothing to retune from)
        self.probe = (QualityProbe(ctl.probe) if ctl.probe is not None
                      else QualityProbe(QualityProbeConfig())
                      if ctl.alpha_bounds is not None else None)
        self.executor = CampaignExecutor(ecfg, xcfg, router, corpus_cfg,
                                         image_degraded=image_degraded,
                                         text_degraded=text_degraded,
                                         probe=self.probe)

    def _normalize(self, est: list[float]) -> list[float]:
        w = np.asarray(est, np.float64)
        w = w / max(w.sum(), 1e-12)
        w = np.maximum(w, self.ctl.min_weight)
        return list(w / w.sum())

    def run(self, docs, cache: B.ResultStore | None = None
            ) -> ControllerResult:
        bs = self.ecfg.batch_size
        n_batches = max(-(-len(docs) // bs), 1)
        n_nodes, ingest_nodes, reparse_nodes, pools = \
            self.executor._topology(n_batches)
        obs_base = _obs_begin(self.xcfg)
        # every node at the campaign alpha (see class docstring)
        pool = self.executor._make_pool(n_nodes, ingest_nodes,
                                        reparse_nodes, pools, {}, cache)
        try:
            return self._run_rounds(pool, docs, cache, n_nodes,
                                    ingest_nodes, obs_base=obs_base)
        finally:
            pool.close()

    def _run_rounds(self, pool, docs, cache, n_nodes: int,
                    ingest_nodes: list[int],
                    obs_base: dict | None = None) -> ControllerResult:
        bs = self.ecfg.batch_size
        n_batches = max(-(-len(docs) // bs), 1)
        hits0, miss0 = pool.snapshot_cache(cache)

        w0 = self.xcfg.node_budget_weights
        if w0 is not None and len(w0) != n_nodes:
            raise ValueError(f"need {n_nodes} node weights, got {len(w0)}")
        weights = self._normalize(
            [w0[i] for i in ingest_nodes] if w0 is not None
            else [1.0] * len(ingest_nodes))
        est: list[float] | None = None
        rounds = max(min(self.ctl.rounds, n_batches), 1)
        trace = self.ctl.telemetry_trace
        weight_history: list[list[float]] = []
        telemetry: list[RoundTelemetry] = []
        monitor = QualityMonitor(ewma=self.ctl.quality_ewma)
        retune = self.ctl.alpha_bounds is not None
        alpha = self.ecfg.alpha
        # quality samples come from ALL nodes' telemetry (re-parse
        # pool nodes complete forwarded batches onto ingest engines,
        # but re-issue paths can append anywhere) — track a per-node
        # high-water mark
        qmark = [len(pool.node_telemetry(i)) for i in range(n_nodes)]

        for r in range(rounds):
            lo = r * n_batches // rounds
            hi = (r + 1) * n_batches // rounds
            if hi <= lo:
                continue
            trace_tp, trace_alpha = _round_trace(trace, r)
            if trace_alpha is not None and trace_alpha != alpha:
                # replayed α trajectory: pin this round's campaign α
                # (and with it the cache tags) before dispatching
                alpha = trace_alpha
                pool.set_alpha(alpha)
            t_round0 = time.time()
            # elastic fleets (the fabric runtime) re-shard over the
            # *live* ingest nodes at every round boundary: a worker
            # that joined since last round absorbs shards, one that
            # left sheds them. Records are placement-independent
            # (global batch keys), so membership churn never changes
            # the record set — only who computes it.
            live = ingest_nodes
            if hasattr(pool, "live_ingest_nodes"):
                live = [i for i in pool.live_ingest_nodes()
                        if i in ingest_nodes] or ingest_nodes
            if live == ingest_nodes:
                round_w = weights
            else:
                idx = {n: j for j, n in enumerate(ingest_nodes)}
                round_w = self._normalize(
                    [weights[idx[i]] for i in live])
            shards = weighted_shard_batches(hi - lo, round_w)
            queues = {
                node: batches_for_indices(docs, bs,
                                          [lo + j for j in shard])
                for node, shard in zip(live, shards)}
            weight_history.append(list(weights))
            tele0 = [len(pool.node_telemetry(i)) for i in ingest_nodes]
            clk0 = pool.clocks.copy()
            pool.drain(queues)
            measured = []
            for j, i in enumerate(ingest_nodes):
                # docs from the round's per-stage telemetry records,
                # excluding cache replays (their docs advance no clock)
                # and abandoned straggler attempts (their docs were
                # re-produced elsewhere) — counting either would inflate
                # the node's observed docs/s and mis-steer the weights
                d_docs = sum(t.n_docs
                             for t in pool.node_telemetry(i)[tele0[j]:]
                             if not (t.cached or t.abandoned))
                d_clk = float(pool.clocks[i] - clk0[i])
                measured.append(d_docs / d_clk if d_clk > 0 else 0.0)
            # absorb this round's fresh probe samples into the quality
            # EWMAs (cached/abandoned batches carry quality=None).
            # Batch-key order, not completion order: the process
            # runtime completes batches in nondeterministic order, and
            # the EWMA is order-sensitive — sorting keys the quality
            # signal to the corpus, so both runtimes derive the same
            # estimates from the same probed set
            fresh = []
            for i in range(n_nodes):
                tele = pool.node_telemetry(i)
                fresh.extend(t for t in tele[qmark[i]:]
                             if not (t.cached or t.abandoned))
                qmark[i] = len(tele)
            fresh.sort(key=lambda t: (t.batch_key is None, t.batch_key))
            n_probe = 0
            for t in fresh:
                n_probe += monitor.observe(t.quality)
            observed = trace_tp if trace_tp is not None else measured
            if len(observed) != len(ingest_nodes):
                raise ValueError(
                    f"telemetry round {r}: need {len(ingest_nodes)} "
                    f"ingest-node observations, got {len(observed)}")
            # EWMA feedback: a zero observation (no work / warm cache
            # replay charged no time) keeps the previous estimate
            if est is None:
                # unobserved nodes start at the mean of the observed
                # ones (neutral), not at an arbitrary constant that
                # would floor-pin them before they ever ran a batch
                pos = [o for o in observed if o > 0]
                fill = sum(pos) / len(pos) if pos else 1.0
                est = [o if o > 0 else fill for o in observed]
            else:
                a = self.ctl.ewma
                est = [(1 - a) * e + a * o if o > 0 else e
                       for e, o in zip(est, observed)]
            weights = self._normalize(est)
            # round-boundary α decision (applied to the NEXT round;
            # a replayed trace overrides it there)
            # a trace entry only pins α when it carries one — a PR-3
            # bare throughput list pins the weights but leaves the α
            # decision live, as documented on _round_trace
            next_alpha = alpha
            if trace_alpha is not None:
                decision = "replay"
            elif not retune:
                decision = "fixed"
            elif n_probe == 0:
                decision = "no-signal"
            else:
                next_alpha, decision = propose_alpha(
                    alpha, monitor, self.ecfg.cheap, self.ecfg.expensive,
                    bounds=self.ctl.alpha_bounds,
                    step=self.ctl.alpha_step,
                    quality_target=self.ctl.quality_target)
            telemetry.append(RoundTelemetry(
                alpha=alpha, throughput=measured,
                quality=monitor.snapshot(), n_probe_docs=n_probe,
                decision=decision))
            rec = obs.recorder()
            if rec.enabled:
                # the α trajectory inline in the timeline: one span per
                # adaptive round carrying the boundary decision, so a
                # bimodal_retune trace shows exactly where α moved
                rec.span("round", f"round-{r}", t_round0,
                         time.time() - t_round0,
                         detail=f"alpha={alpha:.4f} decision={decision}"
                                f" -> {next_alpha:.4f}"
                                f" probe_docs={n_probe}")
            if next_alpha != alpha and r + 1 < rounds:
                # the decision is recorded either way; only apply it
                # when another round will actually route with it
                alpha = next_alpha
                pool.set_alpha(alpha)
        weight_history.append(list(weights))
        spans, folded = _obs_collect(pool, obs_base or {})
        return ControllerResult(
            node_alphas=[alpha] * n_nodes,
            rounds=rounds, weight_history=weight_history,
            telemetry=telemetry, spans=spans, obs_metrics=folded,
            **pool.finalize(len(docs), cache, hits0, miss0))


def autotune_convergence_rounds(weight_history: list[list[float]],
                                rtol: float = 0.05) -> int:
    """Rounds until the controller's weights stabilized: the first round
    index r such that every subsequent update changed no weight by more
    than ``rtol`` relative. len(weight_history) - 1 (i.e. "never, within
    this run") if the last update still moved."""
    n = len(weight_history)
    stable_from = n - 1
    for r in range(n - 1, 0, -1):
        prev, cur = weight_history[r - 1], weight_history[r]
        if all(abs(c - p) <= rtol * max(p, 1e-12)
               for c, p in zip(cur, prev)):
            stable_from = r - 1
        else:
            break
    return stable_from


def scaling_curve(parser: str, node_counts, cfg: CampaignConfig,
                  **kw) -> list[tuple[int, float]]:
    out = []
    for n in node_counts:
        c = dataclasses.replace(cfg, n_nodes=n,
                                n_docs=max(cfg.n_docs, n * 2048))
        out.append((n, simulate_parser_campaign(parser, c, **kw).docs_per_s))
    return out
