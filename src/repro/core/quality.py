"""Online quality estimation + α retuning policy (§2.2 / §7.2 closed
loop) — the layer between the batched scorers (core/metrics) and the
adaptive campaign controller (core/campaign).

AdaParse's selection policy is built on *predicted* per-document
accuracy, but a campaign can also *measure* output quality online:
parser quality varies sharply by document category (arXiv 2410.09871),
so a corpus whose composition drifts mid-campaign (a hard scanned tail,
a publisher switch) silently degrades the cheap parser while α — the
expensive-parse budget — stays wherever the operator pinned it. This
module closes that loop:

- ``QualityProbe`` samples a deterministic, *batch-keyed* subset of
  completed batches (``should_probe`` is a pure function of
  (probe seed, batch key), so the same batches are probed no matter
  which node, round, or process runs them) and scores
  hypothesis-vs-reference token streams per parser with the vectorized
  ``metrics.score_batch`` — BLEU through the fused Pallas n-gram
  kernel (kernels/ngram_score: the pairwise-equality clipped-count
  matrices run on-device in one kernel), ROUGE-L / CAR through the
  jitted batched DPs, all behind padding + length masks. Probe
  results ride on
  ``engine.BatchTelemetry.quality``, and the probe's *cost*
  (``QualityProbeConfig.cost_s_per_doc`` node-seconds per scored doc)
  is charged to the node that completed — and therefore scored — the
  batch (``BatchTelemetry.probe_s``): the controller's throughput EWMA
  sees probe overhead instead of treating scoring as free
  measurement-plane work, so probe rate trades against throughput.
  Records are never affected, and cache replays / abandoned straggler
  attempts carry no quality (exactly like their timing is excluded
  from throughput).

- ``QualityMonitor`` aggregates probe samples into per-parser quality
  EWMAs. A round with zero fresh probe docs (an all-replay warm round,
  or α too small to route anything) reports **no signal** — the
  controller must hold α rather than retune on a stale EWMA.

- ``propose_alpha`` is the round-boundary retuning rule: move α at most
  ``alpha_step`` per round toward ``target_alpha`` — the smallest α
  inside the operator bounds whose blended expected quality
  (1−α)·q̂_cheap + α·q̂_exp meets the quality target (the cheapest
  budget that buys the target; the bound maximizing quality when none
  does). With no expensive-parser estimate yet (α so small no routed
  doc was ever probed) it raises one step, but only while quality is
  short of target — bounded exploration.

Determinism contract ("relaxed determinism"): α moves at *round
boundaries only*, every (round, α, quality) decision is recorded in
``ControllerResult.telemetry``, and replaying that trace
(``ControllerConfig.telemetry_trace``) pins the exact α trajectory —
so a recorded campaign reproduces its record set bit-identically
across restarts (cache keys embed α), while an un-replayed re-run may
diverge, at round granularity, when its quality signal differs (e.g.
warm caches produce no probe samples).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import metrics as M
from repro.data.pipeline import stateless_rng

#: metrics the probe can aggregate; "mean" averages all three.
PROBE_METRICS = M.SCORE_METRICS + ("mean",)


def record_hypothesis(record) -> np.ndarray:
    """A ``ParseRecord``'s emitted pages as one hypothesis token
    stream (empty for a parser that produced nothing) — the single
    definition every quality scorer compares against references."""
    if record.pages and sum(map(len, record.pages)):
        return np.concatenate(record.pages)
    return np.zeros(0, np.int32)


@dataclasses.dataclass(frozen=True)
class QualityProbeConfig:
    """Knobs of the online quality probe."""

    probe_rate: float = 0.25         # fraction of batches sampled
    seed: int = 0                    # probe stream seed (NOT the engine's)
    max_len: int = 256               # score truncation (metrics.score_batch)
    metric: str = "bleu"             # "bleu" | "rouge" | "car" | "mean"
    # probe cost model: scoring a probed batch costs this many
    # node-seconds per document, charged to the node that completed
    # (and therefore scored) the batch. Probing is no longer free
    # measurement-plane work — the controller's throughput EWMA sees
    # the overhead, so an operator can trade probe rate against
    # throughput. Records are never affected (clock/telemetry only).
    cost_s_per_doc: float = 1e-3

    def __post_init__(self):
        if not 0.0 <= self.probe_rate <= 1.0:
            raise ValueError(f"probe_rate must be in [0, 1], got "
                             f"{self.probe_rate}")
        if self.cost_s_per_doc < 0.0:
            raise ValueError(f"probe cost_s_per_doc must be >= 0, got "
                             f"{self.cost_s_per_doc}")
        if self.max_len < 1:
            raise ValueError(f"probe max_len must be >= 1, got "
                             f"{self.max_len}")
        if self.metric not in PROBE_METRICS:
            raise ValueError(f"unknown probe metric {self.metric!r}; "
                             f"choose from {PROBE_METRICS}")


class QualityProbe:
    """Deterministic batch-keyed sampler + per-parser batch scorer.

    Sampling is a pure function of (probe seed, batch key): the probed
    subset is identical however the campaign places, re-issues, or
    prefetches its batches — the property that keeps quality telemetry
    (and therefore the α trajectory derived from it) reproducible."""

    def __init__(self, cfg: QualityProbeConfig | None = None):
        self.cfg = cfg or QualityProbeConfig()

    def should_probe(self, batch_key: int) -> bool:
        if self.cfg.probe_rate >= 1.0:
            return True
        if self.cfg.probe_rate <= 0.0:
            return False
        return bool(stateless_rng(self.cfg.seed, batch_key).rand()
                    < self.cfg.probe_rate)

    def score_records(self, docs, records) -> dict[str, tuple[float, int]]:
        """Score one completed batch: hypothesis (emitted pages) vs
        reference (ground-truth token stream) per document, grouped by
        the parser that produced each record. Returns
        ``{parser: (mean_quality, n_docs)}``."""
        refs: dict[str, list[np.ndarray]] = {}
        hyps: dict[str, list[np.ndarray]] = {}
        for d, r in zip(docs, records):
            refs.setdefault(r.parser, []).append(d.full_text())
            hyps.setdefault(r.parser, []).append(record_hypothesis(r))
        metric = self.cfg.metric
        wanted = M.SCORE_METRICS if metric == "mean" else (metric,)
        out: dict[str, tuple[float, int]] = {}
        for parser in refs:
            s = M.score_batch(refs[parser], hyps[parser],
                              max_len=self.cfg.max_len, metrics=wanted)
            vals = (np.mean([s[m] for m in M.SCORE_METRICS], axis=0)
                    if metric == "mean" else s[metric])
            out[parser] = (float(np.mean(vals)), len(vals))
        return out


class QualityMonitor:
    """Per-parser online quality EWMAs fed by probe samples.

    ``update`` blends one probe observation (a batch's per-parser mean)
    into the parser's estimate; ``estimate`` is None until the parser
    has been observed at least once — the controller treats a round
    that contributed no fresh docs as *no signal* and must not retune
    from whatever stale estimates remain."""

    def __init__(self, ewma: float = 0.5):
        if not 0.0 < ewma <= 1.0:
            raise ValueError(f"quality ewma must be in (0, 1], got {ewma}")
        self.ewma = ewma
        self._est: dict[str, float] = {}
        self.n_docs: dict[str, int] = {}

    def update(self, parser: str, quality: float, n: int) -> None:
        if n <= 0:
            return
        prev = self._est.get(parser)
        self._est[parser] = (quality if prev is None
                             else (1 - self.ewma) * prev
                             + self.ewma * quality)
        self.n_docs[parser] = self.n_docs.get(parser, 0) + n

    def observe(self, quality: dict[str, tuple[float, int]] | None) -> int:
        """Feed one ``BatchTelemetry.quality`` payload; returns the
        number of probe docs absorbed (0 for unprobed/cached/abandoned
        batches, whose payload is None)."""
        if not quality:
            return 0
        n_total = 0
        for parser in sorted(quality):
            q, n = quality[parser]
            self.update(parser, q, n)
            n_total += n
        return n_total

    def estimate(self, parser: str) -> float | None:
        return self._est.get(parser)

    def snapshot(self) -> dict[str, float]:
        return dict(self._est)


def target_alpha(q_cheap: float, q_expensive: float, quality_target: float,
                 bounds: tuple[float, float]) -> float:
    """The α the retuner steers toward: the smallest α within ``bounds``
    whose blended expected quality (1−α)·q_cheap + α·q_exp meets the
    target — i.e. the cheapest budget that buys the target — clamped to
    the best-achievable bound when no α in range does (hi when the
    expensive parser helps, lo when it measures no better)."""
    lo, hi = bounds
    if q_expensive <= q_cheap:
        return lo
    if q_cheap >= quality_target:
        return lo
    need = (quality_target - q_cheap) / (q_expensive - q_cheap)
    return float(min(max(need, lo), hi))


def propose_alpha(alpha: float, monitor: QualityMonitor, cheap: str,
                  expensive: str, *, bounds: tuple[float, float],
                  step: float, quality_target: float
                  ) -> tuple[float, str]:
    """One round-boundary retuning decision: ``(new_alpha, decision)``
    with decision in {"raise", "lower", "hold", "no-signal"}. Moves at
    most ``step`` per round toward ``target_alpha`` and never leaves
    ``bounds``; with no cheap-parser estimate there is nothing to steer
    by (no-signal), and with no expensive-parser estimate it explores
    one step upward only while measured quality is short of target."""
    lo, hi = bounds
    q_c = monitor.estimate(cheap)
    q_e = monitor.estimate(expensive)
    if q_c is None:
        return alpha, "no-signal"
    if q_e is None:
        tgt = min(alpha + step, hi) if q_c < quality_target else alpha
    else:
        tgt = target_alpha(q_c, q_e, quality_target, bounds)
    new = alpha + float(np.clip(tgt - alpha, -step, step))
    new = float(min(max(new, lo), hi))
    # float-dust moves are holds: a micro-retune would still change the
    # engines' cache tags and force a full re-parse of replayable work
    if new > alpha + 1e-9:
        return new, "raise"
    if new < alpha - 1e-9:
        return new, "lower"
    return alpha, "hold"
