"""Cross-machine campaign fabric: a socket transport behind the pool.

The third ``WorkerPool`` runtime (``ExecutorConfig.runtime="fabric"``).
``FabricWorkerPool`` keeps the entire coordinator brain of
``ProcessWorkerPool`` — the in-flight window, the heartbeat-deadline
liveness police, pool-aware re-issue through
``scheduler.reissue_candidates``, and the first-completion-wins dedup
gate — and swaps only the transport: instead of multiprocessing queues
into spawned children, a ``FabricCoordinator`` (the selector hub below)
listens on ``ExecutorConfig.coordinator`` (``HOST:PORT``, port 0 =
auto-bind) and standalone worker processes — on this machine or any
other — dial in over TCP (``serve.py --connect HOST:PORT``, or the
loopback spawner in ``launch/fabric_worker``).

Wire format: every message is one length-prefixed frame — an 8-byte
big-endian length followed by the pickled PR-5 message dataclass
(``PrepareTask`` / ``CompleteTask`` / ``BatchDone`` / ``Heartbeat``
from core/workers, plus the membership frames below). Payloads always
ride inline: shared-memory arenas cannot cross machines, so the fabric
pool runs with ``_shm = None`` and the inherited send/receive paths
fall back to pickled payloads automatically.

Membership is elastic:

- **join** — a dialing worker presents a ``Hello``; with a fingerprint
  (``specs.spec_fingerprint``) it must match the coordinator's spec or
  the worker is rejected with an actionable error naming the differing
  field; with ``fingerprint=None`` (the trusting default for workers
  the coordinator itself launched) the coordinator ships its own
  portable ``WorkerSpec`` in the ``Admit`` reply, and the worker
  verifies the coordinator-stamped fingerprint after deserializing.
  Every admission emits a ``join`` span and bumps ``fabric.joins``.
- **leave** — a connection EOF/reset (crash or detach) emits a
  ``leave`` span, and the inherited liveness police sees the dead
  connection handle and re-issues the worker's in-flight and queued
  batches to live peers.
- The adaptive controller queries ``live_ingest_nodes()`` at every
  round boundary and re-shards over the live fleet.

Determinism is unchanged and is the point: batch rng streams are keyed
by the global batch index and the dedup gate keeps first completions
only, so a campaign with workers joining, crashing, and being rejected
mid-run reproduces the single-node record set byte-identically.

All socket I/O runs on one daemon hub thread (non-blocking sockets
under a ``selectors`` loop). The hub never mutates pool state: inbound
worker messages and membership events are enqueued on the pool's
result queue and processed single-threaded by the inherited drain
loop, exactly like multiprocessing queue messages.
"""
from __future__ import annotations

import dataclasses
import pickle
import queue as queue_lib
import selectors
import socket
import struct
import threading
import time
from collections import deque

from repro.core import obs
from repro.core import specs as spec_lib
from repro.core.workers import BatchDone, Heartbeat, ProcessWorkerPool

_LEN = struct.Struct("!Q")
#: refuse absurd frames instead of allocating unbounded buffers from a
#: corrupt or hostile length prefix
MAX_FRAME_BYTES = 1 << 31

#: an intentionally-wrong fingerprint for exercising the admission
#: rejection path (the elastic_join_leave scenario's rejected worker)
MISMATCHED_FINGERPRINT = {
    "router": "0000000000000000",
    "engine_config": "0000000000000000",
    "backends": "0000000000000000",
}


def parse_addr(addr: str) -> tuple[str, int]:
    """``HOST:PORT`` -> (host, port); port 0 means auto-bind."""
    host, sep, port = addr.rpartition(":")
    if not sep or not host:
        raise ValueError(f"fabric address must be HOST:PORT, got {addr!r}")
    return host, int(port)


def encode_frame(obj) -> bytes:
    payload = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    return _LEN.pack(len(payload)) + payload


class FrameDecoder:
    """Incremental decoder for one stream: feed raw bytes, yield every
    complete frame's unpickled message."""

    def __init__(self):
        self._buf = bytearray()

    def feed(self, data: bytes):
        self._buf += data
        while True:
            if len(self._buf) < _LEN.size:
                return
            (n,) = _LEN.unpack_from(self._buf)
            if n > MAX_FRAME_BYTES:
                raise ValueError(f"fabric frame of {n} bytes exceeds the "
                                 f"{MAX_FRAME_BYTES}-byte cap")
            if len(self._buf) < _LEN.size + n:
                return
            payload = bytes(self._buf[_LEN.size:_LEN.size + n])
            del self._buf[:_LEN.size + n]
            yield pickle.loads(payload)


# ---------------------------------------------------------------------------
# Membership frames
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Hello:
    """A dialing worker's first frame. ``fingerprint`` is the worker's
    ``specs.spec_fingerprint`` when it was built from a local spec, or
    None to request the coordinator's spec (shipped in ``Admit``)."""

    fingerprint: dict | None = None
    host: str = ""
    pid: int = 0


@dataclasses.dataclass
class Admit:
    """Admission reply: the worker's assigned node id and the portable
    ``WorkerSpec`` to build (coordinator-stamped fingerprint included,
    verified worker-side after deserialization)."""

    node_id: int
    spec: object


@dataclasses.dataclass
class Reject:
    """Admission refusal with an actionable reason (the fingerprint
    field that differed, or a full fleet)."""

    reason: str


@dataclasses.dataclass
class Shutdown:
    """Coordinator-initiated teardown (the fabric's queue sentinel — an
    explicit frame, since a bare None is indistinguishable from EOF)."""


@dataclasses.dataclass(frozen=True)
class FabricElastic:
    """Deterministic elastic-membership schedule for tests and the
    scenario lab (production fleets leave this None and grow by simply
    dialing more workers in).

    ``join_after``: ``((node, n), ...)`` — node's worker is launched
    only once the coordinator has completed n batches (a mid-campaign
    join; until then the slot idles and its shards land on peers).
    ``reject``: number of extra loopback workers launched with an
    intentionally mismatched fingerprint — each must be rejected at
    admission (they are never part of the fleet)."""

    join_after: tuple = ()
    reject: int = 0


class _ConnEvent:
    """Hub-to-pool membership event, delivered on the result queue so
    all pool mutation stays on the drain thread."""

    __slots__ = ("kind", "conn", "msg")

    def __init__(self, kind: str, conn: "_Conn", msg=None):
        self.kind = kind                 # "hello" | "leave"
        self.conn = conn
        self.msg = msg


# ---------------------------------------------------------------------------
# Connection + selector hub (the FabricCoordinator's I/O plane)
# ---------------------------------------------------------------------------


class _Conn:
    """One worker connection: inbound frame decoder, outbound byte
    buffer (pumped by the hub on writability), and byte counters."""

    def __init__(self, sock: socket.socket, addr):
        self.sock = sock
        self.addr = addr
        self.decoder = FrameDecoder()
        self.out = bytearray()
        self.alive = True
        self.node: int | None = None     # assigned at admission
        self.close_after_flush = False   # rejected dialer: hang up
        self.bytes_tx = 0
        self.bytes_rx = 0


class FabricCoordinator:
    """The fabric's socket hub: accepts dialing workers, reads frames,
    pumps outbound buffers — all on one daemon thread over non-blocking
    sockets. Inbound messages and membership events are handed to the
    pool through its result queue; outbound sends are enqueued from the
    pool thread via ``send`` and flushed by the selector loop."""

    def __init__(self, host: str, port: int, events: queue_lib.Queue):
        self.events = events
        self.sel = selectors.DefaultSelector()
        self.listener = socket.create_server((host, port))
        self.listener.setblocking(False)
        self.addr: tuple[str, int] = self.listener.getsockname()[:2]
        # self-pipe: wakes the selector when another thread enqueues an
        # outbound frame or asks for shutdown
        self._wake_r, self._wake_w = socket.socketpair()
        self._wake_r.setblocking(False)
        self._wake_w.setblocking(False)
        self.conns: list[_Conn] = []
        self._lock = threading.Lock()
        self._pending: list[tuple[_Conn, bytes | None]] = []
        self._closing = False
        self.sel.register(self.listener, selectors.EVENT_READ,
                          ("accept", None))
        self.sel.register(self._wake_r, selectors.EVENT_READ,
                          ("wake", None))
        self.thread = threading.Thread(target=self._loop, daemon=True,
                                       name="adaparse-fabric-hub")
        self.thread.start()

    # -- pool-thread API -----------------------------------------------------

    def send(self, conn: _Conn, obj) -> None:
        """Enqueue one frame for ``conn`` (thread-safe; the hub thread
        does the actual socket write)."""
        self._enqueue(conn, encode_frame(obj))

    def hangup(self, conn: _Conn) -> None:
        """Close ``conn`` once its outbound buffer has flushed (the
        rejected-admission goodbye)."""
        self._enqueue(conn, None)

    def bytes_totals(self) -> tuple[int, int]:
        return (sum(c.bytes_tx for c in self.conns),
                sum(c.bytes_rx for c in self.conns))

    def close(self, linger_s: float = 1.0) -> None:
        """Stop the hub: give queued outbound frames (the Shutdown
        goodbyes) a bounded window to flush, then tear down."""
        deadline = time.time() + linger_s
        while time.time() < deadline:
            with self._lock:
                pending = bool(self._pending)
            if not pending and not any(c.out for c in self.conns
                                       if c.alive):
                break
            time.sleep(0.01)
        self._closing = True
        self._wake()
        self.thread.join(timeout=2.0)
        for c in self.conns:
            c.alive = False
            try:
                c.sock.close()
            except OSError:
                pass
        for s in (self.listener, self._wake_r, self._wake_w):
            try:
                s.close()
            except OSError:
                pass
        self.sel.close()

    def _enqueue(self, conn: _Conn, data: bytes | None) -> None:
        with self._lock:
            self._pending.append((conn, data))
        self._wake()

    def _wake(self) -> None:
        try:
            self._wake_w.send(b"x")
        except (OSError, BlockingIOError):
            pass                         # a pending wake already queued

    # -- hub thread ----------------------------------------------------------

    def _loop(self) -> None:
        while not self._closing:
            for key, mask in self.sel.select(timeout=0.1):
                kind, conn = key.data
                if kind == "accept":
                    self._accept()
                elif kind == "wake":
                    self._drain_wake()
                else:
                    if mask & selectors.EVENT_READ:
                        self._read(conn)
                    if mask & selectors.EVENT_WRITE and conn.alive:
                        self._write(conn)

    def _accept(self) -> None:
        while True:
            try:
                sock, addr = self.listener.accept()
            except (BlockingIOError, OSError):
                return
            sock.setblocking(False)
            try:
                sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            except OSError:
                pass
            conn = _Conn(sock, addr)
            self.conns.append(conn)
            self.sel.register(sock, selectors.EVENT_READ, ("conn", conn))

    def _drain_wake(self) -> None:
        try:
            while self._wake_r.recv(4096):
                pass
        except (BlockingIOError, OSError):
            pass
        with self._lock:
            items, self._pending = self._pending, []
        for conn, data in items:
            if not conn.alive:
                continue
            if data is None:
                conn.close_after_flush = True
            else:
                conn.out += data
            self._want_write(conn)

    def _want_write(self, conn: _Conn) -> None:
        try:
            self.sel.modify(conn.sock,
                            selectors.EVENT_READ | selectors.EVENT_WRITE,
                            ("conn", conn))
        except (KeyError, ValueError, OSError):
            pass                         # already dropped

    def _read(self, conn: _Conn) -> None:
        while conn.alive:
            try:
                data = conn.sock.recv(1 << 16)
            except (BlockingIOError, InterruptedError):
                return
            except OSError:
                self._drop(conn)
                return
            if not data:
                self._drop(conn)
                return
            conn.bytes_rx += len(data)
            try:
                for msg in conn.decoder.feed(data):
                    if isinstance(msg, Hello):
                        self.events.put(_ConnEvent("hello", conn, msg))
                    else:
                        self.events.put(msg)
            except Exception:
                # corrupt frame (version skew, truncated pickle): the
                # connection is unusable — treat as a leave
                self._drop(conn)
                return

    def _write(self, conn: _Conn) -> None:
        try:
            while conn.out:
                sent = conn.sock.send(conn.out)
                conn.bytes_tx += sent
                del conn.out[:sent]
        except (BlockingIOError, InterruptedError):
            return
        except OSError:
            self._drop(conn)
            return
        # buffer flushed
        if conn.close_after_flush:
            self._drop(conn)
            return
        try:
            self.sel.modify(conn.sock, selectors.EVENT_READ,
                            ("conn", conn))
        except (KeyError, ValueError, OSError):
            pass

    def _drop(self, conn: _Conn) -> None:
        if not conn.alive:
            return
        conn.alive = False
        try:
            self.sel.unregister(conn.sock)
        except (KeyError, ValueError, OSError):
            pass
        try:
            conn.sock.close()
        except OSError:
            pass
        self.events.put(_ConnEvent("leave", conn))


# ---------------------------------------------------------------------------
# Queue/process adapters (what the inherited pool machinery touches)
# ---------------------------------------------------------------------------


class _ConnSender:
    """Task-queue-shaped sender: ``put`` frames the message onto the
    connection's outbound buffer (the fabric's ``task_qs[w]``)."""

    def __init__(self, hub: FabricCoordinator, conn: _Conn):
        self.hub = hub
        self.conn = conn

    def put(self, msg) -> None:
        self.hub.send(self.conn, Shutdown() if msg is None else msg)

    put_nowait = put

    def qsize(self) -> int:
        return 0


class _NullSender:
    """Placeholder sender for a slot no worker has claimed yet; the
    dispatch loop never targets it (the slot is quiet), so a put here
    would be a bug."""

    def put(self, msg) -> None:
        if msg is not None:
            raise RuntimeError("task dispatched to an unclaimed fabric "
                               "slot (pool bug: the slot is quiet)")

    put_nowait = put


class _ConnHandle:
    """Process-shaped liveness handle: the connection is the process —
    EOF/reset reads as a crash to the inherited liveness police."""

    def __init__(self, conn: _Conn):
        self.conn = conn

    def is_alive(self) -> bool:
        return self.conn.alive


class _PendingHandle:
    """A reserved slot awaiting its worker (a deferred joiner or an
    external dialer): alive — the police must not declare a never-
    joined slot crashed — but held quiet until admission completes."""

    def is_alive(self) -> bool:
        return True


# ---------------------------------------------------------------------------
# FabricWorkerPool
# ---------------------------------------------------------------------------


class FabricWorkerPool(ProcessWorkerPool):
    """``ProcessWorkerPool`` with the transport swapped for the fabric:
    the coordinator hub above accepts TCP workers and feeds their
    messages into a plain ``queue.Queue`` result queue, per-worker
    ``task_qs`` frame onto the sockets, and ``procs`` are connection
    liveness handles — so the inherited drain loop, in-flight window,
    dedup gate, liveness police, and ``scheduler.reissue_candidates``
    re-routing run unchanged over a fleet of remote processes."""

    #: heartbeat ``sent_mono`` stamps come from other machines'
    #: CLOCK_MONOTONIC — not comparable with the coordinator's; the
    #: queue-delay diagnostic stays same-host-only (core/workers)
    _mono_comparable = False

    def __init__(self, ecfg, xcfg, router, corpus_cfg, n_nodes: int,
                 ingest_nodes: list[int], reparse_nodes: list[int],
                 pools: list[str] | None, *,
                 alpha_of: dict[int, float] | None = None, cache=None,
                 probe_cfg=None, image_degraded=False,
                 text_degraded=False, backend_specs: tuple = ()):
        self._hub: FabricCoordinator | None = None
        self._local_procs: list = []
        self._validate_xcfg(xcfg)
        cache_dir, cache_max = self._cache_cfg(cache)
        self._init_state(ecfg, xcfg, n_nodes, ingest_nodes,
                         reparse_nodes, pools, alpha_of,
                         has_cache=cache_dir is not None)
        self._shm = None                 # payloads always ride inline
        router = spec_lib.portable_router(router)
        fault = getattr(xcfg, "fault_injection", None)
        self._specs = [
            self._worker_spec(
                i, router=router, corpus_cfg=corpus_cfg,
                cache_dir=cache_dir, cache_max=cache_max,
                probe_cfg=probe_cfg, image_degraded=image_degraded,
                text_degraded=text_degraded,
                backend_specs=tuple(backend_specs), fault=fault,
                shm_base=None, resp_slots=0)
            for i in range(n_nodes)]
        # one fingerprint for the fleet (worker-invariant fields only);
        # stamped on every shipped spec so the worker side can verify
        # nothing drifted in transit, and compared against any
        # fingerprint a dialing worker presents
        self._expected_fp = spec_lib.spec_fingerprint(self._specs[0])
        self._specs = [dataclasses.replace(s, fingerprint=self._expected_fp)
                       for s in self._specs]

        elastic = getattr(xcfg, "fabric", None)
        self._deferred: dict[int, int] = (
            dict(elastic.join_after) if elastic is not None else {})
        bad = set(self._deferred) - set(range(n_nodes))
        if bad:
            raise ValueError(f"fabric.join_after names unknown nodes "
                             f"{sorted(bad)} (fleet has {n_nodes})")
        self._joins = 0
        self._leaves = 0
        self._rejected = 0
        self._left: set[int] = set()
        self._tx_flushed = 0
        self._rx_flushed = 0

        host, port = parse_addr(
            getattr(xcfg, "coordinator", None) or "127.0.0.1:0")
        self.result_q: queue_lib.Queue = queue_lib.Queue()
        self._hub = FabricCoordinator(host, port, self.result_q)
        self.addr = self._hub.addr

        # every slot starts unclaimed: a placeholder liveness handle, a
        # null sender, and quiet status (no work lands until admission)
        self.procs = [_PendingHandle() for _ in range(n_nodes)]
        self.task_qs = [_NullSender() for _ in range(n_nodes)]
        self._beat = [time.time()] * n_nodes
        self._quiet = set(range(n_nodes))
        self._unassigned: deque[int] = deque(
            i for i in range(n_nodes) if i not in self._deferred)

        try:
            if getattr(xcfg, "fabric_spawn", True):
                from repro.launch.fabric_worker import spawn_loopback

                for _ in range(len(self._unassigned)):
                    self._local_procs.append(spawn_loopback(self.addr))
                for _ in range(int(getattr(elastic, "reject", 0) or 0)
                               if elastic is not None else 0):
                    self._local_procs.append(spawn_loopback(
                        self.addr, fingerprint=MISMATCHED_FINGERPRINT))
            self._await_ready()
        except BaseException:
            self.close()
            raise

    # -- startup -------------------------------------------------------------

    def _await_ready(self) -> None:
        """Block until every initially-launched slot is admitted and
        has sent its ready heartbeat (deferred joiners excepted — they
        arrive mid-campaign)."""
        want = set(range(self.n_nodes)) - set(self._deferred)
        ready: set[int] = set()
        deadline = time.time() + self.xcfg.worker_start_timeout_s
        while len(ready & want) < len(want):
            timeout = deadline - time.time()
            if timeout <= 0:
                missing = sorted(want - ready)
                raise RuntimeError(
                    f"fabric workers {missing} not ready within "
                    f"{self.xcfg.worker_start_timeout_s}s "
                    f"(worker_start_timeout_s)")
            try:
                msg = self.result_q.get(timeout=min(timeout, 0.2))
            except queue_lib.Empty:
                continue
            if isinstance(msg, BatchDone) and msg.error is not None:
                raise RuntimeError(f"fabric worker {msg.worker} failed "
                                   f"to start:\n{msg.error}")
            self._handle(msg)
            if isinstance(msg, Heartbeat):
                ready.add(msg.worker)

    # -- membership ----------------------------------------------------------

    def _handle(self, msg) -> None:
        if isinstance(msg, _ConnEvent):
            if msg.kind == "hello":
                self._admit(msg.conn, msg.msg)
            else:
                self._on_leave(msg.conn)
            return
        super()._handle(msg)
        if isinstance(msg, BatchDone):
            self._maybe_spawn_joiners()

    def _admission_error(self, hello: Hello) -> str | None:
        """The admission decision, pure: None admits, a string rejects
        with that actionable reason."""
        if hello.fingerprint is not None:
            mismatch = spec_lib.describe_mismatch(self._expected_fp,
                                                  hello.fingerprint)
            if mismatch:
                return mismatch
        if not self._unassigned:
            return (f"fleet full: all {self.n_nodes} fabric slots are "
                    f"claimed and no join is scheduled — grow "
                    f"ExecutorConfig.n_nodes to admit more workers")
        return None

    def _admit(self, conn: _Conn, hello: Hello) -> None:
        who = f"{hello.host or conn.addr[0]}:{hello.pid}"
        reason = self._admission_error(hello)
        if reason is not None:
            self._rejected += 1
            obs.metrics().count("fabric.rejected")
            rec = obs.recorder()
            if rec.enabled:
                rec.span("admission_rejected", who, time.time(), 0.0,
                         detail=reason)
            self._hub.send(conn, Reject(reason))
            self._hub.hangup(conn)
            return
        w = self._unassigned.popleft()
        conn.node = w
        self.procs[w] = _ConnHandle(conn)
        self.task_qs[w] = _ConnSender(self._hub, conn)
        self._beat[w] = time.time()
        self._joins += 1
        obs.metrics().count("fabric.joins")
        rec = obs.recorder()
        if rec.enabled:
            rec.span("join", w, time.time(), 0.0, node=w,
                     detail=f"admitted {who} as node {w}")
        self._hub.send(conn, Admit(w, self._specs[w]))
        # the slot stays quiet until the worker's ready heartbeat
        # arrives (engine build time); work routed meanwhile lands on
        # peers exactly like a wedged node's would

    def _on_leave(self, conn: _Conn) -> None:
        w = conn.node
        if w is None or w in self._left:
            return
        self._left.add(w)
        self._leaves += 1
        obs.metrics().count("fabric.leaves")
        rec = obs.recorder()
        if rec.enabled:
            rec.span("leave", w, time.time(), 0.0, node=w,
                     abandoned=True,
                     detail=f"connection to node {w} closed "
                            f"(crash or detach)")
        # the inherited police sees the dead handle on its next tick
        # and re-issues the node's in-flight batches to live peers

    def _maybe_spawn_joiners(self) -> None:
        """FabricElastic.join_after: launch a deferred slot's worker
        once enough batches have completed (checked after every
        BatchDone — ``_batches_done`` only moves there)."""
        if not self._deferred:
            return
        due = [w for w, n in self._deferred.items()
               if self._batches_done >= n]
        for w in due:
            del self._deferred[w]
            self._unassigned.append(w)
            if getattr(self.xcfg, "fabric_spawn", True):
                from repro.launch.fabric_worker import spawn_loopback

                self._local_procs.append(spawn_loopback(self.addr))

    def live_ingest_nodes(self) -> list[int]:
        """The ingest nodes a round boundary may shard over right now:
        admitted, connected, and not quiet (a slot awaiting its joiner
        or a wedged straggler sheds its shards to peers)."""
        return [i for i in self.ingest_nodes
                if i not in self._dead and i not in self._quiet
                and self.procs[i].is_alive()]

    # -- counters ------------------------------------------------------------

    def _flush_net_counters(self) -> None:
        """Fold the hub's connection byte counters into the coordinator
        registry as fleet-wide fabric.* counters (delta since the last
        flush — counters are monotone)."""
        if self._hub is None:
            return
        tx, rx = self._hub.bytes_totals()
        if tx > self._tx_flushed:
            obs.metrics().count("fabric.bytes_tx", tx - self._tx_flushed)
            self._tx_flushed = tx
        if rx > self._rx_flushed:
            obs.metrics().count("fabric.bytes_rx", rx - self._rx_flushed)
            self._rx_flushed = rx

    def _police(self) -> None:
        self._flush_net_counters()
        super()._police()

    def finalize(self, n_docs: int, cache, hits0: int, miss0: int) -> dict:
        self._flush_net_counters()
        return super().finalize(n_docs, cache, hits0, miss0)

    # -- teardown ------------------------------------------------------------

    def close(self) -> None:
        for q in getattr(self, "task_qs", []):
            try:
                q.put_nowait(None)       # framed Shutdown to live conns
            except Exception:
                pass
        self._flush_net_counters()
        if self._hub is not None:
            self._hub.close()
            self._hub = None
        for p in self._local_procs:
            p.join(timeout=3.0)
        for p in self._local_procs:
            if p.is_alive():
                p.terminate()
                p.join(timeout=1.0)
        self._local_procs = []
