"""Pluggable parser-backend runtime (§5, App. C).

Every parser the engine can dispatch to is a ``ParserBackend``: a bundle
of capability/cost metadata (device placement, preferred batch shape,
warm-start cost) plus the two operations the hot path needs —
``parse_batch`` and ``cost_batch``. The engine, campaign executor, and
scheduler dispatch through the registry instead of name-string
branching, so heterogeneous fleets (cheap CPU heuristics next to
expensive GPU models, the paper's core resource-scaling axis) and
user-defined backends plug in without touching the core.

The default registry wraps every ``parsers.ParserSpec`` in a
``ChannelBackend`` (the simulated corruption-channel fleet). A custom
backend only needs an ``info`` attribute and the two methods; register
it with ``register_backend`` and reference it by name from
``EngineConfig.cheap`` / ``EngineConfig.expensive``.

``ResultStore`` is the campaign result-store interface: batch-granular
records keyed by (config fingerprint, batch_key, doc ids). Because
every batch is parsed with a stateless rng stream derived from its
batch key, replaying a stored batch is bit-identical to re-parsing it —
a warm campaign reproduces the cold record set exactly while skipping
the parse work. Two implementations: ``ResultCache`` (in-process,
thread-safe dict) and ``DiskResultStore`` (content-addressed on-disk
records with LRU byte-budget eviction, so campaigns replay across
process restarts — ``serve.py --cache-dir``).
"""
from __future__ import annotations

import contextlib
import dataclasses
import fcntl
import hashlib
import json
import os
import pickle
import threading
from typing import Protocol, runtime_checkable

import numpy as np

from repro.core import obs
from repro.core.parsers import MEAN_PAGES, PARSER_SPECS, ParserSpec
from repro.data.synthetic import CorpusConfig, Document, corrupt_documents


@dataclasses.dataclass(frozen=True)
class BackendInfo:
    """Capability/cost metadata the runtime schedules against."""

    name: str
    device: str                      # "cpu" | "gpu"
    pdf_per_sec_node: float          # single-node steady-state throughput
    warm_start_s: float = 0.0        # model-load time (15 s for ViT, §5.2)
    batch_docs: int = 256            # preferred dispatch batch (B_p analogue)
    io_bytes_per_doc: float = 2e6
    scale_cap_nodes: int = 10 ** 9   # e.g. Marker fails to scale past 10


@runtime_checkable
class ParserBackend(Protocol):
    """What the engine needs from a parser: metadata + batched parse/cost."""

    info: BackendInfo

    def parse_batch(self, docs: list[Document], cfg: CorpusConfig,
                    rng: np.random.RandomState, *, image_degraded=False,
                    text_degraded=False) -> list[list[np.ndarray]]: ...

    def cost_batch(self, docs: list[Document]) -> np.ndarray: ...


class ChannelBackend:
    """Default backend: a ``ParserSpec``'s corruption channel (the
    simulated parser fleet calibrated against Table 1 / Fig. 5)."""

    def __init__(self, spec: ParserSpec):
        self.spec = spec
        self.info = BackendInfo(
            name=spec.name,
            device="gpu" if spec.uses_gpu else "cpu",
            pdf_per_sec_node=spec.pdf_per_sec_node,
            warm_start_s=spec.warmup_s,
            batch_docs=10 if spec.uses_gpu else 256,   # page-batched B_p
            io_bytes_per_doc=spec.io_bytes_per_doc,
            scale_cap_nodes=spec.scale_cap_nodes)

    def parse_batch(self, docs, cfg, rng, *, image_degraded=False,
                    text_degraded=False):
        return corrupt_documents(docs, self.spec.channel, cfg, rng,
                                 image_degraded=image_degraded,
                                 text_degraded=text_degraded)

    def cost_batch(self, docs):
        pages = np.fromiter((d.n_pages for d in docs), np.float64,
                            count=len(docs))
        return pages / MEAN_PAGES / self.spec.pdf_per_sec_node


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


_REGISTRY: dict[str, ParserBackend] = {}


def register_backend(backend: ParserBackend,
                     overwrite: bool = False) -> ParserBackend:
    name = backend.info.name
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"backend {name!r} already registered "
                         f"(pass overwrite=True to replace)")
    _REGISTRY[name] = backend
    return backend


def unregister_backend(name: str) -> None:
    _REGISTRY.pop(name, None)


def get_backend(name: str) -> ParserBackend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown parser backend {name!r}; registered: "
                       f"{sorted(_REGISTRY)}") from None


def available_backends() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


for _spec in PARSER_SPECS.values():
    register_backend(ChannelBackend(_spec))


# ---------------------------------------------------------------------------
# Campaign result stores
# ---------------------------------------------------------------------------


@runtime_checkable
class ResultStore(Protocol):
    """Batch-granular result store the engine replays campaigns from.

    Keys are (engine fingerprint, batch_key, doc ids); values are the
    emitted ``ParseRecord`` lists. Batch parsing is stateless in the
    batch key, so a replay is exactly the records a re-parse would
    produce. Implementations must be thread-safe: the executor's
    prefetch workers look batches up concurrently with the consumer
    storing results."""

    hits: int
    misses: int

    def lookup(self, key): ...

    def store(self, key, records) -> None: ...

    def flush(self) -> None: ...

    def __len__(self) -> int: ...


class ResultCache:
    """In-process ``ResultStore``: a thread-safe dict (no persistence,
    no eviction — the warm-campaign fast path within one process)."""

    def __init__(self):
        self._store: dict = {}
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def lookup(self, key):
        """Records for ``key`` or None; counts a hit or a miss."""
        with self._lock:
            recs = self._store.get(key)
            if recs is None:
                self.misses += 1
                obs.metrics().count("store.misses")
            else:
                self.hits += 1
                obs.metrics().count("store.hits")
            return recs

    def store(self, key, records) -> None:
        with self._lock:
            self._store[key] = list(records)
        obs.metrics().count("store.puts")

    def flush(self) -> None:
        """Nothing buffered in-process."""

    def __len__(self) -> int:
        return len(self._store)


class DiskResultStore:
    """Content-addressed on-disk ``ResultStore``.

    Each batch's records are pickled to ``<sha256(key)>.pkl`` under
    ``cache_dir``; a sidecar index carries a logical access clock per
    entry, so LRU eviction order is a pure function of the operation
    sequence (never of filesystem mtimes) and survives process
    restarts. ``max_bytes`` bounds the total record bytes: after every
    store, least-recently-used entries are evicted until the store fits
    (the just-written entry is always retained, so a single oversized
    batch cannot wedge the store). The budget and the LRU order are
    **fleet-wide**: eviction folds the on-disk snapshot + WAL under the
    exclusive flock before choosing victims, so N processes sharing one
    dir enforce one shared ``max_bytes``, not N local ones.

    The index is a compacted snapshot (``index.json``) plus a
    write-ahead log (``index.wal``): every store / hit-bump / eviction
    appends one JSON line to the WAL — O(1) however large the store
    grows, where rewriting the full snapshot per op would scale the
    index cost with the campaign (millions of batches). Opening the
    store replays the WAL on top of the snapshot (undecodable lines —
    a torn append from a killed process — are skipped); compaction —
    rewrite the snapshot atomically, truncate the WAL — runs on
    ``flush()``, whenever eviction shrinks the entry set, and
    automatically every ``COMPACT_EVERY`` WAL ops so recovery stays
    bounded.

    **Multi-process safety** (the worker runtime shares one store dir
    across N worker processes, core/workers): WAL appends are single
    ``O_APPEND`` writes of one full line (atomic on a local
    filesystem) taken under a *shared* ``flock``; compaction takes the
    *exclusive* ``flock`` and folds the **on-disk** state — snapshot
    plus the full WAL, which includes every other process's appends —
    into the new snapshot before truncating the WAL. Two processes
    over one dir therefore never drop each other's WAL tail: an op
    another process appended between our last replay and our
    compaction is folded in, not truncated away. (Every mutation
    appends its WAL line before any compaction can run, so the disk
    state is always a superset of any process's in-memory index.)

    Because keys embed the engine's content fingerprint (router weights
    included) and batch parsing is stateless in the batch key, a warm
    campaign in a *new process* replays the cold record set
    byte-identically (``serve.py --cache-dir``)."""

    INDEX_NAME = "index.json"
    WAL_NAME = "index.wal"
    LOCK_NAME = ".index.lock"
    COMPACT_EVERY = 4096            # WAL ops between automatic compactions

    def __init__(self, cache_dir: str, max_bytes: int | None = None):
        self.dir = str(cache_dir)
        self.max_bytes = max_bytes
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        os.makedirs(self.dir, exist_ok=True)
        self._index_path = os.path.join(self.dir, self.INDEX_NAME)
        self._wal_path = os.path.join(self.dir, self.WAL_NAME)
        self._lock_path = os.path.join(self.dir, self.LOCK_NAME)
        # persistent handles: one lock fd (flock'd per op) and one
        # O_APPEND WAL fd — compaction truncates the WAL *in place*
        # (same inode), so appends through this fd stay valid across
        # any process's compactions and the per-op cost stays one
        # flock + one write instead of two open/close round-trips
        self._lock_fd = os.open(self._lock_path,
                                os.O_CREAT | os.O_RDWR, 0o644)
        self._wal_fd = os.open(self._wal_path,
                               os.O_APPEND | os.O_CREAT | os.O_WRONLY,
                               0o644)
        self._load_index()

    def close(self) -> None:
        """Release the persistent index/lock fds (safe to call twice;
        also runs at GC). The store is unusable afterwards."""
        for attr in ("_wal_fd", "_lock_fd"):
            fd = getattr(self, attr, None)
            if fd is not None:
                try:
                    os.close(fd)
                except OSError:
                    pass
                setattr(self, attr, None)

    def __del__(self):
        self.close()

    # -- index ---------------------------------------------------------------

    @contextlib.contextmanager
    def _flock(self, exclusive: bool):
        """Cross-process advisory lock on the index: shared for WAL
        appends and recovery reads, exclusive for compaction (which
        rewrites the snapshot and truncates the WAL). Intra-process
        callers are already serialized by ``self._lock``, so holding
        one lock fd per store instance is safe."""
        fcntl.flock(self._lock_fd,
                    fcntl.LOCK_EX if exclusive else fcntl.LOCK_SH)
        try:
            yield
        finally:
            fcntl.flock(self._lock_fd, fcntl.LOCK_UN)

    def _disk_sig(self):
        """Cheap change-detector for the on-disk index: the snapshot's
        (inode, size) — a compaction atomically replaces it, changing
        the inode — plus the WAL size through our O_APPEND fd.
        ``_append_wal`` advances the expected WAL size for our own
        appends, so the signature only diverges when *another* process
        publishes ops; divergence makes the next budget check fold the
        full on-disk state (a coincidental match merely defers the fold
        to whichever process does observe the divergence)."""
        try:
            st = os.stat(self._index_path)
            idx = (st.st_ino, st.st_size)
        except FileNotFoundError:
            idx = None
        return idx, os.fstat(self._wal_fd).st_size

    def _in_sync(self) -> bool:
        return self._synced_sig is not None \
            and self._disk_sig() == self._synced_sig

    def _mark_synced(self) -> None:
        self._synced_sig = self._disk_sig()

    def _read_disk_state(self) -> tuple[int, dict, int]:
        """(seq, entries, wal_ops) folded from the on-disk snapshot +
        WAL — the union of every process's published ops. ``put``
        entries whose record file is gone are skipped; undecodable WAL
        lines (torn appends from a killed process) are skipped, not
        treated as end-of-log, so one crash cannot hide other
        processes' later appends."""
        entries: dict[str, list[int]] = {}   # digest -> [seq, bytes]
        try:
            with open(self._index_path) as f:
                data = json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            data = {}
        seq = int(data.get("seq", 0))
        for digest, (s, nbytes) in data.get("entries", {}).items():
            if os.path.exists(self._record_path(digest)):
                entries[digest] = [int(s), int(nbytes)]
        wal_ops = 0
        try:
            f = open(self._wal_path)
        except FileNotFoundError:
            return seq, entries, 0
        with f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    op = json.loads(line)
                except json.JSONDecodeError:
                    continue
                kind, digest = op.get("op"), op.get("d")
                s = int(op.get("s", seq))
                seq = max(seq, s)
                if kind == "put":
                    if os.path.exists(self._record_path(digest)):
                        entries[digest] = [s, int(op["b"])]
                elif kind == "hit":
                    if digest in entries:
                        entries[digest][0] = s
                elif kind == "del":
                    entries.pop(digest, None)
                wal_ops += 1
        return seq, entries, wal_ops

    def _load_index(self) -> None:
        with self._flock(exclusive=False):
            # sig first: an append racing in after the stat makes the
            # signature read stale (forcing a refold), never fresh
            sig = self._disk_sig()
            self._seq, self._entries, self._wal_ops = \
                self._read_disk_state()
        self._synced_sig = sig

    def _append_wal(self, op: dict) -> None:
        # one full line per op in a single O_APPEND write: atomic on a
        # local fs, so concurrent processes never interleave mid-line.
        # The shared flock fences against a concurrent compaction
        # truncating the WAL between our write and its fold-in.
        line = (json.dumps(op) + "\n").encode()
        with self._flock(exclusive=False):
            os.write(self._wal_fd, line)
        self._wal_ops += 1
        if self._synced_sig is not None:
            idx, wal = self._synced_sig
            self._synced_sig = (idx, wal + len(line))

    def _save_index(self) -> None:
        """Compaction: fold the **on-disk** snapshot + WAL (every
        process's published ops, not just ours) into a fresh snapshot,
        truncate the WAL, and adopt the merged view as our in-memory
        index. Runs under the exclusive flock so no other process can
        append between the fold and the truncate."""
        with self._flock(exclusive=True):
            seq, entries, _ = self._read_disk_state()
            self._seq = max(self._seq, seq)
            self._entries = entries
            tmp = self._index_path + ".tmp"
            with open(tmp, "w") as f:
                json.dump({"seq": self._seq, "entries": self._entries}, f)
            os.replace(tmp, self._index_path)
            open(self._wal_path, "w").close()
            self._mark_synced()
        self._wal_ops = 0

    def _record_path(self, digest: str) -> str:
        return os.path.join(self.dir, digest + ".pkl")

    @staticmethod
    def _digest(key) -> str:
        # repr of the key tuple (config fingerprint, batch_key, doc ids)
        # is stable across processes: ints, floats (shortest round-trip
        # repr), strings, bools, tuples only
        return hashlib.sha256(repr(key).encode()).hexdigest()

    # -- ResultStore protocol ------------------------------------------------

    def lookup(self, key):
        """Records for ``key`` or None; counts a hit or a miss and bumps
        the entry's LRU clock on hit (one appended WAL line — the
        snapshot is never rewritten per lookup)."""
        digest = self._digest(key)
        with self._lock:
            ent = self._entries.get(digest)
            if ent is None:
                self.misses += 1
                obs.metrics().count("store.misses")
                return None
            try:
                with open(self._record_path(digest), "rb") as f:
                    blob = f.read()
            except FileNotFoundError:       # evicted behind our back
                del self._entries[digest]
                self._append_wal({"op": "del", "d": digest})
                self.misses += 1
                obs.metrics().count("store.misses")
                return None
            self._seq += 1
            ent[0] = self._seq
            self.hits += 1
            obs.metrics().count("store.hits")
            self._append_wal({"op": "hit", "d": digest, "s": self._seq})
            if self._wal_ops >= self.COMPACT_EVERY:
                self._save_index()
            return pickle.loads(blob)

    def store(self, key, records) -> None:
        digest = self._digest(key)
        blob = pickle.dumps(list(records), protocol=4)
        with self._lock:
            # tmp + rename: a concurrent reader in another worker
            # process sees the old complete record or the new complete
            # record, never a torn pickle (records are deterministic in
            # the key, so either version is the same payload)
            path = self._record_path(digest)
            tmp = f"{path}.tmp{os.getpid()}"
            with open(tmp, "wb") as f:
                f.write(blob)
            os.replace(tmp, path)
            self._seq += 1
            self._entries[digest] = [self._seq, len(blob)]
            self._append_wal({"op": "put", "d": digest, "s": self._seq,
                              "b": len(blob)})
            obs.metrics().count("store.puts")
            if not self._evict(keep=digest) \
                    and self._wal_ops >= self.COMPACT_EVERY:
                self._save_index()

    def _evict(self, keep: str | None = None) -> bool:
        """Drop least-recently-used entries until under ``max_bytes``.
        Deterministic: order follows the logical clock, never mtimes.
        ``keep`` (the just-written digest) is never chosen as victim.

        The byte total and the LRU victim choice are **fleet-wide**:
        the local in-memory view alone would let N workers sharing one
        dir overshoot ``max_bytes`` by ~N× and evict against a stale
        clock. When the local view may be stale (another process
        published ops since our last sync — ``_disk_sig`` diverged) or
        is over budget, fold the on-disk snapshot + WAL under the
        exclusive flock (``_read_disk_state``), choose victims from
        the merged view, and compact inline: the folded-and-evicted
        view *is* the new snapshot, so no ``del`` WAL lines and no
        separate compaction pass are needed. Evicted ``.pkl`` files
        another process still indexes surface there as the
        evicted-behind-our-back miss path in ``lookup``."""
        if self.max_bytes is None:
            return False
        if self._in_sync() and \
                sum(b for _, b in self._entries.values()) <= self.max_bytes:
            return False                 # sole recent writer, under budget
        with self._flock(exclusive=True):
            seq, entries, wal_ops = self._read_disk_state()
            self._seq = max(self._seq, seq)
            self._entries = entries
            total = sum(b for _, b in entries.values())
            if total <= self.max_bytes:
                # stale signature only: adopt the merged view as-is
                self._wal_ops = wal_ops
                self._mark_synced()
                return False
            evicted = False
            while total > self.max_bytes:
                victims = [d for d in entries if d != keep]
                if not victims:
                    break
                victim = min(victims, key=lambda d: entries[d][0])
                total -= entries[victim][1]
                del entries[victim]
                evicted = True
                obs.metrics().count("store.evictions")
                try:
                    os.remove(self._record_path(victim))
                except FileNotFoundError:
                    pass
            tmp = self._index_path + ".tmp"
            with open(tmp, "w") as f:
                json.dump({"seq": self._seq, "entries": self._entries}, f)
            os.replace(tmp, self._index_path)
            open(self._wal_path, "w").close()
            self._wal_ops = 0
            self._mark_synced()
        return evicted

    def flush(self) -> None:
        """Compact: fold outstanding WAL ops into the snapshot."""
        with self._lock:
            if self._wal_ops:
                self._save_index()

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def total_bytes(self) -> int:
        """Fleet-wide record bytes: when another process has published
        ops since our last sync, fold the on-disk snapshot + WAL first
        (shared flock), so the total a caller checks against
        ``max_bytes`` is the same total eviction enforces — not a
        per-process undercount."""
        with self._lock:
            if not self._in_sync():
                self._load_index()
            return sum(b for _, b in self._entries.values())
