"""Pluggable parser-backend runtime (§5, App. C).

Every parser the engine can dispatch to is a ``ParserBackend``: a bundle
of capability/cost metadata (device placement, preferred batch shape,
warm-start cost) plus the two operations the hot path needs —
``parse_batch`` and ``cost_batch``. The engine, campaign executor, and
scheduler dispatch through the registry instead of name-string
branching, so heterogeneous fleets (cheap CPU heuristics next to
expensive GPU models, the paper's core resource-scaling axis) and
user-defined backends plug in without touching the core.

The default registry wraps every ``parsers.ParserSpec`` in a
``ChannelBackend`` (the simulated corruption-channel fleet). A custom
backend only needs an ``info`` attribute and the two methods; register
it with ``register_backend`` and reference it by name from
``EngineConfig.cheap`` / ``EngineConfig.expensive``.

``ResultCache`` is the campaign result cache: batch-granular records
keyed by (config fingerprint, batch_key, doc ids). Because every batch
is parsed with a stateless rng stream derived from its batch key,
replaying a cached batch is bit-identical to re-parsing it — a warm
campaign reproduces the cold record set exactly while skipping the
parse work.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Protocol, runtime_checkable

import numpy as np

from repro.core.parsers import MEAN_PAGES, PARSER_SPECS, ParserSpec
from repro.data.synthetic import CorpusConfig, Document, corrupt_documents


@dataclasses.dataclass(frozen=True)
class BackendInfo:
    """Capability/cost metadata the runtime schedules against."""

    name: str
    device: str                      # "cpu" | "gpu"
    pdf_per_sec_node: float          # single-node steady-state throughput
    warm_start_s: float = 0.0        # model-load time (15 s for ViT, §5.2)
    batch_docs: int = 256            # preferred dispatch batch (B_p analogue)
    io_bytes_per_doc: float = 2e6
    scale_cap_nodes: int = 10 ** 9   # e.g. Marker fails to scale past 10


@runtime_checkable
class ParserBackend(Protocol):
    """What the engine needs from a parser: metadata + batched parse/cost."""

    info: BackendInfo

    def parse_batch(self, docs: list[Document], cfg: CorpusConfig,
                    rng: np.random.RandomState, *, image_degraded=False,
                    text_degraded=False) -> list[list[np.ndarray]]: ...

    def cost_batch(self, docs: list[Document]) -> np.ndarray: ...


class ChannelBackend:
    """Default backend: a ``ParserSpec``'s corruption channel (the
    simulated parser fleet calibrated against Table 1 / Fig. 5)."""

    def __init__(self, spec: ParserSpec):
        self.spec = spec
        self.info = BackendInfo(
            name=spec.name,
            device="gpu" if spec.uses_gpu else "cpu",
            pdf_per_sec_node=spec.pdf_per_sec_node,
            warm_start_s=spec.warmup_s,
            batch_docs=10 if spec.uses_gpu else 256,   # page-batched B_p
            io_bytes_per_doc=spec.io_bytes_per_doc,
            scale_cap_nodes=spec.scale_cap_nodes)

    def parse_batch(self, docs, cfg, rng, *, image_degraded=False,
                    text_degraded=False):
        return corrupt_documents(docs, self.spec.channel, cfg, rng,
                                 image_degraded=image_degraded,
                                 text_degraded=text_degraded)

    def cost_batch(self, docs):
        pages = np.fromiter((d.n_pages for d in docs), np.float64,
                            count=len(docs))
        return pages / MEAN_PAGES / self.spec.pdf_per_sec_node


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


_REGISTRY: dict[str, ParserBackend] = {}


def register_backend(backend: ParserBackend,
                     overwrite: bool = False) -> ParserBackend:
    name = backend.info.name
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"backend {name!r} already registered "
                         f"(pass overwrite=True to replace)")
    _REGISTRY[name] = backend
    return backend


def unregister_backend(name: str) -> None:
    _REGISTRY.pop(name, None)


def get_backend(name: str) -> ParserBackend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown parser backend {name!r}; registered: "
                       f"{sorted(_REGISTRY)}") from None


def available_backends() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


for _spec in PARSER_SPECS.values():
    register_backend(ChannelBackend(_spec))


# ---------------------------------------------------------------------------
# Campaign result cache
# ---------------------------------------------------------------------------


class ResultCache:
    """Content-keyed batch result cache shared across campaigns.

    Keys are (engine fingerprint, batch_key, doc ids); values are the
    emitted ``ParseRecord`` lists. Batch parsing is stateless in the
    batch key, so a replay is exactly the records a re-parse would
    produce. Thread-safe: the executor's prefetch workers look batches
    up concurrently with the consumer storing results.
    """

    def __init__(self):
        self._store: dict = {}
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def lookup(self, key):
        """Records for ``key`` or None; counts a hit or a miss."""
        with self._lock:
            recs = self._store.get(key)
            if recs is None:
                self.misses += 1
            else:
                self.hits += 1
            return recs

    def store(self, key, records) -> None:
        with self._lock:
            self._store[key] = list(records)

    def __len__(self) -> int:
        return len(self._store)
