"""Parser-output quality metrics (§2.2, §7.2).

Texts are token-id sequences (numpy int arrays). Metrics:

- BLEU      — corpus/doc n-gram precision (n<=4), brevity penalty.
- ROUGE-L   — LCS-based F-measure (jit-compiled DP, vmapped over pages).
- CAR       — character accuracy rate ~ 1 - normalized word-level
              Levenshtein, weighted by per-token character length.
- coverage  — fraction of reference pages with any matching output.
- AT        — accepted tokens: fraction of tokens in documents whose BLEU
              exceeds a threshold (the paper's goodput numerator).

``score_batch`` is the vectorized per-document front door: all three
hypothesis-vs-reference scorers run over one padded (B, max_len) batch
with length masks — the hot path of the online quality probe
(core/quality), which scores sampled campaign batches at round
granularity. BLEU dispatches through the fused n-gram op
(kernels/ngram_score: Pallas equality-matrix kernel on TPU, sorted
n-gram multisets on CPU); the old jitted pairwise matcher is kept as
``_bleu_batch``, the baseline the ``engine.score_kernel_speedup`` bench
measures against. ``rouge_l`` and ``car`` are thin corpus-mean wrappers
over it.
"""
from __future__ import annotations

import functools
from collections import Counter

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ngram_score.ops import ngram_bleu

# ---------------------------------------------------------------------------
# BLEU
# ---------------------------------------------------------------------------


def _ngram_counts(seq: np.ndarray, n: int) -> Counter:
    if len(seq) < n:
        return Counter()
    view = np.lib.stride_tricks.sliding_window_view(seq, n)
    return Counter(map(tuple, view))


def bleu(ref: np.ndarray, hyp: np.ndarray, max_n: int = 4,
         smooth: float = 1e-9) -> float:
    """Sentence/document BLEU with uniform weights and brevity penalty."""
    ref = np.asarray(ref).ravel()
    hyp = np.asarray(hyp).ravel()
    if len(hyp) == 0:
        return 0.0
    log_p = 0.0
    for n in range(1, max_n + 1):
        rc, hc = _ngram_counts(ref, n), _ngram_counts(hyp, n)
        total = max(sum(hc.values()), 1)
        clipped = sum(min(c, rc[g]) for g, c in hc.items())
        log_p += np.log((clipped + smooth) / total)
    log_p /= max_n
    bp = min(1.0, np.exp(1.0 - len(ref) / max(len(hyp), 1)))
    return float(bp * np.exp(log_p))


def corpus_bleu(refs: list[np.ndarray], hyps: list[np.ndarray],
                max_n: int = 4) -> float:
    """Corpus BLEU (pooled n-gram counts, standard Papineni definition)."""
    tot_clip = np.zeros(max_n)
    tot = np.zeros(max_n)
    ref_len = hyp_len = 0
    for ref, hyp in zip(refs, hyps):
        ref = np.asarray(ref).ravel()
        hyp = np.asarray(hyp).ravel()
        ref_len += len(ref)
        hyp_len += len(hyp)
        for n in range(1, max_n + 1):
            rc, hc = _ngram_counts(ref, n), _ngram_counts(hyp, n)
            tot[n - 1] += sum(hc.values())
            tot_clip[n - 1] += sum(min(c, rc[g]) for g, c in hc.items())
    if hyp_len == 0:
        return 0.0
    log_p = np.mean(np.log((tot_clip + 1e-9) / np.maximum(tot, 1)))
    bp = min(1.0, np.exp(1.0 - ref_len / max(hyp_len, 1)))
    return float(bp * np.exp(log_p))


# ---------------------------------------------------------------------------
# LCS (ROUGE-L) and Levenshtein (CAR) — jitted DPs
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("max_len",))
def _lcs_batch(a: jax.Array, b: jax.Array, la: jax.Array, lb: jax.Array,
               max_len: int) -> jax.Array:
    """Batched LCS length. a, b: (B, max_len) padded; la, lb true lengths."""

    def one(a1, b1, la1, lb1):
        valid_b = jnp.arange(max_len) < lb1

        def row(prev, ai):
            i, prev_row = prev
            match = (b1 == ai) & valid_b & (i < la1)
            # new[j] = max(prev_row[j], new[j-1], prev_row[j-1] + match)

            def cell(carry, inp):
                diag, pj, m = inp
                best = jnp.maximum(pj, jnp.maximum(carry, diag + m))
                return best, best

            diag = jnp.concatenate([jnp.zeros(1, jnp.int32), prev_row[:-1]])
            _, new_row = jax.lax.scan(
                cell, jnp.int32(0), (diag, prev_row, match.astype(jnp.int32)))
            return (i + 1, new_row), None

        (_, last), _ = jax.lax.scan(row, (jnp.int32(0),
                                          jnp.zeros(max_len, jnp.int32)), a1)
        return last[jnp.maximum(lb1 - 1, 0)] * (lb1 > 0)

    return jax.vmap(one)(a, b, la, lb)


@functools.partial(jax.jit, static_argnames=("max_len",))
def _edit_distance_batch(a: jax.Array, b: jax.Array, la: jax.Array,
                         lb: jax.Array, max_len: int) -> jax.Array:
    """Batched word-level Levenshtein distance on padded id sequences."""

    def one(a1, b1, la1, lb1):
        init = jnp.minimum(jnp.arange(1, max_len + 1), lb1).astype(jnp.int32)

        def row(carry, inp):
            i, prev_row = carry
            ai = inp
            active = i < la1
            sub = (b1 != ai).astype(jnp.int32)
            diag = jnp.concatenate([jnp.array([0], jnp.int32) + i,
                                    prev_row[:-1]])

            def cell(left, inp2):
                up, dg, s = inp2
                best = jnp.minimum(jnp.minimum(up + 1, left + 1), dg + s)
                return best, best

            _, new_row = jax.lax.scan(cell, i + 1, (prev_row, diag, sub))
            new_row = jnp.where(active, new_row, prev_row)
            return (i + 1, new_row), None

        (_, last), _ = jax.lax.scan(row, (jnp.int32(0), init), a1)
        return last[jnp.maximum(lb1 - 1, 0)] * (lb1 > 0) + \
            jnp.where(lb1 > 0, 0, la1)

    return jax.vmap(one)(a, b, la, lb)


@functools.partial(jax.jit, static_argnames=("max_len", "max_n"))
def _bleu_batch(ref: jax.Array, hyp: jax.Array, lr: jax.Array,
                lh: jax.Array, max_len: int, max_n: int = 4) -> jax.Array:
    """Batched sentence BLEU on padded id sequences (uniform n<=max_n
    weights, brevity penalty, 1e-9 smoothing — the same rule as the host
    ``bleu``, truncated to ``max_len`` tokens).

    Superseded on the probe hot path by kernels/ngram_score (same
    clipped-count rule, fused); kept as the XLA baseline that
    ``engine.score_kernel_speedup`` is measured against.

    Clipped counts without Counters: hyp occurrence j of an n-gram g is
    creditable iff its occurrence rank among equal hyp grams is below
    g's count in the reference — both ranks come from pairwise n-gram
    equality matrices, built incrementally (an (n+1)-gram match is an
    n-gram match AND a token match one position later)."""
    smooth = 1e-9
    pos = jnp.arange(max_len)

    def one(r1, h1, lr1, lh1):
        eq_hh = h1[:, None] == h1[None, :]
        eq_hr = h1[:, None] == r1[None, :]
        m_hh, m_hr = eq_hh, eq_hr
        log_p = jnp.float32(0.0)
        for n in range(1, max_n + 1):
            if n > 1:
                # extend (n-1)-gram matches by the token at offset n-1
                w = max_len - (n - 1)
                m_hh = m_hh & jnp.zeros_like(eq_hh).at[:w, :w].set(
                    eq_hh[n - 1:, n - 1:])
                m_hr = m_hr & jnp.zeros_like(eq_hr).at[:w, :w].set(
                    eq_hr[n - 1:, n - 1:])
            ph = pos <= lh1 - n          # valid hyp n-gram starts
            pr = pos <= lr1 - n
            total = jnp.maximum(lh1 - n + 1, 0)
            # per-hyp-gram reference count and prior-occurrence rank
            rc = jnp.sum(m_hr & pr[None, :], axis=1)
            occ = jnp.sum(jnp.tril(m_hh, -1) & ph[None, :], axis=1)
            clipped = jnp.sum(ph & (occ < rc))
            log_p += jnp.log((clipped + smooth) / jnp.maximum(total, 1))
        log_p /= max_n
        bp = jnp.minimum(1.0, jnp.exp(1.0 - lr1 / jnp.maximum(lh1, 1)))
        return jnp.where(lh1 > 0, bp * jnp.exp(log_p), 0.0)

    return jax.vmap(one)(ref, hyp, lr, lh)


def _pad_batch(seqs: list[np.ndarray], max_len: int):
    arr = np.zeros((len(seqs), max_len), np.int32) - 1
    lens = np.zeros(len(seqs), np.int32)
    for i, s in enumerate(seqs):
        s = np.asarray(s).ravel()[:max_len]
        arr[i, :len(s)] = s
        lens[i] = len(s)
    return arr, lens


SCORE_METRICS = ("bleu", "rouge", "car")


def score_batch(refs: list[np.ndarray], hyps: list[np.ndarray],
                max_len: int = 512, beta: float = 1.2,
                metrics: tuple[str, ...] = SCORE_METRICS
                ) -> dict[str, np.ndarray]:
    """Vectorized per-document scores for a batch of (reference,
    hypothesis) token streams — the quality probe's hot path.

    Every sequence is truncated/padded to ``max_len`` and scored with
    length masks: BLEU by the fused n-gram op (``ngram_bleu``), ROUGE-L
    and CAR by the jitted batched DPs (``_lcs_batch``,
    ``_edit_distance_batch``); an empty hypothesis scores 0 on every
    metric. The batch dimension is padded to the next
    power of two (zero-length rows, sliced off before returning) so the
    jit caches stay bounded however probe sample sizes vary.

    Returns ``{"bleu"|"rouge"|"car": (n,), "ref_len": (n,),
    "hyp_len": (n,)}`` float64 arrays, restricted to ``metrics``.
    """
    if len(refs) != len(hyps):
        raise ValueError(f"score_batch needs one hypothesis per reference "
                         f"(got {len(refs)} refs, {len(hyps)} hyps)")
    bad = [m for m in metrics if m not in SCORE_METRICS]
    if bad:
        raise ValueError(f"unknown score metrics {bad}; "
                         f"choose from {SCORE_METRICS}")
    n = len(refs)
    if n == 0:
        out = {m: np.zeros(0) for m in metrics}
        out["ref_len"] = np.zeros(0)
        out["hyp_len"] = np.zeros(0)
        return out
    n_pad = 1 << (n - 1).bit_length()
    fill = [np.zeros(0, np.int32)] * (n_pad - n)
    ra, rl = _pad_batch(list(refs) + fill, max_len)
    ha, hl = _pad_batch(list(hyps) + fill, max_len)
    rln = rl.astype(np.float64)[:n]
    hln = hl.astype(np.float64)[:n]
    out: dict[str, np.ndarray] = {}
    if "bleu" in metrics:
        out["bleu"] = ngram_bleu(ra, ha, rl, hl)[:n]
    if "rouge" in metrics:
        lcs = np.asarray(_lcs_batch(jnp.asarray(ra), jnp.asarray(ha),
                                    jnp.asarray(rl), jnp.asarray(hl),
                                    max_len), np.float64)[:n]
        p = lcs / np.maximum(hln, 1)
        r = lcs / np.maximum(rln, 1)
        out["rouge"] = ((1 + beta ** 2) * p * r
                        / np.maximum(r + beta ** 2 * p, 1e-9))
    if "car" in metrics:
        dist = np.asarray(_edit_distance_batch(jnp.asarray(ra),
                                               jnp.asarray(ha),
                                               jnp.asarray(rl),
                                               jnp.asarray(hl),
                                               max_len), np.float64)[:n]
        out["car"] = np.clip(1.0 - dist / np.maximum(rln, 1), 0.0, 1.0)
    out["ref_len"] = rln
    out["hyp_len"] = hln
    return out


def rouge_l(refs: list[np.ndarray], hyps: list[np.ndarray],
            max_len: int = 512, beta: float = 1.2) -> float:
    """Mean ROUGE-L F score over documents (truncated to max_len tokens)."""
    return float(np.mean(score_batch(refs, hyps, max_len, beta,
                                     metrics=("rouge",))["rouge"]))


def car(refs: list[np.ndarray], hyps: list[np.ndarray],
        max_len: int = 512, mean_word_chars: float = 5.0) -> float:
    """Character accuracy rate ≈ 1 - char-edit/chars, where word-level
    edits are weighted by mean word length (substituted words cost a full
    word of characters; the id->charseq map is deterministic so this is a
    tight proxy)."""
    return float(np.mean(score_batch(refs, hyps, max_len,
                                     metrics=("car",))["car"]))


# ---------------------------------------------------------------------------
# Document-level aggregates
# ---------------------------------------------------------------------------


def coverage(ref_pages: list[list[np.ndarray]],
             hyp_pages: list[list[np.ndarray]]) -> float:
    """Fraction of reference pages retrieved (non-empty parser output)."""
    total = got = 0
    for rp, hp in zip(ref_pages, hyp_pages):
        total += len(rp)
        got += sum(1 for i in range(len(rp))
                   if i < len(hp) and len(np.asarray(hp[i]).ravel()) > 0)
    return got / max(total, 1)


def accepted_tokens(refs: list[np.ndarray], hyps: list[np.ndarray],
                    doc_bleus: list[float] | None = None,
                    threshold: float = 0.4) -> float:
    """AT: fraction of (reference) tokens living in documents whose BLEU
    exceeds the acceptance threshold."""
    if doc_bleus is None:
        doc_bleus = [bleu(r, h) for r, h in zip(refs, hyps)]
    tok = np.array([len(np.asarray(r).ravel()) for r in refs], np.float64)
    ok = np.array([b > threshold for b in doc_bleus], np.float64)
    return float((tok * ok).sum() / max(tok.sum(), 1))


def evaluate_parser(refs: list[np.ndarray], hyps: list[np.ndarray],
                    ref_pages=None, hyp_pages=None,
                    at_threshold: float = 0.4) -> dict:
    doc_bleus = [bleu(r, h) for r, h in zip(refs, hyps)]
    out = {
        "bleu": float(np.mean(doc_bleus)),
        "rouge": rouge_l(refs, hyps),
        "car": car(refs, hyps),
        "at": accepted_tokens(refs, hyps, doc_bleus, at_threshold),
    }
    if ref_pages is not None:
        out["coverage"] = coverage(ref_pages, hyp_pages)
    return out
