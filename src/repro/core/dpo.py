"""Three-stage DPO post-training (Appendix A).

Stage 1 (SFT): minimize L_REG = E ||pi_theta(x^1) - y||^2 — the encoder
regresses the m per-parser accuracies from the default parser's first-page
text.

Stage 2 (DPO): the encoder is reused inside a scorer g_phi (encoder +
positive scalar head) with a frozen reference copy g_ref; minimize

  L_DPO = -E log sigma( beta * [ log g(x+) - log g_ref(x+)
                               - log g(x-) + log g_ref(x-) ] )

over preference pairs (x+, x-) of parser outputs for the same page.

Stage 3: re-fit the regression head at a lowered learning rate on D.

All stages run on the same Param tree; ``fit`` loops are jit-stepped with
the repro.optim stack.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import unwrap
from repro.configs.base import EncoderConfig
from repro.models import encoder as enc_lib
from repro.optim import adamw, apply_updates, chain_clip


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------


def dpo_loss(params_raw, ref_params_raw, cfg: EncoderConfig, batch: dict,
             beta: float = 1.0) -> jax.Array:
    """batch: tok_pos/mask_pos and tok_neg/mask_neg (B, S)."""
    g_pos = enc_lib.preference_score(params_raw, cfg, batch["tok_pos"],
                                     batch["mask_pos"])
    g_neg = enc_lib.preference_score(params_raw, cfg, batch["tok_neg"],
                                     batch["mask_neg"])
    r_pos = enc_lib.preference_score(ref_params_raw, cfg, batch["tok_pos"],
                                     batch["mask_pos"])
    r_neg = enc_lib.preference_score(ref_params_raw, cfg, batch["tok_neg"],
                                     batch["mask_neg"])
    logits = beta * (jnp.log(g_pos) - jnp.log(r_pos)
                     - jnp.log(g_neg) + jnp.log(r_neg))
    return -jnp.mean(jax.nn.log_sigmoid(logits))


def pref_accuracy(params_raw, cfg, batch) -> jax.Array:
    g_pos = enc_lib.preference_score(params_raw, cfg, batch["tok_pos"],
                                     batch["mask_pos"])
    g_neg = enc_lib.preference_score(params_raw, cfg, batch["tok_neg"],
                                     batch["mask_neg"])
    return jnp.mean((g_pos > g_neg).astype(jnp.float32))


# ---------------------------------------------------------------------------
# Trainers
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FitResult:
    params_raw: dict
    losses: list[float]


def _batches(n, bs, rng):
    idx = rng.permutation(n)
    for i in range(0, n - bs + 1, bs):
        yield idx[i:i + bs]


def fit_regression(params_raw, cfg: EncoderConfig, data: dict,
                   steps: int = 200, lr: float = 1e-3, bs: int = 16,
                   seed: int = 0) -> FitResult:
    """Stage 1 / Stage 3. data: tokens (N,S), mask (N,S), targets (N,m),
    optional target_mask."""
    opt = chain_clip(adamw(lr, weight_decay=0.01), 1.0)
    state = opt.init(params_raw)

    @jax.jit
    def step_fn(params, state, step, batch):
        loss, grads = jax.value_and_grad(
            lambda p: enc_lib.regression_loss(p, cfg, batch))(params)
        updates, state = opt.update(grads, state, params, step)
        return apply_updates(params, updates), state, loss

    rng = np.random.RandomState(seed)
    n = data["tokens"].shape[0]
    losses = []
    it = 0
    while it < steps:
        for bidx in _batches(n, min(bs, n), rng):
            if it >= steps:
                break
            batch = {k: jnp.asarray(v[bidx]) for k, v in data.items()}
            params_raw, state, loss = step_fn(params_raw, state,
                                              jnp.asarray(it), batch)
            losses.append(float(loss))
            it += 1
    return FitResult(params_raw, losses)


def fit_dpo(params_raw, cfg: EncoderConfig, pref_data: dict,
            steps: int = 100, lr: float = 5e-4, bs: int = 8,
            beta: float = 1.0, seed: int = 0) -> FitResult:
    """Stage 2. pref_data: tok_pos/mask_pos/tok_neg/mask_neg (M, S)."""
    ref_params = jax.tree_util.tree_map(lambda x: x, params_raw)  # frozen copy
    opt = chain_clip(adamw(lr, weight_decay=0.0), 1.0)
    state = opt.init(params_raw)

    @jax.jit
    def step_fn(params, state, step, batch):
        loss, grads = jax.value_and_grad(
            lambda p: dpo_loss(p, ref_params, cfg, batch, beta))(params)
        updates, state = opt.update(grads, state, params, step)
        return apply_updates(params, updates), state, loss

    rng = np.random.RandomState(seed)
    n = pref_data["tok_pos"].shape[0]
    losses = []
    it = 0
    while it < steps:
        for bidx in _batches(n, min(bs, n), rng):
            if it >= steps:
                break
            batch = {k: jnp.asarray(v[bidx]) for k, v in pref_data.items()}
            params_raw, state, loss = step_fn(params_raw, state,
                                              jnp.asarray(it), batch)
            losses.append(float(loss))
            it += 1
    return FitResult(params_raw, losses)


def three_stage_posttrain(params_raw, cfg: EncoderConfig, reg_data: dict,
                          pref_data: dict, *, sft_steps=200, dpo_steps=100,
                          refit_steps=60, lr=1e-3, seed=0):
    """The full Appendix-A recipe. Returns (params, diagnostics)."""
    r1 = fit_regression(params_raw, cfg, reg_data, steps=sft_steps, lr=lr,
                        seed=seed)
    r2 = fit_dpo(r1.params_raw, cfg, pref_data, steps=dpo_steps, lr=lr / 2,
                 seed=seed)
    r3 = fit_regression(r2.params_raw, cfg, reg_data, steps=refit_steps,
                        lr=lr / 10, seed=seed)
    return r3.params_raw, {
        "sft_loss": r1.losses, "dpo_loss": r2.losses,
        "refit_loss": r3.losses,
    }


def regression_r2(params_raw, cfg, data: dict) -> np.ndarray:
    """Per-parser R^2 of the accuracy regression (paper: 40.0% / 46.5%)."""
    pred = np.asarray(enc_lib.predict_accuracies(
        params_raw, cfg, jnp.asarray(data["tokens"]),
        jnp.asarray(data["mask"])))
    y = np.asarray(data["targets"])
    ss_res = np.sum((pred - y) ** 2, axis=0)
    ss_tot = np.sum((y - y.mean(axis=0)) ** 2, axis=0) + 1e-12
    return 1.0 - ss_res / ss_tot
