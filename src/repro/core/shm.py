"""Zero-copy shared-memory transport for the process worker runtime.

Batch payloads — ingest documents, forwarded ``PreparedBatch``/plan
pairs, and result records — are numpy-array-heavy: pickling them through
the multiprocessing queues copies every page twice (dumps + pipe) and
was the measured ~3.5% per-batch overhead bounding
``engine.mp_wall_speedup``. This module moves the bulk bytes through
``multiprocessing.shared_memory`` segments instead, leaving the
``PrepareTask``/``CompleteTask``/``BatchDone`` dataclasses as
control-plane messages only: a message carries a small ``ShmRef``
(arena name, slot, generation, array descriptors, and the packed
non-array structure) while the array bytes live in a fixed-layout slot.

Layout and safety:

- ``ShmArena``: one segment, ``n_slots`` fixed-size slots. Each slot is
  ``[u64 generation][u32 state][u32 pad][payload]``. The generation tag
  makes re-issue/dedup safe: every write bumps it, and a reader verifies
  it before *and* after copying out, so a straggler handed a slot that
  was reclaimed and reused (its task already completed elsewhere) gets a
  clean ``ShmStale`` instead of silently scoring the wrong batch.
- Task payloads live in one coordinator-owned arena; slots are reclaimed
  only when their task completes (first completion wins), so every
  outstanding attempt of a live task reads valid bytes.
- Results travel through one small per-worker response arena. The worker
  allocates ``STATE_FREE`` slots and flips them ``STATE_FULL`` after
  writing; the coordinator flips them back after copy-out — one writer
  per transition, no locks.
- The *coordinator* creates and unlinks every segment (workers only
  attach), so ``ProcessWorkerPool.close()`` — and the crash-recovery
  path, immediately at worker death — removes every ``/dev/shm`` entry
  even when a worker died mid-batch via ``os._exit``. Attachers never
  touch the (process-tree-shared) resource tracker: until 3.13 an
  attach also registers the name, but the tracker's cache is a set, so
  the duplicate is harmless and the creator's ``unlink()`` unregisters
  exactly once (see ``_attach``).
- Every path degrades to the inline pickled payload: ``/dev/shm``
  unavailable (one warning), a payload larger than the slot, or slot
  exhaustion all return ``None`` from the encode side and the caller
  ships the object in the control message as before. Fallbacks trade
  speed, never correctness.

The codec is exact: dtype/shape/bytes of every array survive, scalars
and strings ride in the (pickled) header structure, so decode(encode(x))
is byte-identical — the invariant the record-parity tests assert.
"""
from __future__ import annotations

import dataclasses
import struct
import warnings
from multiprocessing import shared_memory

import numpy as np

from repro.core import obs

STATE_FREE = 0
STATE_FULL = 1

_HDR = 16                      # u64 generation, u32 state, u32 pad
_GEN = struct.Struct("<Q")
_STATE = struct.Struct("<I")


class ShmStale(RuntimeError):
    """The slot's generation no longer matches the ref: the task was
    completed elsewhere and the slot reclaimed. The reader's attempt is
    a loser of the first-completion race — report and drop."""


class ShmUnavailable(RuntimeError):
    """Shared memory could not be created (e.g. no usable /dev/shm)."""


def _attach(name: str) -> shared_memory.SharedMemory:
    """Attach to an existing segment. Until 3.13 this also registers
    with the (process-tree-shared) resource tracker; that's a set, so
    the duplicate is harmless, and the creator's ``unlink()``
    unregisters exactly once — attachers must NOT unregister themselves
    or they would strip the creator's registration."""
    return shared_memory.SharedMemory(name=name)


# ---------------------------------------------------------------------------
# Codec: python structure -> (header tree, array bytes)
# ---------------------------------------------------------------------------


def _dataclass_registry() -> dict:
    """Payload dataclasses by name (lazy: core imports stay acyclic —
    engine/scheduler never import this module)."""
    from repro.core.engine import ParseRecord, PreparedBatch
    from repro.core.scheduler import BatchPlan
    from repro.data.synthetic import Document

    return {"Document": Document, "ParseRecord": ParseRecord,
            "PreparedBatch": PreparedBatch, "BatchPlan": BatchPlan}


_BY_NAME: dict | None = None
_BY_CLS: dict | None = None


def _registry():
    global _BY_NAME, _BY_CLS
    if _BY_NAME is None:
        _BY_NAME = _dataclass_registry()
        _BY_CLS = {cls: name for name, cls in _BY_NAME.items()}
    return _BY_NAME, _BY_CLS


def _pack(obj, arrays: list) -> tuple:
    """Strip numpy arrays out of ``obj`` into ``arrays``; the returned
    tagged tree carries everything else (and array indices)."""
    if obj is None or isinstance(obj, (bool, int, float, str, bytes)):
        return ("x", obj)
    if isinstance(obj, np.ndarray):
        arrays.append(np.ascontiguousarray(obj))
        return ("np", len(arrays) - 1)
    if isinstance(obj, np.generic):            # numpy scalar, dtype-exact
        return ("ns", obj.dtype.str, obj.tobytes())
    if isinstance(obj, np.random.RandomState):
        return ("rs", _pack(obj.get_state(legacy=True), arrays))
    if isinstance(obj, list):
        return ("li", [_pack(v, arrays) for v in obj])
    if isinstance(obj, tuple):
        return ("tu", [_pack(v, arrays) for v in obj])
    if isinstance(obj, dict):
        return ("di", [(_pack(k, arrays), _pack(v, arrays))
                       for k, v in obj.items()])
    _, by_cls = _registry()
    name = by_cls.get(type(obj))
    if name is not None:
        return ("dc", name,
                [_pack(getattr(obj, f.name), arrays)
                 for f in dataclasses.fields(obj)])
    raise TypeError(f"shm codec cannot pack {type(obj).__name__}; "
                    f"register the dataclass or keep it control-plane")


def _unpack(node: tuple, arrays: list):
    tag = node[0]
    if tag == "x":
        return node[1]
    if tag == "np":
        return arrays[node[1]]
    if tag == "ns":
        return np.frombuffer(node[2], dtype=np.dtype(node[1]))[0]
    if tag == "rs":
        rs = np.random.RandomState()
        rs.set_state(_unpack(node[1], arrays))
        return rs
    if tag == "li":
        return [_unpack(v, arrays) for v in node[1]]
    if tag == "tu":
        return tuple(_unpack(v, arrays) for v in node[1])
    if tag == "di":
        return {_unpack(k, arrays): _unpack(v, arrays)
                for k, v in node[1]}
    if tag == "dc":
        by_name, _ = _registry()
        cls = by_name[node[1]]
        return cls(*[_unpack(v, arrays) for v in node[2]])
    raise TypeError(f"shm codec: unknown tag {tag!r}")


@dataclasses.dataclass
class ShmRef:
    """Control-plane pointer to one packed payload: everything a peer
    needs to attach the named arena (geometry included) and reconstruct
    the object from its slot."""

    arena: str
    slot: int
    generation: int
    nbytes: int
    n_slots: int                   # arena geometry, for attachers
    slot_bytes: int
    header: object                 # packed non-array tree
    descs: tuple                   # ((dtype_str, shape, offset), ...)


def pack_payload(obj):
    """-> (header tree, [contiguous arrays], descs, total payload bytes).

    ``descs`` assigns each array an offset in a contiguous slot layout."""
    arrays: list[np.ndarray] = []
    tree = _pack(obj, arrays)
    descs, off = [], 0
    for a in arrays:
        descs.append((a.dtype.str, a.shape, off))
        off += a.nbytes
    return tree, arrays, tuple(descs), off


def unpack_payload(header, descs, buf) -> object:
    arrays = []
    for dtype_str, shape, off in descs:
        dt = np.dtype(dtype_str)
        n = int(np.prod(shape, dtype=np.int64))
        arr = np.frombuffer(buf, dtype=dt, count=n,
                            offset=off).reshape(shape).copy()
        arrays.append(arr)
    return _unpack(header, arrays)


# ---------------------------------------------------------------------------
# Arena: one segment, fixed generation-tagged slots
# ---------------------------------------------------------------------------


class ShmArena:
    """``n_slots`` fixed-size generation-tagged slots in one shared
    segment. The creator owns the name (and must ``unlink``); attachers
    only map it. All slot-state transitions are single-writer (see
    module docstring), so plain loads/stores suffice."""

    def __init__(self, name: str, n_slots: int, slot_bytes: int, *,
                 create: bool):
        self.n_slots = n_slots
        self.slot_bytes = slot_bytes
        self._stride = _HDR + slot_bytes
        self.created = create
        try:
            if create:
                self._seg = shared_memory.SharedMemory(
                    name=name, create=True, size=n_slots * self._stride)
                self._seg.buf[:] = b"\0" * len(self._seg.buf)
            else:
                self._seg = _attach(name)
        except OSError as e:
            raise ShmUnavailable(
                f"cannot {'create' if create else 'attach'} shared-memory "
                f"arena {name!r}: {e}") from e
        self.name = self._seg.name.lstrip("/")

    # -- slot header ---------------------------------------------------------

    def _off(self, slot: int) -> int:
        return slot * self._stride

    def generation(self, slot: int) -> int:
        return _GEN.unpack_from(self._seg.buf, self._off(slot))[0]

    def set_generation(self, slot: int, gen: int) -> None:
        _GEN.pack_into(self._seg.buf, self._off(slot), gen)

    def state(self, slot: int) -> int:
        return _STATE.unpack_from(self._seg.buf, self._off(slot) + 8)[0]

    def set_state(self, slot: int, state: int) -> None:
        _STATE.pack_into(self._seg.buf, self._off(slot) + 8, state)

    # -- payload -------------------------------------------------------------

    def write(self, slot: int, gen: int, arrays, descs) -> None:
        base = self._off(slot) + _HDR
        buf = self._seg.buf
        for a, (_dt, _shape, off) in zip(arrays, descs):
            if a.nbytes:
                buf[base + off:base + off + a.nbytes] = \
                    memoryview(a.reshape(-1)).cast("B")
        self.set_generation(slot, gen)

    def read(self, ref: ShmRef) -> object:
        """Copy out and decode, verifying the generation tag before and
        after the copy (a concurrent reclaim+rewrite can't go unseen)."""
        if self.generation(ref.slot) != ref.generation:
            raise ShmStale(f"slot {ref.slot} of {self.name} is at "
                           f"generation {self.generation(ref.slot)}, "
                           f"ref wants {ref.generation} (task already "
                           f"completed elsewhere)")
        base = self._off(ref.slot) + _HDR
        raw = bytes(self._seg.buf[base:base + ref.nbytes])
        if self.generation(ref.slot) != ref.generation:
            raise ShmStale(f"slot {ref.slot} of {self.name} was "
                           f"reclaimed during read")
        return unpack_payload(ref.header, ref.descs, raw)

    # -- lifecycle -----------------------------------------------------------

    def close(self) -> None:
        try:
            self._seg.close()
        except (OSError, BufferError):
            pass

    def unlink(self) -> None:
        try:
            self._seg.unlink()
        except FileNotFoundError:
            pass


# ---------------------------------------------------------------------------
# Coordinator side
# ---------------------------------------------------------------------------


def _arena_names(base: str, n_workers: int) -> tuple[str, list[str]]:
    return f"{base}-t", [f"{base}-r{w}" for w in range(n_workers)]


class CoordinatorShmTransport:
    """The coordinator's half: owns (creates, reclaims, unlinks) the
    task arena and every per-worker response arena.

    Arenas are sized lazily from the first packed payload (slot capacity
    2x the first task payload; response slots 4x, since a forwarded
    ``PreparedBatch`` carries the docs plus their extracted/parsed
    pages), so idle pools cost nothing and typical campaigns never hit
    the inline fallback."""

    MIN_SLOT = 1 << 20

    def __init__(self, base: str, n_workers: int, n_task_slots: int,
                 n_resp_slots: int):
        self.base = base
        self.n_workers = n_workers
        self.n_task_slots = n_task_slots
        self.n_resp_slots = n_resp_slots
        self._task: ShmArena | None = None
        self._resp: list[ShmArena] = []
        self._free: list[int] = []
        self._gen = 0
        self._disabled = False
        self.fallbacks = 0             # payloads shipped inline instead

    # -- setup ---------------------------------------------------------------

    def _ensure_arenas(self, first_payload_bytes: int) -> bool:
        if self._task is not None:
            return True
        if self._disabled:
            return False
        task_name, resp_names = _arena_names(self.base, self.n_workers)
        slot = max(2 * first_payload_bytes, self.MIN_SLOT)
        resp_slot = max(4 * first_payload_bytes, self.MIN_SLOT)
        made: list[ShmArena] = []
        try:
            self._task = ShmArena(task_name, self.n_task_slots, slot,
                                  create=True)
            made.append(self._task)
            for name in resp_names:
                a = ShmArena(name, self.n_resp_slots, resp_slot,
                             create=True)
                made.append(a)
                self._resp.append(a)
        except ShmUnavailable as e:
            for a in made:
                a.close()
                a.unlink()
            self._task = None
            self._resp = []
            self._disabled = True
            warnings.warn(
                f"shared-memory transport unavailable ({e}); falling "
                f"back to pickled batch payloads", RuntimeWarning,
                stacklevel=3)
            return False
        self._free = list(range(self.n_task_slots))
        return True

    # -- task payloads (coordinator -> worker) -------------------------------

    def _fallback(self) -> None:
        self.fallbacks += 1
        obs.metrics().count("shm.fallbacks")

    def encode_task(self, obj) -> ShmRef | None:
        """Pack ``obj`` into a free task slot; None means ship inline
        (transport disabled, payload too big, or slots exhausted)."""
        if self._disabled:
            return None
        try:
            header, arrays, descs, nbytes = pack_payload(obj)
        except TypeError:
            self._fallback()
            return None
        if not self._ensure_arenas(nbytes):
            self._fallback()
            return None
        if nbytes > self._task.slot_bytes or not self._free:
            self._fallback()
            return None
        slot = self._free.pop()
        self._gen += 1
        self._task.write(slot, self._gen, arrays, descs)
        return ShmRef(self._task.name, slot, self._gen, nbytes,
                      self._task.n_slots, self._task.slot_bytes, header,
                      descs)

    def free_task(self, ref: ShmRef | None) -> None:
        """Reclaim a completed task's slot. Bumping the generation here
        (not just at reuse) turns any straggler read of a freed slot
        into an immediate clean ``ShmStale``."""
        if ref is None or self._task is None:
            return
        self._gen += 1
        self._task.set_generation(ref.slot, self._gen)
        self._free.append(ref.slot)

    # -- result payloads (worker -> coordinator) -----------------------------

    def take_result(self, ref: ShmRef) -> object:
        """Decode a worker's response payload and free its slot."""
        arena = self._resp_by_name(ref.arena)
        try:
            return arena.read(ref)
        finally:
            arena.set_state(ref.slot, STATE_FREE)

    def release_result(self, ref: ShmRef) -> None:
        """Free a response slot without decoding (dropped duplicate)."""
        arena = self._resp_by_name(ref.arena)
        arena.set_state(ref.slot, STATE_FREE)

    def _resp_by_name(self, name: str) -> ShmArena:
        for a in self._resp:
            if a.name == name:
                return a
        raise KeyError(f"unknown response arena {name!r}")

    # -- lifecycle -----------------------------------------------------------

    def unlink_worker(self, worker_id: int) -> None:
        """Crash-recovery path: a dead worker's response arena loses its
        /dev/shm name immediately (no orphan while the pool keeps
        running); the coordinator's mapping stays valid for results the
        worker queued before dying."""
        if worker_id < len(self._resp):
            self._resp[worker_id].unlink()

    def close(self) -> None:
        """Unlink every segment this transport created."""
        for a in ([self._task] if self._task is not None else []) \
                + self._resp:
            a.close()
            a.unlink()
        self._task = None
        self._resp = []
        self._disabled = True


# ---------------------------------------------------------------------------
# Worker side
# ---------------------------------------------------------------------------


class WorkerShmTransport:
    """A worker's half: attaches the coordinator-owned arenas on first
    use (task-arena geometry rides in every ``ShmRef``; the response
    arena's is derived from its mapped size), reads task payloads, and
    writes result payloads into its own response arena's free slots."""

    def __init__(self, base: str, worker_id: int, n_workers: int,
                 n_resp_slots: int):
        self.base = base
        self.worker_id = worker_id
        _task_name, resp_names = _arena_names(base, n_workers)
        self._resp_name = resp_names[worker_id]
        self._n_resp_slots = n_resp_slots
        self._task: ShmArena | None = None
        self._resp: ShmArena | None = None
        self._resp_gen = 0
        self.fallbacks = 0

    def _fallback(self) -> None:
        self.fallbacks += 1
        obs.metrics().count("shm.fallbacks")

    def read_task(self, ref: ShmRef) -> object:
        if self._task is None:
            self._task = ShmArena(ref.arena, ref.n_slots, ref.slot_bytes,
                                  create=False)
        return self._task.read(ref)

    def encode_result(self, obj) -> ShmRef | None:
        """Pack ``obj`` into a free slot of this worker's response
        arena; None means ship inline."""
        try:
            if self._resp is None:
                probe = _attach(self._resp_name)
                stride = len(probe.buf) // self._n_resp_slots
                probe.close()
                self._resp = ShmArena(self._resp_name,
                                      self._n_resp_slots, stride - _HDR,
                                      create=False)
            header, arrays, descs, nbytes = pack_payload(obj)
        except (ShmUnavailable, TypeError, OSError, FileNotFoundError):
            self._fallback()
            return None
        arena = self._resp
        if nbytes > arena.slot_bytes:
            self._fallback()
            return None
        slot = next((s for s in range(arena.n_slots)
                     if arena.state(s) == STATE_FREE), None)
        if slot is None:
            self._fallback()
            return None
        self._resp_gen += 1
        arena.write(slot, self._resp_gen, arrays, descs)
        arena.set_state(slot, STATE_FULL)
        return ShmRef(arena.name, slot, self._resp_gen, nbytes,
                      arena.n_slots, arena.slot_bytes, header, descs)

    def close(self) -> None:
        for a in (self._task, self._resp):
            if a is not None:
                a.close()
        self._task = self._resp = None
