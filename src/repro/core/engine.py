"""AdaParseEngine: the end-to-end adaptive parsing pipeline (§5).

Per batch of k documents (all stages batched — no per-doc Python loop on
the hot path):
  1. extract     — cheap parser channel, one vectorized application over
                   the whole batch (parsers.run_parser_batch)
  2. CLS I       — fast-feature validity gate (flat segment reductions)
  3. CLS II/III  — improvement prediction (FT: metadata logistic;
                   LLM: SciBERT accuracy regression)
  4. schedule    — α-budget top-⌊αk⌋ selection (App. C, per-batch).
                   FT variant: host numpy mirror (scheduler.plan_batch).
                   LLM variant: one jitted fused XLA program
                   (router.make_route_step -> kernels.budget_route) — the
                   production device path; the host mirror is
                   property-tested to choose identical documents.
  5. re-parse    — expensive parser on the selected docs (batched)
  6. emit        — final text per doc + provenance

Determinism: with an explicit ``batch_key``, the corruption rng is
derived statelessly from (engine seed, batch key) — the same batch
produces the same records no matter which node runs it or in which
order (data/pipeline.stateless_rng). ``run`` keys batches by their
global index, and core/campaign.CampaignExecutor uses the same keys, so
a multi-node campaign reproduces the single-node record set exactly
(including straggler re-issues, which simply re-run the same key).

Execution-layer features mirrored from the paper:
  - warm-start: ViT weights load once per node (15 s) and persist
  - page-batched expensive parsing (B_p = 10)
  - node-local batching (ZIP aggregation analogue): per-batch I/O is
    charged once per batch, not per document
  - straggler mitigation lives in the campaign layer (CampaignExecutor
    re-issues actual batches; campaign.simulate_parser_campaign is the
    analytic fast path)
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import features as feat_lib
from repro.core import metrics as M
from repro.core import parsers as P
from repro.core import scheduler
from repro.core.router import CLS1_OVERRIDE, AdaParseRouter, make_route_step
from repro.data.pipeline import stateless_rng
from repro.data.synthetic import (CorpusConfig, Document,
                                  batch_metadata_features)


@dataclasses.dataclass
class EngineConfig:
    alpha: float = 0.05              # ≤5% of docs to the expensive parser
    batch_size: int = 256            # k (App. C)
    cheap: str = P.CHEAP_PARSER
    expensive: str = P.EXPENSIVE_PARSER
    router_cost_s: float = 0.002     # CLS-III inference per doc (amortized)
    seed: int = 0
    device_route: bool = True        # LLM variant: fused jitted selection


@dataclasses.dataclass
class ParseRecord:
    doc_id: int
    parser: str
    pages: list
    cost_s: float


@dataclasses.dataclass
class EngineStats:
    n_docs: int = 0
    n_expensive: int = 0
    node_seconds: float = 0.0
    router_seconds: float = 0.0
    reissued_tasks: int = 0

    @property
    def throughput(self) -> float:
        return self.n_docs / max(self.node_seconds, 1e-9)


class AdaParseEngine:
    def __init__(self, ecfg: EngineConfig, router: AdaParseRouter,
                 corpus_cfg: CorpusConfig,
                 image_degraded=False, text_degraded=False):
        self.cfg = ecfg
        self.router = router
        self.ccfg = corpus_cfg
        self.image_degraded = image_degraded
        self.text_degraded = text_degraded
        self.rng = np.random.RandomState(ecfg.seed)
        self.stats = EngineStats()
        self._warmed_nodes: set[int] = set()
        self._route_step = None      # lazily built jitted fused program

    # -- routing --------------------------------------------------------------

    def _device_plan(self, extracted, fast) -> scheduler.BatchPlan:
        """LLM-variant production path: encoder fwd + α-budget selection +
        compact-gather as ONE jitted XLA program (no host round-trip
        between scoring and dispatch)."""
        import jax

        if self._route_step is None:
            self._route_step = jax.jit(make_route_step(
                self.router.enc_cfg, self.cfg.alpha,
                cheap_idx=self.router.cheap_idx,
                expensive_idx=self.router.expensive_idx))
        toks, masks = feat_lib.batch_first_page_tokens(
            extracted, self.router.enc_cfg.max_len)
        valid_logit = (self.router.cls1.predict_proba(fast)
                       - self.router.valid_threshold).astype(np.float32)
        out = self._route_step(self.router.enc_params, toks, masks,
                               valid_logit)
        idx = np.asarray(out["selected_idx"])
        sel = np.sort(idx[idx >= 0]).astype(np.int64)
        k = len(extracted)
        cheap = np.setdiff1d(np.arange(k), sel, assume_unique=False)
        return scheduler.BatchPlan(sel, cheap, len(sel) / max(k, 1))

    def _host_plan(self, docs, extracted, fast) -> scheduler.BatchPlan:
        """Numpy mirror (FT variant, and the LLM fallback when
        ``device_route=False``); must agree with the device path on the
        same scores — see tests/test_routing.py."""
        meta = batch_metadata_features(docs)
        if self.router.variant == "llm":
            toks, masks = feat_lib.batch_first_page_tokens(
                extracted, self.router.enc_cfg.max_len)
        else:
            toks = masks = None
        imp = self.router.predict_improvement(fast, meta, toks, masks)
        return scheduler.plan_batch(
            np.nan_to_num(imp, posinf=CLS1_OVERRIDE), self.cfg.alpha)

    # -- single batch ---------------------------------------------------------

    def process_batch(self, docs: list[Document], node_id: int = 0,
                      batch_key: int | None = None) -> list[ParseRecord]:
        """Parse one batch. ``batch_key`` selects the stateless rng stream
        (same key -> same records on any node); None falls back to the
        engine's sequential stream."""
        k = len(docs)
        rng = (stateless_rng(self.cfg.seed, batch_key)
               if batch_key is not None else self.rng)
        # 1. cheap extraction for everyone (also the router input) — one
        #    vectorized channel application over the batch
        extracted = P.run_parser_batch(self.cfg.cheap, docs, self.ccfg, rng,
                                       self.image_degraded,
                                       self.text_degraded)
        cheap_cost = P.parse_cost_batch(self.cfg.cheap, docs)
        cost = float(cheap_cost.sum())
        # 2-4. route: CLS-I gate + improvement + α-budget selection
        fast = feat_lib.batch_fast_features(extracted, self.ccfg)
        if self.router.variant == "llm" and self.cfg.device_route:
            plan = self._device_plan(extracted, fast)
        else:
            plan = self._host_plan(docs, extracted, fast)
        self.stats.router_seconds += self.cfg.router_cost_s * k
        cost += self.cfg.router_cost_s * k
        # 5. expensive re-parse (batched; warm-start once per node)
        sel = plan.expensive_idx
        if sel.size and node_id not in self._warmed_nodes:
            cost += P.PARSER_SPECS[self.cfg.expensive].warmup_s
            self._warmed_nodes.add(node_id)
        sel_docs = [docs[i] for i in sel]
        sel_pages = P.run_parser_batch(self.cfg.expensive, sel_docs,
                                       self.ccfg, rng, self.image_degraded,
                                       self.text_degraded)
        sel_cost = P.parse_cost_batch(self.cfg.expensive, sel_docs)
        cost += float(sel_cost.sum())
        # 6. emit
        records: list[ParseRecord] = []
        by_sel = {int(i): j for j, i in enumerate(sel)}
        for i, d in enumerate(docs):
            j = by_sel.get(i)
            if j is not None:
                records.append(ParseRecord(d.doc_id, self.cfg.expensive,
                                           sel_pages[j], float(sel_cost[j])))
            else:
                records.append(ParseRecord(d.doc_id, self.cfg.cheap,
                                           extracted[i],
                                           float(cheap_cost[i])))
        self.stats.n_expensive += len(sel)
        self.stats.n_docs += k
        self.stats.node_seconds += cost
        return records

    # -- full campaign (single node) -------------------------------------------

    def run(self, docs: list[Document],
            node_id: int = 0) -> dict[int, ParseRecord]:
        out = {}
        bs = self.cfg.batch_size
        for b, i in enumerate(range(0, len(docs), bs)):
            for r in self.process_batch(docs[i:i + bs], node_id=node_id,
                                        batch_key=b):
                out[r.doc_id] = r
        return out

    def evaluate(self, docs: list[Document],
                 records: dict[int, ParseRecord]) -> dict:
        refs = [d.full_text() for d in docs]
        hyps = [np.concatenate(records[d.doc_id].pages)
                if records[d.doc_id].pages
                and sum(map(len, records[d.doc_id].pages))
                else np.zeros(0, np.int32) for d in docs]
        res = M.evaluate_parser(
            refs, hyps,
            ref_pages=[d.pages for d in docs],
            hyp_pages=[records[d.doc_id].pages for d in docs])
        res["throughput_docs_per_node_s"] = self.stats.throughput
        res["frac_expensive"] = self.stats.n_expensive / max(
            self.stats.n_docs, 1)
        return res
