"""AdaParseEngine: the end-to-end adaptive parsing pipeline (§5).

Per batch of k documents the pipeline is three stages, each batched (no
per-doc Python loop on the hot path) and each dispatched through the
parser-backend registry (core/backends):

  prepare_batch — cheap backend channel over the whole batch + CLS-I
                  fast features (host-side; this is the stage the
                  Prefetcher overlaps with the previous batch's routing)
  route_batch   — CLS II/III improvement prediction + α-budget top-⌊αk⌋
                  selection (App. C). FT variant: host numpy mirror
                  (scheduler.plan_batch). LLM variant: one jitted fused
                  XLA program (router.make_route_step ->
                  kernels.budget_route) — the production device path;
                  the host mirror is property-tested to choose identical
                  documents.
  complete_batch— expensive backend re-parse of the selected docs
                  (batched, warm-start once per node) + emit final text
                  per doc with provenance. Cheap-channel/router cost is
                  charged to the engine that prepared the batch
                  (``ingest_engine``) so a heterogeneous campaign can
                  run prepare on a CPU-pool node and complete on a
                  GPU-pool node with correct per-node accounting.

``process_batch`` composes the three stages on one node (the
single-node production path). ``run`` with ``prefetch_depth > 0``
streams prepare through ``data/pipeline.Prefetcher`` so the host
channel application of batch i+1 overlaps the routing/re-parse of
batch i.

Determinism: with an explicit ``batch_key``, the corruption rng is
derived statelessly from (engine seed, batch key) and carried from
prepare into complete — the same batch produces the same records no
matter which node prepares it, which node completes it, whether the
prepare ran in a prefetch worker thread, or whether the records were
replayed from a ``backends.ResultCache`` (data/pipeline.stateless_rng).
``run`` keys batches by their global index, and
core/campaign.CampaignExecutor uses the same keys, so a multi-node
campaign — pooled, prefetched, cached, or all three — reproduces the
single-node record set exactly (including straggler re-issues, which
simply re-run the same key).

Quality plane: with a ``core/quality.QualityProbe`` attached, batches
the probe's deterministic batch-keyed sampler selects get per-parser
scores on their ``BatchTelemetry.quality`` (cache replays and
abandoned straggler attempts stay None) — the signal the campaign
controller retunes α from at round boundaries; ``set_alpha`` applies
such a retune, invalidating the jitted route step and the cache tag.

Execution-layer features mirrored from the paper:
  - warm-start: ViT weights load once per node (15 s) and persist
  - page-batched expensive parsing (B_p = 10, ``BackendInfo.batch_docs``)
  - node-local batching (ZIP aggregation analogue): per-batch I/O is
    charged once per batch, not per document
  - straggler mitigation lives in the campaign layer (CampaignExecutor
    re-issues actual batches; campaign.simulate_parser_campaign is the
    analytic fast path)
"""
from __future__ import annotations

import dataclasses
import hashlib
import time

import numpy as np

from repro.core import backends as B
from repro.core import features as feat_lib
from repro.core import metrics as M
from repro.core import obs
from repro.core import parsers as P
from repro.core import scheduler
from repro.core.router import CLS1_OVERRIDE, AdaParseRouter, make_route_step
from repro.data.pipeline import Prefetcher, stateless_rng
from repro.data.synthetic import (CorpusConfig, Document,
                                  batch_metadata_features)


def _router_fingerprint(router) -> str:
    """Content hash of everything in the router that shapes a routing
    decision (variant, thresholds, CLS I/II weights, encoder params).
    Stable across processes — the property the on-disk ResultStore
    needs to replay campaigns after a restart — and collision-free for
    routers with different weights, which is what made bare id() (or a
    per-process counter) unsound. Memoized on the router object."""
    fp = getattr(router, "_cache_fp", None)
    if fp is not None:
        return fp
    h = hashlib.sha256()

    def upd(x):
        # length-prefix every field so adjacent values can never
        # re-segment into the same byte stream (0.51|23 vs 0.512|3)
        if x is None:
            payload = b"\x00none"
        elif isinstance(x, (bool, int, float, str)):
            payload = repr(x).encode()
        else:
            a = np.ascontiguousarray(np.asarray(x))
            payload = (str(a.shape).encode() + b"|"
                       + str(a.dtype).encode() + b"|" + a.tobytes())
        h.update(b"%d:" % len(payload) + payload)

    for x in (router.variant, router.valid_threshold,
              router.improve_threshold, router.cheap_idx,
              router.expensive_idx, router.cls1.w, router.cls1.b):
        upd(x)
    # enc_cfg shapes the encoder forward (heads, norms, dtypes) even
    # when the param leaves are identical; its dataclass repr is stable
    upd(None if router.enc_cfg is None else repr(router.enc_cfg))
    if router.cls2 is not None:
        upd(router.cls2.w)
        upd(router.cls2.b)
    else:
        upd(None)
    if router.enc_params is not None:
        import jax

        for leaf in jax.tree_util.tree_leaves(router.enc_params):
            upd(leaf)
    fp = h.hexdigest()
    router._cache_fp = fp
    return fp


@dataclasses.dataclass
class EngineConfig:
    alpha: float = 0.05              # ≤5% of docs to the expensive parser
    batch_size: int = 256            # k (App. C)
    cheap: str = P.CHEAP_PARSER      # backend names (core/backends registry)
    expensive: str = P.EXPENSIVE_PARSER
    router_cost_s: float = 0.002     # CLS-III inference per doc (amortized)
    seed: int = 0
    device_route: bool = True        # LLM variant: fused jitted selection
    prefetch_depth: int = 0          # >0: run() overlaps prepare via Prefetcher
    # prepare-stage routing-input path (core/features
    # .prepare_routing_inputs): "auto" = fused Pallas kernel on TPU /
    # fused host oracle elsewhere, "force" = kernel even off-TPU
    # (interpret; parity tests and benches), "host" = legacy unfused
    # numpy pipeline
    feature_kernel: str = "auto"


@dataclasses.dataclass
class ParseRecord:
    doc_id: int
    parser: str
    pages: list
    cost_s: float


@dataclasses.dataclass
class EngineStats:
    n_docs: int = 0
    n_expensive: int = 0
    node_seconds: float = 0.0
    router_seconds: float = 0.0
    reissued_tasks: int = 0
    cache_hits: int = 0

    @property
    def throughput(self) -> float:
        return self.n_docs / max(self.node_seconds, 1e-9)


@dataclasses.dataclass
class BatchTelemetry:
    """Per-batch, per-stage timing emitted by the staged engine — the
    feedback signal the adaptive campaign controller autotunes
    ``node_budget_weights`` from. Appended to the *ingest* engine's
    ``telemetry`` list (the engine that prepared/routed the batch);
    ``complete_node`` records where the expensive re-parse ran."""

    batch_key: int | None
    n_docs: int
    n_expensive: int
    complete_node: int
    prepare_s: float                 # cheap channel + fast features
    route_s: float                   # CLS II/III selection
    complete_s: float                # expensive re-parse (+ warm-start)
    # quality-probe scoring cost (QualityProbeConfig.cost_s_per_doc ×
    # batch size), charged to the completing node's clock: the
    # controller's throughput EWMA sees probe overhead instead of
    # treating scoring as free measurement-plane work
    probe_s: float = 0.0
    cached: bool = False
    # straggler attempt given up at the deadline: its docs were produced
    # again elsewhere, so throughput measurement must skip this record
    abandoned: bool = False
    # per-parser probe scores {parser: (mean_quality, n_docs)} when the
    # quality probe sampled this batch (core/quality.QualityProbe);
    # None for unprobed batches AND for cache replays / abandoned
    # straggler attempts — excluded from the quality signal exactly
    # like their timing is excluded from observed throughput
    quality: dict | None = None

    @property
    def total_s(self) -> float:
        return self.prepare_s + self.route_s + self.complete_s \
            + self.probe_s


@dataclasses.dataclass
class PreparedBatch:
    """Output of the host-side prepare stage. ``rng`` is the batch's
    stateless stream, partially consumed by the cheap channel; complete
    continues it so split prepare/complete is bit-identical to the fused
    single-call path. ``route_host`` carries the host-derived routing
    inputs (first-page tokens / CLS-I logits / metadata features) so the
    consumer's route step is as close to pure device work as possible."""

    docs: list
    batch_key: int | None
    rng: np.random.RandomState
    extracted: list
    fast: np.ndarray
    cheap_cost: np.ndarray
    route_host: dict

    @property
    def ingest_cost_s(self) -> float:
        return float(self.cheap_cost.sum())


class AdaParseEngine:
    def __init__(self, ecfg: EngineConfig, router: AdaParseRouter,
                 corpus_cfg: CorpusConfig,
                 image_degraded=False, text_degraded=False,
                 cache: B.ResultStore | None = None,
                 probe=None):
        self.cfg = ecfg
        self.router = router
        self.ccfg = corpus_cfg
        self.image_degraded = image_degraded
        self.text_degraded = text_degraded
        self.cache = cache
        # optional core/quality.QualityProbe: deterministically sampled
        # batches get per-parser scores on their BatchTelemetry (pure
        # measurement plane — never charged to node clocks or records)
        self.probe = probe
        self.cheap_backend = B.get_backend(ecfg.cheap)
        self.expensive_backend = B.get_backend(ecfg.expensive)
        self.rng = np.random.RandomState(ecfg.seed)
        self.stats = EngineStats()
        self.telemetry: list[BatchTelemetry] = []
        self._warmed_nodes: set[int] = set()
        self._route_step = None      # lazily built jitted fused program
        self._cache_tag = self._make_cache_tag()

    def _make_cache_tag(self):
        """Cache keys must capture everything that shapes a batch's
        records: the full corpus config (any field changes the
        documents), the routing α, and a content fingerprint of the
        router (stable across processes, so a DiskResultStore replays
        campaigns after a restart)."""
        return (self.cfg.seed, self.cfg.alpha, self.cfg.cheap,
                self.cfg.expensive, self.cfg.device_route,
                self.cfg.feature_kernel,
                self.router.variant, dataclasses.astuple(self.ccfg),
                self.image_degraded, self.text_degraded,
                _router_fingerprint(self.router))

    def set_alpha(self, alpha: float) -> None:
        """Round-boundary α retune (core/quality): swap the routing
        budget and invalidate everything derived from it — the jitted
        fused route step (α is baked into its top-⌊αk⌋ capacity) and
        the cache tag (records parsed at a different α are different
        records, so replay only matches runs that retuned identically)."""
        if alpha == self.cfg.alpha:
            return
        self.cfg = dataclasses.replace(self.cfg, alpha=alpha)
        self._route_step = None
        self._cache_tag = self._make_cache_tag()

    # -- routing --------------------------------------------------------------

    def _route_host_features(self, docs, fast, tokens, mask) -> dict:
        """Routing inputs derived during prepare so the consumer-side
        route step is (for the LLM variant) pure device work the
        Prefetcher worker can overlap. ``tokens``/``mask`` come fused
        out of ``prepare_routing_inputs`` — on the kernel path they are
        already device arrays, feeding ``route_step`` without a host
        round-trip."""
        rh: dict = {}
        if self.router.variant == "llm":
            rh["tokens"], rh["mask"] = tokens, mask
            if self.cfg.device_route:
                rh["valid_logit"] = (
                    self.router.cls1.predict_proba(fast)
                    - self.router.valid_threshold).astype(np.float32)
        else:
            rh["meta"] = batch_metadata_features(docs)
        return rh

    def _device_plan(self, prep: PreparedBatch) -> scheduler.BatchPlan:
        """LLM-variant production path: encoder fwd + α-budget selection +
        compact-gather as ONE jitted XLA program (no host round-trip
        between scoring and dispatch)."""
        import jax

        if self._route_step is None:
            self._route_step = jax.jit(make_route_step(
                self.router.enc_cfg, self.cfg.alpha,
                cheap_idx=self.router.cheap_idx,
                expensive_idx=self.router.expensive_idx))
        out = self._route_step(self.router.enc_params,
                               prep.route_host["tokens"],
                               prep.route_host["mask"],
                               prep.route_host["valid_logit"])
        idx = np.asarray(out["selected_idx"])
        sel = np.sort(idx[idx >= 0]).astype(np.int64)
        k = len(prep.extracted)
        cheap = np.setdiff1d(np.arange(k), sel, assume_unique=False)
        return scheduler.BatchPlan(sel, cheap, len(sel) / max(k, 1))

    def _host_plan(self, prep: PreparedBatch) -> scheduler.BatchPlan:
        """Numpy mirror (FT variant, and the LLM fallback when
        ``device_route=False``); must agree with the device path on the
        same scores — see tests/test_routing.py."""
        toks = prep.route_host.get("tokens")
        masks = prep.route_host.get("mask")
        imp = self.router.predict_improvement(
            prep.fast, prep.route_host.get("meta"), toks, masks)
        return scheduler.plan_batch(
            np.nan_to_num(imp, posinf=CLS1_OVERRIDE), self.cfg.alpha)

    # -- pipeline stages ------------------------------------------------------

    def prepare_batch(self, docs: list[Document],
                      batch_key: int | None = None) -> PreparedBatch:
        """Ingest: cheap backend channel over the whole batch, then
        every routing input (CLS-I fast features and, for the LLM
        variant, the first-page token/mask pair) in one fused
        ``prepare_routing_inputs`` call — the Pallas fast_features
        kernel on device backends (``EngineConfig.feature_kernel``).
        Pure w.r.t. engine state (no stats mutation), so it may run in
        a prefetch worker thread."""
        rng = (stateless_rng(self.cfg.seed, batch_key)
               if batch_key is not None else self.rng)
        extracted = self.cheap_backend.parse_batch(
            docs, self.ccfg, rng, image_degraded=self.image_degraded,
            text_degraded=self.text_degraded)
        max_len = (self.router.enc_cfg.max_len
                   if self.router.variant == "llm" else None)
        fast, tokens, mask = feat_lib.prepare_routing_inputs(
            extracted, self.ccfg, max_len=max_len,
            mode=self.cfg.feature_kernel)
        fast = np.asarray(fast)          # CLS-I predict_proba is host-side
        return PreparedBatch(docs, batch_key, rng, extracted, fast,
                             self.cheap_backend.cost_batch(docs),
                             self._route_host_features(docs, fast,
                                                       tokens, mask))

    def route_batch(self, prep: PreparedBatch) -> scheduler.BatchPlan:
        """CLS II/III + α-budget selection over a prepared batch."""
        if self.router.variant == "llm" and self.cfg.device_route:
            return self._device_plan(prep)
        return self._host_plan(prep)

    def complete_batch(self, prep: PreparedBatch, plan: scheduler.BatchPlan,
                       node_id: int = 0,
                       ingest_engine: "AdaParseEngine | None" = None
                       ) -> list[ParseRecord]:
        """Expensive re-parse of the selected docs + emit. All cost/stat
        accounting happens here: cheap-channel + router cost goes to
        ``ingest_engine`` (the engine that prepared/routed the batch —
        defaults to self, the homogeneous case), expensive-parse cost +
        warm-start to self."""
        ing = ingest_engine if ingest_engine is not None else self
        k = len(prep.docs)
        router_cost = self.cfg.router_cost_s * k
        ing.stats.n_docs += k
        ing.stats.router_seconds += router_cost
        ing.stats.node_seconds += prep.ingest_cost_s + router_cost
        sel = plan.expensive_idx
        cost = 0.0
        if sel.size and node_id not in self._warmed_nodes:
            cost += self.expensive_backend.info.warm_start_s
            self._warmed_nodes.add(node_id)
        sel_docs = [prep.docs[i] for i in sel]
        sel_pages = self.expensive_backend.parse_batch(
            sel_docs, self.ccfg, prep.rng,
            image_degraded=self.image_degraded,
            text_degraded=self.text_degraded)
        sel_cost = self.expensive_backend.cost_batch(sel_docs)
        cost += float(sel_cost.sum())
        records: list[ParseRecord] = []
        by_sel = {int(i): j for j, i in enumerate(sel)}
        for i, d in enumerate(prep.docs):
            j = by_sel.get(i)
            if j is not None:
                records.append(ParseRecord(d.doc_id, self.cfg.expensive,
                                           sel_pages[j], float(sel_cost[j])))
            else:
                records.append(ParseRecord(d.doc_id, self.cfg.cheap,
                                           prep.extracted[i],
                                           float(prep.cheap_cost[i])))
        self.stats.n_expensive += len(sel)
        self.stats.node_seconds += cost
        quality = None
        probe_cost = 0.0
        if (self.probe is not None and prep.batch_key is not None
                and self.probe.should_probe(prep.batch_key)):
            quality = self.probe.score_records(prep.docs, records)
            # probing is charged to the node that scored the batch
            # (this one), not treated as free measurement-plane work
            probe_cost = self.probe.cfg.cost_s_per_doc * k
            self.stats.node_seconds += probe_cost
        ing.telemetry.append(BatchTelemetry(
            batch_key=prep.batch_key, n_docs=k, n_expensive=len(sel),
            complete_node=node_id, prepare_s=prep.ingest_cost_s,
            route_s=router_cost, complete_s=cost, probe_s=probe_cost,
            quality=quality))
        # observability: per-stage latency histograms (always-on — a
        # handful of dict ops per *batch*) and, when tracing is
        # enabled, one span per stage reconstructed from the batch's
        # already-measured durations (one record call each, so the hot
        # path gains no extra timers)
        reg = obs.metrics()
        reg.observe("engine.prepare_s", prep.ingest_cost_s)
        reg.observe("engine.route_s", router_cost)
        reg.observe("engine.reparse_s", cost)
        if probe_cost:
            reg.observe("engine.probe_s", probe_cost)
        rec = obs.recorder()
        if rec.enabled:
            key = prep.batch_key if prep.batch_key is not None else -1
            t0 = time.time() - (prep.ingest_cost_s + router_cost + cost
                                + probe_cost)
            rec.span("prepare", key, t0, prep.ingest_cost_s,
                     node=node_id)
            t0 += prep.ingest_cost_s
            rec.span("route", key, t0, router_cost, node=node_id)
            t0 += router_cost
            rec.span("reparse", key, t0, cost, node=node_id,
                     detail=f"{len(sel)}/{k} docs expensive")
            if probe_cost:
                rec.span("probe", key, t0 + cost, probe_cost,
                         node=node_id)
        return records

    # -- result cache ---------------------------------------------------------

    def _cache_key(self, docs, batch_key):
        if self.cache is None or batch_key is None:
            return None
        return (self._cache_tag, batch_key, tuple(d.doc_id for d in docs))

    def prepare_or_lookup(self, docs, batch_key=None, use_cache=True
                          ) -> tuple:
        """One step of the ingest protocol: ``(key, prep, cached)`` where
        exactly one of ``prep``/``cached`` is set. Safe to call from a
        prefetch worker thread. ``use_cache=False`` forces a real prepare
        (used by straggler re-issue, which must model the actual re-parse
        cost rather than replay the abandoned attempt's stored result)."""
        key = self._cache_key(docs, batch_key) if use_cache else None
        cached = None
        if key is not None:
            rec = obs.recorder()
            if rec.enabled:
                tw, tp = time.time(), time.perf_counter()
                cached = self.cache.lookup(key)
                dur = time.perf_counter() - tp
                rec.span("cache_lookup", batch_key, tw, dur,
                         cached=cached is not None)
                obs.metrics().observe("engine.cache_lookup_s", dur)
            else:
                cached = self.cache.lookup(key)
        if cached is not None:
            return key, None, cached
        return key, self.prepare_batch(docs, batch_key=batch_key), None

    def _account_cache_hit(self, records: list[ParseRecord],
                           batch_key: int | None = None) -> None:
        """Replayed batch: count the docs, charge no parse time."""
        n_exp = sum(r.parser == self.cfg.expensive for r in records)
        self.stats.n_docs += len(records)
        self.stats.n_expensive += n_exp
        self.stats.cache_hits += 1
        self.telemetry.append(BatchTelemetry(
            batch_key=batch_key, n_docs=len(records), n_expensive=n_exp,
            complete_node=-1, prepare_s=0.0, route_s=0.0, complete_s=0.0,
            cached=True))

    # -- single batch ---------------------------------------------------------

    def process_batch(self, docs: list[Document], node_id: int = 0,
                      batch_key: int | None = None) -> list[ParseRecord]:
        """Parse one batch (prepare -> route -> complete on this node).
        ``batch_key`` selects the stateless rng stream (same key -> same
        records on any node); None falls back to the engine's sequential
        stream. With a ``ResultCache`` attached, a previously-parsed
        (key, doc ids) batch is replayed instead of re-parsed."""
        key, prep, cached = self.prepare_or_lookup(docs, batch_key)
        if cached is not None:
            self._account_cache_hit(cached, batch_key)
            return cached
        plan = self.route_batch(prep)
        records = self.complete_batch(prep, plan, node_id=node_id)
        if key is not None:
            self.cache.store(key, records)
        return records

    # -- full campaign (single node) -------------------------------------------

    def run(self, docs: list[Document],
            node_id: int = 0) -> dict[int, ParseRecord]:
        bs = self.cfg.batch_size
        batches = [(b, docs[i:i + bs])
                   for b, i in enumerate(range(0, len(docs), bs))]
        out: dict[int, ParseRecord] = {}
        if self.cfg.prefetch_depth > 0:
            for recs in self._overlapped_batches(batches, node_id):
                for r in recs:
                    out[r.doc_id] = r
        else:
            for b, chunk in batches:
                for r in self.process_batch(chunk, node_id=node_id,
                                            batch_key=b):
                    out[r.doc_id] = r
        return out

    def _overlapped_batches(self, batches, node_id):
        """Prefetch-overlapped campaign: the worker thread runs the host
        prepare (cheap channel + features, and cache lookups) for batch
        i+1..i+depth while the consumer routes/completes batch i. Batch
        keys make the records identical to the sequential path."""

        pf = Prefetcher(iter(batches), depth=self.cfg.prefetch_depth,
                        transform=lambda item: self.prepare_or_lookup(
                            item[1], batch_key=item[0]))
        try:
            for key, prep, cached in pf:
                if cached is not None:
                    self._account_cache_hit(cached, key[1])
                    yield cached
                    continue
                plan = self.route_batch(prep)
                records = self.complete_batch(prep, plan, node_id=node_id)
                if key is not None:
                    self.cache.store(key, records)
                yield records
        finally:
            pf.close()

    def evaluate(self, docs: list[Document],
                 records: dict[int, ParseRecord]) -> dict:
        refs = [d.full_text() for d in docs]
        hyps = [np.concatenate(records[d.doc_id].pages)
                if records[d.doc_id].pages
                and sum(map(len, records[d.doc_id].pages))
                else np.zeros(0, np.int32) for d in docs]
        res = M.evaluate_parser(
            refs, hyps,
            ref_pages=[d.pages for d in docs],
            hyp_pages=[records[d.doc_id].pages for d in docs])
        res["throughput_docs_per_node_s"] = self.stats.throughput
        res["frac_expensive"] = self.stats.n_expensive / max(
            self.stats.n_docs, 1)
        return res
