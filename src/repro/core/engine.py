"""AdaParseEngine: the end-to-end adaptive parsing pipeline (§5).

Per batch of k documents:
  1. extract     — run the cheap parser (PyMuPDF channel) on every doc
  2. CLS I       — fast-feature validity gate
  3. CLS II/III  — improvement prediction (FT: metadata logistic;
                   LLM: SciBERT accuracy regression)
  4. schedule    — α-budget top-⌊αk⌋ selection (App. C, per-batch)
  5. re-parse    — expensive parser on the selected docs
  6. emit        — final text per doc + provenance

Execution-layer features mirrored from the paper:
  - warm-start: ViT weights load once per node (15 s) and persist
  - page-batched expensive parsing (B_p = 10)
  - straggler mitigation: tasks exceeding ``straggler_deadline_s`` are
    re-issued to the fastest idle node (resilience, §2.4)
  - node-local batching (ZIP aggregation analogue): per-batch I/O is
    charged once per batch, not per document
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import features as feat_lib
from repro.core import metrics as M
from repro.core import parsers as P
from repro.core import scheduler
from repro.core.router import AdaParseRouter
from repro.data.synthetic import CorpusConfig, Document


@dataclasses.dataclass
class EngineConfig:
    alpha: float = 0.05              # ≤5% of docs to the expensive parser
    batch_size: int = 256            # k (App. C)
    cheap: str = P.CHEAP_PARSER
    expensive: str = P.EXPENSIVE_PARSER
    router_cost_s: float = 0.002     # CLS-III inference per doc (amortized)
    straggler_deadline_s: float = 60.0
    seed: int = 0


@dataclasses.dataclass
class ParseRecord:
    doc_id: int
    parser: str
    pages: list
    cost_s: float


@dataclasses.dataclass
class EngineStats:
    n_docs: int = 0
    n_expensive: int = 0
    node_seconds: float = 0.0
    router_seconds: float = 0.0
    reissued_tasks: int = 0

    @property
    def throughput(self) -> float:
        return self.n_docs / max(self.node_seconds, 1e-9)


class AdaParseEngine:
    def __init__(self, ecfg: EngineConfig, router: AdaParseRouter,
                 corpus_cfg: CorpusConfig,
                 image_degraded=False, text_degraded=False):
        self.cfg = ecfg
        self.router = router
        self.ccfg = corpus_cfg
        self.image_degraded = image_degraded
        self.text_degraded = text_degraded
        self.rng = np.random.RandomState(ecfg.seed)
        self.stats = EngineStats()
        self._warmed_nodes: set[int] = set()

    # -- single batch ---------------------------------------------------------

    def process_batch(self, docs: list[Document],
                      node_id: int = 0) -> list[ParseRecord]:
        k = len(docs)
        # 1. cheap extraction for everyone (also the router input)
        extracted = [P.run_parser(self.cfg.cheap, d, self.ccfg, self.rng,
                                  self.image_degraded, self.text_degraded)
                     for d in docs]
        cost = sum(P.parse_cost_s(self.cfg.cheap, d) for d in docs)
        # 2-3. route
        fast = feat_lib.batch_fast_features(extracted, self.ccfg)
        meta = np.stack([d.metadata_features() for d in docs])
        if self.router.variant == "llm":
            toks, masks = zip(*[feat_lib.first_page_tokens(
                e, self.router.enc_cfg.max_len) for e in extracted])
            toks, masks = np.stack(toks), np.stack(masks)
        else:
            toks = masks = None
        imp = self.router.predict_improvement(fast, meta, toks, masks)
        self.stats.router_seconds += self.cfg.router_cost_s * k
        cost += self.cfg.router_cost_s * k
        # 4. schedule
        plan = scheduler.plan_batch(np.nan_to_num(imp, posinf=1e3),
                                    self.cfg.alpha)
        # 5. expensive re-parse (warm-start once per node)
        if plan.expensive_idx.size and node_id not in self._warmed_nodes:
            cost += P.PARSER_SPECS[self.cfg.expensive].warmup_s
            self._warmed_nodes.add(node_id)
        records: list[ParseRecord] = []
        for i, d in enumerate(docs):
            if i in set(plan.expensive_idx.tolist()):
                pages = P.run_parser(self.cfg.expensive, d, self.ccfg,
                                     self.rng, self.image_degraded,
                                     self.text_degraded)
                c = P.parse_cost_s(self.cfg.expensive, d)
                cost += c
                records.append(ParseRecord(d.doc_id, self.cfg.expensive,
                                           pages, c))
                self.stats.n_expensive += 1
            else:
                records.append(ParseRecord(
                    d.doc_id, self.cfg.cheap, extracted[i],
                    P.parse_cost_s(self.cfg.cheap, d)))
        # straggler simulation: with tiny prob a task hangs and is re-issued
        if self.rng.rand() < 0.01:
            self.stats.reissued_tasks += 1
            cost += min(self.cfg.straggler_deadline_s,
                        0.05 * self.cfg.straggler_deadline_s)
        self.stats.n_docs += k
        self.stats.node_seconds += cost
        return records

    # -- full campaign ----------------------------------------------------------

    def run(self, docs: list[Document]) -> dict[int, ParseRecord]:
        out = {}
        bs = self.cfg.batch_size
        for i in range(0, len(docs), bs):
            for r in self.process_batch(docs[i:i + bs], node_id=0):
                out[r.doc_id] = r
        return out

    def evaluate(self, docs: list[Document],
                 records: dict[int, ParseRecord]) -> dict:
        refs = [d.full_text() for d in docs]
        hyps = [np.concatenate(records[d.doc_id].pages)
                if records[d.doc_id].pages
                and sum(map(len, records[d.doc_id].pages))
                else np.zeros(0, np.int32) for d in docs]
        res = M.evaluate_parser(
            refs, hyps,
            ref_pages=[d.pages for d in docs],
            hyp_pages=[records[d.doc_id].pages for d in docs])
        res["throughput_docs_per_node_s"] = self.stats.throughput
        res["frac_expensive"] = self.stats.n_expensive / max(
            self.stats.n_docs, 1)
        return res
