"""The parser fleet: quality channels + cost models (§3.1, Figs. 3 & 5).

Cost model calibration (single Polaris node = 32 CPU cores + 4 A100):
- abstract: Nougat parses 1-2 PDF/s/node; §5.1: PyMuPDF throughput is
  135x Nougat and 13x pypdf; Fig. 5: PyMuPDF ≈ 315 PDF/s at 128 nodes
  with an FS-contention plateau; Marker ≈ 0.1 PDF/s average at scale;
  Nougat ≈ 8 PDF/s at 128 nodes.

Quality profiles reproduce the Fig. 3 crossing structure: extraction is
best on easy born-digital docs, collapses on scans/scrambled layers;
Nougat is flat-but-page-dropping; GROBID truncates (low coverage).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.data.synthetic import (ChannelProfile, CorpusConfig, Document,
                                  corrupt_document)


@dataclasses.dataclass(frozen=True)
class ParserSpec:
    name: str
    channel: ChannelProfile
    pdf_per_sec_node: float          # single-node steady-state throughput
    uses_gpu: bool
    warmup_s: float = 0.0            # model-load time (15 s for ViT, §5.2)
    io_bytes_per_doc: float = 2e6    # read PDF + write text
    scale_cap_nodes: int = 10 ** 9   # e.g. Marker fails to scale past 10


# Channel severities calibrated against Table 1 (born-digital regime):
# target BLEU  pymupdf 51.9 > tesseract 48.8 > nougat 48.1 > marker 47.5
#              > pypdf 43.6 >> grobid 26.5; coverage 91-97 (marker top);
# plus the Fig. 3 crossing: extraction collapses at high difficulty
# (difficulty_power >= 2.5), recognition parsers stay flat.
PARSER_SPECS: dict[str, ParserSpec] = {
    "pymupdf": ParserSpec(
        "pymupdf",
        ChannelProfile(p_ws=0.10, p_sub=0.08, p_scramble=0.45, p_char=0.12,
                       p_latex=0.85, p_ident=0.3, p_page_drop=0.085,
                       difficulty_power=3.0, flat_floor=0.13,
                       text_layer=True),
        pdf_per_sec_node=202.0, uses_gpu=False, io_bytes_per_doc=2.5e6),
    "pypdf": ParserSpec(
        "pypdf",
        ChannelProfile(p_ws=0.28, p_sub=0.10, p_scramble=0.38, p_char=0.14,
                       p_latex=0.9, p_ident=0.4, p_page_drop=0.08,
                       difficulty_power=2.5, flat_floor=0.16,
                       text_layer=True),
        pdf_per_sec_node=15.5, uses_gpu=False),
    "nougat": ParserSpec(
        "nougat",
        ChannelProfile(p_sub=0.17, p_char=0.10, p_latex=0.10, p_ident=0.12,
                       p_page_drop=0.07, difficulty_power=1.0,
                       flat_floor=0.52, text_layer=False),
        pdf_per_sec_node=1.5, uses_gpu=True, warmup_s=15.0),
    "marker": ParserSpec(
        "marker",
        ChannelProfile(p_sub=0.18, p_char=0.11, p_latex=0.18, p_ident=0.15,
                       p_page_drop=0.033, difficulty_power=1.2,
                       flat_floor=0.50, text_layer=False),
        pdf_per_sec_node=0.65, uses_gpu=True, warmup_s=12.0,
        scale_cap_nodes=10),
    "tesseract": ParserSpec(
        "tesseract",
        ChannelProfile(p_ws=0.10, p_sub=0.12, p_scramble=0.05, p_char=0.13,
                       p_latex=0.75, p_ident=0.25, p_page_drop=0.085,
                       difficulty_power=1.4, flat_floor=0.28,
                       text_layer=False),
        pdf_per_sec_node=4.2, uses_gpu=False),
    "grobid": ParserSpec(
        "grobid",
        ChannelProfile(p_ws=0.05, p_sub=0.16, p_scramble=0.12, p_char=0.09,
                       p_latex=0.8, p_ident=0.3, p_page_drop=0.12,
                       p_fail=0.12, difficulty_power=1.5, flat_floor=0.68,
                       text_layer=True),
        pdf_per_sec_node=7.0, uses_gpu=False),
}

# AdaParse restricts itself to two parsers for scalability (App. C)
CHEAP_PARSER = "pymupdf"
EXPENSIVE_PARSER = "nougat"
# order of the m=6 accuracy-regression outputs (GROBID excluded per Table 4)
REGRESSION_PARSERS = ("pymupdf", "pypdf", "nougat", "marker", "tesseract",
                      "grobid")


def run_parser(name: str, doc: Document, cfg: CorpusConfig,
               rng: np.random.RandomState, image_degraded=False,
               text_degraded=False) -> list[np.ndarray]:
    """Simulated parse: ground truth -> parser's corruption channel.

    Resolved through the backend registry like the batch path. Channel
    backends keep the legacy single-doc rng stream (corrupt_document);
    other backends parse a batch of one."""
    from repro.core import backends
    be = backends.get_backend(name)
    if isinstance(be, backends.ChannelBackend):
        return corrupt_document(doc, be.spec.channel, cfg, rng,
                                image_degraded=image_degraded,
                                text_degraded=text_degraded)
    return be.parse_batch([doc], cfg, rng, image_degraded=image_degraded,
                          text_degraded=text_degraded)[0]


def run_parser_batch(name: str, docs: list[Document], cfg: CorpusConfig,
                     rng: np.random.RandomState, image_degraded=False,
                     text_degraded=False) -> list[list[np.ndarray]]:
    """Batched ``run_parser``: dispatched through the backend registry
    (core/backends), so registered custom backends are reachable here and
    from everything built on top (engine, campaign executor). The default
    registry applies one vectorized channel over the whole batch — the
    engine's hot path (see synthetic.corrupt_documents)."""
    from repro.core import backends
    return backends.get_backend(name).parse_batch(
        docs, cfg, rng, image_degraded=image_degraded,
        text_degraded=text_degraded)


# corpus mean pages: per-doc costs are page-normalized against it (§5.2)
MEAN_PAGES = 4.5


def parse_cost_s(name: str, doc: Document) -> float:
    """Per-document cost in node-seconds (page-normalized, §5.2)."""
    return float(parse_cost_batch(name, [doc])[0])


def parse_cost_batch(name: str, docs: list[Document]) -> np.ndarray:
    """Vectorized ``parse_cost_s`` -> (n,) float64 node-seconds,
    dispatched through the backend registry."""
    from repro.core import backends
    return backends.get_backend(name).cost_batch(docs)


def throughput_at_nodes(name: str, n_nodes: int,
                        fs_bandwidth_Bps: float = 650e9,
                        doc_bytes: float | None = None) -> float:
    """Fig. 5 scaling model: linear in nodes, capped by (a) a backend's
    internal scale ceiling and (b) shared-filesystem bandwidth."""
    from repro.core import backends
    info = backends.get_backend(name).info
    eff_nodes = min(n_nodes, info.scale_cap_nodes)
    linear = info.pdf_per_sec_node * eff_nodes
    io = (doc_bytes or info.io_bytes_per_doc)
    fs_cap = fs_bandwidth_Bps / io * 0.001   # ~0.1% of agg BW per campaign
    return min(linear, fs_cap)
