"""Hierarchical parser-selection router (Fig. 2): CLS I -> II -> III.

- CLS I : logistic regression on CLS-I fast features -> extracted-text
  validity. Invalid -> straight to the high-quality parser.
- CLS II: logistic regression on document metadata -> "would another
  parser significantly improve quality?". No -> accept extraction.
- CLS III: the SciBERT-class encoder regresses per-parser accuracy from
  first-page text; argmax-improvement parser wins (subject to the α
  budget, enforced by the scheduler).

Two production variants (§5.1):
- AdaParse (FT) : CLS I+II only (fast features + metadata, fastText-like
  linear models); improvement-likely -> Nougat directly.
- AdaParse (LLM): CLS I gate, then CLS III LLM inference (DPO-aligned).

``make_route_step`` builds the jit-able fused device step (encoder fwd +
budget top-k dispatch) that the dry-run lowers at production scale.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import EncoderConfig
from repro.core import scheduler
from repro.core.features import N_FAST_FEATURES
from repro.models import encoder as enc_lib

# ---------------------------------------------------------------------------
# Linear stages (CLS I / II)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LinearStage:
    """Logistic regression trained with plain full-batch Newton/GD steps —
    small enough to fit anywhere, interpretable (§5.1)."""

    w: np.ndarray
    b: float

    @classmethod
    def fit(cls, x: np.ndarray, y: np.ndarray, steps: int = 300,
            lr: float = 0.5, l2: float = 1e-4) -> "LinearStage":
        x = np.asarray(x, np.float64)
        y = np.asarray(y, np.float64)
        w = np.zeros(x.shape[1])
        b = 0.0
        for _ in range(steps):
            z = x @ w + b
            p = 1.0 / (1.0 + np.exp(-z))
            g = p - y
            w -= lr * (x.T @ g / len(y) + l2 * w)
            b -= lr * float(g.mean())
        return cls(w, b)

    def predict_proba(self, x: np.ndarray) -> np.ndarray:
        return 1.0 / (1.0 + np.exp(-(np.asarray(x) @ self.w + self.b)))


# ---------------------------------------------------------------------------
# Full router
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class AdaParseRouter:
    variant: str                          # "ft" | "llm"
    cls1: LinearStage                     # validity from fast features
    cls2: LinearStage | None              # improvement-likely from metadata
    enc_cfg: EncoderConfig | None = None  # CLS III model
    enc_params: dict | None = None        # raw arrays
    valid_threshold: float = 0.5
    improve_threshold: float = 0.5
    cheap_idx: int = 0                    # index of pymupdf in regression out
    expensive_idx: int = 2                # index of nougat

    def predict_improvement(self, fast_feats: np.ndarray,
                            meta_feats: np.ndarray,
                            tokens: np.ndarray | None,
                            mask: np.ndarray | None) -> np.ndarray:
        """Per-doc predicted accuracy improvement of expensive over cheap.

        Invalid extraction (CLS I) forces +inf improvement (must re-parse).
        FT variant: improvement = CLS-II probability (- threshold).
        LLM variant: encoder per-parser accuracy regression difference.
        """
        valid = self.cls1.predict_proba(fast_feats) >= self.valid_threshold
        n = len(fast_feats)
        if self.variant == "ft":
            p_imp = self.cls2.predict_proba(meta_feats)
            imp = p_imp - self.improve_threshold
        else:
            pred = np.asarray(enc_lib.predict_accuracies(
                self.enc_params, self.enc_cfg, jnp.asarray(tokens),
                jnp.asarray(mask)))
            imp = pred[:, self.expensive_idx] - pred[:, self.cheap_idx]
        imp = np.where(valid, imp, np.inf)
        return imp

    def predict_all_accuracies(self, tokens, mask) -> np.ndarray:
        assert self.variant == "llm"
        return np.asarray(enc_lib.predict_accuracies(
            self.enc_params, self.enc_cfg, jnp.asarray(tokens),
            jnp.asarray(mask)))


# ---------------------------------------------------------------------------
# Fused device route step (dry-run / production object)
# ---------------------------------------------------------------------------


# CLS-I invalid docs must be re-parsed: their improvement is overridden
# with this large finite score (the host mirror maps +inf to the same
# value via np.nan_to_num(..., posinf=CLS1_OVERRIDE)).
CLS1_OVERRIDE = 1e3


def make_route_step(enc_cfg: EncoderConfig, alpha: float,
                    cheap_idx: int = 0, expensive_idx: int = 2,
                    force_kernel: bool = False):
    """Returns route_step(enc_params_raw, tokens, mask, fast_valid_logit):

    encoder fwd (B, S) -> per-parser accuracies (B, m) -> improvement
    scores -> α-budget threshold + fused select-and-compact
    (``kernels.budget_route``) -> dispatch indices + compacted token batch
    for the expensive parser. One fused SPMD program; this is the paper's
    selection machinery as a single XLA computation, and the production
    selection path of the LLM-variant engine (engine.py).

    ``selected_idx`` is (⌊α·B⌋,) int32 source rows, -1-filled past
    ``count``; ``routed_tokens`` is the compacted (⌊α·B⌋, S) gather.
    """
    from repro.kernels.budget_route import budget_route

    def route_step(enc_params_raw, tokens, mask, valid_logit):
        b = tokens.shape[0]
        pred = enc_lib.predict_accuracies(enc_params_raw, enc_cfg, tokens,
                                          mask)                      # (B, m)
        imp = pred[:, expensive_idx] - pred[:, cheap_idx]
        imp = jnp.where(valid_logit < 0, CLS1_OVERRIDE, imp)
        routed_tokens, sel_idx, count = budget_route(
            imp, tokens, alpha, force_kernel=force_kernel)
        # scatter the compacted indices back to a (B,) mask (-1 -> dropped)
        sel_mask = jnp.zeros((b + 1,), bool).at[
            jnp.where(sel_idx >= 0, sel_idx, b)].set(True)[:b]
        return {
            "pred_acc": pred,
            "improvement": imp,
            "selected_mask": sel_mask,
            "selected_idx": sel_idx,
            "routed_tokens": routed_tokens,
            "count": count,
        }

    return route_step


# ---------------------------------------------------------------------------
# Training data assembly for the router stack
# ---------------------------------------------------------------------------


def make_cls1_labels(bleus_cheap: np.ndarray, thr: float = 0.15) -> np.ndarray:
    """Validity label: extraction yielded non-garbage text."""
    return (bleus_cheap > thr).astype(np.float64)


def make_cls2_labels(bleu_matrix: np.ndarray, cheap_idx: int,
                     margin: float = 0.02) -> np.ndarray:
    """'Another parser improves significantly' label from the accuracy
    matrix (n, m)."""
    best_other = np.delete(bleu_matrix, cheap_idx, axis=1).max(axis=1)
    return (best_other > bleu_matrix[:, cheap_idx] + margin).astype(np.float64)
