"""Worker runtime: the execution layer behind campaign dispatch.

Every campaign path — pooled, prefetched, adaptive, quality-retuned —
dispatches through one ``WorkerPool`` protocol with two
implementations:

- ``LocalWorkerPool`` is the in-process *simulated* fleet (the former
  ``campaign._CampaignRun``): one real ``AdaParseEngine`` per node,
  per-node clocks advanced by the backends' cost models, injected
  stragglers (``ExecutorConfig.straggler_rate``), and
  ``node_speed_factors`` skew. It is the analytic/testing path — fast,
  fully deterministic, and the fleet the 128-node scaling stories run
  on.

- ``ProcessWorkerPool`` backs the same dispatch with **real OS worker
  processes** (``multiprocessing`` spawn context; entrypoint
  ``repro.launch.worker_main``). Each worker rebuilds its own engine
  from a serialized ``WorkerSpec`` (``EngineConfig`` + router + corpus
  config + backend registry spec + result-store dir), and a small
  message protocol — ``PrepareTask`` / ``CompleteTask`` / ``BatchDone``
  / ``Heartbeat`` dataclasses over multiprocessing queues — carries
  batch work out and ``engine.BatchTelemetry`` back. Straggler
  detection is no longer simulated: workers heartbeat on a fixed
  interval, and a worker that misses ``heartbeat_timeout_s`` (wedged)
  or whose process dies (crashed) has its in-flight batches re-issued
  to the least-loaded eligible peer (``scheduler.reissue_candidates``
  — same pool first, crossing pools only when the backend's device
  allows). First completion wins: late results from a straggler that
  recovers are deduplicated by task id, so a re-issue never duplicates
  an emitted record.

Batch payloads default to the zero-copy shared-memory transport
(``core/shm``): the ``PrepareTask``/``CompleteTask``/``BatchDone``
dataclasses stay control-plane messages, while the numpy-heavy bulk
(documents, forwarded prepared batches, result records) travels through
generation-tagged ``ShmArena`` slots — re-issue-safe (a straggler
reading a reclaimed slot gets a clean stale error, and its late reply
drops at the dedup gate), cleaned up by the coordinator on worker crash
and in ``close()``, and falling back to inline pickled payloads
whenever ``/dev/shm`` is unavailable or a payload outgrows its slot
(``ExecutorConfig.transport="pickle"`` forces the old path).

Determinism contract (shared by both pools): batch rng streams are
keyed by the batch's *global* index and carried from prepare into
complete, so an N-process campaign — pooled, prefetched, disk-cached,
crash-recovered, adaptive, or all of the above — produces exactly the
record set of a single-node in-process run over the same corpus.
Telemetry differs (real wall-clock vs simulated node-seconds); records
never do. A shared on-disk ``backends.DiskResultStore`` works across
worker processes (multi-process-safe WAL appends): each worker opens
the store dir itself, and a later single-process warm run replays the
fleet's records byte-identically.
"""
from __future__ import annotations

import dataclasses
import multiprocessing as mp
import os
import queue as queue_lib
import sys
import time
import uuid
from collections import deque
from typing import Protocol, runtime_checkable

import numpy as np

from repro.core import backends as B
from repro.core import obs
from repro.core import scheduler
from repro.core.engine import (AdaParseEngine, BatchTelemetry, EngineConfig,
                               EngineStats)
from repro.core.shm import (CoordinatorShmTransport, ShmArena,  # noqa: F401
                            ShmRef)
from repro.data.pipeline import Prefetcher

TRANSPORTS = ("shm", "pickle")

# ---------------------------------------------------------------------------
# Message protocol (coordinator <-> worker, over multiprocessing queues)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PrepareTask:
    """One batch of ingest work: prepare + route on the receiving
    worker. With ``forward`` set and expensive work routed, the worker
    returns the prepared payload (``BatchDone.prep``/``plan``) for the
    coordinator to forward as a ``CompleteTask`` to the re-parse pool;
    otherwise the worker completes locally and returns records.
    ``alpha`` pins the routing budget per task (round-boundary retunes
    and per-node α budgets ride on the task, not on worker state)."""

    task_id: int
    batch_key: int
    docs: list
    alpha: float
    forward: bool = False
    use_cache: bool = True
    payload: ShmRef | None = None    # shm transport: docs ride here
    attempt: int = 0                 # coordinator-side (re-)send count


@dataclasses.dataclass
class CompleteTask:
    """The expensive re-parse of a routed batch, forwarded to a node of
    the pool matching the expensive backend's device. ``prep``/``plan``
    are the ingest worker's ``engine.PreparedBatch`` / ``BatchPlan``
    (the batch's stateless rng stream travels inside ``prep``, so the
    completing worker emits byte-identical records)."""

    task_id: int
    batch_key: int
    prep: object
    plan: object
    alpha: float
    payload: ShmRef | None = None    # shm transport: (prep, plan) ride here
    attempt: int = 0                 # coordinator-side (re-)send count


@dataclasses.dataclass
class Heartbeat:
    """Liveness beacon, sent by every worker on a fixed interval (and
    once at startup, the ready signal). ``task_id`` names the batch the
    worker is currently executing, None when idle.

    Beyond liveness the beacon carries load context: ``sent_mono`` is
    the sender's ``time.monotonic()`` — a *same-host-only* diagnostic:
    CLOCK_MONOTONIC is per-machine (an arbitrary epoch each boot), so
    the coordinator compares it against its own monotonic clock only
    when sender and coordinator share a host (the process runtime;
    the cross-machine fabric runtime ignores it). Liveness deadlines
    never touch it — they run on coordinator *receive* time — and
    ``queue_depth`` is the worker's task-queue depth at send time (-1
    when the platform cannot report it) — together they let the
    coordinator distinguish a wedged worker from one that is alive but
    digesting a deep backlog before firing a re-issue. ``spans`` and
    ``metrics`` piggyback the observability plane (a bounded drain of
    the worker's span ring and its cumulative metrics snapshot) — no
    extra queues, None when tracing is disabled."""

    worker: int
    sent_at: float
    task_id: int | None = None
    sent_mono: float = 0.0
    queue_depth: int = -1
    spans: list | None = None
    metrics: dict | None = None


@dataclasses.dataclass
class BatchDone:
    """A worker's reply to one task. Exactly one of three shapes:
    records set (completed batch, ``telemetry`` riding along),
    ``prep``/``plan`` set (ingest stage of a forwarded batch), or
    ``error`` set (the traceback of a worker-side failure). ``wall_s``
    is the real measured stage duration — the process runtime's
    replacement for the simulated clocks."""

    task_id: int
    worker: int
    batch_key: int
    records: list | None = None
    telemetry: BatchTelemetry | None = None
    prep: object | None = None
    plan: object | None = None
    cached: bool = False
    wall_s: float = 0.0
    error: str | None = None
    # shm transport: the bulk reply (records, or the forwarded
    # (prep, plan)) rides in a response-arena slot instead of the queue
    payload: ShmRef | None = None
    payload_kind: str = ""           # "records" | "prep"
    # observability piggyback: which (re-)send this reply answers, a
    # bounded drain of the worker's span ring, and its cumulative
    # metrics snapshot (None when tracing is disabled)
    attempt: int = 0
    spans: list | None = None
    metrics: dict | None = None


@dataclasses.dataclass(frozen=True)
class FaultInjection:
    """Deterministic fault hooks for the process runtime (tests and
    chaos demos; production campaigns leave this None).

    ``crash_after``: ``((worker, n), ...)`` — the worker hard-exits
    (``os._exit``) on receiving its (n+1)-th task, losing the
    in-flight batch (the crash-recovery path: heartbeats stop, the
    coordinator re-issues to a pool peer).
    ``mute_after``: ``((worker, n), ...)`` — the worker stops
    heartbeating after n completed tasks but keeps working (a
    wedged-looking straggler whose late duplicate results the
    coordinator must drop).
    ``mute_slowdown_s``: extra per-task sleep once muted, so the
    re-issued attempt and the straggler race.
    ``unmute_after``: ``((worker, n), ...)`` — the worker resumes
    heartbeating after n completed tasks; with ``mute_after`` this
    makes the mute window ``[mute_after, unmute_after)`` in completed
    tasks (a flapping straggler: quiet → re-issue → recover → the
    coordinator must re-admit it without overcommitting its in-flight
    window while late results are still owed)."""

    crash_after: tuple = ()
    mute_after: tuple = ()
    mute_slowdown_s: float = 0.0
    unmute_after: tuple = ()


@dataclasses.dataclass(frozen=True)
class WorkerSpec:
    """Everything a spawned worker needs to rebuild its engine: the
    serialized engine/corpus configs, the router (content-addressed —
    ``engine._router_fingerprint`` reproduces the same cache tag in
    every process), the result-store directory, and a backend registry
    spec (``(module, attr)`` factories re-registered in the child, so
    custom backends flow into worker processes the same way they flow
    through the in-process registry)."""

    worker_id: int
    ecfg: EngineConfig
    router: object
    corpus_cfg: object
    image_degraded: bool = False
    text_degraded: bool = False
    alpha: float | None = None          # per-node α override (weighted budgets)
    cache_dir: str | None = None
    cache_max_bytes: int | None = None
    probe_cfg: object | None = None     # quality.QualityProbeConfig
    backend_specs: tuple = ()           # ((module, attr) factory pairs)
    heartbeat_interval_s: float = 0.5
    fault: FaultInjection | None = None
    # zero-copy transport (core/shm): arena namespace + fleet geometry;
    # shm_base None means pickled payloads (transport="pickle")
    shm_base: str | None = None
    n_workers: int = 1
    shm_resp_slots: int = 8
    # fleet-shared persistent autotune store (kernels/tuning_store):
    # every worker configures this dir, so block-size sweeps amortize
    # across the fleet and a warm restart performs zero re-sweeps
    tuning_dir: str | None = None
    # observability plane (core/obs): span tracing defaults off (noop
    # recorder); when on, the worker records into a bounded ring and
    # ships drained slices on its outgoing messages
    obs_enabled: bool = False
    obs_span_cap: int = 8192
    # content fingerprint (core/specs.spec_fingerprint) stamped by the
    # coordinator before the spec ships; the receiving worker recomputes
    # it after deserializing and refuses to run on a mismatch (guards
    # serialization drift, and the fabric runtime's admission check
    # compares a dialing worker's fingerprint against the same value)
    fingerprint: dict | None = None


# ---------------------------------------------------------------------------
# WorkerPool protocol
# ---------------------------------------------------------------------------


@runtime_checkable
class WorkerPool(Protocol):
    """What campaign dispatch needs from a fleet, local or real.

    ``drain`` runs per-node work queues to completion (callable
    repeatedly — the controller's rounds); ``clocks`` accumulates
    per-node busy node-seconds (simulated or measured);
    ``node_telemetry`` is the per-node ``BatchTelemetry`` stream the
    adaptive controller reads; ``set_alpha`` applies a round-boundary
    retune to every node."""

    n_nodes: int
    records: dict
    clocks: np.ndarray
    reissued: int
    reissued_reparse: int

    def drain(self, queues: dict[int, list]) -> None: ...

    def node_telemetry(self, node: int) -> list[BatchTelemetry]: ...

    def set_alpha(self, alpha: float) -> None: ...

    def node_stats(self) -> list[EngineStats]: ...

    def snapshot_cache(self, cache) -> tuple[int, int]: ...

    def finalize(self, n_docs: int, cache, hits0: int, miss0: int) -> dict: ...

    def close(self) -> None: ...


# ---------------------------------------------------------------------------
# LocalWorkerPool: the in-process simulated fleet
# ---------------------------------------------------------------------------


class LocalWorkerPool:
    """Simulated in-process fleet (the former ``campaign._CampaignRun``):
    mutable campaign state + the work-conserving dispatch loop, shared
    by the one-shot ``CampaignExecutor`` and the round-based
    ``CampaignController`` (which calls ``drain`` once per round while
    clocks, engines, and straggler statistics persist across rounds).
    Stragglers are injected (``ExecutorConfig.straggler_rate``) and
    node speed skew is simulated (``node_speed_factors``) — clocks and
    telemetry only, never records."""

    def __init__(self, ecfg: EngineConfig, xcfg, engines: list[AdaParseEngine],
                 n_nodes: int, ingest_nodes: list[int],
                 reparse_nodes: list[int], pools: list[str] | None):
        self.ecfg = ecfg
        self.xcfg = xcfg
        self.engines = engines
        self.n_nodes = n_nodes
        self.ingest_nodes = ingest_nodes
        self.reparse_nodes = reparse_nodes
        self.pools = pools
        self.cheap_dev = B.get_backend(ecfg.cheap).info.device
        self.exp_dev = B.get_backend(ecfg.expensive).info.device
        self.clocks = np.zeros(n_nodes, np.float64)
        self.records: dict = {}
        self.reissued = 0
        self.reissued_reparse = 0
        self.mean_batch = 0.0
        self.n_done = 0
        self.rng = np.random.RandomState(xcfg.seed)
        sf = xcfg.node_speed_factors
        if sf is None:
            self.speed = np.ones(n_nodes, np.float64)
        else:
            # sized to the *configured* fleet; a small corpus may clamp
            # the effective node count below it, so slice rather than
            # reject a config that is valid at full scale
            if len(sf) != xcfg.n_nodes:
                raise ValueError(f"need {xcfg.n_nodes} node speed factors "
                                 f"(one per configured node), got "
                                 f"{len(sf)}")
            self.speed = np.asarray(sf[:n_nodes], np.float64)
            if np.any(self.speed <= 0):
                raise ValueError("node speed factors must be positive")

    # -- WorkerPool protocol -------------------------------------------------

    def node_telemetry(self, node: int) -> list[BatchTelemetry]:
        return self.engines[node].telemetry

    def set_alpha(self, alpha: float) -> None:
        for e in self.engines:
            e.set_alpha(alpha)

    def node_stats(self) -> list[EngineStats]:
        return [e.stats for e in self.engines]

    def obs_drain(self) -> tuple[list, list]:
        """The simulated fleet records into this process's recorder and
        registry directly; the executor reads those itself."""
        return [], []

    def close(self) -> None:
        """Nothing to tear down in-process."""

    # -- one batch -----------------------------------------------------------

    def execute(self, node, batch, prep_item=None, use_cache=True,
                force_reparse=None):
        """Full pipeline for one batch: prepare+route on ``node``,
        complete on the reparse pool (or on ``force_reparse``). Returns
        (records, ingest_dur, reparse_dur, reparse_node, cache_hit)
        with durations in *unscaled* node-seconds (speed factors apply
        at clock-advance time). ``use_cache=False`` (straggler
        re-issue) forces a real re-parse: the abandoned attempt has
        already stored this key, and replaying it would model the
        re-issued work as free."""
        eng = self.engines[node]
        if prep_item is None:
            key, prep, cached = eng.prepare_or_lookup(
                batch["docs"], batch_key=batch["batch_key"],
                use_cache=use_cache)
        else:
            key, prep, cached = prep_item
        if cached is not None:
            eng._account_cache_hit(cached, batch["batch_key"])
            return cached, 0.0, 0.0, node, True
        plan = eng.route_batch(prep)
        # forward the re-parse to the matching pool only when there is
        # re-parse work; otherwise finish locally
        if plan.expensive_idx.size == 0:
            g = node
        elif force_reparse is not None:
            g = force_reparse
        elif self.pools is None:
            g = node
        else:
            g = scheduler.least_loaded(self.reparse_nodes, self.clocks)
        geng = self.engines[g]
        ingest_dur = (prep.ingest_cost_s
                      + eng.cfg.router_cost_s * len(prep.docs))
        before = eng.stats.node_seconds + (
            geng.stats.node_seconds if geng is not eng else 0.0)
        recs = geng.complete_batch(prep, plan, node_id=g,
                                   ingest_engine=eng)
        after = eng.stats.node_seconds + (
            geng.stats.node_seconds if geng is not eng else 0.0)
        reparse_dur = (after - before) - ingest_dur
        if key is not None:
            eng.cache.store(key, recs)
        return recs, ingest_dur, reparse_dur, g, False

    def advance(self, node, ing, rep, g):
        """Advance the simulated clocks by one batch's work, scaled by
        the per-node speed factors."""
        self.clocks[node] += ing * self.speed[node]
        if g == node:
            self.clocks[node] += rep * self.speed[node]
        else:
            # the reparse node picks the batch up when both it and
            # the ingest hand-off are ready
            self.clocks[g] = (max(self.clocks[g], self.clocks[node])
                              + rep * self.speed[g])

    def _wall(self, node, ing, rep, g) -> float:
        """Wall-clock cost of one batch under the speed factors."""
        return float(ing * self.speed[node] + rep * self.speed[g])

    # -- dispatch loop -------------------------------------------------------

    def drain(self, queues: dict[int, list]) -> None:
        """Run every batch in ``queues`` (node -> work list) to
        completion, with prefetch overlap and pool-aware straggler
        re-issue. May be called repeatedly (the controller's rounds)."""
        xcfg = self.xcfg
        heads = {node: 0 for node in queues}

        def _make_prep(eng):
            return lambda batch: eng.prepare_or_lookup(
                batch["docs"], batch_key=batch["batch_key"])

        streams = {}
        if xcfg.prefetch_depth > 0:
            streams = {
                node: Prefetcher(iter(queues[node]),
                                 depth=xcfg.prefetch_depth,
                                 transform=_make_prep(self.engines[node]))
                for node in queues}

        try:
            while True:
                # work-conserving dispatch: fastest node with work goes next
                ready = [i for i in queues if heads[i] < len(queues[i])]
                if not ready:
                    break
                node = scheduler.least_loaded(ready, self.clocks)
                batch = queues[node][heads[node]]
                heads[node] += 1
                prep_item = (next(streams[node]) if node in streams
                             else None)
                recs, ing, rep, g, hit = self.execute(node, batch,
                                                      prep_item)
                if hit:
                    # replays cost nothing and cannot straggle; keep
                    # their zero duration out of the mean_batch deadline
                    # baseline (a partially warm run would otherwise
                    # collapse the deadline and re-issue real batches
                    # spuriously)
                    for r in recs:
                        self.records[r.doc_id] = r
                    rec_ = obs.recorder()
                    if rec_.enabled:
                        rec_.span("complete", batch["batch_key"],
                                  time.time(), 0.0, node=node,
                                  cached=True)
                    continue
                dur = self._wall(node, ing, rep, g)
                if self.rng.rand() < xcfg.straggler_rate and self.n_done:
                    hung = dur * xcfg.straggler_slowdown
                    deadline = xcfg.deadline_factor * self.mean_batch
                    if hung > deadline:
                        recs, dur = self._reissue(node, batch, recs,
                                                  ing, rep, g, hung,
                                                  deadline)
                    else:
                        self.advance(node, ing * xcfg.straggler_slowdown,
                                     rep * xcfg.straggler_slowdown, g)
                        dur = hung
                else:
                    self.advance(node, ing, rep, g)
                for r in recs:
                    self.records[r.doc_id] = r
                rec_ = obs.recorder()
                if rec_.enabled:
                    # one winning complete span per batch; dur is the
                    # simulated wall cost under the speed factors
                    rec_.span("complete", batch["batch_key"],
                              time.time() - dur, dur, node=g)
                self.n_done += 1
                self.mean_batch += (dur - self.mean_batch) / self.n_done
        finally:
            for pf in streams.values():
                pf.close()

    def _reissue(self, node, batch, recs, ing, rep, g, hung, deadline):
        """Past-deadline straggler: re-issue the ACTUAL batch to the
        least-loaded eligible peer (``scheduler.reissue_candidates``:
        same pool first, crossing pools only when the backend's device
        allows); same batch_key -> identical records. Both attempts
        performed real work, so both stay charged in the per-node
        EngineStats. With no eligible peer the hung task just runs to
        completion at the slowdown."""
        xcfg = self.xcfg
        if g != node and rep > 0:
            # the forwarded expensive re-parse hung on the pool node
            peers = scheduler.reissue_candidates(g, self.pools,
                                                 self.exp_dev, self.n_nodes)
            if peers:
                self.reissued += 1
                self.reissued_reparse += 1
                obs.metrics().count("pool.reissued")
                obs.metrics().count("pool.reissued_reparse")
                rec_ = obs.recorder()
                if rec_.enabled:
                    rec_.span("reissue", batch["batch_key"],
                              time.time(), 0.0, node=g, abandoned=True,
                              detail=f"simulated straggler, reparse "
                                     f"stage on node {g}")
                # ingest completed normally; the reparse node abandons
                # the hung attempt at the deadline. The re-run below
                # appends its own telemetry, so the abandoned attempt's
                # docs must not count toward observed throughput
                self.engines[node].telemetry[-1].abandoned = True
                self.clocks[node] += ing * self.speed[node]
                self.clocks[g] = (max(self.clocks[g], self.clocks[node])
                                  + deadline)
                g2 = scheduler.least_loaded(peers, self.clocks)
                recs, ing, rep, g = self.execute(node, batch,
                                                 use_cache=False,
                                                 force_reparse=g2)[:4]
                # the repeated prepare exists only to regenerate the
                # batch's stateless rng stream — the ingest already ran
                # (and was charged) once, so only the re-issued re-parse
                # advances the clocks
                self.clocks[g] = (max(self.clocks[g], self.clocks[node])
                                  + rep * self.speed[g])
                self.engines[g].stats.reissued_tasks += 1
                return recs, self._wall(node, ing, rep, g)
        else:
            peers = scheduler.reissue_candidates(node, self.pools,
                                                 self.cheap_dev,
                                                 self.n_nodes)
            if peers:
                # give up on the hung ingest at the deadline and re-run
                # the whole batch on the fastest eligible peer; the
                # abandoned attempt's docs re-appear in the peer's
                # telemetry, so skip them in throughput measurement
                self.engines[node].telemetry[-1].abandoned = True
                self.reissued += 1
                obs.metrics().count("pool.reissued")
                rec_ = obs.recorder()
                if rec_.enabled:
                    rec_.span("reissue", batch["batch_key"],
                              time.time(), 0.0, node=node,
                              abandoned=True,
                              detail="simulated straggler, full batch")
                self.clocks[node] += deadline
                other = scheduler.least_loaded(peers, self.clocks)
                recs, ing, rep, g = self.execute(other, batch,
                                                 use_cache=False)[:4]
                self.advance(other, ing, rep, g)
                self.engines[other].stats.reissued_tasks += 1
                return recs, self._wall(other, ing, rep, g)
        # no eligible peer: the straggler runs to completion
        self.advance(node, ing * xcfg.straggler_slowdown,
                     rep * xcfg.straggler_slowdown, g)
        return recs, hung

    # -- result assembly -----------------------------------------------------

    def snapshot_cache(self, cache) -> tuple[int, int]:
        return ((cache.hits, cache.misses) if cache is not None
                else (0, 0))

    def finalize(self, n_docs: int, cache, hits0: int,
                 miss0: int) -> dict:
        """Shared ExecutorResult field assembly (flush the store, wall /
        busy from the clocks, cache-delta counters)."""
        if cache is not None:
            cache.flush()       # persist batched LRU bumps (disk store)
        wall = float(self.clocks.max()) if n_docs else 0.0
        busy = (float(self.clocks.sum()) / (self.n_nodes * wall)) \
            if wall else 0.0
        return dict(
            records=self.records,
            wall_s=wall,
            docs_per_s=n_docs / wall if wall else 0.0,
            node_busy_frac=busy,
            reissued=self.reissued,
            node_stats=[e.stats for e in self.engines],
            cache_hits=(cache.hits - hits0) if cache is not None else 0,
            cache_misses=(cache.misses - miss0) if cache is not None
            else 0,
            reissued_reparse=self.reissued_reparse)


# ---------------------------------------------------------------------------
# ProcessWorkerPool: real OS worker processes
# ---------------------------------------------------------------------------


class _TaskState:
    """Coordinator-side record of one batch's lifecycle: which stage it
    is in, which workers currently owe a result for it (more than one
    after a re-issue), and whether it already completed (the dedup
    gate — first completion wins, late duplicates are dropped)."""

    __slots__ = ("task_id", "node", "batch_key", "docs", "alpha",
                 "stage", "prep", "plan", "ingest_worker", "current",
                 "done", "needs_reissue", "prep_ref", "comp_ref",
                 "attempt")

    def __init__(self, task_id, node, batch_key, docs, alpha):
        self.task_id = task_id
        self.node = node                 # ingest node the batch was queued on
        self.batch_key = batch_key
        self.docs = docs
        self.alpha = alpha
        self.attempt = 0                 # sends so far (re-issues bump it)
        self.stage = "prepare"           # "prepare" | "complete"
        self.prep = None                 # kept for complete-stage re-issue
        self.plan = None
        self.ingest_worker = None        # worker that ran the ingest stage
        self.current: set[int] = set()   # workers owing a result
        self.done = False
        # stalled with its previous attempt lost: the next dispatch is
        # a (deferred) re-issue and must be counted as one
        self.needs_reissue = False
        # shm task-arena slots: packed once per stage, shared by every
        # (re-)issue of that stage, reclaimed when the task completes
        self.prep_ref = None
        self.comp_ref = None


class ProcessWorkerPool:
    """Real worker processes behind campaign dispatch.

    One spawned process per node (``repro.launch.worker_main``), one
    task queue per worker (the coordinator targets placement), one
    shared result queue back. ``drain`` keeps up to
    ``1 + prefetch_depth`` tasks in flight per worker — the process
    runtime's prefetch overlap: the worker's host prepare of a queued
    batch overlaps the coordinator round-trip of the previous one.

    Straggler detection runs on real heartbeat deadlines: a worker that
    misses ``heartbeat_timeout_s`` (wedged) or whose process dies
    (crashed) has its in-flight batches re-issued to the least-loaded
    eligible peer (``scheduler.reissue_candidates`` — same pool first,
    crossing pools only when the backend's device allows). A dead
    worker's queued-but-unstarted work re-routes the same way. First
    completion wins; a recovered straggler's late duplicates are
    dropped (``duplicates_dropped``), so re-issue never duplicates an
    emitted record.

    ``clocks`` accumulate *measured* per-batch wall seconds per worker
    — the controller's throughput EWMA therefore adapts to real node
    speed, not a simulated skew. Records stay placement-independent
    (stateless batch keys), so however batches land, re-issue, or
    replay from a shared ``DiskResultStore``, the record set equals the
    single-node in-process run byte-for-byte."""

    _POLL_S = 0.05
    #: heartbeat ``sent_mono`` stamps are comparable with the
    #: coordinator's monotonic clock only when every worker shares its
    #: host (true for spawned processes; the cross-machine fabric
    #: subclass sets this False). Liveness deadlines never depend on it
    #: — they run on coordinator *receive* time (``_beat``) — it only
    #: gates the same-host queue-delay diagnostic (``_hb_delay``).
    _mono_comparable = True

    @staticmethod
    def _validate_xcfg(xcfg) -> None:
        if xcfg.node_speed_factors is not None:
            raise ValueError(
                "node_speed_factors are simulation-only (they skew the "
                "simulated clocks); the process runtime measures real "
                "node speed — drop them or use runtime='local'")
        if xcfg.heartbeat_timeout_s <= 0:
            raise ValueError(f"heartbeat_timeout_s must be > 0, got "
                             f"{xcfg.heartbeat_timeout_s}")
        if not 0 < xcfg.heartbeat_interval_s < xcfg.heartbeat_timeout_s:
            raise ValueError(
                f"heartbeat_interval_s must be in (0, heartbeat_timeout_s="
                f"{xcfg.heartbeat_timeout_s}), got "
                f"{xcfg.heartbeat_interval_s}")

    @staticmethod
    def _cache_cfg(cache) -> tuple[str | None, int | None]:
        if cache is None:
            return None, None
        if not isinstance(cache, B.DiskResultStore):
            raise ValueError(
                "an in-memory result store cannot be shared across "
                "worker processes; pass a DiskResultStore "
                "(serve.py --cache-dir) or use runtime='local'")
        return cache.dir, cache.max_bytes

    def __init__(self, ecfg: EngineConfig, xcfg, router, corpus_cfg,
                 n_nodes: int, ingest_nodes: list[int],
                 reparse_nodes: list[int], pools: list[str] | None, *,
                 alpha_of: dict[int, float] | None = None, cache=None,
                 probe_cfg=None, image_degraded=False, text_degraded=False,
                 backend_specs: tuple = ()):
        self._validate_xcfg(xcfg)
        transport = getattr(xcfg, "transport", "shm")
        if transport not in TRANSPORTS:
            raise ValueError(f"unknown transport {transport!r}; choose "
                             f"'shm' (zero-copy shared-memory payloads) "
                             f"or 'pickle' (queue-serialized payloads)")
        cache_dir, cache_max = self._cache_cfg(cache)
        self._init_state(ecfg, xcfg, n_nodes, ingest_nodes,
                         reparse_nodes, pools, alpha_of,
                         has_cache=cache_dir is not None)

        resp_slots = self._window + 4
        self._shm: CoordinatorShmTransport | None = None
        shm_base = None
        if transport == "shm":
            shm_base = f"adaparse-{os.getpid():x}-{uuid.uuid4().hex[:8]}"
            self._shm = CoordinatorShmTransport(
                shm_base, n_nodes,
                n_task_slots=2 * n_nodes * self._window + 8,
                n_resp_slots=resp_slots)

        from repro.launch.worker_main import worker_loop

        router = _portable_router(router)
        ctx = mp.get_context("spawn")
        self.result_q = ctx.Queue()
        self.task_qs = [ctx.Queue() for _ in range(n_nodes)]
        fault = getattr(xcfg, "fault_injection", None)
        fp = None
        self.procs = []
        for i in range(n_nodes):
            spec = self._worker_spec(
                i, router=router, corpus_cfg=corpus_cfg,
                cache_dir=cache_dir, cache_max=cache_max,
                probe_cfg=probe_cfg, image_degraded=image_degraded,
                text_degraded=text_degraded,
                backend_specs=tuple(backend_specs), fault=fault,
                shm_base=shm_base, resp_slots=resp_slots)
            if fp is None:
                # one fingerprint for the fleet (router fingerprint is
                # memoized, so this hashes once); the worker recomputes
                # and verifies it after deserializing
                from repro.core.specs import spec_fingerprint
                fp = spec_fingerprint(spec)
            spec = dataclasses.replace(spec, fingerprint=fp)
            p = ctx.Process(target=worker_loop,
                            args=(spec, self.task_qs[i], self.result_q),
                            daemon=True, name=f"adaparse-worker-{i}")
            p.start()
            self.procs.append(p)
        self._beat = [time.time()] * n_nodes
        self._await_ready()

    def _init_state(self, ecfg: EngineConfig, xcfg, n_nodes: int,
                    ingest_nodes: list[int], reparse_nodes: list[int],
                    pools: list[str] | None,
                    alpha_of: dict[int, float] | None, *,
                    has_cache: bool) -> None:
        """Coordinator bookkeeping shared by every transport subclass
        (the fabric pool re-uses all of it over sockets): dispatch
        topology, the dedup/liveness/window state, counters."""
        self.ecfg = ecfg
        self.xcfg = xcfg
        self.n_nodes = n_nodes
        self.ingest_nodes = ingest_nodes
        self.reparse_nodes = reparse_nodes
        self.pools = pools
        self.cheap_dev = B.get_backend(ecfg.cheap).info.device
        self.exp_dev = B.get_backend(ecfg.expensive).info.device
        self.alpha = ecfg.alpha
        self._alpha_of = dict(alpha_of or {})
        self._window = 1 + max(getattr(xcfg, "prefetch_depth", 0), 0)

        self.records: dict = {}
        self.clocks = np.zeros(n_nodes, np.float64)
        self.telemetry: list[list[BatchTelemetry]] = [[] for _ in
                                                      range(n_nodes)]
        self.reissued = 0
        self.reissued_reparse = 0
        self.duplicates_dropped = 0
        self.cache_hits = 0
        self.cache_misses = 0
        self._has_cache = has_cache
        self._wall_s = 0.0
        self._tasks: dict[int, _TaskState] = {}
        self._open: set[int] = set()     # not-yet-done task ids
        # (task_id, worker) results a live straggler still owes after a
        # re-issue won the race — drain lingers briefly for them so the
        # dedup counter is observable, then abandons them
        self._late: set[tuple[int, int]] = set()
        self._load = [0] * n_nodes       # open assignments per worker
        self._dead: set[int] = set()
        self._quiet: set[int] = set()    # missed-heartbeat workers
        # tasks with no live eligible worker *right now* (every
        # candidate is quiet, not dead) — retried each loop tick until
        # a straggler heartbeats back in
        self._stalled: set[int] = set()
        self._next_task_id = 0
        self._n_expensive = [0] * n_nodes
        self._reissued_tasks = [0] * n_nodes
        # observability plane: spans/snapshots absorbed from piggyback
        # fields on incoming messages, plus per-worker heartbeat load
        # context (last reported task-queue depth + in-flight task) so
        # liveness policing can tell backlog from wedge
        self.obs_spans: list = []
        self._obs_snaps: dict[int, dict] = {}
        self._hb_depth = [-1] * n_nodes
        self._hb_task: list[int | None] = [None] * n_nodes
        self._hb_delay = [0.0] * n_nodes
        # live status line (serve.py --status-interval)
        self._status_every = max(
            getattr(xcfg, "status_interval_s", 0.0) or 0.0, 0.0)
        self._status_next = 0.0
        self._total_batches = 0
        self._batches_done = 0
        self._docs_done = 0

    def _worker_spec(self, i: int, *, router, corpus_cfg, cache_dir,
                     cache_max, probe_cfg, image_degraded, text_degraded,
                     backend_specs: tuple, fault,
                     shm_base: str | None, resp_slots: int) -> WorkerSpec:
        """The serialized spec worker ``i`` rebuilds its engine from —
        shared verbatim by the spawn transport (shm payloads) and the
        fabric transport (``shm_base=None``, inline payloads)."""
        return WorkerSpec(
            worker_id=i, ecfg=self.ecfg, router=router,
            corpus_cfg=corpus_cfg, image_degraded=image_degraded,
            text_degraded=text_degraded,
            alpha=self._alpha_of.get(i), cache_dir=cache_dir,
            cache_max_bytes=cache_max, probe_cfg=probe_cfg,
            backend_specs=tuple(backend_specs),
            heartbeat_interval_s=self.xcfg.heartbeat_interval_s,
            fault=fault, shm_base=shm_base, n_workers=self.n_nodes,
            shm_resp_slots=resp_slots,
            tuning_dir=getattr(self.xcfg, "tuning_dir", None),
            obs_enabled=getattr(self.xcfg, "obs", False),
            obs_span_cap=getattr(self.xcfg, "obs_span_cap", 8192))

    # -- startup -------------------------------------------------------------

    def _await_ready(self) -> None:
        """Block until every worker has built its engine and sent the
        ready heartbeat (spawn + imports dominate; a worker that fails
        to build reports its traceback instead of hanging the pool)."""
        ready: set[int] = set()
        deadline = time.time() + self.xcfg.worker_start_timeout_s
        while len(ready) < self.n_nodes:
            timeout = deadline - time.time()
            if timeout <= 0:
                missing = sorted(set(range(self.n_nodes)) - ready)
                self.close()
                raise RuntimeError(
                    f"workers {missing} not ready within "
                    f"{self.xcfg.worker_start_timeout_s}s "
                    f"(worker_start_timeout_s)")
            try:
                msg = self.result_q.get(timeout=min(timeout, 1.0))
            except queue_lib.Empty:
                continue
            if isinstance(msg, BatchDone) and msg.error is not None:
                self.close()
                raise RuntimeError(f"worker {msg.worker} failed to "
                                   f"start:\n{msg.error}")
            if isinstance(msg, Heartbeat):
                ready.add(msg.worker)
                self._beat[msg.worker] = time.time()

    # -- WorkerPool protocol -------------------------------------------------

    def node_telemetry(self, node: int) -> list[BatchTelemetry]:
        return self.telemetry[node]

    def set_alpha(self, alpha: float) -> None:
        """Round-boundary retune: subsequent tasks carry the new α (the
        workers' engines follow per task, invalidating their route
        steps and cache tags exactly like the local path)."""
        self.alpha = alpha
        self._alpha_of = {}

    def node_stats(self) -> list[EngineStats]:
        """Per-node stats reconstructed from the coordinator's view:
        docs/expensive counts from the ingest telemetry, busy seconds
        from the measured clocks."""
        stats = []
        for i in range(self.n_nodes):
            st = EngineStats(node_seconds=float(self.clocks[i]))
            for t in self.telemetry[i]:
                st.n_docs += t.n_docs
                if t.cached:
                    st.cache_hits += 1
            st.n_expensive = self._n_expensive[i]
            st.reissued_tasks = self._reissued_tasks[i]
            stats.append(st)
        return stats

    def snapshot_cache(self, cache) -> tuple[int, int]:
        """Worker-side stores count hits/misses through BatchDone, not
        through the coordinator's store object."""
        return (0, 0)

    def finalize(self, n_docs: int, cache, hits0: int, miss0: int) -> dict:
        if cache is not None:
            cache.flush()
        wall = self._wall_s if n_docs else 0.0
        busy = (float(self.clocks.sum()) / (self.n_nodes * wall)) \
            if wall else 0.0
        return dict(
            records=self.records,
            wall_s=wall,
            docs_per_s=n_docs / wall if wall else 0.0,
            node_busy_frac=busy,
            reissued=self.reissued,
            node_stats=self.node_stats(),
            cache_hits=self.cache_hits,
            cache_misses=self.cache_misses,
            reissued_reparse=self.reissued_reparse,
            duplicates_dropped=self.duplicates_dropped)

    def obs_drain(self) -> tuple[list, list]:
        """Spans + per-worker metric snapshots absorbed from message
        piggybacks so far (the executor folds in its own process's
        recorder and registry on top)."""
        spans, self.obs_spans = self.obs_spans, []
        return spans, list(self._obs_snaps.values())

    def close(self) -> None:
        for i, q in enumerate(self.task_qs):
            try:
                q.put_nowait(None)          # shutdown sentinel
            except (ValueError, OSError, queue_lib.Full):
                pass
        for p in self.procs:
            p.join(timeout=3.0)
        for p in self.procs:
            if p.is_alive():
                p.terminate()
                p.join(timeout=1.0)
        for q in [self.result_q, *self.task_qs]:
            try:
                q.cancel_join_thread()
                q.close()
            except (ValueError, OSError):
                pass
        if self._shm is not None:
            self._shm.close()       # unlink every remaining segment

    # -- dispatch loop -------------------------------------------------------

    def drain(self, queues: dict[int, list]) -> None:
        """Run every queued batch to completion on the worker fleet.
        May be called repeatedly (the controller's rounds); workers and
        the coordinator's dedup state persist across calls, so a late
        duplicate from a previous round is still dropped."""
        pending = {node: deque(items) for node, items in queues.items()
                   if items}
        self._total_batches += sum(len(q) for q in pending.values())
        t0 = time.perf_counter()
        if self._status_every:
            self._status_next = t0 + self._status_every
        try:
            while True:
                self._top_up(pending)
                self._retry_stalled()
                if not pending and not self._open:
                    break
                self._pump()
                self._police()
                self._status_tick(t0)
        finally:
            # the settle window below is bookkeeping, not batch work —
            # wall_s measures time-to-last-record
            self._wall_s += time.perf_counter() - t0
        # settle: recovered stragglers may still owe the late duplicate
        # of a re-issued batch. Linger a bounded grace period so the
        # dedup accounting is observable (records are already final —
        # first completion won); anything later is dropped unread.
        deadline = time.perf_counter() + max(
            getattr(self.xcfg, "straggler_grace_s", 0.0), 0.0)
        while self._late and time.perf_counter() < deadline:
            self._pump()
            self._police()
        for i in range(self.n_nodes):
            obs.metrics().gauge(f"pool.load.n{i}", self._load[i])
        obs.metrics().gauge("pool.window", self._window)

    def _status_tick(self, t0: float) -> None:
        """serve.py --status-interval: a periodic one-line stderr pulse
        (docs/s, α, cache hit rate, in-flight, re-issues)."""
        if not self._status_every:
            return
        now = time.perf_counter()
        if now < self._status_next:
            return
        self._status_next = now + self._status_every
        elapsed = self._wall_s + (now - t0)
        dps = self._docs_done / elapsed if elapsed > 0 else 0.0
        print(obs.status_line(dps, self.alpha, self.cache_hits,
                              self.cache_misses, sum(self._load),
                              self.reissued, self._batches_done,
                              self._total_batches),
              file=sys.stderr, flush=True)

    def _healthy(self, w: int) -> bool:
        return w not in self._dead and w not in self._quiet

    def _owed(self, w: int) -> int:
        """Late results a live worker still owes (re-issued while it was
        quiet, but its attempt is still executing). They occupy the
        worker exactly like open assignments, so the in-flight window
        must count them: otherwise a quiet→recover cycle refills the
        full window on top of the still-running batches, overcommitting
        a just-recovered straggler. Entries clear when the late
        BatchDone arrives or the worker dies."""
        return sum(1 for _tid, lw in self._late if lw == w)

    def _effective_load(self, w: int) -> int:
        return self._load[w] + self._owed(w)

    def _send(self, w: int, task: _TaskState) -> None:
        """Packs the stage's bulk payload into a task-arena slot once
        (re-issues of the same stage reuse the ref — the slot lives
        until the task completes); a failed pack (slot pressure, shm
        unavailable) ships the payload inline instead."""
        if task.stage == "prepare":
            docs = task.docs
            if self._shm is not None:
                if task.prep_ref is None:
                    task.prep_ref = self._shm.encode_task(task.docs)
                if task.prep_ref is not None:
                    docs = None
            msg = PrepareTask(task.task_id, task.batch_key, docs,
                              task.alpha, forward=self.pools is not None,
                              payload=task.prep_ref,
                              attempt=task.attempt)
        else:
            prep, plan = task.prep, task.plan
            if self._shm is not None:
                if task.comp_ref is None:
                    task.comp_ref = self._shm.encode_task(
                        (task.prep, task.plan))
                if task.comp_ref is not None:
                    prep = plan = None
            msg = CompleteTask(task.task_id, task.batch_key, prep, plan,
                               task.alpha, payload=task.comp_ref,
                               attempt=task.attempt)
        task.attempt += 1
        task.current.add(w)
        self._load[w] += 1
        self.task_qs[w].put(msg)

    def _top_up(self, pending: dict[int, deque]) -> None:
        """Keep every healthy worker's in-flight window full; work
        queued on a dead/quiet node re-routes to the least-loaded
        eligible peer (same rule as re-issue)."""
        for node in list(pending):
            q = pending[node]
            while q:
                if self._healthy(node) and \
                        self._effective_load(node) < self._window:
                    target = node
                else:
                    if self._healthy(node):
                        break               # its window is full: wait
                    peers = [i for i in scheduler.reissue_candidates(
                        node, self.pools, self.cheap_dev, self.n_nodes,
                        exclude=self._dead)
                        if self._healthy(i)
                        and self._effective_load(i) < self._window]
                    if not peers:
                        if self._no_possible_worker(node):
                            raise RuntimeError(
                                f"ingest node {node} is gone and no "
                                f"eligible peer is alive; campaign "
                                f"cannot complete")
                        break               # peers busy/quiet: wait
                    target = scheduler.least_loaded(peers, self.clocks)
                batch = q.popleft()
                tid = self._next_task_id
                self._next_task_id += 1
                t = _TaskState(tid, node, batch["batch_key"],
                               batch["docs"],
                               self._alpha_of.get(node, self.alpha))
                self._tasks[tid] = t
                self._open.add(tid)
                self._send(target, t)
            if not q:
                del pending[node]

    def _no_possible_worker(self, node: int) -> bool:
        return node in self._dead and not scheduler.reissue_candidates(
            node, self.pools, self.cheap_dev, self.n_nodes,
            exclude=self._dead)

    def _try_dispatch(self, t: _TaskState) -> bool:
        """Send ``t`` to the least-loaded live worker eligible for its
        stage. False when every live candidate is quiet (a straggler
        that may heartbeat back — the caller stalls and retries);
        raises only when every candidate is *dead*."""
        if t.stage == "complete":
            cands = [i for i in self.reparse_nodes
                     if i not in self._dead]
        else:
            cands = ([t.node] if t.node not in self._dead else []) \
                + scheduler.reissue_candidates(
                    t.node, self.pools, self.cheap_dev, self.n_nodes,
                    exclude=self._dead)
        peers = [i for i in cands if self._healthy(i)]
        if peers:
            self._send(scheduler.least_loaded(peers, self.clocks), t)
            return True
        if not cands:
            raise RuntimeError(
                f"no live worker can run batch {t.batch_key} "
                f"({t.stage} stage); campaign cannot complete")
        return False                     # alive-but-quiet candidates

    def _retry_stalled(self) -> None:
        for tid in list(self._stalled):
            t = self._tasks[tid]
            if t.done or t.current:
                self._stalled.discard(tid)
            elif self._try_dispatch(t):
                self._stalled.discard(tid)
                if t.needs_reissue:
                    t.needs_reissue = False
                    self.reissued += 1
                    obs.metrics().count("pool.reissued")
                    if t.stage == "complete":
                        self.reissued_reparse += 1
                        obs.metrics().count("pool.reissued_reparse")
                    rec = obs.recorder()
                    if rec.enabled:
                        rec.span("reissue", t.batch_key, time.time(),
                                 0.0, attempt=t.attempt,
                                 detail=f"stalled {t.stage} stage "
                                        f"re-dispatched")

    def _pump(self) -> None:
        """Drain the result queue: the first get blocks briefly (the
        loop's pacing), the rest are opportunistic."""
        try:
            self._handle(self.result_q.get(timeout=self._POLL_S))
        except queue_lib.Empty:
            return
        while True:
            try:
                self._handle(self.result_q.get_nowait())
            except queue_lib.Empty:
                return

    def _absorb_obs(self, worker: int, spans, snap) -> None:
        """Fold a message's piggybacked observability payload into the
        coordinator's collection (spans append; the metrics snapshot is
        cumulative, so last-write-wins per worker)."""
        if spans:
            self.obs_spans.extend(spans)
        if snap is not None:
            self._obs_snaps[worker] = snap

    def _handle(self, msg) -> None:
        if isinstance(msg, Heartbeat):
            self._beat[msg.worker] = time.time()
            self._hb_depth[msg.worker] = msg.queue_depth
            self._hb_task[msg.worker] = msg.task_id
            if msg.sent_mono and self._mono_comparable:
                # same-host fleets only: CLOCK_MONOTONIC has a
                # per-machine epoch, so differencing against a remote
                # worker's stamp is meaningless — the fabric subclass
                # keeps this diagnostic off. Liveness deadlines below
                # always run on coordinator receive time (_beat).
                self._hb_delay[msg.worker] = max(
                    0.0, time.monotonic() - msg.sent_mono)
            self._absorb_obs(msg.worker, msg.spans, msg.metrics)
            if msg.worker in self._quiet and \
                    self.procs[msg.worker].is_alive():
                self._quiet.discard(msg.worker)   # straggler recovered
            return
        if not isinstance(msg, BatchDone):
            return
        self._absorb_obs(msg.worker, msg.spans, msg.metrics)
        if msg.payload is not None:
            # copy the bulk reply out of the worker's response arena and
            # free the slot — unconditionally, so a dropped duplicate
            # can never strand a slot in the (bounded) response arena
            obj = self._shm.take_result(msg.payload)
            if msg.payload_kind == "prep":
                msg.prep, msg.plan = obj
            else:
                msg.records = obj
            msg.payload = None
        t = self._tasks.get(msg.task_id)
        if t is None:
            if msg.error is not None:
                # not tied to any known task (e.g. a worker failing
                # after the ready handshake): nothing to re-issue
                raise RuntimeError(f"worker {msg.worker} failed:\n"
                                   f"{msg.error}")
            return
        self._late.discard((msg.task_id, msg.worker))
        if msg.worker in t.current:
            t.current.discard(msg.worker)
            self._load[msg.worker] -= 1
        if t.done:
            # a re-issued straggler's late result — success or failure,
            # it lost the first-completion race and the records are
            # already final
            self.duplicates_dropped += 1
            obs.metrics().count("pool.dedup_dropped")
            rec = obs.recorder()
            if rec.enabled:
                rec.span("dedup", t.batch_key, time.time(), 0.0,
                         node=msg.worker, attempt=msg.attempt,
                         abandoned=True, detail="lost completion race")
            return
        if msg.error is not None:
            if t.current or msg.task_id in self._stalled:
                # a losing attempt failed while another attempt (or a
                # pending re-dispatch) still covers the batch — let
                # the survivor finish instead of tearing down the pool
                return
            raise RuntimeError(f"worker {msg.worker} failed on task "
                               f"{msg.task_id}:\n{msg.error}")
        if msg.prep is not None:
            if t.stage != "prepare":
                # late duplicate of an already-forwarded ingest stage
                self.duplicates_dropped += 1
                obs.metrics().count("pool.dedup_dropped")
                rec = obs.recorder()
                if rec.enabled:
                    rec.span("dedup", t.batch_key, time.time(), 0.0,
                             node=msg.worker, attempt=msg.attempt,
                             abandoned=True,
                             detail="duplicate ingest stage")
                return
            # ingest stage of a forwarded batch finished on msg.worker
            t.ingest_worker = msg.worker
            self.clocks[msg.worker] += msg.wall_s
            t.stage = "complete"
            t.prep, t.plan = msg.prep, msg.plan
            rec = obs.recorder()
            if rec.enabled:
                rec.span("forward", t.batch_key, time.time(), 0.0,
                         node=msg.worker, attempt=msg.attempt,
                         detail="prep handed to reparse pool")
            if not self._try_dispatch(t):
                self._stalled.add(t.task_id)
            return
        # final result for this batch
        t.done = True
        self._open.discard(t.task_id)
        for w in list(t.current):        # other outstanding attempts
            self._load[w] -= 1
            if w not in self._dead:
                self._late.add((t.task_id, w))
        t.current.clear()
        t.prep = t.plan = None
        t.docs = None
        if self._shm is not None:
            # reclaim the task's arena slots; freeing bumps the
            # generation, so any straggler still holding a ref fails
            # stale instead of reading a reused slot
            self._shm.free_task(t.prep_ref)
            self._shm.free_task(t.comp_ref)
            t.prep_ref = t.comp_ref = None
        for r in msg.records:
            self.records[r.doc_id] = r
        ingest = t.ingest_worker if t.ingest_worker is not None \
            else msg.worker
        if msg.telemetry is not None:
            self.telemetry[ingest].append(msg.telemetry)
            self._n_expensive[msg.worker] += msg.telemetry.n_expensive
        self.clocks[msg.worker] += msg.wall_s
        if self._has_cache:
            if msg.cached:
                self.cache_hits += 1
                obs.metrics().count("pool.cache_hits")
            else:
                self.cache_misses += 1
                obs.metrics().count("pool.cache_misses")
        self._batches_done += 1
        self._docs_done += len(msg.records)
        obs.metrics().count("pool.batches_done")
        obs.metrics().observe("pool.batch_wall_s", msg.wall_s)
        rec = obs.recorder()
        if rec.enabled:
            # the authoritative winning `complete` span: exactly one
            # per emitted batch, attributed to the worker whose attempt
            # won the first-completion race
            rec.span("complete", t.batch_key,
                     time.time() - msg.wall_s, msg.wall_s,
                     node=msg.worker, attempt=msg.attempt,
                     cached=msg.cached)

    def _police(self) -> None:
        """Liveness: a dead process (crash) is permanent — its open
        tasks re-issue and its queue re-routes. A worker that missed
        the heartbeat deadline (wedged) is quieted: its open tasks
        re-issue, no new work lands on it, and it rejoins on its next
        heartbeat (late duplicates are dropped)."""
        now = time.time()
        for w in range(self.n_nodes):
            if w in self._dead:
                continue
            if not self.procs[w].is_alive():
                self._dead.add(w)
                self._quiet.discard(w)
                self._late = {(tid, lw) for tid, lw in self._late
                              if lw != w}
                if self._shm is not None:
                    # crash recovery: drop the dead worker's response
                    # arena from /dev/shm now (the coordinator's mapping
                    # stays readable for replies it queued before dying)
                    self._shm.unlink_worker(w)
                self._reissue_from(w)
            elif (now - self._beat[w] > self._deadline_for(w)
                    and w not in self._quiet):
                self._quiet.add(w)
                self._reissue_from(w)

    def _deadline_for(self, w: int) -> float:
        """Effective heartbeat deadline for worker ``w``. A worker
        whose last beacon reported queued work is alive and digesting a
        deep backlog, not wedged — its beacons may simply be stuck
        behind bulky results in the shared queue. Grant one extra base
        timeout per reported queued task (bounded at 4x) before firing
        a re-issue; a worker that reported an empty queue, or one we
        have no depth report from, keeps the base deadline."""
        base = self.xcfg.heartbeat_timeout_s
        depth = self._hb_depth[w]
        if depth > 0:
            return base * (1.0 + min(depth, 4))
        return base

    def _reissue_from(self, w: int) -> None:
        """Re-issue every open task currently owed by ``w`` to the
        least-loaded eligible peer — same pool first, crossing pools
        only when the backend's device allows. The batch's stateless
        rng stream makes the re-run emit identical records, and the
        dedup gate keeps only the first completion."""
        for tid in list(self._open):
            t = self._tasks[tid]
            if w not in t.current:
                continue
            t.current.discard(w)
            self._load[w] -= 1
            if w not in self._dead:
                self._late.add((tid, w))
            device = self.exp_dev if t.stage == "complete" \
                else self.cheap_dev
            peers = [i for i in scheduler.reissue_candidates(
                w, self.pools, device, self.n_nodes,
                exclude=self._dead) if self._healthy(i)]
            if not peers:
                if t.current:
                    continue            # another attempt may finish
                # no live attempt remains right now: stall for retry.
                # A merely-quiet w may still deliver its own result
                # (then the stalled entry clears as done); if every
                # candidate is dead, _try_dispatch raises on the next
                # tick. A dead w's attempt is gone for good, so the
                # eventual re-dispatch counts as a re-issue.
                t.needs_reissue = w in self._dead
                self._stalled.add(tid)
                continue
            g = scheduler.least_loaded(peers, self.clocks)
            self._send(g, t)
            self.reissued += 1
            self._reissued_tasks[g] += 1
            obs.metrics().count("pool.reissued")
            if t.stage == "complete":
                self.reissued_reparse += 1
                obs.metrics().count("pool.reissued_reparse")
            rec = obs.recorder()
            if rec.enabled:
                cause = "crash" if w in self._dead else "wedged"
                rec.span("reissue", t.batch_key, time.time(), 0.0,
                         node=g, attempt=t.attempt,
                         detail=f"{cause} worker {w}, {t.stage} stage")


def _portable_router(router):
    """Back-compat alias: the implementation moved to
    ``core/specs.portable_router`` (shared with the fabric runtime)."""
    from repro.core.specs import portable_router

    return portable_router(router)


def make_worker_pool(ecfg: EngineConfig, xcfg, router, corpus_cfg,
                     n_nodes: int, ingest_nodes: list[int],
                     reparse_nodes: list[int], pools: list[str] | None, *,
                     engines: list[AdaParseEngine] | None = None,
                     alpha_of: dict[int, float] | None = None, cache=None,
                     probe=None, image_degraded=False, text_degraded=False
                     ) -> "WorkerPool":
    """The one dispatch point between the three runtimes: ``local``
    wraps the caller-built engines in the simulated fleet, ``process``
    spawns real worker processes, ``fabric`` listens for workers dialing
    in over TCP (core/fabric — loopback or other machines). In the
    latter two the caller builds no engines — each worker builds its own
    from the serialized spec."""
    runtime = getattr(xcfg, "runtime", "local")
    if runtime in ("process", "fabric"):
        if runtime == "fabric":
            from repro.core.fabric import FabricWorkerPool as pool_cls
        else:
            pool_cls = ProcessWorkerPool
        return pool_cls(
            ecfg, xcfg, router, corpus_cfg, n_nodes, ingest_nodes,
            reparse_nodes, pools, alpha_of=alpha_of, cache=cache,
            probe_cfg=(probe.cfg if probe is not None else None),
            image_degraded=image_degraded, text_degraded=text_degraded,
            backend_specs=getattr(xcfg, "worker_backend_specs", ()) or ())
    if runtime != "local":
        raise ValueError(f"unknown worker runtime {runtime!r}; choose "
                         f"'local' (in-process simulated fleet), "
                         f"'process' (real worker processes), or "
                         f"'fabric' (workers over TCP, core/fabric)")
    return LocalWorkerPool(ecfg, xcfg, engines, n_nodes, ingest_nodes,
                           reparse_nodes, pools)
