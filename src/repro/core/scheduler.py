"""Budget-constrained parser assignment (§4.1, App. C).

The optimization:  max_j Σ E[A(φ_{j_i}) | φ¹(d_i)]  s.t.  Σ T(φ_{j_i}) ≤ T̄

Two-parser case (AdaParse production config): sort documents by predicted
improvement of the expensive parser and route the top ⌊αk⌋ of each batch
of k — streaming, node-local, embarrassingly parallel. The general m-parser
case is solved by a greedy cost-benefit knapsack (host-side, used by the
selection-model benchmark).

``budget_topk`` is the jit-compatible device-side selection op; its Pallas
fusion lives in ``repro.kernels.budget_route``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import obs
from repro.kernels.budget_route.ops import capacity_floor


def alpha_for_budget(t_budget: float, n_docs: int, t_cheap: float,
                     t_expensive: float) -> float:
    """α ≤ (T̄ − n·T_cheap) / (n·(T_exp − T_cheap)), clipped to [0, 1]."""
    if n_docs == 0 or t_expensive <= t_cheap:
        return 1.0
    a = (t_budget - n_docs * t_cheap) / (n_docs * (t_expensive - t_cheap))
    return float(np.clip(a, 0.0, 1.0))


def budget_topk(scores: jax.Array, alpha: float) -> tuple[jax.Array, jax.Array]:
    """Device-side per-batch rule: route the ⌊α·k⌋ highest-scoring items.

    scores (k,) predicted improvement (E[A_exp] − E[A_cheap]).
    Returns (mask (k,) bool, indices (⌊αk⌋,) of selected items).
    Only items with positive predicted improvement are routed.
    """
    k = scores.shape[0]
    n_sel = capacity_floor(alpha, k)
    if n_sel == 0:
        return (jnp.zeros((k,), bool),
                jnp.zeros((0,), jnp.int32))
    vals, idx = jax.lax.top_k(scores, n_sel)
    keep = vals > 0
    mask = jnp.zeros((k,), bool).at[idx].set(keep)
    return mask, idx


def reissue_candidates(node: int, pools: list[str] | None, device: str,
                       n_nodes: int,
                       exclude: set[int] | frozenset | tuple = ()
                       ) -> list[int]:
    """Nodes eligible to take over work stuck on ``node`` (straggler
    re-issue, pool-aware).

    Same-pool peers come first: a straggling stage re-issues inside its
    own device pool. Crossing pools is allowed only when the backend's
    device permits it — a "cpu" backend runs anywhere (every node has
    host cores), while "gpu" work cannot leave the GPU pool; with no
    eligible peer the stuck task simply runs to completion. Without
    pools every other node is a peer.

    ``exclude`` removes nodes from the fleet *before* the same-pool
    short-circuit (the worker runtime passes its dead workers): if
    every same-pool peer is gone, CPU work still falls through to the
    cross-pool nodes instead of concluding no peer exists."""
    obs.metrics().count("sched.reissue_lookups")
    gone = set(exclude)
    gone.add(node)
    if pools is None:
        return [i for i in range(n_nodes) if i not in gone]
    same = [i for i in range(n_nodes)
            if i not in gone and pools[i] == pools[node]]
    if same:
        return same
    if device == "cpu":
        return [i for i in range(n_nodes) if i not in gone]
    obs.metrics().count("sched.reissue_no_peer")
    return []


def least_loaded(candidates: list[int], clocks) -> int:
    """The candidate with the smallest simulated clock (deterministic:
    ties break on node index via min's stable comparison order)."""
    return min(candidates, key=lambda i: (float(clocks[i]), i))


def expected_goodput(alpha: float, t_cheap: float, t_expensive: float,
                     router_cost: float = 0.0) -> float:
    """Docs/node-second of the adaptive strategy (amortized)."""
    per_doc = (1 - alpha) * t_cheap + alpha * t_expensive + router_cost
    return 1.0 / per_doc


# ---------------------------------------------------------------------------
# General m-parser greedy knapsack (reference / benchmark path)
# ---------------------------------------------------------------------------


def assign_parsers_greedy(pred_acc: np.ndarray, costs: np.ndarray,
                          budget: float,
                          devices: list[str] | None = None,
                          device_budgets: dict[str, float] | None = None
                          ) -> np.ndarray:
    """pred_acc (n, m), costs (m,) per-doc node-seconds, budget in
    node-seconds. Start everyone on the cheapest parser, then greedily buy
    the best accuracy-per-cost upgrades until the budget is exhausted.
    Returns assignment (n,) parser indices.

    Pool-aware mode: ``devices`` names each parser's device pool (len m,
    e.g. "cpu"/"gpu" per backends.BackendInfo.device) and
    ``device_budgets`` caps the node-seconds each pool may absorb. An
    upgrade must then fit the target parser's pool budget as well as the
    total budget — a small GPU pool bounds how much Nougat/Marker work
    the campaign can buy regardless of the overall budget (§5 / App. C).
    """
    n, m = pred_acc.shape
    cheapest = int(np.argmin(costs))
    assign = np.full(n, cheapest, np.int64)
    spent = n * costs[cheapest]
    pooled = devices is not None and device_budgets is not None
    if pooled:
        if len(devices) != m:
            raise ValueError(f"need {m} parser devices, got {len(devices)}")
        pool_spent = {d: 0.0 for d in devices}
        pool_spent[devices[cheapest]] = spent
    # candidate upgrades: (gain/extra_cost, doc, parser)
    gains = pred_acc - pred_acc[:, cheapest:cheapest + 1]
    extra = np.maximum(costs - costs[cheapest], 1e-12)[None, :]
    ratio = gains / extra
    order = np.dstack(np.unravel_index(np.argsort(-ratio, axis=None),
                                       ratio.shape))[0]
    cur_gain = np.zeros(n)
    for doc, p in order:
        if p == cheapest:
            continue
        g = gains[doc, p]
        if g <= cur_gain[doc]:
            continue
        cur = assign[doc]
        delta_cost = (costs[p] - costs[cur])
        if spent + delta_cost > budget:
            continue
        if pooled:
            refund = costs[cur] if devices[cur] == devices[p] else 0.0
            cap = device_budgets.get(devices[p], np.inf)
            if pool_spent[devices[p]] - refund + costs[p] > cap:
                continue
            pool_spent[devices[p]] += costs[p] - refund
            if devices[cur] != devices[p]:
                pool_spent[devices[cur]] -= costs[cur]
        spent += delta_cost
        assign[doc] = p
        cur_gain[doc] = g
    return assign


@dataclasses.dataclass
class BatchPlan:
    """One batch's routing decision."""

    expensive_idx: np.ndarray        # docs routed to the expensive parser
    cheap_idx: np.ndarray
    alpha_effective: float


# Minimum selection threshold: only documents with (strictly) positive
# predicted improvement are ever routed. Shared by the host mirror and
# the device op so both paths make identical decisions.
POSITIVE_TAU = 1e-12


def plan_batch(improvement: np.ndarray, alpha: float,
               require_positive: bool = True) -> BatchPlan:
    """Host-side numpy mirror of the fused device selection
    (``kernels.budget_route``): identical capacity, threshold, and
    tie-break semantics, so host and device choose the same documents.

    Rule: capacity = ⌊α·k⌋; τ = capacity-th largest score, clamped to
    ``POSITIVE_TAU`` (never route a non-improving doc). Every row with
    score > τ is selected (there are at most capacity−1 of them by
    definition of τ), then ties *at* τ fill the remaining slots in row
    order — so a strictly better document is never displaced by a tie,
    and ties resolve first-come exactly like the kernel's compaction.
    """
    improvement = np.asarray(improvement)
    k = len(improvement)
    capacity = capacity_floor(alpha, k)
    if capacity == 0:
        return BatchPlan(np.zeros(0, np.int64), np.arange(k), 0.0)
    kth = np.partition(improvement, k - capacity)[k - capacity]
    tau = max(kth, POSITIVE_TAU) if require_positive else kth
    gt = np.nonzero(improvement > tau)[0]
    eq = np.nonzero(improvement == tau)[0][:capacity - len(gt)]
    top = np.sort(np.concatenate([gt, eq]))
    cheap = np.setdiff1d(np.arange(k), top, assume_unique=False)
    return BatchPlan(top.astype(np.int64), cheap, len(top) / max(k, 1))
