"""Shared WorkerSpec serialization + content fingerprinting.

Both worker runtimes describe a worker by the same ``WorkerSpec``
dataclass (core/workers); this module is the single place that turns a
spec into a *content fingerprint* — a small dict of stable hex digests
over the parts that must agree for two processes to produce
byte-identical records:

- ``router``: ``engine._router_fingerprint`` — a content hash over the
  router's thresholds, classifier weights, and encoder parameters (the
  same tag the result-store cache keys on).
- ``engine_config``: the serialized ``EngineConfig`` fields (α budget,
  batch size, backend names, routing mode, seed).
- ``backends``: the ``(module, attr)`` backend-registry factory pairs.

``launch.worker_main`` recomputes the fingerprint after deserializing
its spec and verifies it against the coordinator-stamped value
(guarding serialization drift between coordinator and worker builds),
and the fabric admission check (core/fabric) compares a dialing-in
worker's fingerprint against the coordinator's before admitting it to
the fleet. ``describe_mismatch`` names the first differing field so a
rejected worker gets an actionable error, not a bare hash inequality.

This module deliberately imports only ``core.engine`` (never
``core.workers``) so ``workers -> specs -> engine`` stays acyclic; the
``spec`` arguments are duck-typed.
"""
from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

from repro.core.engine import _router_fingerprint

# the fingerprint keys, in the order mismatches are reported
FINGERPRINT_FIELDS = ("router", "engine_config", "backends")


def portable_router(router):
    """A copy of the router safe to pickle across process (and machine)
    boundaries: jax arrays in ``enc_params`` become numpy (the
    receiving engine re-wraps them on first device use, and
    ``engine._router_fingerprint`` is content-addressed, so the remote
    side derives the identical cache tag)."""
    params = getattr(router, "enc_params", None)
    if params is None:
        return router
    import jax

    return dataclasses.replace(
        router, enc_params=jax.tree_util.tree_map(np.asarray, params))


def _digest(*parts: bytes) -> str:
    h = hashlib.sha256()
    for p in parts:
        h.update(b"%d:" % len(p))
        h.update(p)
    return h.hexdigest()[:16]


def engine_config_fingerprint(ecfg) -> str:
    """Stable digest of the EngineConfig fields that shape records:
    field order is the dataclass declaration order, so two builds of
    the same config hash identically regardless of construction."""
    parts = []
    for f in dataclasses.fields(ecfg):
        parts.append(f.name.encode())
        parts.append(repr(getattr(ecfg, f.name)).encode())
    return _digest(*parts)


def backend_specs_fingerprint(backend_specs) -> str:
    """Digest of the ``(module, attr)`` backend factory pairs (order-
    sensitive: registration order is part of the registry contract)."""
    parts = []
    for mod, attr in tuple(backend_specs or ()):
        parts.append(str(mod).encode())
        parts.append(str(attr).encode())
    return _digest(*parts)


def spec_fingerprint(spec) -> dict:
    """Content fingerprint of a WorkerSpec-shaped object (duck-typed:
    needs ``.router``, ``.ecfg``, ``.backend_specs``). Two workers with
    equal fingerprints produce byte-identical records for the same
    batch keys — the fabric admission bar."""
    return {
        "router": _router_fingerprint(spec.router),
        "engine_config": engine_config_fingerprint(spec.ecfg),
        "backends": backend_specs_fingerprint(spec.backend_specs),
    }


def describe_mismatch(expected: dict, got: dict) -> str | None:
    """None when the fingerprints agree; otherwise an actionable
    message naming the first differing field and both digests."""
    for field in FINGERPRINT_FIELDS:
        e, g = expected.get(field), got.get(field)
        if e != g:
            hint = {
                "router": "the worker was built from a different "
                          "router (retrain or ship the coordinator's "
                          "router file)",
                "engine_config": "EngineConfig differs (α / batch size "
                                 "/ backend names / seed must match "
                                 "the coordinator)",
                "backends": "backend registry spec differs (the "
                            "worker registers different (module, attr) "
                            "factories)",
            }[field]
            return (f"worker fingerprint mismatch on {field!r}: "
                    f"coordinator={e} worker={g} — {hint}")
    extra = set(got) - set(FINGERPRINT_FIELDS)
    if extra:
        return (f"worker fingerprint carries unknown fields "
                f"{sorted(extra)} (version skew between coordinator "
                f"and worker builds)")
    return None
