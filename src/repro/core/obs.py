"""Fleet-wide observability plane: spans, metrics, exporters.

Tracing is off by default and provably cheap when off: the module-level
recorder starts as a :class:`NoopRecorder` whose ``span`` is a
constant-time no-op, and instrumentation sites guard timestamp work
behind ``recorder().enabled``. Metrics counters stay always-on — they
are plain-int dict adds on control-plane paths only, never inside a
per-document loop.

Spans never cross the process boundary through new channels: each
worker records into a bounded ring (``collections.deque`` with
``maxlen`` — appends are GIL-atomic, so the task loop and the heartbeat
thread share it lock-free) and drains a bounded slice into the
``spans`` field piggybacked on outgoing ``BatchDone``/``Heartbeat``
messages. Overflow evicts oldest and is drop-counted; nothing ever
blocks the hot path.

Histograms use fixed log2 buckets so cross-process folding is exact:
bucket ``i`` counts observations in
``[2**(i+MIN_EXP), 2**(i+1+MIN_EXP))`` seconds, and merging snapshots
from any number of processes is element-wise addition with no
re-binning error.
"""
from __future__ import annotations

import json
import math
import os
import time
from collections import deque
from dataclasses import asdict, dataclass
from pathlib import Path

# ---------------------------------------------------------------- spans

#: canonical span names; anything else still records but gets no color.
#: "complete" is the coordinator-emitted *winning* completion (exactly
#: one per emitted batch — the span-conservation invariant); "reparse"
#: is the engine's expensive-stage timing, of which losing re-issue
#: attempts may emit extras.
SPAN_STAGES = ("prepare", "route", "complete", "reparse", "probe",
               "cache_lookup", "forward", "reissue", "dedup", "round",
               "scenario", "join", "leave", "admission_rejected")

#: chrome://tracing reserved color names per stage
_CNAME = {
    "prepare": "thread_state_running",
    "route": "thread_state_runnable",
    "complete": "cq_build_passed",
    "reparse": "thread_state_iowait",
    "probe": "light_memory_dump",
    "cache_lookup": "good",
    "forward": "generic_work",
    "reissue": "bad",
    "dedup": "terrible",
    "round": "vsync_highlight_color",
    "scenario": "black",
    # fabric membership lifecycle (core/fabric): one `join` per
    # admission, one `leave` per lost connection, one
    # `admission_rejected` per refused dialer — #join - #leave equals
    # the live fleet delta (the fabric conservation law)
    "join": "cq_build_attempt_passed",
    "leave": "cq_build_attempt_failed",
    "admission_rejected": "cq_build_failed",
}

#: chrome trace thread ids must be non-negative; the coordinator
#: (node -1) gets its own high lane
_COORD_TID = 999


@dataclass
class Span:
    """One timed (or instant, ``dur == 0``) event in a campaign."""

    name: str
    trace: str          # trace id — the batch key, or a synthetic id
    node: int           # global node id; -1 = coordinator
    pid: int            # OS pid of the recording process
    start: float        # epoch seconds (time.time), cross-process
    dur: float          # seconds; 0 renders as an instant event
    attempt: int = 0
    cached: bool = False
    abandoned: bool = False
    detail: str = ""

    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "Span":
        return cls(**d)


class NoopRecorder:
    """Default recorder: every call is a constant-time no-op."""

    enabled = False
    node = -1
    recorded = 0
    shipped = 0
    dropped = 0

    def span(self, name, trace, start, dur, node=None, attempt=0,
             cached=False, abandoned=False, detail=""):
        return None

    def drain(self, limit=None):
        return []


class RingRecorder:
    """Lock-free bounded span ring for one process.

    ``deque(maxlen=cap)`` appends are GIL-atomic, so the worker task
    loop, the heartbeat thread, and prefetch threads share one ring
    without a lock. When full, the oldest span is silently evicted and
    surfaces in :attr:`dropped` — recording never blocks.
    """

    enabled = True

    def __init__(self, cap: int = 8192, node: int = -1):
        self.cap = int(cap)
        self.node = int(node)
        self.pid = os.getpid()
        self._ring: deque = deque(maxlen=self.cap)
        self.recorded = 0
        self.shipped = 0

    def span(self, name, trace, start, dur, node=None, attempt=0,
             cached=False, abandoned=False, detail=""):
        self._ring.append(Span(
            name=name, trace=str(trace),
            node=self.node if node is None else int(node),
            pid=self.pid, start=float(start), dur=float(dur),
            attempt=int(attempt), cached=bool(cached),
            abandoned=bool(abandoned), detail=detail))
        self.recorded += 1

    def drain(self, limit=None):
        """Pop up to ``limit`` spans (all if None) oldest-first."""
        out = []
        n = len(self._ring)
        if limit is not None:
            n = min(int(limit), n)
        for _ in range(n):
            try:
                out.append(self._ring.popleft())
            except IndexError:    # raced another drainer; ring is empty
                break
        self.shipped += len(out)
        return out

    @property
    def dropped(self) -> int:
        return max(0, self.recorded - self.shipped - len(self._ring))


# -------------------------------------------------------------- metrics

N_BUCKETS = 34
MIN_EXP = -20          # bucket 0 starts at 2**-20 s ≈ 0.95 µs


def _bucket(v: float) -> int:
    if v <= 0.0:
        return 0
    _, e = math.frexp(v)             # v = m * 2**e with m in [0.5, 1)
    return min(N_BUCKETS - 1, max(0, e - 1 - MIN_EXP))


def bucket_bounds() -> list:
    """Upper bounds (seconds) of each bucket, for exporters."""
    return [2.0 ** (i + 1 + MIN_EXP) for i in range(N_BUCKETS)]


class Histogram:
    """Fixed-log2-bucket latency histogram; merges exactly."""

    __slots__ = ("counts", "sum", "total")

    def __init__(self):
        self.counts = [0] * N_BUCKETS
        self.sum = 0.0
        self.total = 0

    def observe(self, v: float):
        self.counts[_bucket(v)] += 1
        self.sum += v
        self.total += 1

    def quantile(self, q: float) -> float:
        """Approximate quantile: geometric midpoint of the bucket that
        crosses the target rank (exact to within one log2 bucket)."""
        if self.total == 0:
            return 0.0
        target = q * self.total
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= target:
                lo = 2.0 ** (i + MIN_EXP)
                return lo * math.sqrt(2.0)
        return 2.0 ** (N_BUCKETS + MIN_EXP)


class Registry:
    """Per-process metrics registry: counters, gauges, histograms."""

    def __init__(self):
        self.counters: dict = {}
        self.gauges: dict = {}
        self.hists: dict = {}

    def count(self, name: str, n: int = 1):
        self.counters[name] = self.counters.get(name, 0) + n

    def gauge(self, name: str, v: float):
        self.gauges[name] = float(v)

    def observe(self, name: str, v: float):
        h = self.hists.get(name)
        if h is None:
            h = self.hists[name] = Histogram()
        h.observe(v)

    def snapshot(self) -> dict:
        """Plain-dict copy, picklable, safe to ship over a queue."""
        return {
            "counters": dict(self.counters),
            "gauges": dict(self.gauges),
            "hists": {k: {"counts": list(h.counts), "sum": h.sum,
                          "total": h.total}
                      for k, h in self.hists.items()},
        }


def _empty_snapshot() -> dict:
    return {"counters": {}, "gauges": {}, "hists": {}}


def fold(snapshots) -> dict:
    """Merge per-process snapshots fleet-wide: counters and histogram
    buckets add exactly; gauges are last-write-wins (they are keyed
    per node, so distinct processes never collide)."""
    out = _empty_snapshot()
    for s in snapshots:
        if not s:
            continue
        for k, v in s.get("counters", {}).items():
            out["counters"][k] = out["counters"].get(k, 0) + v
        out["gauges"].update(s.get("gauges", {}))
        for k, h in s.get("hists", {}).items():
            t = out["hists"].setdefault(
                k, {"counts": [0] * N_BUCKETS, "sum": 0.0, "total": 0})
            t["counts"] = [a + b for a, b in zip(t["counts"], h["counts"])]
            t["sum"] += h["sum"]
            t["total"] += h["total"]
    return out


def diff(snap: dict, base: dict) -> dict:
    """Subtract a baseline snapshot taken at run start, so a registry
    reused across runs in one process reports this run only."""
    out = _empty_snapshot()
    bc = base.get("counters", {})
    for k, v in snap.get("counters", {}).items():
        d = v - bc.get(k, 0)
        if d:
            out["counters"][k] = d
    out["gauges"] = dict(snap.get("gauges", {}))
    bh = base.get("hists", {})
    for k, h in snap.get("hists", {}).items():
        b = bh.get(k, {"counts": [0] * N_BUCKETS, "sum": 0.0, "total": 0})
        total = h["total"] - b["total"]
        if total <= 0:
            continue
        out["hists"][k] = {
            "counts": [a - x for a, x in zip(h["counts"], b["counts"])],
            "sum": h["sum"] - b["sum"], "total": total}
    return out


# ------------------------------------------------------------ exporters

def _sanitize(name: str) -> str:
    return "".join(c if (c.isalnum() or c == "_") else "_" for c in name)


def prometheus_text(folded: dict) -> str:
    """Render a folded snapshot as Prometheus text exposition format."""
    lines = []
    for k in sorted(folded.get("counters", {})):
        n = f"adaparse_{_sanitize(k)}"
        lines.append(f"# TYPE {n} counter")
        lines.append(f"{n}_total {folded['counters'][k]}")
    for k in sorted(folded.get("gauges", {})):
        n = f"adaparse_{_sanitize(k)}"
        lines.append(f"# TYPE {n} gauge")
        lines.append(f"{n} {folded['gauges'][k]}")
    bounds = bucket_bounds()
    for k in sorted(folded.get("hists", {})):
        h = folded["hists"][k]
        n = f"adaparse_{_sanitize(k)}"
        lines.append(f"# TYPE {n} histogram")
        cum = 0
        for i, c in enumerate(h["counts"]):
            cum += c
            if c:
                lines.append(f'{n}_bucket{{le="{bounds[i]:.6g}"}} {cum}')
        lines.append(f'{n}_bucket{{le="+Inf"}} {h["total"]}')
        lines.append(f"{n}_sum {h['sum']:.9g}")
        lines.append(f"{n}_count {h['total']}")
    return "\n".join(lines) + "\n"


class TraceWriter:
    """Emit the two trace artifacts for a run directory:

    - ``spans.jsonl``: one JSON span per line (replayable, the source
      of truth for span-conservation checks), plus a trailing
      ``{"meta": ...}`` line with drop counts;
    - ``trace.json``: Chrome ``trace_event`` JSON — one lane per
      worker (tid = node id, coordinator on its own lane),
      stage-colored, loadable in chrome://tracing or Perfetto.
    """

    def __init__(self, trace_dir):
        self.dir = Path(trace_dir)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.spans_path = self.dir / "spans.jsonl"
        self.chrome_path = self.dir / "trace.json"

    def write(self, spans, dropped: int = 0) -> Path:
        spans = list(spans)
        with open(self.spans_path, "w") as f:
            for s in spans:
                f.write(json.dumps(s.to_dict()) + "\n")
            f.write(json.dumps({"meta": {"n_spans": len(spans),
                                         "dropped": dropped}}) + "\n")
        events = [{"name": "process_name", "ph": "M", "pid": 0, "tid": 0,
                   "args": {"name": "adaparse campaign"}}]
        for node in sorted({s.node for s in spans}):
            tid = _COORD_TID if node < 0 else node
            label = "coordinator" if node < 0 else f"worker {node}"
            events.append({"name": "thread_name", "ph": "M", "pid": 0,
                           "tid": tid, "args": {"name": label}})
        for s in spans:
            ev = {"name": s.name, "cat": s.name, "pid": 0,
                  "tid": _COORD_TID if s.node < 0 else s.node,
                  "ts": s.start * 1e6,
                  "args": {"trace": s.trace, "attempt": s.attempt,
                           "cached": s.cached, "abandoned": s.abandoned,
                           "detail": s.detail, "pid": s.pid}}
            if s.dur > 0:
                ev["ph"] = "X"
                ev["dur"] = s.dur * 1e6
            else:
                ev["ph"] = "i"
                ev["s"] = "t"
            cname = _CNAME.get(s.name)
            if cname:
                ev["cname"] = cname
            events.append(ev)
        with open(self.chrome_path, "w") as f:
            json.dump({"traceEvents": events, "displayTimeUnit": "ms"}, f)
        return self.chrome_path


def load_spans(trace_dir):
    """Replay ``spans.jsonl`` from a trace dir -> (spans, meta)."""
    path = Path(trace_dir) / "spans.jsonl"
    spans, meta = [], {}
    with open(path) as f:
        for line in f:
            d = json.loads(line)
            if "meta" in d:
                meta = d["meta"]
            else:
                spans.append(Span.from_dict(d))
    return spans, meta


def status_line(docs_per_s: float, alpha: float, cache_hits: int,
                cache_misses: int, in_flight: int, reissued: int,
                done: int, total: int) -> str:
    """The one-line live status `serve.py --status-interval` prints."""
    lookups = cache_hits + cache_misses
    hit = (100.0 * cache_hits / lookups) if lookups else 0.0
    return (f"[status] {done}/{total} batches  {docs_per_s:7.1f} docs/s"
            f"  alpha={alpha:.3f}  cache {hit:4.1f}%"
            f"  in-flight {in_flight}  reissued {reissued}")


# ------------------------------------------------------ process globals

_recorder = NoopRecorder()
_registry = Registry()


def recorder():
    return _recorder


def metrics() -> Registry:
    return _registry


def configure(enabled: bool = False, cap: int = 8192, node: int = -1):
    """(Re)install this process's recorder. Called once per worker
    process at startup and once per run by the coordinator; installing
    a fresh ring discards spans from any earlier run in this process."""
    global _recorder
    _recorder = RingRecorder(cap=cap, node=node) if enabled \
        else NoopRecorder()
    return _recorder


def now() -> float:
    return time.time()
