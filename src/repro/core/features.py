"""CLS-I fast features (§5.1): aggregate statistics of the extracted text.

These are "coarse but fast-to-compute" (length, whitespace fraction,
garbage fraction, LaTeX markers, ...) — interpretable and vectorized.
``batch_fast_features`` computes all documents' features from one flat
token stream (segment reductions via bincount), so the engine never
loops over documents in Python on its hot path.

``prepare_routing_inputs`` is the fused prepare-stage entry the engine
dispatches through: one call derives the fast features *and* (for the
LLM router variant) the first-page token/mask pair via
``kernels.fast_features`` — the Pallas kernel on device backends, the
exact packed-stream host oracle elsewhere. The legacy per-function
pipeline below stays as the bit-for-bit reference (``mode="host"``).
"""
from __future__ import annotations

import numpy as np

from repro.data.synthetic import MANGLED, SCRAMBLE, WS, CorpusConfig
from repro.kernels.fast_features import ops as ff_ops

N_FAST_FEATURES = 8
FEATURE_KERNEL_MODES = ("auto", "force", "host")


def batch_fast_features(page_lists, cfg: CorpusConfig) -> np.ndarray:
    """Parser outputs (list of per-doc page lists) -> (n, F) float32.

    Vectorized over the whole batch: per-doc statistics are segment sums
    (``np.bincount`` keyed by a flat doc-of-token index) over the
    concatenated token stream. Documents with no output tokens get an
    all-zero row (the CLS-I "empty extraction" signature).
    """
    n_docs = len(page_lists)
    out = np.zeros((n_docs, N_FAST_FEATURES), np.float32)
    if n_docs == 0:
        return out
    pages_per_doc = np.fromiter((len(p) for p in page_lists), np.int64,
                                count=n_docs)
    doc_of_page = np.repeat(np.arange(n_docs), pages_per_doc)
    flat_pages = [pg for p in page_lists for pg in p]
    n_pages = len(flat_pages)
    page_lens = np.fromiter((len(pg) for pg in flat_pages), np.int64,
                            count=n_pages)
    empty_pages = np.bincount(doc_of_page[page_lens == 0],
                              minlength=n_docs).astype(np.float64)

    t = (np.concatenate(flat_pages) if n_pages
         else np.zeros(0, np.int32)).astype(np.int64)
    tok_doc = np.repeat(doc_of_page, page_lens)
    n_tok = np.bincount(tok_doc, minlength=n_docs).astype(np.float64)
    denom = np.maximum(n_tok, 1.0)

    def frac(mask):
        return np.bincount(tok_doc[mask], minlength=n_docs) / denom

    frac_ws = frac(t == WS)
    frac_scr = frac(t == SCRAMBLE)
    frac_mangled = frac(t == MANGLED)
    frac_latex = frac((t >= cfg.latex_lo) & (t < cfg.ident_lo))
    # distinct tokens per doc: unique composite (doc, token) keys
    key = tok_doc * int(cfg.vocab_size) + t
    uniq = (np.bincount(np.unique(key) // int(cfg.vocab_size),
                        minlength=n_docs) / denom)

    out[:, 0] = np.log1p(n_tok) / 10.0
    out[:, 1] = frac_ws
    out[:, 2] = frac_scr
    out[:, 3] = frac_mangled
    out[:, 4] = frac_latex
    out[:, 5] = uniq
    out[:, 6] = empty_pages / np.maximum(pages_per_doc, 1)
    out[:, 7] = pages_per_doc / 10.0
    # docs with no output at all keep the all-zero signature row
    out[n_tok == 0] = 0.0
    return out


def fast_features(pages: list[np.ndarray], cfg: CorpusConfig) -> np.ndarray:
    """Single-doc convenience wrapper -> (N_FAST_FEATURES,) float32."""
    return batch_fast_features([pages], cfg)[0]


def first_page_tokens(pages: list[np.ndarray], max_len: int,
                      bos: int = 1) -> tuple[np.ndarray, np.ndarray]:
    """First-page text -> fixed-length (tokens, mask) for the CLS-III LLM."""
    page = pages[0] if pages and len(pages[0]) else np.zeros(0, np.int32)
    toks = np.zeros(max_len, np.int32)
    toks[0] = bos
    m = min(len(page), max_len - 1)
    toks[1:1 + m] = page[:m]
    mask = np.zeros(max_len, np.float32)
    mask[:1 + m] = 1.0
    return toks, mask


def prepare_routing_inputs(page_lists, cfg: CorpusConfig, *,
                           max_len: int | None = None,
                           mode: str = "auto"):
    """Every routing input in one fused pass -> (fast, toks, mask).

    ``fast`` is the (n, 8) CLS-I feature block; ``toks``/``mask`` are
    the (n, max_len) first-page encoder inputs, or None when
    ``max_len`` is None (the ft router variant needs features only).

    ``mode`` (``EngineConfig.feature_kernel``): "auto" dispatches the
    Pallas fast_features kernel on TPU and the packed host oracle
    elsewhere (bit-identical to the legacy pipeline, minus the
    composite-key sort); "force" runs the kernel even off-TPU
    (interpret — parity tests and benches); "host" is the legacy
    unfused ``batch_fast_features`` + ``batch_first_page_tokens``
    pipeline.
    """
    if mode not in FEATURE_KERNEL_MODES:
        raise ValueError(f"feature_kernel mode {mode!r} not in "
                         f"{FEATURE_KERNEL_MODES}")
    if mode == "host":
        fast = batch_fast_features(page_lists, cfg)
        if max_len is None:
            return fast, None, None
        toks, mask = batch_first_page_tokens(page_lists, max_len)
        return fast, toks, mask
    packed = ff_ops.pack_routing_batch(page_lists,
                                       max_len=int(max_len or 0))
    return ff_ops.routing_features(
        packed, ws=WS, scramble=SCRAMBLE, mangled=MANGLED,
        latex_lo=cfg.latex_lo, ident_lo=cfg.ident_lo,
        vocab_size=cfg.vocab_size, force_kernel=(mode == "force"))


def batch_first_page_tokens(page_lists, max_len: int, bos: int = 1
                            ) -> tuple[np.ndarray, np.ndarray]:
    """Batched ``first_page_tokens`` -> ((n, L) int32, (n, L) float32).

    One scatter into the padded token matrix instead of n per-doc
    assemblies: first pages are concatenated (truncated to L-1) and
    written through flat (row, col) indices.
    """
    n = len(page_lists)
    toks = np.zeros((n, max_len), np.int32)
    mask = np.zeros((n, max_len), np.float32)
    if n == 0:
        return toks, mask
    toks[:, 0] = bos
    firsts = [(p[0][:max_len - 1] if p and len(p[0]) else
               np.zeros(0, np.int32)) for p in page_lists]
    lens = np.fromiter((len(f) for f in firsts), np.int64, count=n)
    rows = np.repeat(np.arange(n), lens)
    cols = (np.arange(len(rows)) -
            np.repeat(np.cumsum(lens) - lens, lens) + 1)
    if len(rows):
        toks[rows, cols] = np.concatenate(firsts)
    mask[np.arange(max_len)[None, :] < (lens + 1)[:, None]] = 1.0
    return toks, mask
