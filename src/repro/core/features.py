"""CLS-I fast features (§5.1): aggregate statistics of the extracted text.

These are "coarse but fast-to-compute" (length, whitespace fraction,
garbage fraction, LaTeX markers, ...) — interpretable and vectorized.
"""
from __future__ import annotations

import numpy as np

from repro.data.synthetic import MANGLED, SCRAMBLE, WS, CorpusConfig

N_FAST_FEATURES = 8


def fast_features(pages: list[np.ndarray], cfg: CorpusConfig) -> np.ndarray:
    """Parser output pages -> (N_FAST_FEATURES,) float32 vector."""
    text = (np.concatenate(pages) if pages and sum(map(len, pages))
            else np.zeros(0, np.int32))
    n = len(text)
    if n == 0:
        return np.zeros(N_FAST_FEATURES, np.float32)
    frac_ws = float((text == WS).mean())
    frac_scr = float((text == SCRAMBLE).mean())
    frac_mangled = float((text == MANGLED).mean())
    frac_latex = float(((text >= cfg.latex_lo) & (text < cfg.ident_lo)).mean())
    uniq = len(np.unique(text)) / n
    empty_pages = sum(1 for p in pages if len(p) == 0) / max(len(pages), 1)
    return np.asarray([
        np.log1p(n) / 10.0, frac_ws, frac_scr, frac_mangled, frac_latex,
        uniq, empty_pages, len(pages) / 10.0,
    ], np.float32)


def batch_fast_features(page_lists, cfg: CorpusConfig) -> np.ndarray:
    return np.stack([fast_features(p, cfg) for p in page_lists])


def first_page_tokens(pages: list[np.ndarray], max_len: int,
                      bos: int = 1) -> tuple[np.ndarray, np.ndarray]:
    """First-page text -> fixed-length (tokens, mask) for the CLS-III LLM."""
    page = pages[0] if pages and len(pages[0]) else np.zeros(0, np.int32)
    toks = np.zeros(max_len, np.int32)
    toks[0] = bos
    m = min(len(page), max_len - 1)
    toks[1:1 + m] = page[:m]
    mask = np.zeros(max_len, np.float32)
    mask[:1 + m] = 1.0
    return toks, mask
