"""Named deterministic stress scenarios over both worker runtimes.

The scenario lab (ROADMAP): every scheduler/runtime change is regression-
tested against a whole matrix of adversarial campaign shapes instead of
one happy path. A scenario is a declarative ``ScenarioSpec`` — corpus
shape, fleet topology/pools, fault schedule (``workers.FaultInjection``),
straggler/speed-skew knobs, cache warmth, adaptive/retune settings — and
``run_scenario`` executes it, asserts the determinism invariant (the
fleet's record set is byte-identical to the single-node in-process
reference), and returns per-scenario goodput/re-issue/dedup counters.

The reference is chosen by the spec: a fixed-α campaign must reproduce a
plain ``AdaParseEngine.run`` (the PR-2..5 contract — batch rng streams
are keyed by global batch index, so placement, pools, prefetch, caches,
re-issues and weight evolution never change a record); an α-retuned
campaign must reproduce the same ``CampaignController`` at ``n_nodes=1``
(the α trajectory is a pure function of the batch-keyed probe signal,
absorbed in batch-key order, hence node-count independent).

Eight shipped scenarios (``SCENARIOS``):

- ``crash_storm``          two of four real worker processes hard-crash
                           mid-campaign (heartbeat liveness + re-issue)
- ``wedged_straggler_flap`` a worker mutes, its work re-issues, it
                           heartbeats back while still owing late
                           results (the recovery-window bound)
- ``bursty_arrivals``      highly uneven per-node queues via a replayed
                           throughput trace (weighted sharding)
- ``bimodal_retune``       easy/hard-scan bimodal corpus under online α
                           retuning (quality probe + bounds)
- ``cold_warm_shared_store`` 4-process fleet shares one disk store cold,
                           then a fresh fleet replays it warm
- ``slowdown_skew``        pathological per-node speed skew + injected
                           stragglers on the local simulated runtime
- ``elastic_join_leave``   cross-machine fabric runtime over loopback
                           TCP: one worker joins mid-campaign, one
                           hard-crashes (its connection drops and its
                           work re-issues), one dialer is rejected at
                           admission for a fingerprint mismatch — the
                           record set must still match single-node
                           byte-for-byte
- ``shm_crash_reissue``    4-worker fleet over the zero-copy shared-
                           memory transport: a crash mid-campaign plus
                           a muted straggler force re-issues and late
                           duplicate replies through generation-tagged
                           arena slots

``benchmarks/bench_scenarios.py`` sweeps the registry into
``BENCH_scenarios.json``; ``serve.py --scenario NAME`` reproduces any
one from the CLI; ``tests/test_scenarios.py`` runs the fast subset in
tier-1.
"""
from __future__ import annotations

import dataclasses
import os
import tempfile
import time

import numpy as np

from repro.core import backends as B
from repro.core import obs
from repro.core.campaign import (CampaignController, CampaignExecutor,
                                 ControllerConfig, ExecutorConfig,
                                 FaultInjection)
from repro.core.engine import AdaParseEngine, EngineConfig
from repro.core.fabric import FabricElastic
from repro.core.quality import QualityProbeConfig
from repro.data.synthetic import CorpusConfig, generate_corpus


class ScenarioMismatch(AssertionError):
    """The fleet's record set diverged from the single-node reference —
    the determinism invariant every scenario asserts."""


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """One named stress scenario, fully declarative: everything the
    runner needs to build the fleet, schedule its faults, and pick the
    correct single-node reference."""

    name: str
    description: str
    # -- corpus (first half trains the router, second half is parsed) --
    n_docs: int = 150
    corpus_seed: int = 0
    # easiest+hardest thirds of the test split (difficulty-sorted):
    # the easy/hard-scan bimodal quality spread the α retuner reacts to
    bimodal: bool = False
    # -- engine --
    alpha: float = 0.1
    batch_size: int = 16
    # -- fleet topology --
    runtime: str = "local"            # "local" | "process" | "fabric"
    n_nodes: int = 2
    node_pools: tuple[str, ...] | None = None
    prefetch_depth: int = 0
    # local-runtime simulation knobs
    node_speed_factors: tuple[float, ...] | None = None
    straggler_rate: float = 0.0
    straggler_slowdown: float = 4.0
    deadline_factor: float = 2.5
    # -- process-runtime liveness + fault schedule --
    fault: FaultInjection | None = None
    heartbeat_timeout_s: float = 30.0
    heartbeat_interval_s: float = 0.5
    straggler_grace_s: float = 2.0
    # batch-payload transport for the process runtime ("shm" | "pickle");
    # ignored by the local simulated runtime
    transport: str = "shm"
    # fabric-runtime elastic membership schedule (core/fabric
    # .FabricElastic: deferred joiners + rejected mismatched dialers)
    fabric: object | None = None
    # -- adaptive controller (rounds == 0: one-shot executor) --
    rounds: int = 0
    # per-round per-ingest-node docs/s traces (bare PR-3 lists): pins
    # the weight trajectory -> deterministic uneven per-node queues
    arrival_skew: tuple[tuple[float, ...], ...] | None = None
    # online α retuning (None = fixed campaign α)
    alpha_bounds: tuple[float, float] | None = None
    alpha_step: float = 0.05
    quality_target: float = 0.45
    quality_ewma: float = 0.5
    probe_rate: float = 0.0
    # -- shared disk store --
    disk_cache: bool = False
    cache_max_bytes: int | None = None
    # second fresh-store fleet run over the same dir; must replay the
    # cold run entirely (zero misses)
    warm_replay: bool = False


@dataclasses.dataclass
class ScenarioResult:
    """Per-scenario counters recorded into BENCH_scenarios.json. A
    result is only ever constructed after the determinism invariant
    held (``run_scenario`` raises ``ScenarioMismatch`` otherwise)."""

    name: str
    runtime: str
    n_nodes: int
    n_docs: int
    records_match: bool               # asserted True; recorded for the
    wall_s: float                     # bench artifact's per-scenario keys
    goodput_docs_per_s: float
    reissued: int
    reissued_reparse: int
    duplicates_dropped: int
    cache_hits: int
    cache_misses: int
    rounds: int = 0
    alpha_trajectory: list[float] = dataclasses.field(default_factory=list)
    warm_cache_hits: int = 0
    warm_cache_misses: int = 0

    def metrics(self) -> dict:
        return dataclasses.asdict(self)


# ---------------------------------------------------------------------------
# Shared scenario context (corpus + trained router), cached per shape
# ---------------------------------------------------------------------------

_CTX_CACHE: dict = {}


def scenario_context(spec: ScenarioSpec):
    """(corpus_cfg, test_docs, router) for ``spec`` — the corpus and the
    FT router are cached per (n_docs, seed, bimodal) so a sweep over the
    registry pays corpus generation and router training once."""
    key = (spec.n_docs, spec.corpus_seed, spec.bimodal)
    hit = _CTX_CACHE.get(key)
    if hit is not None:
        return hit
    base = _CTX_CACHE.get((spec.n_docs, spec.corpus_seed, False))
    if base is None:
        from repro.launch.serve import build_ft_router  # lazy: no cycle
        ccfg = CorpusConfig(n_docs=spec.n_docs, seed=spec.corpus_seed)
        docs = generate_corpus(ccfg)
        train, test = docs[:spec.n_docs // 2], docs[spec.n_docs // 2:]
        router = build_ft_router(train, ccfg, np.random.RandomState(1))
        base = (ccfg, test, router)
        _CTX_CACHE[(spec.n_docs, spec.corpus_seed, False)] = base
    if not spec.bimodal:
        return base
    ccfg, test, router = base
    pool = sorted(test, key=lambda d: d.difficulty)
    seg = max(len(pool) // 3, 1)
    ctx = (ccfg, pool[:seg] + pool[-seg:], router)
    _CTX_CACHE[key] = ctx
    return ctx


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------


def _controller_cfg(spec: ScenarioSpec, *, trace) -> ControllerConfig:
    return ControllerConfig(
        rounds=spec.rounds, telemetry_trace=trace,
        alpha_bounds=spec.alpha_bounds, alpha_step=spec.alpha_step,
        quality_target=spec.quality_target,
        quality_ewma=spec.quality_ewma,
        probe=(QualityProbeConfig(probe_rate=spec.probe_rate, max_len=192)
               if spec.probe_rate > 0 else None))


def _reference_records(spec: ScenarioSpec, ccfg, test, router,
                       ecfg: EngineConfig) -> dict:
    """The single-node in-process record set the fleet must reproduce
    byte-for-byte. Fixed-α scenarios reference a plain engine run;
    α-retuned scenarios reference the same controller at n_nodes=1
    (the α trajectory is node-count independent, a plain run is not a
    valid reference once α moves between rounds)."""
    if spec.alpha_bounds is None:
        return AdaParseEngine(ecfg, router, ccfg).run(test)
    ref = CampaignController(
        ecfg, ExecutorConfig(n_nodes=1, straggler_rate=0.0),
        _controller_cfg(spec, trace=None), router, ccfg).run(test)
    return ref.records


def _assert_records_match(name: str, reference: dict, got: dict) -> None:
    if set(reference) != set(got):
        raise ScenarioMismatch(
            f"scenario {name}: fleet produced doc ids "
            f"{sorted(set(got) ^ set(reference))[:8]}... differing from "
            f"the single-node reference")
    for i, ref in reference.items():
        rec = got[i]
        same = (rec.parser == ref.parser and rec.cost_s == ref.cost_s
                and len(rec.pages) == len(ref.pages)
                and all(np.array_equal(a, b)
                        for a, b in zip(rec.pages, ref.pages)))
        if not same:
            raise ScenarioMismatch(
                f"scenario {name}: record for doc {i} diverged from the "
                f"single-node reference (parser {rec.parser!r} vs "
                f"{ref.parser!r})")


def _write_scenario_trace(spec: ScenarioSpec, res, t_run0: float,
                          trace_dir: str) -> None:
    """Emit the fleet run's observability artifacts: the span log +
    Chrome trace (with one enclosing ``scenario`` annotation span on
    the coordinator lane) and the folded fleet-wide metrics as
    Prometheus text."""
    spans = list(getattr(res, "spans", None) or [])
    spans.append(obs.Span(
        "scenario", spec.name, -1, os.getpid(), t_run0,
        time.time() - t_run0,
        detail=f"{spec.runtime} runtime x{spec.n_nodes}: "
               f"{spec.description}"))
    spans.sort(key=lambda s: s.start)
    obs.TraceWriter(trace_dir).write(spans)
    folded = getattr(res, "obs_metrics", None) or obs.fold([])
    with open(os.path.join(trace_dir, "metrics.prom"), "w") as f:
        f.write(obs.prometheus_text(folded))


def run_scenario(spec: ScenarioSpec,
                 cache_dir: str | None = None,
                 trace_dir: str | None = None) -> ScenarioResult:
    """Execute ``spec``, assert the byte-identical-records invariant
    against its single-node reference, and return the scenario's
    counters. ``cache_dir`` overrides where a disk-cache scenario puts
    its shared store (default: a fresh temp dir). ``trace_dir`` turns
    the observability plane on for the fleet run and writes the span
    log, Chrome trace, and folded Prometheus metrics there — the whole
    scenario is wrapped in one ``scenario`` annotation span so retune
    timelines (e.g. ``bimodal_retune``) show the α-moving ``round``
    spans inline."""
    ccfg, test, router = scenario_context(spec)
    ecfg = EngineConfig(alpha=spec.alpha, batch_size=spec.batch_size)
    reference = _reference_records(spec, ccfg, test, router, ecfg)
    xcfg = ExecutorConfig(
        obs=trace_dir is not None,
        n_nodes=spec.n_nodes, runtime=spec.runtime,
        node_pools=(list(spec.node_pools)
                    if spec.node_pools is not None else None),
        prefetch_depth=spec.prefetch_depth,
        node_speed_factors=(list(spec.node_speed_factors)
                            if spec.node_speed_factors is not None
                            else None),
        straggler_rate=spec.straggler_rate,
        straggler_slowdown=spec.straggler_slowdown,
        deadline_factor=spec.deadline_factor,
        fault_injection=spec.fault,
        heartbeat_timeout_s=spec.heartbeat_timeout_s,
        heartbeat_interval_s=spec.heartbeat_interval_s,
        straggler_grace_s=spec.straggler_grace_s,
        transport=spec.transport,
        fabric=spec.fabric)

    tmp = None
    store = None
    if spec.disk_cache:
        if cache_dir is None:
            tmp = tempfile.TemporaryDirectory(prefix="adaparse-scn-")
            cache_dir = tmp.name
        store = B.DiskResultStore(cache_dir,
                                  max_bytes=spec.cache_max_bytes)
    try:
        t_run0 = time.time()
        if spec.rounds > 0:
            trace = ([list(t) for t in spec.arrival_skew]
                     if spec.arrival_skew is not None else None)
            res = CampaignController(
                ecfg, xcfg, _controller_cfg(spec, trace=trace), router,
                ccfg).run(test, cache=store)
        else:
            res = CampaignExecutor(ecfg, xcfg, router, ccfg).run(
                test, cache=store)
        _assert_records_match(spec.name, reference, res.records)
        if trace_dir is not None:
            _write_scenario_trace(spec, res, t_run0, trace_dir)

        warm_hits = warm_misses = 0
        if spec.warm_replay:
            # a FRESH store handle over the same dir: the warm fleet
            # must replay everything the cold fleet's workers stored
            # (the multi-process-safe WAL contract)
            warm_store = B.DiskResultStore(cache_dir,
                                           max_bytes=spec.cache_max_bytes)
            warm = CampaignExecutor(
                ecfg, ExecutorConfig(n_nodes=2, straggler_rate=0.0),
                router, ccfg).run(test, cache=warm_store)
            _assert_records_match(spec.name + ":warm", reference,
                                  warm.records)
            warm_hits, warm_misses = warm.cache_hits, warm.cache_misses
            if warm_misses:
                raise ScenarioMismatch(
                    f"scenario {spec.name}: warm replay re-parsed "
                    f"{warm_misses} batches the cold fleet already "
                    f"stored")
        return ScenarioResult(
            name=spec.name, runtime=spec.runtime, n_nodes=spec.n_nodes,
            n_docs=len(test), records_match=True, wall_s=res.wall_s,
            goodput_docs_per_s=res.docs_per_s, reissued=res.reissued,
            reissued_reparse=res.reissued_reparse,
            duplicates_dropped=res.duplicates_dropped,
            cache_hits=res.cache_hits, cache_misses=res.cache_misses,
            rounds=getattr(res, "rounds", 0),
            alpha_trajectory=[t.alpha for t in
                              getattr(res, "telemetry", [])],
            warm_cache_hits=warm_hits, warm_cache_misses=warm_misses)
    finally:
        if tmp is not None:
            tmp.cleanup()


# ---------------------------------------------------------------------------
# The shipped scenario registry
# ---------------------------------------------------------------------------

_SPECS = (
    ScenarioSpec(
        name="crash_storm",
        description="two of four worker processes hard-crash "
                    "mid-campaign; heartbeat liveness re-issues their "
                    "work to the survivors",
        runtime="process", n_nodes=4, batch_size=8, prefetch_depth=1,
        heartbeat_timeout_s=5.0, heartbeat_interval_s=0.1,
        fault=FaultInjection(crash_after=((1, 1), (2, 0)))),
    ScenarioSpec(
        name="wedged_straggler_flap",
        description="a worker stops heartbeating but keeps working, "
                    "its batches re-issue, then it heartbeats back "
                    "while still owing late results (mute + recover "
                    "+ race)",
        runtime="process", n_nodes=2, prefetch_depth=2,
        heartbeat_timeout_s=0.5, heartbeat_interval_s=0.1,
        straggler_grace_s=2.5,
        fault=FaultInjection(mute_after=((1, 0),),
                             unmute_after=((1, 2),),
                             mute_slowdown_s=0.9)),
    ScenarioSpec(
        name="bursty_arrivals",
        description="highly uneven per-node queues: a replayed "
                    "throughput trace drives the weighted sharding to "
                    "pile work onto alternating nodes",
        runtime="local", n_nodes=4, batch_size=8, rounds=2,
        arrival_skew=((8.0, 1.0, 1.0, 0.25), (0.25, 1.0, 1.0, 8.0))),
    ScenarioSpec(
        name="bimodal_retune",
        description="easy/hard-scan bimodal corpus under online alpha "
                    "retuning (full-rate quality probe, operator "
                    "bounds)",
        runtime="local", n_nodes=2, batch_size=8, bimodal=True,
        rounds=3, alpha_bounds=(0.05, 0.9), alpha_step=0.3,
        quality_target=0.5, quality_ewma=1.0, probe_rate=1.0),
    ScenarioSpec(
        name="cold_warm_shared_store",
        description="a 4-process fleet (3 cpu + 1 gpu pool) shares one "
                    "disk store cold, then a fresh fleet over the same "
                    "dir replays it warm with zero misses",
        runtime="process", n_nodes=4,
        node_pools=("cpu", "cpu", "cpu", "gpu"), prefetch_depth=2,
        disk_cache=True, warm_replay=True),
    ScenarioSpec(
        name="shm_crash_reissue",
        description="4-worker fleet over the zero-copy shared-memory "
                    "transport: one worker hard-crashes mid-campaign "
                    "and another mutes then flaps back, so re-issued "
                    "tasks and late duplicate replies all travel "
                    "through generation-tagged arena slots; the record "
                    "set must still match single-node byte-for-byte",
        runtime="process", n_nodes=4, batch_size=8, prefetch_depth=2,
        transport="shm",
        heartbeat_timeout_s=2.0, heartbeat_interval_s=0.1,
        straggler_grace_s=2.5,
        fault=FaultInjection(crash_after=((2, 1),),
                             mute_after=((1, 0),),
                             unmute_after=((1, 2),),
                             mute_slowdown_s=0.9)),
    ScenarioSpec(
        name="elastic_join_leave",
        description="elastic fabric fleet over loopback TCP: slot 2 "
                    "joins after 4 batches, worker 1 hard-crashes "
                    "after 3 (its dropped connection re-issues its "
                    "in-flight + queued batches), and one extra "
                    "dialer is rejected at admission for a spec-"
                    "fingerprint mismatch; the adaptive controller "
                    "re-shards over the live fleet at every round "
                    "boundary and the record set must match single-"
                    "node byte-for-byte",
        runtime="fabric", n_nodes=3, batch_size=8, prefetch_depth=1,
        rounds=3,
        heartbeat_timeout_s=5.0, heartbeat_interval_s=0.1,
        fault=FaultInjection(crash_after=((1, 3),)),
        fabric=FabricElastic(join_after=((2, 4),), reject=1)),
    ScenarioSpec(
        name="slowdown_skew",
        description="pathological per-node speed skew (one node 6x "
                    "slower) plus injected stragglers on the local "
                    "simulated runtime",
        runtime="local", n_nodes=4, batch_size=8,
        node_speed_factors=(1.0, 1.0, 1.0, 6.0),
        straggler_rate=0.5, straggler_slowdown=8.0),
)

SCENARIOS: dict[str, ScenarioSpec] = {s.name: s for s in _SPECS}

#: Scenarios cheap enough for tier-1 (no process spawns): the local
#: simulated fleet end-to-end. The process scenarios run in the bench
#: sweep and the CI fast lane.
FAST_SCENARIOS = ("bursty_arrivals", "bimodal_retune", "slowdown_skew")


def get_scenario(name: str) -> ScenarioSpec:
    try:
        return SCENARIOS[name]
    except KeyError:
        known = ", ".join(sorted(SCENARIOS))
        raise KeyError(f"unknown scenario {name!r}; known: {known}") \
            from None
