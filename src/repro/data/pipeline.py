"""Input pipeline: sharded, prefetched, deterministically resumable.

Design (maps the paper's node-local ZIP-aggregation I/O strategy onto the
TPU input path):
- batches are produced *statelessly* from (seed, step, shard) so restart
  resumes exactly where the checkpoint left off — no loader state to save
  beyond the step counter;
- a double-buffered background thread overlaps host batch synthesis /
  decode with device compute (the host-side analogue of compute/comm
  overlap);
- documents are length-bucketed and packed so jitted steps see a single
  static shape per bucket.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator

import numpy as np


def stateless_rng(seed: int, step: int, shard: int = 0) -> np.random.RandomState:
    # splitmix-style mixing of (seed, step, shard) into a 32-bit stream key
    x = (seed * 0x9E3779B1 + step * 0x85EBCA77 + shard * 0xC2B2AE3D) \
        & 0xFFFFFFFF
    x ^= x >> 16
    x = (x * 0x7FEB352D) & 0xFFFFFFFF
    x ^= x >> 15
    return np.random.RandomState(x or 1)


class BatchSource:
    """Stateless batch factory: fn(step, rng) -> pytree of np arrays."""

    def __init__(self, fn: Callable[[int, np.random.RandomState], dict],
                 seed: int = 0, shard: int = 0, start_step: int = 0):
        self.fn = fn
        self.seed = seed
        self.shard = shard
        self.step = start_step

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        b = self.fn(self.step, stateless_rng(self.seed, self.step,
                                             self.shard))
        self.step += 1
        return b


def batches_for_indices(docs, batch_size: int, indices) -> list[dict]:
    """Materialized work-queue items for the given *global* batch
    indices: each item carries its global ``batch_key`` so any node (or
    any round of an adaptive campaign) reproduces the batch's stateless
    rng stream no matter where or when it runs."""
    return [{"batch_key": int(g),
             "docs": docs[g * batch_size:(g + 1) * batch_size]}
            for g in indices]


class Prefetcher:
    """Double-buffered background prefetch (depth-``depth`` queue).

    Exceptions raised in the worker thread (source or transform) are
    re-raised on the consumer's next ``__next__`` — never swallowed,
    never a hang. After exhaustion (or ``close``) every further
    ``__next__`` raises StopIteration. ``close`` is idempotent and safe
    to call concurrently with a blocked worker."""

    def __init__(self, source, depth: int = 2, transform=None):
        self.source = iter(source)
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.transform = transform or (lambda x: x)
        self._done = object()
        self._error: BaseException | None = None
        self._finished = False
        self._closed = False
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._worker, daemon=True)
        self.thread.start()

    def _worker(self):
        try:
            for item in self.source:
                if self._stop.is_set():
                    return
                self.q.put(self.transform(item))
        except StopIteration:
            pass
        except BaseException as e:       # propagate to the consumer
            self._error = e
        finally:
            self.q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        if self._finished:
            raise StopIteration
        item = self.q.get()
        if item is self._done:
            self._finished = True
            if self._error is not None:
                raise self._error
            raise StopIteration
        return item

    def _drain(self):
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass

    def close(self):
        if self._closed:
            return
        self._closed = True
        self._finished = True
        self._stop.set()
        # unblock a worker stuck on a full queue, then let it exit; the
        # worker may refill the queue once more before seeing the stop
        # flag, so drain until it is gone (bounded — daemon thread)
        for _ in range(200):
            if not self.thread.is_alive():
                break
            self._drain()
            self.thread.join(timeout=0.01)
        self._drain()


# ---------------------------------------------------------------------------
# Length bucketing / packing (document streams)
# ---------------------------------------------------------------------------


def bucket_by_length(lengths: np.ndarray,
                     boundaries: list[int]) -> np.ndarray:
    """Assign each doc to the smallest bucket whose boundary fits it."""
    return np.digitize(lengths, boundaries)


def pack_documents(docs: list[np.ndarray], seq_len: int,
                   pad_id: int = 0, eos_id: int = 1) -> np.ndarray:
    """Greedy first-fit packing of token sequences into (n, seq_len) rows
    separated by EOS — pad-free training the way trillion-token pipelines
    do it (the paper's motivating workload)."""
    rows: list[list[int]] = []
    space: list[int] = []
    for d in docs:
        d = list(np.asarray(d).ravel()[:seq_len - 1]) + [eos_id]
        placed = False
        for i in range(len(rows)):
            if space[i] >= len(d):
                rows[i].extend(d)
                space[i] -= len(d)
                placed = True
                break
        if not placed:
            rows.append(list(d))
            space.append(seq_len - len(d))
    out = np.full((len(rows), seq_len), pad_id, np.int32)
    for i, r in enumerate(rows):
        out[i, :len(r)] = r
    return out


def lm_stream(vocab: int, batch: int, seq_len: int, seed: int = 0,
              shard: int = 0, start_step: int = 0) -> BatchSource:
    """Synthetic LM token stream (Zipf-ish)."""

    def fn(step, rng):
        toks = (rng.zipf(1.3, size=(batch, seq_len + 1)) + 9)
        toks = np.minimum(toks, vocab - 1).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    return BatchSource(fn, seed, shard, start_step)
