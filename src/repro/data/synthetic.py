"""Synthetic scientific-document corpus + parser corruption channels.

Real PDFs are unavailable offline, so the benchmark substrate generates
documents with exact ground truth and models each parser as a corruption
channel over it, with per-parser severity profiles calibrated against the
paper's Tables 1-3 and Figure 3 (see DESIGN.md §2 assumption log). Every
failure mode of Fig. 1 is a parameterized channel:

  (a) whitespace injection   (b) word substitution
  (c) character scrambling   (d) character substitution (near-word)
  (e) identifier corruption  (f) LaTeX->plaintext mangling
  (g) page drop

Documents carry latent difficulty + metadata (producer/year/publisher/
category/pages); the *crossing structure* of Fig. 3 — extraction parsers
beat ViT parsers on easy documents and collapse on hard ones (scrambled
text layers), while Nougat stays flat but drops pages — is what makes
adaptive routing win, and what the router learns to detect from the
extracted text.

Token space: 0=PAD 1=BOS 2=WS 3=SCRAMBLE 4=MANGLED 5..9 reserved;
words in [10, 10+n_words); LaTeX tokens in [latex_lo, latex_hi);
identifiers (SMILES-like) in [ident_lo, ident_hi).
"""
from __future__ import annotations

import dataclasses

import numpy as np

PAD, BOS, WS, SCRAMBLE, MANGLED = 0, 1, 2, 3, 4
WORD_LO = 10


@dataclasses.dataclass
class CorpusConfig:
    n_docs: int = 1000
    n_words: int = 8000
    n_latex: int = 500
    n_ident: int = 200
    min_pages: int = 1
    max_pages: int = 8
    page_tokens: int = 256
    vocab_size: int = 10000          # router-encoder vocab (>= all ids)
    seed: int = 0

    @property
    def latex_lo(self):
        return WORD_LO + self.n_words

    @property
    def ident_lo(self):
        return self.latex_lo + self.n_latex


PRODUCERS = ("pdflatex", "msword", "scanner-v1", "scanner-v2", "indesign",
             "unknown")
PUBLISHERS = ("ArXiv", "BioRxiv", "BMC", "MDPI", "MedRxiv", "Nature")
CATEGORIES = ("math", "bio", "chem", "phys", "eng", "med", "econ", "cs")


@dataclasses.dataclass
class Document:
    doc_id: int
    pages: list[np.ndarray]          # ground-truth token ids per page
    difficulty: float                # latent parse difficulty in [0, 1]
    latex_density: float
    producer: str
    publisher: str
    category: str
    year: int
    scanned: bool

    @property
    def n_pages(self):
        return len(self.pages)

    def full_text(self) -> np.ndarray:
        return np.concatenate(self.pages) if self.pages else np.zeros(0, np.int32)

    def metadata_features(self) -> np.ndarray:
        """CLS-II feature vector: producer one-hot, year (scaled), pages,
        publisher one-hot, scanned flag."""
        prod = np.eye(len(PRODUCERS))[PRODUCERS.index(self.producer)]
        pub = np.eye(len(PUBLISHERS))[PUBLISHERS.index(self.publisher)]
        return np.concatenate([
            prod, pub,
            [(self.year - 2000) / 25.0, self.n_pages / 10.0,
             float(self.scanned)],
        ]).astype(np.float32)


def generate_corpus(cfg: CorpusConfig) -> list[Document]:
    rng = np.random.RandomState(cfg.seed)
    # Zipfian word distribution (natural-language-like)
    ranks = np.arange(1, cfg.n_words + 1)
    probs = 1.0 / ranks ** 1.1
    probs /= probs.sum()
    docs = []
    for i in range(cfg.n_docs):
        category = CATEGORIES[rng.randint(len(CATEGORIES))]
        publisher = PUBLISHERS[rng.randint(len(PUBLISHERS))]
        latex_density = float(np.clip(
            rng.beta(1.2, 6.0) + (0.15 if category in ("math", "phys", "cs")
                                  else 0.0), 0, 0.5))
        scanned = rng.rand() < 0.15
        year = int(1990 + 35 * rng.beta(3, 1.2))
        producer = (rng.choice(["scanner-v1", "scanner-v2"]) if scanned else
                    rng.choice(["pdflatex", "msword", "indesign", "unknown"],
                               p=[0.5, 0.25, 0.15, 0.1]))
        # difficulty: scans and old msword docs are harder; latex adds some
        base = rng.beta(2.0, 5.0)
        difficulty = float(np.clip(
            base + 0.45 * scanned + 0.15 * (producer == "msword")
            + 0.2 * latex_density + 0.1 * (year < 2005), 0, 1))
        n_pages = rng.randint(cfg.min_pages, cfg.max_pages + 1)
        pages = []
        for _ in range(n_pages):
            n_tok = int(cfg.page_tokens * rng.uniform(0.7, 1.3))
            words = rng.choice(cfg.n_words, size=n_tok, p=probs) + WORD_LO
            # sprinkle LaTeX spans + identifiers
            n_spans = rng.poisson(latex_density * 8)
            for _ in range(n_spans):
                s = rng.randint(0, max(n_tok - 6, 1))
                ln = rng.randint(2, 6)
                words[s:s + ln] = cfg.latex_lo + rng.randint(
                    0, cfg.n_latex, size=len(words[s:s + ln]))
            if category in ("chem", "bio", "med") and rng.rand() < 0.3:
                s = rng.randint(0, max(n_tok - 3, 1))
                words[s:s + 2] = cfg.ident_lo + rng.randint(0, cfg.n_ident, 2)
            pages.append(words.astype(np.int32))
        docs.append(Document(i, pages, difficulty, latex_density, producer,
                             publisher, category, year, scanned))
    return docs


# ---------------------------------------------------------------------------
# Corruption channels
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ChannelProfile:
    """Per-parser corruption severities. Rates are at difficulty=1; the
    effective rate is rate * f(difficulty) with channel-specific shaping."""

    p_ws: float = 0.0                # whitespace injection
    p_sub: float = 0.0               # word substitution
    p_scramble: float = 0.0          # char scrambling -> garbage token
    p_char: float = 0.0              # near-word substitution
    p_ident: float = 0.0             # identifier corruption
    p_latex: float = 0.0             # LaTeX span mangling
    p_page_drop: float = 0.0         # whole-page drop
    p_fail: float = 0.0              # document-level failure (empty output)
    text_layer: bool = True          # reads embedded text layer?
    difficulty_power: float = 1.0    # error ~ difficulty ** power
    flat_floor: float = 0.0          # difficulty-independent error floor


def batch_metadata_features(docs: list[Document]) -> np.ndarray:
    """Vectorized ``Document.metadata_features`` over a batch -> (n, F)."""
    n = len(docs)
    n_prod, n_pub = len(PRODUCERS), len(PUBLISHERS)
    out = np.zeros((n, n_prod + n_pub + 3), np.float32)
    if n == 0:
        return out
    rows = np.arange(n)
    out[rows, [PRODUCERS.index(d.producer) for d in docs]] = 1.0
    out[rows, n_prod + np.array([PUBLISHERS.index(d.publisher)
                                 for d in docs])] = 1.0
    out[:, -3] = np.array([(d.year - 2000) / 25.0 for d in docs])
    out[:, -2] = np.array([d.n_pages / 10.0 for d in docs])
    out[:, -1] = np.array([float(d.scanned) for d in docs])
    return out


def _channel_severity(docs: list[Document], prof: ChannelProfile,
                      image_degraded: bool, text_degraded: bool
                      ) -> np.ndarray:
    """Per-doc effective severity, mirroring the scalar rules exactly:
    text parsers suffer from degraded TEXT layers, recognition parsers
    from degraded IMAGES (paper §7.2 regimes)."""
    diff = np.array([d.difficulty for d in docs], np.float64)
    sev = prof.flat_floor + diff ** prof.difficulty_power
    if prof.text_layer:
        if text_degraded:
            sev = np.minimum(1.0, sev + 0.5)
        scanned = np.array([d.scanned for d in docs], bool)
        sev = np.where(scanned, np.minimum(1.0, sev + 0.35), sev)
    elif image_degraded:
        sev = np.minimum(1.0, sev + 0.3)
    return sev


def corrupt_documents(docs: list[Document], prof: ChannelProfile,
                      cfg: CorpusConfig, rng: np.random.RandomState,
                      image_degraded: bool = False,
                      text_degraded: bool = False) -> list[list[np.ndarray]]:
    """Batched ``corrupt_document``: apply one parser channel to a whole
    batch with one rng draw per channel over the flattened token stream
    (all pages of all docs), instead of per-doc/per-page Python loops.

    This is the engine's hot path (every doc goes through the cheap
    channel); the per-channel masks, substitutions, and whitespace
    insertion are each a single vectorized op over ~k * pages * tokens
    elements. Returns output pages per document."""
    n_docs = len(docs)
    if n_docs == 0:
        return []
    sev = _channel_severity(docs, prof, image_degraded, text_degraded)
    failed = (rng.rand(n_docs) < prof.p_fail * sev if prof.p_fail > 0
              else np.zeros(n_docs, bool))

    pages_per_doc = np.array([d.n_pages for d in docs])
    doc_of_page = np.repeat(np.arange(n_docs), pages_per_doc)
    n_pages = int(pages_per_doc.sum())
    flat_pages = [pg for d in docs for pg in d.pages]
    page_lens = np.fromiter((len(pg) for pg in flat_pages), np.int64,
                            count=n_pages)
    dropped = (rng.rand(n_pages) < prof.p_page_drop
               if prof.p_page_drop > 0 else np.zeros(n_pages, bool))
    dropped |= failed[doc_of_page]

    t = (np.concatenate(flat_pages) if n_pages else
         np.zeros(0, np.int64)).astype(np.int64)
    n = len(t)
    page_of_tok = np.repeat(np.arange(n_pages), page_lens)
    sev_tok = sev[doc_of_page[page_of_tok]]
    is_latex = (t >= cfg.latex_lo) & (t < cfg.ident_lo)
    is_ident = t >= cfg.ident_lo
    # (f) LaTeX mangling: whole spans to MANGLED
    if prof.p_latex > 0:
        fail = rng.rand(n) < prof.p_latex * (0.3 + 0.7 * sev_tok)
        t = np.where(is_latex & fail, MANGLED, t)
    # (e) identifier corruption
    if prof.p_ident > 0:
        fail = rng.rand(n) < prof.p_ident * (0.3 + 0.7 * sev_tok)
        t = np.where(is_ident & fail, MANGLED, t)
    # (b) word substitution
    if prof.p_sub > 0:
        m = rng.rand(n) < prof.p_sub * sev_tok
        t = np.where(m, rng.randint(WORD_LO, WORD_LO + cfg.n_words, size=n),
                     t)
    # (d) near-word (character) substitution
    if prof.p_char > 0:
        m = (rng.rand(n) < prof.p_char * sev_tok) & (t >= WORD_LO)
        t = np.where(m, np.bitwise_xor(t, 1), t)
    # (c) scrambling
    if prof.p_scramble > 0:
        m = rng.rand(n) < prof.p_scramble * sev_tok
        t = np.where(m, SCRAMBLE, t)
    # (a) whitespace injection (np.insert keeps each WS inside the page
    # of the token it was drawn against)
    if prof.p_ws > 0:
        m = rng.rand(n) < prof.p_ws * sev_tok
        idx = np.nonzero(m)[0]
        if len(idx):
            page_lens = page_lens + np.bincount(page_of_tok[idx],
                                                minlength=n_pages)
            t = np.insert(t, idx, WS)

    bounds = np.cumsum(page_lens)[:-1]
    pieces = np.split(t.astype(np.int32), bounds) if n_pages else []
    empty = np.zeros(0, np.int32)
    out: list[list[np.ndarray]] = []
    p = 0
    for d in docs:
        out.append([empty if dropped[p + j] else pieces[p + j]
                    for j in range(d.n_pages)])
        p += d.n_pages
    return out


def corrupt_document(doc: Document, prof: ChannelProfile, cfg: CorpusConfig,
                     rng: np.random.RandomState,
                     image_degraded: bool = False,
                     text_degraded: bool = False) -> list[np.ndarray]:
    """Apply a parser's channel to a document; returns output pages."""
    # effective severity: text parsers suffer from degraded TEXT layers,
    # recognition parsers from degraded IMAGES (paper §7.2 regimes)
    sev = prof.flat_floor + (doc.difficulty ** prof.difficulty_power)
    if prof.text_layer:
        if text_degraded:
            sev = min(1.0, sev + 0.5)
        if doc.scanned:
            sev = min(1.0, sev + 0.35)   # scans have OCR'd (noisy) layers
    else:
        if image_degraded:
            sev = min(1.0, sev + 0.3)
    if rng.rand() < prof.p_fail * sev:
        return [np.zeros(0, np.int32) for _ in doc.pages]
    out = []
    for page in doc.pages:
        if rng.rand() < prof.p_page_drop:
            out.append(np.zeros(0, np.int32))
            continue
        t = page.copy()
        n = len(t)
        is_latex = (t >= cfg.latex_lo) & (t < cfg.ident_lo)
        is_ident = t >= cfg.ident_lo
        # (f) LaTeX mangling: whole spans to MANGLED
        if prof.p_latex > 0:
            fail = rng.rand(n) < prof.p_latex * (0.3 + 0.7 * sev)
            t = np.where(is_latex & fail, MANGLED, t)
        # (e) identifier corruption
        if prof.p_ident > 0:
            fail = rng.rand(n) < prof.p_ident * (0.3 + 0.7 * sev)
            t = np.where(is_ident & fail, MANGLED, t)
        # (b) word substitution
        if prof.p_sub > 0:
            m = rng.rand(n) < prof.p_sub * sev
            t = np.where(m, rng.randint(WORD_LO, WORD_LO + cfg.n_words,
                                        size=n), t)
        # (d) near-word (character) substitution
        if prof.p_char > 0:
            m = (rng.rand(n) < prof.p_char * sev) & (t >= WORD_LO)
            t = np.where(m, np.bitwise_xor(t, 1), t)
        # (c) scrambling
        if prof.p_scramble > 0:
            m = rng.rand(n) < prof.p_scramble * sev
            t = np.where(m, SCRAMBLE, t)
        # (a) whitespace injection
        if prof.p_ws > 0:
            m = rng.rand(n) < prof.p_ws * sev
            idx = np.nonzero(m)[0]
            if len(idx):
                t = np.insert(t, idx, WS)
        out.append(t.astype(np.int32))
    return out


# ---------------------------------------------------------------------------
# Preference oracle (stands in for the 23-expert study, §6.3)
# ---------------------------------------------------------------------------


def preference_utility(ref: np.ndarray, hyp: np.ndarray,
                       rng: np.random.RandomState,
                       doc_bleu: float | None = None) -> float:
    """Scalar 'human' utility: BLEU plus stylistic biases (humans punish
    visible garbage — scrambles/whitespace/mangles — more than BLEU does,
    and strongly punish dropped content) plus judgment noise. Calibrated so
    corr(BLEU, win-rate) ≈ 0.5 (paper: ρ̂=0.47)."""
    from repro.core import metrics as M
    b = M.bleu(ref, hyp) if doc_bleu is None else doc_bleu
    hyp = np.asarray(hyp).ravel()
    n = max(len(hyp), 1)
    frac_garbage = float(np.isin(hyp, (SCRAMBLE, MANGLED)).mean()) if len(hyp) else 0.0
    frac_ws = float((hyp == WS).mean()) if len(hyp) else 0.0
    drop_pen = 1.0 if len(hyp) == 0 else 0.0
    return (b - 1.5 * frac_garbage - 0.8 * frac_ws - 0.9 * drop_pen
            + rng.normal(0, 0.18))
