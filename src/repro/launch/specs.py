"""Cell factory: (arch × shape) -> (step_fn, abstract inputs, shardings).

Every one of the 40 assigned cells (+ the paper's own router/parser cells)
is materialized here as a jit-able step function plus weak-type-correct
ShapeDtypeStruct stand-ins for all inputs (parameters, optimizer state,
batches, KV caches) — the dry-run lowers exactly these objects, so no
full-size array is ever allocated.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import Param, abstractify, is_param, unwrap
from repro.configs.base import (ArchConfig, EncoderConfig, GNNConfig,
                                LMConfig, RecsysConfig, ShapeConfig,
                                VitParserConfig, get_config)
from repro.distributed.meshrules import AxisRules
from repro.models import encoder as enc_lib
from repro.models import transformer as lm_lib
from repro.models import vit_parser as vp_lib
from repro.models.attention import KVCache
from repro.models.gnn import equiformer as eq_lib
from repro.models.gnn import sampler as sampler_lib
from repro.models.recsys import models as rs_lib
from repro.optim import adafactor, adamw, apply_updates, chain_clip


# ---------------------------------------------------------------------------
# Helpers
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


def _optimizer_for(arch: ArchConfig):
    if arch.arch_id.startswith("grok"):
        return adafactor(1e-4), "adafactor"
    return chain_clip(adamw(3e-4, weight_decay=0.1), 1.0), "adamw"


def _opt_state_shardings(rules: AxisRules, params, kind: str):
    """Optimizer-state shardings mirror the param layout + ZeRO 'data'."""
    if rules is None:
        return None
    if kind == "adamw":
        t = jax.tree_util.tree_map(
            lambda p: rules.zero_sharding_for(p.axes, p.value.shape),
            params, is_leaf=is_param)
        return {"m": t, "v": t}
    # adafactor: factored leaves {"vr","vc"} else {"v"}
    def leaf(p):
        shp = p.value.shape
        if len(shp) >= 2 and shp[-1] >= 128 and shp[-2] >= 128:
            return {"vr": rules.sharding_for(p.axes[:-1], shp[:-1]),
                    "vc": rules.sharding_for(p.axes[:-2] + p.axes[-1:],
                                             shp[:-2] + shp[-1:])}
        return {"v": rules.zero_sharding_for(p.axes, shp)}

    return {"v": jax.tree_util.tree_map(leaf, params, is_leaf=is_param)}


def _abstract_opt_state(opt, params_raw):
    return jax.eval_shape(opt.init, params_raw)


def _batch_shardings(rules: AxisRules, axes_map: dict, batch: dict):
    if rules is None:
        return None
    return {k: rules.sharding_for(axes_map[k], v.shape)
            for k, v in batch.items()}


@dataclasses.dataclass
class Cell:
    arch_id: str
    shape_name: str
    kind: str                         # train | prefill | decode | serve
    fn: Callable
    args: tuple                       # abstract or concrete
    in_shardings: Any = None          # tree matching args (or None)
    donate_argnums: tuple = ()
    note: str = ""


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------


def _lm_train_cell(arch: ArchConfig, shape: ShapeConfig, rules, abstract,
                   seed=0) -> Cell:
    cfg: LMConfig = arch.model
    opt, kind = _optimizer_for(arch)
    params = lm_lib.init_lm(cfg, seed, abstract=abstract)
    params_raw = unwrap(params)
    opt_state = (_abstract_opt_state(opt, params_raw) if abstract
                 else opt.init(params_raw))

    def train_step(params_raw, opt_state, step, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: lm_lib.lm_loss(p, cfg, batch), has_aux=True)(params_raw)
        updates, opt_state = opt.update(grads, opt_state, params_raw, step)
        params_raw = apply_updates(params_raw, updates)
        return params_raw, opt_state, loss

    b, s = shape["global_batch"], shape["seq_len"]
    if abstract:
        batch = {"tokens": _sds((b, s), jnp.int32),
                 "labels": _sds((b, s), jnp.int32)}
    else:
        rng = np.random.RandomState(seed)
        toks = rng.randint(0, cfg.vocab_size, size=(b, s + 1)).astype(np.int32)
        batch = {"tokens": jnp.asarray(toks[:, :-1]),
                 "labels": jnp.asarray(toks[:, 1:])}
    in_sh = None
    if rules is not None:
        p_sh = rules.param_shardings(params)
        in_sh = (p_sh, _opt_state_shardings(rules, params, kind),
                 rules.sharding_for((), ()),
                 _batch_shardings(rules, {"tokens": ("batch", "seq"),
                                          "labels": ("batch", "seq")}, batch))
    step0 = _sds((), jnp.int32) if abstract else jnp.asarray(0)
    return Cell(arch.arch_id, shape.name, "train", train_step,
                (params_raw, opt_state, step0, batch), in_sh,
                donate_argnums=(0, 1))


def _lm_prefill_cell(arch, shape, rules, abstract, seed=0) -> Cell:
    cfg: LMConfig = arch.model
    params_raw = unwrap(lm_lib.init_lm(cfg, seed, abstract=abstract))
    b, s = shape["global_batch"], shape["seq_len"]

    def prefill_step(params_raw, tokens):
        return lm_lib.prefill(params_raw, cfg, tokens)

    tokens = (_sds((b, s), jnp.int32) if abstract else
              jnp.asarray(np.random.RandomState(seed).randint(
                  0, cfg.vocab_size, size=(b, s)), jnp.int32))
    in_sh = None
    if rules is not None:
        params = lm_lib.init_lm(cfg, seed, abstract=True)
        in_sh = (rules.param_shardings(params),
                 rules.sharding_for(("batch", "seq"), (b, s)))
    return Cell(arch.arch_id, shape.name, "prefill", prefill_step,
                (params_raw, tokens), in_sh)


def _lm_decode_cell(arch, shape, rules, abstract, seed=0) -> Cell:
    cfg: LMConfig = arch.model
    params_raw = unwrap(lm_lib.init_lm(cfg, seed, abstract=abstract))
    b, s = shape["global_batch"], shape["seq_len"]
    cdt = jnp.dtype(cfg.compute_dtype)

    def serve_step(params_raw, tokens, cache, pos):
        return lm_lib.decode_step(params_raw, cfg, tokens, cache, pos)

    if abstract:
        tokens = _sds((b, 1), jnp.int32)
        cache = KVCache.abstract(cfg.n_layers, b, s, cfg.n_kv_heads,
                                 cfg.head_dim, cdt)
        pos = _sds((), jnp.int32)
    else:
        tokens = jnp.zeros((b, 1), jnp.int32)
        cache = KVCache.zeros(cfg.n_layers, b, s, cfg.n_kv_heads,
                              cfg.head_dim, cdt)
        pos = jnp.asarray(s - 1)
    in_sh = None
    if rules is not None:
        cache_axes = ("layers", "batch", "kv_seq", "kv_heads", "d_head")
        cache_shape = (cfg.n_layers, b, s, cfg.n_kv_heads, cfg.head_dim)
        cache_sh = KVCache(rules.sharding_for(cache_axes, cache_shape),
                           rules.sharding_for(cache_axes, cache_shape))
        params = lm_lib.init_lm(cfg, seed, abstract=True)
        in_sh = (rules.param_shardings(params),
                 rules.sharding_for(("batch", None), (b, 1)),
                 cache_sh, rules.sharding_for((), ()))
    return Cell(arch.arch_id, shape.name, "decode", serve_step,
                (params_raw, tokens, cache, pos), in_sh,
                donate_argnums=(2,))


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------

GNN_DATASETS = {
    # shape -> (d_in, n_out, classification?)
    "full_graph_sm": (1433, 7, True),        # Cora
    "minibatch_lg": (602, 41, True),         # Reddit (sampled)
    "ogb_products": (100, 47, True),         # ogbn-products
    "molecule": (16, 1, False),              # batched small molecules
}


def _gnn_dims(shape: ShapeConfig) -> tuple[int, int]:
    from repro.common import round_up
    if shape.name == "minibatch_lg":
        n = sampler_lib.static_node_count(shape["batch_nodes"],
                                          [shape["fanout0"], shape["fanout1"]])
        e = sampler_lib.static_edge_count(shape["batch_nodes"],
                                          [shape["fanout0"], shape["fanout1"]])
        return n, e
    if shape.name == "molecule":
        return shape["n_nodes"] * shape["batch"], shape["n_edges"] * shape["batch"]
    # full-graph cells: pad nodes/edges to a 512 multiple so the mesh can
    # shard them (61,859,140 % 256 != 0 would force full replication —
    # 15 TB/dev). Padding edges are zero-length self-loops, which the
    # equivariance mask already drops from message passing.
    return (round_up(shape["n_nodes"], 512), round_up(shape["n_edges"], 512))


def _gnn_train_cell(arch, shape, rules, abstract, seed=0) -> Cell:
    d_in, n_out, is_cls = GNN_DATASETS[shape.name]
    cfg: GNNConfig = dataclasses.replace(arch.model, d_in=d_in, n_out=n_out)
    opt, kind = _optimizer_for(arch)
    params = eq_lib.init_equiformer(cfg, seed, abstract=abstract)
    params_raw = unwrap(params)
    opt_state = (_abstract_opt_state(opt, params_raw) if abstract
                 else opt.init(params_raw))
    n, e = _gnn_dims(shape)

    def train_step(params_raw, opt_state, step, batch):
        (loss, _), grads = jax.value_and_grad(
            lambda p: eq_lib.equiformer_loss(p, cfg, batch),
            has_aux=True)(params_raw)
        updates, opt_state = opt.update(grads, opt_state, params_raw, step)
        return apply_updates(params_raw, updates), opt_state, loss

    lbl_dtype = jnp.int32 if is_cls else jnp.float32
    if shape.name == "molecule":
        lbl_shape = (shape["batch"], n_out)
    else:
        lbl_shape = (n,)
    if abstract:
        batch = {"pos": _sds((n, 3), jnp.float32),
                 "src": _sds((e,), jnp.int32),
                 "dst": _sds((e,), jnp.int32),
                 "node_feat": _sds((n, d_in), jnp.float32),
                 "labels": _sds(lbl_shape, lbl_dtype)}
        if shape.name == "molecule":
            batch["graph_ids"] = _sds((n,), jnp.int32)
    else:
        rng = np.random.RandomState(seed)
        batch = {"pos": jnp.asarray(rng.randn(n, 3), jnp.float32),
                 "src": jnp.asarray(rng.randint(0, n, e), jnp.int32),
                 "dst": jnp.asarray(rng.randint(0, n, e), jnp.int32),
                 "node_feat": jnp.asarray(rng.randn(n, d_in), jnp.float32),
                 "labels": (jnp.asarray(rng.randint(0, n_out, lbl_shape),
                                        jnp.int32) if is_cls else
                            jnp.asarray(rng.randn(*lbl_shape), jnp.float32))}
        if shape.name == "molecule":
            batch["graph_ids"] = jnp.repeat(
                jnp.arange(shape["batch"], dtype=jnp.int32), shape["n_nodes"])
    if shape.name == "molecule":
        batch["n_graphs"] = shape["batch"]

    def train_step_static(params_raw, opt_state, step, batch):
        if shape.name == "molecule":
            batch = dict(batch, n_graphs=shape["batch"])
        return train_step(params_raw, opt_state, step, batch)

    sharded_batch = {k: v for k, v in batch.items() if k != "n_graphs"}
    in_sh = None
    if rules is not None:
        axes_map = {"pos": ("nodes", None), "src": ("edges",),
                    "dst": ("edges",), "node_feat": ("nodes", "d_feat"),
                    "labels": ("graphs", None) if shape.name == "molecule"
                    else ("nodes",),
                    "graph_ids": ("nodes",)}
        in_sh = (rules.param_shardings(params),
                 _opt_state_shardings(rules, params, kind),
                 rules.sharding_for((), ()),
                 _batch_shardings(rules, axes_map, sharded_batch))
    step0 = _sds((), jnp.int32) if abstract else jnp.asarray(0)
    return Cell(arch.arch_id, shape.name, "train", train_step_static,
                (params_raw, opt_state, step0, sharded_batch), in_sh,
                donate_argnums=(0, 1), note=f"N={n} E={e}")


# ---------------------------------------------------------------------------
# Recsys cells
# ---------------------------------------------------------------------------


def _recsys_batch(cfg: RecsysConfig, b: int, abstract, seed=0):
    if abstract:
        batch = {"sparse": _sds((b, cfg.n_sparse), jnp.int32),
                 "labels": _sds((b,), jnp.float32)}
        if cfg.kind == "dlrm":
            batch["dense"] = _sds((b, cfg.n_dense), jnp.float32)
        if cfg.kind == "dien":
            t = cfg.seq_len
            batch.update(hist=_sds((b, t), jnp.int32),
                         hist_cat=_sds((b, t), jnp.int32),
                         hist_mask=_sds((b, t), jnp.float32),
                         target=_sds((b,), jnp.int32),
                         target_cat=_sds((b,), jnp.int32))
        return batch
    rng = np.random.RandomState(seed)
    sparse = np.stack([rng.randint(0, v, b) for v in cfg.vocab_sizes], 1)
    batch = {"sparse": jnp.asarray(sparse, jnp.int32),
             "labels": jnp.asarray(rng.rand(b) < 0.3, jnp.float32)}
    if cfg.kind == "dlrm":
        batch["dense"] = jnp.asarray(rng.randn(b, cfg.n_dense), jnp.float32)
    if cfg.kind == "dien":
        t = cfg.seq_len
        v0, v1 = cfg.vocab_sizes[0], sum(cfg.vocab_sizes)
        batch.update(
            hist=jnp.asarray(rng.randint(0, v0, (b, t)), jnp.int32),
            hist_cat=jnp.asarray(rng.randint(v0, v1, (b, t)), jnp.int32),
            hist_mask=jnp.ones((b, t), jnp.float32),
            target=jnp.asarray(rng.randint(0, v0, b), jnp.int32),
            target_cat=jnp.asarray(rng.randint(v0, v1, b), jnp.int32))
    return batch


_RS_AXES = {"sparse": ("batch", "fields"), "labels": ("batch",),
            "dense": ("batch", None), "hist": ("batch", None),
            "hist_cat": ("batch", None), "hist_mask": ("batch", None),
            "target": ("batch",), "target_cat": ("batch",),
            "user_query": ("batch", "embed_dim")}


def _recsys_cell(arch, shape, rules, abstract, seed=0) -> Cell:
    cfg: RecsysConfig = arch.model
    params = rs_lib.init_recsys(cfg, seed, abstract=abstract)
    params_raw = unwrap(params)

    if shape.name == "retrieval_cand":
        n_cand = min(shape["n_candidates"], int(sum(cfg.vocab_sizes)))

        k_top = min(100, n_cand)

        def retrieval_step(params_raw, batch):
            return rs_lib.recsys_retrieval(
                params_raw, cfg, dict(batch, n_candidates=n_cand), k=k_top)

        q = (_sds((shape["batch"], cfg.embed_dim), jnp.float32) if abstract
             else jnp.asarray(np.random.RandomState(seed).randn(
                 shape["batch"], cfg.embed_dim), jnp.float32))
        batch = {"user_query": q}
        in_sh = None
        if rules is not None:
            in_sh = (rules.param_shardings(params),
                     _batch_shardings(rules, _RS_AXES, batch))
        return Cell(arch.arch_id, shape.name, "serve", retrieval_step,
                    (params_raw, batch), in_sh, note=f"n_cand={n_cand}")

    b = shape["batch"]
    batch = _recsys_batch(cfg, b, abstract, seed)
    if shape.kind == "train":
        opt, kind = _optimizer_for(arch)
        opt_state = (_abstract_opt_state(opt, params_raw) if abstract
                     else opt.init(params_raw))

        def train_step(params_raw, opt_state, step, batch):
            (loss, _), grads = jax.value_and_grad(
                lambda p: rs_lib.recsys_loss(p, cfg, batch),
                has_aux=True)(params_raw)
            updates, opt_state = opt.update(grads, opt_state, params_raw,
                                            step)
            return apply_updates(params_raw, updates), opt_state, loss

        in_sh = None
        if rules is not None:
            in_sh = (rules.param_shardings(params),
                     _opt_state_shardings(rules, params, kind),
                     rules.sharding_for((), ()),
                     _batch_shardings(rules, _RS_AXES, batch))
        step0 = _sds((), jnp.int32) if abstract else jnp.asarray(0)
        return Cell(arch.arch_id, shape.name, "train", train_step,
                    (params_raw, opt_state, step0, batch), in_sh,
                    donate_argnums=(0, 1))

    serve_batch = {k: v for k, v in batch.items() if k != "labels"}

    def serve_step(params_raw, batch):
        return rs_lib.recsys_scores(params_raw, cfg, batch)

    in_sh = None
    if rules is not None:
        in_sh = (rules.param_shardings(params),
                 _batch_shardings(rules, _RS_AXES, serve_batch))
    return Cell(arch.arch_id, shape.name, "serve", serve_step,
                (params_raw, serve_batch), in_sh)


# ---------------------------------------------------------------------------
# AdaParse router cells (the paper's own model)
# ---------------------------------------------------------------------------


def _router_cell(arch, shape, rules, abstract, seed=0) -> Cell:
    from repro.core.dpo import dpo_loss
    from repro.core.router import make_route_step

    cfg: EncoderConfig = arch.model
    params = enc_lib.init_encoder(cfg, seed, abstract=abstract)
    params_raw = unwrap(params)
    b = shape["global_batch"]
    s = min(shape["seq_len"], cfg.max_len)

    def mk_tok(bb):
        if abstract:
            return _sds((bb, s), jnp.int32), _sds((bb, s), jnp.float32)
        rng = np.random.RandomState(seed)
        return (jnp.asarray(rng.randint(2, cfg.vocab_size, (bb, s)),
                            jnp.int32), jnp.ones((bb, s), jnp.float32))

    if shape.name.startswith("sft"):
        opt, kind = _optimizer_for(arch)
        opt_state = (_abstract_opt_state(opt, params_raw) if abstract
                     else opt.init(params_raw))
        toks, mask = mk_tok(b)
        tgt = (_sds((b, cfg.n_outputs), jnp.float32) if abstract
               else jnp.full((b, cfg.n_outputs), 0.5, jnp.float32))
        batch = {"tokens": toks, "mask": mask, "targets": tgt}

        def train_step(params_raw, opt_state, step, batch):
            loss, grads = jax.value_and_grad(
                lambda p: enc_lib.regression_loss(p, cfg, batch))(params_raw)
            updates, opt_state = opt.update(grads, opt_state, params_raw,
                                            step)
            return apply_updates(params_raw, updates), opt_state, loss

        in_sh = None
        if rules is not None:
            axes = {"tokens": ("batch", "seq"), "mask": ("batch", "seq"),
                    "targets": ("batch", None)}
            in_sh = (rules.param_shardings(params),
                     _opt_state_shardings(rules, params, kind),
                     rules.sharding_for((), ()),
                     _batch_shardings(rules, axes, batch))
        step0 = _sds((), jnp.int32) if abstract else jnp.asarray(0)
        return Cell(arch.arch_id, shape.name, "train", train_step,
                    (params_raw, opt_state, step0, batch), in_sh,
                    donate_argnums=(0, 1))

    if shape.name.startswith("dpo"):
        opt, kind = _optimizer_for(arch)
        opt_state = (_abstract_opt_state(opt, params_raw) if abstract
                     else opt.init(params_raw))
        tp, mp = mk_tok(b)
        tn, mn = mk_tok(b)
        batch = {"tok_pos": tp, "mask_pos": mp, "tok_neg": tn,
                 "mask_neg": mn}

        def train_step(params_raw, ref_raw, opt_state, step, batch):
            loss, grads = jax.value_and_grad(
                lambda p: dpo_loss(p, ref_raw, cfg, batch))(params_raw)
            updates, opt_state = opt.update(grads, opt_state, params_raw,
                                            step)
            return apply_updates(params_raw, updates), opt_state, loss

        in_sh = None
        if rules is not None:
            axes = {k: ("batch", "seq") for k in batch}
            p_sh = rules.param_shardings(params)
            in_sh = (p_sh, p_sh,
                     _opt_state_shardings(rules, params, kind),
                     rules.sharding_for((), ()),
                     _batch_shardings(rules, axes, batch))
        step0 = _sds((), jnp.int32) if abstract else jnp.asarray(0)
        return Cell(arch.arch_id, shape.name, "train", train_step,
                    (params_raw, params_raw, opt_state, step0, batch),
                    in_sh, donate_argnums=(0, 2))

    # route_*: the production fused route step (paper-representative cell)
    alpha = 0.05
    route_step = make_route_step(cfg, alpha)
    toks, mask = mk_tok(b)
    valid = (_sds((b,), jnp.float32) if abstract
             else jnp.ones((b,), jnp.float32))
    in_sh = None
    if rules is not None:
        in_sh = (rules.param_shardings(params),
                 rules.sharding_for(("batch", "seq"), (b, s)),
                 rules.sharding_for(("batch", "seq"), (b, s)),
                 rules.sharding_for(("batch",), (b,)))
    return Cell(arch.arch_id, shape.name, "serve", route_step,
                (params_raw, toks, mask, valid), in_sh,
                note=f"alpha={alpha}")


# ---------------------------------------------------------------------------
# Nougat parser cells
# ---------------------------------------------------------------------------


def _nougat_cell(arch, shape, rules, abstract, seed=0) -> Cell:
    cfg: VitParserConfig = arch.model
    params = vp_lib.init_vit_parser(cfg, seed, abstract=abstract)
    params_raw = unwrap(params)
    b = shape["global_batch"]
    patch_dim = cfg.patch * cfg.patch * 3
    n_p = cfg.n_patches
    cdt = jnp.dtype(cfg.compute_dtype)

    def mk_patches():
        if abstract:
            return _sds((b, n_p, patch_dim), cdt)
        return jnp.asarray(np.random.RandomState(seed).randn(
            b, n_p, patch_dim), cdt)

    if shape.kind == "train":
        t = min(shape["dec_len"], cfg.max_dec_len)
        opt, kind = _optimizer_for(arch)
        opt_state = (_abstract_opt_state(opt, params_raw) if abstract
                     else opt.init(params_raw))
        if abstract:
            toks = _sds((b, t), jnp.int32)
        else:
            toks = jnp.zeros((b, t), jnp.int32)
        batch = {"patches": mk_patches(), "tokens": toks, "labels": toks}

        def train_step(params_raw, opt_state, step, batch):
            (loss, _), grads = jax.value_and_grad(
                lambda p: vp_lib.parser_loss(p, cfg, batch),
                has_aux=True)(params_raw)
            updates, opt_state = opt.update(grads, opt_state, params_raw,
                                            step)
            return apply_updates(params_raw, updates), opt_state, loss

        in_sh = None
        if rules is not None:
            axes = {"patches": ("pages", "patches", None),
                    "tokens": ("pages", "seq"), "labels": ("pages", "seq")}
            in_sh = (rules.param_shardings(params),
                     _opt_state_shardings(rules, params, kind),
                     rules.sharding_for((), ()),
                     _batch_shardings(rules, axes, batch))
        step0 = _sds((), jnp.int32) if abstract else jnp.asarray(0)
        return Cell(arch.arch_id, shape.name, "train", train_step,
                    (params_raw, opt_state, step0, batch), in_sh,
                    donate_argnums=(0, 1))

    if shape.name == "parse_encode":
        def encode_step(params_raw, patches):
            memory = vp_lib.encode_pages(params_raw, cfg, patches)
            state = vp_lib.init_dec_state(params_raw, cfg, memory)
            return state.xk, state.xv

        patches = mk_patches()
        in_sh = None
        if rules is not None:
            in_sh = (rules.param_shardings(params),
                     rules.sharding_for(("pages", "patches", None),
                                        (b, n_p, patch_dim)))
        return Cell(arch.arch_id, shape.name, "serve", encode_step,
                    (params_raw, patches), in_sh)

    # parse_decode: one token for the in-flight page batch
    t = min(shape["dec_len"], cfg.max_dec_len)
    dh = cfg.dec_d_model // cfg.dec_heads

    def decode_step(params_raw, tok, cache_k, cache_v, xk, xv, pos):
        state = vp_lib.DecState(KVCache(cache_k, cache_v), xk, xv)
        logits, state = vp_lib.dec_step(params_raw, cfg, tok, state, pos)
        return logits, state.cache.k, state.cache.v

    cshape = (cfg.dec_layers, b, t, cfg.dec_heads, dh)
    xshape = (cfg.dec_layers, b, n_p, cfg.dec_heads, dh)
    if abstract:
        tok = _sds((b, 1), jnp.int32)
        ck = cv = _sds(cshape, cdt)
        xk = xv = _sds(xshape, cdt)
        pos = _sds((), jnp.int32)
    else:
        tok = jnp.zeros((b, 1), jnp.int32)
        ck = cv = jnp.zeros(cshape, cdt)
        xk = xv = jnp.zeros(xshape, cdt)
        pos = jnp.asarray(t - 1)
    in_sh = None
    if rules is not None:
        c_ax = ("layers", "pages", "kv_seq", "heads", "d_head")
        x_ax = ("layers", "pages", "patches", "heads", "d_head")
        in_sh = (rules.param_shardings(params),
                 rules.sharding_for(("pages", None), (b, 1)),
                 rules.sharding_for(c_ax, cshape),
                 rules.sharding_for(c_ax, cshape),
                 rules.sharding_for(x_ax, xshape),
                 rules.sharding_for(x_ax, xshape),
                 rules.sharding_for((), ()))
    return Cell(arch.arch_id, shape.name, "decode", decode_step,
                (params_raw, tok, ck, cv, xk, xv, pos), in_sh,
                donate_argnums=(2, 3))


# ---------------------------------------------------------------------------
# Dispatcher
# ---------------------------------------------------------------------------


def build_cell(arch_id: str, shape_name: str, rules: AxisRules | None = None,
               abstract: bool = True, reduced: bool = False,
               seed: int = 0, model_override=None) -> Cell:
    arch = get_config(arch_id)
    if model_override is not None:
        arch = dataclasses.replace(arch, model=model_override)
    if reduced:
        arch = arch.reduced()
        shape = _reduce_shape(arch.family, arch.shape(shape_name))
    else:
        shape = arch.shape(shape_name)
    if shape_name in arch.skips and not reduced:
        raise ValueError(f"{arch_id}/{shape_name} skipped: "
                         f"{arch.skips[shape_name]}")
    fam = arch.family
    if fam == "lm":
        if shape.kind == "train":
            return _lm_train_cell(arch, shape, rules, abstract, seed)
        if shape.kind == "prefill":
            return _lm_prefill_cell(arch, shape, rules, abstract, seed)
        return _lm_decode_cell(arch, shape, rules, abstract, seed)
    if fam == "gnn":
        return _gnn_train_cell(arch, shape, rules, abstract, seed)
    if fam == "recsys":
        return _recsys_cell(arch, shape, rules, abstract, seed)
    if fam == "encoder":
        return _router_cell(arch, shape, rules, abstract, seed)
    if fam == "vit_parser":
        return _nougat_cell(arch, shape, rules, abstract, seed)
    raise ValueError(fam)


def _reduce_shape(family: str, shape: ShapeConfig) -> ShapeConfig:
    """Shrink a workload cell for CPU smoke tests (same kind/topology)."""
    d = dict(shape.dims)
    if family in ("lm", "encoder", "vit_parser"):
        if "seq_len" in d:
            d["seq_len"] = min(d["seq_len"], 64)
        if "global_batch" in d:
            d["global_batch"] = min(d["global_batch"], 4)
        if "dec_len" in d:
            d["dec_len"] = min(d["dec_len"], 16)
    elif family == "gnn":
        scale = {"full_graph_sm": dict(n_nodes=64, n_edges=256, d_feat=16),
                 "minibatch_lg": dict(n_nodes=0, n_edges=0, batch_nodes=8,
                                      fanout0=3, fanout1=2),
                 "ogb_products": dict(n_nodes=128, n_edges=512, d_feat=16),
                 "molecule": dict(n_nodes=6, n_edges=12, batch=4)}
        d.update(scale[shape.name])
    elif family == "recsys":
        if "batch" in d:
            d["batch"] = min(d["batch"], 16)
        if "n_candidates" in d:
            d["n_candidates"] = min(d["n_candidates"], 64)
    return ShapeConfig(shape.name, shape.kind, d, shape.note)


def all_cells() -> list[tuple[str, str]]:
    """Every (arch, runnable shape) pair — the 40-cell matrix (minus
    documented skips) + the paper's own cells."""
    from repro.configs import list_archs
    out = []
    for a in list_archs():
        arch = get_config(a)
        for s in arch.runnable_shapes():
            out.append((a, s.name))
    return out
