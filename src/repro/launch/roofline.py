"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), all in seconds:

  compute    = HLO_FLOPs / (chips × 197e12)         [bf16 MXU peak]
  memory     = HLO_bytes / (chips × 819e9)          [HBM]
  collective = Σ collective-operand-bytes / (chips × 50e9)   [ICI]

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``.
collective_bytes is parsed from ``compiled.as_text()``: we sum the
*output* shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute instruction (output size ≈ wire bytes per
participating device for AG/AR; a standard approximation). The dominant
term is the bottleneck the perf loop attacks.
"""
from __future__ import annotations

import dataclasses
import json
import re

import numpy as np

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# matches e.g.:  %ag = bf16[2,1024,128]{2,1,0} all-gather(...)
_INSTR_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^ ]*)\s+([\w-]+)")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    nb = _DTYPE_BYTES.get(dtype)
    if nb is None:
        return 0
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * nb


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum output bytes per collective kind over the whole module."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = _INSTR_RE.search(stripped)
        if not m:
            continue
        op = m.group(4)
        # ops like all-gather-start / all-reduce-done
        base = None
        for k in _COLLECTIVES:
            if op == k or op.startswith(k + "-"):
                base = k
                break
        if base is None:
            continue
        if op.endswith("-done"):
            continue                      # counted at -start
        if m.group(1) is not None:        # tuple shape
            total = sum(_shape_bytes(t, d)
                        for t, d in _SHAPE_RE.findall(m.group(1)))
        else:
            total = _shape_bytes(m.group(2), m.group(3))
        out[base] += total
    return out


_HEAVY_OPS = (" dot(", " convolution(", " gather(", " scatter(",
              " reduce(", " reduce-window(", " sort(", " custom-call(",
              " all-gather(", " all-reduce(", " all-to-all(",
              " reduce-scatter(", " dynamic-slice(",
              " dynamic-update-slice(")


def fused_bytes(hlo_text: str) -> int:
    """TPU-fusion-adjusted HBM traffic estimate.

    The CPU backend leaves elementwise chains unfused, so raw
    ``bytes accessed`` over-counts HBM traffic by ~10-50x vs a TPU
    compile. On TPU, elementwise ops fuse into the adjacent heavy op, so
    traffic ≈ Σ (operand + output bytes) of heavy ops (dots, reductions,
    gathers/scatters, collectives). We parse every heavy instruction's
    inline shapes (output first, then operands) and sum.
    """
    total = 0
    for line in hlo_text.splitlines():
        if not any(op in line for op in _HEAVY_OPS):
            continue
        shapes = _SHAPE_RE.findall(line)
        total += sum(_shape_bytes(t, d) for t, d in shapes)
    return total


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops: float                  # per-device HLO flops (SPMD module)
    hbm_bytes: float              # per-device bytes accessed
    coll_bytes: dict
    per_device_mem: int           # from memory_analysis
    model_flops: float = 0.0      # 6*N*D (or family analogue)
    hbm_bytes_fused: float = 0.0  # fusion-adjusted traffic estimate

    @property
    def t_compute(self) -> float:
        # cost_analysis() reports the per-device partitioned module
        return self.flops / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        """Fusion-adjusted memory term (headline; raw term kept alongside —
        see fused_bytes docstring for why raw CPU numbers overcount)."""
        b = self.hbm_bytes_fused or self.hbm_bytes
        return b / HBM_BW

    @property
    def t_memory_raw(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        # per-device wire bytes: HLO shapes are already per-partition under
        # SPMD, so bytes / ICI_BW is per-chip link time
        return sum(self.coll_bytes.values()) / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def roofline_fraction(self) -> float:
        """useful-compute-time / dominant-term time (1.0 = at roofline)."""
        t_useful = self.model_flops / (self.chips * PEAK_FLOPS_BF16)
        t_dom = max(self.t_compute, self.t_memory, self.t_collective)
        return t_useful / t_dom if t_dom > 0 else 0.0

    @property
    def flops_efficiency(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs x chips) — useful fraction of compute."""
        tot = self.flops * self.chips
        return self.model_flops / tot if tot else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips, "flops": self.flops,
            "hbm_bytes": self.hbm_bytes, "coll_bytes": self.coll_bytes,
            "per_device_mem": self.per_device_mem,
            "model_flops": self.model_flops,
            "hbm_bytes_fused": self.hbm_bytes_fused,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_memory_raw": self.t_memory_raw,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck,
            "roofline_fraction": self.roofline_fraction,
            "flops_efficiency": self.flops_efficiency,
        }


def model_flops_for(arch_id: str, shape_name: str) -> float:
    """Useful FLOPs per step: 6·N·D for LM training (N = active params),
    2·N·D for inference; family analogues elsewhere."""
    from repro.configs.base import get_config
    arch = get_config(arch_id)
    shape = arch.shape(shape_name)
    if arch.family == "lm":
        n_active = arch.model.n_active_params()
        if shape.kind == "train":
            tokens = shape["global_batch"] * shape["seq_len"]
            return 6.0 * n_active * tokens
        if shape.kind == "prefill":
            tokens = shape["global_batch"] * shape["seq_len"]
            return 2.0 * n_active * tokens
        tokens = shape["global_batch"]            # one token per stream
        return 2.0 * n_active * tokens
    if arch.family == "encoder":
        n = arch.model.n_params()
        tokens = shape["global_batch"] * min(shape["seq_len"],
                                             arch.model.max_len)
        mult = {"train": 6.0, "serve": 2.0}[shape.kind]
        if shape.name.startswith("dpo"):
            mult = 6.0 * 2 + 2.0 * 2          # 2 policy fwd+bwd, 2 ref fwd
        return mult * n * tokens
    if arch.family == "vit_parser":
        cfg = arch.model
        n_enc = cfg.enc_layers * (4 * cfg.enc_d_model ** 2
                                  + 2 * cfg.enc_d_model * cfg.enc_d_ff)
        n_dec = cfg.dec_layers * (8 * cfg.dec_d_model ** 2
                                  + 2 * cfg.dec_d_model * cfg.dec_d_ff)
        b = shape["global_batch"]
        t = shape.dims.get("dec_len", 0)
        mult = 6.0 if shape.kind == "train" else 2.0
        enc_toks = b * cfg.n_patches
        dec_toks = b * (t if shape.kind == "train" else 1)
        if shape.name == "parse_encode":
            dec_toks = 0
        if shape.name == "parse_decode":
            enc_toks = 0              # decode cell runs the decoder only
        return mult * (n_enc * enc_toks + n_dec * dec_toks)
    if arch.family == "gnn":
        from repro.launch.specs import _gnn_dims
        cfg = arch.model
        n, e = _gnn_dims(shape)
        n_trunc = cfg.n_coeff
        c = cfg.d_hidden
        so2 = sum(2 * ((cfg.l_max - m + 1) * 2 * c) * ((cfg.l_max - m + 1) * c)
                  * (1 if m == 0 else 2) for m in range(cfg.m_max + 1))
        wig = sum((2 * l + 1) ** 2 * 2 for l in range(cfg.l_max + 1))
        per_edge = so2 + 2 * wig * 2 * c          # conv + rotate in/out
        per_node = 2 * (cfg.l_max + 1) ** 2 * c * c * 2 * 2  # FFN
        fwd = cfg.n_layers * (e * per_edge + n * per_node)
        return 3.0 * fwd                           # fwd + bwd
    if arch.family == "recsys":
        cfg = arch.model
        if shape.name == "retrieval_cand":
            return 2.0 * shape["n_candidates"] * cfg.embed_dim
        b = shape["batch"]
        dims_chain = []
        if cfg.kind == "dlrm":
            f = cfg.n_sparse + 1
            d_int = f * (f - 1) // 2 + cfg.bot_mlp[-1]
            dims_chain = [(cfg.n_dense,) + cfg.bot_mlp,
                          (d_int,) + cfg.top_mlp]
            inter = f * f * cfg.embed_dim
        elif cfg.kind == "deepfm":
            dims_chain = [(cfg.n_sparse * cfg.embed_dim,) + cfg.mlp + (1,)]
            inter = cfg.n_sparse * cfg.embed_dim * 2
        elif cfg.kind == "autoint":
            inter = cfg.n_attn_layers * (
                3 * cfg.n_sparse * cfg.embed_dim * cfg.d_attn
                + 2 * cfg.n_sparse ** 2 * cfg.d_attn)
            dims_chain = [(cfg.n_sparse * cfg.d_attn, 1)]
        else:  # dien
            inter = cfg.seq_len * 6 * (2 * cfg.embed_dim + cfg.gru_dim) \
                * cfg.gru_dim * 2
            dims_chain = [(cfg.gru_dim + 2 * cfg.embed_dim,) + cfg.mlp + (1,)]
        mlp_fl = sum(2 * a * bb for chain in dims_chain
                     for a, bb in zip(chain[:-1], chain[1:]))
        lookup = cfg.n_sparse * cfg.embed_dim
        mult = 3.0 if shape.kind == "train" else 1.0
        return mult * b * 2 * (mlp_fl / 2 + inter + lookup)
    return 0.0


def summarize(records: list[dict]) -> str:
    """Markdown table for EXPERIMENTS.md §Roofline."""
    hdr = ("| arch | shape | mesh | chips | t_comp (ms) | t_mem (ms) | "
           "t_coll (ms) | bottleneck | HLO GFLOPs | model/HLO | roofline frac |")
    sep = "|" + "---|" * 11
    rows = [hdr, sep]
    for r in records:
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['chips']} | "
            f"{r['t_compute']*1e3:.2f} | {r['t_memory']*1e3:.2f} | "
            f"{r['t_collective']*1e3:.2f} | {r['bottleneck']} | "
            f"{r['flops']/1e9:.0f} | {r['flops_efficiency']*100:.0f}% | "
            f"{r['roofline_fraction']*100:.1f}% |")
    return "\n".join(rows)
