import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production meshes, prove memory fit, and extract roofline inputs.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun \
        [--arch qwen3-1.7b] [--shape train_4k] [--multi-pod] [--both] \
        [--no-costing] [--out results/dryrun]

Two compiles per cell:
1. PRODUCTION compile — scan-over-layers, exactly what would ship; gives
   memory_analysis (fits-HBM proof) and the collective schedule.
2. COSTING compiles — XLA's HloCostAnalysis counts while-loop bodies ONCE,
   so scanned programs under-report FLOPs/bytes by the trip count. We
   compile fully-unrolled 1-layer and 2-layer variants (layers identical
   => exact linear extrapolation): corrected = c1*(2-L) + c2*(L-1).
   ViT (enc+dec scans) uses a 3-point plane fit; DIEN extrapolates the
   GRU trip count. Recorded FLOPs/bytes/collective-bytes are corrected;
   memory numbers always come from the production compile.

This module MUST be the process entry point — the XLA_FLAGS line above
runs before jax initializes."""

import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro.configs import get_config, list_archs
from repro.distributed.meshrules import AxisRules, use_rules
from repro.launch import roofline as rl
from repro.launch.mesh import HBM_BYTES, make_production_mesh
from repro.launch.specs import all_cells, build_cell


def _compile(arch_id, shape_name, mesh, rules, model_override=None):
    with mesh:
        with use_rules(rules):
            cell = build_cell(arch_id, shape_name, rules=rules,
                              abstract=True, model_override=model_override)
            jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                             donate_argnums=cell.donate_argnums)
            lowered = jitted.lower(*cell.args)
            compiled = lowered.compile()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = rl.collective_bytes(hlo)
    mem = compiled.memory_analysis()
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "fused_bytes": float(rl.fused_bytes(hlo)),
        "coll": coll,
        "mem_per_dev": int(mem.output_size_in_bytes + mem.temp_size_in_bytes
                           + mem.argument_size_in_bytes
                           - mem.alias_size_in_bytes),
    }


def costing_plan(arch, shape_name) -> list[tuple[object, float]] | None:
    """[(model_cfg, coefficient)] with corrected = sum coef_i * cost_i."""
    m = arch.model
    r = dataclasses.replace
    if arch.family == "lm":
        L = m.n_layers
        # long-seq cells: coarsen flash chunks for the unrolled costing
        # variants (pair count ~ (S/cq)*(S/ck)/2 would explode compile
        # time at 32k); the diagonal-tile overcount this introduces is
        # ~cq/S ~ 6-12% on the attention term (documented in EXPERIMENTS)
        shape = arch.shape(shape_name)
        big = shape.dims.get("seq_len", 0) >= 16384 and \
            shape.kind in ("train", "prefill")
        extra = (dict(q_chunk=2048, kv_chunk=4096) if big else {})
        mk = lambda n: r(m, n_layers=n, scan_layers=False,
                         unroll_pairs=True, **extra)
        return [(mk(1), 2.0 - L), (mk(2), L - 1.0)]
    if arch.family == "encoder":
        L = m.n_layers
        mk = lambda n: r(m, n_layers=n, scan_layers=False)
        return [(mk(1), 2.0 - L), (mk(2), L - 1.0)]
    if arch.family == "gnn":
        L = m.n_layers
        mk = lambda n: r(m, n_layers=n, scan_layers=False)
        return [(mk(1), 2.0 - L), (mk(2), L - 1.0)]
    if arch.family == "vit_parser":
        Le, Ld = m.enc_layers, m.dec_layers
        mk = lambda e, d: r(m, enc_layers=e, dec_layers=d, scan_layers=False)
        if shape_name == "parse_decode":      # encoder not in this cell
            return [(mk(Le, 1), 2.0 - Ld), (mk(Le, 2), Ld - 1.0)]
        return [(mk(1, 1), 3.0 - Le - Ld), (mk(2, 1), Le - 1.0),
                (mk(1, 2), Ld - 1.0)]
    if arch.family == "recsys" and m.kind == "dien":
        T = m.seq_len
        mk = lambda t: r(m, seq_len=t, unroll_gru=True)
        return [(mk(1), 2.0 - T), (mk(2), T - 1.0)]
    return None                                # exact as compiled


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             costing: bool = True, verbose: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = AxisRules(mesh)
    chips = int(len(mesh.devices.ravel()))
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    arch = get_config(arch_id)
    t0 = time.time()
    prod = _compile(arch_id, shape_name, mesh, rules)
    t_prod = time.time() - t0

    flops, hbytes, coll = prod["flops"], prod["bytes"], dict(prod["coll"])
    fbytes = prod["fused_bytes"]
    corrected = False
    plan = costing_plan(arch, shape_name) if costing else None
    if plan is not None:
        flops = hbytes = fbytes = 0.0
        coll = {k: 0.0 for k in prod["coll"]}
        for model_cfg, coef in plan:
            c = _compile(arch_id, shape_name, mesh, rules,
                         model_override=model_cfg)
            flops += coef * c["flops"]
            hbytes += coef * c["bytes"]
            fbytes += coef * c["fused_bytes"]
            for k in coll:
                coll[k] += coef * c["coll"].get(k, 0)
        coll = {k: max(v, 0.0) for k, v in coll.items()}
        flops, hbytes = max(flops, 0.0), max(hbytes, 0.0)
        fbytes = max(fbytes, 0.0)
        corrected = True

    rec = rl.Roofline(
        arch=arch_id, shape=shape_name, mesh=mesh_name, chips=chips,
        flops=flops, hbm_bytes=hbytes, coll_bytes=coll,
        per_device_mem=prod["mem_per_dev"],
        model_flops=rl.model_flops_for(arch_id, shape_name),
        hbm_bytes_fused=fbytes,
    ).to_dict()
    rec["compile_s"] = round(time.time() - t0, 1)
    rec["prod_compile_s"] = round(t_prod, 1)
    rec["fits_hbm"] = prod["mem_per_dev"] <= HBM_BYTES
    rec["mem_gb"] = round(prod["mem_per_dev"] / 2 ** 30, 2)
    rec["scan_corrected"] = corrected
    if verbose:
        print(f"[dryrun] {arch_id}/{shape_name} mesh={mesh_name} "
              f"mem/dev={rec['mem_gb']}GB fits={rec['fits_hbm']} "
              f"GFLOPs/dev={rec['flops']/1e9:.1f} "
              f"bottleneck={rec['bottleneck']} "
              f"frac={rec['roofline_fraction']*100:.1f}% "
              f"({rec['compile_s']}s)", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both", action="store_true")
    ap.add_argument("--no-costing", action="store_true",
                    help="skip the unrolled costing compiles")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    cells = all_cells()
    if args.arch:
        cells = [(a, s) for a, s in cells if a == args.arch]
    if args.shape:
        cells = [(a, s) for a, s in cells if s == args.shape]
    meshes = [False, True] if args.both else [args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for multi_pod in meshes:
        # roofline costing is single-pod only (the table's scope);
        # the multi-pod pass proves the pod axis shards
        costing = (not args.no_costing) and not multi_pod
        for arch_id, shape_name in cells:
            tag = f"{arch_id}__{shape_name}__{'pod2' if multi_pod else 'pod1'}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print(f"[dryrun] skip cached {tag}", flush=True)
                continue
            try:
                rec = run_cell(arch_id, shape_name, multi_pod,
                               costing=costing)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
            except Exception as e:  # noqa: BLE001
                failures.append((tag, repr(e)))
                print(f"[dryrun] FAIL {tag}: {e}", flush=True)
                traceback.print_exc()
    print(f"\n[dryrun] done; {len(failures)} failures", flush=True)
    for t, e in failures:
        print("  FAIL", t, e[:200])
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
