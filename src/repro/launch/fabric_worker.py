"""Standalone fabric worker: dial a coordinator, get admitted, serve
batches (core/fabric — the cross-machine side of
``ExecutorConfig.runtime="fabric"``).

The worker opens one TCP connection to the coordinator
(``serve.py --connect HOST:PORT``), sends a ``Hello`` — with its spec
fingerprint when it was built from a local spec, or ``None`` to
request the coordinator's — and waits for ``Admit`` (assigned node id
+ the portable ``WorkerSpec``, whose coordinator-stamped fingerprint
``worker_main._build_engine`` verifies after deserialization) or
``Reject`` (an actionable mismatch message; the process exits
non-zero).

After admission the loop mirrors ``worker_main.worker_loop`` over the
socket instead of multiprocessing queues: the same
``PrepareTask``/``CompleteTask`` handling through
``worker_main._run_task``, a heartbeat daemon thread on the spec's
interval, the same deterministic fault hooks (``FaultInjection``:
hard ``os._exit`` crash, mute/flap windows), and a framed ``Shutdown``
(or EOF) to leave. Payloads always ride inline — no shared memory
across machines.

``spawn_loopback`` launches this worker as a local spawn-context
process dialing ``127.0.0.1`` — how the fabric pool provisions its own
fleet in tests, CI, and single-host campaigns.
"""
from __future__ import annotations

import multiprocessing as mp
import os
import socket
import sys
import threading
import time
import traceback

_CONNECT_RETRIES = 20
_CONNECT_RETRY_S = 0.25


def _dial(host: str, port: int) -> socket.socket:
    """Connect with a short retry loop (a loopback worker can outrace
    the coordinator's listener by a scheduler tick)."""
    last: Exception | None = None
    for _ in range(_CONNECT_RETRIES):
        try:
            sock = socket.create_connection((host, port), timeout=30.0)
            try:
                sock.setsockopt(socket.IPPROTO_TCP,
                                socket.TCP_NODELAY, 1)
            except OSError:
                pass
            return sock
        except OSError as e:
            last = e
            time.sleep(_CONNECT_RETRY_S)
    raise ConnectionError(f"cannot reach fabric coordinator at "
                          f"{host}:{port}: {last}")


def _send(sock: socket.socket, lock: threading.Lock, obj) -> None:
    from repro.core.fabric import encode_frame

    data = encode_frame(obj)
    with lock:
        sock.sendall(data)


def _frames(sock: socket.socket):
    """Yield every framed message from the blocking socket; returns on
    EOF."""
    from repro.core.fabric import FrameDecoder

    dec = FrameDecoder()
    while True:
        try:
            data = sock.recv(1 << 16)
        except OSError:
            return
        if not data:
            return
        yield from dec.feed(data)


def run_worker(addr: tuple[str, int], *, fingerprint: dict | None = None,
               spec=None) -> None:
    """Dial ``addr``, join the fleet, serve batches until Shutdown/EOF.

    ``fingerprint`` (or one computed from a locally supplied ``spec``)
    is presented at admission; with both None the coordinator's spec is
    trusted and shipped back in the Admit reply."""
    from repro.core import obs
    from repro.core.fabric import Admit, Hello, Reject, Shutdown
    from repro.core.workers import BatchDone, Heartbeat
    from repro.launch.worker_main import _build_engine, _run_task

    if spec is not None and fingerprint is None:
        from repro.core.specs import spec_fingerprint
        fingerprint = spec_fingerprint(spec)

    host, port = addr
    sock = _dial(host, port)
    lock = threading.Lock()
    _send(sock, lock, Hello(fingerprint=fingerprint,
                            host=socket.gethostname(), pid=os.getpid()))
    frames = _frames(sock)
    sock.settimeout(60.0)                # bounded admission wait
    reply = next(frames, None)
    if isinstance(reply, Reject):
        raise SystemExit(f"fabric admission rejected: {reply.reason}")
    if not isinstance(reply, Admit):
        raise SystemExit(f"fabric coordinator hung up before admission "
                         f"(got {reply!r})")
    sock.settimeout(None)
    wid = reply.node_id
    if spec is None:
        spec = reply.spec
    current: list[int | None] = [None]
    muted = [False]
    stop = threading.Event()
    rec = obs.configure(enabled=getattr(spec, "obs_enabled", False),
                        cap=getattr(spec, "obs_span_cap", 8192),
                        node=wid)
    try:
        # _build_engine verifies the coordinator-stamped fingerprint
        # against a recomputation from the deserialized spec
        eng, cache = _build_engine(spec)
    except BaseException:
        try:
            _send(sock, lock, BatchDone(task_id=-1, worker=wid,
                                        batch_key=-1,
                                        error=traceback.format_exc()))
        except OSError:
            pass
        return

    def _heartbeat() -> Heartbeat:
        # queue_depth stays -1: tasks are consumed straight off the
        # socket, so there is no reportable local backlog. sent_mono is
        # the same-host diagnostic only — the fabric coordinator
        # ignores it (per-machine monotonic epochs are not comparable).
        return Heartbeat(
            wid, time.time(), current[0],
            sent_mono=time.monotonic(), queue_depth=-1,
            spans=rec.drain(128) if rec.enabled else None,
            metrics=obs.metrics().snapshot() if rec.enabled else None)

    def beat():
        while not stop.wait(spec.heartbeat_interval_s):
            if not muted[0]:
                try:
                    _send(sock, lock, _heartbeat())
                except OSError:
                    return

    threading.Thread(target=beat, daemon=True).start()
    _send(sock, lock, _heartbeat())                 # ready signal

    fault = spec.fault
    crash_after = dict(fault.crash_after) if fault else {}
    mute_after = dict(fault.mute_after) if fault else {}
    unmute_after = dict(getattr(fault, "unmute_after", ()) or ()) \
        if fault else {}
    n_done = 0
    for task in frames:
        if isinstance(task, Shutdown):
            break
        if wid in crash_after and n_done >= crash_after[wid]:
            # injected crash: hard exit with the batch in flight — the
            # coordinator sees the dead connection and re-issues
            os._exit(3)
        current[0] = task.task_id
        try:
            done = _run_task(eng, wid, task)
        except BaseException:
            done = BatchDone(task.task_id, wid, task.batch_key,
                             error=traceback.format_exc())
        done.attempt = getattr(task, "attempt", 0)
        if done.error is None:
            obs.metrics().observe("worker.task_wall_s", done.wall_s)
        if rec.enabled:
            obs.metrics().gauge(f"obs.dropped.n{wid}", rec.dropped)
            done.spans = rec.drain(512)
            done.metrics = obs.metrics().snapshot()
        if muted[0] and fault is not None and fault.mute_slowdown_s > 0:
            time.sleep(fault.mute_slowdown_s)
        try:
            _send(sock, lock, done)
        except OSError:
            break
        current[0] = None
        n_done += 1
        if wid in mute_after and n_done >= mute_after[wid]:
            muted[0] = not (wid in unmute_after
                            and n_done >= unmute_after[wid])
    stop.set()
    if cache is not None:
        cache.flush()
    try:
        sock.close()
    except OSError:
        pass


def _loopback_main(host: str, port: int,
                   fingerprint: dict | None) -> None:
    try:
        run_worker((host, port), fingerprint=fingerprint)
    except SystemExit as e:
        # rejection is expected for the mismatched-fingerprint workers:
        # surface the actionable reason and exit non-zero
        if e.code and not isinstance(e.code, int):
            print(e.code, file=sys.stderr)
            raise SystemExit(4)
        raise


def spawn_loopback(addr: tuple[str, int], *,
                   fingerprint: dict | None = None) -> mp.process.BaseProcess:
    """Launch one fabric worker as a local spawn-context process
    dialing ``addr`` (a fresh interpreter, like the process runtime's
    children — no inherited JAX state)."""
    host, port = addr
    ctx = mp.get_context("spawn")
    p = ctx.Process(target=_loopback_main, args=(host, port, fingerprint),
                    daemon=True, name="adaparse-fabric-worker")
    p.start()
    return p
