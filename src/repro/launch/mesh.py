"""Production meshes.

Single pod:  (16, 16)      axes ("data", "model")   = 256 v5e chips
Multi-pod:   (2, 16, 16)   axes ("pod", "data", "model") = 512 chips

``make_production_mesh`` is a FUNCTION (never module-level) so importing
this module does not touch jax device state. The ``pod`` axis is
data-parallel by default (the paper's workload is document-parallel);
``pipeline=True`` retags it for 1F1B pipelining (distributed/pipeline.py).

``make_mesh`` is the version-compat entry point: newer jax releases grew
``jax.sharding.AxisType`` + an ``axis_types=`` kwarg on ``jax.make_mesh``
(explicit-sharding meshes), older ones have neither. Everything in the
repo (and the tests) builds meshes through this shim so both work.
"""
from __future__ import annotations

import inspect

import jax


def axis_types_kwargs(n_axes: int) -> dict:
    """``{"axis_types": (Auto,) * n}`` when the installed jax supports it,
    else ``{}`` (pre-AxisType releases default to auto sharding anyway)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    try:
        params = inspect.signature(jax.make_mesh).parameters
    except (TypeError, ValueError):          # pragma: no cover
        return {}
    if "axis_types" not in params:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape, axes):
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **axis_types_kwargs(len(axes)))


# v5e hardware constants (roofline)
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link (~per chip per dir)
HBM_BYTES = 16 * 1024 ** 3        # 16 GiB per chip
