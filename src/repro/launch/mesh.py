"""Production meshes.

Single pod:  (16, 16)      axes ("data", "model")   = 256 v5e chips
Multi-pod:   (2, 16, 16)   axes ("pod", "data", "model") = 512 chips

``make_production_mesh`` is a FUNCTION (never module-level) so importing
this module does not touch jax device state. The ``pod`` axis is
data-parallel by default (the paper's workload is document-parallel);
``pipeline=True`` retags it for 1F1B pipelining (distributed/pipeline.py).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape, axes):
    return jax.make_mesh(
        tuple(shape), tuple(axes),
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


# v5e hardware constants (roofline)
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link (~per chip per dir)
HBM_BYTES = 16 * 1024 ** 3        # 16 GiB per chip
