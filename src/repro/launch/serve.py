"""AdaParse parsing-campaign driver (the paper's end-to-end system).

    PYTHONPATH=src python -m repro.launch.serve --docs 1000 --alpha 0.05 \
        [--variant ft|llm] [--nodes 1]

Builds the corpus, trains the CLS-I/II linear stages (and, for the LLM
variant, SFT+DPO post-trains a reduced SciBERT router), then runs the
engine over the test split and reports Table-1-style metrics + throughput.
With ``--nodes N > 1`` the corpus is executed by the multi-node
``CampaignExecutor`` (real engine per node over batch shards);
batch-keyed rng streams make the record set identical to ``--nodes 1``.

Heterogeneous pools: ``--pools cpu:3,gpu:1`` partitions the fleet into
device pools (cheap-channel ingest on the CPU pool, expensive re-parse
forwarded to the GPU pool — see core/campaign). ``--prefetch-depth N``
overlaps host channel application with routing via
data/pipeline.Prefetcher, and ``--warm-cache`` runs the campaign twice
against one result store to demonstrate cached replay (second pass
reports the hit counters; records are identical). ``--cache-dir DIR``
persists results in a content-addressed ``DiskResultStore`` so a warm
replay also works across process restarts; ``--tuning-dir DIR``
persists kernel autotune winners in a flock-shared ``TuningStore``
(kernels/tuning_store) that the whole worker fleet — and any later
restart — consults instead of re-sweeping; ``--adaptive-rounds N``
dispatches through the round-based ``CampaignController`` that
autotunes the node budget weights from observed throughput.

Online α retuning (core/quality): ``--quality-probe-rate R`` samples a
deterministic batch-keyed fraction of completed batches and scores
them per parser with the batched jitted scorers; ``--alpha-bounds
LO:HI`` then lets the controller move the campaign α inside those
operator bounds toward ``--quality-target`` (at most ``--alpha-step``
per round, at round boundaries only). Requires ``--adaptive-rounds``
— the retune loop lives in the controller.

Worker runtime (core/workers): ``--workers N`` runs the campaign on N
**real OS worker processes** instead of the in-process simulated
fleet — each worker builds its own engine from a serialized spec,
work travels over multiprocessing queues, and stragglers are detected
by real heartbeat deadlines (``--heartbeat-timeout S``: a worker
silent that long has its in-flight batches re-issued to a pool peer;
a crashed worker's work re-routes the same way). Composes with
``--pools`` (the spec must name exactly N nodes), ``--prefetch-depth``
(the per-worker in-flight window), ``--cache-dir`` (workers share the
multi-process-safe disk store), and ``--adaptive-rounds``; stateless
batch keys keep the N-process record set identical to ``--nodes 1``.
``--transport shm|pickle`` picks the batch-payload transport for the
worker fleet: ``shm`` (the default) moves document arrays and parse
records through zero-copy ``multiprocessing.shared_memory`` arena
slots (core/shm) with the queues carrying control-plane messages only,
and degrades to pickled payloads with a warning when ``/dev/shm`` is
unavailable; ``pickle`` forces the original queue-serialized payloads.

Cross-machine fabric (core/fabric): ``--fabric-workers N`` runs the
campaign on N fabric workers — the same worker protocol as
``--workers`` but carried over length-prefixed TCP streams, so the
fleet can span machines. Without ``--coordinator`` the driver spawns
its own N loopback workers (a single-host drop-in for ``--workers``);
with ``--coordinator HOST:PORT`` it binds the fabric listener there
and waits for N standalone workers to dial in from anywhere with
``serve.py --connect HOST:PORT``. Membership is elastic: a joining
worker is admitted after a spec-fingerprint check (mismatch gets an
actionable rejection naming the differing field), and a leaving or
crashed worker's in-flight and queued batches re-issue to the live
fleet — stateless batch keys keep the record set byte-identical to
``--nodes 1`` through any join/leave schedule.

Scenario lab (core/scenarios): ``--scenario NAME`` runs one named,
fully declarative stress scenario (crash storms, wedged-straggler
flaps, bursty arrivals, bimodal retuning, shared-store warm replay,
slowdown skew) over its worker runtime, asserts byte-identical records
against the scenario's single-node reference, and reports its goodput
/ re-issue / dedup / cache counters; ``--scenario list`` prints the
registry. The fleet shape and fault schedule live in the spec, so
campaign-shape flags conflict with ``--scenario``.

Observability (core/obs): ``--trace-dir DIR`` turns the tracing plane
on and writes the run's span log (``spans.jsonl``), a Chrome
``trace_event`` timeline (``trace.json``, one lane per worker,
stage-colored), and folded metrics; ``--metrics-out FILE`` exports the
fleet-folded counters/gauges/latency-histograms as Prometheus text;
``--status-interval S`` prints a live one-line fleet status to stderr
while a worker fleet drains. All three default off, and with them off
the recorder is a noop — the hot path pays nothing.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core import features as F
from repro.core import metrics as M
from repro.core import parsers as P
from repro.core.backends import DiskResultStore, ResultCache
from repro.core.campaign import (CampaignController, CampaignExecutor,
                                 ControllerConfig, ExecutorConfig)
from repro.core.engine import AdaParseEngine, EngineConfig
from repro.core.quality import QualityProbeConfig
from repro.core.router import (AdaParseRouter, LinearStage, make_cls1_labels,
                               make_cls2_labels)
from repro.data.synthetic import CorpusConfig, generate_corpus


def bleu_matrix(docs, ccfg, rng, parsers=P.REGRESSION_PARSERS):
    """(n, m) BLEU of every parser on every doc — one batched channel
    application per parser (the per-doc loop only scores)."""
    mat = np.zeros((len(docs), len(parsers)))
    cheap_pages = []
    refs = [d.full_text() for d in docs]
    for j, name in enumerate(parsers):
        outs = P.run_parser_batch(name, docs, ccfg, rng)
        if name == P.CHEAP_PARSER:
            cheap_pages = outs
        for i, out in enumerate(outs):
            hyp = (np.concatenate(out) if sum(map(len, out))
                   else np.zeros(0, np.int32))
            mat[i, j] = M.bleu(refs[i], hyp)
    return mat, cheap_pages


def fit_cls1_stage(train_docs, ccfg, rng, max_len=None):
    """Shared CLS-I training pipeline for both router variants: score
    the regression parsers, derive the fast features — and, when
    ``max_len`` is given, the first-page encoder inputs — through the
    fused prepare-stage entry (``F.prepare_routing_inputs``, the same
    call site the engine dispatches through), and fit the stage.

    Returns (bleu matrix, cheap-parser pages, fitted stage, toks, mask);
    toks/mask are None without ``max_len``."""
    mat, cheap_pages = bleu_matrix(train_docs, ccfg, rng)
    fast, toks, mask = F.prepare_routing_inputs(cheap_pages, ccfg,
                                                max_len=max_len)
    cls1 = LinearStage.fit(np.asarray(fast), make_cls1_labels(mat[:, 0]))
    return mat, cheap_pages, cls1, toks, mask


def build_ft_router(train_docs, ccfg, rng) -> AdaParseRouter:
    mat, _, cls1, _, _ = fit_cls1_stage(train_docs, ccfg, rng)
    meta = np.stack([d.metadata_features() for d in train_docs])
    cls2 = LinearStage.fit(meta, make_cls2_labels(mat, 0))
    return AdaParseRouter("ft", cls1, cls2)


def build_llm_router(train_docs, ccfg, rng, *, sft_steps=150,
                     dpo_steps=60, seed=0) -> AdaParseRouter:
    from repro.common import unwrap
    from repro.configs import get_config
    from repro.core import dpo as dpo_lib
    from repro.data.synthetic import preference_utility
    from repro.models import encoder as enc_lib

    enc_cfg = get_config("adaparse-router").reduced().model
    mat, _, cls1, toks, masks = fit_cls1_stage(train_docs, ccfg, rng,
                                               max_len=enc_cfg.max_len)
    reg = {"tokens": np.asarray(toks), "mask": np.asarray(masks),
           "targets": mat.astype(np.float32)}
    # preference pairs from the oracle (stands in for the 23-expert study)
    pos_t, pos_m, neg_t, neg_m = [], [], [], []
    for i, d in enumerate(train_docs[:64]):
        outs = {n: P.run_parser(n, d, ccfg, rng)
                for n in (P.CHEAP_PARSER, P.EXPENSIVE_PARSER)}
        ref = d.full_text()
        utils = {n: preference_utility(
            ref, np.concatenate(o) if sum(map(len, o)) else np.zeros(0),
            rng) for n, o in outs.items()}
        better = max(utils, key=utils.get)
        worse = min(utils, key=utils.get)
        tp, mp = F.first_page_tokens(outs[better], enc_cfg.max_len)
        tn, mn = F.first_page_tokens(outs[worse], enc_cfg.max_len)
        pos_t.append(tp); pos_m.append(mp); neg_t.append(tn); neg_m.append(mn)
    pref = {"tok_pos": np.stack(pos_t), "mask_pos": np.stack(pos_m),
            "tok_neg": np.stack(neg_t), "mask_neg": np.stack(neg_m)}
    params = unwrap(enc_lib.init_encoder(enc_cfg, seed))
    params, _ = dpo_lib.three_stage_posttrain(
        params, enc_cfg, reg, pref, sft_steps=sft_steps,
        dpo_steps=dpo_steps, refit_steps=max(sft_steps // 3, 10))
    return AdaParseRouter("llm", cls1, None, enc_cfg=enc_cfg,
                          enc_params=params)


def parse_alpha_bounds(spec: str) -> tuple[float, float]:
    """"0.05:0.4" -> (0.05, 0.4).

    Raises ValueError with an actionable message on malformed specs
    (the CLI surfaces it as an argparse error instead of a traceback
    from deep inside ControllerConfig)."""
    hint = "expected LO:HI with 0 <= LO <= HI <= 1, e.g. '0.05:0.4'"
    lo_s, sep, hi_s = spec.partition(":")
    if not sep:
        raise ValueError(f"--alpha-bounds {spec!r} has no ':'; {hint}")
    try:
        lo, hi = float(lo_s), float(hi_s)
    except ValueError:
        raise ValueError(f"--alpha-bounds {spec!r} is not a pair of "
                         f"floats; {hint}") from None
    if not 0.0 <= lo <= hi <= 1.0:
        raise ValueError(f"--alpha-bounds {spec!r} out of order or out "
                         f"of range; {hint}")
    return lo, hi


def parse_pools(spec: str) -> list[str]:
    """"cpu:3,gpu:1" -> ["cpu", "cpu", "cpu", "gpu"].

    Raises ValueError with an actionable message on malformed specs
    (the CLI surfaces it as an argparse error instead of a traceback
    from deep inside ExecutorConfig)."""
    hint = ("expected DEVICE[:COUNT] entries separated by commas, "
            "e.g. 'cpu:3,gpu:1' or 'cpu,cpu,gpu'")
    pools: list[str] = []
    for part in spec.split(","):
        part = part.strip()
        if not part:
            raise ValueError(f"empty entry in --pools spec {spec!r}; {hint}")
        dev, _, count = part.partition(":")
        if dev not in ("cpu", "gpu"):
            raise ValueError(f"unknown pool device {dev!r} in --pools "
                             f"{spec!r} (choose cpu or gpu); {hint}")
        if count:
            try:
                n = int(count)
            except ValueError:
                raise ValueError(
                    f"pool count {count!r} in --pools {spec!r} is not an "
                    f"integer; {hint}") from None
            if n < 1:
                raise ValueError(f"pool count for {dev!r} in --pools "
                                 f"{spec!r} must be >= 1, got {n}")
        else:
            n = 1
        pools.extend([dev] * n)
    if not pools:
        raise ValueError(f"empty --pools spec {spec!r}; {hint}")
    return pools


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=600)
    ap.add_argument("--alpha", type=float, default=0.05)
    ap.add_argument("--variant", default="ft", choices=["ft", "llm"])
    ap.add_argument("--batch-size", type=int, default=256)
    ap.add_argument("--nodes", type=int, default=1)
    ap.add_argument("--workers", type=int, default=0,
                    help="run the campaign on N real worker processes "
                         "(core/workers spawn runtime) instead of the "
                         "in-process simulated fleet; 0 disables")
    ap.add_argument("--heartbeat-timeout", type=float, default=None,
                    help="seconds of worker silence before its "
                         "in-flight batches re-issue to a pool peer "
                         "(needs --workers; default 30)")
    ap.add_argument("--transport", default=None,
                    help="batch-payload transport for the worker "
                         "processes: shm (zero-copy shared-memory "
                         "arenas, the default; falls back to pickle "
                         "with a warning when /dev/shm is unavailable) "
                         "or pickle (queue-serialized payloads; needs "
                         "--workers)")
    ap.add_argument("--fabric-workers", type=int, default=0,
                    help="run the campaign on N cross-machine fabric "
                         "workers (core/fabric TCP runtime): without "
                         "--coordinator the driver spawns N loopback "
                         "workers itself; with it, the fleet is N "
                         "standalone workers dialing in with --connect. "
                         "0 disables")
    ap.add_argument("--coordinator", default=None, metavar="HOST:PORT",
                    help="bind the fabric coordinator's listener here "
                         "and wait for --fabric-workers standalone "
                         "workers to dial in (instead of spawning "
                         "loopback workers); needs --fabric-workers")
    ap.add_argument("--connect", default=None, metavar="HOST:PORT",
                    help="run as a standalone fabric worker: dial the "
                         "coordinator at HOST:PORT, join its fleet "
                         "(spec-fingerprint admission), serve batches "
                         "until shutdown. Excludes every campaign flag "
                         "— the coordinator ships the worker its spec")
    ap.add_argument("--pools", default=None,
                    help="heterogeneous node pools, e.g. cpu:3,gpu:1 "
                         "(overrides --nodes)")
    ap.add_argument("--prefetch-depth", type=int, default=0,
                    help="overlap host channel prep with routing (>0)")
    ap.add_argument("--warm-cache", action="store_true",
                    help="run the campaign twice against one result store "
                         "and report replay hit counters")
    ap.add_argument("--cache-dir", default=None,
                    help="persist batch results in a content-addressed "
                         "DiskResultStore under this directory (replays "
                         "across process restarts)")
    ap.add_argument("--cache-max-bytes", type=int, default=None,
                    help="LRU byte budget for --cache-dir")
    ap.add_argument("--tuning-dir", default=None,
                    help="persist kernel autotune winners in a "
                         "flock-shared TuningStore under this directory "
                         "(kernels/tuning_store); worker processes share "
                         "one store, so block-size sweeps run once per "
                         "shape across the fleet and a warm restart "
                         "re-sweeps nothing")
    ap.add_argument("--adaptive-rounds", type=int, default=0,
                    help=">0: dispatch through the adaptive "
                         "CampaignController with this many rounds "
                         "(online-autotuned node budget weights)")
    ap.add_argument("--quality-probe-rate", type=float, default=0.0,
                    help="fraction of batches the online quality probe "
                         "scores (deterministic batch-keyed sampling; "
                         "0 disables the probe)")
    ap.add_argument("--alpha-bounds", default=None,
                    help="LO:HI operator bounds for online α retuning, "
                         "e.g. 0.05:0.4 (needs --adaptive-rounds and "
                         "--quality-probe-rate > 0)")
    ap.add_argument("--alpha-step", type=float, default=0.05,
                    help="max per-round α movement for the retuner")
    ap.add_argument("--quality-target", type=float, default=0.45,
                    help="blended probe quality the retuner aims at")
    ap.add_argument("--trace-dir", default=None, metavar="DIR",
                    help="turn the observability plane on and write the "
                         "run's span log (spans.jsonl), Chrome "
                         "trace_event timeline (trace.json, one lane "
                         "per worker), and folded metrics there; "
                         "summarize with repro.launch.obs_report. "
                         "Composes with --scenario")
    ap.add_argument("--metrics-out", default=None, metavar="FILE",
                    help="write the fleet-folded metrics registry "
                         "(counters, gauges, log2-bucket latency "
                         "histograms) as Prometheus text to FILE")
    ap.add_argument("--status-interval", type=float, default=0.0,
                    metavar="S",
                    help="print a live one-line status to stderr every "
                         "S seconds while the worker fleet drains "
                         "(docs/s, alpha, cache hit rate, in-flight, "
                         "re-issues; needs --workers; 0 disables)")
    ap.add_argument("--scenario", default=None, metavar="NAME",
                    help="run one named stress scenario from the "
                         "scenario lab (core/scenarios) and report its "
                         "counters; 'list' prints the registry. The "
                         "fleet shape, fault schedule, and retune "
                         "settings live in the spec, so campaign-shape "
                         "flags conflict with this one")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.connect is not None:
        # standalone fabric worker: everything about the campaign —
        # corpus, router, engine config — arrives from the coordinator
        # in the admission reply, so no campaign flag makes sense here
        busy = [flag for flag, changed in (
            ("--scenario", args.scenario is not None),
            ("--workers", args.workers != 0),
            ("--fabric-workers", args.fabric_workers != 0),
            ("--coordinator", args.coordinator is not None),
            ("--nodes", args.nodes != 1),
        ) if changed]
        if busy:
            ap.error(f"--connect runs this process as a standalone "
                     f"fabric worker (the coordinator owns the whole "
                     f"campaign shape); drop {', '.join(busy)}")
        from repro.core.fabric import parse_addr
        from repro.launch.fabric_worker import run_worker
        try:
            addr = parse_addr(args.connect)
        except ValueError as e:
            ap.error(str(e))
        run_worker(addr)
        return None

    if args.scenario:
        from repro.core.scenarios import (SCENARIOS, get_scenario,
                                          run_scenario)
        if args.scenario == "list":
            for name, spec in SCENARIOS.items():
                print(f"{name:24s} [{spec.runtime}] {spec.description}")
            return None
        conflicts = [flag for flag, changed in (
            ("--nodes", args.nodes != 1),
            ("--workers", args.workers != 0),
            ("--fabric-workers", args.fabric_workers != 0),
            ("--coordinator", args.coordinator is not None),
            ("--pools", args.pools is not None),
            ("--adaptive-rounds", args.adaptive_rounds != 0),
            ("--quality-probe-rate", args.quality_probe_rate != 0.0),
            ("--alpha-bounds", args.alpha_bounds is not None),
            ("--warm-cache", args.warm_cache),
            ("--cache-dir", args.cache_dir is not None),
            ("--tuning-dir", args.tuning_dir is not None),
            ("--heartbeat-timeout", args.heartbeat_timeout is not None),
            ("--transport", args.transport is not None),
            ("--metrics-out", args.metrics_out is not None),
            ("--status-interval", args.status_interval != 0.0),
        ) if changed]
        if conflicts:
            ap.error(f"--scenario {args.scenario} is fully declarative "
                     f"(fleet topology, fault schedule, and retune "
                     f"settings all live in the scenario spec); drop "
                     f"{', '.join(conflicts)}, or run those campaign "
                     f"flags without --scenario")
        try:
            spec = get_scenario(args.scenario)
        except KeyError as e:
            ap.error(e.args[0])
        res = run_scenario(spec, trace_dir=args.trace_dir)
        print(f"[serve] scenario {res.name} [{res.runtime}] "
              f"nodes={res.n_nodes} docs={res.n_docs} "
              f"records_match={res.records_match} "
              f"goodput={res.goodput_docs_per_s:.1f}docs/s "
              f"reissued={res.reissued} "
              f"dup_dropped={res.duplicates_dropped} "
              f"cache={res.cache_hits}h/{res.cache_misses}m "
              f"warm={res.warm_cache_hits}h/{res.warm_cache_misses}m")
        if res.alpha_trajectory:
            print("[serve]   alpha "
                  + "->".join(f"{a:.2f}" for a in res.alpha_trajectory))
        if args.trace_dir:
            print(f"[serve] trace written to {args.trace_dir}; summarize "
                  f"with: python -m repro.launch.obs_report --trace-dir "
                  f"{args.trace_dir}")
        return res.metrics()

    if args.docs < 3:
        ap.error(f"--docs must be >= 3 (got {args.docs}): the corpus is "
                 f"split 1/3 train, 2/3 test")
    if args.batch_size < 1:
        ap.error(f"--batch-size must be >= 1 (got {args.batch_size})")
    if args.nodes < 1:
        ap.error(f"--nodes must be >= 1 (got {args.nodes})")
    if args.prefetch_depth < 0:
        ap.error(f"--prefetch-depth must be >= 0 (got "
                 f"{args.prefetch_depth}); 0 disables prefetch overlap, "
                 f"N > 0 prefetches N batches ahead")
    if args.adaptive_rounds < 0:
        ap.error(f"--adaptive-rounds must be >= 0 (got "
                 f"{args.adaptive_rounds}); 0 uses the one-shot executor")
    if args.workers < 0:
        ap.error(f"--workers must be >= 0 (got {args.workers}); 0 runs "
                 f"the in-process simulated fleet, N > 0 spawns N real "
                 f"worker processes")
    if args.workers and args.nodes != 1:
        ap.error(f"--workers {args.workers} and --nodes {args.nodes} "
                 f"both set the fleet size; choose one (--workers runs "
                 f"real processes, --nodes simulates in-process)")
    if args.fabric_workers < 0:
        ap.error(f"--fabric-workers must be >= 0 (got "
                 f"{args.fabric_workers}); 0 disables the fabric "
                 f"runtime, N > 0 runs the campaign on N fabric workers")
    if args.fabric_workers and args.workers:
        ap.error(f"--workers {args.workers} and --fabric-workers "
                 f"{args.fabric_workers} both pick a real worker "
                 f"runtime; choose one (--workers spawns local queue-"
                 f"connected processes, --fabric-workers runs the "
                 f"TCP fabric)")
    if args.fabric_workers and args.nodes != 1:
        ap.error(f"--fabric-workers {args.fabric_workers} and --nodes "
                 f"{args.nodes} both set the fleet size; choose one")
    if args.coordinator is not None and not args.fabric_workers:
        ap.error("--coordinator binds the fabric listener and waits "
                 "for standalone workers to dial in; it needs "
                 "--fabric-workers N > 0 to size the fleet")
    if args.coordinator is not None:
        from repro.core.fabric import parse_addr
        try:
            parse_addr(args.coordinator)
        except ValueError as e:
            ap.error(str(e))
    if args.heartbeat_timeout is not None and not (args.workers
                                                   or args.fabric_workers):
        ap.error("--heartbeat-timeout only applies to the process and "
                 "fabric runtimes; add --workers or --fabric-workers "
                 "N > 0")
    if args.transport is not None and args.transport not in ("shm",
                                                             "pickle"):
        ap.error(f"unknown --transport {args.transport!r} (choose shm "
                 f"or pickle); shm moves batch payloads through "
                 f"zero-copy shared-memory arenas, pickle serializes "
                 f"them onto the worker queues")
    if args.transport is not None and not args.workers:
        ap.error(f"--transport {args.transport} only applies to the "
                 f"process runtime (payloads of real worker "
                 f"processes); add --workers N > 0")
    if args.heartbeat_timeout is not None and args.heartbeat_timeout <= 0.5:
        ap.error(f"--heartbeat-timeout must exceed the 0.5 s worker "
                 f"heartbeat interval (got {args.heartbeat_timeout}); a "
                 f"deadline at or below the beat period would re-issue "
                 f"healthy workers' batches")
    if args.status_interval < 0:
        ap.error(f"--status-interval must be >= 0 (got "
                 f"{args.status_interval}); 0 disables the live status "
                 f"line")
    if args.status_interval > 0 and not (args.workers
                                         or args.fabric_workers):
        ap.error("--status-interval only applies to the process and "
                 "fabric runtimes (the live status line is printed "
                 "from the worker-fleet drain loop); add --workers or "
                 "--fabric-workers N > 0")
    if ((args.workers or args.fabric_workers) and args.warm_cache
            and not args.cache_dir):
        ap.error("--warm-cache with a real worker fleet needs "
                 "--cache-dir: an in-memory result store cannot be "
                 "shared across worker processes")
    if args.cache_max_bytes is not None and args.cache_dir is None:
        ap.error("--cache-max-bytes only applies with --cache-dir")
    if args.cache_max_bytes is not None and args.cache_max_bytes < 1:
        ap.error(f"--cache-max-bytes must be >= 1 (got "
                 f"{args.cache_max_bytes})")
    if not 0.0 <= args.quality_probe_rate <= 1.0:
        ap.error(f"--quality-probe-rate must be in [0, 1] (got "
                 f"{args.quality_probe_rate}); it is the fraction of "
                 f"batches the quality probe scores")
    if args.quality_probe_rate > 0.0 and not args.adaptive_rounds:
        ap.error("--quality-probe-rate needs --adaptive-rounds > 0: "
                 "probe scores are collected and reported through the "
                 "adaptive controller's round telemetry")
    if args.alpha_step <= 0.0:
        ap.error(f"--alpha-step must be > 0 (got {args.alpha_step})")
    bounds = None
    if args.alpha_bounds is not None:
        if not args.adaptive_rounds:
            ap.error("--alpha-bounds needs --adaptive-rounds > 0: α "
                     "retuning happens at the controller's round "
                     "boundaries")
        if args.quality_probe_rate <= 0.0:
            ap.error("--alpha-bounds needs --quality-probe-rate > 0: "
                     "without probe samples there is no quality signal "
                     "to retune α from")
        try:
            bounds = parse_alpha_bounds(args.alpha_bounds)
        except ValueError as e:
            ap.error(str(e))
        if not bounds[0] <= args.alpha <= bounds[1]:
            ap.error(f"--alpha {args.alpha} lies outside --alpha-bounds "
                     f"{bounds[0]}:{bounds[1]}; start the campaign "
                     f"inside the operator bounds")
    try:
        pools = parse_pools(args.pools) if args.pools else None
    except ValueError as e:
        ap.error(str(e))
    fleet = args.workers or args.fabric_workers
    if fleet and pools and len(pools) != fleet:
        ap.error(f"a {fleet}-worker fleet with --pools needs the pool "
                 f"spec to name exactly {fleet} nodes, got "
                 f"{len(pools)} ({args.pools}); size the pools to the "
                 f"worker fleet")

    if args.tuning_dir:
        # parent-side store handle: router training and single-node
        # runs consult (and, on kernel paths, populate) the same
        # winners the worker fleet shares via WorkerSpec.tuning_dir
        from repro.kernels import tuning_store
        tuning_store.configure(args.tuning_dir)

    ccfg = CorpusConfig(n_docs=args.docs, seed=args.seed)
    docs = generate_corpus(ccfg)
    n_train = args.docs // 3
    train, test = docs[:n_train], docs[n_train:]
    rng = np.random.RandomState(args.seed + 1)
    router = (build_ft_router(train, ccfg, rng) if args.variant == "ft"
              else build_llm_router(train, ccfg, rng))
    nodes = (args.workers or args.fabric_workers
             or (len(pools) if pools else args.nodes))
    ecfg = EngineConfig(alpha=args.alpha, batch_size=args.batch_size,
                        seed=args.seed, prefetch_depth=args.prefetch_depth)
    eng = AdaParseEngine(ecfg, router, ccfg)
    if args.cache_dir:
        cache = DiskResultStore(args.cache_dir,
                                max_bytes=args.cache_max_bytes)
    elif args.warm_cache:
        cache = ResultCache()
    else:
        cache = None
    obs_on = bool(args.trace_dir or args.metrics_out)
    if (nodes > 1 or pools or args.adaptive_rounds or args.workers
            or args.fabric_workers or cache is not None or obs_on):
        runtime = ("fabric" if args.fabric_workers
                   else "process" if args.workers else "local")
        xcfg = ExecutorConfig(
            n_nodes=nodes, node_pools=pools,
            prefetch_depth=args.prefetch_depth,
            runtime=runtime,
            heartbeat_timeout_s=(args.heartbeat_timeout
                                 if args.heartbeat_timeout is not None
                                 else 30.0),
            transport=args.transport or "shm",
            tuning_dir=args.tuning_dir,
            coordinator=args.coordinator or "127.0.0.1:0",
            # an explicit --coordinator means standalone workers dial
            # in from elsewhere; without it the driver provisions its
            # own loopback fleet
            fabric_spawn=args.coordinator is None,
            obs=obs_on, status_interval_s=args.status_interval)
        if args.adaptive_rounds:
            probe = (QualityProbeConfig(probe_rate=args.quality_probe_rate,
                                        seed=args.seed)
                     if args.quality_probe_rate > 0 else None)
            executor = CampaignController(
                ecfg, xcfg,
                ControllerConfig(rounds=args.adaptive_rounds,
                                 alpha_bounds=bounds,
                                 alpha_step=args.alpha_step,
                                 quality_target=args.quality_target,
                                 probe=probe),
                router, ccfg)
        else:
            executor = CampaignExecutor(ecfg, xcfg, router, ccfg)
        cold = executor.run(test, cache=cache)
        # evaluate() throughput comes from the COLD run's real parse
        # costs (a warm replay charges ~no node-seconds)
        for st in cold.node_stats:
            eng.stats.n_docs += st.n_docs
            eng.stats.n_expensive += st.n_expensive
            eng.stats.node_seconds += st.node_seconds
        pool_desc = ",".join(pools) if pools else f"{nodes}x homogeneous"
        runtime_desc = runtime

        def report(label, xres):
            print(f"[serve] executor[{label}] nodes={nodes} ({pool_desc}) "
                  f"runtime={runtime_desc} "
                  f"prefetch={args.prefetch_depth} "
                  f"wall={xres.wall_s:.1f}s docs/s={xres.docs_per_s:.1f} "
                  f"busy={xres.node_busy_frac:.2f} reissued={xres.reissued} "
                  f"cache={xres.cache_hits}h/{xres.cache_misses}m")
            if getattr(xres, "weight_history", None):
                w = ["/".join(f"{x:.2f}" for x in ws)
                     for ws in (xres.weight_history[0],
                                xres.weight_history[-1])]
                print(f"[serve]   adaptive rounds={xres.rounds} "
                      f"weights {w[0]} -> {w[1]}")
                if args.quality_probe_rate > 0 and xres.telemetry:
                    traj = "->".join(f"{t.alpha:.2f}"
                                     for t in xres.telemetry)
                    n_probe = sum(t.n_probe_docs for t in xres.telemetry)
                    print(f"[serve]   quality probe docs={n_probe} "
                          f"alpha {traj} "
                          f"(bounds={args.alpha_bounds or 'off'})")

        report("cold", cold)
        recs = cold.records
        runs = [cold]
        if args.warm_cache:
            warm = executor.run(test, cache=cache)
            report("warm", warm)
            recs = warm.records
            runs.append(warm)
        if obs_on:
            from repro.core import obs
            spans = [s for r in runs for s in (r.spans or [])]
            folded = obs.fold([r.obs_metrics or {} for r in runs])
            if args.trace_dir:
                path = obs.TraceWriter(args.trace_dir).write(spans)
                print(f"[serve] trace written to {args.trace_dir} "
                      f"({len(spans)} spans; Chrome timeline at {path}); "
                      f"summarize with: python -m repro.launch.obs_report "
                      f"--trace-dir {args.trace_dir}")
            if args.metrics_out:
                with open(args.metrics_out, "w") as f:
                    f.write(obs.prometheus_text(folded))
                print(f"[serve] metrics written to {args.metrics_out}")
    else:
        recs = eng.run(test)
    res = eng.evaluate(test, recs)
    if eng.stats.n_docs and eng.stats.node_seconds == 0.0:
        # every batch replayed from a pre-warmed store: there are no
        # real parse costs to report a throughput from
        print("[serve] all batches replayed from cache; "
              "throughput_docs_per_node_s reported as 0")
        res["throughput_docs_per_node_s"] = 0.0
    print(f"[serve] AdaParse({args.variant}) alpha={args.alpha} "
          f"n_test={len(test)}")
    for k, v in res.items():
        print(f"  {k:28s} {v:.4f}")
    return res


if __name__ == "__main__":
    main()
