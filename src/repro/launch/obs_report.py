"""Summarize a run's trace directory (``serve.py --trace-dir``).

    PYTHONPATH=src python -m repro.launch.obs_report --trace-dir DIR

Replays ``spans.jsonl`` (the span log ``core/obs.TraceWriter`` wrote)
and prints:

- a per-stage latency table — count, p50/p95/p99 (exact percentiles
  over the recorded durations, not histogram-bucket estimates), and
  total busy seconds per span stage;
- a per-worker table — span count, busy seconds (work-stage spans
  only: ``prepare``/``route``/``reparse``/``probe``/``cache_lookup``),
  busy fraction of the trace window, and the stages seen on that lane;
- the re-issue cause breakdown (``crash`` / ``wedged`` / ``stalled``,
  parsed from the coordinator's ``reissue`` span details) and the
  dedup / cache-hit counts the span-conservation laws guarantee;
- fabric membership (``join`` / ``leave`` / ``admission_rejected``
  lifecycle spans) when the run used the cross-machine fabric runtime —
  remote workers show up as ordinary per-worker lanes, keyed by the
  node id the coordinator assigned at admission.

It also (re)generates the Chrome ``trace_event`` artifact from the
span log — ``--chrome-out FILE`` writes it elsewhere (default: refresh
``trace.json`` inside the trace dir), so a spans.jsonl shipped without
its sibling is still loadable in chrome://tracing or Perfetto.
"""
from __future__ import annotations

import argparse
import math
import os
import shutil
from collections import Counter, defaultdict

from repro.core import obs

#: Stages whose duration is real work on a worker lane. ``complete``
#: is excluded: the coordinator attributes it to the winning worker
#: with the full batch wall, which already contains the stage spans.
WORK_STAGES = ("prepare", "route", "reparse", "probe", "cache_lookup")


def _pct(vals: list, q: float) -> float:
    """Nearest-rank percentile over the raw measured durations."""
    if not vals:
        return 0.0
    rank = max(math.ceil(q * len(vals)), 1)
    return sorted(vals)[rank - 1]


def _lane(node: int) -> str:
    return "coordinator" if node < 0 else f"worker {node}"


def summarize(spans, meta: dict | None = None) -> dict:
    """The report as a plain dict (the CLI renders it; tests assert
    on it)."""
    meta = meta or {}
    starts = [s.start for s in spans]
    ends = [s.start + s.dur for s in spans]
    window = (max(ends) - min(starts)) if spans else 0.0

    by_stage: dict[str, list] = defaultdict(list)
    by_worker: dict[int, dict] = defaultdict(
        lambda: {"spans": 0, "busy_s": 0.0, "stages": Counter()})
    causes: Counter = Counter()
    n_complete = n_dedup = n_cached = 0
    fabric: Counter = Counter()
    for s in spans:
        by_stage[s.name].append(s.dur)
        w = by_worker[s.node]
        w["spans"] += 1
        w["stages"][s.name] += 1
        if s.name in WORK_STAGES:
            w["busy_s"] += s.dur
        if s.name == "reissue":
            causes[s.detail.split(" ", 1)[0] or "unknown"] += 1
        elif s.name == "complete":
            n_complete += 1
            n_cached += bool(s.cached)
        elif s.name == "dedup":
            n_dedup += 1
        elif s.name in ("join", "leave", "admission_rejected"):
            fabric[s.name] += 1

    stages = {
        name: {"n": len(durs), "p50_s": _pct(durs, 0.50),
               "p95_s": _pct(durs, 0.95), "p99_s": _pct(durs, 0.99),
               "total_s": sum(durs)}
        for name, durs in by_stage.items()}
    workers = {
        node: {"spans": w["spans"], "busy_s": w["busy_s"],
               "busy_frac": (w["busy_s"] / window) if window else 0.0,
               "stages": dict(w["stages"])}
        for node, w in by_worker.items()}
    return {"n_spans": len(spans), "dropped": meta.get("dropped", 0),
            "window_s": window, "stages": stages, "workers": workers,
            "reissue_causes": dict(causes), "complete": n_complete,
            "complete_cached": n_cached, "dedup": n_dedup,
            "fabric": {"joins": fabric["join"], "leaves": fabric["leave"],
                       "rejected": fabric["admission_rejected"]}}


def render(rep: dict) -> str:
    out = [f"[obs] {rep['n_spans']} spans over {rep['window_s']:.2f} s "
           f"({rep['dropped']} dropped at the ring)"]
    out.append(f"{'stage':<14}{'n':>6}{'p50 ms':>10}{'p95 ms':>10}"
               f"{'p99 ms':>10}{'total s':>10}")
    order = {n: i for i, n in enumerate(obs.SPAN_STAGES)}
    for name in sorted(rep["stages"], key=lambda n: order.get(n, 99)):
        st = rep["stages"][name]
        out.append(f"{name:<14}{st['n']:>6}{st['p50_s'] * 1e3:>10.2f}"
                   f"{st['p95_s'] * 1e3:>10.2f}{st['p99_s'] * 1e3:>10.2f}"
                   f"{st['total_s']:>10.2f}")
    out.append("")
    out.append(f"{'lane':<14}{'spans':>6}{'busy s':>10}{'busy %':>8}"
               f"  stages")
    for node in sorted(rep["workers"]):
        w = rep["workers"][node]
        seen = ",".join(sorted(w["stages"]))
        out.append(f"{_lane(node):<14}{w['spans']:>6}{w['busy_s']:>10.2f}"
                   f"{w['busy_frac'] * 100:>7.1f}%  {seen}")
    out.append("")
    causes = rep["reissue_causes"]
    cause_s = (", ".join(f"{c} {n}" for c, n in sorted(causes.items()))
               if causes else "none")
    out.append(f"re-issues: {cause_s}")
    out.append(f"completes: {rep['complete']} "
               f"({rep['complete_cached']} cached)  "
               f"dedup drops: {rep['dedup']}")
    fab = rep.get("fabric") or {}
    if any(fab.values()):
        out.append(f"fabric membership: {fab['joins']} joined, "
                   f"{fab['leaves']} left, {fab['rejected']} rejected "
                   f"(live delta {fab['joins'] - fab['leaves']:+d})")
    return "\n".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="summarize an adaparse trace directory")
    ap.add_argument("--trace-dir", required=True, metavar="DIR",
                    help="directory serve.py --trace-dir wrote "
                         "(needs spans.jsonl)")
    ap.add_argument("--chrome-out", default=None, metavar="FILE",
                    help="where to write the regenerated Chrome "
                         "trace_event JSON (default: trace.json inside "
                         "the trace dir)")
    args = ap.parse_args(argv)
    try:
        spans, meta = obs.load_spans(args.trace_dir)
    except FileNotFoundError:
        ap.error(f"no spans.jsonl under {args.trace_dir!r}; run "
                 f"serve.py with --trace-dir first")
    rep = summarize(spans, meta)
    print(render(rep))
    chrome = obs.TraceWriter(args.trace_dir).write(
        spans, dropped=meta.get("dropped", 0))
    if args.chrome_out:
        os.makedirs(os.path.dirname(os.path.abspath(args.chrome_out)),
                    exist_ok=True)
        shutil.copyfile(chrome, args.chrome_out)
        chrome = args.chrome_out
    print(f"\nChrome trace: {chrome} (open in chrome://tracing or "
          f"https://ui.perfetto.dev)")
    return rep


if __name__ == "__main__":
    main()
