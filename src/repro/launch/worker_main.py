"""Worker-process entrypoint for the campaign worker runtime
(core/workers.ProcessWorkerPool).

Each worker is one spawned OS process (``multiprocessing`` spawn
context — a fresh interpreter, no inherited JAX/numpy state). It
rebuilds its engine from the serialized ``WorkerSpec``: re-registers
any custom backends from the spec's ``(module, attr)`` factory pairs,
opens its own handle on the shared ``DiskResultStore`` directory (the
store's WAL appends are multi-process safe), and builds an
``AdaParseEngine`` whose content-addressed cache tag matches every
other worker's — the property that lets N processes share one result
store and still replay byte-identically.

Protocol (core/workers dataclasses over the two queues):

- ``PrepareTask``  -> prepare + route; complete locally and reply
  ``BatchDone(records, telemetry)``, or — when the task forwards and
  expensive work was routed — reply ``BatchDone(prep, plan)`` for the
  coordinator to forward to the re-parse pool.
- ``CompleteTask`` -> expensive re-parse of a forwarded batch; reply
  ``BatchDone(records, telemetry)``.
- ``Heartbeat``    -> sent on a fixed interval from a daemon thread
  (and once at startup, the ready signal). The coordinator treats a
  missed deadline as a wedged worker and re-issues its in-flight work.
- ``None``         -> shutdown sentinel; flush the store and exit.

With the shm transport (``WorkerSpec.shm_base`` set) the bulk payloads
ride in ``core/shm`` arena slots instead of the queues: inbound tasks
carry a generation-tagged ``ShmRef`` the worker reads (a stale ref —
the task completed elsewhere and its slot was reclaimed — becomes an
error reply the coordinator drops at the dedup gate), and outbound
records / forwarded preps are written into the worker's own response
arena, falling back to inline payloads under slot pressure.

A worker-side exception never wedges the pool: the traceback travels
back as ``BatchDone.error``. ``wall_s`` on every reply is the real
measured stage duration — the process runtime's replacement for the
simulated clocks, and the signal the adaptive controller's throughput
EWMA consumes.
"""
from __future__ import annotations

import dataclasses
import importlib
import os
import threading
import time
import traceback


def _build_engine(spec):
    from repro.core import backends as B
    from repro.core.engine import AdaParseEngine
    from repro.core.quality import QualityProbe

    for mod_name, attr in spec.backend_specs:
        factory = getattr(importlib.import_module(mod_name), attr)
        B.register_backend(factory(), overwrite=True)
    if getattr(spec, "fingerprint", None) is not None:
        # the coordinator stamped a content fingerprint on the spec
        # before shipping it; recompute from what actually arrived and
        # refuse to run on drift (a worker built from a diverged spec
        # would silently break byte-identical record parity)
        from repro.core import specs as spec_lib
        mismatch = spec_lib.describe_mismatch(
            spec.fingerprint, spec_lib.spec_fingerprint(spec))
        if mismatch:
            raise RuntimeError(f"worker {spec.worker_id} spec drifted "
                               f"in transit: {mismatch}")
    if spec.tuning_dir is not None:
        # one flock-shared tuning store per fleet: a block size swept
        # by any worker (or a previous fleet) is a lookup for the rest
        from repro.kernels import tuning_store
        tuning_store.configure(spec.tuning_dir)
    cache = (B.DiskResultStore(spec.cache_dir,
                               max_bytes=spec.cache_max_bytes)
             if spec.cache_dir is not None else None)
    probe = (QualityProbe(spec.probe_cfg)
             if spec.probe_cfg is not None else None)
    ecfg = (spec.ecfg if spec.alpha is None
            else dataclasses.replace(spec.ecfg, alpha=spec.alpha))
    return AdaParseEngine(ecfg, spec.router, spec.corpus_cfg,
                          image_degraded=spec.image_degraded,
                          text_degraded=spec.text_degraded,
                          cache=cache, probe=probe), cache


def _run_task(eng, wid, task):
    from repro.core.workers import BatchDone, CompleteTask

    t0 = time.perf_counter()
    eng.set_alpha(task.alpha)        # no-op when unchanged
    if isinstance(task, CompleteTask):
        recs = eng.complete_batch(task.prep, task.plan, node_id=wid,
                                  ingest_engine=eng)
        key = eng._cache_key(task.prep.docs, task.batch_key)
        if key is not None:
            eng.cache.store(key, recs)
        return BatchDone(task.task_id, wid, task.batch_key, records=recs,
                         telemetry=eng.telemetry[-1],
                         wall_s=time.perf_counter() - t0)
    key, prep, cached = eng.prepare_or_lookup(
        task.docs, batch_key=task.batch_key, use_cache=task.use_cache)
    if cached is not None:
        eng._account_cache_hit(cached, task.batch_key)
        return BatchDone(task.task_id, wid, task.batch_key, records=cached,
                         telemetry=eng.telemetry[-1], cached=True,
                         wall_s=time.perf_counter() - t0)
    plan = eng.route_batch(prep)
    if task.forward and plan.expensive_idx.size:
        return BatchDone(task.task_id, wid, task.batch_key, prep=prep,
                         plan=plan, wall_s=time.perf_counter() - t0)
    recs = eng.complete_batch(prep, plan, node_id=wid)
    if key is not None:
        eng.cache.store(key, recs)
    return BatchDone(task.task_id, wid, task.batch_key, records=recs,
                     telemetry=eng.telemetry[-1],
                     wall_s=time.perf_counter() - t0)


def _decode_payload(shm_t, task) -> None:
    """Resolve a task's shm payload in place (no-op for inline
    payloads). Raises ``ShmStale`` when the slot was reclaimed — the
    task already completed elsewhere."""
    from repro.core.workers import CompleteTask

    if getattr(task, "payload", None) is None:
        return
    obj = shm_t.read_task(task.payload)
    if isinstance(task, CompleteTask):
        task.prep, task.plan = obj
    else:
        task.docs = obj
    task.payload = None


def _encode_reply(shm_t, done) -> None:
    """Move a successful reply's bulk (records, or the forwarded
    (prep, plan)) into the worker's response arena; under slot pressure
    the reply just stays inline."""
    if done.error is not None:
        return
    if done.records is not None:
        ref = shm_t.encode_result(done.records)
        if ref is not None:
            done.records, done.payload, done.payload_kind = \
                None, ref, "records"
    elif done.prep is not None:
        ref = shm_t.encode_result((done.prep, done.plan))
        if ref is not None:
            done.prep = done.plan = None
            done.payload, done.payload_kind = ref, "prep"


def worker_loop(spec, task_q, result_q) -> None:
    """Process main: build the engine, heartbeat, serve tasks until the
    shutdown sentinel."""
    from repro.core import obs
    from repro.core.workers import BatchDone, Heartbeat

    wid = spec.worker_id
    current: list[int | None] = [None]
    muted = [False]
    stop = threading.Event()
    # the per-process observability plane: a noop recorder unless the
    # coordinator asked for tracing (WorkerSpec.obs_enabled); configure
    # before the engine build so warmup instrumentation lands in it
    rec = obs.configure(enabled=getattr(spec, "obs_enabled", False),
                        cap=getattr(spec, "obs_span_cap", 8192),
                        node=wid)
    try:
        eng, cache = _build_engine(spec)
        shm_t = None
        if spec.shm_base is not None:
            from repro.core.shm import WorkerShmTransport
            shm_t = WorkerShmTransport(spec.shm_base, wid, spec.n_workers,
                                       spec.shm_resp_slots)
    except BaseException:
        result_q.put(BatchDone(task_id=-1, worker=wid, batch_key=-1,
                               error=traceback.format_exc()))
        return

    def _queue_depth() -> int:
        try:
            return task_q.qsize()
        except (NotImplementedError, OSError):
            return -1                   # platform can't report depth

    def _heartbeat() -> Heartbeat:
        """Liveness + load context: queue depth and a monotonic send
        stamp let the coordinator tell backlog from wedge, and the
        beacon piggybacks a bounded span-ring drain when tracing is
        on (deque ops are GIL-atomic — no lock against the task
        loop)."""
        depth = _queue_depth()
        obs.metrics().gauge(f"worker.queue_depth.n{wid}", depth)
        return Heartbeat(
            wid, time.time(), current[0],
            sent_mono=time.monotonic(), queue_depth=depth,
            spans=rec.drain(128) if rec.enabled else None,
            metrics=obs.metrics().snapshot() if rec.enabled else None)

    def beat():
        while not stop.wait(spec.heartbeat_interval_s):
            if not muted[0]:
                result_q.put(_heartbeat())

    threading.Thread(target=beat, daemon=True).start()
    result_q.put(_heartbeat())                      # ready signal

    fault = spec.fault
    crash_after = dict(fault.crash_after) if fault else {}
    mute_after = dict(fault.mute_after) if fault else {}
    unmute_after = dict(getattr(fault, "unmute_after", ()) or ()) \
        if fault else {}
    n_done = 0
    while True:
        task = task_q.get()
        if task is None:
            break
        if wid in crash_after and n_done >= crash_after[wid]:
            # injected crash: hard exit with the batch in flight (no
            # reply, no more heartbeats — the coordinator's liveness
            # check must recover it)
            os._exit(3)
        current[0] = task.task_id
        try:
            if shm_t is not None:
                _decode_payload(shm_t, task)
            done = _run_task(eng, wid, task)
            if shm_t is not None:
                _encode_reply(shm_t, done)
        except BaseException:
            done = BatchDone(task.task_id, wid, task.batch_key,
                             error=traceback.format_exc())
        done.attempt = getattr(task, "attempt", 0)
        if done.error is None:
            obs.metrics().observe("worker.task_wall_s", done.wall_s)
        if rec.enabled:
            # piggyback the observability plane on the reply: a bounded
            # ring drain plus the cumulative metrics snapshot (the
            # coordinator keeps the latest per worker and folds)
            obs.metrics().gauge(f"obs.dropped.n{wid}", rec.dropped)
            done.spans = rec.drain(512)
            done.metrics = obs.metrics().snapshot()
        if muted[0] and fault is not None and fault.mute_slowdown_s > 0:
            time.sleep(fault.mute_slowdown_s)
        result_q.put(done)
        current[0] = None
        n_done += 1
        if wid in mute_after and n_done >= mute_after[wid]:
            # wedged-looking straggler; with unmute_after the mute
            # window is [mute_after, unmute_after) — a flap
            muted[0] = not (wid in unmute_after
                            and n_done >= unmute_after[wid])
    stop.set()
    if shm_t is not None:
        shm_t.close()
    if cache is not None:
        cache.flush()
