"""Fault-tolerant training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --shape train_4k [--reduced] [--steps 100] [--ckpt-dir ckpts/qwen] \
        [--ckpt-every 50] [--mesh 1x1]

Production behaviors:
- restart-from-latest: on launch, restores the newest checkpoint in
  --ckpt-dir (params + optimizer state + data-pipeline step) and resumes;
- atomic async checkpoints every --ckpt-every steps (tmp+rename; training
  never blocks on I/O);
- straggler detection on step durations (logged; in multi-host deployment
  the detector's output feeds the elastic rescale planner);
- elastic restore: --mesh may differ from the mesh the checkpoint was
  written on; arrays are device_put into the new sharding.
"""
from __future__ import annotations

import argparse
import signal
import time

import jax
import numpy as np

from repro.checkpoint import checkpoint as ckpt_lib
from repro.distributed.fault import StragglerDetector
from repro.distributed.meshrules import AxisRules, use_rules
from repro.launch.mesh import make_mesh
from repro.launch.specs import build_cell


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--reduced", action="store_true",
                    help="tiny config for CPU runs")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default="1x1",
                    help="e.g. 16x16 (data x model) or 2x16x16")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    dims = tuple(int(x) for x in args.mesh.split("x"))
    axes = ("pod", "data", "model")[-len(dims):] if len(dims) == 3 \
        else ("data", "model")[-len(dims):]
    mesh = make_mesh(dims, axes)
    rules = AxisRules(mesh) if np.prod(dims) > 1 else None

    stop = {"now": False}
    signal.signal(signal.SIGTERM, lambda *_: stop.update(now=True))

    with mesh, use_rules(rules):
        cell = build_cell(args.arch, args.shape, rules=rules,
                          abstract=False, reduced=args.reduced)
        params, opt_state, _, batch0 = cell.args[:4]
        start_step = 0
        if args.ckpt_dir and ckpt_lib.latest_step(args.ckpt_dir) is not None:
            start_step, tree, meta = ckpt_lib.restore(args.ckpt_dir)
            params, opt_state = tree["params"], tree["opt_state"]
            print(f"[train] restored step {start_step} from {args.ckpt_dir}")
        jitted = jax.jit(cell.fn, donate_argnums=cell.donate_argnums)

        detector = StragglerDetector()
        losses = []
        pending = None
        for step in range(start_step, args.steps):
            if stop["now"]:
                print("[train] SIGTERM — checkpointing and exiting")
                break
            t0 = time.time()
            # re-synthesize the batch for this step (stateless pipeline)
            cell_b = build_cell(args.arch, args.shape, rules=rules,
                                abstract=False, reduced=args.reduced,
                                seed=step + 1)
            batch = cell_b.args[-1]
            params, opt_state, loss = jitted(
                params, opt_state, np.int32(step), batch)
            loss = float(loss)
            losses.append(loss)
            detector.record(0, time.time() - t0)
            if step % args.log_every == 0:
                print(f"[train] step {step} loss {loss:.4f} "
                      f"({time.time()-t0:.2f}s)", flush=True)
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                if pending is not None:
                    pending.join()
                pending = ckpt_lib.save_async(
                    args.ckpt_dir, step + 1,
                    {"params": params, "opt_state": opt_state},
                    metadata={"arch": args.arch, "loss": loss})
        if pending is not None:
            pending.join()
        if args.ckpt_dir:
            ckpt_lib.save(args.ckpt_dir, args.steps,
                          {"params": params, "opt_state": opt_state},
                          metadata={"arch": args.arch,
                                    "loss": losses[-1] if losses else None})
        stragglers = detector.stragglers()
        print(f"[train] done; final loss "
              f"{losses[-1] if losses else float('nan'):.4f}; "
              f"stragglers={stragglers}")
        return losses


if __name__ == "__main__":
    main()
