"""Logical-axis -> mesh-axis sharding rules.

Model code annotates tensors with *logical* axes ("batch", "heads", …).
An ambient :class:`AxisRules` context maps those onto physical mesh axes
(``pod``/``data``/``model``) with divisibility guards, producing
``PartitionSpec``s for parameters, activations, and optimizer state.

Outside any context (plain CPU tests), all helpers are no-ops, so model
code stays mesh-agnostic.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common import Param, is_param

# Default logical->mesh mapping. Values are *preference-ordered* tuples of
# mesh axes: a logical dim is sharded over every listed mesh axis that (a)
# exists in the mesh and (b) keeps the dim divisible. "pod" appears first
# for batch-like axes so the multi-pod mesh data-parallelizes across pods.
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    # LM activations. "seq" -> model is Megatron-style sequence parallelism:
    # the residual stream (and scan-layer remat carries) shard their seq dim
    # over the TP axis; GSPMD inserts the AG/RS pair around attention. This
    # is what keeps 64-layer remat carries inside v5e HBM at 314B scale.
    "batch": ("pod", "data"),
    "seq": ("model",),
    "kv_seq": ("data", "model"),  # long-context KV caches (falls through to
                                  # model when batch already owns data)
    # FSDP: weight matrices shard their d_model dim over "data" (they have
    # no batch dim, so no conflict; activations' batch grabs "data" first).
    # GSPMD all-gathers each scanned layer's weights on entry — without
    # this, grok-1-314b params (632 GB bf16) replicate 16x and blow HBM.
    "d_model": ("data",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "d_head": (),
    "d_ff": ("model",),
    "experts": ("model",),
    "expert_ff": ("model",),   # picked up when n_experts isn't divisible (e.g. grok 8e on model=16)
    "expert_cap": ("data",),   # dispatch-buffer capacity dim: each data
                               # shard owns its slice of expert slots
    "vocab": ("model",),
    "layers": (),
    "pos": (),
    # fully-sharded (ZeRO-like) optimizer-state axes
    "fsdp": ("data",),
    # ViT parser
    "patches": ("model",),
    "pages": ("pod", "data"),
    # GNN
    "nodes": ("pod", "data", "model"),
    "edges": ("pod", "data", "model"),
    "graphs": ("pod", "data"),
    "d_feat": (),
    "coeff": (),
    # recsys
    "table_rows": ("model",),
    "embed_dim": (),
    "fields": (),
    "candidates": ("pod", "data", "model"),
    "mlp_in": (),
    "mlp_out": (),
    # pipeline
    "stage": ("pod",),
}


class AxisRules:
    """A mesh + logical-axis rule table, installable as ambient context."""

    def __init__(self, mesh: Mesh, rules: dict[str, tuple[str, ...]] | None = None,
                 overrides: dict[str, tuple[str, ...]] | None = None):
        self.mesh = mesh
        self.rules = dict(DEFAULT_RULES if rules is None else rules)
        if overrides:
            self.rules.update(overrides)

    # -- spec construction ---------------------------------------------------

    def spec_for(self, axes: Sequence[str | None],
                 shape: Sequence[int] | None = None) -> P:
        """Build a PartitionSpec for logical ``axes`` (one per dim).

        Guards: a mesh axis may appear at most once in the whole spec; a dim
        is only sharded if its size is divisible by the mesh-axes product
        (when ``shape`` is provided).
        """
        used: set[str] = set()
        entries = []
        for i, name in enumerate(axes):
            if name is None:
                entries.append(None)
                continue
            pref = self.rules.get(name, ())
            picked: list[str] = []
            for ax in pref:
                if ax not in self.mesh.shape or ax in used:
                    continue
                factor = int(np.prod([self.mesh.shape[a] for a in picked + [ax]]))
                if shape is not None and shape[i] % factor != 0:
                    continue
                picked.append(ax)
            used.update(picked)
            if not picked:
                entries.append(None)
            elif len(picked) == 1:
                entries.append(picked[0])
            else:
                entries.append(tuple(picked))
        while entries and entries[-1] is None:
            entries.pop()
        return P(*entries)

    def sharding_for(self, axes: Sequence[str | None],
                     shape: Sequence[int] | None = None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_for(axes, shape))

    def zero_spec_for(self, axes: Sequence[str | None],
                      shape: Sequence[int]) -> P:
        """ZeRO-style spec: the normal spec, plus the first still-unsharded
        divisible dim picks up the ``data`` axis (optimizer-state sharding)."""
        spec = self.spec_for(axes, shape)
        entries = list(spec) + [None] * (len(shape) - len(spec))
        used = {a for e in entries if e is not None
                for a in ((e,) if isinstance(e, str) else e)}
        if "data" in self.mesh.shape and "data" not in used:
            n = self.mesh.shape["data"]
            for i, e in enumerate(entries):
                if e is None and shape[i] % n == 0 and shape[i] >= n:
                    entries[i] = "data"
                    break
        while entries and entries[-1] is None:
            entries.pop()
        return P(*entries)

    def zero_sharding_for(self, axes, shape) -> NamedSharding:
        return NamedSharding(self.mesh, self.zero_spec_for(axes, shape))

    # -- trees ----------------------------------------------------------------

    def param_shardings(self, params):
        """Param tree -> NamedSharding tree (raw-array structure)."""
        return jax.tree_util.tree_map(
            lambda p: self.sharding_for(p.axes, p.value.shape),
            params, is_leaf=is_param)

    def param_specs(self, params):
        return jax.tree_util.tree_map(
            lambda p: self.spec_for(p.axes, p.value.shape),
            params, is_leaf=is_param)


_TLS = threading.local()


def current_rules() -> AxisRules | None:
    return getattr(_TLS, "rules", None)


@contextlib.contextmanager
def use_rules(rules: AxisRules | None):
    prev = getattr(_TLS, "rules", None)
    _TLS.rules = rules
    try:
        yield rules
    finally:
        _TLS.rules = prev


def shard_hint(x: jax.Array, *axes: str | None) -> jax.Array:
    """Constrain an activation's sharding by logical axes (no-op w/o rules)."""
    ctx = current_rules()
    if ctx is None:
        return x
    spec = ctx.spec_for(axes, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


def logical_sharding(axes: Sequence[str | None],
                     shape: Sequence[int] | None = None) -> NamedSharding | None:
    ctx = current_rules()
    if ctx is None:
        return None
    return ctx.sharding_for(axes, shape)
