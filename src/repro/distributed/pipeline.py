"""Pipeline parallelism over the ``pod`` (or ``stage``) mesh axis.

GPipe-style microbatched schedule built on shard_map + ppermute:
stage s holds layers [s*L/S, (s+1)*L/S); microbatches stream through via
collective_permute. With M microbatches and S stages the bubble fraction
is (S-1)/(M+S-1) — configs pick M >= 4*S.

Used as an *option* for the multi-pod mesh (the default multi-pod config
keeps ``pod`` as pure DP because the paper's workload is document-
parallel; PP is exercised by tests and a dry-run variant).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(stage_fn: Callable, params_stacked, x, mesh: Mesh,
                   axis: str = "pod", n_microbatches: int = 8):
    """Run a layer-stack as a pipeline over ``axis``.

    stage_fn(stage_params, microbatch) -> microbatch (same shape).
    params_stacked: pytree with leading dim = n_stages (sharded over axis).
    x: (batch, ...) global batch (replicated across stages at entry).
    """
    n_stages = mesh.shape[axis]
    b = x.shape[0]
    assert b % n_microbatches == 0
    mb = b // n_microbatches
    micro = x.reshape(n_microbatches, mb, *x.shape[1:])

    def per_stage(params_local, micro_local):
        # params_local: (1, ...) this stage's slice; micro: full stream
        params_local = jax.tree_util.tree_map(lambda a: a[0], params_local)
        stage_id = jax.lax.axis_index(axis)
        n_steps = n_microbatches + n_stages - 1
        buf = jax.lax.pvary(
            jnp.zeros((mb,) + micro_local.shape[2:], micro_local.dtype),
            (axis,))
        outputs = jax.lax.pvary(jnp.zeros_like(micro_local), (axis,))
        micro_local = jax.lax.pvary(micro_local, (axis,))

        def step(carry, t):
            buf, outputs = carry
            # stage 0 ingests microbatch t (if in range)
            take = jnp.clip(t, 0, n_microbatches - 1)
            fresh = jax.lax.dynamic_index_in_dim(micro_local, take, 0,
                                                 keepdims=False)
            inp = jnp.where(stage_id == 0,
                            jnp.where(t < n_microbatches, fresh, buf * 0),
                            buf)
            out = stage_fn(params_local, inp)
            # last stage emits result for microbatch t - (S-1)
            emit_t = t - (n_stages - 1)
            emit_idx = jnp.clip(emit_t, 0, n_microbatches - 1)
            do_emit = (stage_id == n_stages - 1) & (emit_t >= 0)
            upd = jax.lax.dynamic_update_index_in_dim(outputs, out,
                                                      emit_idx, 0)
            outputs = jnp.where(do_emit, upd, outputs)
            # shift activations downstream
            buf = jax.lax.ppermute(
                out, axis,
                [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return (buf, outputs), None

        (buf, outputs), _ = jax.lax.scan(step, (buf, outputs),
                                         jnp.arange(n_steps))
        # gather final outputs from the last stage to all stages
        outputs = jax.lax.psum(
            jnp.where(stage_id == n_stages - 1, outputs, 0.0), axis)
        return outputs

    spec_params = jax.tree_util.tree_map(lambda _: P(axis), params_stacked)
    out = jax.shard_map(per_stage, mesh=mesh,
                        in_specs=(spec_params, P()),
                        out_specs=P())(params_stacked, micro)
    return out.reshape(b, *x.shape[1:])
