"""Fault tolerance & elasticity scaffolding (single-host simulation of the
multi-host control plane; the seams are the real production interfaces).

- HeartbeatMonitor: worker liveness with deadline-based failure marking.
- StragglerDetector: per-step duration tracking; flags workers slower than
  ``factor``x the rolling median (mitigation: the engine re-issues their
  batches; the training driver drops to the backup schedule).
- ElasticPlan: given a failed/new node set, choose the largest valid mesh
  (divisible data axis) and map old->new checkpoint shardings — restore
  handles the actual resharding (checkpoint.restore with new shardings).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque


@dataclasses.dataclass
class WorkerState:
    last_beat: float
    durations: deque


class HeartbeatMonitor:
    def __init__(self, deadline_s: float = 60.0):
        self.deadline = deadline_s
        self.workers: dict[int, WorkerState] = {}

    def beat(self, worker: int, now: float | None = None):
        now = time.monotonic() if now is None else now
        st = self.workers.setdefault(worker, WorkerState(now, deque(maxlen=32)))
        st.last_beat = now

    def dead_workers(self, now: float | None = None) -> list[int]:
        now = time.monotonic() if now is None else now
        return [w for w, st in self.workers.items()
                if now - st.last_beat > self.deadline]


class StragglerDetector:
    def __init__(self, factor: float = 2.0, window: int = 32):
        self.factor = factor
        self.durations: dict[int, deque] = {}
        self.window = window

    def record(self, worker: int, duration_s: float):
        self.durations.setdefault(
            worker, deque(maxlen=self.window)).append(duration_s)

    def median_all(self) -> float:
        import numpy as np
        alld = [d for ds in self.durations.values() for d in ds]
        return float(np.median(alld)) if alld else 0.0

    def stragglers(self) -> list[int]:
        import numpy as np
        med = self.median_all()
        if med <= 0:
            return []
        out = []
        for w, ds in self.durations.items():
            if len(ds) >= 4 and float(np.median(ds)) > self.factor * med:
                out.append(w)
        return out


@dataclasses.dataclass
class ElasticPlan:
    old_shape: tuple[int, ...]
    new_shape: tuple[int, ...]
    axes: tuple[str, ...]

    @property
    def valid(self) -> bool:
        return all(s > 0 for s in self.new_shape)


def plan_rescale(axes: tuple[str, ...], old_shape: tuple[int, ...],
                 available_chips: int, model_axis: str = "model"
                 ) -> ElasticPlan:
    """Keep the model axis fixed (TP degree is architectural); shrink/grow
    the data (and pod) axes to the largest size the chips allow."""
    model_idx = axes.index(model_axis) if model_axis in axes else None
    model = old_shape[model_idx] if model_idx is not None else 1
    other = available_chips // model
    new = list(old_shape)
    if "pod" in axes:
        pod_idx = axes.index("pod")
        data_idx = axes.index("data")
        # prefer whole pods; fall back to shrinking data
        pods = max(other // old_shape[data_idx], 1) \
            if other >= old_shape[data_idx] else 1
        new[pod_idx] = pods
        new[data_idx] = other // pods
    else:
        data_idx = axes.index("data")
        new[data_idx] = other
    return ElasticPlan(tuple(old_shape), tuple(new), axes)
