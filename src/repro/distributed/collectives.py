"""Collective helpers: quantized/compressed data-parallel all-reduce via
shard_map, overlap-friendly reduce-scatter + all-gather splits."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.optim.compression import dequantize_int8, quantize_int8


def compressed_psum_mean(grads_stacked, mesh: Mesh, axis: str = "data",
                         scheme: str = "int8"):
    """Data-parallel gradient mean with int8 wire format (error feedback
    handled by the caller via optim.compression).

    ``grads_stacked``: tree whose leaves have a leading per-shard axis of
    size mesh.shape[axis] (each shard's local gradients). Returns the
    replicated mean tree (leading axis dropped). The quantize happens
    *before* the collective — on real hardware this halves ICI bytes vs
    bf16 (4x vs fp32)."""

    def stage(g):
        g = g[0].astype(jnp.float32)             # local shard's grads
        if scheme == "none":
            return jax.lax.pmean(g, axis)
        # agree on one scale first (one tiny pmax), then quantize + psum
        scale = jax.lax.pmax(jnp.max(jnp.abs(g)), axis) / 127.0
        scale = jnp.maximum(scale, 1e-12)
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        # sum int8 payloads in int32 to avoid overflow across shards
        tot = jax.lax.psum(q.astype(jnp.int32), axis)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
        return (tot.astype(jnp.float32) * scale) / n

    def mapped(gtree):
        return jax.tree_util.tree_map(stage, gtree)

    in_specs = jax.tree_util.tree_map(lambda _: P(axis), grads_stacked)
    out_specs = jax.tree_util.tree_map(lambda _: P(), grads_stacked)
    return jax.shard_map(mapped, mesh=mesh, in_specs=(in_specs,),
                         out_specs=out_specs)(grads_stacked)


def reduce_scatter_then_allgather(x: jax.Array, mesh: Mesh,
                                  axis: str = "data"):
    """ZeRO-style split of an all-reduce into reduce-scatter (before the
    optimizer) + all-gather (after): each shard updates 1/N of the
    parameters. Exposed for the perf loop; inside pjit, the same effect is
    obtained by sharding optimizer state on the 'fsdp' logical axis."""
    n = mesh.shape[axis]

    def stage(xs):
        scat = jax.lax.psum_scatter(xs, axis, scatter_dimension=0,
                                    tiled=True)
        return jax.lax.all_gather(scat, axis, axis=0, tiled=True)

    return jax.shard_map(stage, mesh=mesh, in_specs=P(axis),
                         out_specs=P(axis))(x)
