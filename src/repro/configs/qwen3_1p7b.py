"""Qwen3-1.7B [hf:Qwen/Qwen3-8B; hf]: 28L d=2048 16H (kv=8) d_ff=6144,
vocab 151936, qk-norm, GQA."""
from repro.configs.base import ArchConfig, LMConfig, LM_SHAPES, register


def _model(**kw):
    base = dict(
        name="qwen3-1.7b", n_layers=28, d_model=2048, n_heads=16,
        n_kv_heads=8, d_ff=6144, vocab_size=151936, rope_theta=1e6,
        qk_norm=True,
    )
    base.update(kw)
    return LMConfig(**base)


@register("qwen3-1.7b")
def config() -> ArchConfig:
    return ArchConfig(
        arch_id="qwen3-1.7b", family="lm", model=_model(),
        shapes=LM_SHAPES, source="hf:Qwen/Qwen3-8B; hf",
        skips={"long_500k": "pure full attention; skipped per spec"},
        reduced=lambda: ArchConfig(
            arch_id="qwen3-1.7b", family="lm",
            model=_model(name="qwen3-tiny", n_layers=2, d_model=64,
                         n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=512,
                         param_dtype="float32", compute_dtype="float32"),
            shapes=LM_SHAPES, source="reduced"),
    )
