"""DLRM MLPerf [arXiv:1906.00091; paper]: Criteo-1TB config, 13 dense +
26 sparse fields, embed 128, bot 13-512-256-128, top 1024-1024-512-256-1,
dot interaction. Table sizes: the MLPerf max-40M-row Criteo-TB list
(~187.8M rows total ≈ 24 GB bf16 / 96 GB fp32)."""
from repro.configs.base import (ArchConfig, RECSYS_SHAPES, RecsysConfig,
                                register)

CRITEO_TB_VOCAB = (
    39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63, 38532951,
    2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14, 39979771, 25641295,
    39664984, 585935, 12972, 108, 36)


def _model(**kw):
    base = dict(
        name="dlrm-mlperf", kind="dlrm", n_dense=13, n_sparse=26,
        embed_dim=128, vocab_sizes=CRITEO_TB_VOCAB,
        bot_mlp=(512, 256, 128), top_mlp=(1024, 1024, 512, 256, 1),
        interaction="dot", param_dtype="bfloat16",
        compute_dtype="bfloat16",
    )
    base.update(kw)
    return RecsysConfig(**base)


@register("dlrm-mlperf")
def config() -> ArchConfig:
    return ArchConfig(
        arch_id="dlrm-mlperf", family="recsys", model=_model(),
        shapes=RECSYS_SHAPES, source="arXiv:1906.00091; paper",
        reduced=lambda: ArchConfig(
            arch_id="dlrm-mlperf", family="recsys",
            model=_model(name="dlrm-tiny", n_dense=5, n_sparse=4,
                         embed_dim=8, vocab_sizes=(100, 50, 200, 30),
                         bot_mlp=(16, 8), top_mlp=(32, 16, 1),
                         param_dtype="float32", compute_dtype="float32"),
            shapes=RECSYS_SHAPES, source="reduced"),
    )
