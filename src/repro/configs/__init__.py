"""Arch registry: importing this package registers all configs."""
from repro.configs import (adaparse_router, autoint, deepfm, dien,
                           dlrm_mlperf, equiformer_v2, grok_1_314b,
                           h2o_danube_3_4b, nougat_base, olmoe_1b_7b,
                           phi3_medium_14b, qwen3_1p7b)  # noqa: F401
from repro.configs.base import ArchConfig, get_config, list_archs  # noqa: F401
