"""OLMoE-1B-7B [arXiv:2409.02060; hf]: 16L d=2048 16H (kv=16) MoE 64e top-8,
d_ff_expert=1024, vocab 50304."""
import dataclasses

from repro.configs.base import (ArchConfig, LMConfig, LM_SHAPES, MoEConfig,
                                register)


def _model(**kw):
    base = dict(
        name="olmoe-1b-7b", n_layers=16, d_model=2048, n_heads=16,
        n_kv_heads=16, d_ff=1024, vocab_size=50304, rope_theta=1e4,
        qk_norm=True,                      # OLMoE uses QK-norm
        moe=MoEConfig(n_experts=64, top_k=8, d_ff_expert=1024),
    )
    base.update(kw)
    return LMConfig(**base)


@register("olmoe-1b-7b")
def config() -> ArchConfig:
    return ArchConfig(
        arch_id="olmoe-1b-7b", family="lm", model=_model(), shapes=LM_SHAPES,
        source="arXiv:2409.02060; hf",
        skips={"long_500k": "pure full attention (no sub-quadratic path); "
                            "skipped per spec, see DESIGN.md"},
        reduced=lambda: ArchConfig(
            arch_id="olmoe-1b-7b", family="lm",
            model=_model(name="olmoe-tiny", n_layers=2, d_model=64,
                         n_heads=4, n_kv_heads=4, d_ff=64, vocab_size=512,
                         moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=32),
                         param_dtype="float32", compute_dtype="float32"),
            shapes=LM_SHAPES, source="reduced"),
    )
