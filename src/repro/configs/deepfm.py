"""DeepFM [arXiv:1703.04247; paper]: 39 sparse fields (13 binned-numeric +
26 categorical Criteo-Kaggle), embed 10, deep MLP 400-400-400, FM
interaction."""
from repro.configs.base import (ArchConfig, RECSYS_SHAPES, RecsysConfig,
                                register)

# 13 numeric features discretized to 100 bins each + Criteo-Kaggle
# categorical vocab sizes (standard preprocessing)
CRITEO_KAGGLE_VOCAB = (100,) * 13 + (
    1460, 583, 10131227, 2202608, 305, 24, 12517, 633, 3, 93145, 5683,
    8351593, 3194, 27, 14992, 5461306, 10, 5652, 2173, 4, 7046547, 18, 15,
    286181, 105, 142572)


def _model(**kw):
    base = dict(
        name="deepfm", kind="deepfm", n_dense=0, n_sparse=39, embed_dim=10,
        vocab_sizes=CRITEO_KAGGLE_VOCAB, mlp=(400, 400, 400),
        interaction="fm", param_dtype="bfloat16", compute_dtype="bfloat16",
    )
    base.update(kw)
    return RecsysConfig(**base)


@register("deepfm")
def config() -> ArchConfig:
    return ArchConfig(
        arch_id="deepfm", family="recsys", model=_model(),
        shapes=RECSYS_SHAPES, source="arXiv:1703.04247; paper",
        reduced=lambda: ArchConfig(
            arch_id="deepfm", family="recsys",
            model=_model(name="deepfm-tiny", n_sparse=4, embed_dim=8,
                         vocab_sizes=(100, 50, 200, 30), mlp=(16, 16),
                         param_dtype="float32", compute_dtype="float32"),
            shapes=RECSYS_SHAPES, source="reduced"),
    )
