"""EquiformerV2 [arXiv:2306.12059; unverified]: 12L d_hidden=128 l_max=6
m_max=2 8H, SO(2)-eSCN equivariant graph attention."""
from repro.configs.base import ArchConfig, GNNConfig, GNN_SHAPES, register


def _model(**kw):
    base = dict(
        name="equiformer-v2", n_layers=12, d_hidden=128, l_max=6, m_max=2,
        n_heads=8, n_radial=32, d_in=0, n_out=1,
        param_dtype="bfloat16", compute_dtype="bfloat16",
    )
    base.update(kw)
    return GNNConfig(**base)


@register("equiformer-v2")
def config() -> ArchConfig:
    return ArchConfig(
        arch_id="equiformer-v2", family="gnn", model=_model(),
        shapes=GNN_SHAPES, source="arXiv:2306.12059; unverified",
        reduced=lambda: ArchConfig(
            arch_id="equiformer-v2", family="gnn",
            model=_model(name="eq-tiny", n_layers=2, d_hidden=16, l_max=3,
                         m_max=2, n_heads=4, n_radial=8, d_in=7, n_out=3,
                         param_dtype="float32", compute_dtype="float32"),
            shapes=GNN_SHAPES, source="reduced"),
    )
