"""Nougat-class high-quality parser (~350M: Swin-encoder + mBART-decoder,
Blecher et al. 2023), the expensive path of AdaParse. Page-batched at
B_p=10 pages (paper §5.2), fixed (896, 672) input.

Shapes: training (page-image -> text CE), the serve encode step (page
batch through encoder + cross-KV precompute), and the serve decode step
(one token for a large in-flight page batch)."""
from repro.configs.base import ArchConfig, ShapeConfig, VitParserConfig, register

NOUGAT_SHAPES = (
    ShapeConfig("train_pages", "train",
                {"global_batch": 256, "dec_len": 2048},
                note="pages per step; teacher-forced CE"),
    ShapeConfig("parse_encode", "serve",
                {"global_batch": 2560, "dec_len": 0},
                note="encoder fwd for 256 docs x B_p=10 pages"),
    ShapeConfig("parse_decode", "decode",
                {"global_batch": 2560, "dec_len": 2048},
                note="one decode token against 2048-cache, batch=pages"),
)


def _model(**kw):
    base = dict(
        name="nougat-base", enc_layers=12, enc_d_model=1024, enc_heads=16,
        enc_d_ff=4096, window=112,          # 2352 patches / 21 windows
        image_hw=(896, 672), patch=16,
        dec_layers=10, dec_d_model=1024, dec_heads=16, dec_d_ff=4096,
        vocab_size=50000, max_dec_len=4096, pages_per_batch=10,
    )
    base.update(kw)
    return VitParserConfig(**base)


@register("nougat-base")
def config() -> ArchConfig:
    return ArchConfig(
        arch_id="nougat-base", family="vit_parser", model=_model(),
        shapes=NOUGAT_SHAPES, source="paper (Nougat, arXiv:2308.13418)",
        reduced=lambda: ArchConfig(
            arch_id="nougat-base", family="vit_parser",
            model=_model(name="nougat-tiny", enc_layers=2, enc_d_model=32,
                         enc_heads=4, enc_d_ff=64, window=8,
                         image_hw=(64, 48), dec_layers=2, dec_d_model=32,
                         dec_heads=4, dec_d_ff=64, vocab_size=64,
                         max_dec_len=16, param_dtype="float32",
                         compute_dtype="float32"),
            shapes=NOUGAT_SHAPES, source="reduced"),
    )
