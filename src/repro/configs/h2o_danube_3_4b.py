"""H2O-Danube-3-4B [arXiv:2401.16818; unverified]: 24L d=3840 32H (kv=8)
d_ff=10240, vocab 32000, llama+mistral mix with sliding-window attention
(window 4096) — the one LM arch that runs long_500k (sub-quadratic)."""
from repro.configs.base import ArchConfig, LMConfig, LM_SHAPES, register


def _model(**kw):
    base = dict(
        name="h2o-danube-3-4b", n_layers=24, d_model=3840, n_heads=32,
        n_kv_heads=8, d_ff=10240, vocab_size=32000, rope_theta=1e4,
        sliding_window=4096,
    )
    base.update(kw)
    return LMConfig(**base)


@register("h2o-danube-3-4b")
def config() -> ArchConfig:
    return ArchConfig(
        arch_id="h2o-danube-3-4b", family="lm", model=_model(),
        shapes=LM_SHAPES, source="arXiv:2401.16818; unverified",
        reduced=lambda: ArchConfig(
            arch_id="h2o-danube-3-4b", family="lm",
            model=_model(name="danube-tiny", n_layers=2, d_model=64,
                         n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=512,
                         sliding_window=32, param_dtype="float32",
                         compute_dtype="float32"),
            shapes=LM_SHAPES, source="reduced"),
    )
