"""Config dataclasses + arch/shape registry.

Every assigned architecture registers an :class:`ArchConfig` carrying
(a) its exact published model config, (b) its shape set (each shape is a
named workload cell: training step, prefill, decode, …), and (c) a
``reduced()`` factory for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

# ---------------------------------------------------------------------------
# Model configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router: str = "topk"            # "topk" (paper-of-arch faithful) | "budget" (AdaParse-style)
    budget_alpha: float = 0.125      # only for router="budget": global expert budget fraction
    aux_loss_weight: float = 0.01
    router_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class LMConfig:
    """Decoder-only transformer LM (dense or MoE)."""

    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0                  # 0 -> d_model // n_heads
    rope_theta: float = 1e4
    qk_norm: bool = False
    sliding_window: int | None = None  # SWA window size; None = full attention
    attention_impl: str = "xla_flash"  # "xla_flash" | "naive" | "pallas"
    q_chunk: int = 512
    kv_chunk: int = 1024
    moe: MoEConfig | None = None
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    logits_softcap: float | None = None
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    unroll_pairs: bool = False   # unroll the flash block-pair scan (costing)

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    def n_params(self) -> int:
        """Total parameter count (embeddings included once if tied)."""
        d, h, hk, dh, f, v, L = (self.d_model, self.n_heads, self.n_kv_heads,
                                 self.head_dim, self.d_ff, self.vocab_size,
                                 self.n_layers)
        attn = d * h * dh + 2 * d * hk * dh + h * dh * d
        if self.moe is not None:
            ffn = d * self.moe.n_experts * 3 * self.moe.d_ff_expert \
                + d * self.moe.n_experts
        else:
            ffn = 3 * d * f
        norms = 2 * d + (2 * dh if self.qk_norm else 0)
        emb = v * d * (1 if self.tie_embeddings else 2)
        return L * (attn + ffn + norms) + emb + d

    def n_active_params(self) -> int:
        """Active-per-token parameter count (MoE counts top_k experts)."""
        if self.moe is None:
            return self.n_params()
        d, h, hk, dh, L = (self.d_model, self.n_heads, self.n_kv_heads,
                           self.head_dim, self.n_layers)
        attn = d * h * dh + 2 * d * hk * dh + h * dh * d
        ffn = 3 * d * self.moe.d_ff_expert * self.moe.top_k \
            + d * self.moe.n_experts
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return L * (attn + ffn + 2 * d) + emb + d


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """BERT-style bidirectional encoder (the AdaParse CLS-III router)."""

    name: str
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    vocab_size: int
    max_len: int = 512
    n_outputs: int = 6               # per-parser accuracy regression head
    norm_eps: float = 1e-12
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True

    def n_params(self) -> int:
        d, f, L = self.d_model, self.d_ff, self.n_layers
        per_layer = 4 * d * d + 2 * d * f + 4 * d
        emb = self.vocab_size * d + self.max_len * d + 2 * d
        head = d * d + d * self.n_outputs
        return L * per_layer + emb + head


@dataclasses.dataclass(frozen=True)
class VitParserConfig:
    """Nougat-class parser: windowed-attention image encoder + causal
    cross-attention text decoder. Page pixels -> patch embeddings is a
    stub frontend (input_specs provides patch embeddings directly)."""

    name: str
    # encoder (Swin-ish, single resolution for simplicity at scale)
    enc_layers: int
    enc_d_model: int
    enc_heads: int
    enc_d_ff: int
    window: int                       # window size in patches (1D-flattened windows)
    image_hw: tuple[int, int] = (896, 672)
    patch: int = 16
    # decoder (mBART-ish causal LM with cross attention)
    dec_layers: int = 10
    dec_d_model: int = 1024
    dec_heads: int = 16
    dec_d_ff: int = 4096
    vocab_size: int = 50000
    max_dec_len: int = 4096
    pages_per_batch: int = 10         # paper's B_p
    norm_eps: float = 1e-5
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True

    @property
    def n_patches(self) -> int:
        return (self.image_hw[0] // self.patch) * (self.image_hw[1] // self.patch)

    def n_params(self) -> int:
        e = self.enc_layers * (4 * self.enc_d_model**2
                               + 2 * self.enc_d_model * self.enc_d_ff)
        d = self.dec_layers * (8 * self.dec_d_model**2
                               + 2 * self.dec_d_model * self.dec_d_ff)
        emb = self.vocab_size * self.dec_d_model + self.n_patches * self.enc_d_model
        return e + d + emb


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    """EquiformerV2-style eSCN equivariant graph attention network."""

    name: str
    n_layers: int
    d_hidden: int
    l_max: int
    m_max: int
    n_heads: int
    n_radial: int = 32
    d_edge: int = 0
    d_in: int = 0                     # input node feature dim (0 = embeddings)
    n_out: int = 1                    # regression targets / classes
    cutoff: float = 5.0
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    remat: bool = True
    scan_layers: bool = True

    @property
    def n_coeff(self) -> int:
        """Number of (l, m) spherical coefficients with |m| <= m_max."""
        return sum(min(2 * l + 1, 2 * self.m_max + 1)
                   for l in range(self.l_max + 1))


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    kind: str                         # "dlrm" | "deepfm" | "autoint" | "dien"
    n_dense: int
    n_sparse: int
    embed_dim: int
    vocab_sizes: tuple[int, ...]      # per sparse field
    bot_mlp: tuple[int, ...] = ()
    top_mlp: tuple[int, ...] = ()
    mlp: tuple[int, ...] = ()
    interaction: str = "dot"          # dot | fm | self-attn | augru
    # autoint
    n_attn_layers: int = 0
    n_attn_heads: int = 0
    d_attn: int = 0
    # dien
    seq_len: int = 0
    gru_dim: int = 0
    unroll_gru: bool = False     # unroll GRU time scans (costing variants)
    param_dtype: str = "float32"
    compute_dtype: str = "float32"

    def table_rows(self) -> int:
        return sum(self.vocab_sizes)


# ---------------------------------------------------------------------------
# Shapes (workload cells)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One workload cell: shape name + step kind + dims."""

    name: str
    kind: str                         # "train" | "prefill" | "decode" | "serve"
    dims: dict[str, int] = dataclasses.field(default_factory=dict)
    note: str = ""

    def __getitem__(self, k):
        return self.dims[k]


# ---------------------------------------------------------------------------
# Arch registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    arch_id: str
    family: str                       # "lm" | "gnn" | "recsys" | "encoder" | "vit_parser"
    model: Any
    shapes: tuple[ShapeConfig, ...]
    source: str = ""
    skips: dict[str, str] = dataclasses.field(default_factory=dict)  # shape -> reason
    reduced: Callable[[], "ArchConfig"] | None = None

    def shape(self, name: str) -> ShapeConfig:
        for s in self.shapes:
            if s.name == name:
                return s
        raise KeyError(f"{self.arch_id}: unknown shape {name!r}; "
                       f"have {[s.name for s in self.shapes]}")

    def runnable_shapes(self) -> list[ShapeConfig]:
        return [s for s in self.shapes if s.name not in self.skips]


_REGISTRY: dict[str, Callable[[], ArchConfig]] = {}


def register(arch_id: str):
    def deco(fn: Callable[[], ArchConfig]):
        _REGISTRY[arch_id] = fn
        return fn

    return deco


def get_config(arch_id: str) -> ArchConfig:
    import repro.configs  # noqa: F401  (triggers per-arch module imports)
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]()


def list_archs() -> list[str]:
    import repro.configs  # noqa: F401
    return sorted(_REGISTRY)


# LM-family shared shape set -------------------------------------------------

LM_SHAPES = (
    ShapeConfig("train_4k", "train", {"seq_len": 4096, "global_batch": 256}),
    ShapeConfig("prefill_32k", "prefill", {"seq_len": 32768, "global_batch": 32}),
    ShapeConfig("decode_32k", "decode", {"seq_len": 32768, "global_batch": 128}),
    ShapeConfig("long_500k", "decode", {"seq_len": 524288, "global_batch": 1},
                note="needs sub-quadratic attention"),
)

GNN_SHAPES = (
    ShapeConfig("full_graph_sm", "train",
                {"n_nodes": 2708, "n_edges": 10556, "d_feat": 1433}),
    ShapeConfig("minibatch_lg", "train",
                {"n_nodes": 232_965, "n_edges": 114_615_892,
                 "batch_nodes": 1024, "fanout0": 15, "fanout1": 10},
                note="sampled-training via neighbor sampler"),
    ShapeConfig("ogb_products", "train",
                {"n_nodes": 2_449_029, "n_edges": 61_859_140, "d_feat": 100}),
    ShapeConfig("molecule", "train",
                {"n_nodes": 30, "n_edges": 64, "batch": 128}),
)

RECSYS_SHAPES = (
    ShapeConfig("train_batch", "train", {"batch": 65536}),
    ShapeConfig("serve_p99", "serve", {"batch": 512}),
    ShapeConfig("serve_bulk", "serve", {"batch": 262144}),
    ShapeConfig("retrieval_cand", "serve", {"batch": 1, "n_candidates": 1_000_000}),
)
