"""Grok-1 314B [hf:xai-org/grok-1; unverified]: 64L d=6144 48H (kv=8)
MoE 8e top-2, d_ff=32768, vocab 131072. Trains with Adafactor (AdamW
state does not fit one v5e pod; DESIGN.md §8)."""
from repro.configs.base import (ArchConfig, LMConfig, LM_SHAPES, MoEConfig,
                                register)


def _model(**kw):
    base = dict(
        name="grok-1-314b", n_layers=64, d_model=6144, n_heads=48,
        n_kv_heads=8, d_ff=32768, vocab_size=131072, rope_theta=1e4,
        logits_softcap=30.0,               # grok uses output softcap
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32768),
        q_chunk=1024, kv_chunk=2048,
    )
    base.update(kw)
    return LMConfig(**base)


@register("grok-1-314b")
def config() -> ArchConfig:
    return ArchConfig(
        arch_id="grok-1-314b", family="lm", model=_model(),
        shapes=LM_SHAPES, source="hf:xai-org/grok-1; unverified",
        skips={"long_500k": "pure full attention; skipped per spec"},
        reduced=lambda: ArchConfig(
            arch_id="grok-1-314b", family="lm",
            model=_model(name="grok-tiny", n_layers=2, d_model=64,
                         n_heads=8, n_kv_heads=2, d_ff=128,
                         vocab_size=512, logits_softcap=30.0,
                         moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64),
                         q_chunk=512, kv_chunk=1024,
                         param_dtype="float32", compute_dtype="float32"),
            shapes=LM_SHAPES, source="reduced"),
    )
