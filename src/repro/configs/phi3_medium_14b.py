"""Phi-3-medium-14B [arXiv:2404.14219; unverified]: 40L d=5120 40H (kv=10)
d_ff=17920, vocab 100352, RoPE SwiGLU GQA."""
from repro.configs.base import ArchConfig, LMConfig, LM_SHAPES, register


def _model(**kw):
    base = dict(
        name="phi3-medium-14b", n_layers=40, d_model=5120, n_heads=40,
        n_kv_heads=10, d_ff=17920, vocab_size=100352, rope_theta=1e4,
    )
    base.update(kw)
    return LMConfig(**base)


@register("phi3-medium-14b")
def config() -> ArchConfig:
    return ArchConfig(
        arch_id="phi3-medium-14b", family="lm", model=_model(),
        shapes=LM_SHAPES, source="arXiv:2404.14219; unverified",
        skips={"long_500k": "pure full attention; skipped per spec"},
        reduced=lambda: ArchConfig(
            arch_id="phi3-medium-14b", family="lm",
            model=_model(name="phi3-tiny", n_layers=2, d_model=64,
                         n_heads=8, n_kv_heads=2, d_ff=128, vocab_size=512,
                         param_dtype="float32", compute_dtype="float32"),
            shapes=LM_SHAPES, source="reduced"),
    )
