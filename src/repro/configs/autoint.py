"""AutoInt [arXiv:1810.11921; paper]: 39 sparse fields, embed 16, 3
self-attention layers, 2 heads, d_attn 32."""
from repro.configs.base import (ArchConfig, RECSYS_SHAPES, RecsysConfig,
                                register)
from repro.configs.deepfm import CRITEO_KAGGLE_VOCAB


def _model(**kw):
    base = dict(
        name="autoint", kind="autoint", n_dense=0, n_sparse=39,
        embed_dim=16, vocab_sizes=CRITEO_KAGGLE_VOCAB, n_attn_layers=3,
        n_attn_heads=2, d_attn=32, interaction="self-attn",
        param_dtype="bfloat16", compute_dtype="bfloat16",
    )
    base.update(kw)
    return RecsysConfig(**base)


@register("autoint")
def config() -> ArchConfig:
    return ArchConfig(
        arch_id="autoint", family="recsys", model=_model(),
        shapes=RECSYS_SHAPES, source="arXiv:1810.11921; paper",
        reduced=lambda: ArchConfig(
            arch_id="autoint", family="recsys",
            model=_model(name="autoint-tiny", n_sparse=4, embed_dim=8,
                         vocab_sizes=(100, 50, 200, 30), n_attn_layers=2,
                         n_attn_heads=2, d_attn=8, param_dtype="float32",
                         compute_dtype="float32"),
            shapes=RECSYS_SHAPES, source="reduced"),
    )
