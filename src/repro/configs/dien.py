"""DIEN [arXiv:1809.03672; unverified]: embed 18, behavior seq 100,
GRU + AUGRU dim 108, MLP 200-80. Item/category vocab: Amazon-Books-scale
(367,983 items + 1,601 categories)."""
from repro.configs.base import (ArchConfig, RECSYS_SHAPES, RecsysConfig,
                                register)


def _model(**kw):
    base = dict(
        name="dien", kind="dien", n_dense=0, n_sparse=2, embed_dim=18,
        vocab_sizes=(367983, 1601), seq_len=100, gru_dim=108,
        mlp=(200, 80), interaction="augru", param_dtype="float32",
        compute_dtype="float32",
    )
    base.update(kw)
    return RecsysConfig(**base)


@register("dien")
def config() -> ArchConfig:
    return ArchConfig(
        arch_id="dien", family="recsys", model=_model(),
        shapes=RECSYS_SHAPES, source="arXiv:1809.03672; unverified",
        reduced=lambda: ArchConfig(
            arch_id="dien", family="recsys",
            model=_model(name="dien-tiny", vocab_sizes=(500, 20),
                         seq_len=10, gru_dim=12, mlp=(16, 8)),
            shapes=RECSYS_SHAPES, source="reduced"),
    )
