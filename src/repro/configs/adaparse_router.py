"""AdaParse CLS-III router: SciBERT-class encoder (12L d=768 12H, ~110M)
with the m=6 per-parser accuracy head (the paper's own model; §5.1,
App. A/B). Shapes: SFT regression training, DPO pair training, and the
production route step (encoder fwd + alpha-budget dispatch)."""
from repro.configs.base import ArchConfig, EncoderConfig, ShapeConfig, register

ROUTER_SHAPES = (
    ShapeConfig("sft_4k", "train", {"global_batch": 4096, "seq_len": 512},
                note="stage-1/3 accuracy regression"),
    ShapeConfig("dpo_2k", "train", {"global_batch": 2048, "seq_len": 512},
                note="stage-2 DPO pairs (2x fwd per side + ref)"),
    ShapeConfig("route_64k", "serve", {"global_batch": 65536, "seq_len": 512},
                note="fused route step: encoder + budget top-k dispatch"),
)


def _model(**kw):
    base = dict(
        name="adaparse-router", n_layers=12, d_model=768, n_heads=12,
        d_ff=3072, vocab_size=31090,        # SciBERT scivocab size
        max_len=512, n_outputs=6,
    )
    base.update(kw)
    return EncoderConfig(**base)


@register("adaparse-router")
def config() -> ArchConfig:
    return ArchConfig(
        arch_id="adaparse-router", family="encoder", model=_model(),
        shapes=ROUTER_SHAPES, source="paper (SciBERT, arXiv:1903.10676)",
        reduced=lambda: ArchConfig(
            arch_id="adaparse-router", family="encoder",
            model=_model(name="router-tiny", n_layers=2, d_model=32,
                         n_heads=4, d_ff=64, vocab_size=10000, max_len=64,
                         param_dtype="float32", compute_dtype="float32"),
            shapes=ROUTER_SHAPES, source="reduced"),
    )
