"""Checkpointing: atomic, async, retention-managed, elastic.

- Atomic: write to ``<dir>/tmp.<step>`` then ``os.replace`` into place —
  a crash mid-save never corrupts the latest checkpoint (restart safety).
- Async: ``save_async`` snapshots device arrays to host then writes on a
  background thread; training continues immediately.
- Elastic: arrays are stored *unsharded* (gathered); ``restore`` accepts a
  tree of NamedShardings and device_puts each leaf into the (possibly
  different) target mesh — a checkpoint written on a 256-chip pod restores
  onto 512 chips or 64 (elastic rescale) as long as the logical shapes
  divide. For multi-host production the same format shards at the file
  level (documented seam; this container is single-host).
"""
from __future__ import annotations

import json
import os
import pickle
import threading

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree, metadata: dict | None = None,
         keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    leaves, treedef = _flatten(tree)
    host_leaves = [np.asarray(x) for x in leaves]
    tmp = os.path.join(ckpt_dir, f".tmp.{step}")
    final = os.path.join(ckpt_dir, f"ckpt_{step:010d}")
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "arrays.npz"),
             **{f"leaf_{i}": a for i, a in enumerate(host_leaves)})
    with open(os.path.join(tmp, "treedef.pkl"), "wb") as f:
        pickle.dump(treedef, f)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, **(metadata or {})}, f)
    if os.path.exists(final):
        import shutil
        shutil.rmtree(final)
    os.replace(tmp, final)
    _apply_retention(ckpt_dir, keep)
    return final


def save_async(ckpt_dir: str, step: int, tree, metadata=None,
               keep: int = 3) -> threading.Thread:
    # snapshot to host synchronously (cheap vs write), write in background
    leaves, treedef = _flatten(tree)
    host_leaves = [np.asarray(x) for x in leaves]
    snap = jax.tree_util.tree_unflatten(treedef, host_leaves)
    t = threading.Thread(target=save, args=(ckpt_dir, step, snap, metadata,
                                            keep), daemon=True)
    t.start()
    return t


def _apply_retention(ckpt_dir: str, keep: int):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep]:
        import shutil
        shutil.rmtree(os.path.join(ckpt_dir, f"ckpt_{s:010d}"),
                      ignore_errors=True)


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("ckpt_"):
            try:
                out.append(int(name.split("_")[1]))
            except ValueError:
                pass
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int | None = None, shardings=None):
    """Returns (step, tree, metadata). ``shardings``: optional tree of
    NamedSharding (same structure) for elastic placement."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"ckpt_{step:010d}")
    with open(os.path.join(path, "treedef.pkl"), "rb") as f:
        treedef = pickle.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves = [data[f"leaf_{i}"] for i in range(len(data.files))]
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s), tree, shardings)
    return step, tree, meta
