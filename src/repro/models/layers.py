"""Shared neural layers: norms, rotary embeddings, dense/einsum layers,
activations, embeddings. All functions are pure; parameters are Param
trees (see repro.common)."""
from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.common import (Param, fan_in_init, normal_init, ones_init, param,
                          zeros_init)
from repro.distributed.meshrules import shard_hint

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-12) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def init_rms_norm(d: int, dtype, abstract=False, layers: int | None = None) -> Param:
    shape = (d,) if layers is None else (layers, d)
    axes = ("d_model",) if layers is None else ("layers", "d_model")
    return param(None, shape, axes, zeros_init, dtype, abstract)


def init_layer_norm(d: int, dtype, abstract=False, layers: int | None = None):
    shape = (d,) if layers is None else (layers, d)
    axes = ("d_model",) if layers is None else ("layers", "d_model")
    return {
        "scale": param(None, shape, axes, ones_init, dtype, abstract),
        "bias": param(None, shape, axes, zeros_init, dtype, abstract),
    }


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------


def rope_frequencies(d_head: int, theta: float) -> jax.Array:
    half = d_head // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, d_head); positions: broadcastable to (..., seq)."""
    d_head = x.shape[-1]
    freqs = rope_frequencies(d_head, theta)                       # (half,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    angles = angles[..., :, None, :]                              # (..., S, 1, half)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense / einsum layers
# ---------------------------------------------------------------------------


def init_dense(key, d_in: int, d_out: int, axes: Sequence[str | None],
               dtype, abstract=False, bias: bool = False,
               layers: int | None = None, stddev: float | None = None):
    shape = (d_in, d_out)
    if layers is not None:
        shape = (layers,) + shape
        axes = ("layers",) + tuple(axes)
    init = normal_init(stddev) if stddev is not None else fan_in_init(
        1 if layers is not None else 0)
    p = {"w": param(key, shape, axes, init, dtype, abstract)}
    if bias:
        bshape = (d_out,) if layers is None else (layers, d_out)
        baxes = (axes[-1],) if layers is None else ("layers", axes[-1])
        p["b"] = param(None, bshape, baxes, zeros_init, dtype, abstract)
    return p


def dense(x: jax.Array, p, out_hint: tuple[str | None, ...] | None = None):
    w = p["w"].value if isinstance(p["w"], Param) else p["w"]
    y = jnp.einsum("...i,io->...o", x, w.astype(x.dtype))
    if "b" in p:
        b = p["b"].value if isinstance(p["b"], Param) else p["b"]
        y = y + b.astype(y.dtype)
    if out_hint is not None:
        y = shard_hint(y, *out_hint)
    return y


def mlp_stack(key_gen, dims: Sequence[int], dtype, abstract=False,
              in_axis: str | None = None, hidden_axis: str | None = "d_ff",
              bias: bool = True):
    """A plain MLP as a list of dense layers; hidden dims sharded on
    ``hidden_axis``, final output replicated."""
    layers = []
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        last = i == len(dims) - 2
        ax_in = in_axis if i == 0 else hidden_axis
        ax_out = None if last else hidden_axis
        layers.append(init_dense(None if abstract else key_gen(), a, b,
                                 (ax_in, ax_out), dtype, abstract, bias=bias))
    return layers


def mlp_apply(x: jax.Array, layers, act=jax.nn.relu, final_act=None):
    for i, p in enumerate(layers):
        x = dense(x, p)
        if i < len(layers) - 1:
            x = act(x)
        elif final_act is not None:
            x = final_act(x)
    return x


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------


def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.silu(gate) * up


def gelu(x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x, approximate=True)


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------


def init_embedding(key, vocab: int, d: int, dtype, abstract=False,
                   axes=("vocab", "d_model")) -> Param:
    return param(key, (vocab, d), axes, normal_init(0.02), dtype, abstract)


def embed_lookup(table: jax.Array, ids: jax.Array) -> jax.Array:
    return jnp.take(table, ids, axis=0)


def softcap(logits: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return logits
    return cap * jnp.tanh(logits / cap)


def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       mask: jax.Array | None = None) -> jax.Array:
    """Mean token-level CE in fp32. logits (..., V), labels (...)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
