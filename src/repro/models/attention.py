"""Attention: GQA/MQA/MHA with causal + sliding-window masking.

Three implementations share one interface:

- ``naive``     : materializes the (Sq, Skv) score matrix. Reference.
- ``xla_flash`` : static block-pair streaming attention (online softmax over
  a `lax.scan` of visible (q-block, kv-block) pairs). Causal/SWA-masked
  block pairs are *statically pruned*, so causal costs ~half the FLOPs of
  naive and SWA costs O(S·W). This is the XLA-level analogue of the Pallas
  flash kernel in ``repro.kernels.flash_attention`` (which is the TPU
  target; this path is what the dry-run lowers).
- ``pallas``    : the Pallas kernel (interpret=True on CPU).

Shapes: q (B, Sq, H, Dh); k,v (B, Skv, Hk, Dh); H % Hk == 0.
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.meshrules import shard_hint

NEG_INF = -1e30


def _group(q: jax.Array, n_kv: int) -> jax.Array:
    """(B, S, H, D) -> (B, S, Hk, G, D)."""
    b, s, h, d = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, d)


def _mask_bias(qpos: jax.Array, kpos: jax.Array, causal: bool,
               window: int | None) -> jax.Array:
    """Additive bias (…, Sq, Skv) with NEG_INF at masked positions."""
    ok = jnp.ones((qpos.shape[-1], kpos.shape[-1]), bool)
    if causal:
        ok &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        ok &= (qpos[:, None] - kpos[None, :]) < window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Naive reference
# ---------------------------------------------------------------------------


def attention_naive(q, k, v, *, causal=True, window=None,
                    q_offset: int = 0) -> jax.Array:
    b, sq, h, d = q.shape
    _, skv, hk, _ = k.shape
    qg = _group(q, hk)
    scale = 1.0 / math.sqrt(d)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                   preferred_element_type=jnp.float32) * scale
    qpos = q_offset + jnp.arange(sq)
    kpos = jnp.arange(skv)
    s = s + _mask_bias(qpos, kpos, causal, window)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, sq, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# Static block-pair streaming attention ("xla flash")
# ---------------------------------------------------------------------------


def _visible_pairs(n_q: int, n_k: int, cq: int, ck: int, causal: bool,
                   window: int | None, q_offset: int) -> np.ndarray:
    """Statically enumerate (i, j) block pairs with any unmasked entry."""
    pairs = []
    for i in range(n_q):
        q_lo, q_hi = q_offset + i * cq, q_offset + i * cq + cq - 1
        for j in range(n_k):
            k_lo, k_hi = j * ck, j * ck + ck - 1
            if causal and k_lo > q_hi:
                continue
            if window is not None and k_hi < q_lo - window + 1:
                continue
            pairs.append((i, j))
    return np.asarray(pairs, np.int32).reshape(-1, 2)


def attention_xla_flash(q, k, v, *, causal=True, window=None,
                        q_chunk=512, kv_chunk=1024, q_offset: int = 0,
                        unroll: bool = False):
    b, sq, h, d = q.shape
    _, skv, hk, _ = k.shape
    g = h // hk
    cq, ck = min(q_chunk, sq), min(kv_chunk, skv)
    # pad to block multiples (padding keys are masked via position bounds)
    pq = (-sq) % cq
    pk = (-skv) % ck
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    n_q, n_k = (sq + pq) // cq, (skv + pk) // ck
    # Megatron-TP layout: expand KV to the full H query heads (local repeat
    # — KV is model-replicated when Hk doesn't divide the TP degree) and
    # shard the H dim over "model". The block dim (0) and the intra-block
    # seq dims stay UNSHARDED so the pair-scan's dynamic indexing is local;
    # without these hints GSPMD all-gathers every block each scan step.
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    h_ = h
    qb = q.reshape(b, n_q, cq, h_, d).transpose(1, 0, 3, 2, 4)
    kb = k.reshape(b, n_k, ck, h_, d).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(b, n_k, ck, h_, d).transpose(1, 0, 3, 2, 4)
    # qb: (nq, B, H, Cq, D); kb/vb: (nk, B, H, Ck, D)
    blk = (None, "batch", "heads", None, None)
    qb = shard_hint(qb, *blk)
    kb = shard_hint(kb, *blk)
    vb = shard_hint(vb, *blk)
    pairs = _visible_pairs(n_q, n_k, cq, ck, causal, window, q_offset)
    scale = 1.0 / math.sqrt(d)

    acc_o = shard_hint(jnp.zeros((n_q, b, h_, cq, d), jnp.float32), *blk)
    acc_m = shard_hint(jnp.full((n_q, b, h_, cq), NEG_INF, jnp.float32),
                       None, "batch", "heads", None)
    acc_l = shard_hint(jnp.zeros((n_q, b, h_, cq), jnp.float32),
                       None, "batch", "heads", None)

    def step(carry, pair):
        o, m, l = carry
        i, j = pair[0], pair[1]
        qi = jax.lax.dynamic_index_in_dim(qb, i, 0, keepdims=False)
        kj = jax.lax.dynamic_index_in_dim(kb, j, 0, keepdims=False)
        vj = jax.lax.dynamic_index_in_dim(vb, j, 0, keepdims=False)
        s = jnp.einsum("bhqd,bhkd->bhqk", qi, kj,
                       preferred_element_type=jnp.float32) * scale
        qpos = q_offset + i * cq + jnp.arange(cq)
        kpos = j * ck + jnp.arange(ck)
        ok = jnp.ones((cq, ck), bool)
        if causal:
            ok &= qpos[:, None] >= kpos[None, :]
        if window is not None:
            ok &= (qpos[:, None] - kpos[None, :]) < window
        ok &= (kpos < skv)[None, :]            # kv padding
        s = jnp.where(ok, s, NEG_INF)
        m_i = jax.lax.dynamic_index_in_dim(m, i, 0, keepdims=False)
        l_i = jax.lax.dynamic_index_in_dim(l, i, 0, keepdims=False)
        o_i = jax.lax.dynamic_index_in_dim(o, i, 0, keepdims=False)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_i - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_i * alpha + jnp.sum(p, axis=-1)
        o_new = o_i * alpha[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p.astype(vj.dtype), vj,
            preferred_element_type=jnp.float32)
        o = jax.lax.dynamic_update_index_in_dim(o, o_new, i, 0)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, i, 0)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, i, 0)
        return (o, m, l), None

    (acc_o, acc_m, acc_l), _ = jax.lax.scan(step, (acc_o, acc_m, acc_l),
                                            jnp.asarray(pairs),
                                            unroll=len(pairs) if unroll
                                            else 1)
    # acc_o: (nq, B, H, Cq, D) -> (B, nq*Cq, H, D)
    out = acc_o / jnp.maximum(acc_l[..., None], 1e-30)
    out = out.transpose(1, 0, 3, 2, 4).reshape(b, n_q * cq, h_, d)
    return out[:, :sq].astype(q.dtype)


# ---------------------------------------------------------------------------
# Dispatcher
# ---------------------------------------------------------------------------


def attention(q, k, v, *, causal=True, window=None, impl="xla_flash",
              q_chunk=512, kv_chunk=1024, q_offset: int = 0,
              unroll: bool = False) -> jax.Array:
    if impl == "naive" or (impl == "xla_flash" and q.shape[1] <= q_chunk
                           and k.shape[1] <= kv_chunk):
        return attention_naive(q, k, v, causal=causal, window=window,
                               q_offset=q_offset)
    if impl == "xla_flash":
        return attention_xla_flash(q, k, v, causal=causal, window=window,
                                   q_chunk=q_chunk, kv_chunk=kv_chunk,
                                   q_offset=q_offset, unroll=unroll)
    if impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops
        return fa_ops.flash_attention(q, k, v, causal=causal, window=window)
    raise ValueError(f"unknown attention impl {impl!r}")


# ---------------------------------------------------------------------------
# KV cache (decode)
# ---------------------------------------------------------------------------


class KVCache(NamedTuple):
    """Static-shape KV cache: (B, S_max, Hk, Dh) per layer, stacked on L."""

    k: jax.Array      # (L, B, S, Hk, D)
    v: jax.Array      # (L, B, S, Hk, D)

    @classmethod
    def zeros(cls, n_layers, batch, max_len, n_kv, d_head, dtype=jnp.bfloat16):
        shape = (n_layers, batch, max_len, n_kv, d_head)
        return cls(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))

    @classmethod
    def abstract(cls, n_layers, batch, max_len, n_kv, d_head,
                 dtype=jnp.bfloat16):
        shape = (n_layers, batch, max_len, n_kv, d_head)
        sds = jax.ShapeDtypeStruct(shape, dtype)
        return cls(sds, sds)


def cache_update(cache_k, cache_v, new_k, new_v, pos: jax.Array):
    """Write one decode step at position ``pos`` (scalar). new_*: (B,1,Hk,D)."""
    ck = jax.lax.dynamic_update_slice(cache_k, new_k.astype(cache_k.dtype),
                                      (0, pos, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache_v, new_v.astype(cache_v.dtype),
                                      (0, pos, 0, 0))
    return ck, cv


def decode_attention(q, cache_k, cache_v, pos: jax.Array,
                     window: int | None = None) -> jax.Array:
    """Single-token decode attention against a cache.

    q: (B, 1, H, D); cache: (B, S, Hk, D); pos: scalar index of the current
    token (already written to the cache). For sliding-window attention with
    a long cache, compute is restricted to a static window-sized slice —
    this is what makes ``long_500k`` sub-quadratic.
    """
    b, _, h, d = q.shape
    s_max = cache_k.shape[1]
    if window is not None and window < s_max:
        w = window
        start = jnp.clip(pos - (w - 1), 0, s_max - w)
        k_slc = jax.lax.dynamic_slice_in_dim(cache_k, start, w, axis=1)
        v_slc = jax.lax.dynamic_slice_in_dim(cache_v, start, w, axis=1)
        kpos = start + jnp.arange(w)
    else:
        k_slc, v_slc = cache_k, cache_v
        kpos = jnp.arange(s_max)
    hk = k_slc.shape[2]
    qg = _group(q, hk)
    scale = 1.0 / math.sqrt(d)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_slc,
                   preferred_element_type=jnp.float32) * scale
    ok = kpos <= pos
    if window is not None:
        ok &= kpos > pos - window
    s = jnp.where(ok[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v_slc.dtype), v_slc,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, 1, h, d).astype(q.dtype)
