"""Nougat-class high-quality parser: windowed-attention image encoder +
causal cross-attention text decoder (Swin->mBART, per Blecher et al. 2023),
adapted to TPU:

- 2D Swin windows become 1D windows over the flattened patch sequence with
  alternating half-window shifts (roll). On the MXU the windowed attention
  becomes a batched dense (W x W) attention — hardware-aligned when W is a
  multiple of 128. Documented deviation; attention *pattern* (local +
  shifted overlap) is preserved.
- The pixel->patch frontend is a stub per the modality rule: inputs are
  flattened patch vectors (pages, n_patches, patch*patch*3).

Pages are parsed individually at fixed (896, 672) resolution with
``pages_per_batch`` = B_p = 10 (paper §5.2), which normalizes task size.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.common import KeyGen, normal_init, param
from repro.configs.base import VitParserConfig
from repro.distributed.meshrules import shard_hint
from repro.models import attention as attn_lib
from repro.models.attention import KVCache
from repro.models.layers import (cross_entropy_loss, embed_lookup, gelu,
                                 rms_norm, softcap, swiglu)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_vit_parser(cfg: VitParserConfig, seed: int = 0,
                    abstract: bool = False):
    kg = None if abstract else KeyGen(seed)
    dtype = jnp.dtype(cfg.param_dtype)
    patch_dim = cfg.patch * cfg.patch * 3
    de, he, fe, Le = cfg.enc_d_model, cfg.enc_heads, cfg.enc_d_ff, cfg.enc_layers
    dd, hd, fd, Ld = cfg.dec_d_model, cfg.dec_heads, cfg.dec_d_ff, cfg.dec_layers
    dhe, dhd = de // he, dd // hd

    def mk(L, shape, axes, std):
        lead, laxes = ((L,), ("layers",)) if L else ((), ())
        return param(None if abstract else kg(), lead + shape, laxes + axes,
                     normal_init(std), dtype, abstract)

    enc_layer = {
        "ln1": mk(Le, (de,), ("d_model",), 0.0),
        "ln2": mk(Le, (de,), ("d_model",), 0.0),
        "wq": mk(Le, (de, he, dhe), ("d_model", "heads", "d_head"), de ** -0.5),
        "wk": mk(Le, (de, he, dhe), ("d_model", "heads", "d_head"), de ** -0.5),
        "wv": mk(Le, (de, he, dhe), ("d_model", "heads", "d_head"), de ** -0.5),
        "wo": mk(Le, (he, dhe, de), ("heads", "d_head", "d_model"), de ** -0.5),
        "w_in": mk(Le, (de, fe), ("d_model", "d_ff"), de ** -0.5),
        "w_out": mk(Le, (fe, de), ("d_ff", "d_model"), fe ** -0.5),
    }
    dec_layer = {
        "ln1": mk(Ld, (dd,), ("d_model",), 0.0),
        "ln_x": mk(Ld, (dd,), ("d_model",), 0.0),
        "ln2": mk(Ld, (dd,), ("d_model",), 0.0),
        "wq": mk(Ld, (dd, hd, dhd), ("d_model", "heads", "d_head"), dd ** -0.5),
        "wk": mk(Ld, (dd, hd, dhd), ("d_model", "heads", "d_head"), dd ** -0.5),
        "wv": mk(Ld, (dd, hd, dhd), ("d_model", "heads", "d_head"), dd ** -0.5),
        "wo": mk(Ld, (hd, dhd, dd), ("heads", "d_head", "d_model"), dd ** -0.5),
        "xq": mk(Ld, (dd, hd, dhd), ("d_model", "heads", "d_head"), dd ** -0.5),
        "xk": mk(Ld, (de, hd, dhd), ("d_model", "heads", "d_head"), de ** -0.5),
        "xv": mk(Ld, (de, hd, dhd), ("d_model", "heads", "d_head"), de ** -0.5),
        "xo": mk(Ld, (hd, dhd, dd), ("heads", "d_head", "d_model"), dd ** -0.5),
        "w_gate": mk(Ld, (dd, fd), ("d_model", "d_ff"), dd ** -0.5),
        "w_up": mk(Ld, (dd, fd), ("d_model", "d_ff"), dd ** -0.5),
        "w_down": mk(Ld, (fd, dd), ("d_ff", "d_model"), fd ** -0.5),
    }
    return {
        "patch_proj": mk(0, (patch_dim, de), (None, "d_model"),
                         patch_dim ** -0.5),
        "patch_pos": mk(0, (cfg.n_patches, de), ("patches", "d_model"), 0.02),
        "enc_layers": enc_layer,
        "enc_ln": mk(0, (de,), ("d_model",), 0.0),
        "tok_embed": param(None if abstract else kg(),
                           (cfg.vocab_size, dd), ("vocab", "d_model"),
                           normal_init(0.02), dtype, abstract),
        "dec_layers": dec_layer,
        "dec_ln": mk(0, (dd,), ("d_model",), 0.0),
        "lm_head": mk(0, (dd, cfg.vocab_size), ("d_model", "vocab"),
                      dd ** -0.5),
    }


# ---------------------------------------------------------------------------
# Encoder: 1D windowed attention with alternating shifts
# ---------------------------------------------------------------------------


def _window_attn(x, lp, cfg: VitParserConfig, shift: jax.Array):
    """x: (B, N, D) -> windowed self-attention, window size cfg.window."""
    b, n, d = x.shape
    w = cfg.window
    pad = (-n) % w
    x_sh = jnp.roll(x, -shift, axis=1)
    if pad:
        x_sh = jnp.pad(x_sh, ((0, 0), (0, pad), (0, 0)))
    xw = x_sh.reshape(b * ((n + pad) // w), w, d)
    q = jnp.einsum("bsd,dhk->bshk", xw, lp["wq"].astype(xw.dtype))
    k = jnp.einsum("bsd,dhk->bshk", xw, lp["wk"].astype(xw.dtype))
    v = jnp.einsum("bsd,dhk->bshk", xw, lp["wv"].astype(xw.dtype))
    o = attn_lib.attention_naive(q, k, v, causal=False)
    o = jnp.einsum("bshk,hkd->bsd", o, lp["wo"].astype(o.dtype))
    o = o.reshape(b, n + pad, d)[:, :n]
    return jnp.roll(o, shift, axis=1)


def encode_pages(params_raw, cfg: VitParserConfig, patches: jax.Array):
    """patches: (B_pages, n_patches, patch*patch*3) -> (B_pages, N, De)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    x = jnp.einsum("bnp,pd->bnd", patches.astype(cdt),
                   params_raw["patch_proj"].astype(cdt))
    x = x + params_raw["patch_pos"].astype(cdt)[None]
    x = shard_hint(x, "pages", "patches", "d_model")
    half = cfg.window // 2

    def layer(carry, inp):
        x, = carry
        lp, shift = inp
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        x = x + _window_attn(h, lp, cfg, shift)
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        h = gelu(jnp.einsum("bnd,df->bnf", h, lp["w_in"].astype(cdt)))
        h = shard_hint(h, "pages", "patches", "d_ff")
        x = x + jnp.einsum("bnf,fd->bnd", h, lp["w_out"].astype(cdt))
        x = shard_hint(x, "pages", "patches", "d_model")
        return (x,), None

    shifts = jnp.asarray([0 if i % 2 == 0 else half
                          for i in range(cfg.enc_layers)])
    layer_fn = layer
    if cfg.remat:
        layer_fn = jax.checkpoint(layer,
                                  policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.scan_layers:
        (x,), _ = jax.lax.scan(layer_fn, (x,),
                               (params_raw["enc_layers"], shifts))
    else:
        for i in range(cfg.enc_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i],
                                        params_raw["enc_layers"])
            (x,), _ = layer_fn((x,), (lp, shifts[i]))
    return rms_norm(x, params_raw["enc_ln"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# Decoder
# ---------------------------------------------------------------------------


def _dec_layer_fn(cfg: VitParserConfig, memory, positions, causal=True):
    cdt = jnp.dtype(cfg.compute_dtype)

    def layer(x, lp):
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, lp["wq"].astype(cdt))
        k = jnp.einsum("bsd,dhk->bshk", h, lp["wk"].astype(cdt))
        v = jnp.einsum("bsd,dhk->bshk", h, lp["wv"].astype(cdt))
        from repro.models.layers import apply_rope
        q = apply_rope(q, positions, 1e4)
        k = apply_rope(k, positions, 1e4)
        o = attn_lib.attention(q, k, v, causal=causal, impl="naive")
        x = x + jnp.einsum("bshk,hkd->bsd", o, lp["wo"].astype(cdt))
        # cross attention
        h = rms_norm(x, lp["ln_x"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, lp["xq"].astype(cdt))
        k = jnp.einsum("bnd,dhk->bnhk", memory, lp["xk"].astype(cdt))
        v = jnp.einsum("bnd,dhk->bnhk", memory, lp["xv"].astype(cdt))
        o = attn_lib.attention_naive(q, k, v, causal=False)
        x = x + jnp.einsum("bshk,hkd->bsd", o, lp["xo"].astype(cdt))
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        z = swiglu(jnp.einsum("bsd,df->bsf", h, lp["w_gate"].astype(cdt)),
                   jnp.einsum("bsd,df->bsf", h, lp["w_up"].astype(cdt)))
        z = shard_hint(z, "pages", "seq", "d_ff")
        x = x + jnp.einsum("bsf,fd->bsd", z, lp["w_down"].astype(cdt))
        return shard_hint(x, "pages", "seq", "d_model"), None

    return layer


def decode_logits(params_raw, cfg: VitParserConfig, memory, tokens):
    """Teacher-forced decoder pass. memory (B, N, De); tokens (B, T)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    x = embed_lookup(params_raw["tok_embed"].astype(cdt), tokens)
    positions = jnp.arange(tokens.shape[1])
    layer = _dec_layer_fn(cfg, memory, positions)
    fn = layer
    if cfg.remat:
        fn = jax.checkpoint(layer,
                            policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(lambda c, lp: fn(c, lp), x,
                            params_raw["dec_layers"])
    else:
        for i in range(cfg.dec_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i],
                                        params_raw["dec_layers"])
            x, _ = fn(x, lp)
    x = rms_norm(x, params_raw["dec_ln"], cfg.norm_eps)
    return jnp.einsum("bsd,dv->bsv", x, params_raw["lm_head"].astype(cdt))


def parser_loss(params_raw, cfg: VitParserConfig, batch):
    """Training objective: CE of target page text given page patches."""
    memory = encode_pages(params_raw, cfg, batch["patches"])
    logits = decode_logits(params_raw, cfg, memory, batch["tokens"])
    return cross_entropy_loss(logits, batch["labels"], batch.get("mask")), {}


# -- autoregressive generation (engine path, small scale) -------------------


class DecState(NamedTuple):
    cache: KVCache
    xk: jax.Array       # cross-attn keys  (L, B, N, H, Dh)
    xv: jax.Array


def init_dec_state(params_raw, cfg: VitParserConfig, memory):
    cdt = jnp.dtype(cfg.compute_dtype)
    xk = jnp.einsum("bnd,ldhk->lbnhk", memory,
                    params_raw["dec_layers"]["xk"].astype(cdt))
    xv = jnp.einsum("bnd,ldhk->lbnhk", memory,
                    params_raw["dec_layers"]["xv"].astype(cdt))
    xk = shard_hint(xk, "layers", "pages", "patches", "heads", "d_head")
    xv = shard_hint(xv, "layers", "pages", "patches", "heads", "d_head")
    b = memory.shape[0]
    dh = cfg.dec_d_model // cfg.dec_heads
    cache = KVCache.zeros(cfg.dec_layers, b, cfg.max_dec_len, cfg.dec_heads,
                          dh, cdt)
    return DecState(cache, xk, xv)


def dec_step(params_raw, cfg: VitParserConfig, tok, state: DecState, pos):
    """One decode token: tok (B, 1) -> logits (B, V), new state."""
    cdt = jnp.dtype(cfg.compute_dtype)
    x = embed_lookup(params_raw["tok_embed"].astype(cdt), tok)
    positions = jnp.full((tok.shape[0], 1), pos)

    def layer(x, inp):
        lp, ck, cv, xk, xv = inp
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, lp["wq"].astype(cdt))
        k = jnp.einsum("bsd,dhk->bshk", h, lp["wk"].astype(cdt))
        v = jnp.einsum("bsd,dhk->bshk", h, lp["wv"].astype(cdt))
        from repro.models.layers import apply_rope
        q = apply_rope(q, positions, 1e4)
        k = apply_rope(k, positions, 1e4)
        ck, cv = attn_lib.cache_update(ck, cv, k, v, pos)
        o = attn_lib.decode_attention(q, ck, cv, pos)
        x = x + jnp.einsum("bshk,hkd->bsd", o, lp["wo"].astype(cdt))
        h = rms_norm(x, lp["ln_x"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, lp["xq"].astype(cdt))
        s = jnp.einsum("bqhd,bnhd->bhqn", q, xk,
                       preferred_element_type=jnp.float32)
        s = s * (q.shape[-1] ** -0.5)
        p = jax.nn.softmax(s, axis=-1).astype(cdt)
        o = jnp.einsum("bhqn,bnhd->bqhd", p, xv)
        x = x + jnp.einsum("bqhd,hdm->bqm", o, lp["xo"].astype(cdt))
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        z = swiglu(jnp.einsum("bsd,df->bsf", h, lp["w_gate"].astype(cdt)),
                   jnp.einsum("bsd,df->bsf", h, lp["w_up"].astype(cdt)))
        x = x + jnp.einsum("bsf,fd->bsd", z, lp["w_down"].astype(cdt))
        return x, (ck, cv)

    if cfg.scan_layers:
        x, (nk, nv) = jax.lax.scan(
            layer, x, (params_raw["dec_layers"], state.cache.k,
                       state.cache.v, state.xk, state.xv))
    else:
        nks, nvs = [], []
        for i in range(cfg.dec_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i],
                                        params_raw["dec_layers"])
            x, (ck, cv) = layer(x, (lp, state.cache.k[i], state.cache.v[i],
                                    state.xk[i], state.xv[i]))
            nks.append(ck)
            nvs.append(cv)
        nk, nv = jnp.stack(nks), jnp.stack(nvs)
    x = rms_norm(x[:, -1:], params_raw["dec_ln"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params_raw["lm_head"].astype(cdt))
    return logits[:, 0], DecState(KVCache(nk, nv), state.xk, state.xv)


def generate(params_raw, cfg: VitParserConfig, patches, max_len: int,
             bos_id: int = 1):
    """Greedy autoregressive page parse (used by the engine at small scale)."""
    memory = encode_pages(params_raw, cfg, patches)
    state = init_dec_state(params_raw, cfg, memory)
    b = patches.shape[0]
    tok = jnp.full((b, 1), bos_id, jnp.int32)

    def step(carry, pos):
        tok, state = carry
        logits, state = dec_step(params_raw, cfg, tok, state, pos)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return (nxt, state), nxt[:, 0]

    (_, _), out = jax.lax.scan(step, (tok, state), jnp.arange(max_len))
    return out.T  # (B, max_len)
