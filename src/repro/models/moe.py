"""Mixture-of-Experts FFN with expert parallelism.

Two routers:

- ``topk``   — the architectures' own routing (OLMoE top-8 / grok top-2):
  token-choice softmax top-k with per-expert capacity, sort-based
  dispatch (no (T, E, C) one-hot), load-balancing aux loss.
- ``budget`` — AdaParse-style *budget-constrained expert-choice* routing
  (beyond-paper option): every expert takes exactly its ⌊α·T⌋ slot budget
  of the highest-scoring tokens, the direct MoE analogue of the paper's
  per-batch ⌊αk⌋ scheduling rule (App. C).

Expert weights carry logical axes ("experts", "d_model", "expert_ff") so
the mesh rules automatically choose EP (experts % model == 0, e.g. OLMoE
64e on model=16) or TP-within-expert (grok 8e -> d_ff sharded) layouts.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.common import KeyGen, ceil_div, normal_init, param
from repro.configs.base import MoEConfig
from repro.distributed.meshrules import shard_hint  # noqa: F401 (API)
from repro.models.layers import swiglu


def init_moe(kg: KeyGen | None, d_model: int, cfg: MoEConfig, dtype,
             abstract=False, layers: int | None = None):
    E, Fe = cfg.n_experts, cfg.d_ff_expert
    lead = () if layers is None else (layers,)
    lax_ = () if layers is None else ("layers",)

    def mk(shape, axes, std):
        return param(None if abstract else kg(), lead + shape, lax_ + axes,
                     normal_init(std), dtype, abstract)

    return {
        "router": mk((d_model, E), ("d_model", "experts"),
                     1.0 / math.sqrt(d_model)),
        "w_gate": mk((E, d_model, Fe), ("experts", "d_model", "expert_ff"),
                     1.0 / math.sqrt(d_model)),
        "w_up": mk((E, d_model, Fe), ("experts", "d_model", "expert_ff"),
                   1.0 / math.sqrt(d_model)),
        "w_down": mk((E, Fe, d_model), ("experts", "expert_ff", "d_model"),
                     1.0 / math.sqrt(Fe)),
    }


def _expert_ffn(buf: jax.Array, p, model_axis: str | None = None) -> jax.Array:
    """buf: (E, C, D) -> (E, C, D), bulk grouped matmuls. When the Fe dim
    is sharded over ``model_axis`` (expert slicing), the down-projection's
    partial sums are psum-reduced."""
    h = swiglu(
        jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(buf.dtype)),
        jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(buf.dtype)),
    )
    # NOTE: when Fe is model-sharded the result is a PARTIAL sum; the
    # caller reduces after the (linear) combine — psum(y (T,D)) moves
    # 2.5-10x fewer bytes than psum(out_buf (E,C,D))
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(buf.dtype))


def moe_ffn(x: jax.Array, p, cfg: MoEConfig):
    """x: (B, S, D). Returns (y, aux_loss).

    Under a mesh, the layer runs as an explicit shard_map: tokens stay on
    their data shard (the dispatch is node-local — the same partition
    argument as AdaParse's per-node α budgets), expert FFN weights are
    tensor-parallel on d_ff over "model" ("expert slicing": one psum after
    the down-projection, NO all-to-all). This sidesteps GSPMD's replicated
    scatter strategies, which blow HBM at grok scale.
    """
    from repro.distributed.meshrules import current_rules
    rules = current_rules()
    if rules is not None and rules.mesh.devices.size > 1:
        return _moe_shardmap(x, p, cfg, rules)
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    y, aux = _moe_local(xt, p, cfg)
    return y.reshape(b, s, d), aux


def _moe_local(xt, p, cfg: MoEConfig, model_axis: str | None = None,
               data_axes: tuple = ()):
    """Single-shard MoE over local tokens xt (T, D). Weight slices may be
    Fe-sharded (model_axis set -> psum after down-proj)."""
    router_dtype = jnp.dtype(cfg.router_dtype)
    logits = jnp.einsum("td,de->te", xt.astype(router_dtype),
                        p["router"].astype(router_dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)   # (T, E)
    if cfg.router == "budget":
        y, aux = _budget_route(xt, probs, p, cfg)
    else:
        y, aux = _topk_route(xt, probs, p, cfg, model_axis)
    if data_axes:
        aux = jax.lax.pmean(aux, data_axes)
    return y, aux


def _moe_shardmap(x: jax.Array, p, cfg: MoEConfig, rules):
    from jax.sharding import PartitionSpec as P
    mesh = rules.mesh
    b, s, d = x.shape
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    has_model = "model" in mesh.shape
    xt = x.reshape(b * s, d)

    def local(xt_loc, pw):
        y, aux = _moe_local(xt_loc, pw, cfg,
                            model_axis="model" if has_model else None,
                            data_axes=data_axes)
        return y, aux

    w_specs = {
        "router": P(),
        "w_gate": P(None, None, "model" if has_model else None),
        "w_up": P(None, None, "model" if has_model else None),
        "w_down": P(None, "model" if has_model else None, None),
    }
    tok_spec = P(data_axes if len(data_axes) > 1 else
                 (data_axes[0] if data_axes else None), None)
    y, aux = jax.shard_map(
        local, mesh=mesh, in_specs=(tok_spec, w_specs),
        out_specs=(tok_spec, P()))(xt, p)
    return y.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# Token-choice top-k (sort-based dispatch)
# ---------------------------------------------------------------------------


def _topk_route(xt, probs, p, cfg: MoEConfig, model_axis=None):
    t, d = xt.shape
    E, k = cfg.n_experts, cfg.top_k
    cap = ceil_div(int(cfg.capacity_factor * t * k), E)
    cap = max(cap, 1)

    gate, eids = jax.lax.top_k(probs, k)                 # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    flat_e = eids.reshape(-1)                            # (T*k,)
    flat_tok = jnp.repeat(jnp.arange(t), k)
    flat_gate = gate.reshape(-1)

    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_tok[order], flat_gate[order]
    counts = jnp.bincount(flat_e, length=E)              # (E,)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(t * k) - starts[se]                 # position within expert
    keep = pos < cap
    slot = jnp.where(keep, pos, cap)                     # overflow -> scratch row

    rows = se * (cap + 1) + slot                         # flat row ids
    buf_flat = jnp.zeros((E * (cap + 1), d), xt.dtype) \
        .at[rows].set(jnp.take(xt, st, axis=0))
    buf = buf_flat.reshape(E, cap + 1, d)[:, :cap]

    out_buf = _expert_ffn(buf, p, model_axis)            # (E, cap, D)
    out_flat = jnp.concatenate(
        [out_buf, jnp.zeros((E, 1, d), out_buf.dtype)], axis=1
    ).reshape(E * (cap + 1), d)
    contrib = jnp.take(out_flat, rows, axis=0) \
        * (sg * keep)[:, None].astype(out_buf.dtype)
    y = jnp.zeros((t, d), contrib.dtype).at[st].add(contrib)
    if model_axis is not None:
        y = jax.lax.psum(y, model_axis)

    # load-balance aux loss (Switch): E * sum_e f_e * P_e
    f = counts.astype(jnp.float32) / (t * k)
    pmean = probs.mean(axis=0)
    aux = cfg.aux_loss_weight * E * jnp.sum(f * pmean)
    return y, aux


# ---------------------------------------------------------------------------
# AdaParse budget (expert-choice with global slot budget)
# ---------------------------------------------------------------------------


def _budget_route(xt, probs, p, cfg: MoEConfig, model_axis=None):
    t, d = xt.shape
    E = cfg.n_experts
    cap = max(int(cfg.budget_alpha * t), 1)

    # each expert picks its top-cap tokens (per-batch/per-node sort rule
    # of App. C — node-local budgets, embarrassingly parallel)
    scores = probs.T                                     # (E, T)
    g, tok = jax.lax.top_k(scores, cap)                  # (E, cap)
    buf = jnp.take(xt, tok.reshape(-1), axis=0) \
        .reshape(E, cap, d)                              # gather
    out_buf = _expert_ffn(buf, p, model_axis)
    w = g[..., None].astype(out_buf.dtype)
    y = jnp.zeros((t, d), out_buf.dtype)
    y = y.at[tok.reshape(-1)].add((out_buf * w).reshape(E * cap, d))
    if model_axis is not None:
        y = jax.lax.psum(y, model_axis)
    # budget routing is balanced by construction; aux regularizes entropy
    aux = cfg.aux_loss_weight * jnp.mean(
        jnp.sum(probs * jnp.log(jnp.clip(probs, 1e-9)), axis=-1))
    return y, aux
