"""Recsys model zoo: DLRM (MLPerf), DeepFM, AutoInt, DIEN.

Common interface:
    init_recsys(cfg, seed, abstract) -> Param tree
    recsys_logits(params_raw, cfg, batch) -> (B,) logits
    recsys_loss(params_raw, cfg, batch) -> BCE loss, metrics
    recsys_retrieval(params_raw, cfg, batch, k) -> top-k (scores, ids)

batch: dense (B, n_dense) float, sparse (B, n_sparse) int32 field-local
ids, labels (B,) float; DIEN adds hist (B, T), hist_mask (B, T),
target (B,).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common import KeyGen, normal_init, param
from repro.configs.base import RecsysConfig
from repro.distributed.meshrules import shard_hint
from repro.models.recsys import embedding as emb
from repro.models.recsys import interactions as inter


def _mk_mlp(kg, dims, dtype, abstract, hidden_axis="mlp_hidden"):
    layers = []
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        last = i == len(dims) - 2
        layers.append({
            "w": param(None if abstract else kg(), (a, b),
                       (hidden_axis if i > 0 else None,
                        None if last else hidden_axis),
                       normal_init(a ** -0.5), dtype, abstract),
            "b": param(None, (b,), (None if last else hidden_axis,),
                       lambda k, s, t: jnp.zeros(s, t), dtype, abstract),
        })
    return layers


def _mlp(x, layers, act=jax.nn.relu, final_act=None):
    for i, p in enumerate(layers):
        x = x @ p["w"].astype(x.dtype) + p["b"].astype(x.dtype)
        if i < len(layers) - 1:
            x = act(x)
        elif final_act is not None:
            x = final_act(x)
    return x


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_recsys(cfg: RecsysConfig, seed: int = 0, abstract: bool = False):
    kg = None if abstract else KeyGen(seed)
    dtype = jnp.dtype(cfg.param_dtype)
    D = cfg.embed_dim
    table, offsets = emb.init_table(kg, cfg.vocab_sizes, D, dtype, abstract)
    p: dict = {"table": table}

    if cfg.kind == "dlrm":
        p["bot"] = _mk_mlp(kg, (cfg.n_dense,) + cfg.bot_mlp, dtype, abstract)
        f = cfg.n_sparse + 1
        d_int = f * (f - 1) // 2 + cfg.bot_mlp[-1]
        p["top"] = _mk_mlp(kg, (d_int,) + cfg.top_mlp, dtype, abstract)
    elif cfg.kind == "deepfm":
        p["lin_table"] = emb.init_table(kg, cfg.vocab_sizes, 1, dtype,
                                        abstract)[0]
        p["bias"] = param(None, (1,), (None,),
                          lambda k, s, t: jnp.zeros(s, t), dtype, abstract)
        p["deep"] = _mk_mlp(kg, (cfg.n_sparse * D,) + cfg.mlp + (1,),
                            dtype, abstract)
    elif cfg.kind == "autoint":
        d_in = D
        p["attn"] = []
        dh = cfg.d_attn // cfg.n_attn_heads
        for _ in range(cfg.n_attn_layers):
            p["attn"].append({
                "wq": param(None if abstract else kg(),
                            (d_in, cfg.n_attn_heads, dh),
                            (None, None, None), normal_init(d_in ** -0.5),
                            dtype, abstract),
                "wk": param(None if abstract else kg(),
                            (d_in, cfg.n_attn_heads, dh),
                            (None, None, None), normal_init(d_in ** -0.5),
                            dtype, abstract),
                "wv": param(None if abstract else kg(),
                            (d_in, cfg.n_attn_heads, dh),
                            (None, None, None), normal_init(d_in ** -0.5),
                            dtype, abstract),
                "w_res": param(None if abstract else kg(),
                               (d_in, cfg.d_attn), (None, None),
                               normal_init(d_in ** -0.5), dtype, abstract),
            })
            d_in = cfg.d_attn
        p["out"] = _mk_mlp(kg, (cfg.n_sparse * cfg.d_attn, 1), dtype, abstract)
    elif cfg.kind == "dien":
        d_item = 2 * D                      # item + category embeddings
        p["gru"] = inter.init_gru(kg, d_item, cfg.gru_dim, dtype, abstract)
        p["augru"] = inter.init_gru(kg, cfg.gru_dim, cfg.gru_dim, dtype,
                                    abstract)
        p["att"] = {
            "w1": param(None if abstract else kg(), (4 * cfg.gru_dim, 64),
                        (None, None), normal_init((4 * cfg.gru_dim) ** -0.5),
                        dtype, abstract),
            "b1": param(None, (64,), (None,),
                        lambda k, s, t: jnp.zeros(s, t), dtype, abstract),
            "w2": param(None if abstract else kg(), (64, 1), (None, None),
                        normal_init(64 ** -0.5), dtype, abstract),
            "b2": param(None, (1,), (None,),
                        lambda k, s, t: jnp.zeros(s, t), dtype, abstract),
        }
        p["hist_proj"] = param(None if abstract else kg(),
                               (d_item, cfg.gru_dim), (None, None),
                               normal_init(d_item ** -0.5), dtype, abstract)
        d_final = cfg.gru_dim + d_item
        p["mlp"] = _mk_mlp(kg, (d_final,) + cfg.mlp + (1,), dtype, abstract)
    else:
        raise ValueError(cfg.kind)
    del offsets  # static — recomputed from cfg at trace time
    return p


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def recsys_logits(params_raw, cfg: RecsysConfig, batch: dict) -> jax.Array:
    cdt = jnp.dtype(cfg.compute_dtype)
    table = params_raw["table"].astype(cdt)
    offsets = jnp.asarray(emb.table_offsets(cfg.vocab_sizes)[0]
                          .astype("int32"))

    if cfg.kind == "dlrm":
        dense = batch["dense"].astype(cdt)
        bot = _mlp(dense, params_raw["bot"], final_act=jax.nn.relu)
        vecs = emb.lookup_fields(table, offsets, batch["sparse"])
        allv = jnp.concatenate([bot[:, None, :], vecs], axis=1)
        z = inter.dot_interaction(allv)
        z = jnp.concatenate([bot, z], axis=-1)
        return _mlp(z, params_raw["top"])[:, 0]

    if cfg.kind == "deepfm":
        vecs = emb.lookup_fields(table, offsets, batch["sparse"])
        lin = emb.lookup_fields(params_raw["lin_table"].astype(cdt), offsets,
                                batch["sparse"])[..., 0].sum(-1)
        fm = inter.fm_interaction(vecs)
        deep = _mlp(vecs.reshape(vecs.shape[0], -1), params_raw["deep"])[:, 0]
        return lin + fm + deep + params_raw["bias"].astype(cdt)[0]

    if cfg.kind == "autoint":
        x = emb.lookup_fields(table, offsets, batch["sparse"])
        for lp in params_raw["attn"]:
            x = inter.autoint_layer(x, lp, cfg.n_attn_heads)
        return _mlp(x.reshape(x.shape[0], -1), params_raw["out"])[:, 0]

    if cfg.kind == "dien":
        # hist (B, T) item ids + implicit category = id hashed into field 2
        hist_i = jnp.take(table, batch["hist"], axis=0)
        hist_c = jnp.take(table, batch["hist_cat"], axis=0)
        hist = jnp.concatenate([hist_i, hist_c], axis=-1)      # (B, T, 2D)
        tgt = jnp.concatenate(
            [jnp.take(table, batch["target"], axis=0),
             jnp.take(table, batch["target_cat"], axis=0)], axis=-1)
        hs = inter.gru_scan(hist, params_raw["gru"],
                            unroll=cfg.unroll_gru)              # (B, T, H)
        tgt_h = tgt @ params_raw["hist_proj"].astype(cdt)
        att = inter.attention_scores(hs, tgt_h, params_raw["att"])
        mask = batch.get("hist_mask")
        if mask is not None:
            att = jnp.where(mask > 0, att, -1e30)
        att = jax.nn.softmax(att.astype(jnp.float32), axis=-1).astype(cdt)
        h_final = inter.augru_scan(hs, att, params_raw["augru"],
                                   unroll=cfg.unroll_gru)
        z = jnp.concatenate([h_final, tgt], axis=-1)
        return _mlp(z, params_raw["mlp"])[:, 0]

    raise ValueError(cfg.kind)


def recsys_loss(params_raw, cfg: RecsysConfig, batch: dict):
    logits = recsys_logits(params_raw, cfg, batch).astype(jnp.float32)
    y = batch["labels"].astype(jnp.float32)
    loss = jnp.mean(jnp.maximum(logits, 0) - logits * y
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))
    return loss, {"bce": loss}


def recsys_scores(params_raw, cfg: RecsysConfig, batch: dict) -> jax.Array:
    """Serving: sigmoid CTR scores."""
    return jax.nn.sigmoid(recsys_logits(params_raw, cfg, batch)
                          .astype(jnp.float32))


def recsys_retrieval(params_raw, cfg: RecsysConfig, batch: dict,
                     k: int = 100):
    """retrieval_cand cell: one user context scored against n_candidates
    items via a single batched dot over the (sharded) item block of the
    embedding table. batch: user_query (B, D), cand_offset/cand_rows define
    the candidate row range of the table."""
    cdt = jnp.dtype(cfg.compute_dtype)
    table = params_raw["table"].astype(cdt)
    cands = jax.lax.dynamic_slice_in_dim(
        table, batch.get("cand_offset", 0),
        batch["n_candidates"], axis=0)
    return emb.retrieval_topk(batch["user_query"].astype(cdt), cands, k)
