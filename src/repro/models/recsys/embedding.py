"""Sharded embedding tables + EmbeddingBag.

JAX has no native ``nn.EmbeddingBag`` and no CSR sparse — the bag is built
from ``jnp.take`` + ``jax.ops.segment_sum`` (taxonomy §RecSys). All field
tables are concatenated into ONE row-sharded table (rows over
``("table_rows",)`` -> mesh ``model`` then ``data``) so a batch lookup is a
single gather and the training scatter-add is a single segment-sum — this
is the all-to-all hot path of the recsys cells.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.common import KeyGen, normal_init, param, round_up
from repro.distributed.meshrules import shard_hint


def table_offsets(vocab_sizes, pad_to: int = 1) -> tuple[np.ndarray, int]:
    """Per-field row offsets into the concatenated table (+ padded total)."""
    offs = np.zeros(len(vocab_sizes), np.int64)
    np.cumsum(np.asarray(vocab_sizes[:-1], np.int64), out=offs[1:])
    total = int(np.sum(vocab_sizes))
    return offs, round_up(total, pad_to)


def init_table(kg: KeyGen | None, vocab_sizes, dim: int, dtype,
               abstract=False, pad_to: int = 512):
    offs, total = table_offsets(vocab_sizes, pad_to)
    table = param(None if abstract else kg(), (total, dim),
                  ("table_rows", "embed_dim"),
                  normal_init(dim ** -0.5), dtype, abstract)
    return table, jnp.asarray(offs)


def lookup_fields(table: jax.Array, offsets: jax.Array,
                  ids: jax.Array) -> jax.Array:
    """ids (B, F) per-field local ids -> (B, F, D) embeddings."""
    flat = ids + offsets[None, :]
    out = jnp.take(table, flat, axis=0)
    return shard_hint(out, "batch", "fields", "embed_dim")


def embedding_bag(table: jax.Array, ids: jax.Array,
                  mask: jax.Array | None = None,
                  combiner: str = "sum") -> jax.Array:
    """Fixed-shape bag: ids (B, L) -> (B, D). mask (B, L) marks valid ids."""
    emb = jnp.take(table, ids, axis=0)                    # (B, L, D)
    if mask is not None:
        emb = emb * mask[..., None].astype(emb.dtype)
    if combiner == "sum":
        return emb.sum(axis=1)
    if combiner == "mean":
        denom = (mask.sum(axis=1, keepdims=True) if mask is not None
                 else jnp.full((ids.shape[0], 1), ids.shape[1]))
        return emb.sum(axis=1) / jnp.maximum(denom, 1.0)
    if combiner == "max":
        neg = jnp.finfo(emb.dtype).min
        if mask is not None:
            emb = jnp.where(mask[..., None] > 0, emb, neg)
        return emb.max(axis=1)
    raise ValueError(combiner)


def embedding_bag_ragged(table: jax.Array, flat_ids: jax.Array,
                         bag_ids: jax.Array, n_bags: int,
                         weights: jax.Array | None = None,
                         combiner: str = "sum") -> jax.Array:
    """Ragged bag: flat_ids (T,), bag_ids (T,) -> (n_bags, D).

    The canonical take+segment_sum EmbeddingBag (torch parity op).
    """
    emb = jnp.take(table, flat_ids, axis=0)               # (T, D)
    if weights is not None:
        emb = emb * weights[:, None].astype(emb.dtype)
    s = jax.ops.segment_sum(emb, bag_ids, num_segments=n_bags)
    if combiner == "sum":
        return s
    if combiner == "mean":
        cnt = jax.ops.segment_sum(jnp.ones_like(flat_ids, emb.dtype),
                                  bag_ids, num_segments=n_bags)
        return s / jnp.maximum(cnt, 1.0)[:, None]
    raise ValueError(combiner)


def retrieval_topk(query: jax.Array, item_table: jax.Array,
                   k: int = 100) -> tuple[jax.Array, jax.Array]:
    """Score query (B, D) against all candidates (N, D) via one batched dot
    (no loop), return top-k (scores, ids). The ``retrieval_cand`` cell."""
    scores = jnp.einsum("bd,nd->bn", query, item_table.astype(query.dtype))
    scores = shard_hint(scores, "batch", "candidates")
    return jax.lax.top_k(scores, k)
