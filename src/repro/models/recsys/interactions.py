"""Feature-interaction ops: DLRM dot, FM, AutoInt self-attention, DIEN
GRU/AUGRU (attentional update-gate GRU)."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp


def dot_interaction(vecs: jax.Array, keep_self: bool = False) -> jax.Array:
    """DLRM pairwise dots. vecs (B, F, D) -> (B, F*(F-1)/2 [+F])."""
    b, f, d = vecs.shape
    g = jnp.einsum("bfd,bgd->bfg", vecs, vecs)
    iu, ju = np.triu_indices(f, k=0 if keep_self else 1)
    return g[:, iu, ju]


def fm_interaction(vecs: jax.Array) -> jax.Array:
    """2nd-order FM term: 0.5 * sum_d ((Σ_f v)^2 - Σ_f v^2). (B, F, D)->(B,)."""
    s = vecs.sum(axis=1)
    sq = jnp.square(vecs).sum(axis=1)
    return 0.5 * (jnp.square(s) - sq).sum(axis=-1)


def autoint_layer(x: jax.Array, p: dict, n_heads: int) -> jax.Array:
    """Multi-head self-attention over feature fields with ReLU residual.

    x (B, F, D_in); p: wq/wk/wv (D_in, H, Dh), w_res (D_in, H*Dh).
    """
    q = jnp.einsum("bfd,dhk->bfhk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bfd,dhk->bfhk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bfd,dhk->bfhk", x, p["wv"].astype(x.dtype))
    s = jnp.einsum("bfhk,bghk->bhfg", q, k,
                   preferred_element_type=jnp.float32)
    a = jax.nn.softmax(s * (q.shape[-1] ** -0.5), axis=-1).astype(x.dtype)
    o = jnp.einsum("bhfg,bghk->bfhk", a, v)
    o = o.reshape(x.shape[0], x.shape[1], -1)
    res = jnp.einsum("bfd,de->bfe", x, p["w_res"].astype(x.dtype))
    return jax.nn.relu(o + res)


# ---------------------------------------------------------------------------
# GRU / AUGRU (DIEN)
# ---------------------------------------------------------------------------


def gru_scan(x: jax.Array, p: dict, h0: jax.Array | None = None,
             unroll: bool = False) -> jax.Array:
    """GRU over time. x (B, T, D) -> hidden states (B, T, H)."""
    b, t, d = x.shape
    h_dim = p["wh_z"].shape[1]
    h0 = jnp.zeros((b, h_dim), x.dtype) if h0 is None else h0

    def cell(h, xt):
        z = jax.nn.sigmoid(xt @ p["wx_z"] + h @ p["wh_z"] + p["b_z"])
        r = jax.nn.sigmoid(xt @ p["wx_r"] + h @ p["wh_r"] + p["b_r"])
        n = jnp.tanh(xt @ p["wx_n"] + (r * h) @ p["wh_n"] + p["b_n"])
        h = (1 - z) * n + z * h
        return h, h

    _, hs = jax.lax.scan(cell, h0, x.transpose(1, 0, 2),
                         unroll=x.shape[1] if unroll else 1)
    return hs.transpose(1, 0, 2)


def augru_scan(x: jax.Array, att: jax.Array, p: dict,
               h0: jax.Array | None = None, unroll: bool = False) -> jax.Array:
    """AUGRU: attention-scaled update gate (DIEN interest evolution).

    x (B, T, D); att (B, T) attention scores; returns final hidden (B, H).
    """
    b, t, d = x.shape
    h_dim = p["wh_z"].shape[1]
    h0 = jnp.zeros((b, h_dim), x.dtype) if h0 is None else h0

    def cell(h, inp):
        xt, at = inp
        z = jax.nn.sigmoid(xt @ p["wx_z"] + h @ p["wh_z"] + p["b_z"])
        z = z * at[:, None]                 # attentional update gate
        r = jax.nn.sigmoid(xt @ p["wx_r"] + h @ p["wh_r"] + p["b_r"])
        n = jnp.tanh(xt @ p["wx_n"] + (r * h) @ p["wh_n"] + p["b_n"])
        h = (1 - z) * h + z * n
        return h, None

    h, _ = jax.lax.scan(cell, h0, (x.transpose(1, 0, 2), att.T),
                        unroll=x.shape[1] if unroll else 1)
    return h


def init_gru(kg, d_in: int, d_hidden: int, dtype, abstract=False):
    from repro.common import normal_init, param

    def mk(shape, std):
        return param(None if abstract else kg(), shape,
                     (None,) * len(shape), normal_init(std), dtype, abstract)

    def mkz(shape):
        return param(None, shape, (None,) * len(shape),
                     lambda k, s, t: jnp.zeros(s, t), dtype, abstract)

    p = {}
    for g in ("z", "r", "n"):
        p[f"wx_{g}"] = mk((d_in, d_hidden), d_in ** -0.5)
        p[f"wh_{g}"] = mk((d_hidden, d_hidden), d_hidden ** -0.5)
        p[f"b_{g}"] = mkz((d_hidden,))
    return p


def attention_scores(hist: jax.Array, target: jax.Array, p: dict) -> jax.Array:
    """DIN-style attention: MLP([h, t, h*t, h-t]) -> logits (B, T)."""
    b, t, d = hist.shape
    tgt = jnp.broadcast_to(target[:, None, :], (b, t, d))
    feat = jnp.concatenate([hist, tgt, hist * tgt, hist - tgt], axis=-1)
    h = jax.nn.silu(feat @ p["w1"] + p["b1"])
    return (h @ p["w2"] + p["b2"])[..., 0]
