"""Layer-wise neighbor sampler (GraphSAGE-style) for ``minibatch_lg``.

Host-side (numpy) over a CSR adjacency; emits *static-shape* padded device
batches so the jitted train step never recompiles:

- seeds: (batch_nodes,) target nodes
- hop h with fanout f_h: every frontier node draws f_h neighbors with
  replacement (degree-0 nodes self-loop), giving a fixed edge count
  n_frontier * f_h per hop.
- all sampled nodes are compacted into a local index space; edges are
  (src_local, dst_local) arrays; a mask marks padding.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray      # (N+1,)
    indices: np.ndarray     # (E,)
    n_nodes: int

    @classmethod
    def from_edges(cls, src: np.ndarray, dst: np.ndarray, n_nodes: int):
        order = np.argsort(dst, kind="stable")
        src, dst = src[order], dst[order]
        counts = np.bincount(dst, minlength=n_nodes)
        indptr = np.zeros(n_nodes + 1, np.int64)
        np.cumsum(counts, out=indptr[1:])
        return cls(indptr, src.astype(np.int64), n_nodes)

    def degree(self, v):
        return self.indptr[v + 1] - self.indptr[v]


def random_powerlaw_graph(n_nodes: int, avg_degree: int,
                          seed: int = 0) -> CSRGraph:
    """Synthetic power-law-ish graph (preferential-attachment flavor)."""
    rng = np.random.RandomState(seed)
    n_edges = n_nodes * avg_degree
    # degree-biased endpoints via Zipf-weighted sampling
    w = 1.0 / np.arange(1, n_nodes + 1) ** 0.75
    w /= w.sum()
    src = rng.choice(n_nodes, size=n_edges, p=w)
    dst = rng.randint(0, n_nodes, size=n_edges)
    return CSRGraph.from_edges(src, dst, n_nodes)


def sample_subgraph(g: CSRGraph, seeds: np.ndarray, fanouts: list[int],
                    rng: np.random.RandomState) -> dict:
    """Returns local-index arrays: nodes (global ids), src, dst, edge_mask."""
    frontier = seeds.astype(np.int64)
    all_nodes = [frontier]
    edges_src, edges_dst = [], []
    for f in fanouts:
        deg = g.degree(frontier)
        # sample f neighbors w/ replacement; degree-0 -> self loop
        offs = rng.randint(0, np.maximum(deg, 1)[:, None],
                           size=(len(frontier), f))
        nbr = g.indices[np.minimum(g.indptr[frontier][:, None] + offs,
                                   len(g.indices) - 1)]
        self_loop = deg == 0
        nbr[self_loop] = frontier[self_loop][:, None]
        edges_src.append(nbr.reshape(-1))
        edges_dst.append(np.repeat(frontier, f))
        frontier = np.unique(nbr.reshape(-1))
        all_nodes.append(frontier)

    nodes, local = np.unique(np.concatenate(all_nodes), return_inverse=False), None
    lut = {int(v): i for i, v in enumerate(nodes)}
    map_f = np.vectorize(lut.__getitem__, otypes=[np.int64])
    src = map_f(np.concatenate(edges_src))
    dst = map_f(np.concatenate(edges_dst))
    return {
        "nodes": nodes,
        "src": src,
        "dst": dst,
        "seeds_local": map_f(seeds.astype(np.int64)),
    }


def static_sample(g: CSRGraph, seeds: np.ndarray, fanouts: list[int],
                  rng: np.random.RandomState) -> dict:
    """Fully static-shape sampler (TPU-friendly: the jitted step never
    recompiles). No dedup — the sampled tree is materialized node-by-node,
    so node/edge counts are exact functions of (batch_nodes, fanouts):

        nodes = b * (1 + f0 + f0*f1 + ...);  edges = b * (f0 + f0*f1 + ...)

    Messages flow child -> parent (neighbor -> frontier node).
    """
    seeds = seeds.astype(np.int64)
    b = len(seeds)
    nodes = [seeds]
    src_l, dst_l = [], []
    frontier = seeds
    frontier_idx = np.arange(b, dtype=np.int64)
    next_off = b
    for f in fanouts:
        deg = g.degree(frontier)
        offs = (rng.randint(0, 1 << 30, size=(len(frontier), f))
                % np.maximum(deg, 1)[:, None])
        nbr = g.indices[np.minimum(g.indptr[frontier][:, None] + offs,
                                   max(len(g.indices) - 1, 0))]
        self_loop = deg == 0
        nbr[self_loop] = frontier[self_loop][:, None]
        new_nodes = nbr.reshape(-1)
        new_idx = next_off + np.arange(len(new_nodes), dtype=np.int64)
        src_l.append(new_idx)
        dst_l.append(np.repeat(frontier_idx, f))
        nodes.append(new_nodes)
        frontier, frontier_idx = new_nodes, new_idx
        next_off += len(new_nodes)
    return {
        "nodes": np.concatenate(nodes),
        "src": np.concatenate(src_l),
        "dst": np.concatenate(dst_l),
        "seeds_local": np.arange(b, dtype=np.int64),
    }


def static_node_count(batch_nodes: int, fanouts: list[int]) -> int:
    frontier, total = batch_nodes, batch_nodes
    for f in fanouts:
        frontier *= f
        total += frontier
    return total


def static_edge_count(batch_nodes: int, fanouts: list[int]) -> int:
    frontier, total = batch_nodes, 0
    for f in fanouts:
        total += frontier * f
        frontier *= f
    return total
