"""Message-passing segment primitives.

JAX sparse is BCOO-only, so graph aggregation is built from
``jax.ops.segment_sum``/``segment_max`` over edge-index scatters — this IS
the substrate (taxonomy §GNN), and the Pallas ``segment_mm`` kernel is its
TPU-tiled counterpart for the fused gather-GEMM-scatter hot path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gather_src(x: jax.Array, src: jax.Array) -> jax.Array:
    """Node features -> per-edge source features."""
    return jnp.take(x, src, axis=0)


def scatter_sum(msgs: jax.Array, dst: jax.Array, n_nodes: int) -> jax.Array:
    return jax.ops.segment_sum(msgs, dst, num_segments=n_nodes)


def scatter_mean(msgs: jax.Array, dst: jax.Array, n_nodes: int,
                 eps: float = 1e-9) -> jax.Array:
    s = scatter_sum(msgs, dst, n_nodes)
    cnt = jax.ops.segment_sum(jnp.ones((msgs.shape[0],), msgs.dtype), dst,
                              num_segments=n_nodes)
    return s / jnp.maximum(cnt, eps)[(...,) + (None,) * (msgs.ndim - 1)]


def scatter_max(msgs: jax.Array, dst: jax.Array, n_nodes: int) -> jax.Array:
    return jax.ops.segment_max(msgs, dst, num_segments=n_nodes)


def segment_softmax(logits: jax.Array, segment_ids: jax.Array,
                    num_segments: int) -> jax.Array:
    """Numerically-stable softmax over variable-length segments.

    logits (E, ...) grouped by segment_ids (E,) — the GNN edge-softmax.
    """
    seg_max = jax.ops.segment_max(logits, segment_ids,
                                  num_segments=num_segments)
    seg_max = jnp.where(jnp.isfinite(seg_max), seg_max, 0.0)
    shifted = logits - seg_max[segment_ids]
    expv = jnp.exp(shifted)
    denom = jax.ops.segment_sum(expv, segment_ids, num_segments=num_segments)
    return expv / jnp.maximum(denom[segment_ids], 1e-30)


def degree(dst: jax.Array, n_nodes: int, dtype=jnp.float32) -> jax.Array:
    return jax.ops.segment_sum(jnp.ones_like(dst, dtype), dst,
                               num_segments=n_nodes)
