"""SO(3) machinery for eSCN-style equivariant networks.

- Real spherical harmonics Y_lm via associated-Legendre recursion
  (unrolled over l <= l_max; fully batched/differentiable).
- Real Wigner rotation matrices D^l(R) built *numerically* from the SH
  evaluator: sample K = 2l+1 fixed generic unit vectors u_k, then
  ``Y_l(R u) = D_l(R) Y_l(u)`` gives ``D_l = (pinv(A) B)^T`` with
  A = Y_l(u_k), B = Y_l(R u_k). pinv(A) is precomputed once per l on the
  host, so the per-edge cost is one SH evaluation at K rotated points and
  one (2l+1, K) @ (K, 2l+1) matmul — MXU-friendly and exact.
- Edge-alignment rotation r_hat -> z_hat via Rodrigues (the eSCN frame in
  which the tensor-product contraction becomes per-m SO(2) linear maps;
  we align to z so that the standard azimuthal m-index is the truncated
  one).

Index convention: coefficients for degree l are ordered m = -l..l; the
flat index of (l, m) is l*l + l + m.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Real spherical harmonics
# ---------------------------------------------------------------------------


def _double_factorial(n: int) -> float:
    out = 1.0
    while n > 1:
        out *= n
        n -= 2
    return out


def real_sph_harm(vec, l_max: int, xp=jnp):
    """Real orthonormal SH of unit vectors. vec (..., 3) -> (..., (l_max+1)^2).

    Uses x=sinθcosφ, y=sinθsinφ, z=cosθ. Associated Legendre values are
    built with the standard stable recursions; azimuthal factors use the
    Chebyshev-style recurrence on cos(mφ)·sin^m θ, sin(mφ)·sin^m θ so no
    explicit φ is ever formed (no atan2 -> safe gradients at poles).
    ``xp`` selects the array module (np for host-side precompute).
    """
    jnp = xp  # noqa: N806 - shadow so the body is module-generic
    x, y, z = vec[..., 0], vec[..., 1], vec[..., 2]
    ct = z                                   # cosθ
    # c_m = sin^m θ cos(mφ), s_m = sin^m θ sin(mφ)
    c = [jnp.ones_like(x)]
    s = [jnp.zeros_like(x)]
    for m in range(1, l_max + 1):
        c_prev, s_prev = c[-1], s[-1]
        c.append(c_prev * x - s_prev * y)
        s.append(s_prev * x + c_prev * y)
    # P̄_l^m = P_l^m(cosθ) / sin^m θ  (polynomial in cosθ — finite at poles)
    pbar: dict[tuple[int, int], jax.Array] = {}
    for m in range(0, l_max + 1):
        pmm = _double_factorial(2 * m - 1) * jnp.ones_like(x)  # no Condon-Shortley
        pbar[(m, m)] = pmm
        if m < l_max:
            pbar[(m + 1, m)] = ct * (2 * m + 1) * pmm
        for l in range(m + 2, l_max + 1):
            pbar[(l, m)] = ((2 * l - 1) * ct * pbar[(l - 1, m)]
                            - (l + m - 1) * pbar[(l - 2, m)]) / (l - m)
    out = []
    for l in range(l_max + 1):
        row = [None] * (2 * l + 1)
        for m in range(0, l + 1):
            norm = math.sqrt((2 * l + 1) / (4 * math.pi)
                             * math.factorial(l - m) / math.factorial(l + m))
            if m == 0:
                row[l] = norm * pbar[(l, 0)]
            else:
                base = math.sqrt(2.0) * norm * pbar[(l, m)]
                row[l + m] = base * c[m]
                row[l - m] = base * s[m]
        out.extend(row)
    return jnp.stack(out, axis=-1)


def lm_index(l: int, m: int) -> int:
    return l * l + l + m


def n_coeff_full(l_max: int) -> int:
    return (l_max + 1) ** 2


# ---------------------------------------------------------------------------
# Numeric Wigner matrices
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=8)
def _sample_pinvs(l_max: int, k_extra: int = 2):
    """Fixed generic sample points + per-l pinv(Y_l(u_k)) (host, cached)."""
    rng = np.random.RandomState(0)
    pts = rng.randn(2 * l_max + 1 + k_extra, 3)
    pts /= np.linalg.norm(pts, axis=-1, keepdims=True)
    # pure-numpy precompute: safe to hit this cache inside a jit trace
    ys = np.asarray(real_sph_harm(pts.astype(np.float64), l_max, xp=np))
    pinvs = []
    for l in range(l_max + 1):
        a = ys[:, l * l:(l + 1) * (l + 1)]
        pinvs.append(np.linalg.pinv(a).astype(np.float32))
    return np.asarray(pts, np.float32), tuple(pinvs)


def wigner_from_rotation(rot: jax.Array, l_max: int) -> list[jax.Array]:
    """rot (..., 3, 3) -> [D_0 (...,1,1), D_1 (...,3,3), ... D_lmax].

    Satisfies Y_l(R u) = D_l(R) @ Y_l(u) for every unit u.
    """
    pts, pinvs = _sample_pinvs(l_max)
    rotated = jnp.einsum("...ij,kj->...ki", rot, pts)    # (..., K, 3)
    yr = real_sph_harm(rotated, l_max)                    # (..., K, n_lm)
    out = []
    for l in range(l_max + 1):
        b = yr[..., l * l:(l + 1) * (l + 1)]              # (..., K, 2l+1)
        d = jnp.einsum("mk,...kn->...nm", pinvs[l], b)    # transpose of pinv@B
        out.append(d)
    return out


def align_to_z(r_hat: jax.Array, eps: float = 1e-9) -> jax.Array:
    """Rodrigues rotation R with R @ r_hat = z_hat. r_hat (..., 3)."""
    z = jnp.zeros_like(r_hat).at[..., 2].set(1.0)
    v = jnp.cross(r_hat, z)
    cos = r_hat[..., 2]
    # antiparallel fallback: rotate about x by pi
    vx = _skew(v)
    denom = jnp.maximum(1.0 + cos, eps)[..., None, None]
    r = jnp.eye(3) + vx + (vx @ vx) / denom
    flip = jnp.asarray([[1.0, 0, 0], [0, -1.0, 0], [0, 0, -1.0]])
    anti = (cos < -1.0 + 1e-6)[..., None, None]
    return jnp.where(anti, flip, r)


def _skew(v: jax.Array) -> jax.Array:
    zero = jnp.zeros_like(v[..., 0])
    rows = jnp.stack([
        jnp.stack([zero, -v[..., 2], v[..., 1]], -1),
        jnp.stack([v[..., 2], zero, -v[..., 0]], -1),
        jnp.stack([-v[..., 1], v[..., 0], zero], -1),
    ], -2)
    return rows


# ---------------------------------------------------------------------------
# m-truncation bookkeeping (|m| <= m_max in the edge frame)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=32)
def trunc_indices(l_max: int, m_max: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Returns (flat_idx, l_of, m_of) for coefficients with |m| <= m_max."""
    idx, ls, ms = [], [], []
    for l in range(l_max + 1):
        mm = min(l, m_max)
        for m in range(-mm, mm + 1):
            idx.append(lm_index(l, m))
            ls.append(l)
            ms.append(m)
    return (np.asarray(idx, np.int32), np.asarray(ls, np.int32),
            np.asarray(ms, np.int32))


def block_rotate(x: jax.Array, wig: list[jax.Array],
                 transpose: bool = False) -> jax.Array:
    """Apply block-diagonal Wigner rotation. x (..., n_lm, C)."""
    outs = []
    for l, d in enumerate(wig):
        seg = x[..., l * l:(l + 1) * (l + 1), :]
        eq = "...nm,...mc->...nc" if not transpose else "...mn,...mc->...nc"
        outs.append(jnp.einsum(eq, d, seg))
    return jnp.concatenate(outs, axis=-2)
