"""EquiformerV2-style equivariant graph attention with eSCN convolutions
[arXiv:2306.12059].

Per layer, for every edge (s -> t) with direction r̂ and length r:

1.  Rotate source/target irrep features into the edge frame (R: r̂ -> ẑ)
    with exact numeric Wigner matrices (``so3.wigner_from_rotation``).
2.  Truncate azimuthal index to |m| <= m_max (the eSCN O(L^6) -> O(L^3)
    reduction: in the aligned frame the SO(3) tensor product becomes
    independent per-m SO(2) linear maps).
3.  Apply per-m SO(2) linear maps (complex-pair mixing across the l-stack
    and channels), modulated by a radial MLP over a Gaussian RBF of r.
4.  Graph attention: invariant (l=0) message channels + RBF -> per-head
    logits -> segment-softmax over incoming edges -> weighted message.
5.  Rotate messages back (D^T), scatter-sum to destinations, equivariant
    RMS-norm (per-l), gated nonlinearity, per-l channel-mixing FFN.

Readout: l=0 invariants -> MLP (node-level or graph-pooled).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import KeyGen, normal_init, param
from repro.configs.base import GNNConfig
from repro.distributed.meshrules import shard_hint
from repro.models.gnn import segment, so3


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _so2_param_shapes(cfg: GNNConfig) -> list[tuple[int, int]]:
    """Per |m| in 0..m_max: the l-stack length n_l(m) = l_max - m + 1."""
    return [(m, cfg.l_max - m + 1) for m in range(cfg.m_max + 1)]


def init_equiformer(cfg: GNNConfig, seed: int = 0, abstract: bool = False):
    kg = None if abstract else KeyGen(seed)
    dtype = jnp.dtype(cfg.param_dtype)
    C, L = cfg.d_hidden, cfg.n_layers
    n_lm = so3.n_coeff_full(cfg.l_max)

    def mk(shape, axes, std):
        return param(None if abstract else kg(), (L,) + shape,
                     ("layers",) + axes, normal_init(std), dtype, abstract)

    layer: dict = {
        # per-m SO(2) linear maps (real/imag), 2x channels in -> channels out
        # (source+target concat on channel dim)
        "rad_w1": mk((cfg.n_radial, 2 * C), (None, None), cfg.n_radial ** -0.5),
        "rad_w2": mk((2 * C, (cfg.m_max + 1) * C), (None, None), (2 * C) ** -0.5),
        "attn_w": mk((C + cfg.n_radial, cfg.n_heads), (None, None),
                     (C + cfg.n_radial) ** -0.5),
        # per-l (shared across m — required for equivariance) channel mixing
        "ffn_w1": mk((cfg.l_max + 1, C, C), (None, None, None), C ** -0.5),
        "ffn_w2": mk((cfg.l_max + 1, C, C), (None, None, None), C ** -0.5),
        "gate_w": mk((C, cfg.l_max * C), (None, None), C ** -0.5),
        "norm_scale": mk((cfg.l_max + 1, C), (None, None), 0.0),
    }
    for m, n_l in _so2_param_shapes(cfg):
        d_in, d_out = n_l * 2 * C, n_l * C
        std = d_in ** -0.5
        if m == 0:
            layer[f"so2_m0"] = mk((d_in, d_out), (None, None), std)
        else:
            layer[f"so2_m{m}_r"] = mk((d_in, d_out), (None, None), std)
            layer[f"so2_m{m}_i"] = mk((d_in, d_out), (None, None), std)

    d_in_feat = cfg.d_in if cfg.d_in > 0 else 128
    return {
        "embed_w": param(None if abstract else kg(), (d_in_feat, C),
                         ("d_feat", None), normal_init(d_in_feat ** -0.5),
                         dtype, abstract),
        "layers": layer,
        "out_w1": param(None if abstract else kg(), (C, C), (None, None),
                        normal_init(C ** -0.5), dtype, abstract),
        "out_w2": param(None if abstract else kg(), (C, cfg.n_out),
                        (None, None), normal_init(C ** -0.5), dtype, abstract),
    }


# ---------------------------------------------------------------------------
# Pieces
# ---------------------------------------------------------------------------


def radial_basis(r: jax.Array, n: int, cutoff: float) -> jax.Array:
    """Gaussian RBF with cosine cutoff envelope. r (E,) -> (E, n)."""
    centers = jnp.linspace(0.0, cutoff, n)
    width = cutoff / n
    rbf = jnp.exp(-0.5 * jnp.square((r[:, None] - centers) / width))
    env = 0.5 * (jnp.cos(jnp.pi * jnp.clip(r / cutoff, 0, 1)) + 1.0)
    return rbf * env[:, None]


def _so2_conv(feats: jax.Array, lp, cfg: GNNConfig, rad_scale: jax.Array):
    """feats: (E, n_trunc, 2C) in the edge frame; returns (E, n_trunc, C).

    Per-|m| complex-pair linear maps across the l-stack:
      y_{+m} = Wr x_{+m} - Wi x_{-m};   y_{-m} = Wi x_{+m} + Wr x_{-m}.
    ``rad_scale`` (E, m_max+1, C) modulates each m-block (radial MLP).
    """
    _, ls, ms = so3.trunc_indices(cfg.l_max, cfg.m_max)
    e = feats.shape[0]
    C2 = feats.shape[-1]
    C = C2 // 2
    out_parts = []
    order = []
    for m in range(cfg.m_max + 1):
        rows_p = np.nonzero(ms == m)[0]
        rows_n = np.nonzero(ms == -m)[0]
        n_l = len(rows_p)
        xp = feats[:, rows_p].reshape(e, n_l * C2)
        if m == 0:
            y = (xp @ lp["so2_m0"]).reshape(e, n_l, C)
            y = y * rad_scale[:, 0][:, None, :]
            out_parts.append(y)
            order.extend(rows_p.tolist())
        else:
            xn = feats[:, rows_n].reshape(e, n_l * C2)
            wr, wi = lp[f"so2_m{m}_r"], lp[f"so2_m{m}_i"]
            yp = (xp @ wr - xn @ wi).reshape(e, n_l, C)
            yn = (xp @ wi + xn @ wr).reshape(e, n_l, C)
            scale = rad_scale[:, m][:, None, :]
            out_parts.append(yp * scale)
            order.extend(rows_p.tolist())
            out_parts.append(yn * scale)
            order.extend(rows_n.tolist())
    out = jnp.concatenate(out_parts, axis=1)
    inv = np.argsort(np.asarray(order))
    return out[:, inv]


def _equi_norm(x: jax.Array, scale: jax.Array, l_max: int,
               eps: float = 1e-6) -> jax.Array:
    """Equivariant RMS norm: normalize each degree-l block by its RMS over
    (m, C); learnable per-(l, C) scale."""
    outs = []
    for l in range(l_max + 1):
        seg = x[:, l * l:(l + 1) * (l + 1)]
        rms = jnp.sqrt(jnp.mean(jnp.square(seg), axis=(1, 2),
                                keepdims=True) + eps)
        outs.append(seg / rms * (1.0 + scale[l])[None, None, :])
    return jnp.concatenate(outs, axis=1)


def _gated_act(x: jax.Array, gate_w: jax.Array, l_max: int) -> jax.Array:
    """l=0: SiLU; l>0: sigmoid gate from invariant channels (equivariant)."""
    inv = x[:, 0]                                        # (N, C)
    gates = jax.nn.sigmoid(inv @ gate_w)                 # (N, l_max*C)
    c = x.shape[-1]
    outs = [jax.nn.silu(x[:, :1])]
    for l in range(1, l_max + 1):
        g = gates[:, (l - 1) * c:l * c][:, None, :]
        outs.append(x[:, l * l:(l + 1) * (l + 1)] * g)
    return jnp.concatenate(outs, axis=1)


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def equiformer_forward(params_raw, cfg: GNNConfig, batch: dict) -> jax.Array:
    """batch: node_feat (N, d_in) or None, pos (N, 3), src (E,), dst (E,),
    optional graph_ids (N,) + n_graphs for pooled readout.

    Returns (N, n_out) node outputs or (n_graphs, n_out) if pooled.
    """
    cdt = jnp.dtype(cfg.compute_dtype)
    pos, src, dst = batch["pos"], batch["src"], batch["dst"]
    n_nodes = pos.shape[0]
    C = cfg.d_hidden
    n_lm = so3.n_coeff_full(cfg.l_max)
    tidx, _, _ = so3.trunc_indices(cfg.l_max, cfg.m_max)
    tidx = jnp.asarray(tidx)

    feat = batch.get("node_feat")
    if feat is None:
        feat = jnp.ones((n_nodes, params_raw["embed_w"].shape[0]), cdt)
    inv0 = (feat.astype(cdt) @ params_raw["embed_w"].astype(cdt))
    x = jnp.zeros((n_nodes, n_lm, C), cdt).at[:, 0, :].set(inv0)
    x = shard_hint(x, "nodes", None, None)

    # edge geometry (shared across layers)
    rel = pos[dst] - pos[src]
    r = jnp.linalg.norm(rel.astype(jnp.float32), axis=-1)
    # zero-length (self-loop / padding) edges have no well-defined frame:
    # mask them out of message passing entirely (they'd break equivariance)
    edge_valid = (r > 1e-7).astype(cdt)
    r_hat = rel / jnp.maximum(r, 1e-9)[:, None]
    rot = so3.align_to_z(r_hat)
    wig = so3.wigner_from_rotation(rot, cfg.l_max)        # list of (E, 2l+1, 2l+1)
    wig = [w.astype(cdt) for w in wig]
    rbf = radial_basis(r, cfg.n_radial, cfg.cutoff).astype(cdt)
    rbf = shard_hint(rbf, "edges", None)

    def layer(x, lp):
        # 1-2. rotate into edge frame + m-truncate
        src_f = segment.gather_src(x, src)
        dst_f = segment.gather_src(x, dst)
        ef = jnp.concatenate([src_f, dst_f], axis=-1)     # (E, n_lm, 2C)
        ef = so3.block_rotate(ef, wig)                    # edge frame
        ef = jnp.take(ef, tidx, axis=1)                   # (E, n_trunc, 2C)
        ef = shard_hint(ef, "edges", None, None)
        # 3. radial-modulated SO(2) conv
        rad = jax.nn.silu(rbf @ lp["rad_w1"]) @ lp["rad_w2"]
        rad_scale = rad.reshape(-1, cfg.m_max + 1, C)
        msg = _so2_conv(ef, lp, cfg, rad_scale)           # (E, n_trunc, C)
        # 4. attention over incoming edges
        inv_msg = msg[:, 0]                               # invariant block
        logits = (jnp.concatenate([inv_msg, rbf], axis=-1)
                  @ lp["attn_w"]).astype(jnp.float32)     # (E, H)
        logits = jnp.where(edge_valid[:, None] > 0, logits, -1e30)
        alpha = segment.segment_softmax(logits, dst, n_nodes).astype(cdt)
        heads = msg.reshape(msg.shape[0], msg.shape[1], cfg.n_heads,
                            C // cfg.n_heads)
        heads = heads * alpha[:, None, :, None]
        msg = heads.reshape(msg.shape)
        # 5. un-truncate + rotate back + aggregate
        full = jnp.zeros((msg.shape[0], n_lm, C), msg.dtype)
        full = full.at[:, tidx].set(msg)
        full = so3.block_rotate(full, wig, transpose=True)
        full = full * edge_valid[:, None, None]
        agg = segment.scatter_sum(full, dst, n_nodes)
        x = x + agg.astype(x.dtype)
        # norm + gated act + per-l channel FFN
        x = _equi_norm(x, lp["norm_scale"], cfg.l_max)
        l_of = jnp.asarray([l for l in range(cfg.l_max + 1)
                            for _ in range(2 * l + 1)])
        w1 = jnp.take(lp["ffn_w1"], l_of, axis=0)         # (n_lm, C, C)
        w2 = jnp.take(lp["ffn_w2"], l_of, axis=0)
        h = _gated_act(x, lp["gate_w"], cfg.l_max)
        h = jnp.einsum("nkc,kcd->nkd", h, w1)
        h = _gated_act(h, lp["gate_w"], cfg.l_max)
        h = jnp.einsum("nkc,kcd->nkd", h, w2)
        x = shard_hint(x + h, "nodes", None, None)
        return x, None

    fn = layer
    if cfg.remat:
        fn = jax.checkpoint(layer,
                            policy=jax.checkpoint_policies.nothing_saveable)
    if getattr(cfg, "scan_layers", True):
        x, _ = jax.lax.scan(lambda c, lp: fn(c, lp), x, params_raw["layers"])
    else:
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params_raw["layers"])
            x, _ = fn(x, lp)

    inv = x[:, 0]                                         # (N, C) invariants
    h = jax.nn.silu(inv @ params_raw["out_w1"].astype(cdt))
    out = h @ params_raw["out_w2"].astype(cdt)
    if "graph_ids" in batch:
        out = jax.ops.segment_sum(out, batch["graph_ids"],
                                  num_segments=batch["n_graphs"])
    return out


def equiformer_loss(params_raw, cfg: GNNConfig, batch: dict):
    out = equiformer_forward(params_raw, cfg, batch)
    labels = batch["labels"]
    if labels.dtype in (jnp.int32, jnp.int64):            # classification
        logz = jax.nn.logsumexp(out.astype(jnp.float32), axis=-1)
        gold = jnp.take_along_axis(out.astype(jnp.float32),
                                   labels[:, None], axis=-1)[:, 0]
        nll = logz - gold
        mask = batch.get("label_mask")
        if mask is not None:
            m = mask.astype(jnp.float32)
            return jnp.sum(nll * m) / jnp.maximum(m.sum(), 1.0), {}
        return nll.mean(), {}
    err = jnp.square(out.astype(jnp.float32)
                     - labels.astype(jnp.float32))
    return err.mean(), {}
