"""Decoder-only transformer LM (dense + MoE) with scan-over-layers,
remat, GQA/SWA attention, RoPE, qk-norm, KV-cache decode.

Public surface:
    init_lm(cfg, seed, abstract)        -> Param tree
    lm_logits(params, cfg, tokens)      -> (B, S, V) logits
    lm_loss(params, cfg, batch)         -> scalar loss, metrics
    prefill(params, cfg, tokens)        -> last-position logits, KVCache
    decode_step(params, cfg, tok, cache, pos) -> logits, cache
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.common import KeyGen, Param, normal_init, param, unwrap
from repro.configs.base import LMConfig
from repro.distributed.meshrules import shard_hint
from repro.models import attention as attn_lib
from repro.models.attention import KVCache
from repro.models.layers import (cross_entropy_loss, embed_lookup, rms_norm,
                                 softcap, swiglu)
from repro.models.moe import init_moe, moe_ffn


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_lm(cfg: LMConfig, seed: int = 0, abstract: bool = False):
    kg = None if abstract else KeyGen(seed)
    dtype = jnp.dtype(cfg.param_dtype)
    d, h, hk, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    L = cfg.n_layers

    def mk(shape, axes, std):
        return param(None if abstract else kg(), (L,) + shape,
                     ("layers",) + axes, normal_init(std), dtype, abstract)

    layer = {
        "ln_attn": mk((d,), ("d_model",), 0.0),
        "ln_ffn": mk((d,), ("d_model",), 0.0),
        "wq": mk((d, h, dh), ("d_model", "heads", "d_head"), d ** -0.5),
        "wk": mk((d, hk, dh), ("d_model", "kv_heads", "d_head"), d ** -0.5),
        "wv": mk((d, hk, dh), ("d_model", "kv_heads", "d_head"), d ** -0.5),
        "wo": mk((h, dh, d), ("heads", "d_head", "d_model"),
                 (h * dh) ** -0.5),
    }
    if cfg.qk_norm:
        layer["q_norm"] = mk((dh,), ("d_head",), 0.0)
        layer["k_norm"] = mk((dh,), ("d_head",), 0.0)
    if cfg.moe is not None:
        layer["moe"] = init_moe(kg, d, cfg.moe, dtype, abstract, layers=L)
    else:
        layer["w_gate"] = mk((d, cfg.d_ff), ("d_model", "d_ff"), d ** -0.5)
        layer["w_up"] = mk((d, cfg.d_ff), ("d_model", "d_ff"), d ** -0.5)
        layer["w_down"] = mk((cfg.d_ff, d), ("d_ff", "d_model"),
                             cfg.d_ff ** -0.5)

    params = {
        "embed": param(None if abstract else kg(), (cfg.vocab_size, d),
                       ("vocab", "d_model"), normal_init(0.02), dtype,
                       abstract),
        "layers": layer,
        "ln_final": param(None, (d,), ("d_model",),
                          lambda k, s, t: jnp.zeros(s, t), dtype, abstract),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = param(None if abstract else kg(),
                                  (d, cfg.vocab_size), ("d_model", "vocab"),
                                  normal_init(d ** -0.5), dtype, abstract)
    return params


# ---------------------------------------------------------------------------
# Forward blocks (operate on raw arrays; params already unwrapped)
# ---------------------------------------------------------------------------


def _qkv(x, lp, cfg: LMConfig, positions):
    cdt = jnp.dtype(cfg.compute_dtype)
    h = rms_norm(x, lp["ln_attn"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, lp["wq"].astype(cdt))
    k = jnp.einsum("bsd,dhk->bshk", h, lp["wk"].astype(cdt))
    v = jnp.einsum("bsd,dhk->bshk", h, lp["wv"].astype(cdt))
    if cfg.qk_norm:
        q = rms_norm(q, lp["q_norm"], cfg.norm_eps)
        k = rms_norm(k, lp["k_norm"], cfg.norm_eps)
    from repro.models.layers import apply_rope
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    # seq deliberately unsharded here: the residual stream is seq-sharded
    # (SP); attention gathers seq and shards heads instead (Megatron TP) —
    # the hint mismatch makes GSPMD place the AG/RS pair at the boundary.
    q = shard_hint(q, "batch", None, "heads", "d_head")
    k = shard_hint(k, "batch", None, "kv_heads", "d_head")
    v = shard_hint(v, "batch", None, "kv_heads", "d_head")
    return q, k, v


def _ffn_block(x, lp, cfg: LMConfig):
    h = rms_norm(x, lp["ln_ffn"], cfg.norm_eps)
    if cfg.moe is not None:
        y, aux = moe_ffn(h, lp["moe"], cfg.moe)
        return y.astype(x.dtype), aux
    g = jnp.einsum("bsd,df->bsf", h, lp["w_gate"].astype(h.dtype))
    u = jnp.einsum("bsd,df->bsf", h, lp["w_up"].astype(h.dtype))
    z = shard_hint(swiglu(g, u), "batch", "seq", "d_ff")
    y = jnp.einsum("bsf,fd->bsd", z, lp["w_down"].astype(h.dtype))
    return y.astype(x.dtype), jnp.zeros((), jnp.float32)


def _layer_fn(cfg: LMConfig):
    def layer(carry, lp):
        x, aux = carry
        positions = jnp.arange(x.shape[1])
        # pin the carry itself seq-sharded FIRST — this is the tensor the
        # scan saves for backward; without the pin GSPMD canonicalizes the
        # saved (L, B, S, D) stack to the gathered layout (64x HBM blowup)
        x = shard_hint(x, "batch", "seq", "d_model")
        # SP boundary: gather the seq-sharded residual ONCE per layer (in
        # bf16) — attention and FFN both consume the gathered copy, and
        # outputs reshard back to seq-sharded at the residual adds (this
        # consolidates GSPMD's AG placement; without it the gather happens
        # ~7x per layer on fp32 intermediates)
        xg = shard_hint(x, "batch", None, "d_model")
        q, k, v = _qkv(xg, lp, cfg, positions)
        o = attn_lib.attention(q, k, v, causal=True,
                               window=cfg.sliding_window,
                               impl=cfg.attention_impl,
                               q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                               unroll=cfg.unroll_pairs)
        o = jnp.einsum("bshk,hkd->bsd", o, lp["wo"].astype(o.dtype))
        x = x + shard_hint(o.astype(x.dtype), "batch", "seq", "d_model")
        xg = shard_hint(x, "batch", None, "d_model")
        y, aux_l = _ffn_block(xg, lp, cfg)
        x = x + shard_hint(y.astype(x.dtype), "batch", "seq", "d_model")
        x = shard_hint(x, "batch", "seq", "d_model")
        return (x, aux + aux_l), None

    return layer


def _run_layers(x, layers_raw, cfg: LMConfig):
    layer = _layer_fn(cfg)
    if cfg.remat:
        layer = jax.checkpoint(
            layer, policy=jax.checkpoint_policies.nothing_saveable)
    aux0 = jnp.zeros((), jnp.float32)
    if cfg.scan_layers:
        (x, aux), _ = jax.lax.scan(layer, (x, aux0), layers_raw)
    else:
        carry = (x, aux0)
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], layers_raw)
            carry, _ = layer(carry, lp)
        x, aux = carry
    return x, aux


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------


def lm_logits(params_raw, cfg: LMConfig, tokens: jax.Array):
    """tokens (B, S) -> logits (B, S, V); also returns moe aux loss."""
    cdt = jnp.dtype(cfg.compute_dtype)
    x = embed_lookup(params_raw["embed"].astype(cdt), tokens)
    x = shard_hint(x, "batch", "seq", "d_model")
    x, aux = _run_layers(x, params_raw["layers"], cfg)
    x = rms_norm(x, params_raw["ln_final"], cfg.norm_eps)
    head = (params_raw["embed"].T if "lm_head" not in params_raw
            else params_raw["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(cdt))
    logits = softcap(logits, cfg.logits_softcap)
    logits = shard_hint(logits, "batch", "seq", "vocab")
    return logits, aux


def lm_loss(params_raw, cfg: LMConfig, batch: dict):
    """batch: {"tokens": (B, S), "labels": (B, S), optional "mask"}."""
    logits, aux = lm_logits(params_raw, cfg, batch["tokens"])
    ce = cross_entropy_loss(logits, batch["labels"], batch.get("mask"))
    return ce + aux, {"ce": ce, "aux": aux}


def prefill(params_raw, cfg: LMConfig, tokens: jax.Array):
    """Full-sequence forward that also builds the KV cache.

    Returns (last-position logits (B, V), KVCache). Lowered by the
    ``prefill_*`` dry-run cells.
    """
    cdt = jnp.dtype(cfg.compute_dtype)
    b, s = tokens.shape
    x = embed_lookup(params_raw["embed"].astype(cdt), tokens)
    x = shard_hint(x, "batch", "seq", "d_model")
    positions = jnp.arange(s)

    def layer(carry, lp):
        x, aux = carry
        q, k, v = _qkv(x, lp, cfg, positions)
        o = attn_lib.attention(q, k, v, causal=True,
                               window=cfg.sliding_window,
                               impl=cfg.attention_impl,
                               q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                               unroll=cfg.unroll_pairs)
        o = jnp.einsum("bshk,hkd->bsd", o, lp["wo"].astype(o.dtype))
        x = x + o.astype(x.dtype)
        y, aux_l = _ffn_block(x, lp, cfg)
        x = shard_hint(x + y, "batch", "seq", "d_model")
        return (x, aux + aux_l), (k, v)

    if cfg.remat:
        layer = jax.checkpoint(layer,
                               policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.scan_layers:
        (x, _), (ks, vs) = jax.lax.scan(layer, (x, jnp.zeros((), jnp.float32)),
                                        params_raw["layers"])
    else:
        carry = (x, jnp.zeros((), jnp.float32))
        kvs = []
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params_raw["layers"])
            carry, kv = layer(carry, lp)
            kvs.append(kv)
        (x, _) = carry
        ks = jnp.stack([k for k, _ in kvs])
        vs = jnp.stack([v for _, v in kvs])
    x = rms_norm(x[:, -1:], params_raw["ln_final"], cfg.norm_eps)
    head = (params_raw["embed"].T if "lm_head" not in params_raw
            else params_raw["lm_head"])
    logits = softcap(jnp.einsum("bsd,dv->bsv", x, head.astype(cdt)),
                     cfg.logits_softcap)[:, 0]
    cache = KVCache(shard_hint(ks, "layers", "batch", "kv_seq", "kv_heads",
                               "d_head"),
                    shard_hint(vs, "layers", "batch", "kv_seq", "kv_heads",
                               "d_head"))
    return logits, cache


def decode_step(params_raw, cfg: LMConfig, tokens: jax.Array,
                cache: KVCache, pos: jax.Array):
    """One-token decode. tokens (B, 1); cache (L, B, S, Hk, Dh); pos scalar
    (position at which the new token sits). Returns (logits (B, V), cache)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    x = embed_lookup(params_raw["embed"].astype(cdt), tokens)
    positions = jnp.full((tokens.shape[0], 1), pos)

    def layer(x, inputs):
        lp, ck, cv = inputs
        q, k, v = _qkv(x, lp, cfg, positions)
        ck, cv = attn_lib.cache_update(ck, cv, k, v, pos)
        o = attn_lib.decode_attention(q, ck, cv, pos,
                                      window=cfg.sliding_window)
        o = jnp.einsum("bshk,hkd->bsd", o, lp["wo"].astype(o.dtype))
        x = x + o.astype(x.dtype)
        y, _ = _ffn_block(x, lp, cfg)
        return x + y, (ck, cv)

    if cfg.scan_layers:
        x, (new_k, new_v) = jax.lax.scan(
            lambda c, inp: layer(c, inp), x,
            (params_raw["layers"], cache.k, cache.v))
    else:
        nk, nv = [], []
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params_raw["layers"])
            x, (ck, cv) = layer(x, (lp, cache.k[i], cache.v[i]))
            nk.append(ck)
            nv.append(cv)
        new_k, new_v = jnp.stack(nk), jnp.stack(nv)
    x = rms_norm(x[:, -1:], params_raw["ln_final"], cfg.norm_eps)
    head = (params_raw["embed"].T if "lm_head" not in params_raw
            else params_raw["lm_head"])
    logits = softcap(jnp.einsum("bsd,dv->bsv", x, head.astype(cdt)),
                     cfg.logits_softcap)[:, 0]
    return logits, KVCache(new_k, new_v)
