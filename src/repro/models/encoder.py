"""BERT-style bidirectional encoder — the AdaParse CLS-III router model
(SciBERT-class, ~110M at full config). Supports:

- per-parser accuracy regression head (m outputs in [0,1]) — stage-1 SFT
  target of Appendix A;
- scalar preference head — the g_phi scorer used by DPO (stage 2);
- multi-class parser-selection readout (argmax over predicted accuracies).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common import KeyGen, normal_init, param
from repro.configs.base import EncoderConfig
from repro.distributed.meshrules import shard_hint
from repro.models import attention as attn_lib
from repro.models.layers import embed_lookup, gelu, layer_norm


def init_encoder(cfg: EncoderConfig, seed: int = 0, abstract: bool = False):
    kg = None if abstract else KeyGen(seed)
    dtype = jnp.dtype(cfg.param_dtype)
    d, h, f, L = cfg.d_model, cfg.n_heads, cfg.d_ff, cfg.n_layers
    dh = d // h

    def mk(shape, axes, std, layers=True):
        lead, laxes = ((L,), ("layers",)) if layers else ((), ())
        return param(None if abstract else kg(), lead + shape, laxes + axes,
                     normal_init(std), dtype, abstract)

    def mkz(shape, axes, layers=True):
        lead, laxes = ((L,), ("layers",)) if layers else ((), ())
        return param(None, lead + shape, laxes + axes,
                     lambda k, s, t: jnp.zeros(s, t), dtype, abstract)

    def mko(shape, axes, layers=True):
        lead, laxes = ((L,), ("layers",)) if layers else ((), ())
        return param(None, lead + shape, laxes + axes,
                     lambda k, s, t: jnp.ones(s, t), dtype, abstract)

    layer = {
        "wq": mk((d, h, dh), ("d_model", "heads", "d_head"), d ** -0.5),
        "wk": mk((d, h, dh), ("d_model", "heads", "d_head"), d ** -0.5),
        "wv": mk((d, h, dh), ("d_model", "heads", "d_head"), d ** -0.5),
        "wo": mk((h, dh, d), ("heads", "d_head", "d_model"), d ** -0.5),
        "ln1_s": mko((d,), ("d_model",)),
        "ln1_b": mkz((d,), ("d_model",)),
        "w_in": mk((d, f), ("d_model", "d_ff"), d ** -0.5),
        "b_in": mkz((f,), ("d_ff",)),
        "w_out": mk((f, d), ("d_ff", "d_model"), f ** -0.5),
        "b_out": mkz((d,), ("d_model",)),
        "ln2_s": mko((d,), ("d_model",)),
        "ln2_b": mkz((d,), ("d_model",)),
    }
    return {
        "tok_embed": param(None if abstract else kg(), (cfg.vocab_size, d),
                           ("vocab", "d_model"), normal_init(0.02), dtype,
                           abstract),
        "pos_embed": param(None if abstract else kg(), (cfg.max_len, d),
                           ("pos", "d_model"), normal_init(0.02), dtype,
                           abstract),
        "ln_embed_s": mko((d,), ("d_model",), layers=False),
        "ln_embed_b": mkz((d,), ("d_model",), layers=False),
        "layers": layer,
        "pool_w": mk((d, d), ("d_model", None), d ** -0.5, layers=False),
        "pool_b": mkz((d,), (None,), layers=False),
        "head_w": mk((d, cfg.n_outputs), ("d_model", None), d ** -0.5,
                     layers=False),
        "head_b": mkz((cfg.n_outputs,), (None,), layers=False),
        "pref_w": mk((d, 1), ("d_model", None), d ** -0.5, layers=False),
        "pref_b": mkz((1,), (None,), layers=False),
    }


def _enc_layer(cfg: EncoderConfig):
    cdt = jnp.dtype(cfg.compute_dtype)

    def layer(carry, lp):
        x, bias = carry
        q = jnp.einsum("bsd,dhk->bshk", x, lp["wq"].astype(cdt))
        k = jnp.einsum("bsd,dhk->bshk", x, lp["wk"].astype(cdt))
        v = jnp.einsum("bsd,dhk->bshk", x, lp["wv"].astype(cdt))
        dh = q.shape[-1]
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                       preferred_element_type=jnp.float32) * dh ** -0.5
        # heads (12) don't divide model=16 — shard the q-seq dim of the
        # score tensor instead (else (B,H,S,S) fp32 replicates over model)
        s = shard_hint(s, "batch", None, "seq", None)
        s = s + bias[:, None, None, :]
        p = jax.nn.softmax(s, axis=-1).astype(cdt)
        o = jnp.einsum("bhqk,bkhd->bqhd", p, v)
        o = jnp.einsum("bqhd,hdm->bqm", o, lp["wo"].astype(cdt))
        x = layer_norm(x + o, lp["ln1_s"], lp["ln1_b"], cfg.norm_eps)
        h = gelu(jnp.einsum("bsd,df->bsf", x, lp["w_in"].astype(cdt))
                 + lp["b_in"].astype(cdt))
        # d_ff (not seq) takes the model axis here — the hidden tensor is
        # the layer's biggest (B, S, 4d); seq-sharding it would block TP
        h = shard_hint(h, "batch", None, "d_ff")
        h = jnp.einsum("bsf,fd->bsd", h, lp["w_out"].astype(cdt)) \
            + lp["b_out"].astype(cdt)
        x = layer_norm(x + h, lp["ln2_s"], lp["ln2_b"], cfg.norm_eps)
        x = shard_hint(x, "batch", "seq", "d_model")
        return (x, bias), None

    return layer


def encode(params_raw, cfg: EncoderConfig, tokens: jax.Array,
           mask: jax.Array | None = None) -> jax.Array:
    """tokens (B, S) -> pooled CLS representation (B, D)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    b, s = tokens.shape
    if mask is None:
        mask = jnp.ones((b, s), jnp.float32)
    x = embed_lookup(params_raw["tok_embed"].astype(cdt), tokens)
    x = x + params_raw["pos_embed"][:s].astype(cdt)[None]
    x = layer_norm(x, params_raw["ln_embed_s"], params_raw["ln_embed_b"],
                   cfg.norm_eps)
    x = shard_hint(x, "batch", "seq", "d_model")
    bias = jnp.where(mask > 0, 0.0, attn_lib.NEG_INF).astype(jnp.float32)
    layer = _enc_layer(cfg)
    if cfg.remat:
        layer = jax.checkpoint(layer,
                               policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.scan_layers:
        (x, _), _ = jax.lax.scan(layer, (x, bias), params_raw["layers"])
    else:
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params_raw["layers"])
            (x, bias), _ = layer((x, bias), lp)
    pooled = jnp.tanh(jnp.einsum("bd,de->be", x[:, 0],
                                 params_raw["pool_w"].astype(cdt))
                      + params_raw["pool_b"].astype(cdt))
    return pooled


def predict_accuracies(params_raw, cfg: EncoderConfig, tokens, mask=None):
    """(B, S) tokens -> (B, m) predicted per-parser accuracy in [0, 1]."""
    pooled = encode(params_raw, cfg, tokens, mask)
    out = jnp.einsum("bd,dm->bm", pooled, params_raw["head_w"].astype(pooled.dtype))
    out = out + params_raw["head_b"].astype(pooled.dtype)
    return jax.nn.sigmoid(out.astype(jnp.float32))


def preference_score(params_raw, cfg: EncoderConfig, tokens, mask=None):
    """g_phi(x): positive scalar preference density (B,) for DPO."""
    pooled = encode(params_raw, cfg, tokens, mask)
    z = jnp.einsum("bd,do->bo", pooled, params_raw["pref_w"].astype(pooled.dtype))
    z = z + params_raw["pref_b"].astype(pooled.dtype)
    return jax.nn.softplus(z.astype(jnp.float32))[:, 0] + 1e-6


def regression_loss(params_raw, cfg: EncoderConfig, batch):
    """L_REG = E ||pi(x) - y||^2 with a validity mask over parsers."""
    pred = predict_accuracies(params_raw, cfg, batch["tokens"],
                              batch.get("mask"))
    y = batch["targets"].astype(jnp.float32)
    w = batch.get("target_mask")
    err = jnp.square(pred - y)
    if w is not None:
        w = w.astype(jnp.float32)
        return jnp.sum(err * w) / jnp.maximum(jnp.sum(w), 1.0)
    return jnp.mean(err)
