"""Framework-wide primitives: sharding-annotated parameters, dtype policy,
PRNG helpers, and small tree utilities.

Every parameter in the framework is created through :func:`param`, which
attaches *logical axis names* (e.g. ``("d_model", "d_ff")``) to the array.
``repro.distributed.meshrules`` maps logical axes onto physical mesh axes
(``pod``/``data``/``model``) to produce ``PartitionSpec`` trees for pjit.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Parameter wrapper (pytree node; logical axes ride along as aux data)
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Param:
    """An array annotated with logical sharding axes.

    ``axes`` has one entry per array dim; ``None`` means replicated on that
    dim. Param is a pytree node so optimizer states built with ``tree_map``
    over a Param tree automatically inherit the annotation structure.
    """

    value: jax.Array | jax.ShapeDtypeStruct
    axes: tuple[str | None, ...]

    def tree_flatten(self):
        return (self.value,), self.axes

    @classmethod
    def tree_unflatten(cls, axes, children):
        return cls(children[0], axes)

    @property
    def shape(self):
        return self.value.shape

    @property
    def dtype(self):
        return self.value.dtype


def is_param(x) -> bool:
    return isinstance(x, Param)


def unwrap(tree):
    """Param tree -> raw array tree (same structure, Param nodes erased)."""
    return jax.tree_util.tree_map(lambda p: p.value, tree, is_leaf=is_param)


def axes_tree(tree):
    """Param tree -> tree of logical-axis tuples (leaves are tuples)."""
    return jax.tree_util.tree_map(lambda p: p.axes, tree, is_leaf=is_param)


def wrap_like(values, params):
    """Re-attach the axes of ``params`` onto a raw array tree ``values``."""
    return jax.tree_util.tree_map(
        lambda p, v: Param(v, p.axes), params, values, is_leaf=is_param
    )


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def truncated_normal_init(stddev: float) -> Callable:
    def init(key, shape, dtype):
        return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
                * stddev).astype(dtype)

    return init


def normal_init(stddev: float) -> Callable:
    def init(key, shape, dtype):
        return (jax.random.normal(key, shape, jnp.float32) * stddev).astype(dtype)

    return init


def zeros_init(key, shape, dtype):
    del key
    return jnp.zeros(shape, dtype)


def ones_init(key, shape, dtype):
    del key
    return jnp.ones(shape, dtype)


def fan_in_init(fan_axis: int = 0) -> Callable:
    """LeCun-normal on the given fan-in axis (default first)."""

    def init(key, shape, dtype):
        fan_in = shape[fan_axis] if shape else 1
        return normal_init(1.0 / math.sqrt(max(fan_in, 1)))(key, shape, dtype)

    return init


def param(
    key,
    shape: Sequence[int],
    axes: Sequence[str | None],
    init: Callable | None = None,
    dtype=jnp.float32,
    abstract: bool = False,
) -> Param:
    """Create a sharding-annotated parameter.

    ``abstract=True`` produces a ShapeDtypeStruct instead of allocating —
    used by the dry-run path to build full-size parameter *skeletons*
    without touching host memory.
    """
    shape = tuple(int(s) for s in shape)
    axes = tuple(axes)
    assert len(axes) == len(shape), (shape, axes)
    if abstract:
        return Param(jax.ShapeDtypeStruct(shape, dtype), axes)
    if init is None:
        init = fan_in_init(0)
    return Param(init(key, shape, dtype), axes)


# ---------------------------------------------------------------------------
# Dtype policy
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Policy:
    """Mixed-precision policy: params stored / compute / reductions."""

    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    accum_dtype: Any = jnp.float32

    def cast_compute(self, tree):
        return jax.tree_util.tree_map(
            lambda x: x.astype(self.compute_dtype)
            if jnp.issubdtype(x.dtype, jnp.floating) else x,
            tree,
        )


# ---------------------------------------------------------------------------
# PRNG helpers
# ---------------------------------------------------------------------------


class KeyGen:
    """Sequential PRNG key dispenser: ``k = kg()`` for each fresh consumer."""

    def __init__(self, key_or_seed):
        if isinstance(key_or_seed, int):
            key_or_seed = jax.random.key(key_or_seed)
        self._key = key_or_seed

    def __call__(self, n: int | None = None):
        if n is None:
            self._key, sub = jax.random.split(self._key)
            return sub
        self._key, *subs = jax.random.split(self._key, n + 1)
        return subs


# ---------------------------------------------------------------------------
# Tree / math utilities
# ---------------------------------------------------------------------------


def tree_size(tree) -> int:
    """Total number of elements across all leaves."""
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree) -> int:
    return sum(
        int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
        for x in jax.tree_util.tree_leaves(tree)
    )


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return ceil_div(a, b) * b


def stack_layers(layer_params: list):
    """Stack a list of identically-structured param trees along new axis 0,
    annotating the new axis as the logical ``layers`` axis (replicated)."""
    out = jax.tree_util.tree_map(
        lambda *ps: Param(jnp.stack([p.value for p in ps]),
                          ("layers",) + ps[0].axes),
        *layer_params,
        is_leaf=is_param,
    )
    return out


def abstractify(tree):
    """Array tree -> ShapeDtypeStruct tree (keeps Param wrappers)."""

    def go(x):
        if is_param(x):
            return Param(jax.ShapeDtypeStruct(x.value.shape, x.value.dtype), x.axes)
        return jax.ShapeDtypeStruct(x.shape, x.dtype)

    return jax.tree_util.tree_map(go, tree, is_leaf=is_param)
