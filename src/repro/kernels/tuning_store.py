"""Disk-backed, fleet-shared kernel tuning store.

Autotune sweeps (``kernels/autotune_common``) are expensive relative to
the kernels they tune — a candidate grid at a production shape costs
seconds, the tuned call costs microseconds — and before this store every
winner lived in a per-process dict that died with its worker. The store
makes the tuned block tables a persistent, fleet-wide asset: N worker
processes share one ``--tuning-dir``, the first process to sweep a
(kernel, shape, backend, device) key publishes the winner, and every
later process — including a whole warm fleet restart — reads it back
instead of re-sweeping.

On-disk layout mirrors ``backends.DiskResultStore``'s index idiom (the
proven multi-process WAL protocol, not a new one):

* every ``put`` appends **one full JSON line** to ``tuning.wal`` via a
  single ``O_APPEND`` write (atomic on a local filesystem) under a
  *shared* ``flock`` — concurrent sweepers never interleave mid-line,
  and the shared lock fences against a concurrent compaction truncating
  the WAL between the write and its fold-in;
* compaction (``flush()`` / every ``COMPACT_EVERY`` ops) takes the
  *exclusive* ``flock`` and folds the **on-disk** snapshot
  (``tuning.json``) plus the full WAL — every other process's appends
  included — into a fresh snapshot (tmp + ``os.replace``) before
  truncating the WAL, so two processes never drop each other's tail;
* undecodable WAL lines (a torn append from a killed process) are
  skipped, not treated as end-of-log;
* reads detect staleness via the snapshot's (inode, size) + the WAL
  size (``_disk_sig``) and refold when another process has published,
  so "one process sweeps while another reads" converges without any
  coordination beyond the flock.

Records are plain JSON dicts keyed by a ``kernel|shape|backend|mode``
string (see ``autotune_common.store_key``). Last write wins — winners
are deterministic enough in practice that either is fine, and timing
jitter between two sweeps of the same shape is not worth arbitrating.

``configure(dir)`` installs a process-global store (what
``serve.py --tuning-dir`` and ``WorkerSpec.tuning_dir`` call); the
autotune caches consult it transparently via ``get_store()``.
"""
from __future__ import annotations

import contextlib
import fcntl
import json
import os
import threading


class TuningStore:
    """One tuning-table directory, shareable across processes."""

    SNAP_NAME = "tuning.json"
    WAL_NAME = "tuning.wal"
    LOCK_NAME = ".tuning.lock"
    COMPACT_EVERY = 64              # WAL ops between automatic compactions

    def __init__(self, tuning_dir: str):
        self.dir = str(tuning_dir)
        self._mu = threading.Lock()
        self.hits = 0
        self.misses = 0
        os.makedirs(self.dir, exist_ok=True)
        self._snap_path = os.path.join(self.dir, self.SNAP_NAME)
        self._wal_path = os.path.join(self.dir, self.WAL_NAME)
        self._lock_path = os.path.join(self.dir, self.LOCK_NAME)
        # persistent handles, as in DiskResultStore: one lock fd
        # (flock'd per op) and one O_APPEND WAL fd — compaction
        # truncates the WAL in place (same inode), so appends through
        # this fd stay valid across any process's compactions
        self._lock_fd = os.open(self._lock_path,
                                os.O_CREAT | os.O_RDWR, 0o644)
        self._wal_fd = os.open(self._wal_path,
                               os.O_APPEND | os.O_CREAT | os.O_WRONLY,
                               0o644)
        self._load()

    def close(self) -> None:
        """Release the persistent fds (safe to call twice; runs at GC).
        The store is unusable afterwards."""
        for attr in ("_wal_fd", "_lock_fd"):
            fd = getattr(self, attr, None)
            if fd is not None:
                try:
                    os.close(fd)
                except OSError:
                    pass
                setattr(self, attr, None)

    def __del__(self):
        self.close()

    # -- disk protocol -------------------------------------------------------

    @contextlib.contextmanager
    def _flock(self, exclusive: bool):
        fcntl.flock(self._lock_fd,
                    fcntl.LOCK_EX if exclusive else fcntl.LOCK_SH)
        try:
            yield
        finally:
            fcntl.flock(self._lock_fd, fcntl.LOCK_UN)

    def _disk_sig(self):
        """(snapshot (inode, size), WAL size): diverges from the synced
        signature exactly when another process has published — our own
        appends advance the expected WAL size in ``_append_wal``."""
        try:
            st = os.stat(self._snap_path)
            snap = (st.st_ino, st.st_size)
        except FileNotFoundError:
            snap = None
        return snap, os.fstat(self._wal_fd).st_size

    def _in_sync(self) -> bool:
        return self._synced_sig is not None \
            and self._disk_sig() == self._synced_sig

    def _mark_synced(self) -> None:
        self._synced_sig = self._disk_sig()

    def _read_disk_state(self) -> tuple[dict, int]:
        """(records, wal_ops) folded from the on-disk snapshot + WAL —
        the union of every process's published winners. Torn WAL lines
        are skipped, not treated as end-of-log."""
        try:
            with open(self._snap_path) as f:
                records = dict(json.load(f))
        except (FileNotFoundError, json.JSONDecodeError):
            records = {}
        wal_ops = 0
        try:
            f = open(self._wal_path)
        except FileNotFoundError:
            return records, 0
        with f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    op = json.loads(line)
                except json.JSONDecodeError:
                    continue
                key = op.get("k")
                if key is not None:
                    records[str(key)] = op.get("v")
                wal_ops += 1
        return records, wal_ops

    def _load(self) -> None:
        with self._flock(exclusive=False):
            # sig first: an append racing in after the stat makes the
            # signature read stale (forcing a refold), never fresh
            sig = self._disk_sig()
            self._records, self._wal_ops = self._read_disk_state()
        self._synced_sig = sig

    def _compact(self) -> None:
        """Fold the on-disk snapshot + WAL (every process's appends)
        into a fresh snapshot, truncate the WAL, adopt the merged view.
        Exclusive flock: no other process can append between the fold
        and the truncate."""
        with self._flock(exclusive=True):
            records, _ = self._read_disk_state()
            self._records = records
            tmp = self._snap_path + f".tmp{os.getpid()}"
            with open(tmp, "w") as f:
                json.dump(self._records, f, sort_keys=True)
            os.replace(tmp, self._snap_path)
            open(self._wal_path, "w").close()
            self._mark_synced()
        self._wal_ops = 0

    # -- store API -----------------------------------------------------------

    def get(self, key: str) -> dict | None:
        """The stored record for ``key`` or None; counts a hit or a
        miss. A stale local view (another process published since our
        last sync) is refolded first, so a reader sees a concurrent
        sweeper's winners without reopening the store."""
        with self._mu:
            if not self._in_sync():
                self._load()
            rec = self._records.get(str(key))
            if rec is None:
                self.misses += 1
            else:
                self.hits += 1
            return rec

    def put(self, key: str, record: dict) -> None:
        """Publish a winner: one appended WAL line, fleet-visible
        immediately (readers refold on their next stale ``get``)."""
        line = (json.dumps({"k": str(key), "v": record}) + "\n").encode()
        with self._mu:
            with self._flock(exclusive=False):
                os.write(self._wal_fd, line)
            self._records[str(key)] = record
            self._wal_ops += 1
            if self._synced_sig is not None:
                snap, wal = self._synced_sig
                self._synced_sig = (snap, wal + len(line))
            if self._wal_ops >= self.COMPACT_EVERY:
                self._compact()

    def flush(self) -> None:
        """Compact outstanding WAL ops into the snapshot."""
        with self._mu:
            if self._wal_ops:
                self._compact()

    def keys(self) -> tuple[str, ...]:
        with self._mu:
            if not self._in_sync():
                self._load()
            return tuple(sorted(self._records))

    def __len__(self) -> int:
        with self._mu:
            if not self._in_sync():
                self._load()
            return len(self._records)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


# ---------------------------------------------------------------------------
# Process-global store (what serve.py / worker_main configure)
# ---------------------------------------------------------------------------


_STORE: TuningStore | None = None


def configure(tuning_dir: str | None) -> TuningStore | None:
    """Install (or, with None, remove) the process-global tuning store.
    Reconfiguring the same directory reopens it — a fresh handle with a
    cold in-memory view, which is what a restarted worker does."""
    global _STORE
    if _STORE is not None:
        _STORE.flush()
        _STORE.close()
        _STORE = None
    if tuning_dir is not None:
        _STORE = TuningStore(tuning_dir)
    return _STORE


def get_store() -> TuningStore | None:
    return _STORE


def reset() -> None:
    """Drop the global store without flushing (test isolation)."""
    global _STORE
    if _STORE is not None:
        _STORE.close()
    _STORE = None
