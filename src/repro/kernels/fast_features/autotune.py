"""Comparison-block autotuning for the fast_features kernel.

The kernel's distinct-token scan compares ``block_l`` candidate columns
per step; the sweep times power-of-two candidates at a packed
(width, max_len) shape through the shared ``autotune_common`` harness
and persists the winner when a tuning store is configured. Because
``pack_routing_batch`` quantizes widths to powers of two, a fleet sees
only O(log) distinct shapes — the first worker to meet one sweeps, the
rest (and every warm restart) read the store.

CLI: ``python -m repro.kernels.fast_features.autotune [--device]
[--tuning-dir DIR] [--json OUT]``.
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import autotune_common, tuning_store
from repro.kernels.autotune_common import TuneRecord  # re-export
from repro.kernels.fast_features.kernel import fast_features_kernel

KERNEL_NAME = "fast_features"
DEFAULT_BLOCK_L = 128
DEFAULT_CANDIDATES = (128, 256, 512)
SWEEP_DOCS = 64                        # synthetic batch the sweep times

__all__ = ["TuneRecord", "autotune_fast_features", "tuned_block_l",
           "ensure_tuned", "clear_cache", "DEFAULT_BLOCK_L",
           "DEFAULT_CANDIDATES", "KERNEL_NAME"]


def tuned_block_l(width: int, max_len: int,
                  device: bool | None = None) -> int:
    """The cached/stored winner for this packed shape, or the default."""
    return autotune_common.tuned_value(
        KERNEL_NAME, (width, max_len), DEFAULT_BLOCK_L, device=device)


def clear_cache() -> None:
    autotune_common.clear_cache()


def _make_run(width: int, max_len: int, device: bool, seed: int):
    rng = np.random.RandomState(seed)
    # worst-case occupancy: every stream runs the full width
    tok = jnp.asarray(rng.randint(0, 10000, (SWEEP_DOCS, width),
                                  dtype=np.int32))
    full = jnp.full((SWEEP_DOCS,), width, jnp.int32)
    first = jnp.asarray(rng.randint(0, width + 1, SWEEP_DOCS,
                                    dtype=np.int32))
    pages = jnp.full((SWEEP_DOCS,), 4, jnp.int32)
    empty = jnp.zeros((SWEEP_DOCS,), jnp.int32)

    def make(block_l: int):
        def run():
            out = fast_features_kernel(
                tok, full, first, pages, empty, max_len=max_len,
                block_l=block_l, ws=2, scramble=3, mangled=4,
                latex_lo=8010, ident_lo=8510, interpret=not device)
            jax.block_until_ready([o for o in out if o is not None])
        return run
    return make


def _clamp_candidates(candidates, width: int) -> tuple[int, ...]:
    # the kernel needs block_l | width; widths are powers of two >= 128,
    # so power-of-two candidates clamped to the width always divide it
    return tuple(sorted({min(int(c), width) for c in candidates}))


def autotune_fast_features(width: int, max_len: int = 0, *,
                           candidates: tuple[int, ...] = DEFAULT_CANDIDATES,
                           repeats: int = 2, device: bool = False,
                           seed: int = 0) -> TuneRecord:
    """Time every block_l candidate at (width, max_len), cache (and,
    with a tuning store configured, persist) the winner."""
    return autotune_common.sweep(
        KERNEL_NAME, (width, max_len), "block_l",
        _clamp_candidates(candidates, width),
        _make_run(width, max_len, device, seed),
        repeats=repeats, device=device)


def ensure_tuned(width: int, max_len: int = 0, *,
                 candidates: tuple[int, ...] = DEFAULT_CANDIDATES,
                 repeats: int = 1, device: bool | None = None,
                 seed: int = 0) -> int:
    """Dispatch-time hook: the tuned winner, sweeping-and-persisting on
    a miss only when a tuning store is configured (else the default)."""
    if device is None:
        device = autotune_common.current_device_mode()
    return autotune_common.ensure_tuned(
        KERNEL_NAME, (width, max_len), "block_l",
        _clamp_candidates(candidates, width),
        _make_run(width, max_len, device, seed),
        DEFAULT_BLOCK_L, repeats=repeats, device=device)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fast_features comparison-block autotune sweep")
    ap.add_argument("--width", type=int, default=2048,
                    help="packed stream width (power of two)")
    ap.add_argument("--max-len", type=int, default=64,
                    help="encoder token window (0: features only)")
    ap.add_argument("--candidates", type=str, default=None,
                    help="comma-separated block_l candidates")
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--device", action="store_true",
                    help="compile for the real accelerator (TPU only) "
                         "instead of the interpret-mode sweep")
    ap.add_argument("--tuning-dir", type=str, default=None,
                    help="persist the winner to this fleet-shared "
                         "tuning store")
    ap.add_argument("--json", type=str, default=None,
                    help="write the TuneRecord to this path")
    args = ap.parse_args(argv)
    if args.tuning_dir:
        tuning_store.configure(args.tuning_dir)
    cands = DEFAULT_CANDIDATES
    if args.candidates:
        cands = tuple(int(c) for c in args.candidates.split(","))
    rec = autotune_fast_features(args.width, args.max_len,
                                 candidates=cands, repeats=args.repeats,
                                 device=args.device)
    print(f"fast_features autotune @ (width={args.width}, "
          f"max_len={args.max_len}) "
          f"[{rec.backend}{' device' if rec.device else ' interpret'}]")
    for block_l, t in rec.timings_s:
        tag = "  <-- winner" if block_l == rec.value else ""
        print(f"  block_l={block_l:<6d} {t * 1e3:8.2f} ms{tag}")
    if args.tuning_dir:
        tuning_store.get_store().flush()
        print(f"winner persisted to {args.tuning_dir}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(dataclasses.asdict(rec), f, indent=2)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
