"""Pallas-TPU fused prepare-stage kernel: CLS-I fast features +
first-page token/mask assembly in one pass over the packed batch.

Grid: (n,) — one program per document. The document's padded token
stream (1, L) sits in VMEM; the per-doc scalars (token count,
first-page length, page counts) sit in SMEM. All eight CLS-I features
are masked reductions over the stream; the distinct-token count is a
blocked first-occurrence scan — position i is a duplicate iff some
valid earlier position holds the same token, evaluated ``block_l``
comparison columns at a time (the autotunable knob, bounding the
(L, block_l) equality tile in VMEM). The first-page token/mask pair is
the stream head shifted one right under a BOS, exactly
``features.first_page_tokens``.

Off-TPU the kernel runs in interpret mode (parity tests); dispatch for
real workloads goes through ``ops.routing_features``, which picks the
numpy oracle on CPU hosts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

N_FAST_FEATURES = 8


def _ff_kernel(ntok_ref, first_ref, pages_ref, empty_ref, tok_ref,
               *out_refs, L: int, max_len: int, block_l: int, ws: int,
               scramble: int, mangled: int, latex_lo: int, ident_lo: int,
               bos: int):
    fast_ref = out_refs[0]
    bi = pl.program_id(0)
    nt = ntok_ref[bi]
    t = tok_ref[0, :]
    pos = jax.lax.iota(jnp.int32, L)
    valid = pos < nt

    def count(mask):
        return jnp.sum((mask & valid).astype(jnp.float32))

    n_ws = count(t == ws)
    n_scr = count(t == scramble)
    n_man = count(t == mangled)
    n_latex = count((t >= latex_lo) & (t < ident_lo))

    # distinct tokens: position i is a dup iff an earlier valid position
    # holds the same token; compare block_l candidate columns at a time
    dup = jnp.zeros((L,), jnp.bool_)
    for cb in range(L // block_l):
        tb = t[cb * block_l:(cb + 1) * block_l]          # static slice
        jb = cb * block_l + jax.lax.iota(jnp.int32, block_l)
        hit = ((t[:, None] == tb[None, :])
               & (jb[None, :] < pos[:, None])            # strictly earlier
               & (jb[None, :] < nt))                     # and valid
        dup = dup | jnp.any(hit, axis=1)
    n_uniq = jnp.sum(((~dup) & valid).astype(jnp.float32))

    ntf = nt.astype(jnp.float32)
    denom = jnp.maximum(ntf, 1.0)
    pg = pages_ref[bi].astype(jnp.float32)
    ep = empty_ref[bi].astype(jnp.float32)
    nz = (nt > 0).astype(jnp.float32)    # empty-extraction signature row
    fast_ref[0, :] = nz * jnp.stack([
        jnp.log1p(ntf) / 10.0,
        n_ws / denom,
        n_scr / denom,
        n_man / denom,
        n_latex / denom,
        n_uniq / denom,
        ep / jnp.maximum(pg, 1.0),
        pg / 10.0,
    ])

    if max_len:
        toks_ref, mask_ref = out_refs[1], out_refs[2]
        m = jnp.minimum(first_ref[bi], max_len - 1)
        col = jax.lax.iota(jnp.int32, max_len)
        # stream head shifted one right under BOS (pack guarantees
        # L >= max_len - 1, so the head slice is static)
        shifted = jnp.concatenate(
            [jnp.full((1,), bos, jnp.int32), t[:max_len - 1]])
        keep = col < 1 + m
        toks_ref[0, :] = jnp.where(keep, shifted, 0)
        mask_ref[0, :] = keep.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=(
    "max_len", "block_l", "ws", "scramble", "mangled", "latex_lo",
    "ident_lo", "bos", "interpret"))
def fast_features_kernel(tok, n_tok, first_len, n_pages, n_empty, *,
                         max_len: int, block_l: int, ws: int,
                         scramble: int, mangled: int, latex_lo: int,
                         ident_lo: int, bos: int = 1, interpret=True):
    """Packed batch -> (fast (n, 8) f32[, toks (n, max_len) i32,
    mask (n, max_len) f32]) on-device. ``max_len == 0`` skips the
    token/mask outputs (the ft router variant needs features only)."""
    n, L = tok.shape
    block_l = max(1, min(int(block_l), L))
    if L % block_l:
        raise ValueError(f"block_l={block_l} must divide packed width {L}")
    if max_len and L < max_len - 1:
        raise ValueError(f"packed width {L} < max_len-1={max_len - 1}")
    kern = functools.partial(
        _ff_kernel, L=L, max_len=max_len, block_l=block_l, ws=ws,
        scramble=scramble, mangled=mangled, latex_lo=latex_lo,
        ident_lo=ident_lo, bos=bos)
    out_specs = [pl.BlockSpec((1, N_FAST_FEATURES), lambda i: (i, 0))]
    out_shape = [jax.ShapeDtypeStruct((n, N_FAST_FEATURES), jnp.float32)]
    if max_len:
        out_specs += [pl.BlockSpec((1, max_len), lambda i: (i, 0)),
                      pl.BlockSpec((1, max_len), lambda i: (i, 0))]
        out_shape += [jax.ShapeDtypeStruct((n, max_len), jnp.int32),
                      jax.ShapeDtypeStruct((n, max_len), jnp.float32)]
    out = pl.pallas_call(
        kern,
        grid=(n,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),             # n_tok
            pl.BlockSpec(memory_space=pltpu.SMEM),             # first_len
            pl.BlockSpec(memory_space=pltpu.SMEM),             # n_pages
            pl.BlockSpec(memory_space=pltpu.SMEM),             # n_empty
            pl.BlockSpec((1, L), lambda i: (i, 0)),            # tokens
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(n_tok, first_len, n_pages, n_empty, tok)
    if max_len:
        return out[0], out[1], out[2]
    return out[0], None, None
