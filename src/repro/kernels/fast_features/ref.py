"""Host-numpy oracle for the fused prepare-stage routing inputs.

Operates on the packed token-stream batch (``ops.pack_routing_batch``)
and reproduces ``core.features.batch_fast_features`` +
``batch_first_page_tokens`` **bit-for-bit**: every per-document count
is an exact integer either way, and the float64 → float32 assembly
matches the legacy expressions term by term. This is both the parity
oracle the Pallas kernel is tested against (1e-6) and the CPU dispatch
path of ``routing_features`` — it works on the flat stream in O(T)
(bincount segment sums, like the legacy path) but swaps the legacy
O(T log T) composite-key sort for an O(T + n·V) presence bitmap and
fuses the first-page token/mask assembly into the same pass, which is
where the host-side ``feature_kernel_speedup`` comes from.

Takes plain numeric token-space parameters (no ``CorpusConfig``):
kernels must not depend on core — core imports kernels, not the
reverse.
"""
from __future__ import annotations

import numpy as np

N_FAST_FEATURES = 8
# beyond this many presence-bitmap cells, fall back to the sort-based
# distinct count (bitmap memory is n_docs * vocab_size bytes)
_BITMAP_CELL_BUDGET = 1 << 26


def _distinct_per_doc(flat, rows, n: int, vocab_size: int) -> np.ndarray:
    """Exact distinct-token count per document of the flat stream."""
    if n * int(vocab_size) <= _BITMAP_CELL_BUDGET:
        present = np.zeros((n, int(vocab_size)), np.bool_)
        present[rows, flat] = True
        return present.sum(axis=1)
    key = rows.astype(np.int64) * int(vocab_size) + flat   # legacy sort
    return np.bincount(np.unique(key) // int(vocab_size), minlength=n)


def routing_features_ref(flat, rows, starts, n_tok, first_len, n_pages,
                         n_empty, *, ws: int, scramble: int, mangled: int,
                         latex_lo: int, ident_lo: int, vocab_size: int,
                         max_len: int = 0, bos: int = 1):
    """Packed batch -> (fast (n, 8) f32[, toks (n, max_len) i32,
    mask (n, max_len) f32]).

    ``flat`` is the (T,) concatenation of every document's pages,
    ``rows`` the (T,) doc index per token, ``starts`` the (n,) stream
    start offsets. Token/mask outputs are produced iff ``max_len > 0``
    (the CLS-III LLM router variant); otherwise the return is
    ``(fast, None, None)``.
    """
    flat = np.asarray(flat)
    rows = np.asarray(rows)
    n = len(n_tok)
    n_tok = np.asarray(n_tok, np.int64)
    n_pages = np.asarray(n_pages, np.int64)
    n_empty = np.asarray(n_empty, np.int64)
    out = np.zeros((n, N_FAST_FEATURES), np.float32)
    if n:
        denom = np.maximum(n_tok.astype(np.float64), 1.0)

        def frac(mask):
            return np.bincount(rows[mask], minlength=n) / denom

        out[:, 0] = np.log1p(n_tok.astype(np.float64)) / 10.0
        out[:, 1] = frac(flat == ws)
        out[:, 2] = frac(flat == scramble)
        out[:, 3] = frac(flat == mangled)
        out[:, 4] = frac((flat >= latex_lo) & (flat < ident_lo))
        out[:, 5] = _distinct_per_doc(flat, rows, n, vocab_size) / denom
        out[:, 6] = n_empty / np.maximum(n_pages, 1)
        out[:, 7] = n_pages / 10.0
        # docs with no output at all keep the all-zero signature row
        out[n_tok == 0] = 0.0
    if not max_len:
        return out, None, None
    m = np.minimum(np.asarray(first_len, np.int64), max_len - 1)
    toks = np.zeros((n, max_len), np.int32)
    mask = np.zeros((n, max_len), np.float32)
    if n:
        toks[:, 0] = bos
        # gather each stream's head (= its first page, truncated) out of
        # the flat concatenation; clip keeps padded lanes in bounds
        head = np.asarray(starts, np.int64)[:, None] \
            + np.arange(max_len - 1)[None, :]
        vals = (flat[np.minimum(head, max(len(flat) - 1, 0))]
                if len(flat) else np.zeros((n, max_len - 1), np.int32))
        keep = np.arange(max_len - 1)[None, :] < m[:, None]
        toks[:, 1:] = np.where(keep, vals, 0)
        mask[np.arange(max_len)[None, :] < (m + 1)[:, None]] = 1.0
    return out, toks, mask
