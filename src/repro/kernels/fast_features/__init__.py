from repro.kernels.fast_features.ops import (pack_routing_batch,
                                             routing_features)
from repro.kernels.fast_features.ref import routing_features_ref
