"""Public fused prepare-stage op: pack once, derive every routing input.

``pack_routing_batch`` lowers a parser-output batch (list of per-doc
page lists) into one flat token stream plus per-doc scalars — the only
Python-loop pass the prepare stage makes over the batch.
``routing_features`` then computes the 8 CLS-I fast features and (for
the LLM router variant) the fixed-length first-page token/mask pair in
one fused call: the Pallas kernel on TPU (interpret under
``force_kernel``), the exact numpy oracle (ref.py) elsewhere — so
``engine.prepare_batch``'s routing inputs feed ``route_step`` without a
host round-trip on device backends.

The kernel consumes the streams as a padded (n, width) matrix, built
lazily (the host oracle never pays the scatter) with the width padded
to a power of two (>= 128 lanes and >= the encoder ``max_len``) so the
kernel retraces — and the block_l autotuner sweeps — only O(log)
distinct widths however batches vary.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.fast_features import autotune as ff_autotune
from repro.kernels.fast_features.kernel import fast_features_kernel
from repro.kernels.fast_features.ref import routing_features_ref

MIN_WIDTH = 128


@dataclasses.dataclass(frozen=True)
class PackedBatch:
    """One parser-output batch as packed stream + per-doc scalars."""

    flat: np.ndarray         # (T,) int32 concatenated per-doc streams
    rows: np.ndarray         # (T,) int32 doc index per token
    starts: np.ndarray       # (n,) int64 stream start offsets
    n_tok: np.ndarray        # (n,) int32 true stream lengths
    first_len: np.ndarray    # (n,) int32 first-page lengths
    n_pages: np.ndarray      # (n,) int32
    n_empty: np.ndarray      # (n,) int32 empty (zero-token) pages
    max_len: int             # requested encoder width (0: features only)
    width: int               # padded kernel matrix width (power of two)

    @functools.cached_property
    def tok_matrix(self) -> np.ndarray:
        """(n, width) zero-padded stream matrix — kernel path only."""
        tok = np.zeros((len(self.n_tok), self.width), np.int32)
        if len(self.flat):
            cols = np.arange(len(self.flat)) - self.starts[self.rows]
            tok[self.rows, cols] = self.flat
        return tok


def _pow2_width(target: int) -> int:
    return max(MIN_WIDTH, 1 << int(max(target, 1) - 1).bit_length())


def pack_routing_batch(page_lists, max_len: int = 0) -> PackedBatch:
    """Concatenate each document's pages into one flat stream.

    ``width`` = next power of two >= max(longest stream, ``max_len``,
    ``MIN_WIDTH``), guaranteeing the kernel's static first-page slice
    (width >= max_len - 1) and bounding distinct compiled widths."""
    n = len(page_lists)
    pages_per_doc = np.fromiter((len(p) for p in page_lists), np.int64,
                                count=n)
    doc_of_page = np.repeat(np.arange(n), pages_per_doc)
    flat_pages = [pg for p in page_lists for pg in p]
    page_lens = np.fromiter((len(pg) for pg in flat_pages), np.int64,
                            count=len(flat_pages))
    n_empty = np.bincount(doc_of_page[page_lens == 0], minlength=n)
    doc_lens = np.zeros(n, np.int64)
    np.add.at(doc_lens, doc_of_page, page_lens)
    first_len = np.fromiter(
        ((len(p[0]) if p else 0) for p in page_lists), np.int64, count=n)
    starts = np.cumsum(doc_lens) - doc_lens
    flat = (np.concatenate(flat_pages).astype(np.int32, copy=False)
            if page_lens.sum() else np.zeros(0, np.int32))
    rows = np.repeat(np.arange(n, dtype=np.int32), doc_lens)
    width = _pow2_width(max(int(doc_lens.max()) if n else 0, int(max_len)))
    return PackedBatch(flat=flat, rows=rows, starts=starts,
                       n_tok=doc_lens.astype(np.int32),
                       first_len=first_len.astype(np.int32),
                       n_pages=pages_per_doc.astype(np.int32),
                       n_empty=n_empty.astype(np.int32),
                       max_len=int(max_len), width=width)


def routing_features(packed: PackedBatch, *, ws: int, scramble: int,
                     mangled: int, latex_lo: int, ident_lo: int,
                     vocab_size: int, bos: int = 1,
                     force_kernel: bool = False,
                     block_l: int | None = None):
    """Packed batch -> (fast, toks, mask); toks/mask are None when the
    batch was packed with ``max_len == 0``. Kernel on TPU (or under
    ``force_kernel``, in interpret mode), numpy oracle elsewhere.
    ``block_l=None`` consults the autotune cache/tuning store —
    sweeping on a miss when a persistent store is configured."""
    n = len(packed.n_tok)
    if n and (force_kernel or jax.default_backend() == "tpu"):
        device = jax.default_backend() == "tpu"
        if block_l is None:
            block_l = ff_autotune.ensure_tuned(
                packed.width, packed.max_len, device=device)
        return fast_features_kernel(
            jnp.asarray(packed.tok_matrix), jnp.asarray(packed.n_tok),
            jnp.asarray(packed.first_len), jnp.asarray(packed.n_pages),
            jnp.asarray(packed.n_empty), max_len=packed.max_len,
            block_l=block_l, ws=ws, scramble=scramble, mangled=mangled,
            latex_lo=latex_lo, ident_lo=ident_lo, bos=bos,
            interpret=not device)
    return routing_features_ref(
        packed.flat, packed.rows, packed.starts, packed.n_tok,
        packed.first_len, packed.n_pages, packed.n_empty, ws=ws,
        scramble=scramble, mangled=mangled, latex_lo=latex_lo,
        ident_lo=ident_lo, vocab_size=vocab_size, max_len=packed.max_len,
        bos=bos)
