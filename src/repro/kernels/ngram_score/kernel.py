"""Pallas-TPU fused n-gram BLEU: pairwise equality matrices + length masks.

The quality probe's scorer (metrics.score_batch) in one kernel: for each
document the (max_len, max_len) hyp-hyp and hyp-ref token equality
matrices are built once in VMEM and extended incrementally per n — an
(n+1)-gram match is an n-gram match AND a token match one position later,
i.e. the same matrix shifted up-left by one. Clipped counts without
Counters: hyp occurrence j of an n-gram g is creditable iff its
occurrence rank among equal hyp grams (strict lower-triangle row sum) is
below g's count in the reference (row sum of the hyp-ref matches).

Grid: (ceil(B / block_b),) — ``block_b`` documents per program
(statically unrolled; the autotunable knob, default 1 = one doc per
program). Token rows stream through VMEM blocks of (block_b, max_len)
while lengths sit in SMEM; the batch is zero-padded up to a block_b
multiple and padded rows write 0 and are sliced off. Shifts are
wrap-around rolls: wrapped entries only land at start positions >=
max_len - n + 1, which the validity masks (start <= len - n) always
exclude, so no sentinel fill is needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

SMOOTH = 1e-9


def _score_one(lr_ref, lh_ref, ref_ref, hyp_ref, out_ref, row, doc, *,
               max_len: int, max_n: int, n_docs: int):
    pos = jax.lax.iota(jnp.int32, max_len)
    ii = jax.lax.broadcasted_iota(jnp.int32, (max_len, max_len), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (max_len, max_len), 1)
    lower = ii > jj                       # strict: prior occurrences only

    @pl.when(doc < n_docs)
    def _():
        lr = lr_ref[doc]
        lh = lh_ref[doc]
        r = ref_ref[row, :]
        h = hyp_ref[row, :]
        eq_hh = h[:, None] == h[None, :]
        eq_hr = h[:, None] == r[None, :]
        m_hh, m_hr = eq_hh, eq_hr
        log_p = jnp.float32(0.0)
        for n in range(1, max_n + 1):
            if n > 1:
                # extend (n-1)-gram matches by the token at offset n-1:
                # the base equality matrix rolled up-left; wrapped
                # rows/cols are start positions the ph/pr masks below
                # always reject.
                t = n - 1
                m_hh = m_hh & jnp.roll(jnp.roll(eq_hh, -t, axis=0),
                                       -t, axis=1)
                m_hr = m_hr & jnp.roll(jnp.roll(eq_hr, -t, axis=0),
                                       -t, axis=1)
            ph = pos <= lh - n            # valid hyp n-gram starts
            pr = pos <= lr - n
            total = jnp.maximum(lh - n + 1, 0)
            rc = jnp.sum((m_hr & pr[None, :]).astype(jnp.int32), axis=1)
            occ = jnp.sum((m_hh & lower & ph[None, :]).astype(jnp.int32),
                          axis=1)
            clipped = jnp.sum((ph & (occ < rc)).astype(jnp.int32))
            log_p += jnp.log((clipped.astype(jnp.float32) + SMOOTH)
                             / jnp.maximum(total, 1).astype(jnp.float32))
        log_p /= max_n
        bp = jnp.minimum(
            1.0, jnp.exp(1.0 - lr.astype(jnp.float32)
                         / jnp.maximum(lh, 1).astype(jnp.float32)))
        out_ref[doc] = jnp.where(lh > 0, bp * jnp.exp(log_p), 0.0)

    @pl.when(doc >= n_docs)
    def _():
        out_ref[doc] = 0.0                # padded tail row


def _ngram_bleu_kernel(lr_ref, lh_ref, ref_ref, hyp_ref, out_ref, *,
                       max_len: int, max_n: int, block_b: int,
                       n_docs: int):
    bi = pl.program_id(0)
    for row in range(block_b):            # static unroll over block rows
        _score_one(lr_ref, lh_ref, ref_ref, hyp_ref, out_ref,
                   row, bi * block_b + row,
                   max_len=max_len, max_n=max_n, n_docs=n_docs)


@functools.partial(jax.jit, static_argnames=("max_len", "max_n",
                                             "interpret", "block_b"))
def ngram_bleu_kernel(ref, hyp, lr, lh, *, max_len: int, max_n: int = 4,
                      interpret=True, block_b: int = 1):
    """ref, hyp (B, max_len) int32 padded; lr, lh (B,) int32 lengths.

    Returns (B,) f32 per-document BLEU. ``block_b`` is the autotunable
    docs-per-program block (clamped to [1, B]).
    """
    b = ref.shape[0]
    block_b = max(1, min(int(block_b), b))
    grid = -(-b // block_b)
    b_pad = grid * block_b
    if b_pad != b:
        pad = ((0, b_pad - b),)
        ref = jnp.pad(ref, pad + ((0, 0),))
        hyp = jnp.pad(hyp, pad + ((0, 0),))
        lr = jnp.pad(lr, pad)
        lh = jnp.pad(lh, pad)
    kern = functools.partial(_ngram_bleu_kernel, max_len=max_len,
                             max_n=max_n, block_b=block_b, n_docs=b)
    out = pl.pallas_call(
        kern,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),                  # lr
            pl.BlockSpec(memory_space=pltpu.SMEM),                  # lh
            pl.BlockSpec((block_b, max_len), lambda i: (i, 0)),     # ref
            pl.BlockSpec((block_b, max_len), lambda i: (i, 0)),     # hyp
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.SMEM),
        out_shape=jax.ShapeDtypeStruct((b_pad,), jnp.float32),
        interpret=interpret,
    )(lr, lh, ref, hyp)
    return out[:b]
