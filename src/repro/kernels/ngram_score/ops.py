"""Public fused n-gram BLEU op: the quality probe's scoring hot path.

``ngram_bleu(ref, hyp, ref_len, hyp_len)`` scores a padded (B, max_len)
batch of (reference, hypothesis) token streams per document. On TPU the
Pallas kernel keeps the pairwise equality matrices in VMEM; elsewhere it
dispatches to the sorted-multiset numpy oracle (ref.py), which is both
the exact float64 mirror of the host ``metrics.bleu`` rule and an
O(L log L) replacement for the old XLA O(L^2) pairwise path — the
``engine.score_kernel_speedup`` bench measures that win at probe batch
shapes. ``force_kernel`` runs the kernel in interpret mode (CI parity).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ngram_score.autotune import tuned_block_b
from repro.kernels.ngram_score.kernel import ngram_bleu_kernel
from repro.kernels.ngram_score.ref import ngram_bleu_ref


def ngram_bleu(ref, hyp, ref_len, hyp_len, *, max_n: int = 4,
               force_kernel: bool = False,
               block_b: int | None = None) -> np.ndarray:
    """ref, hyp: (B, max_len) padded int id arrays; ref_len, hyp_len:
    (B,) true lengths. Returns (B,) float64 per-document BLEU.
    ``block_b=None`` consults the per-shape autotune cache/store on the
    kernel path (default: one doc per program for untuned shapes)."""
    ref = np.asarray(ref)
    hyp = np.asarray(hyp)
    if ref.shape != hyp.shape or ref.ndim != 2:
        raise ValueError(f"ngram_bleu needs matching (B, max_len) ref/hyp "
                         f"batches (got {ref.shape} vs {hyp.shape})")
    if force_kernel or jax.default_backend() == "tpu":
        if block_b is None:
            block_b = tuned_block_b(ref.shape[0], ref.shape[1], max_n)
        out = ngram_bleu_kernel(
            jnp.asarray(ref, jnp.int32), jnp.asarray(hyp, jnp.int32),
            jnp.asarray(ref_len, jnp.int32), jnp.asarray(hyp_len, jnp.int32),
            max_len=ref.shape[1], max_n=max_n,
            interpret=jax.default_backend() != "tpu", block_b=block_b)
        return np.asarray(out, np.float64)
    return ngram_bleu_ref(ref, hyp, np.asarray(ref_len),
                          np.asarray(hyp_len), max_n=max_n)
