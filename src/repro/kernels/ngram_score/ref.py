"""Host oracle for the fused n-gram BLEU scorer: exact float64 mirror.

Same scoring rule as ``metrics.bleu`` (uniform n<=max_n weights, brevity
penalty, 1e-9 smoothing) on padded (B, max_len) id batches with length
masks, and the parity reference for the Pallas kernel. Clipped counts
come from sorted n-gram multisets instead of the kernel's O(L^2)
pairwise equality matrices — different factorization, identical counts.

The whole batch is counted at once with *dense integer gram ids*: an
n-gram's id extends the (n-1)-gram's compacted id by the next token's
compacted id, with the document id folded into the chain at order 1.
One int64 ``np.argsort`` per order over the valid positions of every
document (hyp and ref streams together) then yields everything at
once — run boundaries in the sorted values delimit the (doc, gram)
groups, per-stream bincounts over the group ranks give the clipped
counts, and the ranks scattered back are the dense ids the next order
extends. ~1 argsort per order over ~2·B·L elements total, instead of
byte-wise void sorts per document. This is the fast CPU dispatch
target of ``ops.ngram_bleu`` and the ``engine.score_kernel_speedup``
win over the old XLA pairwise path.

``_doc_bleu`` keeps the simple one-document factorization as the
oracle's oracle (tests pit the batched counts against it).
"""
from __future__ import annotations

import numpy as np

SMOOTH = 1e-9


def _gram_view(seq: np.ndarray, n: int) -> np.ndarray:
    """All n-gram windows of ``seq`` as one void element per gram, so a
    single sort/unique over opaque bytes counts the multiset."""
    win = np.ascontiguousarray(
        np.lib.stride_tricks.sliding_window_view(seq, n))
    return win.view(np.dtype((np.void, win.dtype.itemsize * n))).ravel()


def _doc_bleu(ref: np.ndarray, hyp: np.ndarray, max_n: int) -> float:
    """One document, the straightforward per-doc factorization."""
    lh = len(hyp)
    if lh == 0:
        return 0.0
    log_p = 0.0
    for n in range(1, max_n + 1):
        total = max(lh - n + 1, 0)
        clipped = 0
        if total > 0 and len(ref) >= n:
            uh, ch = np.unique(_gram_view(hyp, n), return_counts=True)
            ur, cr = np.unique(_gram_view(ref, n), return_counts=True)
            _, ih, ir = np.intersect1d(uh, ur, assume_unique=True,
                                       return_indices=True)
            clipped = int(np.minimum(ch[ih], cr[ir]).sum())
        log_p += np.log((clipped + SMOOTH) / max(total, 1))
    log_p /= max_n
    bp = min(1.0, np.exp(1.0 - len(ref) / max(lh, 1)))
    return float(bp * np.exp(log_p))


def ngram_bleu_ref(ref: np.ndarray, hyp: np.ndarray, ref_len: np.ndarray,
                   hyp_len: np.ndarray, *, max_n: int = 4) -> np.ndarray:
    """Per-document BLEU over a padded batch.

    ref, hyp: (B, max_len) int id arrays (padding beyond the lengths is
    ignored); ref_len, hyp_len: (B,) true lengths. Returns (B,) float64.
    """
    ref = np.ascontiguousarray(ref)
    hyp = np.ascontiguousarray(hyp)
    lr = np.asarray(ref_len, np.int64)
    lh = np.asarray(hyp_len, np.int64)
    b, max_len = ref.shape
    lens = np.concatenate([lh, lr])            # rows 0..b-1 hyp, b.. ref

    # order-1 compacted token ids over every position of both streams
    # (padding garbage compacts too; it is masked out before counting
    # and, because valid positions shrink with the order, a padded id
    # can never leak into a later order's extension). T = id count.
    both = np.concatenate([hyp, ref], 0).astype(np.int64, copy=False)
    u0 = np.unique(both)
    tok1 = np.searchsorted(u0, both.ravel()).reshape(2 * b, max_len)
    t_ids = np.int64(len(u0))
    doc2 = np.broadcast_to((np.arange(2 * b) % b)[:, None],
                           (2 * b, max_len))
    is_ref2 = np.broadcast_to((np.arange(2 * b) >= b)[:, None],
                              (2 * b, max_len))
    g = doc2 * t_ids + tok1
    log_p = np.zeros(b, np.float64)
    for n in range(1, max_n + 1):
        w = max_len - n + 1
        total = np.maximum(lh - n + 1, 0)
        if w <= 0:                     # max_len < n: no grams anywhere
            log_p += np.log(SMOOTH / np.maximum(total, 1))
            continue
        if n > 1:
            # extend the (doc, (n-1)-gram) id at position p by the
            # token at p+n-1; ids stay < 2*b*max_len and t_ids <=
            # 2*b*max_len, so the product never overflows int64
            g = g[:, :w] * t_ids + tok1[:, n - 1:]
        valid = np.arange(w)[None, :] < (lens[:, None] - n + 1)
        vals = g[valid]
        if vals.size == 0:             # every document shorter than n
            log_p += np.log(SMOOTH / np.maximum(total, 1))
            continue                   # valid only shrinks: g is moot
        # ONE argsort: runs of equal sorted values are the (doc, gram)
        # multiset entries of both streams at once (stability is
        # irrelevant — group identity and counts are order-free)
        order = np.argsort(vals)
        s = vals[order]
        new = np.empty(s.size, np.bool_)
        new[0] = True
        np.not_equal(s[1:], s[:-1], out=new[1:])
        grp = np.cumsum(new) - 1       # dense group rank per element
        n_grp = int(grp[-1]) + 1
        fr = is_ref2[:, :w][valid][order]
        cr = np.bincount(grp[fr], minlength=n_grp)
        ch = np.bincount(grp[~fr], minlength=n_grp)
        docg = doc2[:, :w][valid][order[new]]   # one doc id per group
        clipped = np.bincount(docg, weights=np.minimum(ch, cr),
                              minlength=b)
        log_p += np.log((clipped + SMOOTH) / np.maximum(total, 1))
        if n < max_n:
            # the group rank doubles as the next order's dense id
            ids = np.empty(s.size, np.int64)
            ids[order] = grp
            nxt = np.zeros((2 * b, w), np.int64)
            nxt[valid] = ids
            g = nxt
    log_p /= max_n
    bp = np.minimum(1.0, np.exp(1.0 - lr / np.maximum(lh, 1)))
    return np.where(lh > 0, bp * np.exp(log_p), 0.0)
