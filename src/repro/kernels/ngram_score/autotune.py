"""Docs-per-program autotuning for the ngram_score kernel.

Same harness as budget_route's block_n sweep (``autotune_common``): time
``block_b`` candidates at a (B, max_len, max_n) probe shape, cache the
winner per (shape, backend, device-mode), persist it when a tuning
store is configured so a warm fleet restart re-dispatches without
re-sweeping. Interpret-mode timings are a functional signal only; the
real sweep is TPU-gated behind ``device=True``.

CLI: ``python -m repro.kernels.ngram_score.autotune [--device]
[--tuning-dir DIR] [--json OUT]``.
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import autotune_common, tuning_store
from repro.kernels.autotune_common import TuneRecord  # re-export
from repro.kernels.ngram_score.kernel import ngram_bleu_kernel

KERNEL_NAME = "ngram_score"
DEFAULT_BLOCK_B = 1
DEFAULT_CANDIDATES = (1, 2, 4, 8)

__all__ = ["TuneRecord", "autotune_ngram_bleu", "tuned_block_b",
           "ensure_tuned", "clear_cache", "DEFAULT_BLOCK_B",
           "DEFAULT_CANDIDATES", "KERNEL_NAME"]


def tuned_block_b(b: int, max_len: int, max_n: int = 4,
                  device: bool | None = None) -> int:
    """The cached/stored winner for this probe shape, or the default
    (one document per program)."""
    return autotune_common.tuned_value(
        KERNEL_NAME, (b, max_len, max_n), DEFAULT_BLOCK_B, device=device)


def clear_cache() -> None:
    autotune_common.clear_cache()


def _make_run(b: int, max_len: int, max_n: int, device: bool, seed: int):
    rng = np.random.RandomState(seed)
    ref = jnp.asarray(rng.randint(0, 5000, (b, max_len), dtype=np.int32))
    hyp = jnp.asarray(rng.randint(0, 5000, (b, max_len), dtype=np.int32))
    lr = jnp.asarray(rng.randint(1, max_len + 1, b, dtype=np.int32))
    lh = jnp.asarray(rng.randint(1, max_len + 1, b, dtype=np.int32))

    def make(block_b: int):
        def run():
            out = ngram_bleu_kernel(ref, hyp, lr, lh, max_len=max_len,
                                    max_n=max_n, interpret=not device,
                                    block_b=block_b)
            jax.block_until_ready(out)
        return run
    return make


def _clamp_candidates(candidates, b: int) -> tuple[int, ...]:
    return tuple(sorted({max(1, min(int(c), b)) for c in candidates}))


def autotune_ngram_bleu(b: int, max_len: int, *, max_n: int = 4,
                        candidates: tuple[int, ...] = DEFAULT_CANDIDATES,
                        repeats: int = 2, device: bool = False,
                        seed: int = 0) -> TuneRecord:
    """Time every block_b candidate at (b, max_len, max_n), cache (and,
    with a tuning store configured, persist) the winner."""
    return autotune_common.sweep(
        KERNEL_NAME, (b, max_len, max_n), "block_b",
        _clamp_candidates(candidates, b),
        _make_run(b, max_len, max_n, device, seed),
        repeats=repeats, device=device)


def ensure_tuned(b: int, max_len: int, *, max_n: int = 4,
                 candidates: tuple[int, ...] = DEFAULT_CANDIDATES,
                 repeats: int = 1, device: bool | None = None,
                 seed: int = 0) -> int:
    """Dispatch-time hook: the tuned winner, sweeping-and-persisting on
    a miss only when a tuning store is configured (else the default)."""
    if device is None:
        device = autotune_common.current_device_mode()
    return autotune_common.ensure_tuned(
        KERNEL_NAME, (b, max_len, max_n), "block_b",
        _clamp_candidates(candidates, b),
        _make_run(b, max_len, max_n, device, seed),
        DEFAULT_BLOCK_B, repeats=repeats, device=device)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="ngram_score docs-per-program autotune sweep")
    ap.add_argument("--b", type=int, default=256,
                    help="probe batch size (docs per score_batch call)")
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-n", type=int, default=4)
    ap.add_argument("--candidates", type=str, default=None,
                    help="comma-separated block_b candidates")
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--device", action="store_true",
                    help="compile for the real accelerator (TPU only) "
                         "instead of the interpret-mode sweep")
    ap.add_argument("--tuning-dir", type=str, default=None,
                    help="persist the winner to this fleet-shared "
                         "tuning store")
    ap.add_argument("--json", type=str, default=None,
                    help="write the TuneRecord to this path")
    args = ap.parse_args(argv)
    if args.tuning_dir:
        tuning_store.configure(args.tuning_dir)
    cands = DEFAULT_CANDIDATES
    if args.candidates:
        cands = tuple(int(c) for c in args.candidates.split(","))
    rec = autotune_ngram_bleu(args.b, args.max_len, max_n=args.max_n,
                              candidates=cands, repeats=args.repeats,
                              device=args.device)
    print(f"ngram_score autotune @ (b={args.b}, max_len={args.max_len}, "
          f"max_n={args.max_n}) "
          f"[{rec.backend}{' device' if rec.device else ' interpret'}]")
    for block_b, t in rec.timings_s:
        tag = "  <-- winner" if block_b == rec.value else ""
        print(f"  block_b={block_b:<4d} {t * 1e3:8.2f} ms{tag}")
    if args.tuning_dir:
        tuning_store.get_store().flush()
        print(f"winner persisted to {args.tuning_dir}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(dataclasses.asdict(rec), f, indent=2)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
