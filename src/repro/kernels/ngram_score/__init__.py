from repro.kernels.ngram_score.ops import ngram_bleu
from repro.kernels.ngram_score.ref import ngram_bleu_ref
