"""Pallas-TPU flash attention (forward): blocked online-softmax.

Grid: (batch*kv_heads*q_groups, n_q_blocks, n_kv_blocks) — the kv-block
dim is innermost/sequential so the (m, l, o) accumulators live in VMEM
scratch across kv steps. Q/K/V tiles are BlockSpec'd into VMEM with
MXU-aligned (block_q, d_head) / (block_k, d_head) shapes; block sizes are
multiples of 128 where the head dim allows.

Causal + sliding-window masking is applied inside the tile; fully-masked
tiles are skipped at trace time via the grid index-map pruning trick
(we still visit them but exit early with @pl.when — on TPU the bandwidth
win comes from the early exit before the MXU issue).

This kernel is the TPU target of ``models.attention.attention_xla_flash``
(the XLA fallback used by CPU dry-runs); both share the ref oracle.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, causal: bool, window: int | None,
               block_q: int, block_k: int, seq_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    n_k = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_lo = qi * block_q
    k_lo = ki * block_k
    # static-shape visibility: skip tiles fully masked by causality/window
    run = True
    if causal or window is not None:
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        visible = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            visible &= qpos >= kpos
        if window is not None:
            visible &= (qpos - kpos) < window
        any_visible = jnp.any(visible)
    else:
        visible = None
        any_visible = jnp.bool_(True)

    @pl.when(any_visible)
    def _body():
        q = q_ref[0].astype(jnp.float32)            # (block_q, d)
        k = k_ref[0].astype(jnp.float32)            # (block_k, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos1 = k_lo + jax.lax.broadcasted_iota(jnp.int32,
                                                (block_q, block_k), 1)
        mask = kpos1 < seq_kv                        # kv padding
        if visible is not None:
            mask &= visible
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=-1)[:, None]
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)[:, None]
        v = v_ref[0].astype(jnp.float32)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == n_k - 1)
    def _finish():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention_kernel(q, k, v, *, causal=True, window=None,
                           block_q=128, block_k=128, interpret=True):
    """q (B, Sq, H, D); k, v (B, Skv, Hk, D); H % Hk == 0.

    Grouped heads are folded into the batch dim: each (b, kv_head, group)
    triple is an independent attention problem over its kv stream.
    """
    b, sq, h, d = q.shape
    _, skv, hk, _ = k.shape
    g = h // hk
    scale = 1.0 / math.sqrt(d)
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    pq, pk = (-sq) % block_q, (-skv) % block_k
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    sq_p, skv_p = sq + pq, skv + pk
    # (B, S, Hk, G, D) -> (B*Hk*G, S, D)
    qf = q.reshape(b, sq_p, hk, g, d).transpose(0, 2, 3, 1, 4) \
          .reshape(b * hk * g, sq_p, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hk, skv_p, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hk, skv_p, d)

    grid = (b * hk * g, sq_p // block_q, skv_p // block_k)
    kern = functools.partial(_fa_kernel, scale=scale, causal=causal,
                             window=window, block_q=block_q,
                             block_k=block_k, seq_kv=skv)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda bh, qi, ki, g_=g: (bh // g_, ki, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda bh, qi, ki, g_=g: (bh // g_, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hk * g, sq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    out = out.reshape(b, hk, g, sq_p, d).transpose(0, 3, 1, 2, 4) \
             .reshape(b, sq_p, h, d)
    return out[:, :sq]
