"""Pure-jnp oracle for flash attention (GQA + causal + sliding window)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=None):
    b, sq, h, d = q.shape
    _, skv, hk, _ = k.shape
    qg = q.reshape(b, sq, hk, h // hk, d)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(d)
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    ok = jnp.ones((sq, skv), bool)
    if causal:
        ok &= qpos >= kpos
    if window is not None:
        ok &= (qpos - kpos) < window
    s = jnp.where(ok, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, d).astype(q.dtype)
