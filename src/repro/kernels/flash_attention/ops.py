"""jit'd public wrapper: picks the Pallas kernel on TPU backends and the
interpret-mode kernel elsewhere (CPU validation). Forward-only — training
paths use models.attention.attention_xla_flash (same math, XLA autodiff).
"""
from __future__ import annotations

import jax

from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ref import flash_attention_ref


def flash_attention(q, k, v, *, causal=True, window=None,
                    block_q=128, block_k=128):
    interpret = jax.default_backend() != "tpu"
    return flash_attention_kernel(q, k, v, causal=causal, window=window,
                                  block_q=block_q, block_k=block_k,
                                  interpret=interpret)
