"""Pure-jnp oracle for budget_route: stable select-and-compact."""
from __future__ import annotations

import jax.numpy as jnp


def budget_route_ref(scores, tokens, tau, *, capacity: int):
    n, d = tokens.shape
    mask = scores >= tau
    pos = jnp.cumsum(mask.astype(jnp.int32)) - mask.astype(jnp.int32)
    keep = mask & (pos < capacity)
    out = jnp.zeros((capacity, d), tokens.dtype)
    idx = jnp.full((capacity,), -1, jnp.int32)
    rows = jnp.arange(n, dtype=jnp.int32)
    out = out.at[jnp.where(keep, pos, capacity)].set(
        tokens, mode="drop")
    idx = idx.at[jnp.where(keep, pos, capacity)].set(rows, mode="drop")
    count = jnp.minimum(mask.sum(), capacity).astype(jnp.int32)
    return out, idx, count
