"""Pure-jnp oracle for budget_route: stable select-and-compact.

Selection rule (shared with the Pallas kernel and scheduler.plan_batch):
rows with score > τ are always kept (at most capacity−1 exist when τ is
the capacity-th largest score); ties at τ fill the remaining slots in
row order. A strictly better row is therefore never displaced by a tie.
"""
from __future__ import annotations

import jax.numpy as jnp


def budget_route_ref(scores, tokens, tau, *, capacity: int):
    n, d = tokens.shape
    gt = scores > tau
    eq = scores == tau
    tie_cap = capacity - jnp.sum(gt)
    tie_rank = jnp.cumsum(eq.astype(jnp.int32)) - eq.astype(jnp.int32)
    mask = gt | (eq & (tie_rank < tie_cap))
    pos = jnp.cumsum(mask.astype(jnp.int32)) - mask.astype(jnp.int32)
    keep = mask & (pos < capacity)
    out = jnp.zeros((capacity, d), tokens.dtype)
    idx = jnp.full((capacity,), -1, jnp.int32)
    rows = jnp.arange(n, dtype=jnp.int32)
    out = out.at[jnp.where(keep, pos, capacity)].set(
        tokens, mode="drop")
    idx = idx.at[jnp.where(keep, pos, capacity)].set(rows, mode="drop")
    count = jnp.minimum(mask.sum(), capacity).astype(jnp.int32)
    return out, idx, count
