"""Public fused budget-route op: top-k threshold + Pallas compact-gather.

``budget_route(scores, tokens, alpha)`` is the device-side realization of
scheduler.plan_batch: τ = (⌊α·N⌋)-th largest score via lax.top_k (O(N)),
then one fused select+compact pass. Falls back to the jnp ref off-TPU
unless ``force_kernel`` (tests run the kernel in interpret mode).

Semantics are the *exact* device mirror of ``scheduler.plan_batch``:
floor capacity (⌊α·N⌋ == 0 routes nothing), τ clamped to the shared
positive-improvement threshold, ties at τ kept in row order up to
capacity. The host plan and this op therefore select identical document
sets on the same scores (property-tested in tests/test_routing.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.budget_route.kernel import budget_route_kernel
from repro.kernels.budget_route.ref import budget_route_ref

# keep in sync with scheduler.POSITIVE_TAU (not imported: kernels must not
# depend on core)
POSITIVE_TAU = 1e-12


def budget_route(scores, tokens, alpha: float, *, force_kernel=False,
                 require_positive: bool = True):
    n = scores.shape[0]
    capacity = int(alpha * n)
    if capacity == 0:                 # static: alpha & n are trace-time
        d = tokens.shape[1]
        return (jnp.zeros((0, d), tokens.dtype),
                jnp.zeros((0,), jnp.int32),
                jnp.zeros((), jnp.int32))
    kth = jax.lax.top_k(scores, capacity)[0][-1]
    if require_positive:
        kth = jnp.maximum(kth, jnp.asarray(POSITIVE_TAU, scores.dtype))
    if force_kernel or jax.default_backend() == "tpu":
        return budget_route_kernel(scores, tokens, kth, capacity=capacity,
                                   interpret=jax.default_backend() != "tpu")
    return budget_route_ref(scores, tokens, kth, capacity=capacity)
