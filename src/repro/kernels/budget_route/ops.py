"""Public fused budget-route op: top-k threshold + Pallas compact-gather.

``budget_route(scores, tokens, alpha)`` is the device-side realization of
scheduler.plan_batch: τ = (⌊α·N⌋)-th largest score via lax.top_k (O(N)),
then one fused select+compact pass. Falls back to the jnp ref off-TPU
unless ``force_kernel`` (tests run the kernel in interpret mode).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.budget_route.kernel import budget_route_kernel
from repro.kernels.budget_route.ref import budget_route_ref


def budget_route(scores, tokens, alpha: float, *, force_kernel=False,
                 require_positive: bool = True):
    n = scores.shape[0]
    capacity = max(int(alpha * n), 1)
    kth = jax.lax.top_k(scores, capacity)[0][-1]
    if require_positive:
        kth = jnp.maximum(kth, jnp.asarray(1e-12, scores.dtype))
    if force_kernel or jax.default_backend() == "tpu":
        return budget_route_kernel(scores, tokens, kth, capacity=capacity,
                                   interpret=jax.default_backend() != "tpu")
    return budget_route_ref(scores, tokens, kth, capacity=capacity)
