"""Public fused budget-route op: top-k threshold + Pallas compact-gather.

``budget_route(scores, tokens, alpha)`` is the device-side realization of
scheduler.plan_batch: τ = (⌊α·N⌋)-th largest score via lax.top_k (O(N)),
then one fused select+compact pass. Falls back to the jnp ref off-TPU
unless ``force_kernel`` (tests run the kernel in interpret mode).

Semantics are the *exact* device mirror of ``scheduler.plan_batch``:
floor capacity (⌊α·N⌋ == 0 routes nothing), τ clamped to the shared
positive-improvement threshold, ties at τ kept in row order up to
capacity. The host plan and this op therefore select identical document
sets on the same scores (property-tested in tests/test_routing.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.budget_route.autotune import tuned_block_n
from repro.kernels.budget_route.kernel import budget_route_kernel
from repro.kernels.budget_route.ref import budget_route_ref

# keep in sync with scheduler.POSITIVE_TAU (not imported: kernels must not
# depend on core)
POSITIVE_TAU = 1e-12


def capacity_floor(alpha: float, k: int) -> int:
    """⌊α·k⌋ with an epsilon guard against float dust.

    ``int(alpha * k)`` under-floors rational α whose product is an exact
    integer (0.29 * 100 → 28.999999999999996 → 28, not 29). Snap the
    product to the nearest integer when it is within 1e-9 *relative*
    tolerance — tight enough that genuinely fractional products
    (0.2899999 * 100) still truncate — then floor and clamp to [0, k].

    Single source of truth for every selection path: the host mirror
    (``scheduler.plan_batch`` / ``budget_topk``) and the device op
    (``budget_route``) all call this, so capacity parity holds by
    construction. Lives in the kernels layer because kernels must not
    depend on core (core imports kernels, not the reverse).
    """
    v = alpha * k
    r = round(v)
    if abs(v - r) <= 1e-9 * max(abs(v), 1.0):
        v = r
    return max(min(int(v), k), 0)


def budget_route(scores, tokens, alpha: float, *, force_kernel=False,
                 require_positive: bool = True,
                 block_n: int | None = None):
    """``block_n=None`` consults the per-shape autotune cache
    (``autotune.tuned_block_n``) and falls back to the default block
    size for untuned shapes; pass an explicit value to override."""
    n = scores.shape[0]
    capacity = capacity_floor(alpha, n)
    if capacity == 0:                 # static: alpha & n are trace-time
        d = tokens.shape[1]
        return (jnp.zeros((0, d), tokens.dtype),
                jnp.zeros((0,), jnp.int32),
                jnp.zeros((), jnp.int32))
    kth = jax.lax.top_k(scores, capacity)[0][-1]
    if require_positive:
        kth = jnp.maximum(kth, jnp.asarray(POSITIVE_TAU, scores.dtype))
    if force_kernel or jax.default_backend() == "tpu":
        if block_n is None:
            block_n = tuned_block_n(n, tokens.shape[1], capacity)
        return budget_route_kernel(scores, tokens, kth, capacity=capacity,
                                   block_n=block_n,
                                   interpret=jax.default_backend() != "tpu")
    return budget_route_ref(scores, tokens, kth, capacity=capacity)
