"""Block-size autotuning for the budget_route kernel.

Sweeps ``block_n`` candidates at a given (N, D, capacity) shape, times
the fused select+compact kernel, and caches the winner per shape +
backend so ``budget_route`` picks it up transparently on later calls.
The CI sweep runs in interpret mode (functional timing signal only — it
exercises the grid/BlockSpec plumbing at every candidate); the
real-device sweep is gated behind ``device=True`` (CLI ``--device``) and
refuses to run off-TPU, because interpret-mode timings say nothing about
TPU block residency.

CLI: ``python -m repro.kernels.budget_route.autotune [--route-64k]
[--device] [--json OUT]``.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.budget_route.kernel import budget_route_kernel

DEFAULT_BLOCK_N = 256
DEFAULT_CANDIDATES = (128, 256, 512, 1024, 2048)
# the production routing shape (configs.py "adaparse-router" route_64k)
ROUTE_64K = (65536, 512)


@dataclasses.dataclass(frozen=True)
class TuneRecord:
    n: int
    d_tok: int
    capacity: int
    backend: str
    device: bool
    block_n: int                       # the winner
    timings_s: tuple[tuple[int, float], ...]   # (candidate, best-of-reps)


_CACHE: dict[tuple[int, int, int, str], TuneRecord] = {}


def _key(n: int, d_tok: int, capacity: int) -> tuple[int, int, int, str]:
    return (n, d_tok, capacity, jax.default_backend())


def tuned_block_n(n: int, d_tok: int, capacity: int) -> int:
    """The cached winner for this shape, or the default block size."""
    rec = _CACHE.get(_key(n, d_tok, capacity))
    return rec.block_n if rec is not None else DEFAULT_BLOCK_N


def clear_cache() -> None:
    _CACHE.clear()


def autotune_budget_route(n: int, d_tok: int, capacity: int, *,
                          candidates: tuple[int, ...] = DEFAULT_CANDIDATES,
                          repeats: int = 2, device: bool = False,
                          seed: int = 0) -> TuneRecord:
    """Time every candidate block size at (n, d_tok, capacity), cache and
    return the winner. ``device=True`` compiles for the real accelerator
    and requires a TPU backend; otherwise the sweep runs in interpret
    mode."""
    backend = jax.default_backend()
    if device and backend != "tpu":
        raise RuntimeError(
            f"autotune device sweep needs a TPU backend (found {backend!r});"
            f" drop --device / device=True for the interpret-mode sweep")
    if capacity < 1 or capacity > n:
        raise ValueError(f"capacity must be in [1, n={n}] (got {capacity})")
    rng = np.random.RandomState(seed)
    scores = jnp.asarray(rng.rand(n).astype(np.float32))
    tokens = jnp.asarray(rng.randint(0, 50000, (n, d_tok), dtype=np.int32))
    tau = jax.lax.top_k(scores, capacity)[0][-1]
    # dedupe candidates after the kernel's block_n = min(block_n, n) clamp
    grid = sorted({min(c, n) for c in candidates})
    timings: list[tuple[int, float]] = []
    for block_n in grid:
        def run():
            out, idx, count = budget_route_kernel(
                scores, tokens, tau, capacity=capacity, block_n=block_n,
                interpret=not device)
            jax.block_until_ready((out, idx, count))
        run()                           # warm the jit cache
        best = min(_timeit(run) for _ in range(repeats))
        timings.append((block_n, best))
    winner = min(timings, key=lambda t: t[1])[0]
    rec = TuneRecord(n=n, d_tok=d_tok, capacity=capacity, backend=backend,
                     device=device, block_n=winner,
                     timings_s=tuple(timings))
    _CACHE[_key(n, d_tok, capacity)] = rec
    return rec


def _timeit(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="budget_route block-size autotune sweep")
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--d-tok", type=int, default=128)
    ap.add_argument("--alpha", type=float, default=0.05)
    ap.add_argument("--route-64k", action="store_true",
                    help="sweep the production route_64k shape "
                         f"{ROUTE_64K} instead of --n/--d-tok")
    ap.add_argument("--candidates", type=str, default=None,
                    help="comma-separated block_n candidates")
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--device", action="store_true",
                    help="compile for the real accelerator (TPU only) "
                         "instead of the interpret-mode sweep")
    ap.add_argument("--json", type=str, default=None,
                    help="write the TuneRecord to this path")
    args = ap.parse_args(argv)
    n, d_tok = ROUTE_64K if args.route_64k else (args.n, args.d_tok)
    from repro.kernels.budget_route.ops import capacity_floor
    capacity = max(capacity_floor(args.alpha, n), 1)
    cands = DEFAULT_CANDIDATES
    if args.candidates:
        cands = tuple(int(c) for c in args.candidates.split(","))
    rec = autotune_budget_route(n, d_tok, capacity, candidates=cands,
                                repeats=args.repeats, device=args.device)
    print(f"budget_route autotune @ (n={n}, d={d_tok}, cap={capacity}) "
          f"[{rec.backend}{' device' if rec.device else ' interpret'}]")
    for block_n, t in rec.timings_s:
        tag = "  <-- winner" if block_n == rec.block_n else ""
        print(f"  block_n={block_n:<6d} {t * 1e3:8.2f} ms{tag}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(dataclasses.asdict(rec), f, indent=2)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
