"""Block-size autotuning for the budget_route kernel.

Sweeps ``block_n`` candidates at a given (N, D, capacity) shape through
the shared ``autotune_common`` harness, and caches the winner per
(shape, backend, device-mode) so ``budget_route`` picks it up
transparently on later calls. The CI sweep runs in interpret mode
(functional timing signal only — it exercises the grid/BlockSpec
plumbing at every candidate); the real-device sweep is gated behind
``device=True`` (CLI ``--device``) and refuses to run off-TPU, because
interpret-mode timings say nothing about TPU block residency. The
device flag is part of the cache/store key, so on a TPU host an
interpret sweep can never poison device dispatch.

With a persistent tuning store configured (``serve.py --tuning-dir``),
winners survive the process: a warm fleet restart re-dispatches at
tuned block sizes with zero re-sweeps.

CLI: ``python -m repro.kernels.budget_route.autotune [--route-64k]
[--device] [--tuning-dir DIR] [--json OUT]``.
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import autotune_common, tuning_store
from repro.kernels.autotune_common import TuneRecord  # re-export
from repro.kernels.budget_route.kernel import budget_route_kernel

KERNEL_NAME = "budget_route"
DEFAULT_BLOCK_N = 256
DEFAULT_CANDIDATES = (128, 256, 512, 1024, 2048)
# the production routing shape (configs.py "adaparse-router" route_64k)
ROUTE_64K = (65536, 512)

__all__ = ["TuneRecord", "autotune_budget_route", "tuned_block_n",
           "ensure_tuned", "clear_cache", "DEFAULT_BLOCK_N",
           "DEFAULT_CANDIDATES", "ROUTE_64K", "KERNEL_NAME"]


def tuned_block_n(n: int, d_tok: int, capacity: int,
                  device: bool | None = None) -> int:
    """The cached/stored winner for this shape, or the default block
    size. ``device`` defaults to the mode dispatch actually runs in on
    this host (compiled on TPU, interpret elsewhere)."""
    return autotune_common.tuned_value(
        KERNEL_NAME, (n, d_tok, capacity), DEFAULT_BLOCK_N, device=device)


def clear_cache() -> None:
    autotune_common.clear_cache()


def _make_run(n: int, d_tok: int, capacity: int, device: bool, seed: int):
    rng = np.random.RandomState(seed)
    scores = jnp.asarray(rng.rand(n).astype(np.float32))
    tokens = jnp.asarray(rng.randint(0, 50000, (n, d_tok), dtype=np.int32))
    tau = jax.lax.top_k(scores, capacity)[0][-1]

    def make(block_n: int):
        def run():
            out, idx, count = budget_route_kernel(
                scores, tokens, tau, capacity=capacity, block_n=block_n,
                interpret=not device)
            jax.block_until_ready((out, idx, count))
        return run
    return make


def _clamp_candidates(candidates, n: int) -> tuple[int, ...]:
    # dedupe candidates after the kernel's block_n = min(block_n, n) clamp
    return tuple(sorted({min(int(c), n) for c in candidates}))


def autotune_budget_route(n: int, d_tok: int, capacity: int, *,
                          candidates: tuple[int, ...] = DEFAULT_CANDIDATES,
                          repeats: int = 2, device: bool = False,
                          seed: int = 0) -> TuneRecord:
    """Time every candidate block size at (n, d_tok, capacity), cache
    (and, when a tuning store is configured, persist) the winner.
    ``device=True`` compiles for the real accelerator and requires a
    TPU backend; otherwise the sweep runs in interpret mode."""
    if capacity < 1 or capacity > n:
        raise ValueError(f"capacity must be in [1, n={n}] (got {capacity})")
    return autotune_common.sweep(
        KERNEL_NAME, (n, d_tok, capacity), "block_n",
        _clamp_candidates(candidates, n),
        _make_run(n, d_tok, capacity, device, seed),
        repeats=repeats, device=device)


def ensure_tuned(n: int, d_tok: int, capacity: int, *,
                 candidates: tuple[int, ...] = DEFAULT_CANDIDATES,
                 repeats: int = 1, device: bool | None = None,
                 seed: int = 0) -> int:
    """Dispatch-time hook: the tuned winner, sweeping-and-persisting on
    a miss only when a tuning store is configured (else the default)."""
    if device is None:
        device = autotune_common.current_device_mode()
    return autotune_common.ensure_tuned(
        KERNEL_NAME, (n, d_tok, capacity), "block_n",
        _clamp_candidates(candidates, n),
        _make_run(n, d_tok, capacity, device, seed),
        DEFAULT_BLOCK_N, repeats=repeats, device=device)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="budget_route block-size autotune sweep")
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--d-tok", type=int, default=128)
    ap.add_argument("--alpha", type=float, default=0.05)
    ap.add_argument("--route-64k", action="store_true",
                    help="sweep the production route_64k shape "
                         f"{ROUTE_64K} instead of --n/--d-tok")
    ap.add_argument("--candidates", type=str, default=None,
                    help="comma-separated block_n candidates")
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--device", action="store_true",
                    help="compile for the real accelerator (TPU only) "
                         "instead of the interpret-mode sweep")
    ap.add_argument("--tuning-dir", type=str, default=None,
                    help="persist the winner to this fleet-shared "
                         "tuning store")
    ap.add_argument("--json", type=str, default=None,
                    help="write the TuneRecord to this path")
    args = ap.parse_args(argv)
    if args.tuning_dir:
        tuning_store.configure(args.tuning_dir)
    n, d_tok = ROUTE_64K if args.route_64k else (args.n, args.d_tok)
    from repro.kernels.budget_route.ops import capacity_floor
    capacity = max(capacity_floor(args.alpha, n), 1)
    cands = DEFAULT_CANDIDATES
    if args.candidates:
        cands = tuple(int(c) for c in args.candidates.split(","))
    rec = autotune_budget_route(n, d_tok, capacity, candidates=cands,
                                repeats=args.repeats, device=args.device)
    print(f"budget_route autotune @ (n={n}, d={d_tok}, cap={capacity}) "
          f"[{rec.backend}{' device' if rec.device else ' interpret'}]")
    for block_n, t in rec.timings_s:
        tag = "  <-- winner" if block_n == rec.value else ""
        print(f"  block_n={block_n:<6d} {t * 1e3:8.2f} ms{tag}")
    if args.tuning_dir:
        tuning_store.get_store().flush()
        print(f"winner persisted to {args.tuning_dir}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(dataclasses.asdict(rec), f, indent=2)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
