from repro.kernels.budget_route.ops import budget_route
from repro.kernels.budget_route.ref import budget_route_ref
