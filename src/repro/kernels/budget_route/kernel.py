"""Pallas-TPU fused budget-route: threshold-select + compact-gather.

The AdaParse scheduling op (App. C): given per-document improvement
scores and the α-budget threshold τ (the ⌊αk⌋-th largest score, computed
by a cheap top-k outside), select and *compact* the routed documents'
token rows into a dense (capacity, D) buffer for the expensive parser —
one pass over the batch, no host round-trip, no full sort.

Selection rule (shared with ref.py and scheduler.plan_batch): rows with
score > τ are always selected — by definition of τ at most capacity−1
exist — while ties *at* τ consume a tie budget (capacity − |{s > τ}|,
computed outside) first-come in row order. A strictly better row is
therefore never displaced by a tie, and host/device pick identical sets.

Grid: (n_blocks,) sequential over score blocks. A 2-cell SMEM scratch
carries the running output offset and the running tie count across
blocks; within a block the write position is offset +
exclusive-cumsum(keep). Rows are written with dynamic stores.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _route_kernel(tau_ref, tiecap_ref, scores_ref, tokens_ref, out_ref,
                  idx_ref, count_ref, state_smem, *, block_n: int,
                  capacity: int, n_total: int):
    bi = pl.program_id(0)

    @pl.when(bi == 0)
    def _init():
        state_smem[0] = 0               # rows written so far
        state_smem[1] = 0               # ties at tau consumed so far
        count_ref[0] = 0
        idx_ref[...] = jnp.full_like(idx_ref, -1)

    tau = tau_ref[0]
    tie_cap = tiecap_ref[0]
    scores = scores_ref[...]                        # (block_n,)
    rows = bi * block_n + jax.lax.iota(jnp.int32, block_n)
    in_range = rows < n_total
    gt = (scores > tau) & in_range
    eq = (scores == tau) & in_range
    eq_i = eq.astype(jnp.int32)
    tie_rank = state_smem[1] + jnp.cumsum(eq_i) - eq_i
    keep = gt | (eq & (tie_rank < tie_cap))
    inc = keep.astype(jnp.int32)
    pos_in_block = jnp.cumsum(inc) - inc            # exclusive cumsum
    base = state_smem[0]
    positions = base + pos_in_block

    def write_row(i, _):
        @pl.when(keep[i] & (positions[i] < capacity))
        def _w():
            out_ref[pl.dslice(positions[i], 1), :] = tokens_ref[
                pl.dslice(i, 1), :]
            idx_ref[pl.dslice(positions[i], 1)] = rows[i][None]
        return 0

    jax.lax.fori_loop(0, block_n, write_row, 0)
    state_smem[0] = base + jnp.sum(inc)
    state_smem[1] = state_smem[1] + jnp.sum((eq & keep).astype(jnp.int32))

    @pl.when(bi == pl.num_programs(0) - 1)
    def _finish():
        count_ref[0] = jnp.minimum(state_smem[0], capacity)


@functools.partial(jax.jit, static_argnames=("capacity", "block_n",
                                             "interpret"))
def budget_route_kernel(scores, tokens, tau, *, capacity: int,
                        block_n: int = 256, interpret=True):
    """scores (N,) f32; tokens (N, D); tau scalar threshold.

    Returns (routed (capacity, D), idx (capacity,) int32 source rows
    (-1 = empty), count scalar int32).
    """
    n, d_tok = tokens.shape
    block_n = min(block_n, n)
    scores = scores.astype(jnp.float32)
    tau = jnp.asarray(tau, jnp.float32)
    # tie budget: slots left after every strictly-greater row is taken
    tie_cap = capacity - jnp.sum(scores > tau).astype(jnp.int32)
    pad = (-n) % block_n
    if pad:
        scores = jnp.pad(scores, (0, pad), constant_values=-jnp.inf)
        tokens = jnp.pad(tokens, ((0, pad), (0, 0)))
    n_pad = n + pad
    grid = (n_pad // block_n,)
    kern = functools.partial(_route_kernel, block_n=block_n,
                             capacity=capacity, n_total=n)
    out, idx, count = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),          # tau
            pl.BlockSpec(memory_space=pltpu.SMEM),          # tie budget
            pl.BlockSpec((block_n,), lambda i: (i,)),        # scores
            pl.BlockSpec((block_n, d_tok), lambda i: (i, 0)),  # tokens
        ],
        out_specs=[
            pl.BlockSpec((capacity, d_tok), lambda i: (0, 0)),
            pl.BlockSpec((capacity,), lambda i: (0,)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((capacity, d_tok), tokens.dtype),
            jax.ShapeDtypeStruct((capacity,), jnp.int32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        scratch_shapes=[pltpu.SMEM((2,), jnp.int32)],
        interpret=interpret,
    )(tau[None], tie_cap[None], scores, tokens)
    return out, idx, count[0]
