"""Pallas-TPU EmbeddingBag: fused gather + weighted segment reduce.

out[b] = combine_{l < L} w[b, l] * table[ids[b, l]]

The table stays in ANY/HBM memory space; rows are pulled with dynamic
loads inside the kernel (on real TPU this lowers to per-row DMA — the
FBGEMM-TBE pattern); ids/weights tiles and the output tile live in VMEM.
Grid: (n_batch_blocks,).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bag_kernel(ids_ref, w_ref, table_ref, out_ref, *, bag: int,
                block_b: int, mean: bool):
    acc = jnp.zeros(out_ref.shape, jnp.float32)

    ids = ids_ref[...]
    ws = w_ref[...]

    def body(l, acc):
        def row(b, acc):
            rid = jax.lax.dynamic_index_in_dim(ids, b, 0,
                                               keepdims=False)[l]
            vec = table_ref[pl.dslice(rid, 1), :].astype(jnp.float32)
            wv = jax.lax.dynamic_index_in_dim(ws, b, 0, keepdims=False)[l]
            return jax.lax.dynamic_update_slice_in_dim(
                acc, jax.lax.dynamic_slice_in_dim(acc, b, 1) + vec * wv,
                b, axis=0)
        return jax.lax.fori_loop(0, block_b, row, acc)

    acc = jax.lax.fori_loop(0, bag, body, acc)
    if mean:
        denom = jnp.maximum(jnp.sum(w_ref[...], axis=1), 1e-9)[:, None]
        acc = acc / denom
    out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("combiner", "block_b",
                                             "interpret"))
def embedding_bag_kernel(table, ids, weights, *, combiner: str = "sum",
                         block_b: int = 64, interpret=True):
    """table (R, D); ids (B, L) int32; weights (B, L) f32 -> (B, D)."""
    b, bag = ids.shape
    r, d = table.shape
    block_b = min(block_b, b)
    pad = (-b) % block_b
    if pad:
        ids = jnp.pad(ids, ((0, pad), (0, 0)))
        weights = jnp.pad(weights, ((0, pad), (0, 0)))
    grid = ((b + pad) // block_b,)
    kern = functools.partial(_bag_kernel, bag=bag, block_b=block_b,
                             mean=(combiner == "mean"))
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, bag), lambda i: (i, 0)),
            pl.BlockSpec((block_b, bag), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),       # full table
        ],
        out_specs=pl.BlockSpec((block_b, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b + pad, d), table.dtype),
        interpret=interpret,
    )(ids.astype(jnp.int32), weights.astype(jnp.float32), table)
    return out[:b]
