"""Pure-jnp oracle: take + weighted sum (the manual JAX EmbeddingBag)."""
from __future__ import annotations

import jax.numpy as jnp


def embedding_bag_ref(table, ids, weights, *, combiner: str = "sum"):
    emb = jnp.take(table, ids, axis=0).astype(jnp.float32)
    out = (emb * weights[..., None]).sum(axis=1)
    if combiner == "mean":
        out = out / jnp.maximum(weights.sum(axis=1), 1e-9)[:, None]
    return out.astype(table.dtype)
