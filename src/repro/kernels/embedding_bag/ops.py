"""Public EmbeddingBag wrapper."""
from __future__ import annotations

import jax

from repro.kernels.embedding_bag.kernel import embedding_bag_kernel
from repro.kernels.embedding_bag.ref import embedding_bag_ref


def embedding_bag(table, ids, weights=None, *, combiner: str = "sum",
                  force_kernel=False):
    import jax.numpy as jnp
    if weights is None:
        weights = jnp.ones(ids.shape, jnp.float32)
    if force_kernel or jax.default_backend() == "tpu":
        return embedding_bag_kernel(
            table, ids, weights, combiner=combiner,
            interpret=jax.default_backend() != "tpu")
    return embedding_bag_ref(table, ids, weights, combiner=combiner)
